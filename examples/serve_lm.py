"""Batched serving example: prefill + KV-cache decode for three families.

Exercises the same prefill/decode step functions that the multi-pod dry-run
lowers at production scale — full-attention (olmo), sliding-window + local
rings (gemma3-style), and state-space (mamba2).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import serve


def main():
    for arch in ("olmo-1b", "gemma3-12b", "mamba2-780m"):
        out = serve(
            arch=arch, smoke=True, batch=4, prompt_len=24,
            max_new_tokens=12,
        )
        print(f"  {arch} sample token ids: {out[0][:8].tolist()}")


if __name__ == "__main__":
    main()
