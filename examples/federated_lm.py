"""End-to-end driver: federated training of a ~100M-param LM.

Two federated pods (EC sites) train disjoint shards of a synthetic token
stream with local AdamW steps; every round the pod models are FedAvg'd over
the pod axis with int8-compressed updates (the paper's M_i^UD lever), and
round wall-clock comes from the PON co-simulation under bandwidth slicing.
Checkpoints every round; kill and re-run to see restart.

The ~100M configuration is a scaled olmo-family model (12L, d=768). A few
hundred steps run in tens of minutes on this 1-core container; pass
--steps/--rounds to trim.

Run:  PYTHONPATH=src python examples/federated_lm.py --steps 150 --rounds 2
"""
import argparse

from repro.launch.train import train

# ~100M params: 12L x d768 x ff3072, vocab 32000 (olmo-style family)
CONFIG_100M = dict(
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_head=64,
    d_ff=3072, vocab_size=32000, dtype="float32", param_dtype="float32",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_fedlm_ckpt")
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-size model instead of ~100M")
    args = ap.parse_args()

    overrides = None if args.tiny else CONFIG_100M
    state, history = train(
        arch="olmo-1b",
        smoke=True,                      # base config; overridden below
        steps_per_round=args.steps,
        rounds=args.rounds,
        n_pods=2,
        global_batch=args.batch,
        seq_len=args.seq,
        lr=1e-3,
        ckpt_dir=args.ckpt_dir,
        policy="bs",
        load=0.8,
        compress="int8",
        config_overrides=overrides,
    )
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} over {len(history)} rounds")
    assert last < first, "loss should decrease"


if __name__ == "__main__":
    main()
