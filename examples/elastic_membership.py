"""Elastic membership + fault tolerance: the BS re-trigger in action.

The paper: "The BS algorithm is triggered only when new clients join or
leave the FL task." This example runs FL rounds while clients join, fail
mid-round, and leave — the SliceManager recomputes the slice exactly on
membership changes; deadline-partial aggregation keeps training alive; a
checkpoint restart resumes cleanly.

Run:  PYTHONPATH=src python examples/elastic_membership.py
"""
import os
import shutil

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.membership import SliceManager
from repro.core.slicing import ClientProfile
from repro.data import build_federated_cnn_clients
from repro.fl import CPSServer, SelectionConfig
from repro.fl.client import LocalTrainConfig
from repro.models import cnn

CKPT = "/tmp/repro_elastic_ckpt"
M_BITS = 26.416e6


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    clients, test = build_federated_cnn_clients(
        n_clients=10, samples_per_client=48, loss_fn=cnn.loss_fn,
        train_cfg=LocalTrainConfig(lr=0.05, batch_size=16), seed=0,
    )
    test_batch = {"images": test["images"][:256],
                  "labels": test["labels"][:256]}

    server = CPSServer(
        global_params=cnn.init_params(jax.random.PRNGKey(0)),
        clients=clients[:6],                       # start with 6 clients
        selection=SelectionConfig(strategy="all"),
        failure_prob=0.15,                         # clients fail mid-round
        seed=0,
    )
    mgr = SliceManager(capacity_bps=10e9 * 0.92, t_round=10.0)
    mgr.bootstrap(server.profiles(M_BITS))
    ckpt = CheckpointManager(CKPT, keep=2, use_async=False)

    def report(tag):
        s = mgr.current_slice
        print(
            f"  [{tag}] slice: B={s.bandwidth_bps/1e6:7.1f} Mbps "
            f"window=[{s.t_min:.2f}, {s.t_max:.2f}]s "
            f"recomputes={mgr.recompute_count}"
        )

    report("bootstrap")
    for rnd in range(6):
        log = server.run_round(eval_fn=lambda p: cnn.accuracy(p, test_batch))
        mgr.on_round(float(rnd))                  # no recomputation
        print(
            f"round {rnd}: arrived {log.n_arrived}/{log.n_selected} "
            f"acc={log.eval_metric:.3f}"
        )
        ckpt.save(rnd, server.global_params, metadata={"round": rnd})

        if rnd == 1:                               # two clients JOIN
            for c in clients[6:8]:
                server.clients.append(c)
                mgr.join(
                    ClientProfile(c.client_id, c.t_ud_s, 0.0, M_BITS),
                    t_now=float(rnd),
                )
            report("after join x2")
        if rnd == 3:                               # one client LEAVES
            gone = server.clients.pop(0)
            mgr.leave(gone.client_id, t_now=float(rnd))
            report("after leave")

    # crash + restart: restore the newest valid checkpoint
    restored, meta = ckpt.restore_latest(like=server.global_params)
    acc = float(cnn.accuracy(restored, test_batch))
    print(f"restart from checkpoint round {meta['round']}: acc={acc:.3f}")
    assert mgr.recompute_count == 4  # bootstrap + 2 joins... (joins batch=2)


if __name__ == "__main__":
    main()
