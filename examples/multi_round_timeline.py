"""Multi-round timelines: elastic membership + round deadlines.

Drives the whole training timeline as one stacked simulation
(`repro.net.timeline`): 12 rounds of the paper's operating point under
FCFS and BS, with a quarter of the clients sitting out each round
(elastic membership), then the same sweep under a hard round deadline —
stragglers *defer* their unserved update bits into the next round
instead of being dropped.

Run:  PYTHONPATH=src python examples/multi_round_timeline.py
"""
import numpy as np

from repro.core.slicing import ClientProfile
from repro.net import (
    FLRoundWorkload,
    PONConfig,
    SweepCase,
    SweepSpec,
    TimelineSchedule,
    simulate,
)

M_BITS = 26.416e6
N = 128
R = 12


def main():
    rng = np.random.default_rng(42)
    clients = [
        ClientProfile(client_id=i, t_ud=float(t), t_dl=0.0,
                      m_ud_bits=M_BITS)
        for i, t in enumerate(rng.uniform(1.0, 5.0, N))
    ]
    wl = FLRoundWorkload(clients=clients, model_bits=M_BITS)
    cfg = PONConfig(n_onus=N)
    cases = [
        SweepCase(workload=wl, load=0.8, policy=policy, seed=0)
        for policy in ("fcfs", "bs")
    ]

    spec = SweepSpec(cases=tuple(cases), pon=cfg)

    membership = rng.random((R, N)) < 0.75
    membership[0] = True
    sched = TimelineSchedule(n_rounds=R, membership=membership)
    print(f"== {R} rounds, elastic membership (75% per round), load 0.8")
    for case, tl in zip(cases, simulate(spec.with_schedule(sched))):
        print(
            f"  {case.policy:4s} per-round sync "
            f"{np.round(tl.sync_times, 2)}  total={tl.total_time_s:.1f}s"
        )

    deadline = 5.5
    sched_d = TimelineSchedule(n_rounds=R, membership=membership,
                               deadline_s=deadline)
    print(f"== same sweep under a {deadline}s round deadline (defer)")
    for case, tl in zip(cases, simulate(spec.with_schedule(sched_d))):
        deferred = sum(len(r.deferred) for r in tl.rounds)
        print(
            f"  {case.policy:4s} total={tl.total_time_s:.1f}s "
            f"deferred-uploads={deferred} "
            f"(per round: {[len(r.deferred) for r in tl.rounds]})"
        )


if __name__ == "__main__":
    main()
