"""Quickstart: the paper's system end to end in ~a minute on CPU.

Federated training of the LEAF FEMNIST CNN across 8 EC clients, co-simulated
over the PON under both bandwidth policies. Shows the paper's claim: same
learning curve, less wall-clock under bandwidth slicing.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.data import build_federated_cnn_clients
from repro.fl import (
    CoSimConfig,
    CPSServer,
    FLNetworkCoSim,
    SelectionConfig,
)
from repro.fl.client import LocalTrainConfig
from repro.models import cnn
from repro.net.sim import PONConfig

N_CLIENTS = 8
N_ROUNDS = 5
LOAD = 0.8


def build(policy: str):
    clients, test = build_federated_cnn_clients(
        n_clients=N_CLIENTS,
        samples_per_client=64,
        loss_fn=cnn.loss_fn,
        train_cfg=LocalTrainConfig(lr=0.06, batch_size=16, local_epochs=1),
        seed=0,
    )
    server = CPSServer(
        global_params=cnn.init_params(jax.random.PRNGKey(0)),
        clients=clients,
        selection=SelectionConfig(strategy="fraction", fraction=1.0),
        seed=0,
    )
    # scaled-down edge deployment: 8 EC nodes on a 1 Gbps access PON
    # (the paper's 128-node/10G setting is exercised by benchmarks/fig2b)
    sim = FLNetworkCoSim(
        server,
        CoSimConfig(policy=policy, total_load=LOAD,
                    pon=PONConfig(n_onus=max(N_CLIENTS, 8),
                                  line_rate_bps=1e9), timing_seeds=1),
    )
    test_batch = {"images": test["images"][:256],
                  "labels": test["labels"][:256]}
    return sim, (lambda p: cnn.accuracy(p, test_batch))


def main():
    results = {}
    for policy in ("bs", "fcfs"):
        sim, eval_fn = build(policy)
        res = sim.run(n_rounds=N_ROUNDS, eval_fn=eval_fn)
        results[policy] = res
        print(f"\n=== {policy.upper()} @ load {LOAD} ===")
        for r in res.rounds:
            print(
                f" round {r['round']}: acc={r['eval_metric']:.3f} "
                f"loss={r['mean_loss']:.3f} sync={r['sync_time_s']:.2f}s"
            )
        print(f" total wall-clock: {res.total_time_s:.1f}s")

    bs, fcfs = results["bs"], results["fcfs"]
    saving = 100 * (1 - bs.total_time_s / fcfs.total_time_s)
    print(
        f"\nBandwidth slicing saved {saving:.1f}% training time at load "
        f"{LOAD} (same rounds, same accuracy — the paper's headline claim)."
    )


if __name__ == "__main__":
    main()
