"""pixtral-12b [vlm] — Pixtral-ViT frontend (stub) + Mistral-Nemo backbone.

40L d_model=5120 32H (GQA kv=8, d_head=128) d_ff=14336 vocab=131072.
[hf:mistralai/Pixtral-12B-2409; unverified]

The vision frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed patch embeddings (B, 256, d_model) prepended to token embeddings.
"""
from repro.configs import register
from repro.configs.base import ATTN, LayerSpec, ModelConfig


@register
def pixtral_12b() -> ModelConfig:
    return ModelConfig(
        attn_impl="chunked",
        name="pixtral-12b",
        family="vlm",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=14336,
        vocab_size=131072,
        pattern=(LayerSpec(ATTN),),
        rope_theta=1_000_000.0,
        frontend="vision",
        n_frontend_tokens=256,
        grad_accum=8,
    )
