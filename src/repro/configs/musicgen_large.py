"""musicgen-large [audio] — decoder-only over EnCodec tokens.

48L d_model=2048 32H (kv=32 -> MHA, d_head=64) d_ff=8192 vocab=2048.
[arXiv:2306.05284; hf]

Backbone only per the assignment: the EnCodec frontend is a STUB —
``input_specs()`` supplies precomputed conditioning frame embeddings
(B, 64, d_model) prepended to the codec-token stream. GELU MLP + additive
sinusoidal positions (the MusicGen transformer), no RoPE.
"""
from repro.configs import register
from repro.configs.base import ATTN, LayerSpec, ModelConfig


@register
def musicgen_large() -> ModelConfig:
    return ModelConfig(
        attn_impl="chunked",
        name="musicgen-large",
        family="audio",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_head=64,
        d_ff=8192,
        vocab_size=2048,
        pattern=(LayerSpec(ATTN),),
        mlp_act="gelu",
        use_rope=False,
        abs_sinusoidal=True,
        norm="layernorm",
        frontend="audio",
        n_frontend_tokens=64,
        grad_accum=4,
    )
