"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.

56L d_model=6144 48H (GQA kv=8, d_head=128) d_ff=16384 vocab=32768.
[arXiv:2401.04088; hf]
"""
from repro.configs import register
from repro.configs.base import ATTN, LayerSpec, ModelConfig, MoEConfig

SWA_WINDOW = 4096


@register
def mixtral_8x22b() -> ModelConfig:
    return ModelConfig(
        attn_impl="chunked",
        name="mixtral-8x22b",
        family="moe",
        n_layers=56,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_head=128,
        d_ff=16384,
        vocab_size=32768,
        pattern=(LayerSpec(ATTN, window=SWA_WINDOW),),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16384),
        rope_theta=1_000_000.0,
        fsdp=True,
        remat="full",
        grad_accum=8,
    )
