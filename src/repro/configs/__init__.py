"""Architecture configs — one module per assigned architecture.

``get_config(name)`` returns the full-size config; ``get_config(name,
smoke=True)`` returns the reduced same-family config for CPU smoke tests.
"""
from __future__ import annotations

from repro.configs.base import (  # noqa: F401
    ALL_SHAPES,
    ATTN,
    RGLRU,
    SHAPES_BY_NAME,
    SSD,
    InputShape,
    LayerSpec,
    ModelConfig,
    MoEConfig,
    RecurrentConfig,
    SSMConfig,
    applicable_shapes,
    param_count,
)

_REGISTRY = {}


def register(fn):
    _REGISTRY[fn.__name__] = fn
    return fn


def _load_all():
    # import side-effect registers each arch
    from repro.configs import (  # noqa: F401
        arctic_480b,
        gemma3_12b,
        llama3_8b,
        mamba2_780m,
        mixtral_8x22b,
        musicgen_large,
        olmo_1b,
        pixtral_12b,
        qwen3_14b,
        recurrentgemma_2b,
    )


def list_architectures():
    _load_all()
    return sorted(_REGISTRY)


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    _load_all()
    key = name.replace("-", "_")
    if key not in _REGISTRY:
        raise KeyError(
            f"unknown architecture {name!r}; have {sorted(_REGISTRY)}"
        )
    cfg = _REGISTRY[key]()
    return cfg.smoke() if smoke else cfg
