"""qwen3-14b [dense] — GQA with qk-norm.

40L d_model=5120 40H (GQA kv=8, d_head=128) d_ff=17408 vocab=151936.
[hf:Qwen/Qwen3-8B; hf]
"""
from repro.configs import register
from repro.configs.base import ATTN, LayerSpec, ModelConfig


@register
def qwen3_14b() -> ModelConfig:
    return ModelConfig(
        attn_impl="chunked",
        name="qwen3-14b",
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_head=128,
        d_ff=17408,
        vocab_size=151936,
        pattern=(LayerSpec(ATTN),),
        qk_norm=True,
        rope_theta=1_000_000.0,
        grad_accum=8,
    )
