"""mamba2-780m [ssm] — attention-free SSD (state-space duality).

48L d_model=1536 d_ff=0 vocab=50280, ssm_state=128, expand=2, head 64.
[arXiv:2405.21060; unverified]
"""
from repro.configs import register
from repro.configs.base import SSD, LayerSpec, ModelConfig, SSMConfig


@register
def mamba2_780m() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m",
        family="ssm",
        n_layers=48,
        d_model=1536,
        n_heads=1,          # unused (attention-free)
        n_kv_heads=1,
        d_head=64,
        d_ff=0,
        vocab_size=50280,
        pattern=(LayerSpec(SSD),),
        ssm=SSMConfig(d_state=128, expand=2, d_head=64, d_conv=4, chunk=128),
        use_rope=False,
        tie_embeddings=True,
        grad_accum=1,
    )
