"""Configuration schema for the repro framework.

Every assigned architecture is described by a frozen ``ModelConfig``. The model
zoo (``repro.models``) is driven entirely by this schema — no per-arch model
code. Layer stacking is expressed as a repeating *pattern unit* (a tuple of
``LayerKind``) so heterogeneous stacks (gemma3's 5 local : 1 global,
recurrentgemma's rec-rec-attn) scan cleanly over stacked unit params.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer pattern
# ---------------------------------------------------------------------------

ATTN = "attn"
RGLRU = "rglru"
SSD = "ssd"


@dataclass(frozen=True)
class LayerSpec:
    """One block position inside the repeating pattern unit."""

    kind: str = ATTN            # "attn" | "rglru" | "ssd"
    window: Optional[int] = None  # sliding-window size; None = global attention

    def __post_init__(self):
        if self.kind not in (ATTN, RGLRU, SSD):
            raise ValueError(f"unknown layer kind {self.kind!r}")


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    dense_residual: bool = False    # arctic: dense FFN in parallel with MoE
    load_balance_weight: float = 0.01
    capacity_factor: float = 1.25   # >= n_experts/top_k -> dropless
    group_tokens: int = 8192        # dispatch group size (GShard G axis);
                                    # bounds the (g, E, C) dispatch tensors
    router_dtype: str = "float32"


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    d_head: int = 64
    d_conv: int = 4
    chunk: int = 128              # SSD chunk length (MXU-aligned)


@dataclass(frozen=True)
class RecurrentConfig:
    rnn_width: int = 2560
    d_conv: int = 4
    c_const: float = 8.0          # RG-LRU exponent constant


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(ATTN),)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    recurrent: Optional[RecurrentConfig] = None

    norm: str = "rmsnorm"         # rmsnorm | layernorm | layernorm_nonparam
    mlp_act: str = "swiglu"       # swiglu | gelu
    qk_norm: bool = False
    use_rope: bool = True
    abs_sinusoidal: bool = False  # musicgen-style additive position embedding
    rope_theta: float = 10000.0
    embed_scale: bool = False     # gemma-style sqrt(d_model) embedding scale
    tie_embeddings: bool = False
    logit_softcap: float = 0.0    # gemma-style tanh soft-capping (0 = off)

    frontend: Optional[str] = None   # None | "vision" | "audio"
    n_frontend_tokens: int = 0       # prepended patch/frame embeddings (stub)

    # numerics
    dtype: str = "bfloat16"          # activation/compute dtype
    param_dtype: str = "float32"     # parameter storage dtype
    kv_cache_dtype: str = "bfloat16"  # "bfloat16" | "int8" (quantised cache)

    # implementation switches
    attn_impl: str = "reference"     # "reference" (XLA) | "pallas"
    remat: str = "full"              # none | full | dots  (activation ckpt)
    grad_accum: int = 1              # microbatch accumulation steps

    # distribution knobs (consumed by repro.dist.sharding)
    fsdp: bool = False               # shard params over the data axis too
    zero_opt: bool = True            # shard optimizer state over data axis
    opt_state_dtype: str = "float32"

    # ----- derived -----
    @property
    def unit_len(self) -> int:
        return len(self.pattern)

    @property
    def n_units(self) -> int:
        return self.n_layers // self.unit_len

    @property
    def n_remainder(self) -> int:
        return self.n_layers % self.unit_len

    @property
    def remainder_pattern(self) -> Tuple[LayerSpec, ...]:
        return self.pattern[: self.n_remainder]

    @property
    def d_attn(self) -> int:
        return self.n_heads * self.d_head

    @property
    def has_attention(self) -> bool:
        return any(s.kind == ATTN for s in self.pattern)

    @property
    def max_window(self) -> Optional[int]:
        """Largest attention window; None if any attention layer is global."""
        windows = [s.window for s in self.pattern if s.kind == ATTN]
        if not windows:
            return 0
        if any(w is None for w in windows):
            return None
        return max(windows)

    @property
    def is_subquadratic(self) -> bool:
        """True if no layer needs an unbounded KV cache (long_500k eligible)."""
        return self.max_window is not None

    @property
    def supports_long_context(self) -> bool:
        # gemma3 keeps 1 global layer per unit but the 5 local layers bound the
        # bulk of the cache; per the assignment hybrid/windowed archs run
        # long_500k while *pure* full-attention archs skip it.
        windows = [s.window for s in self.pattern if s.kind == ATTN]
        if not windows:            # attention-free => trivially long-context
            return True
        n_global = sum(1 for w in windows if w is None)
        return n_global < len(windows) or len(windows) < len(self.pattern)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # A reduced config of the same family for CPU smoke tests.
    def smoke(self) -> "ModelConfig":
        kw = dict(
            attn_impl="reference",
            kv_cache_dtype="bfloat16",   # exact decode parity in tests
            n_layers=min(self.n_layers, 2 * self.unit_len),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads > 1 else 1,
            d_head=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=128,
            dtype="float32",
            param_dtype="float32",
            n_frontend_tokens=4 if self.frontend else 0,
        )
        # shrink windows so tests exercise the masking path
        pat = tuple(
            LayerSpec(s.kind, None if s.window is None else min(s.window, 8))
            for s in self.pattern
        )
        kw["pattern"] = pat
        if self.moe is not None:
            n_e = min(self.moe.n_experts, 4)
            # dropless capacity so smoke tests check exact train/decode parity
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=n_e, d_ff_expert=64,
                capacity_factor=float(n_e) / self.moe.top_k,
            )
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, d_head=16, chunk=8
            )
        if self.recurrent is not None:
            kw["recurrent"] = dataclasses.replace(self.recurrent, rnn_width=64)
        return self.replace(**kw)


# ---------------------------------------------------------------------------
# Input shapes (the assigned shape set)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

ALL_SHAPES: Tuple[InputShape, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def applicable_shapes(cfg: ModelConfig) -> Tuple[InputShape, ...]:
    """Shapes that run for this arch (long_500k only for sub-quadratic)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.supports_long_context:
        out.append(LONG_500K)
    return tuple(out)


# ---------------------------------------------------------------------------
# Parameter counting (for roofline MODEL_FLOPS = 6·N·D)
# ---------------------------------------------------------------------------


def param_count(cfg: ModelConfig) -> dict:
    """Analytic parameter counts: total and per-token-active (MoE-aware)."""
    D, F, V = cfg.d_model, cfg.d_ff, cfg.vocab_size
    H, K, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head

    def attn_params():
        p = D * H * Dh + 2 * D * K * Dh + H * Dh * D
        if cfg.qk_norm:
            p += 2 * Dh
        return p

    def mlp_params(f):
        if f == 0:
            return 0
        mult = 3 if cfg.mlp_act == "swiglu" else 2
        return mult * D * f

    def norm_params():
        return 0 if cfg.norm == "layernorm_nonparam" else D

    total = 0
    active = 0
    layers = list(cfg.pattern) * cfg.n_units + list(cfg.remainder_pattern)
    for spec in layers:
        if spec.kind == ATTN:
            p = attn_params() + 2 * norm_params()
            total += p
            active += p
            if cfg.moe is not None:
                e = cfg.moe
                expert = mlp_params(e.d_ff_expert)
                total += D * e.n_experts + e.n_experts * expert
                active += D * e.n_experts + e.top_k * expert
                if e.dense_residual:
                    total += mlp_params(F)
                    active += mlp_params(F)
            else:
                total += mlp_params(F)
                active += mlp_params(F)
        elif spec.kind == RGLRU:
            R = cfg.recurrent.rnn_width
            p = 2 * D * R + R * D + 2 * R + cfg.recurrent.d_conv * R
            p += norm_params() + mlp_params(F) + norm_params()
            total += p
            active += p
        elif spec.kind == SSD:
            s = cfg.ssm
            d_in = s.expand * D
            d_xbc = d_in + 2 * s.d_state
            n_h = d_in // s.d_head
            p = D * (2 * d_in + 2 * s.d_state + n_h)   # in_proj (z,x,B,C,dt)
            p += s.d_conv * d_xbc                       # conv
            p += 2 * n_h + d_in                         # A_log, D skip, gate-norm
            p += d_in * D                               # out_proj
            p += norm_params()
            total += p
            active += p
    emb = V * D
    total += emb + norm_params()
    active += norm_params()
    # embedding lookup is sparse; lm head matmul is dense-active
    if not cfg.tie_embeddings:
        total += D * V
    total_with_emb = total
    active += D * V  # lm head
    return {
        "total": int(total_with_emb),
        "active": int(active),
        "embedding": int(emb),
    }
