"""gemma3-12b [dense] — 5 local : 1 global attention interleave, 128k context.

48L d_model=3840 16H (GQA kv=8, d_head=256) d_ff=15360 vocab=262144.
Pattern unit: 5×local(w=1024) + 1×global; 48 = 8 units.
[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.configs import register
from repro.configs.base import ATTN, LayerSpec, ModelConfig

LOCAL_WINDOW = 1024


@register
def gemma3_12b() -> ModelConfig:
    local = LayerSpec(ATTN, window=LOCAL_WINDOW)
    return ModelConfig(
        attn_impl="chunked",
        name="gemma3-12b",
        family="dense",
        n_layers=48,
        d_model=3840,
        n_heads=16,
        n_kv_heads=8,
        d_head=256,
        d_ff=15360,
        vocab_size=262144,
        pattern=(local, local, local, local, local, LayerSpec(ATTN)),
        qk_norm=True,
        embed_scale=True,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        grad_accum=8,
    )
