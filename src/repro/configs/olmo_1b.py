"""olmo-1b [dense] — MHA with non-parametric LayerNorm.

16L d_model=2048 16H (kv=16 -> MHA, d_head=128) d_ff=8192 vocab=50304.
[arXiv:2402.00838; hf]
"""
from repro.configs import register
from repro.configs.base import ATTN, LayerSpec, ModelConfig


@register
def olmo_1b() -> ModelConfig:
    return ModelConfig(
        attn_impl="chunked",
        name="olmo-1b",
        family="dense",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_head=128,
        d_ff=8192,
        vocab_size=50304,
        pattern=(LayerSpec(ATTN),),
        norm="layernorm_nonparam",
        tie_embeddings=True,
        grad_accum=2,
    )
