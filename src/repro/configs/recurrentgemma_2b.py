"""recurrentgemma-2b [hybrid] — Griffin: RG-LRU + local attention, 1 attn : 2 rec.

26L d_model=2560 10H (MQA kv=1, d_head=256) d_ff=7680 vocab=256000.
Pattern unit (rec, rec, local-attn w=2048); 26 = 8 units + 2 remainder rec.
[arXiv:2402.19427; hf]
"""
from repro.configs import register
from repro.configs.base import (
    ATTN,
    RGLRU,
    LayerSpec,
    ModelConfig,
    RecurrentConfig,
)


@register
def recurrentgemma_2b() -> ModelConfig:
    return ModelConfig(
        attn_impl="chunked",
        name="recurrentgemma-2b",
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        d_head=256,
        d_ff=7680,
        vocab_size=256000,
        pattern=(LayerSpec(RGLRU), LayerSpec(RGLRU), LayerSpec(ATTN, window=2048)),
        recurrent=RecurrentConfig(rnn_width=2560),
        embed_scale=True,
        grad_accum=2,
    )
