"""arctic-480b [moe] — Snowflake Arctic dense-MoE hybrid.

35L d_model=7168 56H (GQA kv=8, d_head=128) d_ff=4864 vocab=32000,
MoE 128 experts top-2 with a dense residual FFN in parallel.
[hf:Snowflake/snowflake-arctic-base; hf]

Memory note: 480B params force FSDP-style param sharding over the data axis
and bf16 optimizer moments to fit 16 GB/chip on a 256-chip pod (see
EXPERIMENTS.md §Perf for the sizing math).
"""
from repro.configs import register
from repro.configs.base import ATTN, LayerSpec, ModelConfig, MoEConfig


@register
def arctic_480b() -> ModelConfig:
    return ModelConfig(
        attn_impl="chunked",
        name="arctic-480b",
        family="moe",
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_head=128,
        d_ff=4864,
        vocab_size=32000,
        pattern=(LayerSpec(ATTN),),
        moe=MoEConfig(
            n_experts=128, top_k=2, d_ff_expert=4864, dense_residual=True
        ),
        fsdp=True,
        param_dtype="bfloat16",     # 480B fp32 params cannot fit 16 GB/chip
        kv_cache_dtype="int8",      # 6 TB bf16 KV cache > HBM at decode_32k
        opt_state_dtype="bfloat16",
        remat="full",
        grad_accum=8,
    )
