"""Distribution layer: sharding rules + sharded step functions.

The repo maps the paper's edge topology onto a TPU-style device mesh
(DESIGN.md §3): each EC-node site is one *pod* of the mesh, the BS slice
carries the cross-pod FedAvg traffic, and inside a pod the usual
data/tensor parallel axes apply. Two modules implement that mapping:

``repro.dist.sharding``
    Pure spec logic — ``PartitionSpec`` rules for parameters, optimizer
    moments, batches and KV caches over the ``("pod", "data", "model")``
    mesh from ``repro.launch.mesh``. No device state is touched, so the
    rules work on ``AbstractMesh`` (tests) and real meshes alike.

``repro.dist.stepfns``
    Jit-able step functions built on those rules: single-pod train step,
    per-pod federated train step (local SGD with grad accumulation),
    cross-pod FedAvg round step with int8/top-k update compression
    (``repro.dist.fedops``), and the prefill/decode serving steps.
"""
from repro.dist import fedops, sharding, stepfns  # noqa: F401
from repro.dist.sharding import (  # noqa: F401
    batch_spec,
    cache_specs,
    opt_moment_specs,
    param_spec,
    param_specs,
)
from repro.dist.stepfns import (  # noqa: F401
    AsyncRoundState,
    TrainState,
    fed_update_bits,
    init_async_state,
    init_fed_state,
    init_train_state,
    make_async_round_step,
    make_decode_step,
    make_fed_round_step,
    make_fed_train_step,
    make_prefill_step,
    make_train_step,
)
