"""Sharded step functions: train, federated train/round, prefill, decode.

All step functions are pure and jit-able; sharding comes from the caller
(``jax.jit`` in/out shardings built by ``repro.launch.specs`` from the
rules in ``repro.dist.sharding``), so the same code runs on one host
device, the 8-device test mesh and the 512-chip production mesh.

Federated layout: every leaf of a federated ``TrainState`` carries a
leading ``n_pods`` axis, sharded over the ``pod`` mesh axis (one pod per
EC-node site, DESIGN.md §3). ``make_fed_train_step`` vmaps the single-pod
step over that axis — local SGD with no cross-pod traffic — and
``make_fed_round_step`` performs the weighted FedAvg reduction whose
upload payload (``M_i^UD``) the paper's BS slice is provisioned for.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist import fedops
from repro.fl.compression import CompressorConfig, compressed_update_bits
from repro.models import lm
from repro.optim.optimizers import (
    OptimizerConfig,
    OptState,
    apply_updates,
    init_opt_state,
)


class TrainState(NamedTuple):
    params: Any
    opt: OptState


# ---------------------------------------------------------------------------
# state init
# ---------------------------------------------------------------------------


def init_train_state(key, cfg: ModelConfig,
                     opt_cfg: OptimizerConfig) -> TrainState:
    params = lm.init_params(key, cfg)
    return TrainState(params=params, opt=init_opt_state(params, opt_cfg))


def init_fed_state(key, cfg: ModelConfig, opt_cfg: OptimizerConfig,
                   n_pods: int) -> TrainState:
    """Replicate one init across a leading ``n_pods`` axis on every leaf.

    All pods start each experiment from the same global model (the CPS
    broadcast); they diverge through local steps and re-sync at rounds.
    """
    base = init_train_state(key, cfg, opt_cfg)
    return jax.tree.map(
        lambda l: jnp.broadcast_to(l[None], (n_pods,) + l.shape), base
    )


# ---------------------------------------------------------------------------
# training
# ---------------------------------------------------------------------------


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: OptimizerConfig,
    schedule: Optional[Callable] = None,
    grad_shardings: Optional[Any] = None,
) -> Callable:
    """Single-pod SGD step with microbatch grad accumulation.

    ``step(state, batch) -> (state, metrics)`` where batch leaves are
    ``(B, ...)``; with ``cfg.grad_accum > 1`` the batch is split into
    ``grad_accum`` microbatches scanned sequentially (grads averaged in
    fp32), so the global batch fits regardless of per-device memory.

    ``grad_shardings`` (a pytree of ``NamedSharding`` matching the param
    tree) pins the accumulated fp32 gradients — and the averaged grads
    fed to the optimizer — to the parameters' layout via
    ``jax.lax.with_sharding_constraint``. Without it, GSPMD is free to
    keep the scan carry in a different layout than the ZeRO-sharded
    optimizer update consumes, which shows up as involuntary resharding
    (reported by XLA between the grad-accum scan and the update).
    """
    accum = max(int(cfg.grad_accum), 1)

    def constrain(grads):
        if grad_shardings is None:
            return grads
        return jax.lax.with_sharding_constraint(grads, grad_shardings)

    def loss_of(params, batch):
        return lm.loss_fn(params, cfg, batch)

    def step(state: TrainState, batch):
        if accum > 1:
            micro = jax.tree.map(
                lambda x: x.reshape(
                    (accum, x.shape[0] // accum) + x.shape[1:]
                ),
                batch,
            )

            def body(carry, mb):
                g_sum, l_sum = carry
                loss, g = jax.value_and_grad(loss_of)(state.params, mb)
                g_sum = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), g_sum, g
                )
                return (constrain(g_sum), l_sum + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (g_sum, l_sum), _ = jax.lax.scan(
                body, (constrain(zeros), jnp.zeros((), jnp.float32)), micro
            )
            g_sum = constrain(g_sum)
            grads = jax.tree.map(
                lambda g, p: (g / accum).astype(p.dtype),
                g_sum, state.params,
            )
            loss = l_sum / accum
        else:
            loss, grads = jax.value_and_grad(loss_of)(state.params, batch)
        grads = constrain(grads)

        lr = jnp.asarray(
            schedule(state.opt.step) if schedule is not None else opt_cfg.lr,
            jnp.float32,
        )
        params, opt, gnorm = apply_updates(
            state.params, grads, state.opt, opt_cfg, lr=lr
        )
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return TrainState(params=params, opt=opt), metrics

    return step


def make_fed_train_step(
    cfg: ModelConfig,
    opt_cfg: OptimizerConfig,
    schedule: Optional[Callable] = None,
    grad_shardings: Optional[Any] = None,
    spmd_axis_name: Optional[str] = None,
) -> Callable:
    """Per-pod local step over the federated (pod-stacked) state.

    ``batch`` leaves are ``(n_pods, per_pod_B, ...)``; the single-pod
    step is vmapped over the pod axis, so under the ``("pod", "data",
    "model")`` mesh each pod trains on its own shard with zero cross-pod
    communication — exactly the paper's local-epoch phase.

    ``grad_shardings`` are *per-pod* (pod axis stripped) shardings for
    the accumulated gradients; pass ``spmd_axis_name="pod"`` so vmap
    prepends the pod mesh axis to every constraint inside the step.
    """
    base = make_train_step(cfg, opt_cfg, schedule,
                           grad_shardings=grad_shardings)

    def step(state: TrainState, batch):
        return jax.vmap(base, spmd_axis_name=spmd_axis_name)(state, batch)

    return step


# ---------------------------------------------------------------------------
# federated round (the M_i^UD traffic)
# ---------------------------------------------------------------------------


def make_fed_round_step(cfg: ModelConfig, compress: Optional[str] = None,
                        topk_frac: float = 0.05,
                        error_feedback: bool = False) -> Callable:
    """Weighted FedAvg across the pod axis (``repro.fl.aggregation``
    semantics, expressed as one cross-pod reduce).

    ``round_step(state, weights) -> state`` with ``weights`` shaped
    ``(n_pods,)`` (client data sizes). ``compress`` in
    ``{None, "none", "int8", "topk", "int8+topk"}`` round-trips each
    pod's update through ``repro.fl.compression`` before averaging.
    Optimizer moments stay pod-local (local adaptive state), mirroring
    the host-side CPS which only ships model weights.

    With ``error_feedback=True`` the signature becomes
    ``round_step(state, weights, residuals) -> (state, residuals)``:
    each pod carries the fp32 residual of what compression dropped and
    adds it to its next upload (``init_round_residuals`` builds the
    initial zeros) — the in-graph mirror of the host-side
    ``fl.compression`` error-feedback pipeline.

    The wire size of the upload this step implies is
    ``fed_update_bits(cfg, compress)`` — the co-sim's slice sizing
    derives from that, not from a hard-coded constant.
    """
    scheme = fedops.check_scheme(compress)

    if error_feedback:
        def round_step_ef(state: TrainState, weights, residuals):
            params, new_res = fedops.fedavg_pods(
                state.params, weights, scheme=scheme,
                topk_frac=topk_frac, residuals=residuals,
            )
            return TrainState(params=params, opt=state.opt), new_res

        return round_step_ef

    def round_step(state: TrainState, weights) -> TrainState:
        params = fedops.fedavg_pods(
            state.params, weights, scheme=scheme, topk_frac=topk_frac
        )
        return TrainState(params=params, opt=state.opt)

    return round_step


def init_round_residuals(state: TrainState):
    """Zero error-feedback residuals for ``make_fed_round_step(...,
    error_feedback=True)`` — pod-stacked fp32, like the params."""
    return fedops.init_residuals(state.params)


# ---------------------------------------------------------------------------
# asynchronous federated round (FedBuff on the pod axis)
# ---------------------------------------------------------------------------


class AsyncRoundState(NamedTuple):
    """Cross-round state of the async (FedBuff) federated loop.

    ``global_params``: pod-stacked broadcast copies of the current
    global model (every pod holds the same rows). ``refs``: each pod's
    *download reference* — the global model it last synced to, which
    its next upload delta is computed against (pods that downloaded at
    different rounds hold different refs). ``pending``: each pod's
    snapshotted fp32 update delta — the payload travelling on the wire
    while the pod's upload is in flight.
    """

    global_params: Any
    refs: Any
    pending: Any


def init_async_state(state: TrainState) -> AsyncRoundState:
    """Fresh async state: every pod synced to the same global model,
    nothing in flight."""
    return AsyncRoundState(
        global_params=state.params,
        refs=state.params,
        pending=jax.tree.map(
            lambda l: jnp.zeros(l.shape, jnp.float32), state.params
        ),
    )


def make_async_round_step(cfg: ModelConfig, compress: Optional[str] = None,
                          topk_frac: float = 0.05,
                          error_feedback: bool = False,
                          server_lr: float = 1.0,
                          staleness_power: float = 0.5,
                          quorum_frac: Optional[float] = None,
                          quorum_expected: Optional[int] = None) -> Callable:
    """Buffered asynchronous aggregation (FedBuff) across the pod axis.

    ``async_step(state, astate, weights, arrived, staleness, frac,
    snap, rejoin) -> (state, astate)`` where all mask/weight args are
    ``(n_pods,)`` arrays driven by the network timeline
    (``repro.net.timeline`` async mode — arrivals, staleness and
    partial fractions per aggregation event):

    * ``snap`` (bool): pods that just finished their local round —
      their update delta ``params - refs`` is snapshotted into
      ``pending`` (the upload begins; later training never leaks into
      the in-flight payload);
    * ``arrived`` (bool): pods whose upload reached the CPS this round
      — their pending deltas merge into the global, weighted
      ``w_i · frac_i / (1+τ_i)^p`` (``staleness`` τ in rounds,
      ``frac`` the served fraction for partial updates);
    * ``rejoin`` (bool): pods that resync to the new global
      (arrived pods re-entering, and drop-policy pods whose update was
      discarded) — their params and refs take the fresh broadcast;
      stragglers still uploading keep theirs.

    Optimizer moments stay pod-local, as in the sync round step. With
    ``error_feedback=True`` the signature grows a trailing
    ``residuals`` arg and returns ``(state, astate, residuals)`` —
    arrived pods' wire encodings run through the same error-feedback
    pipeline as the sync compressed round.

    ``quorum_frac`` threads the in-graph quorum gate through to
    ``fedops.fedbuff_pods``: with fewer than ``ceil(quorum_frac *
    quorum_expected)`` arrivals (default ``n_pods``) the merge degrades
    to the previous global model. Rejoining pods then resync to that
    *unchanged* global — the degraded-round semantics of
    ``repro.net.timeline``'s ``quorum_met=False`` rounds.
    """
    scheme = fedops.check_scheme(compress)

    def _advance(state, astate, weights, arrived, staleness, frac,
                 snap, rejoin, residuals):
        pending = jax.tree.map(
            lambda p, ref, pen: jnp.where(
                fedops._bmask(snap, pen),
                (p.astype(jnp.float32) - ref.astype(jnp.float32)), pen,
            ),
            state.params, astate.refs, astate.pending,
        )
        merged = fedops.fedbuff_pods(
            pending, astate.global_params, weights, arrived, staleness,
            server_lr=server_lr, scheme=scheme, topk_frac=topk_frac,
            staleness_power=staleness_power, frac=frac,
            residuals=residuals,
            quorum_frac=quorum_frac, n_expected=quorum_expected,
        )
        new_global, new_res = merged if error_feedback else (merged, None)
        take = lambda new, old: jax.tree.map(  # noqa: E731
            lambda n, o: jnp.where(fedops._bmask(rejoin, o), n, o),
            new, old,
        )
        params = take(new_global, state.params)
        refs = take(new_global, astate.refs)
        new_astate = AsyncRoundState(
            global_params=new_global, refs=refs, pending=pending
        )
        return TrainState(params=params, opt=state.opt), new_astate, new_res

    if error_feedback:
        return _advance

    def async_step(state, astate, weights, arrived, staleness, frac,
                   snap, rejoin):
        state, astate, _ = _advance(
            state, astate, weights, arrived, staleness, frac, snap,
            rejoin, None,
        )
        return state, astate

    return async_step


def fed_update_bits(cfg: ModelConfig, compress: Optional[str] = "int8",
                    topk_frac: float = 0.05) -> int:
    """Wire bits of ONE pod's upload under ``compress`` (``M_i^UD``).

    Derived from the real parameter tree via ``eval_shape`` (no
    allocation) and ``repro.fl.compression``'s accounting, so the co-sim
    slice demand tracks the actual sharded update payload.
    """
    scheme = fedops.check_scheme(compress)
    params = jax.eval_shape(
        lambda k: lm.init_params(k, cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32),
    )
    comp = CompressorConfig(scheme=scheme, topk_frac=topk_frac)
    return compressed_update_bits(params, comp)


def payload_summary(cfg: ModelConfig,
                    schemes=("none", "int8"),
                    topk_frac: float = 0.05) -> dict:
    """Wire-size provenance of one pod's upload per compression scheme.

    The observability layer stamps this into metrics reports and JSONL
    round logs so a timing artifact carries the payload sizes it was
    produced under (``model_bits`` is the fp32 downlink broadcast).
    """
    bits = {str(s): int(fed_update_bits(cfg, s, topk_frac))
            for s in schemes}
    return {
        "model_bits": bits.get("none", int(fed_update_bits(cfg, "none"))),
        "upload_bits": bits,
        "topk_frac": topk_frac,
    }


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig) -> Callable:
    """``step(params, tokens, cache, extra_embeds=None) -> (logits, cache)``."""

    def step(params, tokens, cache, extra_embeds=None):
        return lm.prefill(params, cfg, tokens, cache, extra_embeds)

    return step


def make_decode_step(cfg: ModelConfig) -> Callable:
    """``step(params, token, cache) -> (logits, cache)`` — one token."""

    def step(params, token, cache):
        return lm.decode_step(params, cfg, token, cache)

    return step
