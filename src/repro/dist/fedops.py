"""In-graph cross-pod federated collectives.

The federated state keeps a leading ``n_pods`` axis on every leaf (sharded
over the ``pod`` mesh axis). A FedAvg round is then a weighted reduction
over that axis followed by a broadcast — on a real fleet this is the
cross-site ``M_i^UD`` upload the BS slice is sized for, so the round step
optionally pushes each pod's update through the same int8/top-k
compression pipeline as ``repro.fl.compression`` before averaging.

Compression operates on the *delta from pod 0* (the pods start each round
from identical params, so inter-pod deltas are small and quantise far
more accurately than raw weights). Reconstruction is exact for pod 0
(zero delta), so the scheme degrades gracefully to plain FedAvg as the
pods converge.

Error feedback (matching the host-side ``fl.compression`` pipeline):
each pod carries an fp32 residual of what compression dropped last
round; the residual is added to the next round's delta before encoding,
so compression noise averages out instead of biasing FedAvg. The
residual pytree lives in the round state (``init_residuals`` /
``fedavg_pods(..., residuals=...)``) and stays pod-local — it is never
transmitted.

Asynchronous rounds (FedBuff): ``fedbuff_pods`` applies a *buffered*
staleness-weighted delta merge instead of a full average — only the
pods whose upload reached the CPS this round (``arrived``) contribute,
each discounted by ``1/(1+τ)^p`` for its staleness ``τ`` (rounds since
it downloaded the model it trained on) and optionally scaled by a
served *fraction* (the network layer's ``deadline_policy="partial"``).
The merge consumes snapshotted update deltas (one per pod, frozen when
the pod finished its local round — its upload payload), so a pod whose
upload is still in flight contributes exactly the bits it put on the
wire, not whatever its parameters drifted to since. Host-side mirror:
``repro.fl.aggregation.fedbuff_merge``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.fl.compression import (
    dequantize_int8,
    quantize_int8,
    topk_sparsify,
)

SCHEMES = ("none", "int8", "topk", "int8+topk")


def check_scheme(scheme) -> str:
    """Normalise/validate a compression scheme name (None -> "none")."""
    scheme = scheme or "none"
    if scheme not in SCHEMES:
        raise ValueError(
            f"unknown compression scheme {scheme!r}; have {SCHEMES}"
        )
    return scheme


def pod_weighted_mean(leaf: jnp.ndarray, w_norm: jnp.ndarray) -> jnp.ndarray:
    """Weighted mean over the leading pod axis, broadcast back to all pods.

    Same semantics as ``repro.fl.aggregation.fedavg`` (fp32 accumulate,
    cast back to the leaf dtype) but expressed over a stacked axis so it
    lowers to a single cross-pod reduce under GSPMD.
    """
    g = jnp.tensordot(w_norm, leaf.astype(jnp.float32), axes=1)
    return jnp.broadcast_to(g.astype(leaf.dtype)[None], leaf.shape)


def init_residuals(params):
    """Zero fp32 error-feedback residuals, one per pod-stacked leaf."""
    return jax.tree.map(
        lambda l: jnp.zeros(l.shape, jnp.float32), params
    )


def compress_pod_updates(
    leaf: jnp.ndarray, scheme: str, topk_frac: float = 0.05,
    residual: Optional[jnp.ndarray] = None,
):
    """Round-trip each pod's update through the wire compression.

    ``leaf`` is ``(n_pods, ...)``. Each pod's transmitted payload is its
    delta from the pod-0 reference; the returned array is what the
    aggregator reconstructs (``ref + decode(encode(delta))``), matching
    the decode-side view that ``repro.fl.compression.compress_delta``
    simulates on the host.

    With ``residual`` (fp32, same shape as ``leaf``), the residual is
    added to the delta before encoding and the call returns
    ``(decoded, new_residual)`` where ``new_residual = target -
    decode(encode(target))`` — per-pod error feedback. A ``"none"``
    scheme transmits exactly, so the residual passes through unchanged
    (as in the host pipeline).
    """
    scheme = check_scheme(scheme)
    if scheme == "none":
        return leaf if residual is None else (leaf, residual)
    ref = leaf[0]
    target = (leaf - ref[None]).astype(jnp.float32)
    if residual is not None:
        target = target + residual
    comp = target
    if "topk" in scheme:
        comp = jax.vmap(partial(topk_sparsify, frac=topk_frac))(comp)
    if "int8" in scheme:
        q, scale = jax.vmap(quantize_int8)(comp)
        comp = jax.vmap(dequantize_int8)(q, scale)
    decoded = (ref.astype(jnp.float32)[None] + comp).astype(leaf.dtype)
    if residual is None:
        return decoded
    return decoded, target - comp


def fedavg_pods(params, weights: jnp.ndarray, scheme: str = "none",
                topk_frac: float = 0.05, residuals=None):
    """Compressed weighted FedAvg over the pod axis of a param pytree.

    With ``residuals`` (a pytree from ``init_residuals``), applies
    error-feedback compression and returns ``(avg_params,
    new_residuals)``; without, returns ``avg_params`` (unchanged
    behaviour).
    """
    w = weights.astype(jnp.float32)
    w_norm = w / jnp.sum(w)

    if residuals is None:
        def avg(leaf):
            decoded = compress_pod_updates(leaf, scheme, topk_frac)
            return pod_weighted_mean(decoded, w_norm)

        return jax.tree.map(avg, params)

    def avg_ef(leaf, res):
        decoded, new_res = compress_pod_updates(
            leaf, scheme, topk_frac, residual=res
        )
        return pod_weighted_mean(decoded, w_norm), new_res

    pairs = jax.tree.map(avg_ef, params, residuals)
    avg_params = jax.tree.map(
        lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple)
    )
    new_residuals = jax.tree.map(
        lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple)
    )
    return avg_params, new_residuals


# ---------------------------------------------------------------------------
# asynchronous (FedBuff) aggregation
# ---------------------------------------------------------------------------


def staleness_discount(staleness, power: float = 0.5) -> jnp.ndarray:
    """``(1 + τ)^-p`` — the FedBuff staleness weight (p=0.5 default)."""
    return (1.0 + jnp.asarray(staleness, jnp.float32)) ** (-power)


def compress_deltas(deltas: jnp.ndarray, scheme: str,
                    topk_frac: float = 0.05, residual=None):
    """Round-trip pod-stacked update *deltas* through the wire encoding.

    Unlike :func:`compress_pod_updates` there is no pod-0 reference —
    ``deltas`` already are the small wire payloads (params minus each
    pod's own download reference). With ``residual`` returns
    ``(decoded, new_residual)`` for error feedback; the caller masks
    the residual update to the pods that actually transmitted.
    """
    scheme = check_scheme(scheme)
    if scheme == "none":
        return deltas if residual is None else (deltas, residual)
    target = deltas.astype(jnp.float32)
    if residual is not None:
        target = target + residual
    comp = target
    if "topk" in scheme:
        comp = jax.vmap(partial(topk_sparsify, frac=topk_frac))(comp)
    if "int8" in scheme:
        q, scale = jax.vmap(quantize_int8)(comp)
        comp = jax.vmap(dequantize_int8)(q, scale)
    decoded = comp.astype(deltas.dtype)
    if residual is None:
        return decoded
    return decoded, target - comp


def _bmask(mask: jnp.ndarray, leaf: jnp.ndarray) -> jnp.ndarray:
    """Reshape a ``(n_pods,)`` mask to broadcast over a stacked leaf."""
    return mask.reshape((-1,) + (1,) * (leaf.ndim - 1))


def fedbuff_pods(pending, global_params, weights: jnp.ndarray,
                 arrived: jnp.ndarray, staleness: jnp.ndarray,
                 server_lr: float = 1.0, scheme: str = "none",
                 topk_frac: float = 0.05, staleness_power: float = 0.5,
                 frac=None, residuals=None,
                 quorum_frac: Optional[float] = None,
                 n_expected=None):
    """Buffered staleness-weighted (FedBuff) merge over the pod axis.

    ``pending``: pytree of ``(n_pods, ...)`` snapshotted update deltas
    (each pod's upload payload); ``global_params``: pod-stacked
    broadcast copies of the current global model; ``arrived``:
    ``(n_pods,)`` bool — whose upload completed this round;
    ``staleness``: ``(n_pods,)`` rounds since each pod downloaded the
    model it trained on; ``frac``: optional served fraction in
    ``[0, 1]`` (partial updates). The new global is

        ``G' = G + server_lr · Σ_i (w_i/Σ_j w_j) · s_i · f_i · Δ_i``
        over arrived pods, ``s_i = (1+τ_i)^-p``, ``f_i`` the fraction

    (a no-op when nothing arrived). Data weights ``w`` mix the
    co-arrivals *relatively* (all fresh and complete ⇒ exactly the
    FedAvg delta step), while staleness and fraction discount each
    update *absolutely* — a lone stale or partial arrival moves the
    global by ``s·f·Δ``, never by the full delta (self-normalising the
    discounts would cancel them whenever one update arrives alone).
    Same fp32-accumulate/cast-back numerics as :func:`fedavg_pods`;
    with ``residuals`` the arrived pods' wire encodings run through
    error feedback (non-arrived pods' residuals pass through
    untouched) and the call returns ``(new_global, new_residuals)``.

    ``quorum_frac`` gates the merge in-graph (traceable — no host
    round-trip): fewer than ``ceil(quorum_frac * n_expected)`` arrivals
    (``n_expected`` defaults to ``n_pods``) zeroes every merge weight,
    so the round *degrades* — the global model passes through
    untouched, mirroring ``repro.fl.aggregation.quorum_commit``.
    """
    m = arrived.astype(jnp.float32)
    w = weights.astype(jnp.float32) * m
    s = staleness_discount(staleness, staleness_power)
    f = jnp.ones_like(w) if frac is None else jnp.asarray(frac, jnp.float32)
    # Σ w = 0 (no arrivals) must leave the global untouched
    w_norm = w / jnp.maximum(w.sum(), 1e-12) * s * f * m
    if quorum_frac is not None:
        n_exp = jnp.asarray(
            arrived.shape[0] if n_expected is None else n_expected,
            jnp.float32,
        )
        need = jnp.maximum(jnp.ceil(quorum_frac * n_exp), 1.0)
        w_norm = w_norm * (m.sum() >= need).astype(jnp.float32)

    def merge(leaf_delta, g, res=None):
        if res is None:
            decoded = compress_deltas(leaf_delta, scheme, topk_frac)
        else:
            decoded, cand = compress_deltas(
                leaf_delta, scheme, topk_frac, residual=res
            )
        upd = jnp.tensordot(w_norm, decoded.astype(jnp.float32), axes=1)
        newg = (
            g.astype(jnp.float32) + server_lr * upd[None]
        ).astype(g.dtype)
        if res is None:
            return newg
        new_res = jnp.where(_bmask(arrived, res), cand, res)
        return newg, new_res

    if residuals is None:
        return jax.tree.map(merge, pending, global_params)
    pairs = jax.tree.map(merge, pending, global_params, residuals)
    new_global = jax.tree.map(
        lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple)
    )
    new_residuals = jax.tree.map(
        lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple)
    )
    return new_global, new_residuals
