"""In-graph cross-pod federated collectives.

The federated state keeps a leading ``n_pods`` axis on every leaf (sharded
over the ``pod`` mesh axis). A FedAvg round is then a weighted reduction
over that axis followed by a broadcast — on a real fleet this is the
cross-site ``M_i^UD`` upload the BS slice is sized for, so the round step
optionally pushes each pod's update through the same int8/top-k
compression pipeline as ``repro.fl.compression`` before averaging.

Compression operates on the *delta from pod 0* (the pods start each round
from identical params, so inter-pod deltas are small and quantise far
more accurately than raw weights). Reconstruction is exact for pod 0
(zero delta), so the scheme degrades gracefully to plain FedAvg as the
pods converge.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.fl.compression import (
    dequantize_int8,
    quantize_int8,
    topk_sparsify,
)

SCHEMES = ("none", "int8", "topk", "int8+topk")


def check_scheme(scheme) -> str:
    """Normalise/validate a compression scheme name (None -> "none")."""
    scheme = scheme or "none"
    if scheme not in SCHEMES:
        raise ValueError(
            f"unknown compression scheme {scheme!r}; have {SCHEMES}"
        )
    return scheme


def pod_weighted_mean(leaf: jnp.ndarray, w_norm: jnp.ndarray) -> jnp.ndarray:
    """Weighted mean over the leading pod axis, broadcast back to all pods.

    Same semantics as ``repro.fl.aggregation.fedavg`` (fp32 accumulate,
    cast back to the leaf dtype) but expressed over a stacked axis so it
    lowers to a single cross-pod reduce under GSPMD.
    """
    g = jnp.tensordot(w_norm, leaf.astype(jnp.float32), axes=1)
    return jnp.broadcast_to(g.astype(leaf.dtype)[None], leaf.shape)


def compress_pod_updates(
    leaf: jnp.ndarray, scheme: str, topk_frac: float = 0.05
) -> jnp.ndarray:
    """Round-trip each pod's update through the wire compression.

    ``leaf`` is ``(n_pods, ...)``. Each pod's transmitted payload is its
    delta from the pod-0 reference; the returned array is what the
    aggregator reconstructs (``ref + decode(encode(delta))``), matching
    the decode-side view that ``repro.fl.compression.compress_delta``
    simulates on the host.
    """
    scheme = check_scheme(scheme)
    if scheme == "none":
        return leaf
    ref = leaf[0]
    delta = (leaf - ref[None]).astype(jnp.float32)
    if "topk" in scheme:
        delta = jax.vmap(partial(topk_sparsify, frac=topk_frac))(delta)
    if "int8" in scheme:
        q, scale = jax.vmap(quantize_int8)(delta)
        delta = jax.vmap(dequantize_int8)(q, scale)
    return (ref.astype(jnp.float32)[None] + delta).astype(leaf.dtype)


def fedavg_pods(params, weights: jnp.ndarray, scheme: str = "none",
                topk_frac: float = 0.05):
    """Compressed weighted FedAvg over the pod axis of a param pytree."""
    w = weights.astype(jnp.float32)
    w_norm = w / jnp.sum(w)

    def avg(leaf):
        decoded = compress_pod_updates(leaf, scheme, topk_frac)
        return pod_weighted_mean(decoded, w_norm)

    return jax.tree.map(avg, params)
