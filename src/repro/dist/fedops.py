"""In-graph cross-pod federated collectives.

The federated state keeps a leading ``n_pods`` axis on every leaf (sharded
over the ``pod`` mesh axis). A FedAvg round is then a weighted reduction
over that axis followed by a broadcast — on a real fleet this is the
cross-site ``M_i^UD`` upload the BS slice is sized for, so the round step
optionally pushes each pod's update through the same int8/top-k
compression pipeline as ``repro.fl.compression`` before averaging.

Compression operates on the *delta from pod 0* (the pods start each round
from identical params, so inter-pod deltas are small and quantise far
more accurately than raw weights). Reconstruction is exact for pod 0
(zero delta), so the scheme degrades gracefully to plain FedAvg as the
pods converge.

Error feedback (matching the host-side ``fl.compression`` pipeline):
each pod carries an fp32 residual of what compression dropped last
round; the residual is added to the next round's delta before encoding,
so compression noise averages out instead of biasing FedAvg. The
residual pytree lives in the round state (``init_residuals`` /
``fedavg_pods(..., residuals=...)``) and stays pod-local — it is never
transmitted.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.fl.compression import (
    dequantize_int8,
    quantize_int8,
    topk_sparsify,
)

SCHEMES = ("none", "int8", "topk", "int8+topk")


def check_scheme(scheme) -> str:
    """Normalise/validate a compression scheme name (None -> "none")."""
    scheme = scheme or "none"
    if scheme not in SCHEMES:
        raise ValueError(
            f"unknown compression scheme {scheme!r}; have {SCHEMES}"
        )
    return scheme


def pod_weighted_mean(leaf: jnp.ndarray, w_norm: jnp.ndarray) -> jnp.ndarray:
    """Weighted mean over the leading pod axis, broadcast back to all pods.

    Same semantics as ``repro.fl.aggregation.fedavg`` (fp32 accumulate,
    cast back to the leaf dtype) but expressed over a stacked axis so it
    lowers to a single cross-pod reduce under GSPMD.
    """
    g = jnp.tensordot(w_norm, leaf.astype(jnp.float32), axes=1)
    return jnp.broadcast_to(g.astype(leaf.dtype)[None], leaf.shape)


def init_residuals(params):
    """Zero fp32 error-feedback residuals, one per pod-stacked leaf."""
    return jax.tree.map(
        lambda l: jnp.zeros(l.shape, jnp.float32), params
    )


def compress_pod_updates(
    leaf: jnp.ndarray, scheme: str, topk_frac: float = 0.05,
    residual: Optional[jnp.ndarray] = None,
):
    """Round-trip each pod's update through the wire compression.

    ``leaf`` is ``(n_pods, ...)``. Each pod's transmitted payload is its
    delta from the pod-0 reference; the returned array is what the
    aggregator reconstructs (``ref + decode(encode(delta))``), matching
    the decode-side view that ``repro.fl.compression.compress_delta``
    simulates on the host.

    With ``residual`` (fp32, same shape as ``leaf``), the residual is
    added to the delta before encoding and the call returns
    ``(decoded, new_residual)`` where ``new_residual = target -
    decode(encode(target))`` — per-pod error feedback. A ``"none"``
    scheme transmits exactly, so the residual passes through unchanged
    (as in the host pipeline).
    """
    scheme = check_scheme(scheme)
    if scheme == "none":
        return leaf if residual is None else (leaf, residual)
    ref = leaf[0]
    target = (leaf - ref[None]).astype(jnp.float32)
    if residual is not None:
        target = target + residual
    comp = target
    if "topk" in scheme:
        comp = jax.vmap(partial(topk_sparsify, frac=topk_frac))(comp)
    if "int8" in scheme:
        q, scale = jax.vmap(quantize_int8)(comp)
        comp = jax.vmap(dequantize_int8)(q, scale)
    decoded = (ref.astype(jnp.float32)[None] + comp).astype(leaf.dtype)
    if residual is None:
        return decoded
    return decoded, target - comp


def fedavg_pods(params, weights: jnp.ndarray, scheme: str = "none",
                topk_frac: float = 0.05, residuals=None):
    """Compressed weighted FedAvg over the pod axis of a param pytree.

    With ``residuals`` (a pytree from ``init_residuals``), applies
    error-feedback compression and returns ``(avg_params,
    new_residuals)``; without, returns ``avg_params`` (unchanged
    behaviour).
    """
    w = weights.astype(jnp.float32)
    w_norm = w / jnp.sum(w)

    if residuals is None:
        def avg(leaf):
            decoded = compress_pod_updates(leaf, scheme, topk_frac)
            return pod_weighted_mean(decoded, w_norm)

        return jax.tree.map(avg, params)

    def avg_ef(leaf, res):
        decoded, new_res = compress_pod_updates(
            leaf, scheme, topk_frac, residual=res
        )
        return pod_weighted_mean(decoded, w_norm), new_res

    pairs = jax.tree.map(avg_ef, params, residuals)
    avg_params = jax.tree.map(
        lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple)
    )
    new_residuals = jax.tree.map(
        lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple)
    )
    return avg_params, new_residuals
