"""PartitionSpec rules for the ("pod", "data", "model") mesh.

Everything here is pure spec logic keyed on parameter *path names* and
shapes — no device state — so the same rules drive the AbstractMesh
contract tests, the dry-run lowering on 512 placeholder devices, and the
host-mesh integration tests.

The rules (Megatron/GSPMD conventions):

* **column-parallel** (default for matrices): shard the output features
  (last dim) over ``model`` — ``wq``/``wk``/``wv``, MLP up/gate, SSD
  ``in_proj``, …
* **row-parallel** for output projections (``wo``, ``w_down``,
  ``out_proj``, ``w_out``): shard the input features (dim −2) over
  ``model`` so the preceding column-parallel activations feed it without
  a gather.
* **embeddings**: vocab-sharded over ``model`` when the vocab size
  divides the axis; otherwise fall back to sharding ``d_model`` (mamba2's
  50280 vocab is not 16-divisible).
* **MoE stacks**: expert-parallel — the expert dim over ``model`` — when
  ``n_experts`` divides the axis (arctic's 128); otherwise tensor-shard
  within each expert like a plain matrix (mixtral's 8 < 16).
* **FSDP** (``cfg.fsdp``): additionally shard the complementary matrix
  dim over ``data``. ``opt_moment_specs`` applies the same treatment for
  ``cfg.zero_opt`` so Adam moments are ZeRO-sharded even when parameters
  are not.
* **norm scales/biases and other vectors replicate** — they are tiny and
  every ``model`` shard needs them.

A leading ``units`` path component marks the stacked-layer axis from the
scan-over-units model; it is never sharded.
"""
from __future__ import annotations

import math
from typing import Any, List, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

# output projections whose *input* features are model-sharded
ROW_PARALLEL = ("wo", "w_down", "out_proj", "w_out")
# vector-ish leaves that always replicate (rank rule catches most; these
# names guard against future 2-D gains/biases)
REPLICATED = ("scale", "bias", "lam", "a_log", "dt_bias", "d_skip")


def _axis_size(mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return int(dict(mesh.shape)[name])


def _axis_or_none(mesh, name: str):
    return name if name in mesh.axis_names else None


def _path_names(path) -> List[str]:
    """KeyPath entries -> plain strings ('units', 'b0', 'mixer', 'wq')."""
    names = []
    for entry in path:
        if hasattr(entry, "key"):
            names.append(str(entry.key))
        elif hasattr(entry, "name"):
            names.append(str(entry.name))
        elif hasattr(entry, "idx"):
            names.append(str(entry.idx))
        else:
            names.append(str(entry))
    return names


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------


def param_spec(
    path_names: Sequence[Any],
    shape: Tuple[int, ...],
    cfg: ModelConfig,
    mesh,
    *,
    fsdp: bool | None = None,
) -> P:
    """PartitionSpec for one parameter leaf.

    ``path_names`` is the pytree path as strings (e.g. ``["units", "b0",
    "mixer", "wq"]``), ``shape`` the full leaf shape (including the
    stacked-units axis when present). ``fsdp=None`` defers to
    ``cfg.fsdp``; pass an explicit bool to override (ZeRO moments).
    """
    names = [str(n) for n in path_names]
    leaf = names[-1] if names else ""
    ndim = len(shape)
    model = _axis_size(mesh, "model")
    data = _axis_size(mesh, "data")
    model_ax = _axis_or_none(mesh, "model")
    data_ax = _axis_or_none(mesh, "data")
    use_fsdp = bool(cfg.fsdp) if fsdp is None else bool(fsdp)
    lead = 1 if names and names[0] == "units" else 0

    # vectors, scalars, norm gains: replicate
    if (
        ndim - lead < 2
        or leaf in REPLICATED
        or any("norm" in n for n in names)
    ):
        return P(None)

    # embeddings / untied head: vocab-sharded with d_model fallback
    if leaf in ("embed", "lm_head"):
        v_ax, d_ax = (0, 1) if leaf == "embed" else (1, 0)
        entries: List[Any] = [None, None]
        if model_ax is not None and shape[v_ax] % model == 0:
            entries[v_ax] = model_ax
        elif model_ax is not None and shape[d_ax] % model == 0:
            entries[d_ax] = model_ax
        if use_fsdp and data_ax is not None:
            free = v_ax if entries[v_ax] is None else d_ax
            if entries[free] is None and shape[free] % data == 0:
                entries[free] = data_ax
        return P(*entries)

    # MoE expert stacks: expert-parallel when the axis divides, else
    # tensor-shard within each expert
    if cfg.moe is not None and "moe" in names and leaf in (
        "w_gate", "w_up", "w_down"
    ):
        E = cfg.moe.n_experts
        e_ax = next(
            (i for i in range(lead, ndim - 2) if shape[i] == E), None
        )
        if e_ax is not None:
            entries = [None] * ndim
            if model_ax is not None and E % model == 0:
                entries[e_ax] = model_ax
                if use_fsdp and data_ax is not None:
                    for i in range(e_ax + 1, ndim):
                        if shape[i] % data == 0:
                            entries[i] = data_ax
                            break
                return P(*entries)
            # fall through to the generic matrix rule below
        # (router and non-expert-dim leaves also use the generic rule)

    # generic matrices: column-parallel by default, row-parallel for
    # output projections; FSDP shards the complementary dim over data
    entries = [None] * ndim
    row = leaf in ROW_PARALLEL
    m_ax = ndim - 2 if row else ndim - 1
    f_ax = ndim - 1 if row else ndim - 2
    if model_ax is not None and shape[m_ax] % model == 0:
        entries[m_ax] = model_ax
    if (
        use_fsdp
        and data_ax is not None
        and f_ax >= lead
        and entries[f_ax] is None
        and shape[f_ax] % data == 0
    ):
        entries[f_ax] = data_ax
    return P(*entries)


def param_specs(params, cfg: ModelConfig, mesh, *, fsdp: bool | None = None):
    """PartitionSpec tree for a parameter pytree (path-name driven)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(
            _path_names(path), tuple(leaf.shape), cfg, mesh, fsdp=fsdp
        ),
        params,
    )


def opt_moment_specs(moments, cfg: ModelConfig, mesh):
    """Specs for Adam/momentum moment trees (mirror the params).

    With ``cfg.zero_opt`` the moments get the FSDP data-axis treatment
    even when the parameters themselves are not FSDP-sharded — classic
    ZeRO partitioning of optimizer state.
    """
    return param_specs(
        moments, cfg, mesh, fsdp=bool(cfg.fsdp or cfg.zero_opt)
    )


# ---------------------------------------------------------------------------
# batch / cache rules
# ---------------------------------------------------------------------------


def _batch_axes(mesh, batch: int) -> Tuple[str, ...]:
    """Largest ("pod","data") prefix-trimmed combo that divides ``batch``."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    while axes:
        prod = math.prod(_axis_size(mesh, a) for a in axes)
        if prod and batch % prod == 0:
            return tuple(axes)
        axes = axes[1:]  # drop the pod axis first, then data
    return ()


def _batch_entry(mesh, batch: int):
    axes = _batch_axes(mesh, batch)
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


def batch_spec(mesh, global_batch: int) -> P:
    """Spec for a (B, ...) batch array: B over the pod+data axes."""
    entry = _batch_entry(mesh, global_batch)
    return P() if entry is None else P(entry)


def cache_specs(cache_shapes, cfg: ModelConfig, mesh, global_batch: int):
    """Specs for the serving cache pytree from ``repro.models.lm``.

    Batch dim over the pod+data axes; the fused kv-head/feature dim of
    ``k``/``v`` (and conv/recurrent states) over ``model`` — matching the
    column-parallel projection output so decode never gathers the cache.
    SSD states shard their head dim instead (``d_state`` stays local to
    the chunk recurrence).
    """
    model = _axis_size(mesh, "model")
    model_ax = _axis_or_none(mesh, "model")
    b_entry = _batch_entry(mesh, global_batch)

    def spec(path, leaf):
        names = _path_names(path)
        leaf_name = names[-1] if names else ""
        shape = tuple(leaf.shape)
        ndim = len(shape)
        if ndim == 0 or leaf_name == "pos":
            return P()
        lead = 1 if names and names[0] == "units" else 0
        entries: List[Any] = [None] * ndim
        if lead < ndim and b_entry is not None and shape[lead] == global_batch:
            entries[lead] = b_entry
        if model_ax is not None and ndim - lead >= 2:
            if leaf_name == "h" and ndim - lead == 4:
                # SSD state (B, n_heads, d_head, d_state): shard heads
                if shape[lead + 1] % model == 0:
                    entries[lead + 1] = model_ax
            elif leaf_name in ("k", "v", "conv", "h"):
                if shape[-1] % model == 0:
                    entries[-1] = model_ax
        return P(*entries)

    return jax.tree_util.tree_map_with_path(spec, cache_shapes)
