"""Optimizers as pure pytree transforms (shard-compatible by construction).

The optimizer state mirrors the parameter tree leaf-for-leaf, so whatever
sharding the parameters carry applies to the state (plus the ZeRO option in
``repro.dist.sharding`` that additionally shards moments over the data axis).
``opt_state_dtype`` controls moment precision (bf16 moments halve the HBM
footprint of Adam — required to fit arctic-480b, see DESIGN.md).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"            # "sgd" | "momentum" | "adamw"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: object          # first moment (or momentum buffer); None-like for sgd
    nu: object          # second moment; None-like for sgd/momentum


def init_opt_state(params, cfg: OptimizerConfig) -> OptState:
    dt = jnp.dtype(cfg.state_dtype)
    def zeros():
        return jax.tree.map(lambda l: jnp.zeros(l.shape, dt), params)
    step = jnp.zeros((), jnp.int32)
    if cfg.name == "sgd":
        empty = jax.tree.map(lambda l: jnp.zeros((0,), dt), params)
        return OptState(step, empty, empty)
    if cfg.name == "momentum":
        empty = jax.tree.map(lambda l: jnp.zeros((0,), dt), params)
        return OptState(step, zeros(), empty)
    return OptState(step, zeros(), zeros())


def _clip_by_global_norm(grads, max_norm: float):
    sq = sum(
        jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)
    )
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def apply_updates(params, grads, state: OptState, cfg: OptimizerConfig,
                  lr: Optional[jnp.ndarray] = None):
    """Returns (new_params, new_state, grad_norm)."""
    lr = cfg.lr if lr is None else lr
    if cfg.grad_clip:
        grads, gnorm = _clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = jnp.zeros(())
    step = state.step + 1
    sdt = jnp.dtype(cfg.state_dtype)

    if cfg.name == "sgd":
        new_params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32))
            .astype(p.dtype),
            params, grads,
        )
        return new_params, OptState(step, state.mu, state.nu), gnorm

    if cfg.name == "momentum":
        mu = jax.tree.map(
            lambda m, g: (0.9 * m.astype(jnp.float32) + g.astype(jnp.float32))
            .astype(sdt),
            state.mu, grads,
        )
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr * m.astype(jnp.float32))
            .astype(p.dtype),
            params, mu,
        )
        return new_params, OptState(step, mu, state.nu), gnorm

    # adamw
    stepf = step.astype(jnp.float32)
    mu = jax.tree.map(
        lambda m, g: (cfg.b1 * m.astype(jnp.float32)
                      + (1 - cfg.b1) * g.astype(jnp.float32)).astype(sdt),
        state.mu, grads,
    )
    nu = jax.tree.map(
        lambda v, g: (cfg.b2 * v.astype(jnp.float32)
                      + (1 - cfg.b2) * jnp.square(g.astype(jnp.float32)))
        .astype(sdt),
        state.nu, grads,
    )
    bc1 = 1 - cfg.b1 ** stepf
    bc2 = 1 - cfg.b2 ** stepf

    def upd(p, m, v):
        mhat = m.astype(jnp.float32) / bc1
        vhat = v.astype(jnp.float32) / bc2
        u = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:     # decay matrices only
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, OptState(step, mu, nu), gnorm
