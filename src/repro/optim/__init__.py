"""Optimizer substrate."""
from repro.optim.optimizers import (  # noqa: F401
    OptimizerConfig,
    OptState,
    apply_updates,
    init_opt_state,
)
from repro.optim.schedules import constant, inverse_sqrt, warmup_cosine  # noqa: F401
