"""Pallas TPU kernels for the perf-critical compute layers.

Each subpackage ships kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd public wrapper with custom_vjp) and ref.py (pure-jnp oracle).
On non-TPU backends the kernels run in interpret mode — the whole stack is
testable in this CPU container; TPU is the compilation target.
"""
