"""jit backend for the batched PON cycle engine (`backend="jit"`).

``run_phase_device`` compiles one entire transfer phase — every cycle of
every ``(case, pon)`` row — into a single ``lax.while_loop`` device
program: deadline/outage capacity masking, the fused counter-based
traffic sampler (arrival bits are generated on-device in 64-cycle
windows and never touch the host), background FIFO push/serve over a
prefix-sum ring, the stable-argsort waterfill grants (Pallas rank-sum
kernel on TPU, the jnp oracle elsewhere), the CPS max-min split, FL
queue serves and completion credit.  The numpy engine
(``repro.net.engine._run_phase``) is the parity oracle at rtol 1e-6.

Carry layout (all fixed-shape; ``R`` rows, ``U`` client columns, ``N``
ONUs, ``Wr = HISTORY_CYCLES``):

======================  =======================  =======================
carry                   shape/dtype              numpy counterpart
======================  =======================  =======================
``k, t``                i32 / f64 scalars        cycle index, clock
``rem/done/done_t``     (R, U) f64/bool/f64      ``_run_phase`` locals
``waiting``             (R, U) bool              un-pushed clients
``qb/push_key/…time``   (R, U) f64/i64/f64       ``_FLQueues``
``buf``                 (R, 64, N) f32           sampler window cache
``cum/drained/backlog`` (R, N) f64               ``_BgQueues`` prefixes
``ptr``                 (R, N) i32               bg head-of-line cycle
``ring``                (R, N, Wr) f64           last-Wr cycle prefixes
``exact``               bool scalar              ring-walk validity
======================  =======================  =======================

The one structure that cannot be carried whole on device is the bg
queues' unbounded prefix *history*: the numpy engine walks it to find
the new head after a partial drain.  Per cycle at most ONE queue per
row is partially granted (the waterfill pours whole backlogs until the
marginal queue), and its head almost always sits within the last few
cycles — so the carry keeps a ``Wr``-cycle prefix ring and the serve
step walks that.  A marginal queue whose head has aged out of the ring
(sustained overload) clears the ``exact`` flag; the host entry then
returns ``None`` and the engine transparently re-runs that phase on
the numpy path, so the backend is *always* exact, merely slower in
regimes the device program was not sized for.

Multi-tenant sweeps (``SweepCase.jobs``, PR 9) are NOT compiled: the
per-cycle inter-job fairness split (``repro.net.jobs.job_fair_split``)
and the per-job prefix spending would add a ragged job axis to every
carry above.  The engine silently clears ``use_jit`` for multi-job
sweeps and runs the numpy path (documented in DESIGN.md §12); a
degenerate all-single-job sweep normalises to the plain layout and
keeps this backend.

Precision policy: queue state is float64, so the program is built and
called under a scoped ``jax.experimental.enable_x64()`` context — the
global x64 flag is never flipped for library users (regression-tested).
The fused sampler keeps the traffic kernels' explicit uint32/float32
dtypes, which is what makes its stream bit-identical to the host
backends.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import enable_x64

from repro.kernels.ponsim import ref as _ref
from repro.kernels.ponsim.kernel import waterfill_grants_pallas
from repro.kernels.traffic.ref import _WIN_SHIFT, WINDOW

CAP_EPS = 1e-9                       # repro.net.engine constants
SEG_EPS = 1.0
EPS_BITS = 1.0
_IKEY_INF = np.int64(np.iinfo(np.int64).max // 4)

HISTORY_CYCLES = 128                 # bg prefix ring length (pow2)

# program cache: one compilation per (mode, shapes, flags, layout)
_programs: dict = {}
_COMPILE_COUNT = 0                   # bumped at trace time (tested)
_PALLAS_INTERPRET = False            # tests flip to run the kernel on CPU


def compile_count() -> int:
    return _COMPILE_COUNT


def clear_cache() -> None:
    _programs.clear()


def _waterfill_device(backlog, hol, cap, use_pallas: bool):
    """Waterfill grants with exact full drains.

    The oracle path is bitwise-faithful to ``engine._waterfill``.  The
    Pallas path runs in f32; full drains come back as bitwise ``b32``
    (kernel contract), so the f64 backlog is restored for those lanes —
    the serve step's ``grants == backlog`` fast path stays exact."""
    if not use_pallas:
        return _ref.waterfill_grants_ref(backlog, hol, cap)
    n = backlog.shape[1]
    pad = (-n) % 128
    b32 = backlog.astype(jnp.float32)
    k32 = hol.astype(jnp.float32)
    if pad:
        b32 = jnp.pad(b32, ((0, 0), (0, pad)))
        k32 = jnp.pad(k32, ((0, 0), (0, pad)),
                      constant_values=jnp.float32(jnp.inf))
    g32 = waterfill_grants_pallas(b32, k32, cap.astype(jnp.float32),
                                  interpret=_PALLAS_INTERPRET)[:, :n]
    b32 = b32[:, :n]
    fullm = (g32 == b32) & (b32 > 0)
    return jnp.where(fullm, backlog, g32.astype(backlog.dtype))


def _build_program(spec, lay, n_draws: int):
    """Close the static layout/config into one jitted phase program."""
    global _COMPILE_COUNT
    (mode, R, U, N, S, P, has_bg, has_cps, has_deadline, has_outage,
     fill_unfinished, use_pallas, max_slots, _ndraws, _onu_sig) = spec
    single, identity = lay.single, lay.identity
    fast = mode == "fcfs" and single
    lay_onu = np.asarray(lay.onu, np.int64)              # (U,)
    lay_pos = np.arange(U, dtype=np.int64)
    seg_starts = np.asarray(lay.seg_starts, np.int64)
    seg_onus = np.asarray(lay.seg_onus, np.int64)
    Sg = len(seg_starts)
    seg_ids = np.repeat(np.arange(Sg), np.asarray(lay.seg_len))
    Wr = HISTORY_CYCLES

    def _backlog_per_onu(qb):
        if identity:
            return qb
        if single:
            return jnp.zeros((R, N), qb.dtype).at[:, seg_onus].set(qb)
        seg = jax.ops.segment_sum(qb.T, seg_ids, num_segments=Sg,
                                  indices_are_sorted=True).T
        return jnp.zeros((R, N), qb.dtype).at[:, seg_onus].set(seg)

    def _heads(qb, push_key):
        nonzero = qb > 0.0
        pk = jnp.where(nonzero, push_key, 0)
        combined = jnp.where(nonzero, pk * np.int64(U) + lay_pos,
                             _IKEY_INF)
        m = jax.ops.segment_min(combined.T, seg_ids, num_segments=Sg,
                                indices_are_sorted=True).T      # (R, Sg)
        has = m < _IKEY_INF
        pos = jnp.where(has, m % np.int64(U), 0)
        return has, pos

    def _hol_per_onu(qb, push_key, push_time):
        if identity:
            return jnp.where(qb > 0.0, push_time, jnp.inf)
        if single:
            return jnp.full((R, N), jnp.inf,
                            push_time.dtype).at[:, seg_onus].set(
                jnp.where(qb > 0.0, push_time, jnp.inf))
        has, pos = _heads(qb, push_key)
        times = jnp.where(has,
                          jnp.take_along_axis(push_time, pos, axis=1),
                          jnp.inf)
        return jnp.full((R, N), jnp.inf,
                        push_time.dtype).at[:, seg_onus].set(times)

    def _count_le(a, v):
        """Per-row ``#{j : a[r, j] <= v[r]}`` for row-sorted ``a``."""
        return jax.vmap(
            lambda ar, vr: jnp.searchsorted(ar, vr, side="right")
        )(a, v).astype(jnp.int32)

    def _first_ge(a, v):
        """Per-row index of the first ``a[r, j] >= v[r]``."""
        return jax.vmap(
            lambda ar, vr: jnp.searchsorted(ar, vr, side="left")
        )(a, v).astype(jnp.int32)

    def program(dyn):
        global _COMPILE_COUNT
        _COMPILE_COUNT += 1                 # trace-time side effect
        cyc, prop = dyn["cyc"], dyn["prop"]
        tmax = dyn["tmax"]
        part = dyn["part"]
        rem0 = dyn["rem0"]
        f64 = rem0.dtype

        def _slot_grants(backlog_onu, t, cap):
            te_g = dyn["te"] + cyc
            active = dyn["svalid"] & (dyn["ts"] < t + cyc) & (te_g > t)
            overlap = (jnp.minimum(te_g, t + cyc)
                       - jnp.maximum(dyn["ts"], t))
            want = dyn["srate"] * jnp.maximum(overlap, 0.0)
            want = jnp.minimum(
                want, jnp.take_along_axis(backlog_onu, dyn["sonu"], 1))
            want = jnp.where(active & (want > 0.0), want, 0.0)
            prefix = jnp.cumsum(want, axis=1)
            grants = jnp.minimum(
                want, jnp.maximum(cap[:, None] - (prefix - want), 0.0))
            rows = jnp.broadcast_to(jnp.arange(R)[:, None], (R, S))
            return jnp.zeros((R, N), f64).at[rows, dyn["sonu"]].add(
                grants)

        done0 = ~part | (rem0 <= 0.0)
        carry = {
            "k": jnp.int32(0),
            "t": jnp.zeros((), f64),
            "done_t": jnp.full((R, U), jnp.nan, f64),
            "exact": jnp.bool_(True),
        }
        if fast:
            # Scalar-S carry: with single-client queues served in a
            # priority order known before the loop (host tables), the
            # whole FL queue system collapses to one cumulative-service
            # scalar per row plus the count of completed ranks.
            carry.update(
                fls=jnp.zeros((R,), f64),
                cdone=jnp.zeros((R,), jnp.int32),
            )
        else:
            carry.update(
                rem=rem0,
                done=done0,
                waiting=part & ~done0,
                qb=jnp.zeros((R, U), f64),
                push_key=jnp.full((R, U), _IKEY_INF, jnp.int64),
                push_time=jnp.zeros((R, U), f64),
            )
        if has_bg:
            carry.update(
                buf=jnp.zeros((R, WINDOW, N), jnp.float32),
                cum=jnp.zeros((R, N), f64),
                drained=jnp.zeros((R, N), f64),
                backlog=jnp.zeros((R, N), f64),
                ptr=jnp.zeros((R, N), jnp.int32),
                ring=jnp.zeros((R, N, Wr), f64),
            )

        def cond(c):
            if fast:
                liv = dyn["m_live"] > c["cdone"]
                ok = ((c["t"] < tmax) & liv.any()
                      & (c["k"] < dyn["k_max"]))
                if has_deadline:
                    ok &= (liv & (dyn["cap_t"] > c["t"])).any()
                return ok
            live = ~c["done"] & part
            ok = (c["t"] < tmax) & live.any() & (c["k"] < dyn["k_max"])
            if has_deadline:
                # the numpy loop breaks at the body top, before any
                # mutation, when no live client's row deadline is ahead
                ok &= (live & (dyn["cap_t"] > c["t"])[:, None]).any()
            return ok

        def body(c):
            k, t = c["k"], c["t"]
            out = dict(c)
            cap_cyc = dyn["cap_col"]
            if has_deadline:
                cap_cyc = jnp.where(dyn["cap_t"] > t, cap_cyc, 0.0)
            if has_outage:
                dark = (dyn["out0"] <= t) & (t < dyn["out1"])
                cap_cyc = jnp.where(dark, 0.0, cap_cyc)

            # ---- bg arrivals: fused threefry sampler + FIFO push
            if has_bg:
                buf = lax.cond(
                    (k & (WINDOW - 1)) == 0,
                    lambda _: _ref.sample_window_ref(
                        dyn["keys"], dyn["thr"], k >> _WIN_SHIFT,
                        n_onus=N, n_draws=n_draws,
                        inv_burst=dyn["inv_burst"],
                        packet_bits=dyn["packet_bits"]),
                    lambda _: c["buf"], None)
                bits = lax.dynamic_index_in_dim(
                    buf, k & (WINDOW - 1), axis=1,
                    keepdims=False).astype(f64)
                fresh = (c["backlog"] <= 0.0) & (bits > 0.0)
                cum = c["cum"] + bits
                bg_backlog = cum - c["drained"]
                bg_ptr = jnp.where(fresh, k, c["ptr"])
                ring = lax.dynamic_update_slice(
                    c["ring"], cum[:, :, None],
                    (jnp.int32(0), jnp.int32(0), k & (Wr - 1)))
                out.update(buf=buf, cum=cum, backlog=bg_backlog,
                           ptr=bg_ptr, ring=ring)

            # ---- FL push
            if fast:
                # pushes are a host-precomputed prefix of the rank
                # order: the pushed-total boundary T_k replaces all
                # per-client push bookkeeping
                npk = _count_le(dyn["kp_rank"],
                                jnp.broadcast_to(k, (R,)))
                t_k = jnp.take_along_axis(
                    dyn["p_incl"], npk.astype(jnp.int64)[:, None],
                    axis=1)[:, 0]
                fl_tot = t_k - c["fls"]
            else:
                newly = c["waiting"] & (dyn["ready"] <= t + cyc)
                qb = jnp.where(newly, c["rem"], c["qb"])
                push_key = jnp.where(
                    newly,
                    k.astype(jnp.int64) * np.int64(U + 1)
                    + dyn["list_pos"],
                    c["push_key"])
                push_time = jnp.where(
                    newly, jnp.maximum(dyn["ready"], t),
                    c["push_time"])
                out.update(waiting=c["waiting"] & ~newly,
                           push_key=push_key, push_time=push_time)

            # ---- grants
            backlog_onu = None if fast else _backlog_per_onu(qb)
            if mode == "fcfs":
                bg_sum = (out["backlog"].sum(axis=1) if has_bg else 0.0)
                if has_cps:
                    fl_want = (fl_tot if fast
                               else backlog_onu.sum(axis=1))
                    want = jnp.minimum(bg_sum + fl_want, cap_cyc)
                    eff = _ref.cps_waterfill_ref(
                        want.reshape(-1, P), dyn["cps_cap"]).reshape(-1)
                else:
                    eff = cap_cyc
                if has_bg:
                    # the numpy `_waterfill` lazy hard-row check, hoisted
                    # to a scalar cond: when every row's demand sits at
                    # least one bit under capacity the pour grants full
                    # backlogs regardless of age order, so ordering work
                    # is skipped entirely.  Under sub-unit load that is
                    # the common cycle; only bursts take the hard branch.
                    easy = jnp.all(bg_sum <= eff - 1.0)

                    def _bg_easy(b, ptr, e):
                        return b, jnp.bool_(False)

                    if use_pallas:
                        def _bg_hard(b, ptr, e):
                            hol = jnp.where(b > 0.0, ptr.astype(f64),
                                            jnp.inf)
                            return (_waterfill_device(b, hol, e, True),
                                    jnp.bool_(False))
                    else:
                        def _bg_hard(b, ptr, e):
                            # bg head-of-line keys are arrival *cycles*,
                            # so the stable argsort collapses to a
                            # counting pour over `Wr` age buckets:
                            # bucket-sum scatter + tiny suffix sums +
                            # one column prefix for the single marginal
                            # bucket — O(N) instead of O(N log N), and
                            # ~20x cheaper than XLA's sort here.  Ages
                            # clip at Wr-1; if the margin lands in that
                            # clipped bucket with 2+ queues their column
                            # order may differ from true arrival order,
                            # so that (sustained-overload) case clears
                            # `exact` and the host re-runs on numpy.
                            has = b > 0.0
                            age = jnp.clip(k - ptr, 0, Wr - 1)
                            aidx = jnp.where(has, age, 0)
                            bval = jnp.where(has, b, 0.0)
                            rws = jnp.arange(R)[:, None]
                            bs = jnp.zeros((R, Wr), b.dtype).at[
                                rws, aidx].add(bval)
                            flip = jnp.cumsum(bs[:, ::-1], axis=1)
                            csame = flip[:, ::-1]          # Σ age ≥ a
                            colder = csame - bs            # Σ age > a
                            tq = jnp.take_along_axis(colder, aidx, 1)
                            cq = jnp.take_along_axis(csame, aidx, 1)
                            capq = e[:, None]
                            fullq = has & (cq <= capq)
                            marg = has & (tq < capq) & (cq > capq)
                            bm = jnp.where(marg, bval, 0.0)
                            wq = jnp.cumsum(bm, axis=1) - bm
                            room = capq - (tq + wq)
                            pour = jnp.where(room > CAP_EPS,
                                             jnp.minimum(b, room), 0.0)
                            g = jnp.where(fullq, b,
                                          jnp.where(marg, pour, 0.0))
                            nclip = (has & (age == Wr - 1)).sum(axis=1)
                            amb = ((marg & (aidx == Wr - 1)).any(axis=1)
                                   & (nclip >= 2)).any()
                            return g, amb
                    bg_grants, bg_amb = lax.cond(
                        easy, _bg_easy, _bg_hard,
                        out["backlog"], out["ptr"], eff)
                    out["exact"] = out["exact"] & ~bg_amb
                    cap_fl = eff - bg_grants.sum(axis=1)
                else:
                    cap_fl = eff
                if not fast:
                    fl_grants = _waterfill_device(
                        backlog_onu,
                        _hol_per_onu(qb, push_key, push_time),
                        cap_fl, use_pallas)
            else:
                fl_grants = _slot_grants(backlog_onu, t, cap_cyc)
                if has_cps:
                    # recompute with the waterfilled shares is a bitwise
                    # no-op for rows the CPS does not cut (the numpy
                    # path's conditional recompute, branch-free)
                    want = fl_grants.sum(axis=1)
                    eff = _ref.cps_waterfill_ref(
                        want.reshape(-1, P), dyn["cps_cap"]).reshape(-1)
                    fl_grants = _slot_grants(backlog_onu, t, eff)

            # ---- bg serve: full drains + the one marginal queue/row
            if has_bg:
                cum, drained = out["cum"], out["drained"]
                backlog, ptr = out["backlog"], out["ptr"]
                full = (bg_grants > 0.0) & (bg_grants == backlog)
                budget = jnp.where(full, 0.0, bg_grants)
                drained = jnp.where(full, cum, drained)
                backlog = jnp.where(full, 0.0, backlog)
                ptr = jnp.where(full, k + 1, ptr)
                part_q = budget > CAP_EPS
                has_part = part_q.any(axis=1)
                jm = jnp.argmax(part_q, axis=1)     # ≤1 partial per row
                rows = jnp.arange(R)
                tgt = drained[rows, jm] + budget[rows, jm]
                cum_q = cum[rows, jm]
                # prefix values of the marginal queue over the last Wr
                # cycles, ascending (pre-history ring slots hold 0 and
                # never exceed a positive target)
                cyc_idx = jnp.arange(Wr, dtype=jnp.int32) - (Wr - 1) + k
                pref = jnp.take(out["ring"][rows, jm],
                                cyc_idx & (Wr - 1), axis=1)
                ex1 = pref > tgt[:, None]
                j1rel = jnp.argmax(ex1, axis=1).astype(jnp.int32)
                jstar = k - (Wr - 1) + j1rel
                seg_end = jnp.take_along_axis(
                    pref, j1rel[:, None], 1)[:, 0]
                snap = seg_end - tgt <= SEG_EPS
                dr1 = jnp.where(snap, seg_end, tgt)
                bklg = cum_q - dr1
                low = bklg < 0.5
                # snap consumed through jstar; next head = first later
                # cycle whose prefix exceeds the snapped drain (always
                # in-window: prefix(k) = cum > drained when not low)
                ex2 = ((pref > dr1[:, None])
                       & (jnp.arange(Wr)[None, :] > j1rel[:, None]))
                j2 = k - (Wr - 1) + jnp.argmax(ex2, axis=1).astype(
                    jnp.int32)
                new_dr = jnp.where(low, cum_q, dr1)
                new_bk = jnp.where(low, 0.0, bklg)
                new_pt = jnp.where(low, k + 1,
                                   jnp.where(snap, j2, jstar))
                # the walk is exact unless the head had already aged out
                # of the ring AND the window start exceeds the target
                stale = has_part & ex1[:, 0] & (
                    ptr[rows, jm] < k - (Wr - 1))
                drained = drained.at[rows, jm].set(
                    jnp.where(has_part, new_dr, drained[rows, jm]))
                backlog = backlog.at[rows, jm].set(
                    jnp.where(has_part, new_bk, backlog[rows, jm]))
                ptr = ptr.at[rows, jm].set(
                    jnp.where(has_part, new_pt, ptr[rows, jm]))
                out.update(cum=cum, drained=drained, backlog=backlog,
                           ptr=ptr,
                           exact=out["exact"] & ~stale.any())

            # ---- FL serve + completion credit
            if fast:
                # Single-client queues keep their (push_time, column)
                # key for the whole phase and pushes are ready-driven,
                # so service is strictly prefix-contiguous in a
                # priority order known before the loop: the waterfill
                # pour over all queues reduces to advancing one
                # cumulative-service scalar S per row against the
                # host-precomputed demand boundaries Q_r.  A client's
                # sub-SEG_EPS residual is discarded on its last serve
                # (the numpy drop), which is exactly "snap S to the
                # next boundary when it lands within SEG_EPS below it"
                # — the drop and the EPS_BITS credit share the same
                # threshold, so rank r is complete iff Q_r <= S.
                s_pre = c["fls"]
                capx = jnp.maximum(cap_fl, 0.0)
                s1 = jnp.where(
                    cap_fl > CAP_EPS,
                    jnp.where(fl_tot <= capx, t_k, s_pre + capx),
                    s_pre)
                qpad = jnp.concatenate(
                    [dyn["q_bound"], jnp.full((R, 1), jnp.inf, f64)],
                    axis=1)
                rkx = _first_ge(dyn["q_bound"], s1)
                qv = jnp.take_along_axis(
                    qpad, rkx.astype(jnp.int64)[:, None], axis=1)[:, 0]
                bump = (s1 > s_pre) & (qv - s1 <= SEG_EPS)
                s2 = jnp.where(bump, qv, s1)
                c_new = _count_le(dyn["q_bound"], s2)
                c_old = c["cdone"]

                def _credit(dt):
                    hit = ((dyn["rank_u"] >= c_old[:, None])
                           & (dyn["rank_u"] < c_new[:, None]))
                    return jnp.where(hit, t + cyc + prop, dt)

                out.update(
                    fls=s2,
                    cdone=c_new,
                    done_t=lax.cond((c_new > c_old).any(), _credit,
                                    lambda dt: dt, c["done_t"]),
                    k=k + 1,
                    t=t + cyc,
                )
                return out
            if single:
                fl_budget = (fl_grants if identity
                             else fl_grants[:, lay_onu])
                act = (fl_budget > CAP_EPS) & (qb > 0.0)
                take = jnp.where(act, jnp.minimum(fl_budget, qb), 0.0)
                drop = act & (qb - take <= SEG_EPS)
                qb2 = jnp.where(drop, 0.0, qb - take)
            else:
                fullf = (fl_grants > 0.0) & (fl_grants == backlog_onu)
                qb1 = jnp.where(fullf[:, lay_onu], 0.0, qb)
                budget0 = jnp.where(fullf, 0.0, fl_grants)[:, seg_onus]
                rows2 = jnp.arange(R)[:, None]

                def serve_it(_, st):
                    qb_c, budget_c = st
                    has, pos = _heads(qb_c, push_key)
                    srv = has & (budget_c > CAP_EPS)
                    hq = jnp.take_along_axis(qb_c, pos, axis=1)
                    take = jnp.where(srv, jnp.minimum(budget_c, hq),
                                     0.0)
                    resid = jnp.where(srv, hq - take, jnp.inf)
                    drop = srv & (resid <= SEG_EPS)
                    newq = jnp.where(drop, 0.0, hq - take)
                    # scatter through a scratch column: non-served
                    # segments park at index U instead of clobbering
                    # column 0
                    qb_ext = jnp.concatenate(
                        [qb_c, jnp.zeros((R, 1), f64)], axis=1)
                    qb_ext = qb_ext.at[
                        rows2, jnp.where(srv, pos, U)].set(
                        jnp.where(srv, newq, 0.0))
                    charge = jnp.where(drop, resid, 0.0)
                    return (qb_ext[:, :U],
                            jnp.maximum(budget_c - take - charge, 0.0))

                qb2, _ = lax.fori_loop(0, max_slots, serve_it,
                                       (qb1, budget0))
            drained_fl = qb - qb2
            new_rem = c["rem"] - drained_fl
            newly_done = (~c["done"] & (drained_fl > 0.0)
                          & (new_rem <= EPS_BITS))
            out.update(
                qb=qb2,
                rem=jnp.where(newly_done, 0.0,
                              jnp.maximum(new_rem, 0.0)),
                done=c["done"] | newly_done,
                done_t=jnp.where(newly_done, t + cyc + prop,
                                 c["done_t"]),
                k=k + 1,
                t=t + cyc,
            )
            return out

        final = lax.while_loop(cond, body, carry)
        done_t, t = final["done_t"], final["t"]
        if fast:
            # reconstruct per-client rem/done from the final S against
            # each column's demand boundary (done clients land at
            # exactly 0.0, untouched queues at exactly rem0)
            scol = final["fls"][:, None]
            served_done = dyn["pushes"] & (dyn["q_col"] <= scol)
            done_f = done0 | served_done
            rem_f = jnp.where(
                dyn["pushes"],
                jnp.clip(dyn["q_col"] - scol, 0.0, rem0), rem0)
        else:
            done_f = final["done"]
            rem_f = final["rem"]
        if has_deadline:
            left = part & ~done_f & ~dyn["finite_dl"][:, None]
            done_t = jnp.where(left, t + prop, done_t)
        elif fill_unfinished:
            left = part & ~done_f
            done_t = jnp.where(left, t + prop, done_t)
        return done_t, rem_f, final["exact"]

    donate = () if jax.default_backend() == "cpu" else (0,)
    return jax.jit(program, donate_argnums=donate)


def run_phase_device(cfg, lay, rem_init, ready_t, mode: str, *,
                     keys=None, lams=None, slot_arrays=None,
                     max_t: float = 600.0, fill_unfinished: bool = True,
                     cap_row=None, cps_cap: Optional[float] = None,
                     n_pons: int = 1, deadline_row=None,
                     outage_row=None, use_pallas: Optional[bool] = None,
                     ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
    """Run one phase on device.  Mirrors ``engine._run_phase``'s
    signature with the host ``_Stream`` replaced by its raw
    ``(keys, lams)`` so sampling fuses into the program.

    Returns ``(done_t, rem)`` numpy arrays, or ``None`` when the bg
    ring walk lost exactness (sustained overload aged a marginal head
    out of the ``HISTORY_CYCLES`` ring) — the caller re-runs the phase
    on the numpy engine.
    """
    R, U = rem_init.shape
    N = int(cfg.n_onus)
    cyc = float(cfg.cycle_time_s)
    prop = float(cfg.propagation_s)
    if cap_row is None:
        cap_row = np.full(
            (R,), cfg.line_rate_bps * cyc * cfg.efficiency)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    has_deadline = deadline_row is not None
    has_outage = outage_row is not None
    has_cps = cps_cap is not None
    if has_deadline:
        cap_t = np.where(np.isfinite(deadline_row), deadline_row, max_t)
        tmax = float(cap_t.max())
    else:
        tmax = float(max_t)
    k_max = int(np.ceil(max(tmax, 0.0) / cyc)) + 16

    use_bg = mode == "fcfs"
    lams = (np.zeros((R,), np.float32) if lams is None
            else np.asarray(lams, np.float32))
    has_bg = bool(use_bg and lams.size and float(lams.max()) > 0.0)
    n_draws = 0
    dyn = {
        "cyc": np.float64(cyc),
        "prop": np.float64(prop),
        "tmax": np.float64(tmax),
        "k_max": np.int32(k_max),
        "part": np.asarray(lay.part, bool),
        "rem0": np.asarray(rem_init, np.float64),
        "ready": np.asarray(ready_t, np.float64),
        "list_pos": np.asarray(lay.list_pos, np.int64),
        "cap_col": np.asarray(cap_row, np.float64),
    }
    if mode == "fcfs" and lay.single:
        # Scalar-S tables (see the grant/serve step): push cycles and
        # push times are ready-driven, so replay the loop's exact float
        # accumulation of t on the host, sort the priority order once,
        # and hand the program cumulative-demand boundaries per rank.
        t_seq = np.empty(k_max, np.float64)
        t_seq[0] = 0.0
        if k_max > 1:
            np.cumsum(np.full(k_max - 1, cyc), out=t_seq[1:])
        tc = t_seq + cyc                    # the loop's t + cyc values
        ready = np.asarray(ready_t, np.float64)
        kp = np.searchsorted(tc, ready.ravel()).reshape(R, U)
        part_b = np.asarray(lay.part, bool)
        rem_b = np.asarray(rem_init, np.float64)
        pushes = part_b & (rem_b > 0.0) & (kp < k_max)
        pt = np.where(
            pushes,
            np.maximum(ready, t_seq[np.minimum(kp, k_max - 1)]),
            np.inf)
        # rank order = the waterfill's stable sort over per-ONU push
        # times: primary key push time, ties broken by ONU index
        onu_key = np.broadcast_to(
            np.asarray(lay.onu, np.int64), (R, U))
        rk = np.lexsort((onu_key, pt), axis=1)          # rank -> col
        rows_ = np.arange(R)[:, None]
        m_rank = np.where(pushes, rem_b, 0.0)[rows_, rk]
        p_incl = np.zeros((R, U + 1))
        np.cumsum(m_rank, axis=1, out=p_incl[:, 1:])
        push_rank = pushes[rows_, rk]
        q_bound = np.where(push_rank, p_incl[:, 1:], np.inf)
        rank_u = np.argsort(rk, axis=1)                 # col -> rank
        dyn["kp_rank"] = np.where(
            push_rank, kp[rows_, rk], k_max).astype(np.int32)
        dyn["p_incl"] = p_incl
        dyn["q_bound"] = q_bound
        dyn["rank_u"] = rank_u.astype(np.int32)
        dyn["q_col"] = q_bound[rows_, rank_u]
        dyn["pushes"] = pushes
        dyn["m_live"] = (part_b & (rem_b > 0.0)).sum(
            axis=1).astype(np.int32)
    if has_deadline:
        dyn["cap_t"] = np.asarray(cap_t, np.float64)
        dyn["finite_dl"] = np.isfinite(deadline_row)
    if has_outage:
        dyn["out0"] = np.asarray(outage_row[:, 0], np.float64)
        dyn["out1"] = np.asarray(outage_row[:, 1], np.float64)
    if has_cps:
        dyn["cps_cap"] = np.float64(cps_cap)
    if has_bg:
        from repro.kernels.traffic.ops import (_poisson_thresholds,
                                               _tail_bound)
        from repro.net.engine import PACKET_BITS

        lam_w = np.asarray(lams, np.float64) * WINDOW
        n_draws = _tail_bound(float(lam_w.max()))
        dyn["keys"] = np.asarray(keys, np.uint32)
        dyn["thr"] = _poisson_thresholds(lam_w, n_draws)
        dyn["inv_burst"] = np.float32(1.0 / cfg.bg_burst_packets)
        dyn["packet_bits"] = np.float32(PACKET_BITS)
    S = 1
    if mode == "bs":
        ts, te, sonu, srate, svalid = slot_arrays
        S = ts.shape[1]
        dyn.update(ts=np.asarray(ts, np.float64),
                   te=np.asarray(te, np.float64),
                   sonu=np.asarray(sonu, np.int64),
                   srate=np.asarray(srate, np.float64),
                   svalid=np.asarray(svalid, bool))

    max_slots = int(np.asarray(lay.seg_len).max())
    spec = (mode, R, U, N, S, int(n_pons), has_bg, has_cps,
            has_deadline, has_outage, bool(fill_unfinished),
            bool(use_pallas), max_slots, n_draws,
            hash(np.asarray(lay.onu).tobytes()))

    with enable_x64():
        prog = _programs.get(spec)
        if prog is None:
            if len(_programs) > 64:
                _programs.clear()
            prog = _programs[spec] = _build_program(spec, lay, n_draws)
        dyn_dev = {key: jnp.asarray(val) for key, val in dyn.items()}
        done_t, rem, exact = prog(dyn_dev)
        if not bool(exact):
            return None
        return np.asarray(done_t), np.asarray(rem)
