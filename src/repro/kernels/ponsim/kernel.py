"""Pallas TPU kernel for the waterfill grant step.

The grant step of the cycle engine serves queues oldest-first until the
cycle capacity runs out (``repro.net.engine._waterfill``).  The numpy /
XLA oracles express it as a stable argsort + prefix sum; on TPU a sort
per cycle is the wrong shape (tiny rows, huge batch), so this kernel
uses the O(N^2) *rank-sum* form instead:

    S_i   = sum_j backlog_j * [key_j < key_i  or  (key_j == key_i and j < i)]
    room  = cap - S_i
    g_i   = min(backlog_i, room)   if room > eps else 0

``S_i`` is exactly the sorted-prefix "water already poured" for queue
``i`` under a *stable* oldest-first order, so the grants match the sort
formulation (up to f32 accumulation order).  The comparison matrix is a
natural MXU/VPU shape: a (BI, BJ) mask contracted against a BJ backlog
tile, streamed over j-tiles with a fori accumulator — no sort, no
scatter.

Full drains must stay *exact* (the serve step detects them by float
equality), so callers recover them from ``g == backlog`` in f32 — when
``room >= backlog`` the kernel emits bitwise ``backlog`` — and restore
the f64 backlog for those lanes (``ops._waterfill_device``).

The kernel is only dispatched on TPU backends; the CPU container
exercises it through ``interpret=True`` (tests/test_ponsim_jit.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CAP_EPS = 1e-9   # repro.net.engine.CAP_EPS

BLOCK_I = 128    # queues granted per grid cell (lane width)
BLOCK_J = 128    # contribution tile streamed per fori step


def _waterfill_kernel(b_ref, key_ref, cap_ref, brow_ref, krow_ref, g_ref,
                      *, n_cols: int):
    i = pl.program_id(1)
    bi = b_ref[0, :]                              # (BI,) this row's tile
    ki = key_ref[0, :]
    idx_i = i * BLOCK_I + jax.lax.broadcasted_iota(
        jnp.int32, (BLOCK_I,), 0)

    def body(jc, acc):
        sl = (pl.dslice(0, 1), pl.dslice(jc * BLOCK_J, BLOCK_J))
        bj = pl.load(brow_ref, sl)[0]             # (BJ,) whole-row tile
        kj = pl.load(krow_ref, sl)[0]
        idx_j = jc * BLOCK_J + jax.lax.broadcasted_iota(
            jnp.int32, (BLOCK_J,), 0)
        earlier = (kj[None, :] < ki[:, None]) | (
            (kj[None, :] == ki[:, None])
            & (idx_j[None, :] < idx_i[:, None])
        )
        return acc + jnp.sum(
            jnp.where(earlier, bj[None, :], jnp.float32(0.0)), axis=1)

    n_tiles = n_cols // BLOCK_J
    served = jax.lax.fori_loop(
        0, n_tiles, body, jnp.zeros((BLOCK_I,), jnp.float32))
    room = cap_ref[0] - served
    g = jnp.where(room > jnp.float32(CAP_EPS),
                  jnp.minimum(bi, room), jnp.float32(0.0))
    g_ref[0, :] = g


@functools.partial(jax.jit, static_argnames=("interpret",))
def waterfill_grants_pallas(backlog, key, cap, *, interpret: bool = False):
    """Rank-sum waterfill grants, float32.

    backlog: (R, N) f32, key: (R, N) f32 (lower = older; +inf = empty),
    cap: (R,) f32.  N must be a multiple of 128 — pad with
    ``backlog=0, key=+inf`` (a zero-backlog queue contributes nothing
    and takes nothing).  Returns (R, N) f32 grants; full drains are
    bitwise ``backlog``.
    """
    r, n = backlog.shape
    if n % BLOCK_I:
        raise ValueError(f"n_queues {n} not a multiple of {BLOCK_I}")
    grid = (r, n // BLOCK_I)
    return pl.pallas_call(
        functools.partial(_waterfill_kernel, n_cols=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BLOCK_I), lambda i, j: (i, j)),
            pl.BlockSpec((1, BLOCK_I), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1, n), lambda i, j: (i, 0)),
            pl.BlockSpec((1, n), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_I), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, n), jnp.float32),
        interpret=interpret,
    )(backlog, key, cap, backlog, key)
