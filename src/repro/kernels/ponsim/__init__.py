"""On-device PON cycle engine: kernel (Pallas) / ops (program) / ref.

The jit backend of ``repro.net.engine`` (``backend="jit"``): one
``lax.while_loop`` device program per transfer phase, with the traffic
sampler fused in and the waterfill grant step as a Pallas TPU kernel
(XLA oracle elsewhere).  Mirrors the ``repro.kernels.traffic``
kernel/ops/ref layout.
"""
from repro.kernels.ponsim.ops import (  # noqa: F401
    HISTORY_CYCLES,
    compile_count,
    run_phase_device,
)
from repro.kernels.ponsim.ref import (  # noqa: F401
    cps_waterfill_ref,
    sample_window_ref,
    waterfill_grants_ref,
    waterfill_grants_xla,
)
