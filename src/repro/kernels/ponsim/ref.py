"""Pure-jnp oracle pieces for the on-device PON cycle engine.

The numpy engine (``repro.net.engine``) advances one polling cycle per
Python iteration; the jit backend (``ops.py``) re-expresses the whole
phase as one ``lax.while_loop`` device program. This module holds the
per-cycle *grant* primitives of that program in plain jnp — the exact
semantic mirrors of their numpy counterparts:

* :func:`waterfill_grants_ref` — oldest-first sequential
  ``take = min(backlog, cap)`` grants as stable argsort + prefix-sum
  room, including the numpy path's lazy skip (when total demand sits a
  bit under capacity every queue is granted its full backlog, bitwise —
  a ``lax.cond`` keeps that exactness AND skips the sort on device);
* :func:`cps_waterfill_ref` — the max-min CPS split across a case's
  PONs (``repro.net.multi_pon.cps_waterfill`` in jnp, same closed-form
  water level);
* :func:`sample_window_ref` — one 64-cycle window of the counter-based
  Poisson-burst sampler with a *traced* window index, so the scan can
  generate arrival bits on-device. It reuses the integer threefry and
  the float32 burst mappings of ``repro.kernels.traffic.ref`` verbatim,
  which is what makes the fused stream bit-identical to the host
  sampler (pinned by tests/test_ponsim_jit.py).

Everything here is dtype-explicit: the queue arithmetic runs in float64
(under the backend entry point's scoped x64 guard, ``ops.py``) while the
sampler stays uint32/float32 exactly like every other traffic backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.traffic.ref import (
    _WIN_SHIFT,
    UNIT_SCALE,
    WINDOW,
    draw_key,
    threefry2x32_ref,
)

CAP_EPS = 1e-9        # repro.net.engine.CAP_EPS


def waterfill_grants_ref(backlog, hol, cap):
    """Oldest-first waterfill grants ``(R, N)`` — the jnp mirror of
    ``repro.net.engine._waterfill``.

    ``hol`` is any array that sorts queues by head-of-line age (float
    times with ``inf`` for empty queues, or integer arrival cycles);
    ``cap`` is the per-row cycle capacity ``(R,)``. When no row's total
    demand exceeds ``cap - 1`` every queue takes its full backlog
    *bitwise* (the numpy lazy path) and the sort is skipped on device
    too (``lax.cond``).
    """

    def _sorted(args):
        backlog, hol, cap = args
        R = backlog.shape[0]
        order = jnp.argsort(hol, axis=1, stable=True)
        rows = jnp.arange(R)[:, None]
        b_s = jnp.take_along_axis(backlog, order, axis=1)
        prefix = jnp.cumsum(b_s, axis=1)
        room = cap[:, None] - (prefix - b_s)
        g_s = jnp.where(room > CAP_EPS, jnp.minimum(b_s, room), 0.0)
        g = jnp.zeros_like(backlog).at[rows, order].set(g_s)
        # rows under capacity keep the exact-backlog fast path (the
        # serve step detects full drains by float *equality*)
        hard = backlog.sum(axis=1) > cap - 1.0
        return jnp.where(hard[:, None], g, backlog)

    any_hard = jnp.any(backlog.sum(axis=1) > cap - 1.0)
    return lax.cond(any_hard, _sorted, lambda args: args[0],
                    (backlog, hol, cap))


def cps_waterfill_ref(want, cap):
    """Max-min fair CPS split, jnp mirror of
    ``repro.net.multi_pon.cps_waterfill`` for a ``(G, P)`` batch.

    Non-over rows return ``want`` unchanged (bitwise, like the numpy
    early-out); over rows sit at the exact water level
    ``eff_p = min(want_p, mu)``.
    """
    G, P = want.shape
    tot = want.sum(axis=1)
    over = tot > cap + CAP_EPS
    ws = jnp.sort(want, axis=1)
    cum = jnp.cumsum(ws, axis=1)
    prev = cum - ws
    mu_k = (cap - prev) / (P - jnp.arange(P, dtype=want.dtype))
    kk = jnp.argmax(mu_k <= ws, axis=1)
    mu = jnp.take_along_axis(mu_k, kk[:, None], axis=1)
    return jnp.where(over[:, None], jnp.minimum(want, mu), want)


def sample_window_ref(keys, thresholds, win, *, n_onus: int,
                      n_draws: int, inv_burst, packet_bits):
    """Arrival bits ``(R, WINDOW, n_onus)`` float32 for window ``win``.

    The in-scan variant of ``traffic.ref.sample_arrival_bits_ref``: one
    window at a time, with the window index *traced* (it is the scan
    counter ``k >> 6``) instead of static. Same draws, same integer
    thresholds, same float32 burst mappings — the produced stream is
    bit-identical to every host backend (tested).
    """
    keys = jnp.asarray(keys, jnp.uint32)
    thresholds = jnp.asarray(thresholds, jnp.int32)
    inv_burst = jnp.asarray(inv_burst, jnp.float32)
    R = keys.shape[0]
    c0 = jnp.asarray(win, jnp.uint32)                    # window counter
    c1 = jnp.arange(n_onus, dtype=jnp.uint32)[None, :]
    k0 = keys[:, 0][:, None]
    k1 = keys[:, 1][:, None]

    # window burst count: integer inverse CDF, k = #{ j : bits > T_j }
    kd0, kd1 = draw_key(k0, k1, 0)
    w0, _ = threefry2x32_ref(kd0, kd1, c0, c1)           # (R, N)
    b24 = (w0 >> jnp.uint32(8)).astype(jnp.int32)
    count = (b24[:, None, :] > thresholds[:, :, None]).astype(
        jnp.int32).sum(axis=1)                            # (R, N)

    # bursts: draw j of every (row, onu) stream is an independent
    # threefry instance (Weyl key), so the j axis vectorises.  Per-cycle
    # packet totals are small integers — exactly representable in
    # float32 — so unordered scatter-adds produce the same bits as the
    # sequential per-draw accumulation they replace.  ``n_draws`` is a
    # Poisson tail bound ~2x the realised maximum count, so the second
    # half of the draws is usually all-dead: it is scattered (and its
    # threefry evaluated) only under a ``lax.cond`` — adding nothing is
    # bitwise adding zeros, so the skip is exact.
    inv_log_q = jnp.float32(1.0) / jnp.log1p(-inv_burst)

    def _scatter(buf, j0: int, j1: int):
        j = jnp.arange(j0 + 1, j1 + 1, dtype=jnp.uint32)[None, :, None]
        bd0, bd1 = draw_key(k0[:, None, :], k1[:, None, :], j)
        x0, x1 = threefry2x32_ref(bd0, bd1, c0, c1[None])  # (R, j, N)
        place = (x0 >> jnp.uint32(32 - _WIN_SHIFT)).astype(jnp.int32)
        u = (x1 >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(
            UNIT_SCALE
        )
        glen = jnp.float32(1.0) + jnp.floor(jnp.log1p(-u) * inv_log_q)
        live = j.astype(jnp.int32) <= count[:, None, :]
        return buf.at[
            jnp.arange(R)[:, None, None], place,
            jnp.arange(n_onus)[None, None, :],
        ].add(jnp.where(live, glen, jnp.float32(0.0)))

    j_half = max(1, n_draws // 2)
    packets = _scatter(
        jnp.zeros((R, WINDOW, n_onus), jnp.float32), 0, j_half)
    if j_half < n_draws:
        packets = lax.cond(
            count.max() > j_half,
            lambda p: _scatter(p, j_half, n_draws),
            lambda p: p, packets)
    return packets * jnp.asarray(packet_bits, jnp.float32)


def waterfill_grants_xla(backlog, hol, cap):
    """Standalone jitted entry for the oracle waterfill (parity tests
    call this directly; the scan program inlines the ref body)."""
    return jax.jit(waterfill_grants_ref)(backlog, hol, cap)
