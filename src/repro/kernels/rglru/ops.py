"""Jit'd wrapper for the RG-LRU scan: Pallas fwd, XLA-reference bwd."""
from __future__ import annotations


import jax

from repro.kernels.rglru.kernel import rglru_scan_fwd
from repro.kernels.rglru.ref import rglru_scan_ref


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@jax.custom_vjp
def rglru_scan(a, b, h0):
    return rglru_scan_fwd(a, b, h0, interpret=_interpret_default())


def _fwd(a, b, h0):
    return rglru_scan(a, b, h0), (a, b, h0)


def _bwd(res, g):
    a, b, h0 = res
    _, vjp = jax.vjp(rglru_scan_ref, a, b, h0)
    return vjp(g)


rglru_scan.defvjp(_fwd, _bwd)
