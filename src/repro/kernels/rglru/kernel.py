"""Pallas TPU kernel for the RG-LRU linear recurrence.

Computes h_t = a_t * h_{t-1} + b_t over the time axis, the core of Griffin's
RG-LRU (gates/inputs are fused elementwise pre-work done by the caller).

Grid: (batch, r_blocks, time_chunks) — time is the trailing (sequential)
dimension, so the carry h lives in VMEM scratch across chunks; inside a chunk
the recurrence steps over rows of a (time_chunk, block_r) VMEM tile. The
layout keeps the lane dimension (block_r = 128·k) fully vectorised: every
step is a fused multiply-add over 128-wide lanes, which is how a diagonal
linear RNN should hit the VPU.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_R = 128
DEFAULT_BLOCK_T = 128


def _rglru_kernel(a_ref, b_ref, h0_ref, o_ref, h_scr, *, block_t):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)       # (block_t, block_r)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]
        o_ref[0, t] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_t, step, h_scr[...])
    h_scr[...] = h


@functools.partial(
    jax.jit, static_argnames=("block_r", "block_t", "interpret")
)
def rglru_scan_fwd(
    a, b, h0=None,
    block_r: int = DEFAULT_BLOCK_R,
    block_t: int = DEFAULT_BLOCK_T,
    interpret: bool = False,
):
    """a, b: (B, S, R); h0: (B, R) or None. Returns h: (B, S, R) fp32."""
    B, S, R = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, R), jnp.float32)
    block_r = min(block_r, R)
    block_t = min(block_t, S)
    S_pad = math.ceil(S / block_t) * block_t
    R_pad = math.ceil(R / block_r) * block_r
    if (S_pad, R_pad) != (S, R):
        a = jnp.pad(a, ((0, 0), (0, S_pad - S), (0, R_pad - R)))
        b = jnp.pad(b, ((0, 0), (0, S_pad - S), (0, R_pad - R)))
        h0 = jnp.pad(h0, ((0, 0), (0, R_pad - R)))

    grid = (B, R_pad // block_r, S_pad // block_t)
    out = pl.pallas_call(
        functools.partial(_rglru_kernel, block_t=block_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, block_r), lambda b_, ri, ti: (b_, ti, ri)),
            pl.BlockSpec((1, block_t, block_r), lambda b_, ri, ti: (b_, ti, ri)),
            pl.BlockSpec((1, block_r), lambda b_, ri, ti: (b_, ri)),
        ],
        out_specs=pl.BlockSpec(
            (1, block_t, block_r), lambda b_, ri, ti: (b_, ti, ri)
        ),
        out_shape=jax.ShapeDtypeStruct((B, S_pad, R_pad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_r,), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return out[:, :S, :R]
