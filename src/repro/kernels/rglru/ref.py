"""Pure-jnp oracle: sequential lax.scan linear recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a, b, h0=None):
    """h_t = a_t * h_{t-1} + b_t. a, b: (B,S,R); h0: (B,R)|None -> (B,S,R)."""
    B, S, R = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, R), jnp.float32)

    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    _, hs = jax.lax.scan(
        step,
        h0.astype(jnp.float32),
        (
            jnp.moveaxis(a.astype(jnp.float32), 1, 0),
            jnp.moveaxis(b.astype(jnp.float32), 1, 0),
        ),
    )
    return jnp.moveaxis(hs, 0, 1)
