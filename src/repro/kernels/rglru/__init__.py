from repro.kernels.rglru import ops, ref  # noqa: F401
from repro.kernels.rglru.ops import rglru_scan  # noqa: F401
