"""Public int8 compression ops (Pallas on TPU, interpret elsewhere)."""
from __future__ import annotations

import jax

from repro.kernels.quant.kernel import dequantize_int8_fwd, quantize_int8_fwd


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def quantize_int8(x, block: int = 4096):
    return quantize_int8_fwd(x, block=block, interpret=_interpret_default())


def dequantize_int8(q, scales, block: int = 4096):
    return dequantize_int8_fwd(
        q, scales, block=block, interpret=_interpret_default()
    )


def roundtrip(x, block: int = 4096):
    """quantise+dequantise, same shape back (the wire transform)."""
    q, s = quantize_int8(x, block)
    flat = dequantize_int8(q, s, block)
    return flat[: x.size].reshape(x.shape).astype(x.dtype)
