"""Pure-jnp oracle for blockwise int8 quantisation."""
from __future__ import annotations

import math

import jax.numpy as jnp


def quantize_int8_ref(x, block: int = 4096):
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.size
    block = min(block, max(n, 1))
    n_pad = math.ceil(n / block) * block
    if n_pad != n:
        flat = jnp.pad(flat, (0, n_pad - n))
    blocks = flat.reshape(-1, block)
    amax = jnp.max(jnp.abs(blocks), axis=1)
    scales = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scales[:, None]), -127, 127)
    return q.reshape(-1).astype(jnp.int8), scales.astype(jnp.float32)


def dequantize_int8_ref(q, scales, block: int = 4096):
    block = min(block, max(q.size, 1))
    blocks = q.reshape(-1, block).astype(jnp.float32)
    return (blocks * scales[:, None]).reshape(-1)


def roundtrip_ref(x, block: int = 4096):
    q, s = quantize_int8_ref(x, block)
    flat = dequantize_int8_ref(q, s, block)
    return flat[: x.size].reshape(x.shape)
