"""Pallas TPU kernel: blockwise symmetric int8 quantisation (+dequant).

The paper's ``M_i^UD`` lever on-device: model updates are quantised to int8
with one fp32 scale per block before hitting the wire (4x traffic reduction
feeding Algorithm 1's ``B = Σ M_i^UD / τ``), and dequantised on the CPS.

Grid: 1-D over blocks of the flattened tensor; each program reduces its
(block,) tile to an absmax, derives the scale, and writes the int8 payload +
scale — one VMEM pass, no HBM round-trip for the scale computation.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 4096


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[0] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[0]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def quantize_int8_fwd(x, block: int = DEFAULT_BLOCK, interpret: bool = False):
    """x: any shape -> (q int8 flat-padded, scales (n_blocks,), orig_size)."""
    flat = x.reshape(-1)
    n = flat.size
    block = min(block, max(n, 1))
    n_pad = math.ceil(n / block) * block
    if n_pad != n:
        flat = jnp.pad(flat, (0, n_pad - n))
    n_blocks = n_pad // block
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.int8),
            jax.ShapeDtypeStruct((n_blocks,), jnp.float32),
        ],
        interpret=interpret,
    )(flat)
    return q, s


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dequantize_int8_fwd(q, scales, block: int = DEFAULT_BLOCK,
                        interpret: bool = False):
    n_pad = q.size
    block = min(block, max(n_pad, 1))
    n_blocks = n_pad // block
    x = pl.pallas_call(
        _dequant_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        interpret=interpret,
    )(q, scales)
    return x
