"""Pallas TPU flash-attention forward kernel (causal / sliding-window / GQA).

Grid: (batch, q_heads, q_blocks, kv_blocks) — the trailing kv dimension is
sequential on TPU, so the online-softmax running state (m, l, acc) lives in
VMEM scratch and persists across kv iterations for the same q block.
BlockSpecs tile q/k/v to MXU-aligned (block_q x d_head) / (block_k x d_head)
VMEM windows; kv blocks that lie entirely outside the causal/window band are
skipped via ``pl.when`` (no VMEM traffic is wasted on them — the index map
still runs, but the body does not).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, block_q, block_k, seq_len, causal, window, scale,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    n_k = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # is any (query, key) pair in this tile inside the causal/window band?
    first_q = q_start
    last_q = q_start + block_q - 1
    first_k = k_start
    live = True
    if causal:
        live = first_k <= last_q
    if window is not None:
        # newest key visible to the oldest query: q - k < window
        live = jnp.logical_and(live, first_q - (k_start + block_k - 1) < window)

    @pl.when(live if isinstance(live, jnp.ndarray) else live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # (block_q, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (block_k, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                       # (block_q, block_k)

        q_pos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0
        )
        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1
        )
        mask = k_pos < seq_len
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, q_pos - k_pos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == n_k - 1)
    def _finish():
        l = l_scr[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention_fwd(
    q, k, v,
    causal: bool = True,
    window=None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
):
    """q: (B, S, H, D); k, v: (B, T, K, D) with H % K == 0 -> (B, S, H, D)."""
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    group = H // K
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    # pad sequence dims to block multiples
    S_pad = math.ceil(S / block_q) * block_q
    T_pad = math.ceil(T / block_k) * block_k
    if S_pad != S:
        q = jnp.pad(q, ((0, 0), (0, S_pad - S), (0, 0), (0, 0)))
    if T_pad != T:
        k = jnp.pad(k, ((0, 0), (0, T_pad - T), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, T_pad - T), (0, 0), (0, 0)))

    # (B, S, H, D) -> (B, H, S, D) blocks are contiguous per head
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    grid = (B, H, S_pad // block_q, T_pad // block_k)
    kernel = functools.partial(
        _flash_kernel,
        block_q=block_q,
        block_k=block_k,
        seq_len=T,
        causal=causal,
        window=window,
        scale=D ** -0.5,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec(
                (1, 1, block_k, D),
                lambda b, h, qi, ki, g=group: (b, h // g, ki, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_k, D),
                lambda b, h, qi, ki, g=group: (b, h // g, ki, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, S_pad, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out.transpose(0, 2, 1, 3)
    return out[:, :S]
