"""Jit'd public wrapper: Pallas forward + XLA-reference backward.

The forward runs the Pallas kernel (interpret mode on CPU so the whole stack
stays testable in this container); the backward recomputes through the jnp
oracle and differentiates it — the standard "fast fwd, recompute bwd"
custom_vjp pattern, numerically identical to training directly on the
reference (the fwd values agree to kernel tolerance).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.attention.kernel import flash_attention_fwd
from repro.kernels.attention.ref import attention_ref


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q, k, v, causal: bool = True, window=None):
    return flash_attention_fwd(
        q, k, v, causal=causal, window=window,
        interpret=_interpret_default(),
    )


def _fwd(q, k, v, causal, window):
    out = flash_attention_fwd(
        q, k, v, causal=causal, window=window,
        interpret=_interpret_default(),
    )
    return out, (q, k, v)


def _bwd(causal, window, residuals, g):
    q, k, v = residuals
    _, vjp = jax.vjp(
        lambda q_, k_, v_: attention_ref(q_, k_, v_, causal, window), q, k, v
    )
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
