"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, causal: bool = True, window=None):
    """q: (B,S,H,D); k,v: (B,T,K,D), H % K == 0 -> (B,S,H,D). fp32 softmax."""
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, D).astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k.astype(jnp.float32))
    scores = scores * (D ** -0.5)
    qi = jnp.arange(S)[:, None]
    kj = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask = kj <= qi
    if window is not None:
        mask = mask & ((qi - kj) < window)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(jnp.float32))
    return out.reshape(B, S, H, D).astype(q.dtype)
