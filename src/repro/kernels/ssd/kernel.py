"""Pallas TPU kernel for the Mamba-2 SSD (state-space duality) scan.

The chunked SSD schedule maps the SSM onto the MXU: inside a chunk the
output is a masked (decay-weighted) attention-like product C·Bᵀ — dense
matmuls; across chunks a tiny state recurrence (P x N per head) carries in
VMEM scratch.

Grid: (batch, heads, chunks) with the chunk axis trailing (sequential), so
the running state h (d_head x d_state) persists in scratch. Per program the
VMEM working set is x (Q x P), B/C (Q x N), dt (Q), masks (Q x Q) — with
Q = 128, P = 64, N = 128 that is well under 1 MB: several programs fit VMEM
concurrently and every matmul dimension is 128-aligned for the MXU.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _ssd_kernel(x_ref, b_ref, c_ref, dt_ref, a_ref, o_ref, h_scr, *, chunk):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0].astype(jnp.float32)       # (Q, P)
    bm = b_ref[0].astype(jnp.float32)         # (Q, N)
    cm = c_ref[0].astype(jnp.float32)         # (Q, N)
    dt = dt_ref[0, 0].astype(jnp.float32)     # (Q,)
    a = a_ref[0, 0]                           # scalar decay rate (negative)

    da = dt * a                               # (Q,)
    cum = jnp.cumsum(da)                      # inclusive
    seg = cum[-1]

    # intra-chunk: scores[t, s] = (C_t . B_s) * exp(cum_t - cum_s) * dt_s, s<=t
    cb = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                         # (Q, Q)
    rel = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(tri, jnp.exp(rel), 0.0)
    scores = cb * decay * dt[None, :]
    y = jax.lax.dot_general(
        scores, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                         # (Q, P)

    # inter-chunk: y += exp(cum_t) * C_t . h_prev
    h = h_scr[...]                            # (P, N)
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cm, h, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    # state update: h = exp(seg) * h + sum_s exp(seg - cum_s) dt_s x_s B_s^T
    w = jnp.exp(seg - cum) * dt               # (Q,)
    xw = x * w[:, None]                       # (Q, P)
    h_new = jnp.exp(seg) * h + jax.lax.dot_general(
        xw, bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                         # (P, N)
    h_scr[...] = h_new
    o_ref[0, 0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_fwd(
    xh, b_mat, c_mat, dt, a,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
):
    """Chunked SSD scan.

    xh:    (B, S, H, P)  per-head inputs
    b_mat: (B, S, N)     shared input projection
    c_mat: (B, S, N)     shared output projection
    dt:    (B, S, H)     positive step sizes (fp32)
    a:     (H,)          negative decay rates
    Returns y: (B, S, H, P) fp32.
    """
    B, S, H, P = xh.shape
    N = b_mat.shape[-1]
    Q = min(chunk, S)
    S_pad = math.ceil(S / Q) * Q
    if S_pad != S:
        xh = jnp.pad(xh, ((0, 0), (0, S_pad - S), (0, 0), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, S_pad - S), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, S_pad - S), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, S_pad - S), (0, 0)))

    xt = xh.transpose(0, 2, 1, 3)             # (B, H, S, P)
    dtt = dt.transpose(0, 2, 1)               # (B, H, S)
    nc = S_pad // Q

    grid = (B, H, nc)
    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=Q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1), lambda b, h, c: (0, h)),
        ],
        out_specs=pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S_pad, P), jnp.float32),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xt, b_mat, c_mat, dtt, a.reshape(1, H))
    return out.transpose(0, 2, 1, 3)[:, :S]
