from repro.kernels.ssd import ops, ref  # noqa: F401
from repro.kernels.ssd.ops import ssd_scan  # noqa: F401
