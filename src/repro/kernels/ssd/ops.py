"""Jit'd wrapper for the SSD scan: Pallas fwd, XLA-reference bwd."""
from __future__ import annotations

import jax

from repro.kernels.ssd.kernel import ssd_scan_fwd
from repro.kernels.ssd.ref import ssd_scan_ref


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@jax.custom_vjp
def ssd_scan(xh, b_mat, c_mat, dt, a):
    return ssd_scan_fwd(xh, b_mat, c_mat, dt, a,
                        interpret=_interpret_default())


def _fwd(xh, b_mat, c_mat, dt, a):
    return ssd_scan(xh, b_mat, c_mat, dt, a), (xh, b_mat, c_mat, dt, a)


def _bwd(res, g):
    _, vjp = jax.vjp(ssd_scan_ref, *res)
    return vjp(g)


ssd_scan.defvjp(_fwd, _bwd)
