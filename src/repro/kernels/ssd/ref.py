"""Pure-jnp oracle: token-by-token SSM recurrence (the slow exact form)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(xh, b_mat, c_mat, dt, a):
    """Sequential SSM recurrence.

    h_t = exp(dt_t * a) h_{t-1} + dt_t * (x_t B_t^T);  y_t = C_t . h_t
    Shapes as in ssd_scan_fwd; returns (B, S, H, P) fp32.
    """
    B, S, H, P = xh.shape
    N = b_mat.shape[-1]

    def step(h, inp):
        x_t, b_t, c_t, dt_t = inp
        # h: (B, H, P, N)
        da = jnp.exp(dt_t * a[None, :])               # (B, H)
        inc = jnp.einsum("bh,bn,bhp->bhpn", dt_t, b_t, x_t)
        h = h * da[..., None, None] + inc
        y = jnp.einsum("bn,bhpn->bhp", c_t, h)
        return h, y

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    xs = (
        jnp.moveaxis(xh.astype(jnp.float32), 1, 0),
        jnp.moveaxis(b_mat.astype(jnp.float32), 1, 0),
        jnp.moveaxis(c_mat.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
    )
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1)
