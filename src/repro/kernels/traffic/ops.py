"""Public counter-based traffic sampling ops.

``sample_arrival_bits`` is the engine-facing entry point: given a batch
of stream keys it materialises any ``(cycle0, n_cycles)`` window of the
per-ONU background arrival process, identically regardless of how the
caller chunks the window (regression-tested).

Three interchangeable backends produce the *bit-identical* stream:

* ``"pallas"`` — the TPU kernel (``kernel.py``; ``"pallas_interpret"``
  runs it through the interpreter for CI parity tests);
* ``"xla"`` — the jitted pure-jnp oracle (``ref.py``);
* ``"numpy"`` — the sparse host path, default off-TPU: the uniform
  *bits* come from a vectorised numpy threefry (integer, exact), while
  every float mapping from bits to samples goes through XLA-evaluated
  tables (Poisson CDF prefix, geometric burst-length LUT), so no host
  libm ulp difference can leak into the stream. Burst lengths are only
  drawn for the ~``1-exp(-λ)`` fraction of nonzero cells, which is what
  makes this path faster than the dense draws it replaces.
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Optional, Tuple

import jax
import numpy as np

from repro.kernels.traffic import ref as _ref

_MASK32 = 0xFFFFFFFF
_ROTS = _ref._ROTS


# Weyl constants mixing the PON index into a stream key (murmur3 c1/c2;
# deliberately distinct from ref.KEY_WEYL_* so a pon-shifted stream can
# never alias another stream's per-draw derived keys).
_PON_WEYL_0 = 0xCC9E2D51
_PON_WEYL_1 = 0x1B873593

# Weyl constants mixing a tenant-job index into a stream key (murmur3
# final-avalanche / xorshift-mult constants; distinct from both the PON
# pair above and ref.KEY_WEYL_* for the same no-aliasing reason).
_JOB_WEYL_0 = 0xC2B2AE35
_JOB_WEYL_1 = 0x27D4EB2F


def make_stream_key(seed: int, phase: int, round_index: int = 0,
                    pon: int = 0, job: int = 0) -> np.ndarray:
    """uint32 ``(2,)`` key for one case's (phase, round, pon, job) stream.

    ``seed`` fills one key word, ``(phase, round)`` the other, and the
    PON and job indices Weyl-shift both words; threefry does the
    mixing. Distinct (seed, phase, round, pon, job) tuples therefore
    get independent streams, and a stream's values depend on nothing
    else — the O(1)-seek contract. ``pon=0`` reproduces the
    pre-multi-PON key bit-for-bit, and ``job=0`` the pre-multi-job key
    (both pinned by the stream regressions).
    """
    return np.array(
        [
            (seed + pon * _PON_WEYL_0 + job * _JOB_WEYL_0) & _MASK32,
            (phase + 2 * round_index + pon * _PON_WEYL_1
             + job * _JOB_WEYL_1) & _MASK32,
        ],
        np.uint32,
    )


def _tail_bound(lam_w: float) -> int:
    """Draw budget with negligible truncated Poisson tail mass for the
    per-*window* burst rate.

    ``λ_w + 12·sqrt(λ_w+1) + 8`` puts the truncation point ≥12 standard
    deviations above the mean (tail < 1e-20); rounded up to a multiple
    of 8 so distinct rates share compilations.
    """
    k = int(math.ceil(lam_w + 12.0 * math.sqrt(lam_w + 1.0) + 8.0))
    return max(8, int(math.ceil(k / 8.0)) * 8)


# ---------------------------------------------------------------------------
# numpy host path
# ---------------------------------------------------------------------------


def threefry2x32_np(k0, k1, c0, c1):
    """Vectorised numpy Threefry-2x32 (bit-identical to ``ref.py``)."""
    k0 = np.asarray(k0, np.uint32)
    k1 = np.asarray(k1, np.uint32)
    ks = (k0, k1, k0 ^ k1 ^ np.uint32(_ref._C240))
    x0 = np.asarray(c0, np.uint32) + ks[0]
    x1 = np.asarray(c1, np.uint32) + ks[1]
    for block in range(5):
        for r in _ROTS[block % 2]:
            x0 = x0 + x1
            x1 = (x1 << np.uint32(r)) | (x1 >> np.uint32(32 - r))
            x1 = x1 ^ x0
        x0 = x0 + ks[(block + 1) % 3]
        x1 = x1 + ks[(block + 2) % 3] + np.uint32(block + 1)
    return x0, x1


_TF_BLOCK = 1 << 15               # L2-resident working-set per pass


def _threefry_blocked(k0: int, k1: int, c0_flat, c1_flat, out0, out1,
                      tmp):
    """In-place blocked Threefry-2x32 for one *scalar* key pair.

    The dense draw-0 pass is the sampler's hot loop; the ~110 elementwise
    passes per call are memory-allocation-bound at full array size, so
    the state arrays are walked in L2-sized blocks with preallocated
    scratch (no temporaries, ~cache-resident traffic).
    """
    k0, k1 = int(k0), int(k1)
    ks = (k0, k1, k0 ^ k1 ^ _ref._C240)
    inj = [(np.uint32(ks[(b + 1) % 3]),
            np.uint32((ks[(b + 2) % 3] + b + 1) & _MASK32))
           for b in range(5)]
    n = len(c0_flat)
    for s in range(0, n, _TF_BLOCK):
        e = min(s + _TF_BLOCK, n)
        x0 = out0[s:e]
        x1 = out1[s:e]
        t = tmp[: e - s]
        np.add(c0_flat[s:e], np.uint32(ks[0]), out=x0)
        np.add(c1_flat[s:e], np.uint32(ks[1]), out=x1)
        for block in range(5):
            for r in _ROTS[block % 2]:
                np.add(x0, x1, out=x0)
                np.right_shift(x1, np.uint32(32 - r), out=t)
                np.left_shift(x1, np.uint32(r), out=x1)
                np.bitwise_or(x1, t, out=x1)
                np.bitwise_xor(x1, x0, out=x1)
            np.add(x0, inj[block][0], out=x0)
            np.add(x1, inj[block][1], out=x1)


@functools.lru_cache(maxsize=8)
def _geometric_lut(inv_burst: float) -> np.ndarray:
    return np.asarray(_ref.geometric_lut(inv_burst))


_cdf_cache: Dict[Tuple[bytes, int], np.ndarray] = {}


def _poisson_thresholds(lam_w: np.ndarray, n_draws: int) -> np.ndarray:
    key = (lam_w.tobytes(), n_draws)
    if key not in _cdf_cache:
        if len(_cdf_cache) > 64:
            _cdf_cache.clear()
        _cdf_cache[key] = _ref.poisson_thresholds(lam_w, n_draws)
    return _cdf_cache[key]


_BLOCK_OFF = 1 << 25              # > 2**24: per-case searchsorted offset


def _threefry_keys_blocked(kd0, kd1, c0, c1):
    """Blocked in-place Threefry-2x32 for per-element key arrays (the
    ragged burst-length draws, where the draw index varies per cell)."""
    n = len(c0)
    ks2 = kd0 ^ kd1 ^ np.uint32(_ref._C240)
    out0 = np.empty(n, np.uint32)
    out1 = np.empty(n, np.uint32)
    tmp = np.empty(min(_TF_BLOCK, n), np.uint32)
    for s in range(0, n, _TF_BLOCK):
        e = min(s + _TF_BLOCK, n)
        x0 = out0[s:e]
        x1 = out1[s:e]
        t = tmp[: e - s]
        ks = (kd0[s:e], kd1[s:e], ks2[s:e])
        np.add(c0[s:e], ks[0], out=x0)
        np.add(c1[s:e], ks[1], out=x1)
        for block in range(5):
            for r in _ROTS[block % 2]:
                np.add(x0, x1, out=x0)
                np.right_shift(x1, np.uint32(32 - r), out=t)
                np.left_shift(x1, np.uint32(r), out=x1)
                np.bitwise_or(x1, t, out=x1)
                np.bitwise_xor(x1, x0, out=x1)
            np.add(x0, ks[(block + 1) % 3], out=x0)
            np.add(x1, ks[(block + 2) % 3], out=x1)
            np.add(x1, np.uint32(block + 1), out=x1)
    return out0, out1


@functools.lru_cache(maxsize=8)
def _counter_templates(n_win: int, n_onus: int):
    return (
        np.repeat(np.arange(n_win, dtype=np.int64), n_onus),
        np.tile(np.arange(n_onus, dtype=np.uint32), n_win),
    )


def _window_counts(keys, win0, n_win, n_onus, lam_arr, n_draws):
    """Burst count per (case, window, onu): dense draw-0 threefry plus
    an offset-blocked integer searchsorted against each case's f64
    Poisson threshold table."""
    B = keys.shape[0]
    n_flat = n_win * n_onus
    c0_base, c1_flat = _counter_templates(n_win, n_onus)
    c0_flat = ((win0 + c0_base) & _MASK32).astype(np.uint32)
    w0 = np.empty((B, n_flat), np.uint32)
    w1 = np.empty((B, n_flat), np.uint32)
    tmp = np.empty(min(_TF_BLOCK, n_flat), np.uint32)
    for b in range(B):
        # word 1 of draw 0 is unused (the count consumes word 0 only)
        _threefry_blocked(keys[b, 0], keys[b, 1], c0_flat, c1_flat,
                          w0[b], w1[b], tmp)
    tables = _poisson_thresholds(
        np.asarray(lam_arr, np.float64) * _ref.WINDOW, n_draws
    ).astype(np.int64)
    table_all = (tables
                 + np.arange(B, dtype=np.int64)[:, None] * _BLOCK_OFF
                 ).ravel()
    b24 = (w0 >> np.uint32(8)).astype(np.int64)
    b24 += np.arange(B, dtype=np.int64)[:, None] * _BLOCK_OFF
    cnt = (np.searchsorted(table_all, b24.reshape(-1), side="left")
           - np.repeat(np.arange(B, dtype=np.int64), n_flat) * n_draws)
    return cnt, c0_flat, c1_flat


def _burst_groups(cnt):
    """(flat cell, draw index) pairs for every burst, via cumsum tricks
    (no ``np.repeat`` over the ragged axis)."""
    nz = np.flatnonzero(cnt)
    kk = cnt[nz]
    total = int(kk.sum())
    starts = np.zeros(len(nz), np.int64)
    np.cumsum(kk[:-1], out=starts[1:])
    step = np.zeros(total, np.int64)
    step[starts[1:]] = 1
    src = nz[np.cumsum(step)]
    dstep = np.ones(total, np.int64)
    dstep[starts[1:]] = 1 - kk[:-1]
    du = np.cumsum(dstep).astype(np.uint32)
    return src, du


def _burst_bits(keys, cnt, c0_flat, c1_flat, cycle0, win0, n_cycles,
                n_onus, n_flat, inv_burst):
    """Place and size every burst: one keyed threefry per burst — word 0
    places it on a cycle (top 6 bits, exactly uniform over the window),
    word 1 draws its geometric length — accumulated with bincount."""
    B = keys.shape[0]
    src, du = _burst_groups(cnt)
    g_b = src // n_flat
    g_f = src - g_b * n_flat
    kd0 = keys[g_b, 0] + du * np.uint32(_ref.KEY_WEYL_0)
    kd1 = keys[g_b, 1] ^ (du * np.uint32(_ref.KEY_WEYL_1))
    x0, x1 = _threefry_keys_blocked(
        kd0, kd1, c0_flat[g_f], c1_flat[g_f]
    )
    place = (x0 >> np.uint32(32 - 6)).astype(np.int64)
    glen = _geometric_lut(float(inv_burst))[x1 >> np.uint32(8)]
    win_i = g_f // n_onus
    onu_i = g_f - win_i * n_onus
    cyc = (win_i << 6) + place - (cycle0 - (win0 << 6))
    ok = (cyc >= 0) & (cyc < n_cycles)
    dest = (g_b * n_cycles + cyc) * n_onus + onu_i
    return np.bincount(
        dest[ok], weights=glen[ok], minlength=B * n_cycles * n_onus,
    )


def _sample_numpy(keys, cycle0, lam_arr, inv_burst, packet_bits,
                  n_cycles, n_onus, n_draws):
    B = keys.shape[0]
    win0 = cycle0 >> 6
    n_win = ((cycle0 + n_cycles - 1) >> 6) - win0 + 1
    cnt, c0_flat, c1_flat = _window_counts(
        keys, win0, n_win, n_onus, lam_arr, n_draws
    )
    if cnt.any():
        out_flat = _burst_bits(
            keys, cnt, c0_flat, c1_flat, cycle0, win0, n_cycles,
            n_onus, n_win * n_onus, inv_burst,
        )
    else:
        out_flat = np.zeros(B * n_cycles * n_onus)
    out = out_flat.reshape(B, n_cycles, n_onus)
    return out * float(packet_bits)


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("cycle0", "n_cycles", "n_onus", "n_draws"),
)
def _sample_xla(keys, thresholds, inv_burst, packet_bits, *, cycle0,
                n_cycles, n_onus, n_draws):
    return _ref.sample_arrival_bits_ref(
        keys, cycle0, thresholds, inv_burst, packet_bits,
        n_cycles=n_cycles, n_onus=n_onus, n_draws=n_draws,
    )


def sample_arrival_bits(keys, cycle0: int, n_cycles: int, n_onus: int,
                        lam, inv_burst: float, packet_bits: float,
                        backend: Optional[str] = None) -> np.ndarray:
    """Arrival bits ``(B, n_cycles, n_onus)`` float64 numpy.

    ``keys``: uint32 ``(B, 2)`` (or ``(2,)`` for B=1); ``lam``: per-case
    per-cycle burst rate, scalar or ``(B,)``. ``backend``: ``None``
    auto-selects (Pallas on TPU, the sparse numpy path elsewhere);
    ``"numpy"``, ``"xla"``, ``"pallas"`` and ``"pallas_interpret"``
    force a path — all produce the identical stream (tested).
    """
    keys = np.atleast_2d(np.asarray(keys, np.uint32))
    lam_arr = np.ascontiguousarray(np.broadcast_to(
        np.asarray(lam, np.float32), (keys.shape[0],)
    ))
    lam_max = float(lam_arr.max())
    if lam_max <= 0.0:
        return np.zeros((keys.shape[0], n_cycles, n_onus))
    n_draws = _tail_bound(lam_max * _ref.WINDOW)
    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "numpy"
    if backend == "numpy":
        return _sample_numpy(
            keys, cycle0, lam_arr, inv_burst, packet_bits,
            n_cycles, n_onus, n_draws,
        )
    thresholds = _poisson_thresholds(
        np.asarray(lam_arr, np.float64) * _ref.WINDOW, n_draws
    )
    if backend == "xla":
        out = _sample_xla(
            keys, thresholds, inv_burst, packet_bits,
            cycle0=int(cycle0),
            n_cycles=n_cycles, n_onus=n_onus, n_draws=n_draws,
        )
    elif backend in ("pallas", "pallas_interpret"):
        from repro.kernels.traffic.kernel import sample_arrival_bits_tpu

        out = sample_arrival_bits_tpu(
            keys, int(cycle0), thresholds,
            n_cycles=n_cycles, n_onus=n_onus, n_draws=n_draws,
            inv_burst=float(inv_burst), packet_bits=float(packet_bits),
            interpret=(backend == "pallas_interpret"),
        )
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return np.asarray(out, np.float64)
