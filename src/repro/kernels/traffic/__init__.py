"""Counter-based Poisson-burst traffic sampler (threefry-2x32).

Replaces the engine's sequential numpy Poisson/negative-binomial draws:
every (case, onu, cycle) cell of the background arrival process is a
pure function of a 64-bit stream key and the (cycle, onu) counter, so

* the stream is O(1)-seekable — any cycle window can be materialised
  without generating its prefix;
* chunk boundaries cannot change the stream (the per-case numpy RNG
  made arrivals depend on chunk sizes);
* the whole sweep batch samples in one fused XLA/Pallas call instead of
  one ``rng.poisson`` + ``rng.negative_binomial`` pair per case.

Layout follows ``kernels/{rglru,quant,ssd}``: ``kernel.py`` is the
Pallas TPU kernel, ``ref.py`` the pure-jnp oracle (the XLA fallback on
non-TPU backends), ``ops.py`` the public dispatch.
"""
from repro.kernels.traffic.ops import (  # noqa: F401
    make_stream_key,
    sample_arrival_bits,
    threefry2x32_np,
)
from repro.kernels.traffic.ref import threefry2x32_ref  # noqa: F401
