"""Pallas TPU kernel for the counter-based Poisson-burst sampler.

Grid: ``(B, n_window_blocks)`` — each program materialises the cycles
of a block of 64-cycle sampling windows for one case, entirely in VMEM:
threefry-2x32 counters are rebuilt from ``broadcasted_iota`` (the
stream is a pure function of (window, onu), no state crosses tiles),
the ``Poisson(64λ)`` count scan runs per window, and each burst draw is
accumulated output-stationary — burst ``j``'s placement (top 6 bits of
word 0) is compared against every cycle row of its window, its
geometric length (word 1) added where it lands.

Distribution parameters (``inv_burst``, ``packet_bits``, ``n_draws``)
are compile-time constants — a sweep has a handful of distinct values —
while the per-case window rate ``lam_w`` and the seek offset ``win0``
stay runtime inputs so one compilation serves every chunk of every
case.

The kernel intentionally avoids ``pltpu.prng_*``: the hand-rolled
threefry keeps the stream identical to the XLA oracle (``ref.py``) and
the sparse numpy host path, which is what makes results
backend-independent.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.kernels.traffic.ref import (
    _C240,
    _ROTS,
    KEY_WEYL_0,
    KEY_WEYL_1,
    UNIT_SCALE,
    WINDOW,
)

DEFAULT_BLOCK_WINDOWS = 4
_LANE = 128                       # TPU lane tiling for the trailing axis


def _rotl(x, r: int):
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def _threefry2x32(k0, k1, c0, c1):
    ks = (k0, k1, k0 ^ k1 ^ jnp.uint32(_C240))
    x0 = c0 + ks[0]
    x1 = c1 + ks[1]
    for block in range(5):
        for r in _ROTS[block % 2]:
            x0 = x0 + x1
            x1 = _rotl(x1, r)
            x1 = x1 ^ x0
        x0 = x0 + ks[(block + 1) % 3]
        x1 = x1 + ks[(block + 2) % 3] + jnp.uint32(block + 1)
    return x0, x1


def _traffic_kernel(keys_ref, thr_ref, win0_ref, out_ref, *,
                    block_windows: int, n_onus_pad: int, n_draws: int,
                    inv_burst: float, packet_bits: float):
    i = pl.program_id(1)
    k0 = keys_ref[0, 0]
    k1 = keys_ref[0, 1]
    wshape = (block_windows, n_onus_pad)
    c0 = (win0_ref[0] + jnp.uint32(i * block_windows)
          + lax.broadcasted_iota(jnp.uint32, wshape, 0))
    c1 = lax.broadcasted_iota(jnp.uint32, wshape, 1)

    def words(d):
        du = jnp.uint32(d)
        kd0 = k0 + du * jnp.uint32(KEY_WEYL_0)
        kd1 = k1 ^ (du * jnp.uint32(KEY_WEYL_1))
        return _threefry2x32(kd0, kd1, c0, c1)

    # window burst count: integer inverse CDF over the host-built
    # threshold table, k = #{ j : bits24 > T_j }
    w0, _ = words(0)
    b24 = (w0 >> jnp.uint32(8)).astype(jnp.int32)

    def pois_body(j, count):
        return count + (b24 > thr_ref[0, j]).astype(jnp.int32)

    count = lax.fori_loop(
        0, n_draws, pois_body, jnp.zeros(wshape, jnp.int32)
    )

    inv_log_q = jnp.float32(1.0) / jnp.log1p(jnp.float32(-inv_burst))
    n_cyc = block_windows * WINDOW
    cyc_in_win = lax.broadcasted_iota(
        jnp.int32, (n_cyc, n_onus_pad), 0
    ) % WINDOW

    def expand(x):
        """(windows, onus) -> (windows*64 cycles, onus)."""
        return jnp.broadcast_to(
            x[:, None, :], (block_windows, WINDOW, n_onus_pad)
        ).reshape(n_cyc, n_onus_pad)

    count_c = expand(count)

    def burst_body(j, packets):
        x0, x1 = words(j)
        place = (x0 >> jnp.uint32(32 - 6)).astype(jnp.int32)
        u = (x1 >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(
            UNIT_SCALE
        )
        glen = jnp.float32(1.0) + jnp.floor(jnp.log1p(-u) * inv_log_q)
        hit = (expand(place) == cyc_in_win) & (j <= count_c)
        return packets + jnp.where(hit, expand(glen), jnp.float32(0.0))

    packets = lax.fori_loop(
        1, n_draws + 1, burst_body,
        jnp.zeros((n_cyc, n_onus_pad), jnp.float32),
    )
    out_ref[0, :, :] = packets * jnp.float32(packet_bits)


def sample_arrival_bits_tpu(keys, cycle0: int, thresholds, *,
                            n_cycles: int, n_onus: int, n_draws: int,
                            inv_burst: float, packet_bits: float,
                            block_windows: int = DEFAULT_BLOCK_WINDOWS,
                            interpret: bool = False):
    """Arrival bits ``(B, n_cycles, n_onus)`` float32 via the TPU kernel.

    ``keys`` uint32 ``(B, 2)``; ``thresholds`` int32 ``(B, n_draws)``
    from ``ref.poisson_thresholds``; ``cycle0`` the absolute cycle of
    the first row. Only the intra-window offset (``cycle0 % 64``, at
    most 64 alignment classes) is compile-time; the window base stays a
    runtime input so one compilation serves every chunk of a stream.
    """
    win0 = cycle0 >> 6
    lo = cycle0 - (win0 << 6)
    return _sample_tpu_jit(
        keys, jnp.uint32(win0), thresholds, lo=lo, n_cycles=n_cycles,
        n_onus=n_onus, n_draws=n_draws, inv_burst=inv_burst,
        packet_bits=packet_bits, block_windows=block_windows,
        interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=("lo", "n_cycles", "n_onus", "n_draws",
                     "inv_burst", "packet_bits", "block_windows",
                     "interpret"),
)
def _sample_tpu_jit(keys, win0, thresholds, *, lo: int, n_cycles: int,
                    n_onus: int, n_draws: int, inv_burst: float,
                    packet_bits: float, block_windows: int,
                    interpret: bool):
    B = keys.shape[0]
    n_win = ((lo + n_cycles - 1) >> 6) + 1
    bw = min(block_windows, n_win)
    n_win_pad = math.ceil(n_win / bw) * bw
    n_onu_pad = math.ceil(n_onus / _LANE) * _LANE
    grid = (B, n_win_pad // bw)
    win0_arr = jnp.reshape(jnp.asarray(win0, jnp.uint32), (1,))
    out = pl.pallas_call(
        functools.partial(
            _traffic_kernel,
            block_windows=bw, n_onus_pad=n_onu_pad, n_draws=n_draws,
            inv_burst=inv_burst, packet_bits=packet_bits,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 2), lambda b, i: (b, 0)),
            pl.BlockSpec((1, n_draws), lambda b, i: (b, 0)),
            pl.BlockSpec((1,), lambda b, i: (0,)),
        ],
        out_specs=pl.BlockSpec(
            (1, bw * WINDOW, n_onu_pad), lambda b, i: (b, i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(
            (B, n_win_pad * WINDOW, n_onu_pad), jnp.float32
        ),
        interpret=interpret,
    )(jnp.asarray(keys, jnp.uint32),
      jnp.asarray(thresholds, jnp.int32), win0_arr)
    return out[:, lo:lo + n_cycles, :n_onus]
