"""Pure-jnp oracle for the counter-based Poisson-burst sampler.

The sampler is a keyed pure function ``(key, onu, cycle) -> bits``,
organised around fixed 64-cycle *windows* (Poisson-process thinning:
``Poisson(64λ)`` bursts per window placed conditionally-uniformly over
its 64 cycles is exactly iid ``Poisson(λ)`` bursts per cycle — the same
law as per-cycle draws, at 1/64th the dense randomness):

* draw 0 of a ``(window, onu)`` counter drives the window's burst count
  via bounded inverse-CDF summation over ``Poisson(64λ)``;
* draw ``j ≥ 1`` yields burst ``j``: output word 0 places it on a cycle
  (top 6 bits — exactly uniform over 64), word 1 draws its
  geometric(1/burst) packet length via the exact inverse CDF. The
  per-cycle packet total is ``Σ_bursts length·[placed here]`` — the
  ``k + NB(k, 1/burst)`` law of the numpy draws it replaces, without
  sequential state.

The draw index is folded into the threefry *key* (Weyl increments), the
``(window, onu)`` pair is the *counter*, so any cycle range is
O(1)-seekable and chunk boundaries can never change the stream. Burst
counts go through host-built integer thresholds
(:func:`poisson_thresholds`) and burst lengths through an XLA-evaluated
float32 LUT (:func:`geometric_lut`) with a fixed operation order, so
the Pallas kernel and the sparse numpy host path (``ops.py``) reproduce
the stream bit-for-bit (tested).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

# Threefry-2x32 constants (Random123 / JAX's PRNG).
_C240 = 0x1BD11BDA
_ROTS = ((13, 15, 26, 6), (17, 29, 16, 24))
# Weyl-style per-draw key derivation constants (golden-ratio / murmur3).
KEY_WEYL_0 = 0x9E3779B9
KEY_WEYL_1 = 0x85EBCA6B
UNIT_SCALE = 1.0 / (1 << 24)      # top-24-bit uniform in [0, 1)
WINDOW = 64                       # cycles per sampling window
_WIN_SHIFT = 6


def _rotl(x, r: int):
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def threefry2x32_ref(k0, k1, c0, c1):
    """Standard 20-round Threefry-2x32 over broadcastable uint32 arrays.

    Returns the two output words; matches
    ``jax.extend.random.threefry_2x32`` bit-for-bit (tested).
    """
    k0 = jnp.asarray(k0, jnp.uint32)
    k1 = jnp.asarray(k1, jnp.uint32)
    ks = (k0, k1, k0 ^ k1 ^ jnp.uint32(_C240))
    x0 = jnp.asarray(c0, jnp.uint32) + ks[0]
    x1 = jnp.asarray(c1, jnp.uint32) + ks[1]
    for block in range(5):
        for r in _ROTS[block % 2]:
            x0 = x0 + x1
            x1 = _rotl(x1, r)
            x1 = x1 ^ x0
        x0 = x0 + ks[(block + 1) % 3]
        x1 = x1 + ks[(block + 2) % 3] + jnp.uint32(block + 1)
    return x0, x1


def draw_key(k0, k1, d):
    """Per-draw derived key: draw ``d`` of a stream is an independent
    threefry instance (Weyl-incremented key words)."""
    d = jnp.asarray(d, jnp.uint32)
    return (k0 + d * jnp.uint32(KEY_WEYL_0),
            k1 ^ (d * jnp.uint32(KEY_WEYL_1)))


def poisson_thresholds(lam_w, n_draws: int):
    """int32 ``(B, n_draws)`` inverse-CDF thresholds for the window
    burst count: ``count = #{ j : bits24 > T_j }`` with
    ``T_j = floor(CDF_Poisson(λ_w)(j) · 2²⁴)``.

    Computed host-side in float64 log space (stable for any λ_w — a
    float32 pmf recurrence underflows to denormal garbage beyond
    λ_w ≈ 90) and shared verbatim by every backend, so burst counts are
    integer-exact and bit-identical everywhere. f64 error (~1e-13) is
    far below the 2⁻²⁴ threshold quantum.
    """
    import numpy as _np

    lam_w = _np.asarray(lam_w, _np.float64).reshape(-1)
    j = _np.arange(n_draws, dtype=_np.float64)
    logfact = _np.concatenate(
        [[0.0], _np.cumsum(_np.log(_np.arange(1.0, n_draws)))]
    )
    with _np.errstate(divide="ignore", invalid="ignore"):
        lpmf = (-lam_w[:, None] + j[None, :] * _np.log(lam_w)[:, None]
                - logfact[None, :])
    lpmf = _np.where(lam_w[:, None] > 0.0, lpmf, -_np.inf)
    lpmf[lam_w <= 0.0, 0] = 0.0    # λ=0: all mass at count 0
    cdf = _np.cumsum(_np.exp(lpmf), axis=1)
    return _np.floor(
        _np.minimum(cdf, 1.0) * float(1 << 24)
    ).astype(_np.int32)


@functools.partial(jax.jit, static_argnames=())
def geometric_lut(inv_burst):
    """int32 ``(2**24,)`` map from 24-bit uniform to a geometric(p)
    burst length, evaluated once in XLA float32 so every backend applies
    the identical (ulp-exact) inverse CDF."""
    inv_burst = jnp.asarray(inv_burst, jnp.float32)
    u = jnp.arange(1 << 24, dtype=jnp.uint32).astype(jnp.float32) * (
        jnp.float32(UNIT_SCALE)
    )
    inv_log_q = jnp.float32(1.0) / jnp.log1p(-inv_burst)
    return (jnp.float32(1.0)
            + jnp.floor(jnp.log1p(-u) * inv_log_q)).astype(jnp.int32)


def sample_arrival_bits_ref(keys, cycle0, thresholds, inv_burst,
                            packet_bits, *, n_cycles: int, n_onus: int,
                            n_draws: int):
    """Arrival bits ``(B, n_cycles, n_onus)`` float32.

    ``keys``: uint32 ``(B, 2)`` stream keys; ``thresholds``: int32
    ``(B, n_draws)`` from :func:`poisson_thresholds` (per-window burst
    count inverse CDF); ``inv_burst``: scalar geometric parameter
    (1/mean burst packets).
    """
    keys = jnp.asarray(keys, jnp.uint32)
    thresholds = jnp.asarray(thresholds, jnp.int32)
    inv_burst = jnp.asarray(inv_burst, jnp.float32)
    cycle0 = int(cycle0)
    win0 = cycle0 >> _WIN_SHIFT
    n_win = ((cycle0 + n_cycles - 1) >> _WIN_SHIFT) - win0 + 1
    k0 = keys[:, 0][:, None, None]
    k1 = keys[:, 1][:, None, None]
    c0 = (jnp.uint32(win0)
          + jnp.arange(n_win, dtype=jnp.uint32))[None, :, None]
    c1 = jnp.arange(n_onus, dtype=jnp.uint32)[None, None, :]

    # window burst count: integer inverse CDF, k = #{ j : bits > T_j }
    kd0, kd1 = draw_key(k0, k1, 0)
    w0, _ = threefry2x32_ref(kd0, kd1, c0, c1)
    b24 = (w0 >> jnp.uint32(8)).astype(jnp.int32)
    shape = b24.shape

    def pois_body(j, count):
        t_j = lax.dynamic_index_in_dim(
            thresholds, j, axis=1, keepdims=False
        )[:, None, None]
        return count + (b24 > t_j).astype(jnp.int32)

    count = lax.fori_loop(
        0, n_draws, pois_body, jnp.zeros(shape, jnp.int32)
    )

    # bursts: word 0 places (top 6 bits — exact uniform over the
    # window), word 1 draws the geometric length; accumulate densely
    inv_log_q = jnp.float32(1.0) / jnp.log1p(-inv_burst)
    slot = jnp.arange(WINDOW, dtype=jnp.int32)[None, None, :, None]

    def burst_body(j, packets):
        bd0, bd1 = draw_key(k0, k1, j)
        x0, x1 = threefry2x32_ref(bd0, bd1, c0, c1)
        place = (x0 >> jnp.uint32(32 - _WIN_SHIFT)).astype(jnp.int32)
        u = (x1 >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(
            UNIT_SCALE
        )
        glen = jnp.float32(1.0) + jnp.floor(jnp.log1p(-u) * inv_log_q)
        live = (j <= count)
        hit = (place[:, :, None, :] == slot) & live[:, :, None, :]
        return packets + jnp.where(hit, glen[:, :, None, :],
                                   jnp.float32(0.0))

    packets = lax.fori_loop(
        1, n_draws + 1, burst_body,
        jnp.zeros((shape[0], n_win, WINDOW, n_onus), jnp.float32),
    )
    packets = packets.reshape(shape[0], n_win * WINDOW, n_onus)
    lo = cycle0 - (win0 << _WIN_SHIFT)
    return (packets[:, lo:lo + n_cycles, :]
            * jnp.asarray(packet_bits, jnp.float32))
