"""Federated partitioning: writers -> EC nodes/clients with heterogeneity."""
from __future__ import annotations

from typing import Callable, List

import numpy as np

from repro.data.synthetic import femnist_like
from repro.fl.client import Client, LocalTrainConfig


def build_federated_cnn_clients(
    n_clients: int,
    samples_per_client: int,
    loss_fn: Callable,
    train_cfg: LocalTrainConfig,
    seed: int = 0,
    t_ud_range=(1.0, 5.0),
) -> tuple:
    """LEAF-style clients with paper-faithful compute heterogeneity.

    T_i^UD ~ Uniform[1, 5] s (paper Fig 2b) — fixed per client across rounds
    (it is a property of the EC node's hardware + data volume).
    Returns (clients, test_set).
    """
    writers, test = femnist_like(n_clients, samples_per_client, seed=seed)
    rng = np.random.default_rng(seed + 1)
    t_uds = rng.uniform(*t_ud_range, size=n_clients)
    clients = [
        Client(
            client_id=i,
            data=writers[i],
            loss_fn=loss_fn,
            cfg=train_cfg,
            t_ud_s=float(t_uds[i]),
        )
        for i in range(n_clients)
    ]
    return clients, test


def partition_tokens(
    tokens: np.ndarray, n_clients: int, seq_len: int
) -> List[np.ndarray]:
    """Contiguous shards of a token stream, one per client (non-IID order)."""
    usable = (len(tokens) // (n_clients * (seq_len + 1))) * (seq_len + 1)
    shards = []
    for i in range(n_clients):
        start = i * usable
        shard = tokens[start : start + usable]
        shards.append(shard.reshape(-1, seq_len + 1))
    return shards
