"""Sharding-aware input pipeline for distributed LM training.

Host-side batching of a token stream into (tokens, labels) with deterministic
order, plus ``shard_batch`` that places the global batch onto the mesh with
the activation sharding (batch over ("pod", "data")). Per-pod data disjointness
(the FL property: each pod trains on its own shard) is enforced by slicing the
stream by pod index before batching.
"""
from __future__ import annotations

from typing import Dict, Iterator

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class TokenBatcher:
    def __init__(
        self,
        tokens: np.ndarray,
        global_batch: int,
        seq_len: int,
        seed: int = 0,
        pod_index: int = 0,
        n_pods: int = 1,
    ):
        # FL semantics: each pod sees a disjoint contiguous shard
        shard_len = len(tokens) // max(n_pods, 1)
        tokens = tokens[pod_index * shard_len : (pod_index + 1) * shard_len]
        self.block = seq_len + 1
        n_seqs = len(tokens) // self.block
        self.data = tokens[: n_seqs * self.block].reshape(n_seqs, self.block)
        self.global_batch = global_batch
        self.rng = np.random.default_rng(seed)
        self.epoch = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            order = self.rng.permutation(len(self.data))
            for start in range(0, len(order) - self.global_batch + 1,
                               self.global_batch):
                rows = self.data[order[start : start + self.global_batch]]
                yield {
                    "tokens": rows[:, :-1].astype(np.int32),
                    "labels": rows[:, 1:].astype(np.int32),
                }
            self.epoch += 1


def shard_batch(batch: Dict[str, np.ndarray], mesh: Mesh) -> Dict:
    """Place a host batch onto the mesh, batch dim over ('pod','data')."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    sharding = NamedSharding(mesh, P(axes))
    return {k: jax.device_put(v, sharding) for k, v in batch.items()}
