"""Data substrate: synthetic tasks, federated partitioning, input pipeline."""
from repro.data.federated import (  # noqa: F401
    build_federated_cnn_clients,
    partition_tokens,
)
from repro.data.pipeline import TokenBatcher, shard_batch  # noqa: F401
from repro.data.synthetic import femnist_like, lm_tokens  # noqa: F401
