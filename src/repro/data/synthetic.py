"""Synthetic datasets (the container is offline — no FEMNIST download).

* ``femnist_like``: a 62-class, 28x28 image task with *writer-style* non-IID
  structure: each synthetic "writer" has a private affine style (stroke
  weight, slant, offset) applied to class prototypes — mirroring LEAF
  FEMNIST's per-writer partitioning (arXiv:1812.01097). Learnable but not
  trivial; accuracy saturates with rounds like Fig 2a.

* ``lm_tokens``: a Zipf-distributed Markov token stream for LM smoke tests
  and the ~100M-param example run.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

N_CLASSES = 62
IMG = 28


def _class_prototypes(rng: np.random.Generator) -> np.ndarray:
    """Smooth random prototypes per class: (62, 28, 28)."""
    protos = rng.normal(0.0, 1.0, size=(N_CLASSES, IMG, IMG)).astype(np.float32)
    # low-pass: average pooling smooths into blob-like glyphs
    k = 5
    padded = np.pad(protos, ((0, 0), (k // 2, k // 2), (k // 2, k // 2)),
                    mode="wrap")
    out = np.zeros_like(protos)
    for dy in range(k):
        for dx in range(k):
            out += padded[:, dy : dy + IMG, dx : dx + IMG]
    out /= k * k
    out = (out - out.mean(axis=(1, 2), keepdims=True)) / (
        out.std(axis=(1, 2), keepdims=True) + 1e-6
    )
    return out


def femnist_like(
    n_writers: int,
    samples_per_writer: int,
    seed: int = 0,
    label_skew: float = 0.5,
) -> Tuple[list, Dict[str, np.ndarray]]:
    """Returns (per_writer_datasets, test_set).

    Each writer draws classes from a writer-specific Dirichlet distribution
    (``label_skew`` < 1 -> strong non-IID) and renders prototypes with the
    writer's private style + noise.
    """
    rng = np.random.default_rng(seed)
    protos = _class_prototypes(rng)
    writers = []
    for w in range(n_writers):
        wrng = np.random.default_rng(seed * 100_003 + w)
        class_probs = wrng.dirichlet(np.full(N_CLASSES, label_skew))
        gain = wrng.uniform(0.6, 1.4)
        bias = wrng.uniform(-0.3, 0.3)
        shift = wrng.integers(-2, 3, size=2)
        labels = wrng.choice(N_CLASSES, size=samples_per_writer, p=class_probs)
        imgs = protos[labels] * gain + bias
        imgs = np.roll(imgs, shift=tuple(shift), axis=(1, 2))
        imgs = imgs + wrng.normal(0, 0.35, size=imgs.shape)
        writers.append(
            {
                "images": imgs[..., None].astype(np.float32),
                "labels": labels.astype(np.int32),
            }
        )
    # test set spans ALL writers' styles (uniform labels): a client fraction
    # that never sees some writers' styles plateaus below full involvement —
    # the paper's Fig 2a saturation effect.
    trng = np.random.default_rng(seed + 777)
    per_writer = max(4, (4 * samples_per_writer) // max(n_writers, 1))
    t_imgs, t_labels = [], []
    for w in range(n_writers):
        wrng = np.random.default_rng(seed * 100_003 + w)
        wrng.dirichlet(np.full(N_CLASSES, label_skew))  # keep stream aligned
        gain = wrng.uniform(0.6, 1.4)
        bias = wrng.uniform(-0.3, 0.3)
        shift = wrng.integers(-2, 3, size=2)
        labels = trng.integers(0, N_CLASSES, size=per_writer)
        imgs = protos[labels] * gain + bias
        imgs = np.roll(imgs, shift=tuple(shift), axis=(1, 2))
        imgs = imgs + trng.normal(0, 0.35, size=imgs.shape)
        t_imgs.append(imgs)
        t_labels.append(labels)
    order = trng.permutation(n_writers * per_writer)
    test = {
        "images": np.concatenate(t_imgs)[order][..., None].astype(np.float32),
        "labels": np.concatenate(t_labels)[order].astype(np.int32),
    }
    return writers, test


def lm_tokens(
    n_tokens: int,
    vocab_size: int,
    seed: int = 0,
    order: int = 1,
) -> np.ndarray:
    """Zipf-Markov token stream: learnable bigram structure."""
    rng = np.random.default_rng(seed)
    # sparse row-stochastic transition with Zipf-ish mass
    fanout = min(32, vocab_size)
    nexts = rng.integers(0, vocab_size, size=(vocab_size, fanout))
    probs = 1.0 / np.arange(1, fanout + 1)
    probs /= probs.sum()
    out = np.empty(n_tokens, dtype=np.int32)
    tok = int(rng.integers(vocab_size))
    choices = rng.choice(fanout, size=n_tokens, p=probs)
    jumps = rng.random(n_tokens) < 0.05
    randoms = rng.integers(0, vocab_size, size=n_tokens)
    for i in range(n_tokens):
        tok = int(randoms[i]) if jumps[i] else int(nexts[tok, choices[i]])
        out[i] = tok
    return out
