"""The CPS (centralized parameter server): round orchestration + aggregation.

Fault tolerance: clients can fail mid-round (``failure_prob``); the server
aggregates whatever arrived by the round deadline, weighted by data size —
the deadline-partial-aggregation strategy. Membership changes flow through
``repro.core.membership.SliceManager`` so the BS slice re-triggers exactly
per the paper.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.core.slicing import ClientProfile
from repro.fl.aggregation import fedavg, fedbuff_merge
from repro.fl.client import Client
from repro.fl.compression import CompressorConfig, compress_delta
from repro.fl.selection import SelectionConfig, select_clients


@dataclass
class RoundLog:
    round_index: int
    n_selected: int
    n_arrived: int
    mean_loss: float
    update_bits: float
    eval_metric: Optional[float] = None
    sync_time_s: Optional[float] = None
    # quorum aggregation: None = no quorum configured; False = the round
    # degraded to the previous global model (too few arrivals)
    quorum_met: Optional[bool] = None


@dataclass
class PendingUpdate:
    """A trained-and-compressed client update awaiting arrival at the
    CPS — the co-simulation holds these while the upload is in flight
    (deferred/async rounds) and applies them staleness-weighted when
    the network says they landed."""

    client_id: int
    delta: object                   # decoded wire delta vs base params
    weight: float                   # client data size
    loss: float                     # local training loss
    bits: float                     # wire bits of the full update


@dataclass
class CPSServer:
    global_params: object
    clients: List[Client]
    selection: SelectionConfig = field(default_factory=SelectionConfig)
    compression: CompressorConfig = field(
        default_factory=lambda: CompressorConfig(scheme="none")
    )
    failure_prob: float = 0.0
    seed: int = 0
    history: List[RoundLog] = field(default_factory=list)
    _error_states: Dict[int, object] = field(default_factory=dict)
    _round: int = 0

    def __post_init__(self):
        self.rng = np.random.default_rng(self.seed)

    def profiles(self, model_bits: float) -> List[ClientProfile]:
        return [
            ClientProfile(
                client_id=c.client_id,
                t_ud=c.t_ud_s,
                t_dl=0.0,
                m_ud_bits=model_bits,
                distance_m=c.distance_m,
            )
            for c in self.clients
        ]

    def run_round(
        self,
        eval_fn: Optional[Callable] = None,
    ) -> RoundLog:
        """One synchronous round: select -> local train -> compress -> FedAvg."""
        self._round += 1
        selected = select_clients(
            [self._as_profile(c) for c in self.clients],
            self.selection,
            self.rng,
        )
        by_id = {c.client_id: c for c in self.clients}
        chosen = [by_id[p.client_id] for p in selected]

        arrived_params, weights, losses, bits_total = [], [], [], 0
        for client in chosen:
            if self.failure_prob and self.rng.random() < self.failure_prob:
                continue  # client failed / missed the deadline: skip its update
            local_params, loss = client.train(self.global_params, self.rng)
            delta = jax.tree.map(
                lambda a, b: a - b, local_params, self.global_params
            )
            decoded, err, bits = compress_delta(
                delta, self.compression,
                self._error_states.get(client.client_id),
            )
            if err is not None:
                self._error_states[client.client_id] = err
            arrived = jax.tree.map(
                lambda g, d: g + d, self.global_params, decoded
            )
            arrived_params.append(arrived)
            weights.append(client.n_samples)
            losses.append(loss)
            bits_total += bits

        if arrived_params:  # partial aggregation if some clients failed
            self.global_params = fedavg(arrived_params, weights)

        log = RoundLog(
            round_index=self._round,
            n_selected=len(chosen),
            n_arrived=len(arrived_params),
            mean_loss=float(np.mean(losses)) if losses else float("nan"),
            update_bits=float(bits_total),
            eval_metric=(
                float(eval_fn(self.global_params)) if eval_fn else None
            ),
        )
        self.history.append(log)
        return log

    def train_client_update(self, client: Client,
                            base_params) -> Optional[PendingUpdate]:
        """Local training + wire compression against ``base_params``.

        The returned ``PendingUpdate.delta`` is the *decoded* delta the
        CPS reconstructs (same error-feedback pipeline as the sync
        round); it stays pending until the network simulation delivers
        it — possibly rounds later, with staleness. ``failure_prob``
        rolls exactly as in :meth:`run_round`: a failed client returns
        ``None`` (its update is lost mid-round).
        """
        if self.failure_prob and self.rng.random() < self.failure_prob:
            return None
        local_params, loss = client.train(base_params, self.rng)
        delta = jax.tree.map(lambda a, b: a - b, local_params, base_params)
        decoded, err, bits = compress_delta(
            delta, self.compression,
            self._error_states.get(client.client_id),
        )
        if err is not None:
            self._error_states[client.client_id] = err
        return PendingUpdate(
            client_id=client.client_id, delta=decoded,
            weight=float(client.n_samples), loss=float(loss),
            bits=float(bits),
        )

    def apply_updates(
        self,
        items: Sequence,
        eval_fn: Optional[Callable] = None,
        server_lr: float = 1.0,
        n_expected: Optional[int] = None,
        quorum_frac: Optional[float] = None,
    ) -> RoundLog:
        """One aggregation event: merge the arrived updates.

        ``items``: ``(update, staleness, frac)`` triples — a
        :class:`PendingUpdate`, its staleness in rounds, and the served
        fraction (1.0 for complete uploads; the network layer's
        ``deadline_policy="partial"`` delivers fractions). The global
        model moves by the staleness/fraction-discounted weighted delta
        (``fedbuff_merge`` — data weights mix relatively, the discounts
        apply absolutely); an empty event only advances the round
        counter (the deadline fired with nothing aggregated).

        ``quorum_frac`` (with ``n_expected`` pending uploads) gates the
        merge: fewer than ``quorum_threshold(n_expected, quorum_frac)``
        arrivals and the round degrades — the global model stands
        unchanged and the log records ``quorum_met=False``.
        """
        from repro.fl.aggregation import quorum_threshold

        items = list(items)
        self._round += 1
        quorum_met: Optional[bool] = None
        if quorum_frac is not None:
            if n_expected is None:
                raise ValueError("quorum_frac needs n_expected")
            quorum_met = (
                len(items) >= quorum_threshold(n_expected, quorum_frac)
            )
        if items and quorum_met is not False:
            self.global_params = fedbuff_merge(
                self.global_params,
                [u.delta for u, _, _ in items],
                [u.weight for u, _, _ in items],
                [s for _, s, _ in items],
                server_lr=server_lr,
                fracs=[f for _, _, f in items],
            )
        losses = [u.loss for u, _, _ in items]
        log = RoundLog(
            round_index=self._round,
            n_selected=len(items),
            n_arrived=len(items),
            mean_loss=float(np.mean(losses)) if losses else float("nan"),
            update_bits=float(sum(u.bits * f for u, _, f in items)),
            eval_metric=(
                float(eval_fn(self.global_params)) if eval_fn else None
            ),
            quorum_met=quorum_met,
        )
        self.history.append(log)
        return log

    def _as_profile(self, c: Client) -> ClientProfile:
        return ClientProfile(
            client_id=c.client_id,
            t_ud=c.t_ud_s,
            t_dl=0.0,
            m_ud_bits=0.0,
            distance_m=c.distance_m,
        )
