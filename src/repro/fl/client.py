"""Client-side local training executor (generic over model via loss_fn)."""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class LocalTrainConfig:
    lr: float = 0.05
    batch_size: int = 32
    local_epochs: int = 1
    momentum: float = 0.0


@partial(jax.jit, static_argnames=("loss_fn", "lr", "momentum"))
def _sgd_step(params, velocity, batch, loss_fn, lr, momentum):
    loss, grads = jax.value_and_grad(loss_fn)(params, batch)
    if momentum:
        velocity = jax.tree.map(
            lambda v, g: momentum * v + g, velocity, grads
        )
        grads = velocity
    params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
    return params, velocity, loss


class Client:
    """One FL client: local data + local SGD. Failure injection for FT tests."""

    def __init__(
        self,
        client_id: int,
        data: Dict[str, np.ndarray],
        loss_fn: Callable,
        cfg: LocalTrainConfig,
        t_ud_s: float = 1.0,
        distance_m: float = 20_000.0,
    ):
        self.client_id = client_id
        self.data = data
        self.loss_fn = loss_fn
        self.cfg = cfg
        self.t_ud_s = t_ud_s            # heterogeneous compute time (paper)
        self.distance_m = distance_m

    @property
    def n_samples(self) -> int:
        return len(next(iter(self.data.values())))

    def train(self, global_params, rng: np.random.Generator):
        """Run local epochs of minibatch SGD from the global model."""
        params = jax.tree.map(jnp.copy, global_params)
        velocity = jax.tree.map(lambda l: jnp.zeros_like(l), params)
        n = self.n_samples
        bs = min(self.cfg.batch_size, n)
        losses = []
        for _ in range(self.cfg.local_epochs):
            order = rng.permutation(n)
            for start in range(0, n - bs + 1, bs):
                idx = order[start : start + bs]
                batch = {k: jnp.asarray(v[idx]) for k, v in self.data.items()}
                params, velocity, loss = _sgd_step(
                    params, velocity, batch, self.loss_fn,
                    self.cfg.lr, self.cfg.momentum,
                )
                losses.append(float(loss))
        return params, float(np.mean(losses)) if losses else 0.0
