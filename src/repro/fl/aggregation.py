"""Model aggregation strategies at the CPS.

``fedavg`` is the paper's choice (McMahan et al., AISTATS 2017): the global
model is the data-size-weighted average of client models. ``fedadam`` treats
the averaged client delta as a pseudo-gradient for a server Adam step
(Reddi et al., adaptive federated optimisation) — useful when client LRs are
small. ``FedBuffAggregator`` is the asynchronous buffer variant used by the
async mode of the co-simulation.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp


def fedavg(client_params: Sequence, weights: Sequence[float]):
    """Weighted average of client parameter pytrees (FedAvg)."""
    if len(client_params) == 0:
        raise ValueError("fedavg needs at least one client update")
    w = jnp.asarray(weights, dtype=jnp.float32)
    w = w / jnp.sum(w)

    def avg(*leaves):
        stacked = jnp.stack([l.astype(jnp.float32) for l in leaves])
        out = jnp.tensordot(w, stacked, axes=1)
        return out.astype(leaves[0].dtype)

    return jax.tree.map(avg, *client_params)


def fedavg_delta(global_params, client_params: Sequence,
                 weights: Sequence[float]):
    """Weighted-average *delta* (client - global); pseudo-gradient form."""
    avg = fedavg(client_params, weights)
    return jax.tree.map(lambda a, g: a - g, avg, global_params)


@dataclass
class ServerAdamState:
    mu: object
    nu: object
    count: int = 0


def fedadam_init(global_params) -> ServerAdamState:
    zeros = jax.tree.map(lambda l: jnp.zeros_like(l, jnp.float32), global_params)
    return ServerAdamState(mu=zeros, nu=jax.tree.map(jnp.copy, zeros))


def fedadam_step(
    global_params,
    state: ServerAdamState,
    client_params: Sequence,
    weights: Sequence[float],
    lr: float = 1e-2,
    b1: float = 0.9,
    b2: float = 0.99,
    eps: float = 1e-3,
):
    """Server-side Adam on the averaged client delta."""
    delta = fedavg_delta(global_params, client_params, weights)
    count = state.count + 1
    mu = jax.tree.map(
        lambda m, d: b1 * m + (1 - b1) * d.astype(jnp.float32), state.mu, delta
    )
    nu = jax.tree.map(
        lambda v, d: b2 * v + (1 - b2) * jnp.square(d.astype(jnp.float32)),
        state.nu,
        delta,
    )
    mu_hat = jax.tree.map(lambda m: m / (1 - b1 ** count), mu)
    nu_hat = jax.tree.map(lambda v: v / (1 - b2 ** count), nu)
    new_params = jax.tree.map(
        lambda p, m, v: (
            p.astype(jnp.float32) + lr * m / (jnp.sqrt(v) + eps)
        ).astype(p.dtype),
        global_params,
        mu_hat,
        nu_hat,
    )
    return new_params, ServerAdamState(mu=mu, nu=nu, count=count)


def staleness_scale(staleness: float, power: float = 0.5) -> float:
    """``(1 + τ)^-p`` — the FedBuff staleness discount (p=0.5 default).

    Host-side mirror of ``repro.dist.fedops.staleness_discount``.
    """
    return float((1.0 + float(staleness)) ** (-power))


def fedbuff_merge(global_params, deltas: Sequence,
                  weights: Sequence[float],
                  staleness: Optional[Sequence[float]] = None,
                  server_lr: float = 1.0,
                  staleness_power: float = 0.5,
                  fracs: Optional[Sequence[float]] = None):
    """Staleness-weighted buffered delta merge (FedBuff).

    ``G' = G + server_lr · Σ_i (w_i/Σ_j w_j) · s_i · f_i · Δ_i`` with
    ``s_i = (1+τ_i)^-p`` and ``f_i`` the served fraction — the
    host-side mirror of ``repro.dist.fedops.fedbuff_pods`` (same
    fp32-accumulate, cast-back numerics). Data weights mix co-arrivals
    *relatively* (all fresh and complete ⇒ the FedAvg delta step);
    staleness and fraction discount *absolutely*, so a lone stale or
    partial arrival moves the global by ``s·f·Δ``, never the full
    delta. An empty buffer is a no-op.
    """
    deltas = list(deltas)
    if not deltas:
        return global_params
    taus = [0.0] * len(deltas) if staleness is None else list(staleness)
    fs = [1.0] * len(deltas) if fracs is None else list(fracs)
    total_w = float(sum(weights))
    if total_w <= 0.0:
        return global_params
    coeffs = [
        w / total_w * staleness_scale(t, staleness_power) * f
        for w, t, f in zip(weights, taus, fs)
    ]

    def step(p, *ds):
        upd = sum(
            c * d.astype(jnp.float32) for c, d in zip(coeffs, ds)
        )
        return (p.astype(jnp.float32) + server_lr * upd).astype(p.dtype)

    return jax.tree.map(step, global_params, *deltas)


def quorum_threshold(n_expected: int, quorum_frac: float) -> int:
    """Minimum arrived-update count for a round to commit:
    ``max(1, ceil(quorum_frac * n_expected))``."""
    import math

    if n_expected < 0:
        raise ValueError("n_expected must be >= 0")
    if not 0.0 < quorum_frac <= 1.0:
        raise ValueError(f"quorum_frac must be in (0, 1]; got {quorum_frac}")
    return max(1, math.ceil(quorum_frac * n_expected))


def quorum_commit(global_params, deltas: Sequence,
                  weights: Sequence[float], *,
                  n_expected: int, quorum_frac: float,
                  staleness: Optional[Sequence[float]] = None,
                  fracs: Optional[Sequence[float]] = None,
                  server_lr: float = 1.0,
                  staleness_power: float = 0.5):
    """Quorum-gated merge: ``(new_global, quorum_met)``.

    With at least ``quorum_threshold(n_expected, quorum_frac)`` arrived
    updates the round commits through ``fedbuff_merge``; below the
    quorum the round *degrades* — the previous global model is returned
    unchanged (``quorum_met=False``) and the arrived updates are
    discarded, mirroring the timeline's ``quorum_met=False`` rounds
    (which only occur after ``quorum_max_extends`` deadline doublings).
    Host-side mirror of the in-graph gate in
    ``repro.dist.fedops.fedbuff_pods``.
    """
    deltas = list(deltas)
    if len(deltas) < quorum_threshold(n_expected, quorum_frac):
        return global_params, False
    return fedbuff_merge(
        global_params, deltas, weights, staleness=staleness,
        server_lr=server_lr, staleness_power=staleness_power,
        fracs=fracs,
    ), True


@dataclass
class FedBuffAggregator:
    """Asynchronous aggregation (FedBuff): apply once K updates buffered.

    Staleness is discounted with ``staleness_scale`` (1/sqrt(1+τ) at
    the default power) — a standard choice.
    """

    buffer_size: int = 8
    server_lr: float = 1.0
    staleness_power: float = 0.5
    _buffer: List = field(default_factory=list)

    def add(self, delta, weight: float, staleness: int = 0) -> bool:
        scale = weight * staleness_scale(staleness, self.staleness_power)
        self._buffer.append((delta, float(scale)))
        return len(self._buffer) >= self.buffer_size

    def flush(self, global_params):
        if not self._buffer:
            return global_params
        deltas = [d for d, _ in self._buffer]
        weights = [w for _, w in self._buffer]
        self._buffer.clear()
        return fedbuff_merge(
            global_params, deltas, weights, server_lr=self.server_lr
        )

    @property
    def pending(self) -> int:
        return len(self._buffer)
