"""Federated-learning substrate: server, clients, aggregation, co-simulation."""
from repro.fl.aggregation import (  # noqa: F401
    FedBuffAggregator,
    fedadam_init,
    fedadam_step,
    fedavg,
    fedavg_delta,
    fedbuff_merge,
    staleness_scale,
)
from repro.fl.client import Client, LocalTrainConfig  # noqa: F401
from repro.fl.compression import (  # noqa: F401
    CompressorConfig,
    compress_delta,
    compressed_update_bits,
    dequantize_int8,
    quantize_int8,
    topk_sparsify,
)
from repro.fl.selection import SelectionConfig, select_clients  # noqa: F401
from repro.fl.server import (  # noqa: F401
    CPSServer,
    PendingUpdate,
    RoundLog,
)
from repro.fl.simulation import (  # noqa: F401
    CoSimConfig,
    CoSimResult,
    FLNetworkCoSim,
)
