"""FL × PON co-simulation: real JAX training + network timing per round.

Couples the ``CPSServer`` (actual federated SGD on the LEAF-style CNN) with
the PON round simulator. Learning dynamics (accuracy vs round — Fig 2a) come
from real training; wall-clock training time (Fig 2b/3, the 36% saving)
comes from rounds × simulated synchronisation time.

Network timing backends (``FLNetworkCoSim.run``):

* ``"timeline"`` (default) — the whole training timeline advances as ONE
  stacked simulation on ``repro.net.timeline``: per-round client sets
  become membership masks over the union workload, per-round (possibly
  compression-dependent) upload sizes become the schedule's ``m_ud_bits``,
  and every round × timing-seed runs concurrently on the engine's batch
  axis with counter-keyed arrival streams.
* ``"per_round"`` — the PR 2 loop: one engine call per round, with the
  paper's observation that a fixed client set reuses its timing (the BS
  slice is recomputed only on membership change) expressed as a cache.

Deadline/async co-simulation (``mode="sync"`` with ``deadline_s``, or
``mode="async"``): timing and learning *couple* — who arrives in each
aggregation event, how stale, and with what served fraction is decided
by the network simulation, so the net timeline runs first and then
drives the training loop update by update. Deferred and async-straggler
updates apply staleness-weighted (``1/sqrt(1+τ)``, FedBuff), dropped
updates never apply, and partial updates apply scaled by the served
fraction — the Fig. 2a-style accuracy-vs-wall-clock comparison across
sync/drop/defer/partial/async under both DBA policies.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace as _dc_replace
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.slicing import ClientProfile
from repro.faults import FaultSchedule, RetryPolicy
from repro.fl.server import CPSServer
from repro.net.api import SweepSpec, simulate
from repro.net.engine import SweepCase
from repro.net.jobs import JobSpec
from repro.net.multi_pon import MultiPonTopology
from repro.net.sim import FLRoundWorkload, PONConfig
from repro.net.timeline import TimelineSchedule


@dataclass
class CoSimConfig:
    policy: str = "bs"              # "bs" | "fcfs"
    total_load: float = 0.8
    model_bits: float = 26.416e6    # global model size (fp32 downlink)
    upload_bits: Optional[float] = None  # per-client M_i^UD; None = model_bits
    pon: PONConfig = field(default_factory=PONConfig)
    timing_seeds: int = 2           # average the net-sim over this many seeds
    # several wavelength/OLT segments sharing a CPS uplink: ``pon``
    # then describes ONE segment and clients spread across
    # n_pons * pon.n_onus ONUs (None = single PON)
    topology: Optional[MultiPonTopology] = None
    # observability hub (repro.obs.Collector) threaded into every
    # network simulation this co-sim drives; None (the default) leaves
    # all outputs bitwise identical to an uninstrumented run
    collector: Optional[object] = None
    # deterministic fault injection (repro.faults): dropout/loss faults
    # and quorum aggregation need the *coupled* deadline/async path
    # (who retries/arrives is an event); outage-only faults also thread
    # into the decoupled timeline timing
    faults: Optional[FaultSchedule] = None
    retry: Optional[RetryPolicy] = None
    quorum_frac: Optional[float] = None
    # multi-tenant contention: competitor jobs (repro.net.jobs.JobSpec,
    # job_id >= 1) sharing the PON/CPS with this FL task, plus the
    # ClientProfiles backing their client ids; the primary task becomes
    # job 0 and every round's capacity is split by ``fairness``
    jobs: Optional[Tuple[JobSpec, ...]] = None
    job_clients: Optional[Tuple[ClientProfile, ...]] = None
    fairness: str = "maxmin"

    @classmethod
    def from_fed_model(cls, model_cfg, compress: str = "int8", **kw):
        """Size the slice from the real sharded update payload.

        Instead of the paper's hard-coded CNN constant, ``model_bits``
        becomes the fp32 wire size of the global model (the server's
        full-precision downlink broadcast) and ``upload_bits`` the size
        of one pod's *compressed* upload
        (``repro.dist.stepfns.fed_update_bits``) — so slice provisioning
        tracks whatever architecture/compression the pods actually
        train.
        """
        from repro.dist.stepfns import fed_update_bits  # avoid import cycle

        return cls(
            model_bits=float(fed_update_bits(model_cfg, "none")),
            upload_bits=float(fed_update_bits(model_cfg, compress)),
            **kw,
        )


@dataclass
class CoSimResult:
    rounds: List[dict]
    total_time_s: float
    sync_time_s: float              # steady-state per-round sync time
    policy: str
    load: float

    def time_to_metric(self, target: float) -> Optional[float]:
        """Wall-clock until eval_metric >= target (None if never)."""
        t = 0.0
        for r in self.rounds:
            t += r["sync_time_s"]
            if r["eval_metric"] is not None and r["eval_metric"] >= target:
                return t
        return None


class FLNetworkCoSim:
    def __init__(self, server: CPSServer, cfg: CoSimConfig):
        self.server = server
        self.cfg = cfg
        self._timing_cache: Dict[Tuple, float] = {}
        self._update_bits_from_compression = False
        self._collector = cfg.collector

    def _jobs_bundle(
        self, clients: List[ClientProfile],
    ) -> Tuple[List[ClientProfile], Optional[tuple]]:
        """(workload clients incl. tenant clients, full jobs tuple) —
        the primary task becomes job 0 over the server's clients."""
        if self.cfg.jobs is None:
            return clients, None
        primary = JobSpec(
            job_id=0,
            clients=tuple(sorted(c.client_id for c in clients)),
            model_bits=float(self.cfg.model_bits),
        )
        return (clients + list(self.cfg.job_clients or ()),
                (primary,) + tuple(self.cfg.jobs))

    def _round_sync_time(self, clients: List[ClientProfile]) -> float:
        # the key must pin every cfg field the timing depends on —
        # model_bits/upload_bits included, or mutating cfg between
        # run() calls on a reused co-sim would serve stale timings
        key = (
            self.cfg.policy,
            round(self.cfg.total_load, 6),
            self.cfg.model_bits,
            self.cfg.upload_bits,
            self.cfg.pon,
            self.cfg.topology,
            self.cfg.jobs,
            self.cfg.job_clients,
            self.cfg.fairness,
            tuple(sorted((c.client_id, round(c.t_ud, 6), c.m_ud_bits)
                         for c in clients)),
        )
        if key not in self._timing_cache:
            wl_clients, jobs = self._jobs_bundle(clients)
            wl = FLRoundWorkload(
                clients=wl_clients, model_bits=self.cfg.model_bits
            )
            # all timing seeds run as one stacked engine simulation
            results = simulate(SweepSpec(
                cases=tuple(
                    SweepCase(workload=wl, load=self.cfg.total_load,
                              policy=self.cfg.policy, seed=s,
                              topology=self.cfg.topology, jobs=jobs,
                              fairness=self.cfg.fairness)
                    for s in range(self.cfg.timing_seeds)
                ),
                pon=self.cfg.pon,
            ), collector=self._collector)
            # multi-tenant rounds gate on the PRIMARY job's sync time —
            # competitor jobs contend for capacity but do not hold this
            # task's aggregation open
            self._timing_cache[key] = float(np.mean([
                r.sync_time if jobs is None
                else r.job_stats[0].sync_time
                for r in results
            ]))
        return self._timing_cache[key]

    def _client_profiles(
        self, m_bits: Optional[float] = None,
    ) -> Tuple[List[ClientProfile], float]:
        if m_bits is None:
            m_bits = (
                self.cfg.upload_bits
                if self.cfg.upload_bits is not None
                else self.cfg.model_bits
            )
        profiles = [
            ClientProfile(
                client_id=c.client_id,
                t_ud=c.t_ud_s,
                t_dl=0.0,
                m_ud_bits=m_bits,
                distance_m=c.distance_m,
            )
            for c in self.server.clients
        ]
        return profiles, float(m_bits)

    def _round_profiles(self, log) -> Tuple[List[ClientProfile], float]:
        m_bits = None
        if self._update_bits_from_compression and log.n_arrived:
            m_bits = log.update_bits / max(log.n_arrived, 1)
        return self._client_profiles(m_bits)

    def _timeline_sync_times(
        self, per_round: List[List[ClientProfile]],
        m_bits: List[float],
    ) -> np.ndarray:
        """Per-round sync times, averaged over timing seeds, from ONE
        stacked multi-round simulation: the union of all rounds' clients
        forms the workload, per-round participation the membership
        mask, per-round upload sizes the schedule's ``m_ud_bits``."""
        R = len(per_round)
        union: Dict[int, ClientProfile] = {}
        for profs in per_round:
            for p in profs:
                union.setdefault(p.client_id, p)
        ids = sorted(union)
        if self.cfg.jobs is not None:
            # multi-tenant timelines take a plain schedule (per-round
            # membership/size rewrites are single-tenant features), so
            # the client set and upload size must be static across
            # rounds — per-job cadence goes through JobSpec instead
            static = all(
                {p.client_id for p in profs} == set(ids)
                for profs in per_round
            ) and len({float(b) for b in m_bits}) <= 1
            if not static or self.cfg.faults is not None:
                raise ValueError(
                    "multi-tenant co-simulation needs a static client "
                    "set, uniform upload size and no fault schedule "
                    "on the decoupled timeline backend; use "
                    "backend='per_round' for varying rounds"
                )
            wl_clients, jobs = self._jobs_bundle([union[c] for c in ids])
            wl = FLRoundWorkload(
                clients=wl_clients, model_bits=self.cfg.model_bits,
            )
            results = simulate(SweepSpec(
                cases=tuple(
                    SweepCase(workload=wl, load=self.cfg.total_load,
                              policy=self.cfg.policy, seed=s,
                              topology=self.cfg.topology, jobs=jobs,
                              fairness=self.cfg.fairness)
                    for s in range(self.cfg.timing_seeds)
                ),
                pon=self.cfg.pon,
                schedule=TimelineSchedule(n_rounds=R),
            ), collector=self._collector)
            return np.mean([
                [rnd.job_sync[0] for rnd in r.rounds] for r in results
            ], axis=0)
        pos = {cid: j for j, cid in enumerate(ids)}
        membership = np.zeros((R, len(ids)), bool)
        for r, profs in enumerate(per_round):
            for p in profs:
                membership[r, pos[p.client_id]] = True
        wl = FLRoundWorkload(
            clients=[union[c] for c in ids],
            model_bits=self.cfg.model_bits,
        )
        schedule = TimelineSchedule(
            n_rounds=R, membership=membership,
            m_ud_bits=np.asarray(m_bits),
            faults=self.cfg.faults,
        )
        results = simulate(SweepSpec(
            cases=tuple(
                SweepCase(workload=wl, load=self.cfg.total_load,
                          policy=self.cfg.policy, seed=s,
                          topology=self.cfg.topology)
                for s in range(self.cfg.timing_seeds)
            ),
            pon=self.cfg.pon,
            schedule=schedule,
        ), collector=self._collector)
        return np.mean([r.sync_times for r in results], axis=0)

    def _run_coupled(
        self,
        n_rounds: int,
        eval_fn: Optional[Callable],
        deadline_s,
        deadline_policy: str,
        buffer_k: Optional[int],
    ) -> CoSimResult:
        """Deadline/async co-simulation: the network decides per round
        who arrives (and how stale / how complete), the training loop
        follows.

        Every client participates each round unless its previous upload
        is still in flight (a deferred or async straggler — it idles
        until the stale update lands, then re-enters fresh). Fresh
        participants train against the global model at their entry
        round (a ``failure_prob`` roll can kill the update, exactly as
        in the sync path); their decoded update applies at the
        aggregation event the network delivers it to, discounted by
        staleness and served fraction
        (``fl.aggregation.fedbuff_merge``).

        Who arrives in which round is an *event*, not an average, so
        the coupled path follows one arrival realization —
        ``timing_seeds`` must be 1 (the decoupled path averages sync
        times over seeds; arrival sets cannot be averaged).

        Fault injection (``cfg.faults``) rides the same timeline: a
        dropout/loss victim's trained update stays pending while its
        retransmission is in flight (it does NOT retrain — the retry
        re-sends the same payload), a ``gave_up`` client abandons the
        pending update and trains fresh at its next entry, and
        ``cfg.quorum_frac`` gates each aggregation event
        (``CPSServer.apply_updates`` degrades to the previous global
        model below quorum).
        """
        if self.cfg.timing_seeds != 1:
            raise ValueError(
                "coupled deadline/async co-simulation follows one "
                "arrival realization; set timing_seeds=1 (who arrives "
                "per round is an event, not an averageable time)"
            )
        profiles, _ = self._client_profiles()
        wl = FLRoundWorkload(
            clients=profiles, model_bits=self.cfg.model_bits
        )
        schedule = TimelineSchedule(
            n_rounds=n_rounds, deadline_s=deadline_s,
            deadline_policy=deadline_policy, buffer_k=buffer_k,
            faults=self.cfg.faults, retry=self.cfg.retry,
            quorum_frac=self.cfg.quorum_frac,
        )
        net = simulate(SweepSpec(
            cases=(SweepCase(workload=wl, load=self.cfg.total_load,
                             policy=self.cfg.policy, seed=0,
                             topology=self.cfg.topology),),
            pon=self.cfg.pon,
            schedule=schedule,
        ), collector=self._collector)[0]
        by_id = {c.client_id: c for c in self.server.clients}
        pending: Dict[int, "PendingUpdate"] = {}
        rounds = []
        total_time = 0.0
        for rnd in net.rounds:
            fresh = sorted(set(rnd.ul_bits) - set(pending))
            for cid in fresh:
                # a failed client (same roll as the sync path) uploads
                # bits the network still carries, but its update is
                # lost — it contributes nothing when it "arrives"
                pending[cid] = self.server.train_client_update(
                    by_id[cid], self.server.global_params,
                )
            items = []
            for cid in rnd.arrived:
                u = pending.pop(cid)
                if u is not None:
                    items.append((u, rnd.staleness.get(cid, 0), 1.0))
            for cid in sorted(rnd.partial):
                u = pending.pop(cid)
                frac = rnd.partial[cid]
                if u is not None and frac > 0.0:
                    items.append((u, 0, frac))
            for cid in rnd.dropped:
                pending.pop(cid, None)
            # fault outcomes: failed (dropout) and lost (corrupted)
            # clients keep their trained update pending — the retry
            # re-sends the same payload; a gave_up client abandons it
            for cid in rnd.gave_up:
                pending.pop(cid, None)
            log = self.server.apply_updates(
                items, eval_fn=eval_fn,
                n_expected=(len(rnd.ul_bits)
                            if self.cfg.quorum_frac is not None else None),
                quorum_frac=self.cfg.quorum_frac,
            )
            log.sync_time_s = rnd.sync_time
            total_time += rnd.sync_time
            if self._collector is not None:
                self._collector.event(
                    "fl_round", mode="coupled", round=log.round_index,
                    sync_time_s=rnd.sync_time, n_arrived=log.n_arrived,
                    n_deferred=len(rnd.deferred),
                    n_dropped=len(rnd.dropped),
                    n_partial=len(rnd.partial),
                    n_failed=len(rnd.failed),
                    n_lost=len(rnd.lost),
                    quorum_met=rnd.quorum_met,
                    payload_bits=float(sum(rnd.ul_bits.values())),
                )
            rounds.append(
                {
                    "round": log.round_index,
                    "eval_metric": log.eval_metric,
                    "mean_loss": log.mean_loss,
                    "sync_time_s": rnd.sync_time,
                    "n_arrived": log.n_arrived,
                    "staleness": dict(rnd.staleness),
                    "n_failed": len(rnd.failed),
                    "n_lost": len(rnd.lost),
                    "quorum_met": log.quorum_met,
                }
            )
        return CoSimResult(
            rounds=rounds,
            total_time_s=total_time,
            sync_time_s=rounds[-1]["sync_time_s"] if rounds else 0.0,
            policy=self.cfg.policy,
            load=self.cfg.total_load,
        )

    def run(
        self,
        n_rounds: int,
        eval_fn: Optional[Callable] = None,
        update_bits_from_compression: bool = False,
        backend: str = "timeline",
        mode: str = "sync",
        deadline_s=None,
        deadline_policy: str = "defer",
        async_buffer: Optional[int] = None,
        collector=None,
        spec: Optional[SweepSpec] = None,
    ) -> CoSimResult:
        """Train ``n_rounds`` rounds and attach simulated network timing.

        ``spec`` (``repro.net.SweepSpec``, optional) re-points the
        network side at a spec template: its single case supplies
        (policy, load, topology, fairness) and ``spec.pon`` the PON
        config — the case's workload is replaced by the server's
        clients each round, and the co-sim builds its own schedule
        from ``n_rounds`` (schedule-bearing specs are rejected).

        ``backend="timeline"`` (default) resolves all rounds' timings in
        one stacked multi-round simulation after training;
        ``backend="per_round"`` keeps the PR 2 loop (one engine call per
        round, cached by client set) as the reference.

        ``mode="async"`` (FedBuff: each aggregation fires at the
        ``async_buffer``-th completed upload; default half the clients)
        or a ``deadline_s`` with a ``deadline_policy`` switch to the
        *coupled* co-simulation, where simulated arrival times decide
        which updates reach each aggregation event, how stale, and how
        complete — see :meth:`_run_coupled`. Compression-measured
        upload sizes (``update_bits_from_compression``) are a
        decoupled-path feature only.

        ``collector`` (``repro.obs.Collector``) overrides
        ``cfg.collector`` for this run; either turns on metrics in
        every network simulation the co-sim drives plus per-round
        ``fl_round`` events. ``None`` everywhere is bitwise identical
        to an uninstrumented run.
        """
        from repro.obs.trace import maybe_span

        if collector is not None:
            self._collector = collector
        if spec is not None:
            spec.validate()
            if spec.schedule is not None:
                raise ValueError(
                    "the co-sim builds its own schedule from "
                    "n_rounds; pass a schedule-free spec"
                )
            if len(spec.cases) != 1:
                raise ValueError(
                    "co-sim spec needs exactly one template case (its "
                    "workload is replaced by the server's clients)"
                )
            case = spec.cases[0]
            self.cfg = _dc_replace(
                self.cfg, policy=case.policy, total_load=case.load,
                topology=case.topology, fairness=case.fairness,
                pon=spec.pon if spec.pon is not None else self.cfg.pon,
            )
            self._timing_cache.clear()
        if backend not in ("timeline", "per_round"):
            raise ValueError(f"unknown backend {backend!r}")
        if mode not in ("sync", "async"):
            raise ValueError(f"unknown mode {mode!r}")
        if async_buffer is not None:
            # an explicit buffer IS the async request (mirrors the CLI,
            # where --async-buffer alone enables FedBuff); combining it
            # with a deadline fails in TimelineSchedule's validation
            mode = "async"
        coupled = mode == "async" or deadline_s is not None
        if coupled and self.cfg.jobs is not None:
            raise ValueError(
                "multi-tenant contention (cfg.jobs) takes per-job "
                "deadlines (JobSpec.deadline_s, fairness='deadline'); "
                "round-level deadline/async coupling is single-tenant"
            )
        if not coupled:
            if (self.cfg.faults is not None
                    and self.cfg.faults.couples_rounds):
                raise ValueError(
                    "dropout/loss fault injection decides who retries "
                    "and who arrives per round — an event, not a "
                    "timing average; use the coupled path (deadline_s "
                    "or mode='async'). Outage-only faults are fine "
                    "decoupled."
                )
            if self.cfg.quorum_frac is not None:
                raise ValueError(
                    "quorum aggregation gates per-round arrivals; use "
                    "the coupled path (deadline_s, per "
                    "TimelineSchedule's quorum validation)"
                )
        if coupled:
            if update_bits_from_compression:
                raise ValueError(
                    "update_bits_from_compression needs the decoupled "
                    "path; coupled deadline/async timing runs before "
                    "training"
                )
            if mode == "async" and async_buffer is None:
                async_buffer = max(1, len(self.server.clients) // 2)
            return self._run_coupled(
                n_rounds, eval_fn, deadline_s, deadline_policy,
                async_buffer if mode == "async" else None,
            )
        self._update_bits_from_compression = update_bits_from_compression
        rounds = []
        per_round_profiles: List[List[ClientProfile]] = []
        per_round_bits: List[float] = []
        sync = 0.0
        total_time = 0.0
        for _ in range(n_rounds):
            with maybe_span(self._collector, "fl:train_round"):
                log = self.server.run_round(eval_fn=eval_fn)
            profiles, m_bits = self._round_profiles(log)
            per_round_profiles.append(profiles)
            per_round_bits.append(m_bits)
            if backend == "per_round":
                sync = self._round_sync_time(profiles)
                log.sync_time_s = sync
                total_time += sync
            if self._collector is not None:
                self._collector.event(
                    "fl_round", mode="sync", round=log.round_index,
                    n_arrived=log.n_arrived,
                    payload_bits=float(m_bits) * log.n_arrived,
                )
            rounds.append(
                {
                    "round": log.round_index,
                    "eval_metric": log.eval_metric,
                    "mean_loss": log.mean_loss,
                    "sync_time_s": sync,
                    "n_arrived": log.n_arrived,
                }
            )
        if backend == "timeline" and rounds:
            sync_times = self._timeline_sync_times(
                per_round_profiles, per_round_bits
            )
            for entry, log, s in zip(rounds, self.server.history[-len(
                    rounds):], sync_times):
                entry["sync_time_s"] = float(s)
                log.sync_time_s = float(s)
            total_time = float(sync_times.sum())
            sync = float(sync_times[-1])
        return CoSimResult(
            rounds=rounds,
            total_time_s=total_time,
            sync_time_s=sync,
            policy=self.cfg.policy,
            load=self.cfg.total_load,
        )
