"""FL × PON co-simulation: real JAX training + network timing per round.

Couples the ``CPSServer`` (actual federated SGD on the LEAF-style CNN) with
the PON round simulator. Learning dynamics (accuracy vs round — Fig 2a) come
from real training; wall-clock training time (Fig 2b, the 36% saving) comes
from rounds × simulated synchronisation time. Since the paper's BS slice is
recomputed only on membership change, the per-round timing for a fixed
client set is cached and reused across rounds.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.slicing import ClientProfile
from repro.net.engine import SweepCase, simulate_round_sweep
from repro.net.sim import FLRoundWorkload, PONConfig, RoundResult
from repro.fl.server import CPSServer


@dataclass
class CoSimConfig:
    policy: str = "bs"              # "bs" | "fcfs"
    total_load: float = 0.8
    model_bits: float = 26.416e6    # global model size (fp32 downlink)
    upload_bits: Optional[float] = None  # per-client M_i^UD; None = model_bits
    pon: PONConfig = field(default_factory=PONConfig)
    timing_seeds: int = 2           # average the net-sim over this many seeds

    @classmethod
    def from_fed_model(cls, model_cfg, compress: str = "int8", **kw):
        """Size the slice from the real sharded update payload.

        Instead of the paper's hard-coded CNN constant, ``model_bits``
        becomes the fp32 wire size of the global model (the server's
        full-precision downlink broadcast) and ``upload_bits`` the size
        of one pod's *compressed* upload
        (``repro.dist.stepfns.fed_update_bits``) — so slice provisioning
        tracks whatever architecture/compression the pods actually
        train.
        """
        from repro.dist.stepfns import fed_update_bits  # avoid import cycle

        return cls(
            model_bits=float(fed_update_bits(model_cfg, "none")),
            upload_bits=float(fed_update_bits(model_cfg, compress)),
            **kw,
        )


@dataclass
class CoSimResult:
    rounds: List[dict]
    total_time_s: float
    sync_time_s: float              # steady-state per-round sync time
    policy: str
    load: float

    def time_to_metric(self, target: float) -> Optional[float]:
        """Wall-clock until eval_metric >= target (None if never)."""
        t = 0.0
        for r in self.rounds:
            t += r["sync_time_s"]
            if r["eval_metric"] is not None and r["eval_metric"] >= target:
                return t
        return None


class FLNetworkCoSim:
    def __init__(self, server: CPSServer, cfg: CoSimConfig):
        self.server = server
        self.cfg = cfg
        self._timing_cache: Dict[Tuple, float] = {}

    def _round_sync_time(self, clients: List[ClientProfile]) -> float:
        key = (
            self.cfg.policy,
            round(self.cfg.total_load, 6),
            tuple(sorted((c.client_id, round(c.t_ud, 6), c.m_ud_bits)
                         for c in clients)),
        )
        if key not in self._timing_cache:
            wl = FLRoundWorkload(
                clients=clients, model_bits=self.cfg.model_bits
            )
            # all timing seeds run as one stacked engine simulation
            results = simulate_round_sweep(
                self.cfg.pon,
                [
                    SweepCase(workload=wl, load=self.cfg.total_load,
                              policy=self.cfg.policy, seed=s)
                    for s in range(self.cfg.timing_seeds)
                ],
            )
            self._timing_cache[key] = float(
                np.mean([r.sync_time for r in results])
            )
        return self._timing_cache[key]

    def run(
        self,
        n_rounds: int,
        eval_fn: Optional[Callable] = None,
        update_bits_from_compression: bool = False,
    ) -> CoSimResult:
        rounds = []
        total_time = 0.0
        sync = 0.0
        for _ in range(n_rounds):
            log = self.server.run_round(eval_fn=eval_fn)
            m_bits = (
                self.cfg.upload_bits
                if self.cfg.upload_bits is not None
                else self.cfg.model_bits
            )
            if update_bits_from_compression and log.n_arrived:
                m_bits = log.update_bits / max(log.n_arrived, 1)
            profiles = [
                ClientProfile(
                    client_id=c.client_id,
                    t_ud=c.t_ud_s,
                    t_dl=0.0,
                    m_ud_bits=m_bits,
                    distance_m=c.distance_m,
                )
                for c in self.server.clients
            ]
            sync = self._round_sync_time(profiles)
            log.sync_time_s = sync
            total_time += sync
            rounds.append(
                {
                    "round": log.round_index,
                    "eval_metric": log.eval_metric,
                    "mean_loss": log.mean_loss,
                    "sync_time_s": sync,
                    "n_arrived": log.n_arrived,
                }
            )
        return CoSimResult(
            rounds=rounds,
            total_time_s=total_time,
            sync_time_s=sync,
            policy=self.cfg.policy,
            load=self.cfg.total_load,
        )
