"""Update compression — the ``M_i^UD`` lever of the paper's Algorithm 1.

The slice bandwidth demand is ``Σ M_i^UD / τ``; shrinking the update bytes
shrinks the slice (or lets more clients share it). Two standard schemes, both
with error feedback so compression noise does not bias FedAvg:

* int8 symmetric per-tensor quantisation (4x vs fp32). The Pallas kernel
  (repro.kernels.quant) implements the same transform for on-device use; this
  module is the host-side pipeline.
* top-k sparsification (magnitude): keep the k largest entries per tensor.

``CompressionState`` carries the per-client error-feedback residual.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp


# --------------------------- int8 quantisation -----------------------------


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


# --------------------------- top-k sparsification --------------------------


def topk_sparsify(x: jnp.ndarray, frac: float) -> jnp.ndarray:
    """Zero all but the top-``frac`` fraction of entries by magnitude."""
    flat = x.reshape(-1).astype(jnp.float32)
    k = max(1, int(frac * flat.size))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(flat) >= thresh
    return (flat * mask).reshape(x.shape)


# --------------------------- error-feedback pipeline ------------------------


@dataclass
class CompressorConfig:
    scheme: str = "int8"       # "none" | "int8" | "topk" | "int8+topk"
    topk_frac: float = 0.05
    error_feedback: bool = True


def init_error_state(params):
    return jax.tree.map(lambda l: jnp.zeros_like(l, jnp.float32), params)


def compress_delta(delta, cfg: CompressorConfig, error_state=None):
    """Compress an update pytree. Returns (decoded_delta, new_error, bits).

    ``decoded_delta`` is what the server will see after decode (simulation
    runs both directions at once); ``bits`` is the wire size, which is what
    feeds ``M_i^UD`` in the BS algorithm.
    """
    if cfg.scheme == "none":
        bits = sum(
            32 * l.size for l in jax.tree.leaves(delta)
        )
        return delta, error_state, bits

    if error_state is None and cfg.error_feedback:
        error_state = init_error_state(delta)

    bits_total = 0
    decoded = {}

    leaves_d, treedef = jax.tree.flatten(delta)
    leaves_e = (
        jax.tree.leaves(error_state) if error_state is not None
        else [None] * len(leaves_d)
    )
    out_d, out_e = [], []
    for d, e in zip(leaves_d, leaves_e):
        target = d.astype(jnp.float32)
        if cfg.error_feedback and e is not None:
            target = target + e
        comp = target
        bits = 0
        if "topk" in cfg.scheme:
            comp = topk_sparsify(comp, cfg.topk_frac)
            k = max(1, int(cfg.topk_frac * comp.size))
            bits += k * (32 + 32)           # value + index
        if "int8" in cfg.scheme:
            q, scale = quantize_int8(comp)
            comp = dequantize_int8(q, scale)
            if "topk" in cfg.scheme:
                k = max(1, int(cfg.topk_frac * comp.size))
                bits = k * (8 + 32) + 32    # int8 payload + index + scale
            else:
                bits = 8 * comp.size + 32
        elif "topk" not in cfg.scheme:
            bits = 32 * comp.size
        err = target - comp if cfg.error_feedback else None
        out_d.append(comp.astype(d.dtype))
        out_e.append(err)
        bits_total += bits

    decoded = jax.tree.unflatten(treedef, out_d)
    new_error = (
        jax.tree.unflatten(treedef, out_e) if cfg.error_feedback else None
    )
    return decoded, new_error, int(bits_total)


def compressed_update_bits(params, cfg: CompressorConfig) -> int:
    """Wire size of one update under ``cfg`` (without compressing)."""
    total = 0
    for l in jax.tree.leaves(params):
        if cfg.scheme == "none":
            total += 32 * l.size
        elif cfg.scheme == "int8":
            total += 8 * l.size + 32
        elif cfg.scheme == "topk":
            total += max(1, int(cfg.topk_frac * l.size)) * 64
        elif cfg.scheme == "int8+topk":
            total += max(1, int(cfg.topk_frac * l.size)) * 40 + 32
    return total
