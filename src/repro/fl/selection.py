"""Client selection strategies for each FL round.

* ``fraction`` — the paper's Fig 2a sweep: a fixed percentage of all clients
  participates each round (uniform without replacement).
* ``deadline`` — the Nishio-style baseline the paper argues against: drop
  stragglers that cannot meet the round deadline.
* ``all`` — full participation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

from repro.core.deadline import select_by_deadline
from repro.core.slicing import ClientProfile


@dataclass(frozen=True)
class SelectionConfig:
    strategy: str = "fraction"     # "fraction" | "deadline" | "all"
    fraction: float = 1.0
    deadline_s: float = 6.0
    uplink_bps: float = 10e9


def select_clients(
    clients: Sequence[ClientProfile],
    cfg: SelectionConfig,
    rng: np.random.Generator,
) -> List[ClientProfile]:
    if cfg.strategy == "all":
        return list(clients)
    if cfg.strategy == "fraction":
        n = max(1, int(round(cfg.fraction * len(clients))))
        idx = rng.choice(len(clients), size=n, replace=False)
        return [clients[i] for i in sorted(idx)]
    if cfg.strategy == "deadline":
        selected, _ = select_by_deadline(
            clients, cfg.deadline_s, cfg.uplink_bps
        )
        return selected
    raise ValueError(f"unknown selection strategy {cfg.strategy!r}")
