"""PON network substrate: traffic, DBA engines, round + timeline sims."""
from repro.faults import (  # noqa: F401  (re-export: timeline fault model)
    FaultSchedule,
    RetryPolicy,
)
from repro.net.engine import (  # noqa: F401
    SweepCase,
    simulate_round_sweep,
)
from repro.net.multi_pon import (  # noqa: F401
    MultiPonTopology,
    cps_waterfill,
    pon_bg_rates,
    simulate_multi_pon_round,
)
from repro.net.timeline import (  # noqa: F401
    TimelineResult,
    TimelineRound,
    TimelineSchedule,
    simulate_timeline_per_round,
    simulate_timeline_reference,
    simulate_timeline_sweep,
)
from repro.net.dba import (  # noqa: F401
    DEFAULT_EFFICIENCY,
    FCFSBestEffort,
    FCFSLimitedService,
    OnuQueue,
    SlicedDBA,
)
from repro.net.sim import (  # noqa: F401
    FLRoundWorkload,
    PONConfig,
    RoundResult,
    simulate_round,
)
from repro.net.traffic import (  # noqa: F401
    PACKET_BITS,
    CounterSource,
    CounterStream,
    PoissonSource,
    PrecomputedSource,
    background_rate_for_load,
    burst_lambda,
    per_onu_sources,
)
