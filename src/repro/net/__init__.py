"""PON network substrate: traffic, DBA engines, round simulator."""
from repro.net.engine import (  # noqa: F401
    SweepCase,
    simulate_round_sweep,
)
from repro.net.dba import (  # noqa: F401
    DEFAULT_EFFICIENCY,
    FCFSBestEffort,
    FCFSLimitedService,
    OnuQueue,
    SlicedDBA,
)
from repro.net.sim import (  # noqa: F401
    FLRoundWorkload,
    PONConfig,
    RoundResult,
    simulate_round,
)
from repro.net.traffic import (  # noqa: F401
    PACKET_BITS,
    PoissonSource,
    PrecomputedSource,
    background_rate_for_load,
    per_onu_sources,
)
