"""PON network substrate: traffic, DBA engines, round + timeline sims.

The curated surface is ``__all__``: build a :class:`SweepSpec` and run
it with :func:`simulate`; the ``simulate_*`` functions remain as
compatibility entry points (their keyword forms are deprecated) and as
parity oracles. Everything else in the submodules is internal.
"""
from repro.faults import (
    FaultSchedule,
    RetryPolicy,
)
from repro.net.api import (
    SweepSpec,
    simulate,
)
from repro.net.dba import (
    DEFAULT_EFFICIENCY,
    FCFSBestEffort,
    FCFSLimitedService,
    OnuQueue,
    SlicedDBA,
)
from repro.net.engine import (
    SweepCase,
    simulate_round_sweep,
)
from repro.net.jobs import (
    FAIRNESS_POLICIES,
    JobRoundStats,
    JobSpec,
    job_fair_split,
    make_competing_jobs,
    simulate_jobs_round_reference,
)
from repro.net.multi_pon import (
    MultiPonTopology,
    cps_waterfill,
    pon_bg_rates,
    simulate_multi_pon_round,
)
from repro.net.sim import (
    FLRoundWorkload,
    PONConfig,
    RoundResult,
    simulate_round,
)
from repro.net.timeline import (
    DEADLINE_POLICIES,
    TimelineResult,
    TimelineRound,
    TimelineSchedule,
    simulate_timeline_per_round,
    simulate_timeline_reference,
    simulate_timeline_sweep,
)
from repro.net.traffic import (
    PACKET_BITS,
    CounterSource,
    CounterStream,
    PoissonSource,
    PrecomputedSource,
    background_rate_for_load,
    burst_lambda,
    per_onu_sources,
)

__all__ = [
    # spec facade (preferred entry point)
    "SweepSpec",
    "simulate",
    # sweep building blocks
    "SweepCase",
    "PONConfig",
    "FLRoundWorkload",
    "RoundResult",
    # multi-tenant jobs
    "FAIRNESS_POLICIES",
    "JobSpec",
    "JobRoundStats",
    "job_fair_split",
    "make_competing_jobs",
    "simulate_jobs_round_reference",
    # multi-PON topology
    "MultiPonTopology",
    "cps_waterfill",
    "pon_bg_rates",
    "simulate_multi_pon_round",
    # timelines
    "DEADLINE_POLICIES",
    "TimelineSchedule",
    "TimelineRound",
    "TimelineResult",
    "simulate_timeline_sweep",
    "simulate_timeline_per_round",
    "simulate_timeline_reference",
    # faults (re-export: timeline fault model)
    "FaultSchedule",
    "RetryPolicy",
    # single-round entry points / oracles
    "simulate_round_sweep",
    "simulate_round",
    # DBA engines
    "DEFAULT_EFFICIENCY",
    "FCFSBestEffort",
    "FCFSLimitedService",
    "OnuQueue",
    "SlicedDBA",
    # traffic sources
    "PACKET_BITS",
    "CounterSource",
    "CounterStream",
    "PoissonSource",
    "PrecomputedSource",
    "background_rate_for_load",
    "burst_lambda",
    "per_onu_sources",
]
