"""Dynamic bandwidth allocation engines for the TDM-PON.

Service classes follow PON T-CONT practice, which is also the paper's
narrative: *background* traffic (broadband access, mobile backhaul — "the
other traffic ... can coexist in the same PON") rides **assured** T-CONTs
with SLA'd bandwidth, while the FL training traffic is, without slicing,
plain **best-effort**:

* ``FCFSBestEffort`` — the paper's benchmark ("simply follows FCFS queuing
  policy"): every polling cycle the assured background queues are served
  first (up to their offered backlog), and FL queues share only the residual
  capacity, FCFS by head-of-line age. Under a total load ρ the FL task
  therefore drains at ≈ (eff − ρ)·C — which is exactly why the paper's FCFS
  synchronisation time grows with load.

* ``SlicedDBA`` — the proposal: during the BS slice the scheduled client's
  FL queue is served *first* at the slice bandwidth B (its slot — a
  dedicated T-CONT), and the remaining capacity serves background. FL
  latency becomes independent of the background load.

``efficiency`` models PON framing overhead (guard times, REPORT/GRANT,
FEC) — effective payload rate = efficiency × line rate (≈0.92 for
10G-class PON).

Queues are fluid (bits) with per-ONU FIFO between kinds by arrival order.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.scheduler import SlotAssignment

DEFAULT_EFFICIENCY = 0.92


def _kind_matches(seg_kind, kind) -> bool:
    """Class match: a segment kind is either a plain class string
    ("bg"/"fl") or an owner-tagged tuple ``(class, owner_id)`` — the FL
    phases tag each client's traffic so served bits are attributed to
    the client whose update they carry."""
    return seg_kind == kind or (
        isinstance(seg_kind, tuple) and seg_kind[0] == kind
    )


@dataclass
class OnuQueue:
    """Per-ONU queue: FIFO of [kind, bits, t_arrive] segments."""

    onu_id: int
    segments: List[list] = field(default_factory=list)
    hol_time: float = np.inf         # arrival time of head-of-line backlog

    def push(self, kind, bits: float, t: float):
        if bits <= 0:
            return
        if not self.segments:
            self.hol_time = t
        self.segments.append([kind, bits, t])

    @property
    def backlog(self) -> float:
        return sum(s[1] for s in self.segments)

    def backlog_of(self, kind) -> float:
        return sum(s[1] for s in self.segments if _kind_matches(s[0], kind))

    def hol_time_of(self, kind) -> float:
        for s in self.segments:
            if _kind_matches(s[0], kind):
                return s[2]
        return np.inf

    def serve(self, bits: float, kind=None) -> Dict[object, float]:
        """Drain up to ``bits`` from the FIFO head (optionally only ``kind``
        class segments, preserving order among them). Returns drained bits
        by exact segment kind (owner tags preserved).

        Single-pass: survivors are rebuilt into a fresh list instead of
        ``pop(i)``-compacting in place, so a serve over n segments is O(n)
        rather than O(n^2)."""
        served: Dict[object, float] = {}
        remaining = bits
        kept: List[list] = []
        for j, seg in enumerate(self.segments):
            if remaining <= 1e-9:
                kept.extend(self.segments[j:])
                break
            if kind is not None and not _kind_matches(seg[0], kind):
                kept.append(seg)
                continue
            take = min(seg[1], remaining)
            seg[1] -= take
            remaining -= take
            served[seg[0]] = served.get(seg[0], 0.0) + take
            if seg[1] <= 1.0:            # < 1 bit: numerically drained
                remaining = max(0.0, remaining - seg[1])
            else:
                kept.append(seg)
        self.segments = kept
        self.hol_time = kept[0][2] if kept else np.inf
        return served


class FCFSBestEffort:
    """Benchmark DBA: assured background first, FL best-effort FCFS residual."""

    def __init__(
        self,
        line_rate_bps: float,
        cycle_time_s: float,
        n_onus: int,
        efficiency: float = DEFAULT_EFFICIENCY,
    ):
        self.capacity_bits = line_rate_bps * cycle_time_s * efficiency
        self.n_onus = n_onus

    def grant(
        self, queues: Sequence[OnuQueue], cap_bits: Optional[float] = None
    ) -> Dict[int, Dict[str, float]]:
        """``cap_bits`` caps this cycle below the wavelength capacity —
        the PON's waterfilled share of a shared CPS uplink."""
        grants: Dict[int, Dict[str, float]] = {}
        cap = self.capacity_bits
        if cap_bits is not None:
            cap = min(cap, cap_bits)

        # 1) assured class: background backlogs, oldest first
        bg_q = [(q.hol_time_of("bg"), q) for q in queues if q.backlog_of("bg") > 0]
        for _, q in sorted(bg_q, key=lambda x: x[0]):
            take = min(q.backlog_of("bg"), cap)
            if take <= 0:
                continue
            grants.setdefault(q.onu_id, {})["bg"] = take
            cap -= take
            if cap <= 1e-9:
                return grants

        # 2) best-effort class: FL queues, FCFS by head-of-line age
        fl_q = [(q.hol_time_of("fl"), q) for q in queues if q.backlog_of("fl") > 0]
        for _, q in sorted(fl_q, key=lambda x: x[0]):
            take = min(q.backlog_of("fl"), cap)
            if take <= 0:
                continue
            grants.setdefault(q.onu_id, {})["fl"] = take
            cap -= take
            if cap <= 1e-9:
                break
        return grants


# Backwards-compatible alias (the paper simply calls the benchmark "FCFS")
FCFSLimitedService = FCFSBestEffort


class SlicedDBA:
    """The paper's DBA: reserved slice grants first, assured bg from the rest."""

    def __init__(
        self,
        line_rate_bps: float,
        cycle_time_s: float,
        n_onus: int,
        slice_bandwidth_bps: float,
        slots: Sequence[SlotAssignment],
        efficiency: float = DEFAULT_EFFICIENCY,
    ):
        self.capacity_bits = line_rate_bps * cycle_time_s * efficiency
        self.cycle_time_s = cycle_time_s
        self.slice_rate = slice_bandwidth_bps
        self.slots = sorted(slots, key=lambda s: s.t_start)
        self.fcfs = FCFSBestEffort(
            line_rate_bps, cycle_time_s, n_onus, efficiency
        )

    def active_slots(self, t_cycle: float) -> List[SlotAssignment]:
        # one extra cycle of grace absorbs cycle-quantisation float error
        t_end = t_cycle + self.cycle_time_s
        return [
            s
            for s in self.slots
            if s.t_start < t_end and s.t_end + self.cycle_time_s > t_cycle
        ]

    def grant(
        self, queues: Sequence[OnuQueue], t_cycle: float,
        cap_bits: Optional[float] = None,
    ) -> Dict[int, Dict[str, float]]:
        """Returns {onu_id: {"fl": bits, "bg": bits}} for this cycle.

        FL rides ONLY in its slice slots (dedicated T-CONT); background is
        assured from the remaining capacity. ``cap_bits`` caps the cycle
        below the wavelength capacity (the PON's waterfilled share of a
        shared CPS uplink).
        """
        grants: Dict[int, Dict[str, float]] = {}
        by_id = {q.onu_id: q for q in queues}
        cap_total = self.capacity_bits
        if cap_bits is not None:
            cap_total = min(cap_total, cap_bits)
        reserved_spent = 0.0
        for slot in self.active_slots(t_cycle):
            q = by_id.get(slot.client_id)
            if q is None:
                continue
            overlap = min(
                slot.t_end + self.cycle_time_s, t_cycle + self.cycle_time_s
            ) - max(slot.t_start, t_cycle)
            fl_bits = min(
                self.slice_rate * max(overlap, 0.0),
                q.backlog_of("fl"),
                cap_total - reserved_spent,
            )
            if fl_bits > 0:
                g = grants.setdefault(slot.client_id, {})
                g["fl"] = g.get("fl", 0.0) + fl_bits
                reserved_spent += fl_bits
        # assured background from the remaining capacity, oldest first
        cap = cap_total - reserved_spent
        bg_q = [
            (q.hol_time_of("bg"), q) for q in queues if q.backlog_of("bg") > 0
        ]
        for _, q in sorted(bg_q, key=lambda x: x[0]):
            take = min(q.backlog_of("bg"), cap)
            if take <= 0:
                continue
            g = grants.setdefault(q.onu_id, {})
            g["bg"] = g.get("bg", 0.0) + take
            cap -= take
            if cap <= 1e-9:
                break
        return grants
