"""Vectorized batched PON round engine.

``repro.net.sim`` advances one polling cycle at a time through Python
dicts and per-cycle ``sorted()`` calls over ``OnuQueue`` segment lists; a
128-ONU round costs thousands of interpreted cycles and a full Fig. 2b
sweep takes minutes.  This module keeps that simulator as the semantic
reference and re-expresses one cycle as a handful of array operations
over *all* ONUs at once, with a batch axis over sweep cases
(seed x load x policy) — and, under a ``MultiPonTopology``, over the
cases' wavelength segments too: rows become flattened ``(case, pon)``
pairs over per-PON ONU columns, coupled each cycle by the CPS
waterfill (``repro.net.multi_pon``):

* queue backlogs are ``(n_cases, n_onus)`` float arrays; FL queues are
  tracked per client in a static ``(onu, client_id)``-sorted layout so
  per-ONU aggregates are ``np.add.reduceat`` calls;
* the FCFS DBA's "assured background oldest-first, then best-effort FL
  oldest-first" becomes a stable argsort by head-of-line age plus
  prefix-sum waterfilling of the cycle capacity;
* the Sliced DBA's slot grants are an overlap computation over the slot
  arrays (``repro.core.scheduler.slots_to_arrays``) plus the same
  prefix-sum capacity cap;
* background FIFO state (head-of-line ages, the reference's 1-bit
  segment compaction) is kept exactly via per-ONU head pointers into the
  arrival history, so the engine reproduces the reference's per-client
  ``dl_done``/``ready``/``ul_done`` within float tolerance when both
  consume the same arrival process (property-tested).

Public API: ``SweepCase`` + ``simulate_round_sweep`` (a whole sweep as
one stacked simulation — legacy kwarg form; prefer building a
``repro.net.SweepSpec`` and calling ``simulate(spec)``);
``repro.net.sim.simulate_round`` uses this as its default backend.
Multi-tenant cases (``SweepCase.jobs``) add a job axis: columns gain a
job binding next to ``cid_of`` and each cycle's FL capacity is split
across jobs by the case's fairness policy (``repro.net.jobs``).
"""
from __future__ import annotations

import functools
import warnings
from collections import OrderedDict
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.scheduler import schedule_slots, slots_to_arrays
from repro.core.slicing import ClientProfile, SliceSpec, compute_slice
from repro.net.jobs import (
    FAIRNESS_POLICIES,
    compute_job_stats,
    job_fair_split,
    validate_case_jobs,
)
from repro.net.multi_pon import (
    MultiPonTopology,
    cps_waterfill,
    pon_bg_rates,
)
from repro.net.traffic import (
    PACKET_BITS,
    background_rate_for_load,
    burst_lambda,
)

CAP_EPS = 1e-9       # the DBAs' "capacity exhausted" threshold
SEG_EPS = 1.0        # OnuQueue.serve: segments under 1 bit are compacted
EPS_BITS = 1.0       # sim._settle: a client is done below 1 remaining bit
_IKEY_INF = np.iinfo(np.int64).max // 4


@dataclass(frozen=True)
class SweepCase:
    """One cell of a sweep: a workload under (policy, load, seed).

    ``dl_arrivals``/``ul_arrivals`` optionally inject a precomputed
    per-cycle background arrival matrix ``(n_cycles, n_onus)`` (bits) for
    each phase — the parity-test hook; cycles beyond the matrix see zero
    arrivals (columns are global ONUs — ``n_pons * n_onus`` wide under a
    topology).  When absent, arrivals come from the case's counter-based
    Poisson-burst stream keyed by ``(seed, phase, stream_round, pon)``
    (``repro.kernels.traffic``) — identical regardless of chunking and
    O(1)-seekable, so a multi-round timeline can address round
    ``stream_round``'s arrivals directly.

    ``no_dl_ids`` lists clients that skip the model download (their
    ``dl_done`` is 0.0): the multi-round timeline's deadline carriers,
    which resume a partial upload instead of fetching a fresh model.

    ``topology`` stacks the case over several wavelength/OLT segments
    sharing a CPS uplink (``repro.net.multi_pon.MultiPonTopology``);
    every case of a sweep must share one topology. ``None`` is the
    single-PON network described by the ``PONConfig`` alone.

    ``jobs`` (tuple of ``repro.net.jobs.JobSpec``) makes the case
    multi-tenant: the jobs must partition ``workload.clients`` exactly,
    each job's downlink broadcasts its OWN ``model_bits``, and every
    cycle's FL capacity is split across jobs by ``fairness``
    (``"maxmin"`` | ``"weighted"`` | ``"deadline"``) before the
    per-queue grants. A sweep where every case has exactly one job runs
    the single-tenant path bitwise and only adds per-job stats.
    """

    workload: "FLRoundWorkload"  # noqa: F821  (imported lazily, no cycle)
    load: float
    policy: str                  # "fcfs" | "bs"
    seed: int = 0
    dl_arrivals: Optional[np.ndarray] = None
    ul_arrivals: Optional[np.ndarray] = None
    stream_round: int = 0
    no_dl_ids: frozenset = frozenset()
    topology: Optional[MultiPonTopology] = None
    jobs: Optional[tuple] = None          # Tuple[JobSpec, ...]
    fairness: str = "maxmin"


# ---------------------------------------------------------------------------
# client layout: (local_onu, slot) columns, per-PON client bindings
# ---------------------------------------------------------------------------


class _Layout:
    """Static slot layout shared by every row of a sweep.

    Rows are flattened ``(case, pon)`` pairs (case-major); columns are
    ``(local_onu, slot)`` pairs, ascending, where ONU ``o`` carries
    ``max_p |clients on (p, o)|`` slots — so per-ONU reductions are
    contiguous ``reduceat`` segments shared by every row, while each
    row binds its own PON's clients to the slots (``cid_of[p, col]``;
    a column is dead — ``part`` False — in rows whose PON or case
    doesn't bind it).  Slots within an ONU are bound in ascending
    ``client_id`` order, so the settle order (ascending id within an
    ONU) is the column order, exactly the PR 2 single-PON layout when
    ``n_pons == 1``.  Column count is the *per-PON maximum*, not the
    client union — a 32-PON stack of 4 096 clients keeps ~128 columns
    per row instead of 4 096, which is what makes stacking win over a
    per-PON loop.

    Client placement: global onu = id % (n_pons * n_onus); PON =
    onu // n_onus, local onu = onu % n_onus.
    """

    def __init__(self, cases: Sequence[SweepCase], n_onus: int,
                 n_pons: int = 1):
        total = n_onus * n_pons
        ids = sorted(
            {c.client_id for case in cases for c in case.workload.clients}
        )
        if not ids:
            raise ValueError("sweep needs at least one client")
        buckets: Dict[tuple, List[int]] = {}
        for i in ids:                       # ascending id within buckets
            o = i % total
            buckets.setdefault((o // n_onus, o % n_onus), []).append(i)
        slots = np.zeros(n_onus, np.int64)
        for (_, o), lst in buckets.items():
            slots[o] = max(slots[o], len(lst))
        self.onu = np.repeat(np.arange(n_onus, dtype=np.int64), slots)
        slot_off = np.zeros(n_onus + 1, np.int64)
        np.cumsum(slots, out=slot_off[1:])
        nU = self.n_clients = int(slot_off[-1])
        self.pos = np.arange(nU, dtype=np.int64)
        # per-PON slot binding: which client id a column carries
        self.cid_of = np.full((n_pons, nU), -1, np.int64)
        colmap: Dict[int, int] = {}
        for (p, o), lst in buckets.items():
            for s, cid in enumerate(lst):
                col = int(slot_off[o]) + s
                self.cid_of[p, col] = cid
                colmap[cid] = col
        starts = [0] + [
            j for j in range(1, nU) if self.onu[j] != self.onu[j - 1]
        ]
        self.seg_starts = np.asarray(starts, np.int64)
        self.seg_onus = self.onu[self.seg_starts]
        self.seg_len = np.diff(np.append(self.seg_starts, nU))
        self.single = bool(self.seg_len.max() == 1)
        # one slot per ONU in ONU order: per-ONU aggregates are the
        # column arrays themselves (no scatter, no allocation)
        self.identity = self.single and nU == n_onus and bool(
            (self.onu == np.arange(n_onus)).all()
        )

        B = len(cases)
        R = B * n_pons
        self.n_pons = n_pons
        self.part = np.zeros((R, nU), bool)
        self.t_ud = np.zeros((R, nU))
        self.m_ud = np.zeros((R, nU))
        self.dist = np.full((R, nU), 20_000.0)
        self.list_pos = np.zeros((R, nU), np.int64)
        for b, case in enumerate(cases):
            seen = set()
            for p, c in enumerate(case.workload.clients):
                if c.client_id in seen:
                    raise ValueError(
                        f"duplicate client_id {c.client_id} in case {b}"
                    )
                seen.add(c.client_id)
                o = c.client_id % total
                r = b * n_pons + o // n_onus
                j = colmap[c.client_id]
                self.part[r, j] = True
                self.t_ud[r, j] = c.t_ud
                self.m_ud[r, j] = c.m_ud_bits
                self.dist[r, j] = c.distance_m
                self.list_pos[r, j] = p

    def rows(self, sel: np.ndarray) -> "_Layout":
        """Row-sliced view for a sub-batch of rows (columns shared)."""
        sub = object.__new__(_Layout)
        sub.__dict__.update(self.__dict__)
        for name in ("part", "t_ud", "m_ud", "dist", "list_pos"):
            setattr(sub, name, getattr(self, name)[sel])
        return sub


_LAYOUT_CACHE: "OrderedDict[tuple, _Layout]" = OrderedDict()
_LAYOUT_CACHE_MAX = 16


def _layout_for(cases: Sequence[SweepCase], n_onus: int,
                n_pons: int = 1) -> _Layout:
    """Memoized ``_Layout`` construction.

    The layout depends only on the client tuples (ids, t_ud, m_ud,
    distance — ``ClientProfile`` is frozen/hashable) and the topology
    shape, and is never mutated after ``__init__`` — every phase of
    every round of a timeline with stable membership rebuilds the exact
    same python bucket/colmap loops.  A small LRU keyed by the client
    tuples removes that rebuild; elastic-membership timelines simply
    rotate through the LRU.
    """
    try:
        key = (int(n_onus), int(n_pons),
               tuple(tuple(case.workload.clients) for case in cases))
    except TypeError:             # unhashable client type: build fresh
        return _Layout(cases, n_onus, n_pons)
    lay = _LAYOUT_CACHE.get(key)
    if lay is None:
        lay = _Layout(cases, n_onus, n_pons)
        _LAYOUT_CACHE[key] = lay
        while len(_LAYOUT_CACHE) > _LAYOUT_CACHE_MAX:
            _LAYOUT_CACHE.popitem(last=False)
    else:
        _LAYOUT_CACHE.move_to_end(key)
    return lay


# ---------------------------------------------------------------------------
# arrival streams
# ---------------------------------------------------------------------------

_CHUNK = 1024
_CHUNK_TARGET_CELLS = 1 << 22     # bound per-chunk sampler memory


class _CaseFixed:
    """Replays an injected ``(n_cycles, n_onus)`` arrival matrix."""

    def __init__(self, rows: np.ndarray, n_onus: int):
        rows = np.asarray(rows, np.float64)
        if rows.ndim != 2 or rows.shape[1] != n_onus:
            raise ValueError(f"arrivals must be (n_cycles, {n_onus})")
        self.rows = rows
        self.n = n_onus

    def chunk(self, cycle0: int, length: int) -> np.ndarray:
        out = np.zeros((length, self.n))
        avail = self.rows[cycle0:cycle0 + length]
        out[: len(avail)] = avail
        return out


class _Stream:
    """Batched counter-based arrival rows, chunked and O(1)-seekable.

    Sampled cases (``(key, lam)`` pairs) are drawn in ONE vectorized
    sampler call per chunk; injected cases replay their fixed matrices.
    Chunk boundaries never affect values (counter-based sampler), so the
    adaptive chunk length is purely a memory/speed knob.
    """

    def __init__(self, entries: List, n_onus: int, inv_burst: float,
                 packet_bits: float = PACKET_BITS):
        self.n = n_onus
        self.inv_burst = inv_burst
        self.packet_bits = packet_bits
        self.fixed = [(i, e) for i, e in enumerate(entries)
                      if isinstance(e, _CaseFixed)]
        self.sampled = [(i, e) for i, e in enumerate(entries)
                        if not isinstance(e, _CaseFixed)]
        self.B = len(entries)
        if self.sampled:
            self.keys = np.stack([np.asarray(e[0], np.uint32)
                                  for _, e in self.sampled])
            self.lams = np.array([e[1] for _, e in self.sampled],
                                 np.float32)
            self.rows_sel = np.array([i for i, _ in self.sampled])
        self.chunk_len = int(np.clip(
            _CHUNK_TARGET_CELLS // max(self.B * n_onus, 1), 64, _CHUNK
        ))
        self._buf: Optional[np.ndarray] = None
        self._base = 0

    def row(self, k: int) -> np.ndarray:
        if self._buf is None or k >= self._base + self._buf.shape[1]:
            from repro.kernels.traffic.ops import sample_arrival_bits

            self._base = k
            buf = np.zeros((self.B, self.chunk_len, self.n))
            if self.sampled and float(self.lams.max()) > 0.0:
                buf[self.rows_sel] = sample_arrival_bits(
                    self.keys, k, self.chunk_len, self.n, self.lams,
                    self.inv_burst, self.packet_bits,
                )
            for i, e in self.fixed:
                buf[i] = e.chunk(k, self.chunk_len)
            self._buf = buf
        return self._buf[:, k - self._base, :]


# ---------------------------------------------------------------------------
# background queues: exact FIFO semantics over the arrival history
# ---------------------------------------------------------------------------


class _BgQueues:
    """Batched per-ONU background FIFOs on a chunked prefix-sum history.

    One segment per (cycle, ONU) arrival, stored as the *cumulative*
    arrival bits per queue (``prefix[b, j, n]`` = bits pushed through
    cycle ``j``). A queue's state is then just its total drained offset
    ``D``: backlog is ``cum - D``, the head-of-line segment is the first
    cycle whose prefix exceeds ``D``, and ``OnuQueue.serve``'s
    sequential drain collapses to one closed-form advance —
    ``D' = D + grant``, plus the reference's ≤1-bit compaction charge,
    which can only trigger at the final partial segment (a genuine
    sub-bit residue requires the budget to die inside that segment), so
    a single snap reproduces the walk exactly.
    """

    def __init__(self, B: int, n_onus: int):
        self.B, self.N = B, n_onus
        self.ptr = np.zeros((B, n_onus), np.int64)   # head segment cycle
        self.drained = np.zeros((B, n_onus))         # incl. snap charges
        self.cum = np.zeros((B, n_onus))             # pushed through k
        self.backlog = np.zeros((B, n_onus))
        self._chunks: Dict[int, np.ndarray] = {}

    def push(self, k: int, bits: np.ndarray):
        cidx, off = divmod(k, _CHUNK)
        buf = self._chunks.get(cidx)
        if buf is None:
            buf = self._chunks[cidx] = np.empty((self.B, _CHUNK, self.N))
        fresh = (self.backlog <= 0.0) & (bits > 0.0)
        np.add(self.cum, bits, out=self.cum)
        buf[:, off, :] = self.cum
        self.backlog = self.cum - self.drained
        # an arrival into an empty queue is the new head; every other
        # event keeps ptr exact (full drains set k+1, partial drains
        # advance it), so head-of-line lookups are pure gathers
        self.ptr = np.where(fresh, k, self.ptr)
        if k and off == 0:
            live = np.where(self.backlog > 0.0, self.ptr, k)
            floor = int(live.min()) // _CHUNK
            for c in [c for c in self._chunks if c < floor]:
                del self._chunks[c]

    def _prefix_at(self, rb, rn, idx) -> np.ndarray:
        """Prefix values at absolute cycle ``idx`` for a flat subset."""
        out = np.zeros(len(rb))
        for cidx, buf in self._chunks.items():
            base = cidx * _CHUNK
            m = (idx >= base) & (idx < base + _CHUNK)
            if m.any():
                out[m] = buf[rb[m], idx[m] - base, rn[m]]
        return out

    _ADV_W = 32                   # window width per advance hop

    def _advance(self, rb, rn, ptr, target, k: int) -> np.ndarray:
        """First cycle ≤ k whose prefix exceeds ``target`` (per queue).

        A drain can cross tens of segments (a near-capacity grant over
        packet-sized arrivals), so the walk gathers a prefix *window*
        per queue and jumps to the first exceeding cycle — one gather +
        argmax per hop instead of one gather per segment. Queues still
        unresolved after a few hops (pathological) fall back to a
        per-queue binary search over their own prefix row.
        """
        # single steps first: the marginal (partially-granted) queue
        # usually crosses 1-2 segments, so (P,) gathers win
        for _ in range(3):
            move = (ptr <= k) & (self._prefix_at(rb, rn, ptr) <= target)
            if not move.any():
                return ptr
            ptr = ptr + move
        # windowed hops for the long walks (a big grant over many
        # packet-sized segments): one gather + argmax per hop
        W = self._ADV_W
        offs = np.arange(W, dtype=np.int64)
        sel = np.nonzero(move)[0]
        sptr = ptr[sel]
        srb, srn, star = rb[sel], rn[sel], target[sel]
        for _ in range(3):
            idx = sptr[:, None] + offs
            valid = idx <= k
            slab = self._prefix_at(
                np.broadcast_to(srb[:, None], idx.shape).ravel(),
                np.broadcast_to(srn[:, None], idx.shape).ravel(),
                np.minimum(idx, k).ravel(),
            ).reshape(idx.shape)
            stop = (slab > star[:, None]) | ~valid
            first = np.argmax(stop, axis=1)
            found = stop[np.arange(len(sptr)), first]
            sptr = np.where(found, sptr + first, sptr + W)
            if found.all():
                ptr[sel] = sptr
                return ptr
        ptr[sel] = sptr
        rows = sel[np.nonzero(~found)[0]]
        for i in rows:
            b, n, t = int(rb[i]), int(rn[i]), target[i]
            j = int(ptr[i])
            while j <= k:
                cidx, off = divmod(j, _CHUNK)
                buf = self._chunks[cidx]
                row = buf[b, off:min(_CHUNK, k + 1 - cidx * _CHUNK), n]
                pos = int(np.searchsorted(row, t, side="right"))
                if pos < len(row):
                    j = cidx * _CHUNK + off + pos
                    break
                j = (cidx + 1) * _CHUNK
            ptr[i] = j
        return ptr

    def hol_key(self) -> np.ndarray:
        """FCFS sort key: the head segment's arrival cycle (cycle times
        are strictly increasing, so ordering by ``ptr`` is ordering by
        head-of-line age — integer argsort, no time lookup)."""
        return np.where(self.backlog > 0.0, self.ptr, _IKEY_INF)

    def serve(self, grants: np.ndarray, k: int):
        # fast path: a grant equal to the whole backlog (the common
        # under-capacity case) drains the queue exactly
        full = (grants > 0.0) & (grants == self.backlog)
        budget = np.where(full, 0.0, grants)
        if full.any():
            self.drained = np.where(full, self.cum, self.drained)
            self.backlog = np.where(full, 0.0, self.backlog)
            self.ptr = np.where(full, k + 1, self.ptr)
        part = budget > CAP_EPS
        if not part.any():
            return
        # partial grants: closed-form drain on the prefix history
        rb, rn = np.nonzero(part)
        target = self.drained[rb, rn] + budget[rb, rn]
        ptr = self._advance(rb, rn, self.ptr[rb, rn], target, k)
        seg_end = self._prefix_at(rb, rn, ptr)
        in_hist = ptr <= k
        snap = in_hist & (seg_end - target <= SEG_EPS)
        drained = np.where(snap, seg_end, target)
        bklg = np.where(in_hist, self.cum[rb, rn] - drained, 0.0)
        low = bklg < 0.5
        drained = np.where(low, self.cum[rb, rn], drained)
        bklg = np.where(low, 0.0, bklg)
        ptr = np.where(low, k + 1, ptr)
        # a snap consumed through the segment at ptr; the new head is
        # the next *arrival* cycle (prefix > drained), not blindly
        # ptr+1, which may be a zero-arrival cycle and would corrupt
        # the FCFS head-of-line age (the reference's restore loop)
        adv = np.nonzero(snap & ~low)[0]
        if len(adv):
            ptr[adv] = self._advance(
                rb[adv], rn[adv], ptr[adv] + 1, drained[adv], k
            )
        self.drained[rb, rn] = drained
        self.ptr[rb, rn] = ptr
        self.backlog[rb, rn] = bklg


# ---------------------------------------------------------------------------
# per-cycle kernels
# ---------------------------------------------------------------------------


def _waterfill(backlog: np.ndarray, hol_fn, cap: np.ndarray) -> np.ndarray:
    """Oldest-first sequential ``take = min(backlog, cap)`` grants,
    expressed as stable argsort + prefix-sum room.

    ``hol_fn`` returns any array that sorts queues by head-of-line age
    (float times, or integer arrival cycles — strictly-increasing cycle
    times make them order-equivalent). It is called lazily: when total
    demand sits at least one bit under capacity, every queue is granted
    its full backlog regardless of age order (room >= suffix >= own
    backlog for every prefix), so the sort — and computing head-of-line
    ages at all — is skipped.
    """
    hard = backlog.sum(axis=1) > cap - 1.0
    if not np.any(hard):
        return backlog.copy()
    grants = backlog.copy()
    hb = backlog[hard]
    hol = hol_fn()[hard]
    order = np.argsort(hol, axis=1, kind="stable")
    rows = np.arange(hb.shape[0])[:, None]
    b_s = hb[rows, order]
    prefix = np.cumsum(b_s, axis=1)
    room = cap[hard][:, None] - (prefix - b_s)
    g_s = np.where(room > CAP_EPS, np.minimum(b_s, room), 0.0)
    g = np.empty_like(g_s)
    g[rows, order] = g_s
    grants[hard] = g
    return grants


class _FLQueues:
    """Batched per-ONU FL FIFOs over the static client layout."""

    def __init__(self, lay: _Layout, B: int, n_onus: int):
        self.lay = lay
        self.B, self.N = B, n_onus
        nU = lay.n_clients
        self.qb = np.zeros((B, nU))
        self.push_key = np.full((B, nU), _IKEY_INF, np.int64)
        self.push_time = np.zeros((B, nU))
        self._bidx = np.arange(B)[:, None]
        # one client per ONU: FIFO heads are the clients themselves, so
        # drains and reductions collapse to direct column scatters
        self.single = lay.single

    def push(self, mask: np.ndarray, bits: np.ndarray, k: int, t: float,
             ready_t: np.ndarray):
        lay = self.lay
        self.qb = np.where(mask, bits, self.qb)
        key = k * np.int64(lay.n_clients + 1) + lay.list_pos
        self.push_key = np.where(mask, key, self.push_key)
        self.push_time = np.where(
            mask, np.maximum(ready_t, t), self.push_time
        )

    def backlog_per_onu(self, mask: Optional[np.ndarray] = None
                        ) -> np.ndarray:
        """Per-ONU FL backlog; ``mask`` (multi-tenant jobs) restricts
        the sum to one job's columns. ``mask=None`` keeps the
        single-tenant paths bitwise (including the aliased identity
        view)."""
        lay = self.lay
        if lay.identity:
            if mask is None:
                return self.qb      # aliased view: callers read only
            return np.where(mask, self.qb, 0.0)
        qb = self.qb if mask is None else np.where(mask, self.qb, 0.0)
        out = np.zeros((self.B, self.N))
        if self.single:
            out[:, lay.seg_onus] = qb
        else:
            out[:, lay.seg_onus] = np.add.reduceat(
                qb, lay.seg_starts, axis=1
            )
        return out

    def _heads(self, mask: Optional[np.ndarray] = None):
        """(head_exists, head_pos, budget_seg aligner) per ONU segment."""
        lay = self.lay
        nU = np.int64(lay.n_clients)
        nonzero = self.qb > 0.0
        if mask is not None:
            nonzero = nonzero & mask
        pk = np.where(nonzero, self.push_key, 0)
        combined = np.where(nonzero, pk * nU + lay.pos, _IKEY_INF)
        m = np.minimum.reduceat(combined, lay.seg_starts, axis=1)
        has = m < _IKEY_INF
        pos = np.where(has, m % nU, 0)
        return has, pos

    def hol_per_onu(self, mask: Optional[np.ndarray] = None
                    ) -> np.ndarray:
        lay = self.lay
        live = self.qb > 0.0
        if mask is not None:
            live = live & mask
        if lay.identity:
            return np.where(live, self.push_time, np.inf)
        out = np.full((self.B, self.N), np.inf)
        if self.single:
            out[:, lay.seg_onus] = np.where(
                live, self.push_time, np.inf
            )
            return out
        has, pos = self._heads(mask)
        times = np.where(
            has, self.push_time[self._bidx, pos], np.inf
        )
        out[:, lay.seg_onus] = times
        return out

    def serve(self, grants_onu: np.ndarray, backlog_onu: np.ndarray,
              mask: Optional[np.ndarray] = None):
        """Drain FIFO heads per ONU, reproducing ``OnuQueue.serve``'s
        1-bit segment compaction (which also charges the grant).

        With ``mask`` (multi-tenant jobs) the grant is one job's share
        and only that job's columns drain — ``backlog_onu`` must then
        be the same-masked per-ONU backlog."""
        lay = self.lay
        if self.single:
            budget = (grants_onu if lay.identity
                      else grants_onu[:, lay.onu])
            act = (budget > CAP_EPS) & (self.qb > 0.0)
            if mask is not None:
                act = act & mask
            take = np.where(act, np.minimum(budget, self.qb), 0.0)
            drop = act & (self.qb - take <= SEG_EPS)
            self.qb = np.where(drop, 0.0, self.qb - take)
            return
        full = (grants_onu > 0.0) & (grants_onu == backlog_onu)
        if np.any(full):
            zero = full[:, lay.onu]
            if mask is not None:
                zero = zero & mask
            self.qb = np.where(zero, 0.0, self.qb)
        budget = np.where(full, 0.0, grants_onu)[:, lay.seg_onus]
        while True:
            has, pos = self._heads(mask)
            srv = has & (budget > CAP_EPS)
            if not np.any(srv):
                break
            hq = self.qb[self._bidx, pos]
            take = np.where(srv, np.minimum(budget, hq), 0.0)
            resid = np.where(srv, hq - take, np.inf)
            drop = srv & (resid <= SEG_EPS)
            newq = np.where(drop, 0.0, hq - take)
            rb, rs = np.nonzero(srv)
            self.qb[rb, pos[rb, rs]] = newq[rb, rs]
            charge = np.where(drop, resid, 0.0)
            budget = np.maximum(budget - take - charge, 0.0)


def _credit(rem, done, done_t, drained, t_done: float):
    """Attribute served FL bits to the clients that own them.

    ``drained`` is each client's own queue drain this cycle
    (``qb_before - qb_after``) — ownership attribution, mirroring the
    reference's owner-tagged segments: a client is done exactly when its
    queued update has fully crossed the wire. (The segment-compaction
    charge zeroes a queue together with its sub-1-bit remnant, so
    "queue empty" and "remaining ≤ 1 bit" coincide on both backends.)
    """
    new_rem = rem - drained
    newly = ~done & (drained > 0.0) & (new_rem <= EPS_BITS)
    rem = np.where(newly, 0.0, np.maximum(new_rem, 0.0))
    done = done | newly
    done_t = np.where(newly, t_done, done_t)
    return rem, done, done_t


def _slot_grants(slot_arrays, backlog_onu, t: float, cyc: float,
                 cap: np.ndarray, n_onus: int) -> np.ndarray:
    """SlicedDBA slot grants: overlap * slice rate, capped by the FL
    backlog and the (sequentially spent) per-row cycle capacity
    ``cap`` — the wavelength capacity, or the row's waterfilled CPS
    share."""
    ts, te, onu_idx, rate, valid = slot_arrays
    B, S = ts.shape
    te_g = te + cyc
    active = valid & (ts < t + cyc) & (te_g > t)
    if not np.any(active):
        return np.zeros((B, n_onus))
    overlap = np.minimum(te_g, t + cyc) - np.maximum(ts, t)
    want = rate * np.maximum(overlap, 0.0)
    bidx = np.arange(B)[:, None]
    want = np.minimum(want, backlog_onu[bidx, onu_idx])
    want = np.where(active & (want > 0.0), want, 0.0)
    prefix = np.cumsum(want, axis=1)
    grants = np.minimum(
        want, np.maximum(cap[:, None] - (prefix - want), 0.0)
    )
    out = np.zeros((B, n_onus))
    np.add.at(out, (np.broadcast_to(bidx, (B, S)), onu_idx), grants)
    return out


def _job_grants_fcfs(fl: _FLQueues, ctx, cap_fl: np.ndarray, t: float):
    """Per-job FCFS grant plan: split the FL residual capacity across
    jobs by the fairness policy on per-job total backlog, then
    oldest-first waterfill each job's share over its own queues.

    Returns ``(mask, grants_onu, backlog_onu)`` triples, one per job.
    The inter-job split is per PON row — the CPS coupling stays at the
    (case, pon) level because background demand entangles the rows
    before jobs are distinguishable.
    """
    masks = ctx["masks"]
    bos = [fl.backlog_per_onu(m) for m in masks]
    demand = np.stack([bo.sum(axis=1) for bo in bos], axis=1)
    shares = job_fair_split(demand, cap_fl, ctx["fairness"],
                            weights=ctx["weights"],
                            slack=ctx["deadlines"] - t)
    return [
        (m, _waterfill(bos[j], functools.partial(fl.hol_per_onu, m),
                       shares[:, j]), bos[j])
        for j, m in enumerate(masks)
    ]


def _job_grants_bs(slot_arrays, fl: _FLQueues, ctx, t: float, cyc: float,
                   cap: np.ndarray, n_onus: int,
                   cps_cap: Optional[float], n_pons: int):
    """Per-job SlicedDBA grant plan.

    Slot wants are computed exactly like ``_slot_grants`` (overlap *
    slice rate, capped by the owning job's backlog at the slot's ONU),
    aggregated into per-(row, job) demand for the fairness split —
    re-capped by the CPS waterfill over the flattened ``(pon, job)``
    shares of each case when a CPS rate binds — and each job's slots
    then spend prefix room within the job's own share.
    """
    ts, te, onu_idx, rate, valid, sjob = slot_arrays
    B, S = ts.shape
    masks = ctx["masks"]
    J = len(masks)
    bos = [fl.backlog_per_onu(m) for m in masks]
    te_g = te + cyc
    active = valid & (ts < t + cyc) & (te_g > t)
    # best-effort tail: inter-job fairness / CPS re-capping can
    # throttle a job below its scheduled slice rate, leaving backlog
    # when its window closes; an expired slot keeps requesting at the
    # slice rate so contended bits drain instead of starving
    tail = valid & (te_g <= t)
    if not np.any(active | tail):
        zero = np.zeros((B, n_onus))
        return [(m, zero, bos[j]) for j, m in enumerate(masks)]
    overlap = np.minimum(te_g, t + cyc) - np.maximum(ts, t)
    want = np.where(active, rate * np.maximum(overlap, 0.0),
                    np.where(tail, rate * cyc, 0.0))
    bidx = np.arange(B)[:, None]
    want = np.minimum(want, np.stack(bos)[sjob, bidx, onu_idx])
    want = np.where(want > 0.0, want, 0.0)
    demand = np.stack(
        [np.where(sjob == j, want, 0.0).sum(axis=1) for j in range(J)],
        axis=1,
    )
    shares = job_fair_split(demand, cap, ctx["fairness"],
                            weights=ctx["weights"],
                            slack=ctx["deadlines"] - t)
    if cps_cap is not None:
        # the (case, pon, job) waterfill: a case's bs rows are its
        # n_pons consecutive rows, so reshaping shares pon-major /
        # job-minor puts each case's P*J slices in one waterfill row
        shares = cps_waterfill(
            shares.reshape(-1, n_pons * J), cps_cap
        ).reshape(B, J)
    plan = []
    for j, m in enumerate(masks):
        wj = np.where(sjob == j, want, 0.0)
        prefix = np.cumsum(wj, axis=1)
        gj = np.minimum(
            wj, np.maximum(shares[:, j:j + 1] - (prefix - wj), 0.0)
        )
        out = np.zeros((B, n_onus))
        np.add.at(out, (np.broadcast_to(bidx, (B, S)), onu_idx), gj)
        plan.append((m, out, bos[j]))
    return plan


# ---------------------------------------------------------------------------
# phase runner
# ---------------------------------------------------------------------------


def _run_phase(cfg, lay: _Layout, rem_init, ready_t,
               stream: Optional[_Stream], mode: str, slot_arrays=None,
               max_t: float = 600.0, fill_unfinished: bool = True,
               cap_row: Optional[np.ndarray] = None,
               cps_cap: Optional[float] = None, n_pons: int = 1,
               deadline_row: Optional[np.ndarray] = None,
               outage_row: Optional[np.ndarray] = None,
               collector=None, phase_label: str = "",
               jobs_ctx=None):
    """One transfer phase for a (policy-homogeneous) batch of rows.

    Rows are ``(case, pon)`` pairs (case-major); ``cap_row`` is each
    row's wavelength cycle capacity and ``cps_cap`` the per-cycle CPS
    budget shared by the ``n_pons`` consecutive rows of one case —
    when set, each cycle first waterfills the CPS capacity across a
    case's per-PON demands and every row allocates within its share.

    Returns ``(done_t, rem)``: per-client completion times
    ``(B, n_clients)`` (NaN for clients not in a case's workload) and
    the bits still unserved when the phase ended. With
    ``fill_unfinished`` (the legacy behaviour) clients cut off at
    ``max_t`` get ``t + propagation`` as their completion time; the
    timeline's deadline mode passes False and reads ``rem`` instead
    (missed-deadline bits defer to the next round). ``stream`` is the
    background arrival stream (unused — and may be None — in "bs"
    mode).

    ``deadline_row`` (``(B,)`` float, ``inf`` = no deadline) gives each
    row its OWN time cutoff: cycles starting at or past a row's
    deadline grant it nothing (exactly the scalar-deadline rule ``t <
    deadline``, applied per row), unfinished clients of deadlined rows
    keep their ``rem``, and ``inf`` rows fall back to the
    ``max_t``-capped ``fill_unfinished`` behaviour. All ``n_pons``
    rows of one case must share a deadline (the CPS waterfill couples
    them).

    ``outage_row`` (``(B, 2)`` float ``[start, end)``, ``inf`` rows =
    never) masks each row's capacity to zero for cycles starting
    inside its outage window (``start <= t < end`` on the cycle-start
    clock, exactly the deadline comparison): the ONU/link is dark —
    arrivals still queue, nothing is granted — and service resumes
    after the window. ``None`` is bitwise identical to all-``inf``.

    ``collector`` (``repro.obs.Collector``) turns on per-cycle metrics
    over the ``(B,)`` row axis — backlog depths, grant totals, cycle
    utilization, waterfill residuals, CPS want/eff — as a
    ``PhaseStats`` registered under ``phase_label``.  With
    ``collector=None`` the instrumentation is a single identity check
    per cycle and every output is bitwise unchanged: the accumulators
    only *read* arrays the phase already computed.

    ``jobs_ctx`` (multi-tenant sweeps) carries the per-row job masks,
    weights, deadlines and the fairness policy: each cycle's FL
    capacity is first split across jobs (``_job_grants_fcfs`` /
    ``_job_grants_bs``) and every job drains only its own queues
    within its share. ``None`` (single-tenant) keeps the grant/serve
    sequence bitwise unchanged.
    """
    B = rem_init.shape[0]
    N = cfg.n_onus
    cyc = cfg.cycle_time_s
    prop = cfg.propagation_s
    if cap_row is None:
        cap_row = np.full((B,), cfg.line_rate_bps * cyc * cfg.efficiency)
    cap_col = cap_row
    if deadline_row is None:
        cap_t = None
        tmax = max_t
    else:
        cap_t = np.where(np.isfinite(deadline_row), deadline_row, max_t)
        tmax = float(cap_t.max())

    rem = rem_init.copy()
    done = ~lay.part | (rem <= 0.0)
    done_t = np.full(rem.shape, np.nan)
    fl = _FLQueues(lay, B, N)
    # Under the Sliced DBA the FL slice is served *first*; background only
    # gets the residual capacity and never feeds back into the FL grants,
    # so the BS phase needs no background simulation at all (this is the
    # paper's isolation claim, and it is exact — not an approximation).
    use_bg = mode == "fcfs"
    bg = _BgQueues(B, N) if use_bg else None

    obs = None
    if collector is not None:
        obs = collector.phase(phase_label or mode, B)
        ob_bg_depth = ob_fl_depth = ob_bg_g = ob_fl_g = None
        ob_cps_w = ob_cps_e = None

    n_left = int(np.count_nonzero(~done & lay.part))
    waiting = lay.part & ~done
    n_wait = int(np.count_nonzero(waiting))
    t = 0.0
    k = 0
    cap_cyc = cap_col
    while t < tmax and n_left:
        if cap_t is not None:
            alive = cap_t > t
            if not np.any(alive[:, None] & lay.part & ~done):
                break
            cap_cyc = np.where(alive, cap_col, 0.0)
        if outage_row is not None:
            base = cap_cyc if cap_t is not None else cap_col
            dark = (outage_row[:, 0] <= t) & (t < outage_row[:, 1])
            cap_cyc = np.where(dark, 0.0, base)
        if use_bg:
            bg.push(k, stream.row(k))
        if n_wait:
            # a waiting client can't already be done (ownership credit
            # requires a pushed queue), so part & ~done is implied
            newly = waiting & (ready_t <= t + cyc)
            n_new = int(np.count_nonzero(newly))
            if n_new:
                waiting &= ~newly
                n_wait -= n_new
                fl.push(newly, rem, k, t, ready_t)

        # pushed & undone clients hold exactly the nonzero FL queues, so
        # the idle stretch before the first ready client skips FL work
        if n_left > n_wait:
            backlog_onu = fl.backlog_per_onu()
            if obs is not None:
                ob_fl_depth = backlog_onu.sum(axis=1)
                if use_bg:
                    ob_bg_depth = bg.backlog.sum(axis=1)
            plan = None
            if mode == "fcfs":
                if cps_cap is None:
                    eff = cap_cyc
                else:
                    want = np.minimum(
                        bg.backlog.sum(axis=1) + backlog_onu.sum(axis=1),
                        cap_cyc,
                    )
                    eff = cps_waterfill(
                        want.reshape(-1, n_pons), cps_cap
                    ).reshape(-1)
                    if obs is not None:
                        ob_cps_w, ob_cps_e = want, eff
                bg_grants = _waterfill(bg.backlog, bg.hol_key, eff)
                cap_fl = eff - bg_grants.sum(axis=1)
                if jobs_ctx is None:
                    fl_grants = _waterfill(
                        backlog_onu, fl.hol_per_onu, cap_fl
                    )
                else:
                    plan = _job_grants_fcfs(fl, jobs_ctx, cap_fl, t)
                    fl_grants = sum(g for _, g, _ in plan)
            elif jobs_ctx is None:
                fl_grants = _slot_grants(slot_arrays, backlog_onu, t,
                                         cyc, cap_cyc, N)
                if cps_cap is not None:
                    want = fl_grants.sum(axis=1)
                    eff = cps_waterfill(
                        want.reshape(-1, n_pons), cps_cap
                    ).reshape(-1)
                    if obs is not None:
                        ob_cps_w, ob_cps_e = want, eff
                    if np.any(eff < want):
                        fl_grants = _slot_grants(
                            slot_arrays, backlog_onu, t, cyc, eff, N
                        )
            else:
                plan = _job_grants_bs(slot_arrays, fl, jobs_ctx, t, cyc,
                                      cap_cyc, N, cps_cap, n_pons)
                fl_grants = sum(g for _, g, _ in plan)
            if obs is not None:
                ob_fl_g = fl_grants.sum(axis=1)
                if use_bg:
                    ob_bg_g = bg_grants.sum(axis=1)
            if use_bg:
                bg.serve(bg_grants, k)
            if np.any(fl_grants > 0.0):
                prev_qb = fl.qb.copy()
                if plan is None:
                    fl.serve(fl_grants, backlog_onu)
                else:
                    for mask_j, g_j, bo_j in plan:
                        if np.any(g_j > 0.0):
                            fl.serve(g_j, bo_j, mask_j)
                rem, done, done_t = _credit(
                    rem, done, done_t, prev_qb - fl.qb, t + cyc + prop
                )
                n_left = int(np.count_nonzero(~done & lay.part))
        elif use_bg:
            if cps_cap is None:
                eff = cap_cyc
            else:
                want = np.minimum(bg.backlog.sum(axis=1), cap_cyc)
                eff = cps_waterfill(
                    want.reshape(-1, n_pons), cps_cap
                ).reshape(-1)
                if obs is not None:
                    ob_cps_w, ob_cps_e = want, eff
            bg_grants = _waterfill(bg.backlog, bg.hol_key, eff)
            if obs is not None:
                ob_bg_depth = bg.backlog.sum(axis=1)
                ob_bg_g = bg_grants.sum(axis=1)
            bg.serve(bg_grants, k)
        if obs is not None:
            obs.cycle(cap_cyc, bg_backlog=ob_bg_depth,
                      fl_backlog=ob_fl_depth, bg_grants=ob_bg_g,
                      fl_grants=ob_fl_g, cps_want=ob_cps_w,
                      cps_eff=ob_cps_e)
            ob_bg_depth = ob_fl_depth = ob_bg_g = ob_fl_g = None
            ob_cps_w = ob_cps_e = None
        t += cyc
        k += 1

    if cap_t is not None:
        # per-row deadlines: only deadline-free (inf) rows time out at
        # ``max_t`` with filled completion times; deadlined rows report
        # their unserved ``rem`` instead
        left = lay.part & ~done & ~np.isfinite(deadline_row)[:, None]
        done_t = np.where(left, t + prop, done_t)
    elif fill_unfinished:
        left = lay.part & ~done
        done_t = np.where(left, t + prop, done_t)
    return done_t, rem


# ---------------------------------------------------------------------------
# sweep driver
# ---------------------------------------------------------------------------


def _case_bg_rate(case: SweepCase, cfg, t_round_hint: float) -> float:
    clients = case.workload.clients
    n = len(clients)
    training_rate = (
        n * (case.workload.model_bits
             + float(np.mean([c.m_ud_bits for c in clients])))
        / max(t_round_hint, 1e-9)
    )
    return background_rate_for_load(
        case.load, cfg.line_rate_bps, training_rate
    )


@functools.lru_cache(maxsize=512)
def _bs_slice_cached(profiles: tuple, capacity_bps: float):
    if not profiles:
        return None, slots_to_arrays([])
    spec = compute_slice(
        list(profiles), t_current=0.0, t_round=0.0,
        capacity_bps=capacity_bps, h=1,
    )
    slots = schedule_slots(list(profiles), spec, round_start=0.0)
    return spec, slots_to_arrays(slots)


def _bs_slice(profiles: List[ClientProfile], capacity_bps: float):
    """Per-segment slice spec + slot arrays (empty segments allowed —
    a PON row of a multi-PON case may hold no clients).

    Memoized on the (frozen, hashable) profile tuple: the bs downstream
    is analytic, so a folded/sequential timeline re-derives the *same*
    slice spec and slot schedule every round — the profile shows the
    repeated ``compute_slice``/``schedule_slots``/``slots_to_arrays``
    work on every phase entry.  Callers treat the returned arrays as
    immutable (``_stack_slots`` only reads them).
    """
    try:
        return _bs_slice_cached(tuple(profiles), float(capacity_bps))
    except TypeError:             # unhashable profile type: uncached
        return _bs_slice_cached.__wrapped__(
            tuple(profiles), float(capacity_bps))


def _stack_slots(per_row, n_onus: int):
    """Pad per-row slot arrays to a common (B, S) shape."""
    S = max(
        (len(a["client_id"]) for _, a in per_row), default=0
    ) or 1
    B = len(per_row)
    ts = np.full((B, S), np.inf)
    te = np.full((B, S), -np.inf)
    onu = np.zeros((B, S), np.int64)
    rate = np.zeros((B, 1))
    valid = np.zeros((B, S), bool)
    for b, (spec, a) in enumerate(per_row):
        s = len(a["client_id"])
        if s:
            ts[b, :s] = a["t_start"]
            te[b, :s] = a["t_end"]
            onu[b, :s] = a["client_id"] % n_onus
            valid[b, :s] = True
        if spec is not None:
            rate[b, 0] = spec.bandwidth_bps
    return ts, te, onu, rate, valid


def _stack_slots_jobs(per_row, n_onus: int):
    """Pad per-(row, job) slot arrays to a common ``(B, S)`` shape.

    ``per_row[b]`` is a list of ``(job_index, spec, arrays)`` triples
    in job order. Unlike ``_stack_slots``, ``rate`` is per-slot — each
    job carves its own slice, so one row holds several bandwidths —
    and ``sjob`` binds every slot to its owning job (padding binds to
    job 0 with ``valid`` False, contributing zero demand).
    """
    B = len(per_row)
    S = max(
        (sum(len(a["client_id"]) for _, _, a in row) for row in per_row),
        default=0,
    ) or 1
    ts = np.full((B, S), np.inf)
    te = np.full((B, S), -np.inf)
    onu = np.zeros((B, S), np.int64)
    rate = np.zeros((B, S))
    valid = np.zeros((B, S), bool)
    sjob = np.zeros((B, S), np.int64)
    for b, row in enumerate(per_row):
        s0 = 0
        for j, spec, a in row:
            s = len(a["client_id"])
            if not s:
                continue
            ts[b, s0:s0 + s] = a["t_start"]
            te[b, s0:s0 + s] = a["t_end"]
            onu[b, s0:s0 + s] = a["client_id"] % n_onus
            rate[b, s0:s0 + s] = spec.bandwidth_bps
            valid[b, s0:s0 + s] = True
            sjob[b, s0:s0 + s] = j
            s0 += s
    return ts, te, onu, rate, valid, sjob


def _sweep_topology(cases: Sequence[SweepCase]) -> MultiPonTopology:
    """The one topology shared by every case (None ≡ trivial)."""
    topos = {case.topology for case in cases}
    topos.discard(None)
    if len(topos) > 1:
        raise ValueError("sweep cases must share one MultiPonTopology")
    if not topos:
        return MultiPonTopology()
    topo = topos.pop()
    if any(case.topology is None for case in cases) and not topo.trivial:
        raise ValueError("sweep cases must share one MultiPonTopology")
    return topo


def _check_jobs_cases(cases: Sequence[SweepCase]):
    """Every case carries jobs partitioning its workload, or none do."""
    for b, case in enumerate(cases):
        if case.jobs is None:
            raise ValueError(
                f"cases[{b}] has no jobs but the sweep carries jobs; "
                "give every case a jobs tuple (or none)"
            )
        try:
            validate_case_jobs(case.jobs, case.workload)
        except ValueError as e:
            raise ValueError(f"cases[{b}]: {e}") from None


def _multi_job_fairness(cases: Sequence[SweepCase], ul_deadline_s,
                        ul_outage_s) -> str:
    """Validate a genuinely multi-tenant sweep; returns its fairness."""
    if ul_deadline_s is not None or ul_outage_s is not None:
        raise ValueError(
            "multi-job sweeps take per-job deadlines "
            "(JobSpec.deadline_s under fairness='deadline'), not "
            "round-level ul_deadline_s/ul_outage_s"
        )
    fair = {case.fairness for case in cases}
    if len(fair) != 1:
        raise ValueError(
            f"sweep cases must share one fairness policy; "
            f"got {sorted(fair)}"
        )
    fairness = fair.pop()
    if fairness not in FAIRNESS_POLICIES:
        raise ValueError(
            f"unknown fairness policy {fairness!r}; "
            f"have {FAIRNESS_POLICIES}"
        )
    for b, case in enumerate(cases):
        if case.dl_arrivals is not None or case.ul_arrivals is not None:
            raise ValueError(
                f"cases[{b}]: injected arrivals are a single-tenant "
                "parity hook; multi-job cases draw counter streams"
            )
        if case.no_dl_ids:
            raise ValueError(
                f"cases[{b}]: no_dl_ids (deadline carriers) do not "
                "compose with multi-job cases"
            )
    return fairness


def _record_job_uploads(collector, case: SweepCase, res):
    """Per-job upload-time recording (``<policy>/job<id>`` keys)."""
    if collector is None or not res.job_stats:
        return
    ul = res.ul_done
    for job in case.jobs:
        times = [
            ul[cid] for cid in job.clients
            if cid in ul and np.isfinite(ul[cid])
        ]
        if times:
            collector.record_upload_times(
                f"{case.policy}/job{job.job_id}", case.load, times
            )


def _single_job_sweep(cfg, cases: Sequence[SweepCase], **kw):
    """Degenerate jobs sweeps — every case has exactly one job — run on
    the single-tenant path (bitwise identical to a no-jobs sweep of the
    same workloads, preserving the PR 8 pins) and get their
    ``job_stats`` attached post-hoc."""
    from repro.net.sim import FLRoundWorkload

    norm = []
    for case in cases:
        job = case.jobs[0]
        wl = case.workload
        if float(job.model_bits) != float(wl.model_bits):
            wl = FLRoundWorkload(
                clients=wl.clients, model_bits=float(job.model_bits),
                t_aggregate=wl.t_aggregate,
            )
        norm.append(replace(case, jobs=None, workload=wl))
    results = _round_sweep(cfg, norm, **kw)
    topo = _sweep_topology(list(cases))
    for case, res in zip(cases, results):
        res.job_stats = compute_job_stats(
            case.jobs, res.ul_done, cfg.n_onus, topo.n_pons
        )
        _record_job_uploads(kw.get("collector"), case, res)
    return results


def _round_sweep(cfg, cases: Sequence[SweepCase],
                 t_round_hint: float = 10.0,
                 max_t: float = 600.0,
                 ul_deadline_s=None,
                 ul_outage_s=None,
                 collector=None,
                 backend: Optional[str] = None,
                 ) -> List["RoundResult"]:
    """Simulate every sweep case as one stacked array simulation.

    Semantics match ``repro.net.sim.simulate_round``'s reference
    implementation per case (property-tested); both backends consume the
    same counter-based arrival stream keyed by (seed, phase,
    stream_round, pon), so seeded results agree across backends and
    batch compositions unless arrivals are injected.

    A shared ``SweepCase.topology`` stacks every case over its
    ``n_pons`` wavelength segments: the simulation rows become
    ``(case, pon)`` pairs over per-PON ONU columns, each row under its
    own wavelength capacity, coupled per cycle by the CPS waterfill
    (``repro.net.multi_pon``) when the topology carries a CPS rate.
    With injected arrival matrices the columns are global ONUs
    (``n_pons * cfg.n_onus`` wide) and each row replays its own PON's
    slice.

    ``ul_deadline_s`` cuts the upload phase at a round deadline: clients
    still transmitting then keep their unserved bits in the result's
    ``ul_remaining`` (their ``ul_done`` is NaN) — the multi-round
    timeline defers those bits to the next round. A scalar applies to
    every case (the PR 3 behaviour, bitwise unchanged); a sequence
    gives each case its OWN deadline (``None``/``inf`` entries =
    no deadline for that case) — the timeline's folded drop/partial
    rows and the async mode's per-case k-th-completion cutoffs.

    ``ul_outage_s`` injects per-case upstream ONU/link outage windows
    (``repro.faults``): a sequence of ``None`` (no outage), ``(2,)``
    ``[start, end)`` (every PON of the case), or ``(n_pons, 2)``
    per-PON windows, phase-relative seconds like the deadlines. During
    a window the affected rows' cycle capacity is masked to zero (the
    link is dark; arrivals still queue) — one more per-row array axis,
    exactly like the per-case deadline column. ``None`` (the default)
    is bitwise identical to all-``inf`` windows.

    ``collector`` (``repro.obs.Collector``, optional) records per-phase
    cycle metrics inside ``_run_phase`` plus per-case upload-completion
    times keyed by (policy, load); ``collector=None`` (the default) is
    bitwise identical to an uninstrumented run.

    ``backend`` selects the phase engine: ``None``/``"numpy"`` is the
    host cycle loop (the default, bitwise-pinned); ``"jit"`` compiles
    each phase to one jax device program with the traffic sampler fused
    in (``repro.kernels.ponsim``) — parity with numpy at rtol 1e-6,
    with a transparent numpy re-run for phases whose background state
    outgrows the device ring (see ``ops.run_phase_device``).  The jit
    backend rejects injected arrival matrices and ``collector``
    instrumentation.
    """
    from repro.net.sim import RoundResult  # lazy: sim imports us lazily
    from repro.obs.trace import maybe_span

    cases = list(cases)
    if backend not in (None, "numpy", "jit"):
        raise ValueError(f"unknown engine backend {backend!r}")
    use_jit = backend == "jit"
    if use_jit:
        if collector is not None:
            raise ValueError(
                "backend='jit' does not support collector "
                "instrumentation; use the numpy backend"
            )
        if any(case.dl_arrivals is not None
               or case.ul_arrivals is not None for case in cases):
            raise ValueError(
                "backend='jit' does not support injected arrival "
                "matrices; use the numpy backend"
            )
    jobs_any = any(case.jobs is not None for case in cases)
    fairness = None
    if jobs_any:
        _check_jobs_cases(cases)
        if not any(len(case.jobs) > 1 for case in cases):
            return _single_job_sweep(
                cfg, cases, t_round_hint=t_round_hint, max_t=max_t,
                ul_deadline_s=ul_deadline_s, ul_outage_s=ul_outage_s,
                collector=collector, backend=backend,
            )
        fairness = _multi_job_fairness(cases, ul_deadline_s, ul_outage_s)
        if use_jit:
            # kernels/ponsim carries no job axis: multi-job sweeps fall
            # back to the numpy engine transparently (DESIGN §12)
            use_jit = False
    topo = _sweep_topology(cases)
    P = topo.n_pons
    n_local = cfg.n_onus
    total_onus = P * n_local
    for b, case in enumerate(cases):
        if case.policy not in ("fcfs", "bs"):
            raise ValueError(f"unknown policy {case.policy!r}")
        if case.policy == "bs":
            bad = [c.client_id for c in case.workload.clients
                   if c.client_id >= total_onus]
            if bad:
                raise ValueError(
                    "bs policy requires client_id < n_onus * n_pons; "
                    f"got {bad}"
                )
        for name in ("dl_arrivals", "ul_arrivals"):
            arr = getattr(case, name)
            if arr is None:
                continue
            a = np.asarray(arr, np.float64)
            if a.ndim != 2 or a.shape[1] != total_onus:
                raise ValueError(
                    f"cases[{b}].{name} must be 2-D with "
                    f"n_pons * n_onus = {total_onus} columns; "
                    f"got shape {np.shape(arr)}"
                )
    lay = _layout_for(cases, n_local, P)
    B = len(cases)
    R = B * P
    row_case = np.repeat(np.arange(B), P)
    row_pon = np.tile(np.arange(P), B)
    rates_pon = topo.rates(cfg)
    cap_row = np.tile(topo.capacity_bits(cfg), B)
    cps_cap = topo.cps_capacity_bits(cfg)
    per_onu_rate = np.stack([
        pon_bg_rates(c.workload.clients, c.workload.model_bits, c.load,
                     cfg, topo, t_round_hint,
                     model_bits_by_client=(
                         None if c.jobs is None else
                         {cid: float(job.model_bits)
                          for job in c.jobs for cid in job.clients}
                     ))
        for c in cases
    ])                                                  # (B, n_pons)
    per_case_dl = isinstance(ul_deadline_s, (list, tuple, np.ndarray))
    if per_case_dl:
        dl_case = np.array(
            [np.inf if d is None else float(d) for d in ul_deadline_s],
            np.float64,
        )
        if dl_case.shape != (B,):
            raise ValueError(
                f"per-case ul_deadline_s needs {B} entries; "
                f"got shape {dl_case.shape}"
            )
        dl_row = np.repeat(dl_case, P)
        ul_max_t = max_t
    else:
        dl_case = dl_row = None
        ul_max_t = max_t if ul_deadline_s is None else ul_deadline_s
    if ul_outage_s is not None:
        if len(ul_outage_s) != B:
            raise ValueError(
                f"per-case ul_outage_s needs {B} entries; "
                f"got {len(ul_outage_s)}"
            )
        outage_row = np.full((B, P, 2), np.inf)
        for b, win in enumerate(ul_outage_s):
            if win is None:
                continue
            arr = np.asarray(win, np.float64)
            if arr.shape == (2,):
                arr = np.broadcast_to(arr, (P, 2))
            if arr.shape != (P, 2):
                raise ValueError(
                    f"ul_outage_s[{b}] must be (2,) or ({P}, 2); "
                    f"got shape {arr.shape}"
                )
            outage_row[b] = arr
        outage_row = outage_row.reshape(R, 2)
        if not np.isfinite(outage_row[:, 0]).any():
            outage_row = None       # all-inf: keep the bitwise-off path
    else:
        outage_row = None
    no_dl = np.zeros((R, lay.n_clients), bool)
    for b, case in enumerate(cases):
        if case.no_dl_ids:
            skip = list(case.no_dl_ids)
            for p in range(P):
                no_dl[b * P + p] = np.isin(lay.cid_of[p], skip)
    no_dl &= lay.part

    # multi-tenant jobs: the per-row job axis next to the slot layout —
    # every live column binds to its owning job (jcol), carries its
    # job's model bits (mb), and every row knows its jobs' weights and
    # soft deadlines for the fairness split. Sweeps mixing job counts
    # pad to the max J with zero-demand phantom jobs, which every
    # fairness policy grants nothing.
    jobs_info = None
    if jobs_any:
        J = max(len(case.jobs) for case in cases)
        jcol = np.full((R, lay.n_clients), -1, np.int64)
        mb_col = np.zeros((R, lay.n_clients))
        w_row = np.ones((R, J))
        dl_jrow = np.full((R, J), np.inf)
        for b, case in enumerate(cases):
            jidx_of = {cid: j for j, job in enumerate(case.jobs)
                       for cid in job.clients}
            mb_of = {cid: float(job.model_bits) for job in case.jobs
                     for cid in job.clients}
            for p in range(P):
                r = b * P + p
                for col in np.nonzero(lay.part[r])[0]:
                    cid = int(lay.cid_of[p, col])
                    jcol[r, col] = jidx_of[cid]
                    mb_col[r, col] = mb_of[cid]
            for j, job in enumerate(case.jobs):
                w_row[b * P:(b + 1) * P, j] = float(job.weight)
                if job.deadline_s is not None:
                    dl_jrow[b * P:(b + 1) * P, j] = float(job.deadline_s)
        jobs_info = {"J": J, "jcol": jcol, "mb": mb_col, "w": w_row,
                     "dl": dl_jrow, "fairness": fairness}

    def jobs_ctx_for(sel):
        """Row-sliced per-job phase context (None when single-tenant)."""
        if jobs_info is None:
            return None
        jc = jobs_info["jcol"][sel]
        return {
            "masks": [jc == j for j in range(jobs_info["J"])],
            "weights": jobs_info["w"][sel],
            "deadlines": jobs_info["dl"][sel],
            "fairness": jobs_info["fairness"],
        }

    def providers(sel, phase):
        from repro.kernels.traffic.ops import make_stream_key

        entries = []
        for r in sel:
            b, p = int(row_case[r]), int(row_pon[r])
            case = cases[b]
            injected = (case.dl_arrivals if phase == "dl"
                        else case.ul_arrivals)
            if injected is not None:
                if P > 1:
                    arr = np.asarray(injected, np.float64)
                    if arr.ndim != 2 or arr.shape[1] != total_onus:
                        raise ValueError(
                            f"arrivals must be (n_cycles, {total_onus})"
                        )
                    injected = arr[:, p * n_local:(p + 1) * n_local]
                entries.append(_CaseFixed(injected, n_local))
            else:
                entries.append((
                    make_stream_key(case.seed, 0 if phase == "dl" else 1,
                                    case.stream_round, p),
                    burst_lambda(per_onu_rate[b, p], cfg.cycle_time_s,
                                 PACKET_BITS, cfg.bg_burst_packets),
                ))
        return _Stream(entries, n_local, 1.0 / cfg.bg_burst_packets)

    def stream_params(sel, phase):
        """Raw (keys, lams) of ``providers(sel, phase)``'s sampled
        entries — the jit backend fuses the sampler on-device instead
        of going through a host ``_Stream``."""
        from repro.kernels.traffic.ops import make_stream_key

        ks = np.empty((len(sel), 2), np.uint32)
        ls = np.empty((len(sel),), np.float32)
        for i, r in enumerate(sel):
            b, p = int(row_case[r]), int(row_pon[r])
            case = cases[b]
            ks[i] = make_stream_key(case.seed, 0 if phase == "dl" else 1,
                                    case.stream_round, p)
            ls[i] = burst_lambda(per_onu_rate[b, p], cfg.cycle_time_s,
                                 PACKET_BITS, cfg.bg_burst_packets)
        return ks, ls

    def run_phase(sub, rem0, ready, sel, phase, mode, **kw):
        """One phase on the selected backend; the jit path falls back
        to numpy when the device program reports an inexact bg walk."""
        if use_jit:
            from repro.kernels.ponsim.ops import run_phase_device

            keys_ = lams_ = None
            if mode == "fcfs":
                keys_, lams_ = stream_params(sel, phase)
            out = run_phase_device(
                cfg, sub, rem0, ready, mode, keys=keys_, lams=lams_,
                slot_arrays=kw.get("slot_arrays"), max_t=kw["max_t"],
                fill_unfinished=kw.get("fill_unfinished", True),
                cap_row=kw.get("cap_row"), cps_cap=kw.get("cps_cap"),
                n_pons=kw.get("n_pons", 1),
                deadline_row=kw.get("deadline_row"),
                outage_row=kw.get("outage_row"),
            )
            if out is not None:
                return out
        stream = providers(sel, phase) if mode == "fcfs" else None
        return _run_phase(cfg, sub, rem0, ready, stream, mode, **kw)

    # ---- downstream ------------------------------------------------------
    dl_done = np.full((R, lay.n_clients), np.nan)
    fcfs_rows = np.array(
        [r for r in range(R) if cases[row_case[r]].policy == "fcfs"],
        np.int64,
    )
    bs_rows = np.array(
        [r for r in range(R) if cases[row_case[r]].policy == "bs"],
        np.int64,
    )
    if len(fcfs_rows):
        sub = lay.rows(fcfs_rows)
        if jobs_info is None:
            bits = np.array([cases[row_case[r]].workload.model_bits
                             for r in fcfs_rows])[:, None]
        else:
            bits = jobs_info["mb"][fcfs_rows]
        rem0 = np.where(sub.part & ~no_dl[fcfs_rows], bits, 0.0)
        ready0 = np.zeros_like(rem0)
        with maybe_span(collector, "phase:dl:fcfs", rows=len(fcfs_rows)):
            dl_done[fcfs_rows], _ = run_phase(
                sub, rem0, ready0, fcfs_rows, "dl", "fcfs",
                max_t=max_t, cap_row=cap_row[fcfs_rows], cps_cap=cps_cap,
                n_pons=P, collector=collector, phase_label="dl:fcfs",
                jobs_ctx=jobs_ctx_for(fcfs_rows),
            )
    for r in bs_rows:
        b, p = int(row_case[r]), int(row_pon[r])
        mb = (cases[b].workload.model_bits if jobs_info is None
              else jobs_info["mb"][r])
        t_bcast = mb / (rates_pon[p] * cfg.efficiency) + cfg.propagation_s
        dl_done[r] = np.where(lay.part[r], t_bcast, np.nan)
    dl_done = np.where(no_dl, 0.0, dl_done)

    ready_t = dl_done + lay.t_ud

    # ---- upstream --------------------------------------------------------
    ul_done = np.full((R, lay.n_clients), np.nan)
    ul_rem = np.zeros((R, lay.n_clients))
    specs: Dict[int, SliceSpec] = {}
    if len(fcfs_rows):
        sub = lay.rows(fcfs_rows)
        rem0 = np.where(sub.part, sub.m_ud, 0.0)
        ready = np.where(sub.part, ready_t[fcfs_rows], np.inf)
        with maybe_span(collector, "phase:ul:fcfs", rows=len(fcfs_rows)):
            ul_done[fcfs_rows], ul_rem[fcfs_rows] = run_phase(
                sub, rem0, ready, fcfs_rows, "ul", "fcfs",
                max_t=ul_max_t, fill_unfinished=ul_deadline_s is None,
                cap_row=cap_row[fcfs_rows], cps_cap=cps_cap, n_pons=P,
                deadline_row=None if dl_row is None else dl_row[fcfs_rows],
                outage_row=(None if outage_row is None
                            else outage_row[fcfs_rows]),
                collector=collector, phase_label="ul:fcfs",
                jobs_ctx=jobs_ctx_for(fcfs_rows),
            )
    if len(bs_rows):
        per_row = []
        per_row_jobs = []
        for r in bs_rows:
            b, p = int(row_case[r]), int(row_pon[r])
            dl_map = {
                int(lay.cid_of[p, j]): float(dl_done[r, j])
                for j in range(lay.n_clients) if lay.part[r, j]
            }
            if jobs_info is None:
                profiles = [
                    ClientProfile(
                        client_id=c.client_id,
                        t_ud=c.t_ud,
                        t_dl=dl_map[c.client_id],
                        m_ud_bits=c.m_ud_bits,
                        distance_m=c.distance_m,
                    )
                    for c in cases[b].workload.clients
                    if c.client_id in dl_map
                ]
                spec, arrays = _bs_slice(
                    profiles, float(rates_pon[p] * cfg.efficiency)
                )
                if P == 1:
                    specs[b] = spec
                per_row.append((spec, arrays))
            else:
                # each job carves its own slice over its own clients;
                # slots stay grouped job-major, matching the oracle
                row_slots = []
                for j, job in enumerate(cases[b].jobs):
                    jset = set(job.clients)
                    profiles = [
                        ClientProfile(
                            client_id=c.client_id,
                            t_ud=c.t_ud,
                            t_dl=dl_map[c.client_id],
                            m_ud_bits=c.m_ud_bits,
                            distance_m=c.distance_m,
                        )
                        for c in cases[b].workload.clients
                        if c.client_id in dl_map and c.client_id in jset
                    ]
                    spec, arrays = _bs_slice(
                        profiles, float(rates_pon[p] * cfg.efficiency)
                    )
                    row_slots.append((j, spec, arrays))
                per_row_jobs.append(row_slots)
        slot_arrays = (_stack_slots(per_row, n_local)
                       if jobs_info is None
                       else _stack_slots_jobs(per_row_jobs, n_local))
        sub = lay.rows(bs_rows)
        rem0 = np.where(sub.part, sub.m_ud, 0.0)
        ready = np.where(sub.part, ready_t[bs_rows], np.inf)
        with maybe_span(collector, "phase:ul:bs", rows=len(bs_rows)):
            ul_done[bs_rows], ul_rem[bs_rows] = run_phase(
                sub, rem0, ready, bs_rows, "ul", "bs",
                slot_arrays=slot_arrays, max_t=ul_max_t,
                fill_unfinished=ul_deadline_s is None,
                cap_row=cap_row[bs_rows], cps_cap=cps_cap, n_pons=P,
                deadline_row=None if dl_row is None else dl_row[bs_rows],
                outage_row=(None if outage_row is None
                            else outage_row[bs_rows]),
                collector=collector, phase_label="ul:bs",
                jobs_ctx=jobs_ctx_for(bs_rows),
            )

    # ---- assemble --------------------------------------------------------
    results = []
    for b, case in enumerate(cases):
        dl: Dict[int, float] = {}
        rd: Dict[int, float] = {}
        ul: Dict[int, float] = {}
        remaining: Dict[int, float] = {}
        for p in range(P):
            r = b * P + p
            sel = lay.part[r]
            if not sel.any():
                continue
            ids = lay.cid_of[p][sel]
            dl.update(
                (int(i), float(v)) for i, v in zip(ids, dl_done[r, sel])
            )
            rd.update(
                (int(i), float(v)) for i, v in zip(ids, ready_t[r, sel])
            )
            ul.update(
                (int(i), float(v)) for i, v in zip(ids, ul_done[r, sel])
            )
            remaining.update(
                (int(i), float(v))
                for i, v in zip(ids, ul_rem[r, sel]) if v > 0.0
            )
        if per_case_dl:
            dlb = float(dl_case[b])
            has_dl = bool(np.isfinite(dl_case[b]))
        else:
            dlb = ul_deadline_s
            has_dl = ul_deadline_s is not None
        if remaining and has_dl:
            sync = dlb + case.workload.t_aggregate
        else:
            sync = max(ul.values()) + case.workload.t_aggregate
        if collector is not None:
            ul_times = [v for v in ul.values() if np.isfinite(v)]
            if ul_times:
                collector.record_upload_times(case.policy, case.load,
                                              ul_times)
        results.append(RoundResult(
            policy=case.policy,
            sync_time=sync,
            dl_done=dl,
            ready=rd,
            ul_done=ul,
            compute_bound=max(rd.values()),
            load=case.load,
            slice_spec=specs.get(b),
            ul_remaining=remaining if has_dl else None,
            job_stats=(None if case.jobs is None else
                       compute_job_stats(case.jobs, ul, n_local, P)),
        ))
        if case.jobs is not None:
            _record_job_uploads(collector, case, results[-1])
    return results


def simulate_round_sweep(cfg, cases=None,
                         t_round_hint: float = 10.0,
                         max_t: float = 600.0,
                         ul_deadline_s=None,
                         ul_outage_s=None,
                         collector=None,
                         backend: Optional[str] = None,
                         ) -> List["RoundResult"]:
    """Public round-sweep entry point.

    Preferred form: pass a ``repro.net.SweepSpec`` —
    ``simulate_round_sweep(spec)`` or ``simulate_round_sweep(cfg,
    spec)`` with an explicit ``PONConfig`` — which validates the bundle
    once and dispatches to the engine (``repro.net.api.simulate`` is
    the same call). The spec must not carry a ``schedule``; timelines
    go through ``simulate_timeline_sweep``/``simulate``.

    The legacy kwarg form ``simulate_round_sweep(cfg, cases,
    t_round_hint=..., ul_deadline_s=..., ...)`` still works, emits a
    ``DeprecationWarning``, and delegates to the same engine —
    results are identical (asserted in ``tests/test_api.py``). See
    ``_round_sweep`` for the full semantics of every knob.
    """
    from repro.net.api import SweepSpec, simulate

    spec = None
    pon = None
    if isinstance(cfg, SweepSpec):
        if cases is not None:
            raise TypeError(
                "simulate_round_sweep(spec) takes no second argument; "
                "put the PONConfig in spec.pon or call "
                "simulate_round_sweep(cfg, spec)"
            )
        spec = cfg
    elif isinstance(cases, SweepSpec):
        spec, pon = cases, cfg
    if spec is not None:
        if spec.schedule is not None:
            raise ValueError(
                "spec carries a schedule; call simulate(spec) or "
                "simulate_timeline_sweep(spec) for timelines"
            )
        return simulate(spec, pon, collector=collector)
    warnings.warn(
        "simulate_round_sweep(cfg, cases, **kwargs) is deprecated; "
        "build a repro.net.SweepSpec and call simulate(spec) "
        "(or pass the spec to simulate_round_sweep)",
        DeprecationWarning, stacklevel=2,
    )
    return _round_sweep(
        cfg, cases, t_round_hint=t_round_hint, max_t=max_t,
        ul_deadline_s=ul_deadline_s, ul_outage_s=ul_outage_s,
        collector=collector, backend=backend,
    )
