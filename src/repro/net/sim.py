"""Cycle-driven PON simulator for FL synchronisation rounds.

Topology (paper §3): one OLT/CPS + ``n_onus`` ONU/EC nodes, 10 Gbps
symmetric, 20 km reach, 1 ms polling cycle, ~92% effective payload
efficiency (guard/REPORT/FEC overheads). Background Poisson traffic rides
assured T-CONTs in both directions; the FL task's traffic is:

  downstream: the global model — one unicast copy per involved EC node under
  FCFS (each copy queues as best-effort behind assured background); under BS
  a single reserved broadcast (PON downstream is physically broadcast, so the
  slice needs one copy only).

  upstream: each client's ``M_i^UD`` update, entering its ONU's best-effort
  queue when local training finishes (FCFS) or its slice slot (BS).

The simulator advances in polling cycles, applying the chosen DBA, and
records per-client download/ready/upload-completion times. The round's
synchronisation time is ``max_i upload_done_i + T_a``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.scheduler import schedule_slots
from repro.core.slicing import (
    LIGHT_SPEED_FIBER,
    ClientProfile,
    SliceSpec,
    compute_slice,
)
from repro.net.dba import (
    DEFAULT_EFFICIENCY,
    FCFSBestEffort,
    OnuQueue,
    SlicedDBA,
)
from repro.net.traffic import PoissonSource, background_rate_for_load

EPS_BITS = 1.0


@dataclass(frozen=True)
class PONConfig:
    n_onus: int = 128
    line_rate_bps: float = 10e9      # symmetric up/down (paper §3)
    distance_m: float = 20_000.0
    cycle_time_s: float = 1e-3
    efficiency: float = DEFAULT_EFFICIENCY
    bg_burst_packets: float = 16.0

    @property
    def propagation_s(self) -> float:
        return self.distance_m / LIGHT_SPEED_FIBER


@dataclass
class RoundResult:
    policy: str
    sync_time: float
    dl_done: Dict[int, float]
    ready: Dict[int, float]
    ul_done: Dict[int, float]
    compute_bound: float
    load: float
    slice_spec: Optional[SliceSpec] = None
    # set when the round ran under an upload deadline: bits still queued
    # at the cutoff per client (their ul_done is NaN); the multi-round
    # timeline defers these to the next round
    ul_remaining: Optional[Dict[int, float]] = None
    # set when the case carried tenant jobs: job_id -> JobRoundStats
    # with per-job ONU/OLT/CPS-tier aggregation times (repro.net.jobs)
    job_stats: Optional[Dict[int, "JobRoundStats"]] = None  # noqa: F821

    @property
    def comm_overhead(self) -> float:
        return self.sync_time - self.compute_bound


@dataclass
class FLRoundWorkload:
    """One round's FL inputs: involved clients with their compute times."""

    clients: List[ClientProfile]
    model_bits: float                # global model size (downlink)
    t_aggregate: float = 0.0


def _bg_push(queues, sources, t, cycle):
    for q, src in zip(queues, sources):
        q.push("bg", src.arrivals(cycle), t)


def _mk_sources(cfg: PONConfig, bg_rate_bps: float, rng) -> List[PoissonSource]:
    per_onu = bg_rate_bps / cfg.n_onus
    return [
        PoissonSource(per_onu, rng, burst_packets=cfg.bg_burst_packets)
        for _ in range(cfg.n_onus)
    ]


def _credit(served, remaining, done, t, cfg):
    """Attribute served FL bits to the clients that own them.

    FL segments are owner-tagged ``("fl", client_id)``, so a grant's
    bits go to the client whose update they carry — a client is done
    exactly when its own queued update has crossed the wire. (The seed
    attributed an ONU's served FL bits across its clients in ascending
    id order, which let an earlier-id client absorb a later one's queued
    bits; the later client's residual was never re-enqueued and starved
    whenever several clients shared an ONU.)
    """
    for kind, bits in served.items():
        if not isinstance(kind, tuple):
            continue
        cid = kind[1]
        if cid not in remaining:
            continue
        remaining[cid] -= bits
        if remaining[cid] <= EPS_BITS:
            done[cid] = t + cfg.cycle_time_s + cfg.propagation_s
            del remaining[cid]


def _downstream_phase(
    cfg: PONConfig,
    workload: FLRoundWorkload,
    bg_rate_bps: float,
    rng: np.random.Generator,
    reserved: bool,
    max_t: float = 600.0,
    sources=None,
    skip_ids=frozenset(),
) -> Dict[int, float]:
    """Model distribution; returns per-client download-done time.

    ``skip_ids`` (deadline carriers resuming a partial upload) take no
    downstream traffic; their download time is 0.
    """
    clients = workload.clients
    if reserved:
        # BS: one reserved broadcast at (effective) line rate
        t = (
            workload.model_bits / (cfg.line_rate_bps * cfg.efficiency)
            + cfg.propagation_s
        )
        return {c.client_id: 0.0 if c.client_id in skip_ids else t
                for c in clients}

    queues = [OnuQueue(i) for i in range(cfg.n_onus)]
    qmap = {q.onu_id: q for q in queues}
    fresh = [c for c in clients if c.client_id not in skip_ids]
    for c in fresh:     # per-EC-node unicast copies enqueue at round start
        qmap[c.client_id % cfg.n_onus].push(
            ("fl", c.client_id), workload.model_bits, 0.0
        )
    if sources is None:
        sources = _mk_sources(cfg, bg_rate_bps, rng)
    dba = FCFSBestEffort(
        cfg.line_rate_bps, cfg.cycle_time_s, cfg.n_onus, cfg.efficiency
    )
    remaining = {c.client_id: workload.model_bits for c in fresh}
    done: Dict[int, float] = {c.client_id: 0.0 for c in clients
                              if c.client_id in skip_ids}
    t = 0.0
    while remaining and t < max_t:
        _bg_push(queues, sources, t, cfg.cycle_time_s)
        for onu_id, g in dba.grant(queues).items():
            q = qmap[onu_id]
            if "bg" in g:
                q.serve(g["bg"], kind="bg")
            if "fl" in g:
                served = q.serve(g["fl"], kind="fl")
                _credit(served, remaining, done, t, cfg)
        t += cfg.cycle_time_s
    for cid in list(remaining):
        done[cid] = t + cfg.propagation_s
    return done


def _upstream_phase(
    cfg: PONConfig,
    workload: FLRoundWorkload,
    ready: Dict[int, float],
    bg_rate_bps: float,
    rng: np.random.Generator,
    dba_mode: str,
    slice_spec: Optional[SliceSpec] = None,
    slots=None,
    max_t: float = 600.0,
    sources=None,
    deadline_s: Optional[float] = None,
    outage_s: Optional[Tuple[float, float]] = None,
) -> Tuple[Dict[int, float], Dict[int, float]]:
    """Upload phase; returns (per-client upload-done time, bits still
    queued at the cutoff).

    With ``deadline_s`` the phase stops at the round deadline and the
    unfinished clients' remaining bits are reported instead of being
    timed out at ``max_t`` (the multi-round deferral hook).

    ``outage_s`` (``(start, end)`` phase-relative seconds) is an
    ONU/link outage window: cycles starting inside it grant nothing —
    arrivals (background and newly-ready clients) still queue, service
    resumes after the window. Matches the engine's per-row capacity
    masking rule (``start <= t < end`` on the cycle-start clock).
    """
    if deadline_s is not None:
        max_t = deadline_s
    o_start, o_end = outage_s if outage_s is not None else (np.inf, np.inf)
    clients = workload.clients
    queues = [OnuQueue(i) for i in range(cfg.n_onus)]
    qmap = {q.onu_id: q for q in queues}
    if sources is None:
        sources = _mk_sources(cfg, bg_rate_bps, rng)
    if dba_mode == "bs":
        dba = SlicedDBA(
            cfg.line_rate_bps,
            cfg.cycle_time_s,
            cfg.n_onus,
            slice_spec.bandwidth_bps,
            slots,
            cfg.efficiency,
        )
    else:
        dba = FCFSBestEffort(
            cfg.line_rate_bps, cfg.cycle_time_s, cfg.n_onus, cfg.efficiency
        )

    remaining = {c.client_id: c.m_ud_bits for c in clients}
    pending = dict(ready)
    done: Dict[int, float] = {}
    t = 0.0
    while remaining and t < max_t:
        for cid, t_ready in list(pending.items()):
            if t_ready <= t + cfg.cycle_time_s:
                qmap[cid % cfg.n_onus].push(
                    ("fl", cid), remaining[cid], max(t_ready, t)
                )
                del pending[cid]
        _bg_push(queues, sources, t, cfg.cycle_time_s)
        if o_start <= t < o_end:
            t += cfg.cycle_time_s
            continue                # link dark: no grants this cycle
        grants = (
            dba.grant(queues, t) if dba_mode == "bs" else dba.grant(queues)
        )
        for onu_id, g in grants.items():
            q = qmap[onu_id]
            if "bg" in g:
                q.serve(g["bg"], kind="bg")
            if "fl" in g:
                served = q.serve(g["fl"], kind="fl")
                _credit(served, remaining, done, t, cfg)
        t += cfg.cycle_time_s
    if deadline_s is None:
        for cid in list(remaining):
            done[cid] = t + cfg.propagation_s
        remaining = {}
    else:
        for cid in remaining:
            done[cid] = float("nan")
    return done, dict(remaining)


def simulate_round(
    cfg: PONConfig,
    workload: FLRoundWorkload,
    total_load: float,
    policy: str,
    seed: int = 0,
    t_round_hint: float = 10.0,
    backend: str = "vectorized",
    _dl_sources=None,
    _ul_sources=None,
    ul_deadline_s: Optional[float] = None,
    ul_outage_s=None,
    no_dl_ids=frozenset(),
    stream_round: int = 0,
    topology=None,
) -> RoundResult:
    """Simulate one synchronisation round under ``policy`` in {fcfs, bs}.

    ``backend="vectorized"`` (default) runs the round on the batched
    array engine (``repro.net.engine``); ``backend="jit"`` runs the
    same engine with its device cycle loop
    (``repro.kernels.ponsim``, numpy fallback on unsupported shapes);
    ``backend="reference"`` keeps the original cycle-by-cycle
    simulator. Both implement the same
    semantics (property-tested against each other). The reference
    backend keeps its own seeded numpy arrival draws unless
    ``_dl_sources``/``_ul_sources`` inject per-ONU sources (parity-test
    hook; forces the reference backend) — feeding it
    ``repro.net.traffic.CounterStream`` sources replays the engine's
    exact counter-based arrival process.

    ``ul_deadline_s`` cuts the upload phase at a round deadline
    (unfinished bits come back in ``RoundResult.ul_remaining``);
    ``ul_outage_s`` (``(start, end)`` seconds, or ``(n_pons, 2)`` per
    PON under a topology) masks upstream capacity during an ONU/link
    outage window (``repro.faults``); ``no_dl_ids`` marks deadline
    carriers that skip the model download; ``stream_round`` keys the
    engine's arrival stream for multi-round timelines.

    ``topology`` (``repro.net.multi_pon.MultiPonTopology``) stacks the
    round over several wavelength segments sharing a CPS uplink; the
    reference backend then runs the cycle-by-cycle multi-PON oracle
    (``simulate_multi_pon_round``), which draws from the engine's
    counter streams directly and accepts no injected sources.
    """
    if backend not in ("vectorized", "reference", "jit"):
        raise ValueError(f"unknown backend {backend!r}")
    if (backend in ("vectorized", "jit") and _dl_sources is None
            and _ul_sources is None):
        from repro.net.engine import SweepCase, _round_sweep

        return _round_sweep(
            cfg,
            [SweepCase(workload=workload, load=total_load, policy=policy,
                       seed=seed, stream_round=stream_round,
                       no_dl_ids=frozenset(no_dl_ids),
                       topology=topology)],
            t_round_hint=t_round_hint,
            ul_deadline_s=ul_deadline_s,
            ul_outage_s=None if ul_outage_s is None else [ul_outage_s],
            backend="jit" if backend == "jit" else None,
        )[0]
    if backend == "jit":
        raise ValueError(
            "backend='jit' cannot replay injected per-ONU sources; "
            "use backend='vectorized' or 'reference'"
        )
    if topology is not None and not topology.trivial:
        from repro.net.multi_pon import simulate_multi_pon_round

        if _dl_sources is not None or _ul_sources is not None:
            raise ValueError(
                "multi-PON reference rounds draw from counter streams; "
                "injected per-ONU sources are single-PON only"
            )
        return simulate_multi_pon_round(
            cfg, topology, workload, total_load, policy, seed=seed,
            t_round_hint=t_round_hint, ul_deadline_s=ul_deadline_s,
            ul_outage_s=ul_outage_s,
            no_dl_ids=frozenset(no_dl_ids), stream_round=stream_round,
        )

    rng = np.random.default_rng(seed)
    clients = workload.clients
    n = len(clients)
    # the training traffic's own average rate is part of the offered load
    training_rate = (
        n * (workload.model_bits + float(np.mean([c.m_ud_bits for c in clients])))
        / max(t_round_hint, 1e-9)
    )
    bg_rate = background_rate_for_load(
        total_load, cfg.line_rate_bps, training_rate
    )

    if ul_outage_s is not None:
        win = np.asarray(ul_outage_s, np.float64).reshape(-1)
        if win.size != 2:
            raise ValueError(
                "single-PON ul_outage_s must be one (start, end) window"
            )
        ul_outage_s = (float(win[0]), float(win[1]))

    dl_done = _downstream_phase(
        cfg, workload, bg_rate, rng, reserved=(policy == "bs"),
        sources=_dl_sources, skip_ids=frozenset(no_dl_ids),
    )
    ready = {c.client_id: dl_done[c.client_id] + c.t_ud for c in clients}
    spec = slots = None
    if policy == "bs":
        # The OLT computed the slice from Φ at membership time; slice times
        # are relative to the round start (t_current = 0, single round h·T=0).
        profiles = [
            ClientProfile(
                client_id=c.client_id,
                t_ud=c.t_ud,
                t_dl=dl_done[c.client_id],
                m_ud_bits=c.m_ud_bits,
                distance_m=c.distance_m,
            )
            for c in clients
        ]
        spec = compute_slice(
            profiles, t_current=0.0, t_round=0.0,
            capacity_bps=cfg.line_rate_bps * cfg.efficiency, h=1,
        )
        slots = schedule_slots(profiles, spec, round_start=0.0)
        ul_done, ul_remaining = _upstream_phase(
            cfg, workload, ready, bg_rate, rng, "bs", spec, slots,
            sources=_ul_sources, deadline_s=ul_deadline_s,
            outage_s=ul_outage_s,
        )
    else:
        ul_done, ul_remaining = _upstream_phase(
            cfg, workload, ready, bg_rate, rng, "fcfs",
            sources=_ul_sources, deadline_s=ul_deadline_s,
            outage_s=ul_outage_s,
        )

    if ul_remaining and ul_deadline_s is not None:
        sync = ul_deadline_s + workload.t_aggregate
    else:
        sync = max(ul_done.values()) + workload.t_aggregate
    compute_bound = max(ready.values())
    return RoundResult(
        policy=policy,
        sync_time=sync,
        dl_done=dl_done,
        ready=ready,
        ul_done=ul_done,
        compute_bound=compute_bound,
        load=total_load,
        slice_spec=spec,
        ul_remaining=ul_remaining if ul_deadline_s is not None else None,
    )
