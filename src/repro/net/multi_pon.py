"""Multi-PON (wavelength-stacked) topology sharing one CPS uplink.

The paper evaluates one OLT with tens of ONUs; the edge-computing
framing (many OLTs feeding one edge aggregation point) is the
1000+-ONU regime: ``n_pons`` wavelength/OLT segments, each a full
TDM-PON with its own cycle capacity and DBA, converge on a
converged-packet-segment (CPS) link of finite capacity.  Per polling
cycle the CPS capacity is **waterfilled** across the PONs (max-min
fair): a PON's cycle demand is what its own DBA would serve under its
wavelength capacity, and when the PONs' total demand exceeds the CPS
capacity each PON is granted ``min(demand_p, mu)`` with the water
level ``mu`` chosen so the grants exactly exhaust the CPS link.
Within its CPS share a PON allocates as usual (assured background
oldest-first then best-effort FL under FCFS; reserved slice slots
under BS — the slice holds CPS priority end to end, so FL stays
isolated from background load, which is the paper's claim carried up
one level).

This module holds the topology description (``MultiPonTopology``),
the shared waterfill kernel (``cps_waterfill`` — the vectorized
engine and the reference oracle call the *same* function so their
water levels agree to the float), the per-PON background-rate split
(``pon_bg_rates``), and the parity oracle
``simulate_multi_pon_round``: an explicit per-PON cycle loop over
``OnuQueue`` dict state with a CPS post-pass between the raw DBA
grants and the serve step.  The stacked engine
(``repro.net.engine``) must reproduce it at rtol 1e-6
(property-tested in ``tests/test_multi_pon.py``).

Client placement: client ``i`` lives on global ONU ``i %
(n_pons * cfg.n_onus)``; PON ``onu // cfg.n_onus``, local ONU ``onu %
cfg.n_onus``.  With ``n_pons == 1`` this reduces to the single-PON
``i % n_onus`` map and every quantity here collapses to the PR 2/3
behaviour.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.scheduler import schedule_slots
from repro.core.slicing import ClientProfile, compute_slice
from repro.net.dba import FCFSBestEffort, OnuQueue, SlicedDBA
from repro.net.sim import RoundResult, _credit
from repro.net.traffic import (
    background_rate_for_load,
    counter_streams_for_pons,
)

CAP_EPS = 1e-9                    # matches the DBAs' exhaustion threshold

__all__ = [
    "MultiPonTopology",
    "cps_waterfill",
    "pon_bg_rates",
    "simulate_multi_pon_round",
]


@dataclass(frozen=True)
class MultiPonTopology:
    """Several OLT/wavelength segments sharing a CPS uplink.

    ``n_pons`` wavelength segments each serve ``cfg.n_onus`` ONUs at
    ``cfg.line_rate_bps`` (or a per-PON override via
    ``pon_rates_bps``).  ``cps_rate_bps`` is the shared CPS link; its
    per-cycle byte budget is waterfilled across the PONs each polling
    cycle (``None`` = uncontended, the PONs are independent).  The CPS
    link carries no PON framing, so its cycle capacity is
    ``rate * cycle_time`` without the PON efficiency factor.
    """

    n_pons: int = 1
    cps_rate_bps: Optional[float] = None
    pon_rates_bps: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        if self.n_pons < 1:
            raise ValueError("n_pons must be >= 1")
        if self.cps_rate_bps is not None and self.cps_rate_bps <= 0:
            raise ValueError("cps_rate_bps must be positive")
        if self.pon_rates_bps is not None:
            rates = tuple(float(r) for r in self.pon_rates_bps)
            if len(rates) != self.n_pons:
                raise ValueError(
                    f"pon_rates_bps needs {self.n_pons} entries; "
                    f"got {len(rates)}"
                )
            object.__setattr__(self, "pon_rates_bps", rates)

    @property
    def trivial(self) -> bool:
        """True when the topology adds nothing over a lone PONConfig
        (the engine's bitwise-compatibility fast path)."""
        return (self.n_pons == 1 and self.cps_rate_bps is None
                and self.pon_rates_bps is None)

    def rates(self, cfg) -> np.ndarray:
        if self.pon_rates_bps is not None:
            return np.asarray(self.pon_rates_bps, np.float64)
        return np.full(self.n_pons, cfg.line_rate_bps, np.float64)

    def capacity_bits(self, cfg) -> np.ndarray:
        """Per-PON cycle capacity ``(n_pons,)`` (payload bits)."""
        return self.rates(cfg) * cfg.cycle_time_s * cfg.efficiency

    def cps_capacity_bits(self, cfg) -> Optional[float]:
        if self.cps_rate_bps is None:
            return None
        return float(self.cps_rate_bps) * cfg.cycle_time_s

    def total_onus(self, cfg) -> int:
        return self.n_pons * cfg.n_onus

    def pon_of(self, client_id: int, cfg) -> int:
        return (int(client_id) % self.total_onus(cfg)) // cfg.n_onus

    def local_onu(self, client_id: int, cfg) -> int:
        return (int(client_id) % self.total_onus(cfg)) % cfg.n_onus


def cps_waterfill(want: np.ndarray, cap) -> np.ndarray:
    """Max-min fair split of the CPS cycle capacity across PONs.

    ``want``: per-PON cycle demand, ``(..., n_pons)`` (a ``(G, P)``
    batch from the engine or a single ``(P,)`` vector from the
    oracle); ``cap``: CPS capacity per group, scalar or ``(G,)``.
    Returns ``eff`` of ``want``'s shape with ``eff <= want``
    elementwise, ``sum(eff) <= cap`` per group, and — when the cap
    binds — ``eff_p = min(want_p, mu)`` at the exact water level.
    Rows are independent, so the batched call and the per-row call
    produce identical floats.
    """
    want = np.asarray(want, np.float64)
    if want.ndim == 1:
        return cps_waterfill(want[None, :], cap)[0]
    G, P = want.shape
    cap_b = np.broadcast_to(np.asarray(cap, np.float64), (G,))
    tot = want.sum(axis=1)
    eff = want.copy()
    over = tot > cap_b + CAP_EPS
    if not over.any():
        return eff
    w = want[over]
    c = cap_b[over]
    ws = np.sort(w, axis=1)
    cum = np.cumsum(ws, axis=1)
    # after fully granting the k smallest demands, the rest split the
    # residual evenly: mu_k = (cap - sum of k smallest) / (P - k); the
    # water level is the first feasible one (mu_k <= ws[k])
    prev = cum - ws
    mu_k = (c[:, None] - prev) / (P - np.arange(P, dtype=np.float64))
    k = np.argmax(mu_k <= ws, axis=1)
    mu = mu_k[np.arange(len(w)), k]
    eff[over] = np.minimum(w, mu[:, None])
    return eff


def pon_bg_rates(clients: Sequence[ClientProfile], model_bits: float,
                 total_load: float, cfg, topo: MultiPonTopology,
                 t_round_hint: float = 10.0,
                 model_bits_by_client=None) -> np.ndarray:
    """Per-ONU background rate ``(n_pons,)`` of each wavelength segment.

    Each PON's offered background makes up ``total_load`` on *its*
    wavelength given its own share of the training traffic (the
    clients placed on it); with ``n_pons == 1`` this is exactly the
    single-PON split the engine has always used.

    ``model_bits_by_client`` (multi-tenant jobs) prices each client's
    downlink at its *own job's* model size instead of the shared
    ``model_bits``; ``None`` keeps the single-job arithmetic bitwise.
    """
    rates = topo.rates(cfg)
    total = topo.total_onus(cfg)
    out = np.zeros(topo.n_pons)
    for p in range(topo.n_pons):
        cl = [c for c in clients
              if (c.client_id % total) // cfg.n_onus == p]
        if not cl:
            training_rate = 0.0
        elif model_bits_by_client is not None:
            training_rate = sum(
                model_bits_by_client[c.client_id] + c.m_ud_bits
                for c in cl
            ) / max(t_round_hint, 1e-9)
        else:
            training_rate = (
                len(cl)
                * (model_bits + float(np.mean([c.m_ud_bits for c in cl])))
                / max(t_round_hint, 1e-9)
            )
        out[p] = background_rate_for_load(
            total_load, float(rates[p]), training_rate
        ) / cfg.n_onus
    return out


# ---------------------------------------------------------------------------
# reference oracle: per-PON cycle loop + CPS post-pass
# ---------------------------------------------------------------------------


def _grant_total(grants: Dict[int, Dict[str, float]]) -> float:
    return sum(b for kinds in grants.values() for b in kinds.values())


def simulate_multi_pon_round(
    cfg,
    topo: MultiPonTopology,
    workload,
    total_load: float,
    policy: str,
    seed: int = 0,
    t_round_hint: float = 10.0,
    max_t: float = 600.0,
    ul_deadline_s: Optional[float] = None,
    ul_outage_s: Optional[np.ndarray] = None,
    no_dl_ids=frozenset(),
    stream_round: int = 0,
    collector=None,
) -> RoundResult:
    """Cycle-by-cycle multi-PON reference round (the parity oracle).

    Per cycle and per PON the raw DBA grants are computed under the
    PON's own wavelength capacity; the CPS post-pass waterfills the
    shared capacity across the PONs' grant totals and any PON cut
    below its raw total re-grants under its CPS share
    (``grant(..., cap_bits=eff_p)``).  Background arrivals come from
    the same counter streams the engine consumes, keyed
    ``(seed, phase, stream_round, pon)``.  Semantics of everything
    else (FIFO queues, credit attribution, deadlines, carriers that
    skip the download) match ``repro.net.sim`` exactly.

    ``ul_outage_s`` (``(n_pons, 2)`` ``[start, end)`` windows, or
    ``(2,)`` applied to every PON; ``inf`` = never) darkens a PON's
    upstream during its window: its raw grant is empty — so the CPS
    waterfill sees zero demand from it — while arrivals still queue;
    exactly the engine's per-row capacity masking.

    ``collector`` (``repro.obs.Collector``, optional) records the CPS
    waterfill per-PON want/eff bits, per-cycle CPS uplink utilization
    and upload completion times; ``None`` (the default) is bitwise
    identical to an uninstrumented run.
    """
    if policy not in ("fcfs", "bs"):
        raise ValueError(f"unknown policy {policy!r}")
    P = topo.n_pons
    n_local = cfg.n_onus
    total = topo.total_onus(cfg)
    clients = workload.clients
    if policy == "bs":
        bad = [c.client_id for c in clients if c.client_id >= total]
        if bad:
            raise ValueError(
                f"bs policy requires client_id < n_onus * n_pons; got {bad}"
            )
    pon_of = {c.client_id: topo.pon_of(c.client_id, cfg) for c in clients}
    onu_of = {c.client_id: topo.local_onu(c.client_id, cfg)
              for c in clients}
    rates = topo.rates(cfg)
    cps_cap = topo.cps_capacity_bits(cfg)
    per_onu = pon_bg_rates(clients, workload.model_bits, total_load,
                           cfg, topo, t_round_hint)
    cyc = cfg.cycle_time_s
    prop = cfg.propagation_s
    skip = frozenset(no_dl_ids)
    if ul_outage_s is not None:
        outage = np.asarray(ul_outage_s, np.float64)
        if outage.shape == (2,):
            outage = np.broadcast_to(outage, (P, 2))
        if outage.shape != (P, 2):
            raise ValueError(
                f"ul_outage_s must be (2,) or ({P}, 2); "
                f"got shape {outage.shape}"
            )
        if not np.isfinite(outage[:, 0]).any():
            outage = None
    else:
        outage = None

    def _cps_grants(raws, regrant):
        if cps_cap is None:
            return raws
        want = np.array([_grant_total(g) for g in raws])
        eff = cps_waterfill(want, cps_cap)
        if collector is not None:
            collector.counter("multi_pon.cps_want_bits", (P,)).add(want)
            collector.counter("multi_pon.cps_eff_bits", (P,)).add(eff)
            collector.gauge("multi_pon.cps_util").observe(
                float(eff.sum()) / cps_cap
            )
        return [raws[p] if eff[p] >= want[p] else regrant(p, float(eff[p]))
                for p in range(P)]

    def _serve(qmaps, grants_all, remaining, done, t):
        for p in range(P):
            for onu_id, g in grants_all[p].items():
                q = qmaps[p][onu_id]
                if "bg" in g:
                    q.serve(g["bg"], kind="bg")
                if "fl" in g:
                    served = q.serve(g["fl"], kind="fl")
                    _credit(served, remaining, done, t, cfg)

    def _dark(p: int, t: float, windows) -> bool:
        """PON ``p``'s upstream is in its outage window at cycle start
        ``t`` (same comparison as the engine's capacity mask)."""
        return (windows is not None
                and windows[p, 0] <= t < windows[p, 1])

    def _fcfs_phase(bits0, ready, phase_idx, max_t_p, deadline,
                    windows=None):
        queues = [[OnuQueue(i) for i in range(n_local)] for _ in range(P)]
        dbas = [FCFSBestEffort(float(rates[p]), cyc, n_local,
                               cfg.efficiency) for p in range(P)]
        streams = counter_streams_for_pons(
            seed, phase_idx, per_onu, cyc, n_local,
            cfg.bg_burst_packets, round_index=stream_round,
        )
        sources = [[streams[p].source(i) for i in range(n_local)]
                   for p in range(P)]
        remaining = dict(bits0)
        pending = dict(ready)
        done: Dict[int, float] = {}
        t = 0.0
        while remaining and t < max_t_p:
            for cid, t_ready in list(pending.items()):
                if t_ready <= t + cyc:
                    queues[pon_of[cid]][onu_of[cid]].push(
                        ("fl", cid), remaining[cid], max(t_ready, t)
                    )
                    del pending[cid]
            for p in range(P):
                for q, src in zip(queues[p], sources[p]):
                    q.push("bg", src.arrivals(cyc), t)
            raws = [{} if _dark(p, t, windows)
                    else dbas[p].grant(queues[p]) for p in range(P)]
            grants_all = _cps_grants(
                raws, lambda p, e: dbas[p].grant(queues[p], cap_bits=e)
            )
            _serve(
                [{q.onu_id: q for q in queues[p]} for p in range(P)],
                grants_all, remaining, done, t,
            )
            t += cyc
        if deadline is None:
            for cid in list(remaining):
                done[cid] = t + prop
            remaining = {}
        else:
            for cid in remaining:
                done[cid] = float("nan")
        return done, dict(remaining)

    def _bs_phase(bits0, ready, dl_done, max_t_p, deadline,
                  windows=None):
        # The slice is a reserved T-CONT end to end (PON slot + CPS
        # priority); background rides the residual CPS capacity and
        # never feeds back into FL service, so — exactly as in the
        # single-PON engine — the BS phase simulates no background.
        # Queues carry their *global* ONU id: the SlicedDBA matches a
        # slot to the queue whose onu_id equals the slot's client_id.
        queues = [[OnuQueue(p * n_local + i) for i in range(n_local)]
                  for p in range(P)]
        dbas: list = []
        specs: Dict[int, object] = {}
        for p in range(P):
            profs = [
                ClientProfile(
                    client_id=c.client_id, t_ud=c.t_ud,
                    t_dl=dl_done[c.client_id], m_ud_bits=c.m_ud_bits,
                    distance_m=c.distance_m,
                )
                for c in clients if pon_of[c.client_id] == p
            ]
            if not profs:
                dbas.append(None)
                continue
            spec = compute_slice(
                profs, t_current=0.0, t_round=0.0,
                capacity_bps=float(rates[p] * cfg.efficiency), h=1,
            )
            slots = schedule_slots(profs, spec, round_start=0.0)
            specs[p] = spec
            dbas.append(SlicedDBA(
                float(rates[p]), cyc, n_local, spec.bandwidth_bps,
                slots, cfg.efficiency,
            ))
        remaining = dict(bits0)
        pending = dict(ready)
        done: Dict[int, float] = {}
        t = 0.0
        while remaining and t < max_t_p:
            for cid, t_ready in list(pending.items()):
                if t_ready <= t + cyc:
                    queues[pon_of[cid]][onu_of[cid]].push(
                        ("fl", cid), remaining[cid], max(t_ready, t)
                    )
                    del pending[cid]
            raws = [dbas[p].grant(queues[p], t)
                    if dbas[p] and not _dark(p, t, windows) else {}
                    for p in range(P)]
            grants_all = _cps_grants(
                raws,
                lambda p, e: dbas[p].grant(queues[p], t, cap_bits=e),
            )
            _serve(
                [{q.onu_id: q for q in queues[p]} for p in range(P)],
                grants_all, remaining, done, t,
            )
            t += cyc
        if deadline is None:
            for cid in list(remaining):
                done[cid] = t + prop
            remaining = {}
        else:
            for cid in remaining:
                done[cid] = float("nan")
        return done, dict(remaining), specs

    # ---- downstream ------------------------------------------------------
    fresh = [c for c in clients if c.client_id not in skip]
    if policy == "bs":
        dl_done = {
            c.client_id: (
                0.0 if c.client_id in skip
                else workload.model_bits
                / (rates[pon_of[c.client_id]] * cfg.efficiency) + prop
            )
            for c in clients
        }
    else:
        bits0 = {c.client_id: workload.model_bits for c in fresh}
        ready0 = {c.client_id: 0.0 for c in fresh}
        dl_done, _ = _fcfs_phase(bits0, ready0, 0, max_t, None)
        for c in clients:
            if c.client_id in skip:
                dl_done[c.client_id] = 0.0

    ready = {c.client_id: dl_done[c.client_id] + c.t_ud for c in clients}

    # ---- upstream --------------------------------------------------------
    ul_max_t = max_t if ul_deadline_s is None else ul_deadline_s
    bits_ul = {c.client_id: c.m_ud_bits for c in clients}
    specs: Dict[int, object] = {}
    if policy == "bs":
        ul_done, ul_remaining, specs = _bs_phase(
            bits_ul, dict(ready), dl_done, ul_max_t, ul_deadline_s,
            windows=outage,
        )
    else:
        ul_done, ul_remaining = _fcfs_phase(
            bits_ul, dict(ready), 1, ul_max_t, ul_deadline_s,
            windows=outage,
        )

    if ul_remaining and ul_deadline_s is not None:
        sync = ul_deadline_s + workload.t_aggregate
    else:
        sync = max(ul_done.values()) + workload.t_aggregate
    if collector is not None:
        collector.record_upload_times(policy, total_load,
                                      list(ul_done.values()))
    return RoundResult(
        policy=policy,
        sync_time=sync,
        dl_done=dl_done,
        ready=ready,
        ul_done=ul_done,
        compute_bound=max(ready.values()),
        load=total_load,
        slice_spec=specs.get(0) if P == 1 else None,
        ul_remaining=ul_remaining if ul_deadline_s is not None else None,
    )
