"""Multi-round timeline engine over the batched PON round engine.

The paper's headline quantities (Fig. 3 training-time saving,
accuracy-vs-wall-clock) are *multi-round*: R synchronisation rounds back
to back, with elastic client membership and (optionally) per-round
deadlines. After PR 2 the co-simulation still drove the vectorized
engine one round at a time from a Python loop, rebuilding layout and
queue state every round. This module advances the whole training
timeline in one call:

* **Folded mode** (no deadlines): rounds are independent given their
  start times, so the round axis folds into the engine's batch axis —
  all R rounds of all B cases run as ONE stacked simulation. One
  ``_Layout`` build, one ``_BgQueues``/``_FLQueues`` allocation carried
  across the whole timeline, one cycle loop whose per-cycle Python cost
  is amortised over R·B rows instead of B. The counter-based arrival
  sampler (``repro.kernels.traffic``) keys round ``r``'s stream by
  ``(seed, phase, r)``, so every row addresses its own arrivals with no
  sequential draw state.
* **Sequential mode** (round deadlines): a client still uploading at the
  deadline *defers* its remaining update bits to the next round (it
  skips the next model download and resumes the stale upload — array
  state carried between rounds), which couples consecutive rounds; the
  engine then advances round by round, still batched over cases.

``simulate_timeline_reference`` is the parity oracle: an explicit
per-round Python loop over the *cycle-by-cycle dict simulator*
(``backend="reference"``), fed the engine's exact counter streams via
``repro.net.traffic.CounterStream``. Tests require sync times and
per-round served bits to agree at rtol 1e-6, including elastic
membership and deadline deferral.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.net.engine import SweepCase, simulate_round_sweep
from repro.net.sim import FLRoundWorkload, RoundResult

__all__ = [
    "TimelineSchedule",
    "TimelineRound",
    "TimelineResult",
    "simulate_timeline_sweep",
    "simulate_timeline_per_round",
    "simulate_timeline_reference",
]


@dataclass(frozen=True)
class TimelineSchedule:
    """The multi-round structure shared by every case of a sweep.

    ``membership``: optional ``(n_rounds, n_clients)`` bool mask over
    each case's ``workload.clients`` *list positions* — a client masked
    out of round r takes no part in it (downloads nothing, uploads no
    bits). Deferred carriers override the mask: an in-flight stale
    upload finishes regardless of membership (defer, not drop).

    ``m_ud_bits``: optional per-round upload-size override, ``(n_rounds,)``
    scalars or ``(n_rounds, n_clients)`` — the co-simulation feeds the
    measured (compressed) update size of each round.

    ``deadline_s``: optional round deadline(s), scalar or ``(n_rounds,)``
    — the upload phase is cut at the deadline and unfinished clients
    carry their remaining bits into the next round.
    """

    n_rounds: int
    membership: Optional[np.ndarray] = None
    m_ud_bits: Optional[np.ndarray] = None
    deadline_s: Optional[object] = None

    def __post_init__(self):
        if self.n_rounds < 1:
            raise ValueError("n_rounds must be >= 1")
        if self.membership is not None:
            m = np.asarray(self.membership, bool)
            if m.ndim != 2 or m.shape[0] != self.n_rounds:
                raise ValueError(
                    f"membership must be (n_rounds, n_clients); "
                    f"got {m.shape}"
                )
            object.__setattr__(self, "membership", m)
        if self.deadline_s is not None:
            d = np.asarray(self.deadline_s, np.float64).reshape(-1)
            if d.size not in (1, self.n_rounds):
                raise ValueError(
                    f"deadline_s must be scalar or (n_rounds,); "
                    f"got {d.size} values for {self.n_rounds} rounds"
                )
        if self.m_ud_bits is not None:
            m = np.asarray(self.m_ud_bits, np.float64)
            if m.shape[0] != self.n_rounds:
                raise ValueError(
                    f"m_ud_bits must lead with n_rounds="
                    f"{self.n_rounds}; got shape {m.shape}"
                )

    def deadline(self, r: int) -> Optional[float]:
        if self.deadline_s is None:
            return None
        d = np.asarray(self.deadline_s, np.float64).reshape(-1)
        return float(d[r] if d.size > 1 else d[0])

    def round_m_ud(self, r: int, j: int, default: float) -> float:
        if self.m_ud_bits is None:
            return default
        m = np.asarray(self.m_ud_bits, np.float64)
        return float(m[r] if m.ndim == 1 else m[r, j])


@dataclass
class TimelineRound:
    """One round of one case's timeline."""

    round_index: int
    sync_time: float
    t_start: float
    t_end: float
    ul_bits: Dict[int, float]       # bits actually served this round
    arrived: List[int]              # clients whose update completed
    deferred: Dict[int, float]      # bits carried into the next round
    result: Optional[RoundResult]   # None for empty (no-client) rounds


@dataclass
class TimelineResult:
    policy: str
    load: float
    seed: int
    rounds: List[TimelineRound]

    @property
    def sync_times(self) -> np.ndarray:
        return np.array([r.sync_time for r in self.rounds])

    @property
    def total_time_s(self) -> float:
        return float(self.sync_times.sum())


# ---------------------------------------------------------------------------
# per-round workload construction (shared by engine and reference paths)
# ---------------------------------------------------------------------------


def _round_setup(case: SweepCase, schedule: TimelineSchedule, r: int,
                 carry: Dict[int, float]):
    """(clients_r, no_dl_ids, rem_start) for round ``r`` of one case.

    Fresh members take the round's upload size; carriers (clients with
    deferred bits) re-enter with their remaining bits, zero compute time
    and no model download, regardless of the membership mask.
    """
    clients = case.workload.clients
    mask = (schedule.membership[r] if schedule.membership is not None
            else np.ones(len(clients), bool))
    out = []
    rem_start: Dict[int, float] = {}
    for j, c in enumerate(clients):
        if c.client_id in carry:
            bits = carry[c.client_id]
            out.append(replace(c, t_ud=0.0, t_dl=0.0, m_ud_bits=bits))
            rem_start[c.client_id] = bits
        elif mask[j]:
            bits = schedule.round_m_ud(r, j, c.m_ud_bits)
            out.append(replace(c, m_ud_bits=bits))
            rem_start[c.client_id] = bits
    return out, frozenset(carry), rem_start


def _round_view(r: int, t_start: float, result: Optional[RoundResult],
                rem_start: Dict[int, float], t_aggregate: float,
                ) -> Tuple[TimelineRound, Dict[int, float]]:
    """Fold one round's RoundResult into a TimelineRound + next carry."""
    if result is None:
        rnd = TimelineRound(
            round_index=r, sync_time=t_aggregate, t_start=t_start,
            t_end=t_start + t_aggregate, ul_bits={}, arrived=[],
            deferred={}, result=None,
        )
        return rnd, {}
    deferred = dict(result.ul_remaining or {})
    ul_bits = {
        cid: rem_start[cid] - deferred.get(cid, 0.0)
        for cid in rem_start
    }
    arrived = sorted(cid for cid in rem_start if cid not in deferred)
    rnd = TimelineRound(
        round_index=r, sync_time=result.sync_time, t_start=t_start,
        t_end=t_start + result.sync_time, ul_bits=ul_bits,
        arrived=arrived, deferred=deferred, result=result,
    )
    return rnd, deferred


def _validate(cases: Sequence[SweepCase], schedule: TimelineSchedule):
    cases = list(cases)
    if not cases:
        raise ValueError("timeline sweep needs at least one case")
    for case in cases:
        if case.dl_arrivals is not None or case.ul_arrivals is not None:
            raise ValueError(
                "timeline cases draw from counter streams; injected "
                "arrival matrices are a single-round parity hook"
            )
        if schedule.membership is not None and (
            schedule.membership.shape[1] != len(case.workload.clients)
        ):
            raise ValueError(
                "membership mask width must match workload.clients"
            )
    return cases


# ---------------------------------------------------------------------------
# engine-backed drivers
# ---------------------------------------------------------------------------


def _sequential(cfg, cases, schedule, t_round_hint, max_t):
    """Round-by-round engine advance, carrying deferred bits (the only
    legal order under deadlines; also the PR 2 per-round loop that the
    folded mode is benchmarked against)."""
    B = len(cases)
    carries: List[Dict[int, float]] = [{} for _ in range(B)]
    t_now = np.zeros(B)
    out = [TimelineResult(policy=c.policy, load=c.load, seed=c.seed,
                          rounds=[]) for c in cases]
    for r in range(schedule.n_rounds):
        row_cases = []
        row_meta = []
        for b, case in enumerate(cases):
            clients_r, no_dl, rem_start = _round_setup(
                case, schedule, r, carries[b]
            )
            if not clients_r:
                row_meta.append((b, None, rem_start))
                continue
            wl = FLRoundWorkload(
                clients=clients_r,
                model_bits=case.workload.model_bits,
                t_aggregate=case.workload.t_aggregate,
            )
            row_meta.append((b, len(row_cases), rem_start))
            row_cases.append(SweepCase(
                workload=wl, load=case.load, policy=case.policy,
                seed=case.seed, stream_round=r, no_dl_ids=no_dl,
                topology=case.topology,
            ))
        results = simulate_round_sweep(
            cfg, row_cases, t_round_hint=t_round_hint, max_t=max_t,
            ul_deadline_s=schedule.deadline(r),
        ) if row_cases else []
        for b, ridx, rem_start in row_meta:
            res = results[ridx] if ridx is not None else None
            rnd, carry = _round_view(
                r, float(t_now[b]), res, rem_start,
                cases[b].workload.t_aggregate,
            )
            out[b].rounds.append(rnd)
            carries[b] = carry
            t_now[b] += rnd.sync_time
    return out


def _folded(cfg, cases, schedule, t_round_hint, max_t):
    """The whole timeline as ONE stacked simulation: the round axis is
    folded into the engine batch axis (rounds are independent given
    their start times when nothing defers)."""
    rows = []
    meta = []            # (b, r, rem_start, row_index or None)
    for b, case in enumerate(cases):
        for r in range(schedule.n_rounds):
            clients_r, _, rem_start = _round_setup(case, schedule, r, {})
            if not clients_r:
                meta.append((b, r, rem_start, None))
                continue
            wl = FLRoundWorkload(
                clients=clients_r,
                model_bits=case.workload.model_bits,
                t_aggregate=case.workload.t_aggregate,
            )
            meta.append((b, r, rem_start, len(rows)))
            rows.append(SweepCase(
                workload=wl, load=case.load, policy=case.policy,
                seed=case.seed, stream_round=r,
                topology=case.topology,
            ))
    results = simulate_round_sweep(
        cfg, rows, t_round_hint=t_round_hint, max_t=max_t,
    ) if rows else []
    out = [TimelineResult(policy=c.policy, load=c.load, seed=c.seed,
                          rounds=[]) for c in cases]
    t_now = np.zeros(len(cases))
    for b, r, rem_start, ridx in meta:
        res = results[ridx] if ridx is not None else None
        rnd, _ = _round_view(
            r, float(t_now[b]), res, rem_start,
            cases[b].workload.t_aggregate,
        )
        out[b].rounds.append(rnd)
        t_now[b] += rnd.sync_time
    return out


def simulate_timeline_sweep(cfg, cases: Sequence[SweepCase],
                            schedule: TimelineSchedule,
                            mode: str = "auto",
                            t_round_hint: float = 10.0,
                            max_t: float = 600.0) -> List[TimelineResult]:
    """Advance the full multi-round timeline for every case.

    ``mode="auto"`` folds the round axis into the batch (one stacked
    simulation) when the schedule has no deadlines and falls back to the
    sequential carry loop otherwise; ``"folded"``/``"sequential"`` force
    a path (parity tests check they agree when both are legal).
    """
    cases = _validate(cases, schedule)
    if mode == "auto":
        mode = "sequential" if schedule.deadline_s is not None else "folded"
    if mode == "folded":
        if schedule.deadline_s is not None:
            raise ValueError(
                "deadline deferral couples consecutive rounds; folded "
                "mode requires a schedule without deadlines"
            )
        return _folded(cfg, cases, schedule, t_round_hint, max_t)
    if mode == "sequential":
        return _sequential(cfg, cases, schedule, t_round_hint, max_t)
    raise ValueError(f"unknown mode {mode!r}")


def simulate_timeline_per_round(cfg, cases: Sequence[SweepCase],
                                schedule: TimelineSchedule,
                                t_round_hint: float = 10.0,
                                max_t: float = 600.0,
                                ) -> List[TimelineResult]:
    """The PR 2 per-round loop: one engine call per round, queue state
    rebuilt every round. Identical results to ``simulate_timeline_sweep``
    (same streams); kept as the benchmark baseline."""
    cases = _validate(cases, schedule)
    return _sequential(cfg, cases, schedule, t_round_hint, max_t)


# ---------------------------------------------------------------------------
# reference loop (parity oracle)
# ---------------------------------------------------------------------------


def simulate_timeline_reference(cfg, cases: Sequence[SweepCase],
                                schedule: TimelineSchedule,
                                t_round_hint: float = 10.0,
                                max_t: float = 600.0,
                                ) -> List[TimelineResult]:
    """Per-round loop over the cycle-by-cycle *dict* simulator.

    Every round rebuilds the reference simulator from scratch and feeds
    it the engine's counter-based arrival streams
    (``CounterStream.source``), so the timeline engine must reproduce
    its sync times and per-round bits exactly (rtol 1e-6) — including
    elastic membership and deadline deferral.
    """
    from repro.kernels.traffic.ops import make_stream_key
    from repro.net.engine import _case_bg_rate
    from repro.net.multi_pon import simulate_multi_pon_round
    from repro.net.sim import simulate_round
    from repro.net.traffic import CounterStream

    cases = _validate(cases, schedule)
    out = []
    for case in cases:
        carry: Dict[int, float] = {}
        t_now = 0.0
        res = TimelineResult(policy=case.policy, load=case.load,
                             seed=case.seed, rounds=[])
        for r in range(schedule.n_rounds):
            clients_r, no_dl, rem_start = _round_setup(
                case, schedule, r, carry
            )
            if not clients_r:
                rnd, carry = _round_view(
                    r, t_now, None, rem_start,
                    case.workload.t_aggregate,
                )
                res.rounds.append(rnd)
                t_now += rnd.sync_time
                continue
            wl = FLRoundWorkload(
                clients=clients_r,
                model_bits=case.workload.model_bits,
                t_aggregate=case.workload.t_aggregate,
            )
            if case.topology is not None and not case.topology.trivial:
                # the cycle-by-cycle multi-PON oracle keys its own
                # (seed, phase, round, pon) counter streams
                result = simulate_multi_pon_round(
                    cfg, case.topology, wl, case.load, case.policy,
                    seed=case.seed, t_round_hint=t_round_hint,
                    max_t=max_t, ul_deadline_s=schedule.deadline(r),
                    no_dl_ids=no_dl, stream_round=r,
                )
                rnd, carry = _round_view(
                    r, t_now, result, rem_start,
                    case.workload.t_aggregate,
                )
                res.rounds.append(rnd)
                t_now += rnd.sync_time
                continue
            row = SweepCase(workload=wl, load=case.load,
                            policy=case.policy, seed=case.seed)
            per_onu = _case_bg_rate(row, cfg, t_round_hint) / cfg.n_onus
            streams = [
                CounterStream(
                    make_stream_key(case.seed, phase, r), per_onu,
                    cfg.cycle_time_s, cfg.n_onus,
                    burst_packets=cfg.bg_burst_packets,
                )
                for phase in (0, 1)
            ]
            result = simulate_round(
                cfg, wl, case.load, case.policy, seed=case.seed,
                t_round_hint=t_round_hint, backend="reference",
                _dl_sources=[streams[0].source(i)
                             for i in range(cfg.n_onus)],
                _ul_sources=[streams[1].source(i)
                             for i in range(cfg.n_onus)],
                ul_deadline_s=schedule.deadline(r),
                no_dl_ids=no_dl,
            )
            rnd, carry = _round_view(
                r, t_now, result, rem_start, case.workload.t_aggregate
            )
            res.rounds.append(rnd)
            t_now += rnd.sync_time
        out.append(res)
    return out
