"""Multi-round timeline engine over the batched PON round engine.

The paper's headline quantities (Fig. 3 training-time saving,
accuracy-vs-wall-clock) are *multi-round*: R synchronisation rounds back
to back, with elastic client membership and (optionally) per-round
deadlines. After PR 2 the co-simulation still drove the vectorized
engine one round at a time from a Python loop, rebuilding layout and
queue state every round. This module advances the whole training
timeline in one call:

* **Folded mode** (rounds independent given their start times): the
  round axis folds into the engine's batch axis — all R rounds of all
  B cases run as ONE stacked simulation. One ``_Layout`` build, one
  ``_BgQueues``/``_FLQueues`` allocation carried across the whole
  timeline, one cycle loop whose per-cycle Python cost is amortised
  over R·B rows instead of B. The counter-based arrival sampler
  (``repro.kernels.traffic``) keys round ``r``'s stream by
  ``(seed, phase, r)``, so every row addresses its own arrivals with no
  sequential draw state. Legal whenever nothing couples consecutive
  rounds: no deadline at all, or ``deadline_policy`` in
  ``{"drop", "partial"}`` (a straggler's unserved bits never cross the
  round boundary — folded rows carry per-row deadlines).
* **Sequential mode** (``deadline_policy="defer"``): a client still
  uploading at the deadline *defers* its remaining update bits to the
  next round (it skips the next model download and resumes the stale
  upload — array state carried between rounds), which couples
  consecutive rounds; the engine then advances round by round, still
  batched over cases.
* **Async mode** (``buffer_k``, FedBuff semantics): there is no fixed
  deadline — aggregation fires as soon as ``buffer_k`` pending uploads
  complete. Each round runs twice on the engine: a free-running pass
  finds the k-th completion time ``t_k`` (causality makes the prefix
  before ``t_k`` identical with or without a cutoff), then a deadline
  pass at ``t_k`` yields the exact unserved bits of the stragglers,
  which defer FedBuff-style. Per-client *staleness* ``τ_i`` (rounds
  elapsed since the client downloaded its model) is reported per round
  so the learning layer can weight stale updates (e.g. ``1/sqrt(1+τ)``).

Deadline policies (``TimelineSchedule.deadline_policy``):

* ``"defer"`` (default, the PR 3/4 behaviour — bitwise unchanged): the
  straggler keeps its unserved bits and resumes next round as a
  zero-compute carrier.
* ``"drop"``: the straggler's unserved bits are discarded at the
  deadline (its served bits were wasted wire time); the client
  re-enters fresh next round.
* ``"partial"``: the *served* fraction counts as a usable partial
  update (``TimelineRound.partial`` maps client → served fraction);
  the unserved remainder is discarded and the client re-enters fresh.

``simulate_timeline_reference`` is the parity oracle: an explicit
per-round Python loop over the *cycle-by-cycle dict simulator*
(``backend="reference"``), fed the engine's exact counter streams via
``repro.net.traffic.CounterStream`` — extended with the same two-pass
rule for async rounds and the same policy folding. Tests require sync
times, per-round served bits, staleness and policy outcomes to agree
at rtol 1e-6 for all three policies and async arrivals, including
elastic membership and multi-PON topologies.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.net.engine import SweepCase, simulate_round_sweep
from repro.net.sim import FLRoundWorkload, RoundResult

__all__ = [
    "DEADLINE_POLICIES",
    "TimelineSchedule",
    "TimelineRound",
    "TimelineResult",
    "simulate_timeline_sweep",
    "simulate_timeline_per_round",
    "simulate_timeline_reference",
]

DEADLINE_POLICIES = ("defer", "drop", "partial")


@dataclass(frozen=True)
class TimelineSchedule:
    """The multi-round structure shared by every case of a sweep.

    ``membership``: optional ``(n_rounds, n_clients)`` bool mask over
    each case's ``workload.clients`` *list positions* — a client masked
    out of round r takes no part in it (downloads nothing, uploads no
    bits). Deferred carriers override the mask: an in-flight stale
    upload finishes regardless of membership (defer, not drop).

    ``m_ud_bits``: optional per-round upload-size override, ``(n_rounds,)``
    scalars or ``(n_rounds, n_clients)`` — the co-simulation feeds the
    measured (compressed) update size of each round.

    ``deadline_s``: optional round deadline(s), scalar or ``(n_rounds,)``
    — the upload phase is cut at the deadline and unfinished clients
    are handled per ``deadline_policy``.

    ``deadline_policy``: what happens to a straggler's unserved bits at
    the deadline — ``"defer"`` (carry to the next round, the default),
    ``"drop"`` (discard) or ``"partial"`` (discard, but report the
    served fraction as a usable partial update).

    ``buffer_k``: async (FedBuff) mode — ignore ``deadline_s`` (must be
    None) and fire each round's aggregation as soon as ``buffer_k``
    pending uploads complete; stragglers defer with staleness.

    All array inputs are normalised and defensively copied once at
    construction: later mutation of the caller's arrays cannot desync
    the folded engine from the sequential/reference loops (which would
    otherwise re-read the caller's memory at different times).
    """

    n_rounds: int
    membership: Optional[np.ndarray] = None
    m_ud_bits: Optional[np.ndarray] = None
    deadline_s: Optional[object] = None
    deadline_policy: str = "defer"
    buffer_k: Optional[int] = None

    def __post_init__(self):
        if self.n_rounds < 1:
            raise ValueError("n_rounds must be >= 1")
        if self.deadline_policy not in DEADLINE_POLICIES:
            raise ValueError(
                f"unknown deadline_policy {self.deadline_policy!r}; "
                f"have {DEADLINE_POLICIES}"
            )
        if self.membership is not None:
            m = np.array(self.membership, dtype=bool)
            if m.ndim != 2 or m.shape[0] != self.n_rounds:
                raise ValueError(
                    f"membership must be (n_rounds, n_clients); "
                    f"got {m.shape}"
                )
            object.__setattr__(self, "membership", m)
        if self.deadline_s is not None:
            d = np.array(self.deadline_s, dtype=np.float64).reshape(-1)
            if d.size not in (1, self.n_rounds):
                raise ValueError(
                    f"deadline_s must be scalar or (n_rounds,); "
                    f"got {d.size} values for {self.n_rounds} rounds"
                )
            object.__setattr__(self, "deadline_s", d)
        elif self.deadline_policy != "defer":
            raise ValueError(
                f"deadline_policy={self.deadline_policy!r} needs "
                "deadline_s (without a deadline nothing is ever cut)"
            )
        if self.m_ud_bits is not None:
            m = np.array(self.m_ud_bits, dtype=np.float64)
            if m.shape[0] != self.n_rounds:
                raise ValueError(
                    f"m_ud_bits must lead with n_rounds="
                    f"{self.n_rounds}; got shape {m.shape}"
                )
            object.__setattr__(self, "m_ud_bits", m)
        if self.buffer_k is not None:
            if int(self.buffer_k) < 1:
                raise ValueError("buffer_k must be >= 1")
            if self.deadline_s is not None:
                raise ValueError(
                    "async mode (buffer_k) fires at the k-th arrival; "
                    "it cannot be combined with deadline_s"
                )
            object.__setattr__(self, "buffer_k", int(self.buffer_k))

    @property
    def asynchronous(self) -> bool:
        return self.buffer_k is not None

    @property
    def couples_rounds(self) -> bool:
        """True when state crosses round boundaries (no folding)."""
        return self.asynchronous or (
            self.deadline_s is not None and self.deadline_policy == "defer"
        )

    def deadline(self, r: int) -> Optional[float]:
        if self.deadline_s is None:
            return None
        d = self.deadline_s
        return float(d[r] if d.size > 1 else d[0])

    def round_m_ud(self, r: int, j: int, default: float) -> float:
        if self.m_ud_bits is None:
            return default
        m = self.m_ud_bits
        return float(m[r] if m.ndim == 1 else m[r, j])


@dataclass
class TimelineRound:
    """One round of one case's timeline."""

    round_index: int
    sync_time: float
    t_start: float
    t_end: float
    ul_bits: Dict[int, float]       # bits actually served this round
    arrived: List[int]              # clients whose update completed
    deferred: Dict[int, float]      # bits carried into the next round
    result: Optional[RoundResult]   # None for empty (no-client) rounds
    # rounds elapsed since each arrived client downloaded its model
    # (0 unless the client deferred across rounds — defer/async modes)
    staleness: Dict[int, int] = field(default_factory=dict)
    # deadline_policy="drop": bits discarded at the deadline per client
    dropped: Dict[int, float] = field(default_factory=dict)
    # deadline_policy="partial": served fraction (usable partial update)
    # per client cut at the deadline
    partial: Dict[int, float] = field(default_factory=dict)


@dataclass
class TimelineResult:
    policy: str
    load: float
    seed: int
    rounds: List[TimelineRound]

    @property
    def sync_times(self) -> np.ndarray:
        return np.array([r.sync_time for r in self.rounds])

    @property
    def total_time_s(self) -> float:
        return float(self.sync_times.sum())


# ---------------------------------------------------------------------------
# per-round workload construction (shared by engine and reference paths)
# ---------------------------------------------------------------------------


def _round_setup(case: SweepCase, schedule: TimelineSchedule, r: int,
                 carry: Dict[int, float]):
    """(clients_r, no_dl_ids, rem_start) for round ``r`` of one case.

    Fresh members take the round's upload size; carriers (clients with
    deferred bits) re-enter with their remaining bits, zero compute time
    and no model download, regardless of the membership mask.
    """
    clients = case.workload.clients
    mask = (schedule.membership[r] if schedule.membership is not None
            else np.ones(len(clients), bool))
    out = []
    rem_start: Dict[int, float] = {}
    for j, c in enumerate(clients):
        if c.client_id in carry:
            bits = carry[c.client_id]
            out.append(replace(c, t_ud=0.0, t_dl=0.0, m_ud_bits=bits))
            rem_start[c.client_id] = bits
        elif mask[j]:
            bits = schedule.round_m_ud(r, j, c.m_ud_bits)
            out.append(replace(c, m_ud_bits=bits))
            rem_start[c.client_id] = bits
    return out, frozenset(carry), rem_start


def _round_view(r: int, t_start: float, result: Optional[RoundResult],
                rem_start: Dict[int, float], t_aggregate: float,
                policy: str = "defer",
                entry: Optional[Dict[int, int]] = None,
                ) -> Tuple[TimelineRound, Dict[int, float]]:
    """Fold one round's RoundResult into a TimelineRound + next carry.

    ``entry`` maps each pending client to the round it downloaded its
    model (maintained by the drivers); arrived clients report staleness
    ``r - entry``.  A ``None`` result is only legal for a round with no
    pending clients — carriers must always be routed into a non-empty
    round, or their bits would silently vanish.
    """
    if result is None:
        if rem_start:
            raise RuntimeError(
                f"round {r} produced no simulation result but has "
                f"pending clients {sorted(rem_start)}: carriers must be "
                "routed into a non-empty round, not dropped"
            )
        rnd = TimelineRound(
            round_index=r, sync_time=t_aggregate, t_start=t_start,
            t_end=t_start + t_aggregate, ul_bits={}, arrived=[],
            deferred={}, result=None,
        )
        return rnd, {}
    remaining = dict(result.ul_remaining or {})
    ul_bits = {
        cid: rem_start[cid] - remaining.get(cid, 0.0)
        for cid in rem_start
    }
    arrived = sorted(cid for cid in rem_start if cid not in remaining)
    staleness = {
        cid: (r - entry.get(cid, r)) if entry is not None else 0
        for cid in arrived
    }
    deferred: Dict[int, float] = {}
    dropped: Dict[int, float] = {}
    partial: Dict[int, float] = {}
    if policy == "defer":
        deferred = remaining
    elif policy == "drop":
        dropped = remaining
    elif policy == "partial":
        partial = {cid: ul_bits[cid] / rem_start[cid] for cid in remaining}
    else:  # pragma: no cover - schedule validation rejects earlier
        raise ValueError(f"unknown deadline_policy {policy!r}")
    rnd = TimelineRound(
        round_index=r, sync_time=result.sync_time, t_start=t_start,
        t_end=t_start + result.sync_time, ul_bits=ul_bits,
        arrived=arrived, deferred=deferred, result=result,
        staleness=staleness, dropped=dropped, partial=partial,
    )
    return rnd, deferred


def _observe_round(collector, case, rnd: TimelineRound,
                   deadline: Optional[float]) -> None:
    """Fold one TimelineRound into the collector: round wall time and
    outcome counts (``record_round``), staleness of arrived updates,
    and deadline slack (deadline − completion) of clients that made the
    cut. Pure reads — a ``None`` collector is a no-op and simulation
    state is never touched."""
    if collector is None:
        return
    if rnd.staleness:
        collector.record_staleness(list(rnd.staleness.values()))
    if deadline is not None and rnd.result is not None and rnd.arrived:
        slack = [deadline - rnd.result.ul_done.get(cid, np.nan)
                 for cid in rnd.arrived]
        collector.record_slack(case.policy, case.load, slack)
    collector.record_round(
        policy=case.policy, load=case.load, seed=case.seed,
        round=rnd.round_index, sync_time=rnd.sync_time,
        t_start=rnd.t_start, t_end=rnd.t_end,
        ul_bits=float(sum(rnd.ul_bits.values())),
        n_arrived=len(rnd.arrived), n_deferred=len(rnd.deferred),
        n_dropped=len(rnd.dropped), n_partial=len(rnd.partial),
    )


def _kth_completion(result: RoundResult, rem_start: Dict[int, float],
                    buffer_k: int) -> float:
    """The async cutoff: completion time of the k-th pending upload.

    Zero-bit uploads complete at the round start (their ``ul_done`` is
    NaN — nothing was ever queued). Fewer than k pending clients fall
    back to the last completion (a plain full round).
    """
    times = sorted(
        0.0 if np.isnan(result.ul_done[cid]) else float(result.ul_done[cid])
        for cid in rem_start
    )
    return times[min(buffer_k, len(times)) - 1]


def _validate(cases: Sequence[SweepCase], schedule: TimelineSchedule):
    cases = list(cases)
    if not cases:
        raise ValueError("timeline sweep needs at least one case")
    for case in cases:
        if case.dl_arrivals is not None or case.ul_arrivals is not None:
            raise ValueError(
                "timeline cases draw from counter streams; injected "
                "arrival matrices are a single-round parity hook"
            )
        if schedule.membership is not None and (
            schedule.membership.shape[1] != len(case.workload.clients)
        ):
            raise ValueError(
                "membership mask width must match workload.clients"
            )
    return cases


# ---------------------------------------------------------------------------
# engine-backed drivers
# ---------------------------------------------------------------------------


def _build_rows(cases, schedule, r, carries):
    """Per-round SweepCase rows + alignment metadata for a batch."""
    row_cases = []
    row_meta = []
    for b, case in enumerate(cases):
        clients_r, no_dl, rem_start = _round_setup(
            case, schedule, r, carries[b]
        )
        if not clients_r:
            row_meta.append((b, None, rem_start))
            continue
        wl = FLRoundWorkload(
            clients=clients_r,
            model_bits=case.workload.model_bits,
            t_aggregate=case.workload.t_aggregate,
        )
        row_meta.append((b, len(row_cases), rem_start))
        row_cases.append(SweepCase(
            workload=wl, load=case.load, policy=case.policy,
            seed=case.seed, stream_round=r, no_dl_ids=no_dl,
            topology=case.topology,
        ))
    return row_cases, row_meta


def _advance_rounds(cfg, cases, schedule, t_round_hint, max_t, policy,
                    deadline_fn, collector=None):
    """The shared round-by-round driver: build rows, resolve each
    round's deadline(s) via ``deadline_fn(r, row_cases, row_meta)``
    (a scalar, or a per-row list), advance the engine, fold results
    and carry deferred state/entry rounds forward."""
    from repro.obs.trace import maybe_span

    B = len(cases)
    carries: List[Dict[int, float]] = [{} for _ in range(B)]
    entries: List[Dict[int, int]] = [{} for _ in range(B)]
    t_now = np.zeros(B)
    out = [TimelineResult(policy=c.policy, load=c.load, seed=c.seed,
                          rounds=[]) for c in cases]
    for r in range(schedule.n_rounds):
        row_cases, row_meta = _build_rows(cases, schedule, r, carries)
        for b, _, rem_start in row_meta:
            for cid in rem_start:
                entries[b].setdefault(cid, r)
        deadlines = deadline_fn(r, row_cases, row_meta)
        with maybe_span(collector, f"timeline:round[{r}]",
                        rows=len(row_cases)):
            results = simulate_round_sweep(
                cfg, row_cases, t_round_hint=t_round_hint, max_t=max_t,
                ul_deadline_s=deadlines, collector=collector,
            ) if row_cases else []
        per_row_dl = isinstance(deadlines, (list, tuple, np.ndarray))
        for b, ridx, rem_start in row_meta:
            res = results[ridx] if ridx is not None else None
            rnd, carry = _round_view(
                r, float(t_now[b]), res, rem_start,
                cases[b].workload.t_aggregate, policy, entries[b],
            )
            out[b].rounds.append(rnd)
            carries[b] = carry
            entries[b] = {cid: entries[b][cid] for cid in carry}
            t_now[b] += rnd.sync_time
            if collector is not None:
                dl = (deadlines[ridx]
                      if per_row_dl and ridx is not None else
                      None if per_row_dl else deadlines)
                _observe_round(collector, cases[b], rnd, dl)
    return out


def _sequential(cfg, cases, schedule, t_round_hint, max_t,
                collector=None):
    """Round-by-round engine advance, carrying deferred bits (the only
    legal order under defer deadlines; also the PR 2 per-round loop that
    the folded mode is benchmarked against)."""
    return _advance_rounds(
        cfg, cases, schedule, t_round_hint, max_t,
        schedule.deadline_policy,
        lambda r, row_cases, row_meta: schedule.deadline(r),
        collector=collector,
    )


def _async(cfg, cases, schedule, t_round_hint, max_t, collector=None):
    """FedBuff-style async rounds: each round is cut at the completion
    time of the ``buffer_k``-th pending upload (two engine passes — a
    free-running pass locates ``t_k``, a deadline pass at ``t_k``
    yields the stragglers' exact unserved bits), and stragglers defer
    with staleness. Cycles whose start precedes ``t_k`` complete, so
    the round's served bits reflect the cutoff at cycle granularity —
    the same rule the reference oracle applies.
    """
    k = schedule.buffer_k

    def deadline_fn(r, row_cases, row_meta):
        # NOTE: the free-running probe pass stays uninstrumented — only
        # the deadline pass (the round that actually happens) feeds the
        # collector, so nothing is double-counted.
        free = simulate_round_sweep(
            cfg, row_cases, t_round_hint=t_round_hint, max_t=max_t,
        )
        deadlines: List[Optional[float]] = [None] * len(row_cases)
        for _, ridx, rem_start in row_meta:
            if ridx is not None:
                deadlines[ridx] = _kth_completion(
                    free[ridx], rem_start, k
                )
        return deadlines

    return _advance_rounds(
        cfg, cases, schedule, t_round_hint, max_t, "defer", deadline_fn,
        collector=collector,
    )


def _folded(cfg, cases, schedule, t_round_hint, max_t, collector=None):
    """The whole timeline as ONE stacked simulation: the round axis is
    folded into the engine batch axis (legal whenever rounds are
    independent given their start times — no deadline, or drop/partial
    policies whose stragglers never carry state forward; each row then
    runs under its own round's deadline)."""
    rows = []
    row_deadlines: List[Optional[float]] = []
    meta = []            # (b, r, rem_start, row_index or None)
    for b, case in enumerate(cases):
        for r in range(schedule.n_rounds):
            clients_r, _, rem_start = _round_setup(case, schedule, r, {})
            if not clients_r:
                meta.append((b, r, rem_start, None))
                continue
            wl = FLRoundWorkload(
                clients=clients_r,
                model_bits=case.workload.model_bits,
                t_aggregate=case.workload.t_aggregate,
            )
            meta.append((b, r, rem_start, len(rows)))
            rows.append(SweepCase(
                workload=wl, load=case.load, policy=case.policy,
                seed=case.seed, stream_round=r,
                topology=case.topology,
            ))
            row_deadlines.append(schedule.deadline(r))
    from repro.obs.trace import maybe_span

    has_deadline = schedule.deadline_s is not None
    with maybe_span(collector, "timeline:folded", rows=len(rows),
                    rounds=schedule.n_rounds):
        results = simulate_round_sweep(
            cfg, rows, t_round_hint=t_round_hint, max_t=max_t,
            ul_deadline_s=row_deadlines if has_deadline else None,
            collector=collector,
        ) if rows else []
    out = [TimelineResult(policy=c.policy, load=c.load, seed=c.seed,
                          rounds=[]) for c in cases]
    t_now = np.zeros(len(cases))
    for b, r, rem_start, ridx in meta:
        res = results[ridx] if ridx is not None else None
        rnd, _ = _round_view(
            r, float(t_now[b]), res, rem_start,
            cases[b].workload.t_aggregate, schedule.deadline_policy,
        )
        out[b].rounds.append(rnd)
        t_now[b] += rnd.sync_time
        if collector is not None:
            _observe_round(collector, cases[b], rnd,
                           schedule.deadline(r))
    return out


def simulate_timeline_sweep(cfg, cases: Sequence[SweepCase],
                            schedule: TimelineSchedule,
                            mode: str = "auto",
                            t_round_hint: float = 10.0,
                            max_t: float = 600.0,
                            collector=None) -> List[TimelineResult]:
    """Advance the full multi-round timeline for every case.

    ``mode="auto"`` folds the round axis into the batch (one stacked
    simulation) when nothing couples consecutive rounds — no deadline,
    or ``deadline_policy`` in ``{"drop", "partial"}`` — and falls back
    to the sequential carry loop for defer deadlines;
    ``schedule.buffer_k`` selects the async (FedBuff) driver.
    ``"folded"``/``"sequential"`` force a path (parity tests check they
    agree when both are legal).

    ``collector`` (``repro.obs.Collector``, optional) records engine
    phase metrics, per-round outcomes (``record_round``), upload-delay
    and deadline-slack histograms and staleness counts; ``None`` (the
    default) is bitwise identical to an uninstrumented run. Async
    schedules instrument only the deadline pass — the free-running
    probe pass is a search, not a simulated round.
    """
    cases = _validate(cases, schedule)
    if schedule.asynchronous:
        if mode == "folded":
            raise ValueError(
                "async rounds couple consecutive rounds (stragglers "
                "defer); folded mode is unavailable"
            )
        return _async(cfg, cases, schedule, t_round_hint, max_t,
                      collector=collector)
    if mode == "auto":
        mode = "sequential" if schedule.couples_rounds else "folded"
    if mode == "folded":
        if schedule.couples_rounds:
            raise ValueError(
                "deadline deferral couples consecutive rounds; folded "
                "mode requires a schedule without deferred state "
                "(no deadline, or drop/partial policies)"
            )
        return _folded(cfg, cases, schedule, t_round_hint, max_t,
                       collector=collector)
    if mode == "sequential":
        return _sequential(cfg, cases, schedule, t_round_hint, max_t,
                           collector=collector)
    raise ValueError(f"unknown mode {mode!r}")


def simulate_timeline_per_round(cfg, cases: Sequence[SweepCase],
                                schedule: TimelineSchedule,
                                t_round_hint: float = 10.0,
                                max_t: float = 600.0,
                                collector=None,
                                ) -> List[TimelineResult]:
    """The PR 2 per-round loop: one engine call per round, queue state
    rebuilt every round. Identical results to ``simulate_timeline_sweep``
    (same streams); kept as the benchmark baseline. Async schedules run
    the (inherently per-round) two-pass async driver."""
    cases = _validate(cases, schedule)
    if schedule.asynchronous:
        return _async(cfg, cases, schedule, t_round_hint, max_t,
                      collector=collector)
    return _sequential(cfg, cases, schedule, t_round_hint, max_t,
                       collector=collector)


# ---------------------------------------------------------------------------
# reference loop (parity oracle)
# ---------------------------------------------------------------------------


def simulate_timeline_reference(cfg, cases: Sequence[SweepCase],
                                schedule: TimelineSchedule,
                                t_round_hint: float = 10.0,
                                max_t: float = 600.0,
                                ) -> List[TimelineResult]:
    """Per-round loop over the cycle-by-cycle *dict* simulator.

    Every round rebuilds the reference simulator from scratch and feeds
    it the engine's counter-based arrival streams
    (``CounterStream.source``), so the timeline engine must reproduce
    its sync times and per-round bits exactly (rtol 1e-6) — including
    elastic membership, all three deadline policies and async rounds
    (the same two-pass k-th-completion rule, on fresh stream cursors
    per pass).
    """
    from repro.kernels.traffic.ops import make_stream_key
    from repro.net.engine import _case_bg_rate
    from repro.net.multi_pon import simulate_multi_pon_round
    from repro.net.sim import simulate_round
    from repro.net.traffic import CounterStream

    cases = _validate(cases, schedule)
    policy = schedule.deadline_policy
    out = []
    for case in cases:
        carry: Dict[int, float] = {}
        entry: Dict[int, int] = {}
        t_now = 0.0
        res = TimelineResult(policy=case.policy, load=case.load,
                             seed=case.seed, rounds=[])
        for r in range(schedule.n_rounds):
            clients_r, no_dl, rem_start = _round_setup(
                case, schedule, r, carry
            )
            for cid in rem_start:
                entry.setdefault(cid, r)
            if not clients_r:
                rnd, carry = _round_view(
                    r, t_now, None, rem_start,
                    case.workload.t_aggregate, policy, entry,
                )
                res.rounds.append(rnd)
                t_now += rnd.sync_time
                continue
            wl = FLRoundWorkload(
                clients=clients_r,
                model_bits=case.workload.model_bits,
                t_aggregate=case.workload.t_aggregate,
            )

            def run_ref(deadline):
                """One reference round under ``deadline`` — fresh
                stream cursors per call, so the async two-pass replays
                the identical arrival process."""
                if case.topology is not None and not case.topology.trivial:
                    # the cycle-by-cycle multi-PON oracle keys its own
                    # (seed, phase, round, pon) counter streams
                    return simulate_multi_pon_round(
                        cfg, case.topology, wl, case.load, case.policy,
                        seed=case.seed, t_round_hint=t_round_hint,
                        max_t=max_t, ul_deadline_s=deadline,
                        no_dl_ids=no_dl, stream_round=r,
                    )
                row = SweepCase(workload=wl, load=case.load,
                                policy=case.policy, seed=case.seed)
                per_onu = (_case_bg_rate(row, cfg, t_round_hint)
                           / cfg.n_onus)
                streams = [
                    CounterStream(
                        make_stream_key(case.seed, phase, r), per_onu,
                        cfg.cycle_time_s, cfg.n_onus,
                        burst_packets=cfg.bg_burst_packets,
                    )
                    for phase in (0, 1)
                ]
                return simulate_round(
                    cfg, wl, case.load, case.policy, seed=case.seed,
                    t_round_hint=t_round_hint, backend="reference",
                    _dl_sources=[streams[0].source(i)
                                 for i in range(cfg.n_onus)],
                    _ul_sources=[streams[1].source(i)
                                 for i in range(cfg.n_onus)],
                    ul_deadline_s=deadline,
                    no_dl_ids=no_dl,
                )

            if schedule.asynchronous:
                free = run_ref(None)
                result = run_ref(
                    _kth_completion(free, rem_start, schedule.buffer_k)
                )
            else:
                result = run_ref(schedule.deadline(r))
            rnd, carry = _round_view(
                r, t_now, result, rem_start,
                case.workload.t_aggregate, policy, entry,
            )
            entry = {cid: entry[cid] for cid in carry}
            res.rounds.append(rnd)
            t_now += rnd.sync_time
        out.append(res)
    return out
