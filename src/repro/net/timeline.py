"""Multi-round timeline engine over the batched PON round engine.

The paper's headline quantities (Fig. 3 training-time saving,
accuracy-vs-wall-clock) are *multi-round*: R synchronisation rounds back
to back, with elastic client membership and (optionally) per-round
deadlines. After PR 2 the co-simulation still drove the vectorized
engine one round at a time from a Python loop, rebuilding layout and
queue state every round. This module advances the whole training
timeline in one call:

* **Folded mode** (rounds independent given their start times): the
  round axis folds into the engine's batch axis — all R rounds of all
  B cases run as ONE stacked simulation. One ``_Layout`` build, one
  ``_BgQueues``/``_FLQueues`` allocation carried across the whole
  timeline, one cycle loop whose per-cycle Python cost is amortised
  over R·B rows instead of B. The counter-based arrival sampler
  (``repro.kernels.traffic``) keys round ``r``'s stream by
  ``(seed, phase, r)``, so every row addresses its own arrivals with no
  sequential draw state. Legal whenever nothing couples consecutive
  rounds: no deadline at all, or ``deadline_policy`` in
  ``{"drop", "partial"}`` (a straggler's unserved bits never cross the
  round boundary — folded rows carry per-row deadlines).
* **Sequential mode** (``deadline_policy="defer"``): a client still
  uploading at the deadline *defers* its remaining update bits to the
  next round (it skips the next model download and resumes the stale
  upload — array state carried between rounds), which couples
  consecutive rounds; the engine then advances round by round, still
  batched over cases.
* **Async mode** (``buffer_k``, FedBuff semantics): there is no fixed
  deadline — aggregation fires as soon as ``buffer_k`` pending uploads
  complete. Each round runs twice on the engine: a free-running pass
  finds the k-th completion time ``t_k`` (causality makes the prefix
  before ``t_k`` identical with or without a cutoff), then a deadline
  pass at ``t_k`` yields the exact unserved bits of the stragglers,
  which defer FedBuff-style. Per-client *staleness* ``τ_i`` (rounds
  elapsed since the client downloaded its model) is reported per round
  so the learning layer can weight stale updates (e.g. ``1/sqrt(1+τ)``).

Deadline policies (``TimelineSchedule.deadline_policy``):

* ``"defer"`` (default, the PR 3/4 behaviour — bitwise unchanged): the
  straggler keeps its unserved bits and resumes next round as a
  zero-compute carrier.
* ``"drop"``: the straggler's unserved bits are discarded at the
  deadline (its served bits were wasted wire time); the client
  re-enters fresh next round.
* ``"partial"``: the *served* fraction counts as a usable partial
  update (``TimelineRound.partial`` maps client → served fraction);
  the unserved remainder is discarded and the client re-enters fresh.

``simulate_timeline_reference`` is the parity oracle: an explicit
per-round Python loop over the *cycle-by-cycle dict simulator*
(``backend="reference"``), fed the engine's exact counter streams via
``repro.net.traffic.CounterStream`` — extended with the same two-pass
rule for async rounds and the same policy folding. Tests require sync
times, per-round served bits, staleness and policy outcomes to agree
at rtol 1e-6 for all three policies and async arrivals, including
elastic membership and multi-PON topologies.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field, replace
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.faults import FaultSchedule, RetryPolicy
from repro.net.engine import SweepCase, _round_sweep
from repro.net.sim import FLRoundWorkload, RoundResult

__all__ = [
    "DEADLINE_POLICIES",
    "TimelineSchedule",
    "TimelineRound",
    "TimelineResult",
    "simulate_timeline_sweep",
    "simulate_timeline_per_round",
    "simulate_timeline_reference",
]

DEADLINE_POLICIES = ("defer", "drop", "partial")


@dataclass(frozen=True)
class TimelineSchedule:
    """The multi-round structure shared by every case of a sweep.

    ``membership``: optional ``(n_rounds, n_clients)`` bool mask over
    each case's ``workload.clients`` *list positions* — a client masked
    out of round r takes no part in it (downloads nothing, uploads no
    bits). Deferred carriers override the mask: an in-flight stale
    upload finishes regardless of membership (defer, not drop).

    ``m_ud_bits``: optional per-round upload-size override, ``(n_rounds,)``
    scalars or ``(n_rounds, n_clients)`` — the co-simulation feeds the
    measured (compressed) update size of each round.

    ``deadline_s``: optional round deadline(s), scalar or ``(n_rounds,)``
    — the upload phase is cut at the deadline and unfinished clients
    are handled per ``deadline_policy``.

    ``deadline_policy``: what happens to a straggler's unserved bits at
    the deadline — ``"defer"`` (carry to the next round, the default),
    ``"drop"`` (discard) or ``"partial"`` (discard, but report the
    served fraction as a usable partial update).

    ``buffer_k``: async (FedBuff) mode — ignore ``deadline_s`` (must be
    None) and fire each round's aggregation as soon as ``buffer_k``
    pending uploads complete; stragglers defer with staleness.

    ``faults`` (``repro.faults.FaultSchedule``): deterministic client
    dropout, upstream ONU/link outage windows and payload loss drawn
    from counter-based streams. Failed uploads retransmit under
    ``retry`` (``repro.faults.RetryPolicy``; defaults to
    ``RetryPolicy()`` when faults can fail uploads): the client backs
    off for ``delay_rounds(attempt)`` rounds — during which it is *not*
    re-admitted by the membership mask; retry suppression overrides the
    mask exactly like deferred carriers do, so a masked-in client can
    never hold two uploads in flight — then re-enters like a carrier
    (no fresh download, zero compute, full pending bits). Past
    ``max_retries`` it abandons the update and re-enters fresh through
    membership. A ``trivial`` fault schedule is bitwise identical to
    ``faults=None``.

    ``quorum_frac``: quorum aggregation — a deadlined round commits
    only when at least ``ceil(quorum_frac * n_pending)`` un-faulted
    uploads arrived by the deadline; otherwise the round's deadline
    doubles and the round re-runs (identical counter streams make the
    rerun a superset of the first pass), up to ``quorum_max_extends``
    times, after which the round reports ``quorum_met=False`` and the
    learning layer degrades to the previous global model. Requires
    ``deadline_s``; incompatible with ``buffer_k`` (async mode is its
    own arrival quorum).

    All array inputs are normalised and defensively copied once at
    construction: later mutation of the caller's arrays cannot desync
    the folded engine from the sequential/reference loops (which would
    otherwise re-read the caller's memory at different times).
    """

    n_rounds: int
    membership: Optional[np.ndarray] = None
    m_ud_bits: Optional[np.ndarray] = None
    deadline_s: Optional[object] = None
    deadline_policy: str = "defer"
    buffer_k: Optional[int] = None
    faults: Optional[FaultSchedule] = None
    retry: Optional[RetryPolicy] = None
    quorum_frac: Optional[float] = None
    quorum_max_extends: int = 2

    def __post_init__(self):
        if self.n_rounds < 1:
            raise ValueError("n_rounds must be >= 1")
        if self.deadline_policy not in DEADLINE_POLICIES:
            raise ValueError(
                f"unknown deadline_policy {self.deadline_policy!r}; "
                f"have {DEADLINE_POLICIES}"
            )
        if self.membership is not None:
            m = np.array(self.membership, dtype=bool)
            if m.ndim != 2 or m.shape[0] != self.n_rounds:
                raise ValueError(
                    f"membership must be (n_rounds, n_clients); "
                    f"got {m.shape}"
                )
            object.__setattr__(self, "membership", m)
        if self.deadline_s is not None:
            d = np.array(self.deadline_s, dtype=np.float64).reshape(-1)
            if d.size not in (1, self.n_rounds):
                raise ValueError(
                    f"deadline_s must be scalar or (n_rounds,); "
                    f"got {d.size} values for {self.n_rounds} rounds"
                )
            object.__setattr__(self, "deadline_s", d)
        elif self.deadline_policy != "defer":
            raise ValueError(
                f"deadline_policy={self.deadline_policy!r} needs "
                "deadline_s (without a deadline nothing is ever cut)"
            )
        if self.m_ud_bits is not None:
            m = np.array(self.m_ud_bits, dtype=np.float64)
            if m.shape[0] != self.n_rounds:
                raise ValueError(
                    f"m_ud_bits must lead with n_rounds="
                    f"{self.n_rounds}; got shape {m.shape}"
                )
            object.__setattr__(self, "m_ud_bits", m)
        if self.buffer_k is not None:
            if int(self.buffer_k) < 1:
                raise ValueError("buffer_k must be >= 1")
            if self.deadline_s is not None:
                raise ValueError(
                    "async mode (buffer_k) fires at the k-th arrival; "
                    "it cannot be combined with deadline_s"
                )
            object.__setattr__(self, "buffer_k", int(self.buffer_k))
        if self.faults is not None and not isinstance(
            self.faults, FaultSchedule
        ):
            raise TypeError("faults must be a repro.faults.FaultSchedule")
        if self.retry is not None and not isinstance(
            self.retry, RetryPolicy
        ):
            raise TypeError("retry must be a repro.faults.RetryPolicy")
        if self.quorum_frac is not None:
            q = float(self.quorum_frac)
            if not 0.0 < q <= 1.0:
                raise ValueError(
                    f"quorum_frac must be in (0, 1]; got {q}"
                )
            if self.buffer_k is not None:
                raise ValueError(
                    "async mode (buffer_k) is its own arrival quorum; "
                    "it cannot be combined with quorum_frac"
                )
            if self.deadline_s is None:
                raise ValueError(
                    "quorum_frac needs deadline_s: without a deadline "
                    "every pending upload always arrives"
                )
            object.__setattr__(self, "quorum_frac", q)
        if int(self.quorum_max_extends) < 0:
            raise ValueError("quorum_max_extends must be >= 0")
        object.__setattr__(
            self, "quorum_max_extends", int(self.quorum_max_extends)
        )

    @property
    def asynchronous(self) -> bool:
        return self.buffer_k is not None

    @property
    def active_faults(self) -> Optional[FaultSchedule]:
        """The fault schedule, or None when absent/trivial — every
        fault code path gates on this, which is what makes a trivial
        ``FaultSchedule()`` bitwise identical to ``faults=None``."""
        f = self.faults
        return None if f is None or f.trivial else f

    @property
    def retry_policy(self) -> RetryPolicy:
        return self.retry if self.retry is not None else RetryPolicy()

    @property
    def couples_rounds(self) -> bool:
        """True when state crosses round boundaries (no folding)."""
        faults = self.active_faults
        return (
            self.asynchronous
            or (self.deadline_s is not None
                and self.deadline_policy == "defer")
            or (faults is not None and faults.couples_rounds)
            or self.quorum_frac is not None
        )

    def deadline(self, r: int) -> Optional[float]:
        if self.deadline_s is None:
            return None
        d = self.deadline_s
        return float(d[r] if d.size > 1 else d[0])

    def round_m_ud(self, r: int, j: int, default: float) -> float:
        if self.m_ud_bits is None:
            return default
        m = self.m_ud_bits
        return float(m[r] if m.ndim == 1 else m[r, j])


@dataclass
class TimelineRound:
    """One round of one case's timeline."""

    round_index: int
    sync_time: float
    t_start: float
    t_end: float
    ul_bits: Dict[int, float]       # bits actually served this round
    arrived: List[int]              # clients whose update completed
    deferred: Dict[int, float]      # bits carried into the next round
    result: Optional[RoundResult]   # None for empty (no-client) rounds
    # rounds elapsed since each arrived client downloaded its model
    # (0 unless the client deferred across rounds — defer/async modes)
    staleness: Dict[int, int] = field(default_factory=dict)
    # deadline_policy="drop": bits discarded at the deadline per client
    dropped: Dict[int, float] = field(default_factory=dict)
    # deadline_policy="partial": served fraction (usable partial update)
    # per client cut at the deadline
    partial: Dict[int, float] = field(default_factory=dict)
    # fault outcomes (repro.faults): clients that died mid-upload this
    # round (bits they served before dying — wasted wire time), and
    # completed uploads whose payload arrived corrupted
    failed: Dict[int, float] = field(default_factory=dict)
    lost: List[int] = field(default_factory=list)
    # failed clients' scheduled retransmission round / abandonments
    retry_at: Dict[int, int] = field(default_factory=dict)
    gave_up: List[int] = field(default_factory=list)
    # quorum aggregation: whether the round met its arrival quorum
    # (None = no quorum configured) and how often the deadline doubled
    quorum_met: Optional[bool] = None
    deadline_extensions: int = 0
    # multi-tenant cases: job_id -> this round's per-job sync time
    # (CPS tier; empty for single-tenant rounds and rounds the job
    # sat out under its cadence)
    job_sync: Dict[int, float] = field(default_factory=dict)


@dataclass
class TimelineResult:
    policy: str
    load: float
    seed: int
    rounds: List[TimelineRound]

    @property
    def sync_times(self) -> np.ndarray:
        return np.array([r.sync_time for r in self.rounds])

    @property
    def total_time_s(self) -> float:
        return float(self.sync_times.sum())


# ---------------------------------------------------------------------------
# per-round workload construction (shared by engine and reference paths)
# ---------------------------------------------------------------------------


class _RetryEntry(NamedTuple):
    """An in-flight retransmission: due round + the bits to re-send."""

    due_round: int
    bits: float
    attempt: int


class _FaultState:
    """Per-case fault bookkeeping carried across rounds by every
    driver (sequential, async and the reference loop)."""

    __slots__ = ("retries", "attempts")

    def __init__(self):
        # in-flight retransmissions: client -> _RetryEntry
        self.retries: Dict[int, _RetryEntry] = {}
        # consecutive failed attempts per client (cleared on a clean
        # arrival or on giving up)
        self.attempts: Dict[int, int] = {}


_MIN_FAULT_BITS = 2.0   # dropout truncation floor (avoid 0-bit uploads)


def _round_setup(case: SweepCase, schedule: TimelineSchedule, r: int,
                 carry: Dict[int, float],
                 retries: Optional[Dict[int, _RetryEntry]] = None):
    """(clients_r, no_dl_ids, rem_start, drops) for round ``r``.

    Fresh members take the round's upload size; carriers (clients with
    deferred bits) re-enter with their remaining bits, zero compute time
    and no model download, regardless of the membership mask. Retry
    entries behave the same two ways: one *due* (``due_round <= r``)
    re-enters exactly like a carrier; one still backing off suppresses
    the client's fresh membership entry — the invariant that a
    membership mask can never revive a client inside an in-flight
    deferred/retry upload holds by construction (regression-tested).

    ``drops`` maps this round's dropout victims (``schedule.faults``)
    to their *full* pending bits; their simulated upload is truncated
    at the death point (``_MIN_FAULT_BITS`` floor), and the full
    payload is what the retry will re-send.
    """
    clients = case.workload.clients
    mask = (schedule.membership[r] if schedule.membership is not None
            else np.ones(len(clients), bool))
    retries = retries or {}
    out = []
    rem_start: Dict[int, float] = {}
    no_dl = set(carry)
    for j, c in enumerate(clients):
        cid = c.client_id
        if cid in carry:
            if cid in retries:       # pragma: no cover - internal guard
                raise RuntimeError(
                    f"client {cid} is both a deferred carrier and an "
                    "in-flight retry at round "
                    f"{r}: fault bookkeeping desynced"
                )
            bits = carry[cid]
            out.append(replace(c, t_ud=0.0, t_dl=0.0, m_ud_bits=bits))
            rem_start[cid] = bits
        elif cid in retries:
            ent = retries[cid]
            if ent.due_round > r:
                continue             # backing off: mask never revives
            retries.pop(cid)         # in flight again from this round
            out.append(replace(c, t_ud=0.0, t_dl=0.0,
                               m_ud_bits=ent.bits))
            rem_start[cid] = ent.bits
            no_dl.add(cid)
        elif mask[j]:
            bits = schedule.round_m_ud(r, j, c.m_ud_bits)
            out.append(replace(c, m_ud_bits=bits))
            rem_start[cid] = bits
    drops: Dict[int, float] = {}
    faults = schedule.active_faults
    if faults is not None and faults.dropout_rate > 0.0 and rem_start:
        frac = faults.dropouts(r, sorted(rem_start), case.seed)
        if frac:
            for i, c in enumerate(out):
                f = frac.get(c.client_id)
                if f is None:
                    continue
                full = c.m_ud_bits
                cut = min(max(f * full, _MIN_FAULT_BITS), full)
                out[i] = replace(c, m_ud_bits=cut)
                rem_start[c.client_id] = cut
                drops[c.client_id] = full
    return out, frozenset(no_dl), rem_start, drops


def _round_view(r: int, t_start: float, result: Optional[RoundResult],
                rem_start: Dict[int, float], t_aggregate: float,
                policy: str = "defer",
                entry: Optional[Dict[int, int]] = None,
                ) -> Tuple[TimelineRound, Dict[int, float]]:
    """Fold one round's RoundResult into a TimelineRound + next carry.

    ``entry`` maps each pending client to the round it downloaded its
    model (maintained by the drivers); arrived clients report staleness
    ``r - entry``.  A ``None`` result is only legal for a round with no
    pending clients — carriers must always be routed into a non-empty
    round, or their bits would silently vanish.
    """
    if result is None:
        if rem_start:
            raise RuntimeError(
                f"round {r} produced no simulation result but has "
                f"pending clients {sorted(rem_start)}: carriers must be "
                "routed into a non-empty round, not dropped"
            )
        rnd = TimelineRound(
            round_index=r, sync_time=t_aggregate, t_start=t_start,
            t_end=t_start + t_aggregate, ul_bits={}, arrived=[],
            deferred={}, result=None,
        )
        return rnd, {}
    remaining = dict(result.ul_remaining or {})
    ul_bits = {
        cid: rem_start[cid] - remaining.get(cid, 0.0)
        for cid in rem_start
    }
    arrived = sorted(cid for cid in rem_start if cid not in remaining)
    staleness = {
        cid: (r - entry.get(cid, r)) if entry is not None else 0
        for cid in arrived
    }
    deferred: Dict[int, float] = {}
    dropped: Dict[int, float] = {}
    partial: Dict[int, float] = {}
    if policy == "defer":
        deferred = remaining
    elif policy == "drop":
        dropped = remaining
    elif policy == "partial":
        partial = {cid: ul_bits[cid] / rem_start[cid] for cid in remaining}
    else:  # pragma: no cover - schedule validation rejects earlier
        raise ValueError(f"unknown deadline_policy {policy!r}")
    rnd = TimelineRound(
        round_index=r, sync_time=result.sync_time, t_start=t_start,
        t_end=t_start + result.sync_time, ul_bits=ul_bits,
        arrived=arrived, deferred=deferred, result=result,
        staleness=staleness, dropped=dropped, partial=partial,
    )
    return rnd, deferred


def _observe_round(collector, case, rnd: TimelineRound,
                   deadline: Optional[float]) -> None:
    """Fold one TimelineRound into the collector: round wall time and
    outcome counts (``record_round``), staleness of arrived updates,
    and deadline slack (deadline − completion) of clients that made the
    cut. Pure reads — a ``None`` collector is a no-op and simulation
    state is never touched."""
    if collector is None:
        return
    if rnd.staleness:
        collector.record_staleness(list(rnd.staleness.values()))
    if deadline is not None and rnd.result is not None and rnd.arrived:
        slack = [deadline - rnd.result.ul_done.get(cid, np.nan)
                 for cid in rnd.arrived]
        collector.record_slack(case.policy, case.load, slack)
    collector.record_round(
        policy=case.policy, load=case.load, seed=case.seed,
        round=rnd.round_index, sync_time=rnd.sync_time,
        t_start=rnd.t_start, t_end=rnd.t_end,
        ul_bits=float(sum(rnd.ul_bits.values())),
        n_arrived=len(rnd.arrived), n_deferred=len(rnd.deferred),
        n_dropped=len(rnd.dropped), n_partial=len(rnd.partial),
    )


def _round_faulted(schedule: TimelineSchedule, case, r: int,
                   rem_start: Dict[int, float],
                   drops: Dict[int, float]) -> frozenset:
    """The round's faulted clients: dropout victims plus the loss draw.

    The loss draw covers every *pending* client (not just the arrived
    ones), so the set is a pure function of ``(round, pending)`` —
    identical for the quorum rerun, the async probe pass and the
    reference oracle.
    """
    faults = schedule.active_faults
    lost = (faults.losses(r, sorted(rem_start), case.seed)
            if faults is not None and faults.loss_rate > 0.0 and rem_start
            else frozenset())
    return frozenset(drops) | lost


def _effective_arrived(result: RoundResult, rem_start: Dict[int, float],
                       faulted: frozenset) -> List[int]:
    """Uploads that completed AND were not cancelled by a fault — the
    arrivals the quorum counts (shared by engine drivers and oracle)."""
    remaining = result.ul_remaining or {}
    return [cid for cid in rem_start
            if cid not in remaining and cid not in faulted]


def _apply_round_faults(schedule: TimelineSchedule, case, r: int,
                        rnd: TimelineRound, rem_start: Dict[int, float],
                        carry: Dict[int, float], drops: Dict[int, float],
                        fstate: _FaultState,
                        collector=None) -> Dict[int, float]:
    """Cancel faulted arrivals, book retry-with-backoff entries and
    return the updated carry (shared by the sequential/async drivers
    and the reference loop — both backends fold faults identically).

    Dropout victims are failed this round regardless of deadline
    policy: their served bits were wasted wire time (``rnd.failed``),
    and the retry re-sends the *full* payload. Loss victims completed
    the wire transfer but the payload is discarded (``rnd.lost``); the
    retry re-sends the failure round's pending bits (fragment
    retransmission is not modelled). Either way the client backs off
    ``retry.delay_rounds(attempt)`` rounds (``rnd.retry_at``) or — past
    ``max_retries`` attempts — abandons the update (``rnd.gave_up``)
    and re-enters fresh through membership.
    """
    faults = schedule.active_faults
    if faults is None:
        return carry
    retry = schedule.retry_policy

    def book(cid: int, bits: float):
        attempt = fstate.attempts.get(cid, 0) + 1
        if attempt > retry.max_retries:
            fstate.attempts.pop(cid, None)
            rnd.gave_up.append(cid)
            if collector is not None:
                collector.event("fault.gave_up", round=r, client=cid,
                                attempts=attempt - 1, seed=case.seed)
            return
        fstate.attempts[cid] = attempt
        due = r + retry.delay_rounds(attempt)
        fstate.retries[cid] = _RetryEntry(due, bits, attempt)
        rnd.retry_at[cid] = due

    for cid in sorted(drops):
        rnd.failed[cid] = rnd.ul_bits.get(cid, 0.0)
        if cid in rnd.arrived:
            rnd.arrived.remove(cid)
        rnd.staleness.pop(cid, None)
        carry.pop(cid, None)
        rnd.deferred.pop(cid, None)
        rnd.dropped.pop(cid, None)
        rnd.partial.pop(cid, None)
        book(cid, drops[cid])
        if collector is not None:
            collector.event("fault.dropout", round=r, client=cid,
                            wasted_bits=rnd.failed[cid], seed=case.seed)
    if faults.loss_rate > 0.0 and rnd.arrived:
        lost_draw = faults.losses(r, sorted(rem_start), case.seed)
        for cid in [c for c in rnd.arrived if c in lost_draw]:
            rnd.arrived.remove(cid)
            rnd.staleness.pop(cid, None)
            rnd.lost.append(cid)
            book(cid, rem_start[cid])
            if collector is not None:
                collector.event("fault.loss", round=r, client=cid,
                                bits=rem_start[cid], seed=case.seed)
    for cid in rnd.arrived:          # a clean arrival resets backoff
        fstate.attempts.pop(cid, None)
    return carry


def _kth_completion(result: RoundResult, rem_start: Dict[int, float],
                    buffer_k: int,
                    exclude: frozenset = frozenset()) -> Optional[float]:
    """The async cutoff: completion time of the k-th pending upload.

    Zero-bit uploads complete at the round start (their ``ul_done`` is
    NaN — nothing was ever queued). Fewer than k pending clients fall
    back to the last completion (a plain full round). ``exclude``
    (the round's faulted clients) never counts toward the buffer — the
    aggregator waits for the k-th *valid* update; if nothing valid is
    pending the round runs free (``None``: no deadline)."""
    times = sorted(
        0.0 if np.isnan(result.ul_done[cid]) else float(result.ul_done[cid])
        for cid in rem_start if cid not in exclude
    )
    if not times:
        return None
    return times[min(buffer_k, len(times)) - 1]


def _validate(cases: Sequence[SweepCase], schedule: TimelineSchedule):
    cases = list(cases)
    if not cases:
        raise ValueError("timeline sweep needs at least one case")
    for case in cases:
        if case.dl_arrivals is not None or case.ul_arrivals is not None:
            raise ValueError(
                "timeline cases draw from counter streams; injected "
                "arrival matrices are a single-round parity hook"
            )
        if schedule.membership is not None and (
            schedule.membership.shape[1] != len(case.workload.clients)
        ):
            raise ValueError(
                "membership mask width must match workload.clients"
            )
    return cases


# ---------------------------------------------------------------------------
# engine-backed drivers
# ---------------------------------------------------------------------------


def _case_n_pons(case) -> int:
    return case.topology.n_pons if case.topology is not None else 1


def _build_rows(cases, schedule, r, carries, fstates=None):
    """Per-round SweepCase rows + alignment metadata for a batch.

    Metadata rows are ``(b, row_index_or_None, rem_start, drops)``;
    ``fstates`` (per-case ``_FaultState``) supplies in-flight retries
    whose due entries re-enter this round.
    """
    row_cases = []
    row_meta = []
    for b, case in enumerate(cases):
        clients_r, no_dl, rem_start, drops = _round_setup(
            case, schedule, r, carries[b],
            fstates[b].retries if fstates is not None else None,
        )
        if not clients_r:
            row_meta.append((b, None, rem_start, drops))
            continue
        wl = FLRoundWorkload(
            clients=clients_r,
            model_bits=case.workload.model_bits,
            t_aggregate=case.workload.t_aggregate,
        )
        row_meta.append((b, len(row_cases), rem_start, drops))
        row_cases.append(SweepCase(
            workload=wl, load=case.load, policy=case.policy,
            seed=case.seed, stream_round=r, no_dl_ids=no_dl,
            topology=case.topology,
        ))
    return row_cases, row_meta


def _round_outages(cases, schedule, r, row_meta):
    """Per-engine-row outage windows for round ``r`` (aligned with the
    round's row_cases), or None when outage injection is inactive."""
    faults = schedule.active_faults
    if faults is None or faults.outage_rate <= 0.0:
        return None
    n_rows = sum(1 for _, ridx, _, _ in row_meta if ridx is not None)
    outages: List[Optional[np.ndarray]] = [None] * n_rows
    for b, ridx, _, _ in row_meta:
        if ridx is not None:
            outages[ridx] = faults.outage_windows(
                r, _case_n_pons(cases[b]), cases[b].seed
            )
    return outages


def _advance_rounds(cfg, cases, schedule, t_round_hint, max_t, policy,
                    deadline_fn, collector=None, backend=None):
    """The shared round-by-round driver: build rows, resolve each
    round's deadline(s) via ``deadline_fn(r, row_cases, row_meta,
    outages)`` (a scalar, or a per-row list), advance the engine, apply
    the round's faults/quorum and carry deferred + retry state forward.

    Quorum reruns (doubled deadline) re-advance only the unmet rows;
    like the async probe pass they stay uninstrumented at the engine
    level — only the first pass feeds phase metrics — but each
    extension emits a ``quorum.extend`` event.
    """
    import math

    from repro.obs.trace import maybe_span

    B = len(cases)
    carries: List[Dict[int, float]] = [{} for _ in range(B)]
    entries: List[Dict[int, int]] = [{} for _ in range(B)]
    fstates = [_FaultState() for _ in range(B)]
    t_now = np.zeros(B)
    out = [TimelineResult(policy=c.policy, load=c.load, seed=c.seed,
                          rounds=[]) for c in cases]
    quorum = schedule.quorum_frac
    for r in range(schedule.n_rounds):
        row_cases, row_meta = _build_rows(
            cases, schedule, r, carries, fstates
        )
        for b, _, rem_start, _ in row_meta:
            for cid in rem_start:
                entries[b].setdefault(cid, r)
        outages = _round_outages(cases, schedule, r, row_meta)
        deadlines = deadline_fn(r, row_cases, row_meta, outages)
        with maybe_span(collector, f"timeline:round[{r}]",
                        rows=len(row_cases)):
            results = _round_sweep(
                cfg, row_cases, t_round_hint=t_round_hint, max_t=max_t,
                ul_deadline_s=deadlines, ul_outage_s=outages,
                collector=collector, backend=backend,
            ) if row_cases else []
        ext_counts: Dict[int, int] = {}
        met: Dict[int, bool] = {}
        if quorum is not None and row_cases:
            dls = (list(deadlines)
                   if isinstance(deadlines, (list, tuple, np.ndarray))
                   else [deadlines] * len(row_cases))

            def _unmet():
                redo = []
                for b, ridx, rem_start, drops in row_meta:
                    if ridx is None or dls[ridx] is None:
                        continue
                    faulted = _round_faulted(
                        schedule, cases[b], r, rem_start, drops
                    )
                    got = len(_effective_arrived(
                        results[ridx], rem_start, faulted
                    ))
                    need = max(1, math.ceil(quorum * len(rem_start)))
                    met[ridx] = got >= need
                    if got < need:
                        redo.append((b, ridx))
                return redo

            for _ in range(schedule.quorum_max_extends):
                redo = _unmet()
                if not redo:
                    break
                for b, ridx in redo:
                    dls[ridx] = float(dls[ridx]) * 2.0
                    ext_counts[ridx] = ext_counts.get(ridx, 0) + 1
                    if collector is not None:
                        collector.event(
                            "quorum.extend", round=r,
                            seed=cases[b].seed,
                            deadline_s=dls[ridx],
                            extension=ext_counts[ridx],
                        )
                sub_idx = [ridx for _, ridx in redo]
                sub = _round_sweep(
                    cfg, [row_cases[i] for i in sub_idx],
                    t_round_hint=t_round_hint, max_t=max_t,
                    ul_deadline_s=[dls[i] for i in sub_idx],
                    ul_outage_s=(None if outages is None else
                                 [outages[i] for i in sub_idx]),
                    backend=backend,
                )
                for j, ridx in enumerate(sub_idx):
                    results[ridx] = sub[j]
            else:
                _unmet()        # final verdicts after the last extend
            deadlines = dls
        per_row_dl = isinstance(deadlines, (list, tuple, np.ndarray))
        for b, ridx, rem_start, drops in row_meta:
            res = results[ridx] if ridx is not None else None
            rnd, carry = _round_view(
                r, float(t_now[b]), res, rem_start,
                cases[b].workload.t_aggregate, policy, entries[b],
            )
            if ridx is not None and ridx in met:
                rnd.quorum_met = met[ridx]
                rnd.deadline_extensions = ext_counts.get(ridx, 0)
            carry = _apply_round_faults(
                schedule, cases[b], r, rnd, rem_start, carry, drops,
                fstates[b], collector,
            )
            out[b].rounds.append(rnd)
            carries[b] = carry
            entries[b] = {
                cid: ent for cid, ent in entries[b].items()
                if cid in carry or cid in fstates[b].retries
            }
            t_now[b] += rnd.sync_time
            if collector is not None:
                dl = (deadlines[ridx]
                      if per_row_dl and ridx is not None else
                      None if per_row_dl else deadlines)
                _observe_round(collector, cases[b], rnd, dl)
    return out


def _sequential(cfg, cases, schedule, t_round_hint, max_t,
                collector=None, backend=None):
    """Round-by-round engine advance, carrying deferred bits (the only
    legal order under defer deadlines; also the PR 2 per-round loop that
    the folded mode is benchmarked against)."""
    return _advance_rounds(
        cfg, cases, schedule, t_round_hint, max_t,
        schedule.deadline_policy,
        lambda r, row_cases, row_meta, outages: schedule.deadline(r),
        collector=collector, backend=backend,
    )


def _async(cfg, cases, schedule, t_round_hint, max_t, collector=None,
           backend=None):
    """FedBuff-style async rounds: each round is cut at the completion
    time of the ``buffer_k``-th pending upload (two engine passes — a
    free-running pass locates ``t_k``, a deadline pass at ``t_k``
    yields the stragglers' exact unserved bits), and stragglers defer
    with staleness. Cycles whose start precedes ``t_k`` complete, so
    the round's served bits reflect the cutoff at cycle granularity —
    the same rule the reference oracle applies.
    """
    k = schedule.buffer_k

    def deadline_fn(r, row_cases, row_meta, outages):
        # NOTE: the free-running probe pass stays uninstrumented — only
        # the deadline pass (the round that actually happens) feeds the
        # collector, so nothing is double-counted.
        free = _round_sweep(
            cfg, row_cases, t_round_hint=t_round_hint, max_t=max_t,
            ul_outage_s=outages, backend=backend,
        )
        deadlines: List[Optional[float]] = [None] * len(row_cases)
        for b, ridx, rem_start, drops in row_meta:
            if ridx is not None:
                deadlines[ridx] = _kth_completion(
                    free[ridx], rem_start, k,
                    _round_faulted(schedule, cases[b], r, rem_start,
                                   drops),
                )
        return deadlines

    return _advance_rounds(
        cfg, cases, schedule, t_round_hint, max_t, "defer", deadline_fn,
        collector=collector, backend=backend,
    )


def _folded(cfg, cases, schedule, t_round_hint, max_t, collector=None,
            backend=None):
    """The whole timeline as ONE stacked simulation: the round axis is
    folded into the engine batch axis (legal whenever rounds are
    independent given their start times — no deadline, or drop/partial
    policies whose stragglers never carry state forward; each row then
    runs under its own round's deadline)."""
    faults = schedule.active_faults
    has_outage = faults is not None and faults.outage_rate > 0.0
    rows = []
    row_deadlines: List[Optional[float]] = []
    row_outages: List[Optional[np.ndarray]] = []
    meta = []            # (b, r, rem_start, row_index or None)
    for b, case in enumerate(cases):
        for r in range(schedule.n_rounds):
            clients_r, _, rem_start, _ = _round_setup(
                case, schedule, r, {}
            )
            if not clients_r:
                meta.append((b, r, rem_start, None))
                continue
            wl = FLRoundWorkload(
                clients=clients_r,
                model_bits=case.workload.model_bits,
                t_aggregate=case.workload.t_aggregate,
            )
            meta.append((b, r, rem_start, len(rows)))
            rows.append(SweepCase(
                workload=wl, load=case.load, policy=case.policy,
                seed=case.seed, stream_round=r,
                topology=case.topology,
            ))
            row_deadlines.append(schedule.deadline(r))
            if has_outage:
                # outage injection never couples rounds (dark cycles
                # just delay the round's own uploads), so it folds as
                # one more per-row axis: each row carries its round's
                # counter-keyed window
                row_outages.append(faults.outage_windows(
                    r, _case_n_pons(case), case.seed
                ))
    from repro.obs.trace import maybe_span

    has_deadline = schedule.deadline_s is not None
    with maybe_span(collector, "timeline:folded", rows=len(rows),
                    rounds=schedule.n_rounds):
        results = _round_sweep(
            cfg, rows, t_round_hint=t_round_hint, max_t=max_t,
            ul_deadline_s=row_deadlines if has_deadline else None,
            ul_outage_s=row_outages if has_outage else None,
            collector=collector, backend=backend,
        ) if rows else []
    out = [TimelineResult(policy=c.policy, load=c.load, seed=c.seed,
                          rounds=[]) for c in cases]
    t_now = np.zeros(len(cases))
    for b, r, rem_start, ridx in meta:
        res = results[ridx] if ridx is not None else None
        rnd, _ = _round_view(
            r, float(t_now[b]), res, rem_start,
            cases[b].workload.t_aggregate, schedule.deadline_policy,
        )
        out[b].rounds.append(rnd)
        t_now[b] += rnd.sync_time
        if collector is not None:
            _observe_round(collector, cases[b], rnd,
                           schedule.deadline(r))
    return out


def _jobs_schedule_check(schedule: TimelineSchedule) -> None:
    """Multi-job timelines fold rounds by construction — reject every
    schedule feature that couples rounds or rewrites per-round
    workloads (those are single-tenant features; per-job round cadence
    is expressed through ``JobSpec.period``/``phase`` instead)."""
    if (schedule.membership is not None
            or schedule.m_ud_bits is not None
            or schedule.deadline_s is not None
            or schedule.buffer_k is not None
            or schedule.active_faults is not None
            or schedule.quorum_frac is not None):
        raise ValueError(
            "multi-job timelines need a plain schedule (n_rounds "
            "only): membership masks, per-round update sizes, "
            "deadlines, async buffering, fault injection and quorum "
            "extension are single-job features — encode per-job "
            "cadence via JobSpec.period/phase instead"
        )


def _folded_jobs(cfg, cases, schedule, mode, t_round_hint, max_t,
                 collector=None, backend=None):
    """Folded driver for multi-tenant cases: each round keeps only the
    jobs active under their cadence (``JobSpec.active_in``), the round
    axis folds into the engine batch exactly like ``_folded``, and the
    per-job CPS sync times land in ``TimelineRound.job_sync``."""
    if not all(case.jobs is not None for case in cases):
        raise ValueError(
            "a timeline sweep cannot mix multi-job and single-job "
            "cases; split them into separate sweeps"
        )
    _jobs_schedule_check(schedule)
    if mode not in ("auto", "folded"):
        raise ValueError(
            "multi-job timelines have independent rounds and always "
            f"fold; mode {mode!r} is unavailable"
        )
    rows = []
    meta = []            # (b, r, rem_start, row_index or None)
    for b, case in enumerate(cases):
        for r in range(schedule.n_rounds):
            active = tuple(j for j in case.jobs if j.active_in(r))
            keep = {cid for j in active for cid in j.clients}
            clients_r = [c for c in case.workload.clients
                         if c.client_id in keep]
            rem_start = {c.client_id: c.m_ud_bits for c in clients_r}
            if not clients_r:
                meta.append((b, r, rem_start, None))
                continue
            wl = FLRoundWorkload(
                clients=clients_r,
                model_bits=case.workload.model_bits,
                t_aggregate=case.workload.t_aggregate,
            )
            meta.append((b, r, rem_start, len(rows)))
            rows.append(replace(case, workload=wl, stream_round=r,
                                jobs=active))
    from repro.obs.trace import maybe_span

    with maybe_span(collector, "timeline:folded-jobs", rows=len(rows),
                    rounds=schedule.n_rounds):
        results = _round_sweep(
            cfg, rows, t_round_hint=t_round_hint, max_t=max_t,
            collector=collector, backend=backend,
        ) if rows else []
    out = [TimelineResult(policy=c.policy, load=c.load, seed=c.seed,
                          rounds=[]) for c in cases]
    t_now = np.zeros(len(cases))
    for b, r, rem_start, ridx in meta:
        res = results[ridx] if ridx is not None else None
        rnd, _ = _round_view(
            r, float(t_now[b]), res, rem_start,
            cases[b].workload.t_aggregate, "defer",
        )
        if res is not None and res.job_stats:
            rnd.job_sync = {jid: js.sync_time
                            for jid, js in res.job_stats.items()}
        out[b].rounds.append(rnd)
        t_now[b] += rnd.sync_time
        if collector is not None:
            _observe_round(collector, cases[b], rnd, None)
    return out


def _timeline_sweep(cfg, cases: Sequence[SweepCase],
                    schedule: TimelineSchedule,
                    mode: str = "auto",
                    t_round_hint: float = 10.0,
                    max_t: float = 600.0,
                    collector=None,
                    backend: Optional[str] = None,
                    ) -> List[TimelineResult]:
    """Advance the full multi-round timeline for every case.

    ``mode="auto"`` folds the round axis into the batch (one stacked
    simulation) when nothing couples consecutive rounds — no deadline,
    or ``deadline_policy`` in ``{"drop", "partial"}`` — and falls back
    to the sequential carry loop for defer deadlines;
    ``schedule.buffer_k`` selects the async (FedBuff) driver.
    ``"folded"``/``"sequential"`` force a path (parity tests check they
    agree when both are legal). Multi-job cases (``SweepCase.jobs``)
    always fold — their rounds are independent by construction — and
    report per-job sync times via ``TimelineRound.job_sync``.

    ``collector`` (``repro.obs.Collector``, optional) records engine
    phase metrics, per-round outcomes (``record_round``), upload-delay
    and deadline-slack histograms and staleness counts; ``None`` (the
    default) is bitwise identical to an uninstrumented run. Async
    schedules instrument only the deadline pass — the free-running
    probe pass is a search, not a simulated round.
    """
    cases = _validate(cases, schedule)
    if any(case.jobs is not None for case in cases):
        return _folded_jobs(cfg, cases, schedule, mode, t_round_hint,
                            max_t, collector=collector, backend=backend)
    if schedule.asynchronous:
        if mode == "folded":
            raise ValueError(
                "async rounds couple consecutive rounds (stragglers "
                "defer); folded mode is unavailable"
            )
        return _async(cfg, cases, schedule, t_round_hint, max_t,
                      collector=collector, backend=backend)
    if mode == "auto":
        mode = "sequential" if schedule.couples_rounds else "folded"
    if mode == "folded":
        if schedule.couples_rounds:
            raise ValueError(
                "schedule couples consecutive rounds (deadline "
                "deferral, dropout/loss retries or quorum extension); "
                "folded mode requires independent rounds — no "
                "deadline or drop/partial policies, and at most "
                "outage-only fault injection"
            )
        return _folded(cfg, cases, schedule, t_round_hint, max_t,
                       collector=collector, backend=backend)
    if mode == "sequential":
        return _sequential(cfg, cases, schedule, t_round_hint, max_t,
                           collector=collector, backend=backend)
    raise ValueError(f"unknown mode {mode!r}")


def simulate_timeline_sweep(cfg, cases=None, schedule=None,
                            mode: str = "auto",
                            t_round_hint: float = 10.0,
                            max_t: float = 600.0,
                            collector=None,
                            backend: Optional[str] = None,
                            ) -> List[TimelineResult]:
    """Advance the full multi-round timeline for every case.

    Preferred form: build a :class:`repro.net.SweepSpec` carrying a
    ``schedule`` and pass it as the sole positional argument (or as
    ``cases`` with a ``PONConfig`` first). The legacy
    ``(cfg, cases, schedule, **kwargs)`` form still works but emits a
    ``DeprecationWarning``; both forms produce identical results (the
    spec path is a thin frozen facade over the same driver).

    See ``_timeline_sweep`` for mode semantics and the collector
    contract.
    """
    from repro.net.api import SweepSpec, simulate

    spec = None
    pon = None
    if isinstance(cfg, SweepSpec):
        if cases is not None or schedule is not None:
            raise TypeError(
                "pass either a SweepSpec or (cfg, cases, schedule), "
                "not both"
            )
        spec = cfg
    elif isinstance(cases, SweepSpec):
        if schedule is not None:
            raise TypeError(
                "pass the schedule inside the SweepSpec, not as a "
                "third argument"
            )
        spec, pon = cases, cfg
    if spec is not None:
        if spec.schedule is None:
            raise ValueError(
                "simulate_timeline_sweep needs a spec with a "
                "schedule; use simulate(spec) or "
                "simulate_round_sweep(spec) for single-round sweeps"
            )
        if mode != "auto" and mode != spec.mode:
            spec = replace(spec, mode=mode)
        return simulate(spec, pon, collector=collector)
    warnings.warn(
        "simulate_timeline_sweep(cfg, cases, schedule, **kwargs) is "
        "deprecated; build a repro.net.SweepSpec (with .schedule) and "
        "call simulate(spec) (or pass the spec to "
        "simulate_timeline_sweep)",
        DeprecationWarning, stacklevel=2,
    )
    return _timeline_sweep(cfg, cases, schedule, mode=mode,
                           t_round_hint=t_round_hint, max_t=max_t,
                           collector=collector, backend=backend)


def simulate_timeline_per_round(cfg, cases: Sequence[SweepCase],
                                schedule: TimelineSchedule,
                                t_round_hint: float = 10.0,
                                max_t: float = 600.0,
                                collector=None,
                                backend: Optional[str] = None,
                                ) -> List[TimelineResult]:
    """The PR 2 per-round loop: one engine call per round, queue state
    rebuilt every round. Identical results to ``simulate_timeline_sweep``
    (same streams); kept as the benchmark baseline. Async schedules run
    the (inherently per-round) two-pass async driver. Multi-job cases
    delegate to the folded jobs driver — their rounds are independent,
    so the per-round baseline and the fold coincide."""
    cases = _validate(cases, schedule)
    if any(case.jobs is not None for case in cases):
        return _folded_jobs(cfg, cases, schedule, "auto", t_round_hint,
                            max_t, collector=collector, backend=backend)
    if schedule.asynchronous:
        return _async(cfg, cases, schedule, t_round_hint, max_t,
                      collector=collector, backend=backend)
    return _sequential(cfg, cases, schedule, t_round_hint, max_t,
                       collector=collector, backend=backend)


# ---------------------------------------------------------------------------
# reference loop (parity oracle)
# ---------------------------------------------------------------------------


def simulate_timeline_reference(cfg, cases: Sequence[SweepCase],
                                schedule: TimelineSchedule,
                                t_round_hint: float = 10.0,
                                max_t: float = 600.0,
                                ) -> List[TimelineResult]:
    """Per-round loop over the cycle-by-cycle *dict* simulator.

    Every round rebuilds the reference simulator from scratch and feeds
    it the engine's counter-based arrival streams
    (``CounterStream.source``), so the timeline engine must reproduce
    its sync times and per-round bits exactly (rtol 1e-6) — including
    elastic membership, all three deadline policies and async rounds
    (the same two-pass k-th-completion rule, on fresh stream cursors
    per pass).
    """
    from repro.kernels.traffic.ops import make_stream_key
    from repro.net.engine import _case_bg_rate
    from repro.net.multi_pon import simulate_multi_pon_round
    from repro.net.sim import simulate_round
    from repro.net.traffic import CounterStream

    import math

    cases = _validate(cases, schedule)
    policy = schedule.deadline_policy
    quorum = schedule.quorum_frac
    out = []
    for case in cases:
        carry: Dict[int, float] = {}
        entry: Dict[int, int] = {}
        fstate = _FaultState()
        t_now = 0.0
        res = TimelineResult(policy=case.policy, load=case.load,
                             seed=case.seed, rounds=[])
        for r in range(schedule.n_rounds):
            clients_r, no_dl, rem_start, drops = _round_setup(
                case, schedule, r, carry, fstate.retries
            )
            for cid in rem_start:
                entry.setdefault(cid, r)
            if not clients_r:
                rnd, carry = _round_view(
                    r, t_now, None, rem_start,
                    case.workload.t_aggregate, policy, entry,
                )
                res.rounds.append(rnd)
                t_now += rnd.sync_time
                continue
            wl = FLRoundWorkload(
                clients=clients_r,
                model_bits=case.workload.model_bits,
                t_aggregate=case.workload.t_aggregate,
            )
            faults = schedule.active_faults
            outage = (faults.outage_windows(r, _case_n_pons(case),
                                            case.seed)
                      if faults is not None and faults.outage_rate > 0.0
                      else None)

            def run_ref(deadline):
                """One reference round under ``deadline`` — fresh
                stream cursors per call, so the async two-pass replays
                the identical arrival process."""
                if case.topology is not None and not case.topology.trivial:
                    # the cycle-by-cycle multi-PON oracle keys its own
                    # (seed, phase, round, pon) counter streams
                    return simulate_multi_pon_round(
                        cfg, case.topology, wl, case.load, case.policy,
                        seed=case.seed, t_round_hint=t_round_hint,
                        max_t=max_t, ul_deadline_s=deadline,
                        no_dl_ids=no_dl, stream_round=r,
                        ul_outage_s=outage,
                    )
                row = SweepCase(workload=wl, load=case.load,
                                policy=case.policy, seed=case.seed)
                per_onu = (_case_bg_rate(row, cfg, t_round_hint)
                           / cfg.n_onus)
                streams = [
                    CounterStream(
                        make_stream_key(case.seed, phase, r), per_onu,
                        cfg.cycle_time_s, cfg.n_onus,
                        burst_packets=cfg.bg_burst_packets,
                    )
                    for phase in (0, 1)
                ]
                return simulate_round(
                    cfg, wl, case.load, case.policy, seed=case.seed,
                    t_round_hint=t_round_hint, backend="reference",
                    _dl_sources=[streams[0].source(i)
                                 for i in range(cfg.n_onus)],
                    _ul_sources=[streams[1].source(i)
                                 for i in range(cfg.n_onus)],
                    ul_deadline_s=deadline,
                    no_dl_ids=no_dl,
                    ul_outage_s=(None if outage is None else
                                 (float(outage[0, 0]),
                                  float(outage[0, 1]))),
                )

            quorum_met: Optional[bool] = None
            extensions = 0
            if schedule.asynchronous:
                free = run_ref(None)
                faulted = _round_faulted(schedule, case, r, rem_start,
                                         drops)
                result = run_ref(
                    _kth_completion(free, rem_start, schedule.buffer_k,
                                    faulted)
                )
            elif quorum is not None:
                # same extend-until-met loop as the engine driver:
                # identical counter streams make each rerun a superset
                # of the previous pass
                faulted = _round_faulted(schedule, case, r, rem_start,
                                         drops)
                need = max(1, math.ceil(quorum * len(rem_start)))
                dl = schedule.deadline(r)
                result = run_ref(dl)
                while True:
                    got = len(_effective_arrived(result, rem_start,
                                                 faulted))
                    quorum_met = got >= need
                    if (quorum_met
                            or extensions >= schedule.quorum_max_extends):
                        break
                    dl = float(dl) * 2.0
                    extensions += 1
                    result = run_ref(dl)
            else:
                result = run_ref(schedule.deadline(r))
            rnd, carry = _round_view(
                r, t_now, result, rem_start,
                case.workload.t_aggregate, policy, entry,
            )
            rnd.quorum_met = quorum_met
            rnd.deadline_extensions = extensions
            carry = _apply_round_faults(
                schedule, case, r, rnd, rem_start, carry, drops, fstate,
            )
            entry = {cid: ent for cid, ent in entry.items()
                     if cid in carry or cid in fstate.retries}
            res.rounds.append(rnd)
            t_now += rnd.sync_time
        out.append(res)
    return out
