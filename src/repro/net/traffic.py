"""Background traffic sources (paper §3: Poisson background traffic).

Arrivals are Poisson *bursts* of fixed-size packets (1500 B Ethernet frames).
``burst_packets`` > 1 draws a geometric burst length per arrival — access
traffic is bursty in practice and this is what makes FCFS queueing visibly
load-dependent at PON time scales.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

PACKET_BITS = 1500 * 8


@dataclass
class PoissonSource:
    rate_bps: float                 # offered load in bits/s
    rng: np.random.Generator
    packet_bits: float = PACKET_BITS
    burst_packets: float = 16.0     # mean packets per burst (geometric)

    def arrivals(self, dt_s: float) -> float:
        """Bits arriving in a window of dt seconds."""
        if self.rate_bps <= 0:
            return 0.0
        mean_burst_bits = self.packet_bits * self.burst_packets
        burst_rate = self.rate_bps / mean_burst_bits     # bursts per second
        n_bursts = self.rng.poisson(burst_rate * dt_s)
        if n_bursts == 0:
            return 0.0
        lengths = self.rng.geometric(1.0 / self.burst_packets, size=n_bursts)
        return float(lengths.sum()) * self.packet_bits


@dataclass
class PrecomputedSource:
    """Replays a fixed per-cycle arrival sequence for one ONU.

    Drop-in for ``PoissonSource`` in the reference simulator's phases;
    cycles beyond the sequence see zero arrivals. Used by the parity
    tests to feed the reference simulator and the vectorized engine the
    identical background arrival process.
    """

    rows: "object"                  # 1-D sequence of bits per cycle
    cursor: int = 0

    def arrivals(self, dt_s: float) -> float:
        i = self.cursor
        self.cursor += 1
        if i >= len(self.rows):
            return 0.0
        return float(self.rows[i])


def per_onu_sources(
    total_rate_bps: float,
    n_onus: int,
    rng: np.random.Generator,
    burst_packets: float = 16.0,
) -> list:
    """Split an aggregate offered load evenly across ONUs."""
    rate = total_rate_bps / n_onus
    return [
        PoissonSource(rate_bps=rate, rng=rng, burst_packets=burst_packets)
        for _ in range(n_onus)
    ]


def background_rate_for_load(
    total_load: float,
    line_rate_bps: float,
    training_rate_bps: float = 0.0,
) -> float:
    """Offered background rate so that background + training == total load.

    The paper: "The background traffic follows Poisson distribution, which
    together with training traffic determines the total traffic load."
    """
    rate = total_load * line_rate_bps - training_rate_bps
    return max(rate, 0.0)
