"""Background traffic sources (paper §3: Poisson background traffic).

Arrivals are Poisson *bursts* of fixed-size packets (1500 B Ethernet frames).
``burst_packets`` > 1 draws a geometric burst length per arrival — access
traffic is bursty in practice and this is what makes FCFS queueing visibly
load-dependent at PON time scales.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

PACKET_BITS = 1500 * 8


@dataclass
class PoissonSource:
    rate_bps: float                 # offered load in bits/s
    rng: np.random.Generator
    packet_bits: float = PACKET_BITS
    burst_packets: float = 16.0     # mean packets per burst (geometric)

    def arrivals(self, dt_s: float) -> float:
        """Bits arriving in a window of dt seconds."""
        if self.rate_bps <= 0:
            return 0.0
        mean_burst_bits = self.packet_bits * self.burst_packets
        burst_rate = self.rate_bps / mean_burst_bits     # bursts per second
        n_bursts = self.rng.poisson(burst_rate * dt_s)
        if n_bursts == 0:
            return 0.0
        lengths = self.rng.geometric(1.0 / self.burst_packets, size=n_bursts)
        return float(lengths.sum()) * self.packet_bits


@dataclass
class PrecomputedSource:
    """Replays a fixed per-cycle arrival sequence for one ONU.

    Drop-in for ``PoissonSource`` in the reference simulator's phases;
    cycles beyond the sequence see zero arrivals. Used by the parity
    tests to feed the reference simulator and the vectorized engine the
    identical background arrival process.
    """

    rows: "object"                  # 1-D sequence of bits per cycle
    cursor: int = 0

    def arrivals(self, dt_s: float) -> float:
        i = self.cursor
        self.cursor += 1
        if i >= len(self.rows):
            return 0.0
        return float(self.rows[i])


def burst_lambda(
    rate_bps: float,
    cycle_s: float,
    packet_bits: float = PACKET_BITS,
    burst_packets: float = 16.0,
) -> float:
    """Per-cycle burst rate λ for an offered per-ONU bit rate."""
    if rate_bps <= 0:
        return 0.0
    return rate_bps / (packet_bits * burst_packets) * cycle_s


class CounterStream:
    """Counter-based arrival streams for one (case, phase, round).

    Wraps ``repro.kernels.traffic`` so the *reference* cycle-by-cycle
    simulator can consume the exact same keyed arrival process as the
    vectorized engine: ``source(onu)`` returns a ``PoissonSource``-shaped
    object whose ``arrivals`` replays the counter stream one cycle at a
    time. Rows are materialised in shared chunks (every ONU of a stream
    reads the same sampler output), so the per-ONU cursor objects stay
    O(1) per cycle.
    """

    def __init__(self, key, rate_bps: float, cycle_s: float, n_onus: int,
                 packet_bits: float = PACKET_BITS,
                 burst_packets: float = 16.0, chunk: int = 1024):
        self.key = key
        self.n_onus = n_onus
        self.packet_bits = packet_bits
        self.inv_burst = 1.0 / burst_packets
        self.lam = burst_lambda(rate_bps, cycle_s, packet_bits,
                                burst_packets)
        self.chunk = chunk
        self._base = 0
        self._buf = None

    def rows(self, k: int):
        """The ``(n_onus,)`` arrival bits of cycle ``k``."""
        if self._buf is None or not (
            self._base <= k < self._base + len(self._buf)
        ):
            from repro.kernels.traffic.ops import sample_arrival_bits

            self._base = k
            self._buf = sample_arrival_bits(
                self.key, k, self.chunk, self.n_onus, self.lam,
                self.inv_burst, self.packet_bits,
            )[0]
        return self._buf[k - self._base]

    def source(self, onu: int) -> "CounterSource":
        return CounterSource(self, onu)


@dataclass
class CounterSource:
    """Per-ONU cursor view over a :class:`CounterStream`."""

    stream: CounterStream
    onu: int
    cursor: int = 0

    def arrivals(self, dt_s: float) -> float:
        k = self.cursor
        self.cursor += 1
        return float(self.stream.rows(k)[self.onu])


def counter_streams_for_pons(
    seed: int,
    phase: int,
    per_onu_rates,
    cycle_s: float,
    n_onus: int,
    burst_packets: float = 16.0,
    round_index: int = 0,
) -> list:
    """One :class:`CounterStream` per wavelength segment.

    Segment ``p`` draws from the stream keyed
    ``(seed, phase, round_index, pon=p)`` at its own per-ONU rate
    ``per_onu_rates[p]`` — the exact streams the stacked multi-PON
    engine consumes, exposed for the cycle-by-cycle reference oracle.
    """
    from repro.kernels.traffic.ops import make_stream_key

    return [
        CounterStream(
            make_stream_key(seed, phase, round_index, pon),
            float(rate), cycle_s, n_onus, burst_packets=burst_packets,
        )
        for pon, rate in enumerate(np.asarray(per_onu_rates, np.float64))
    ]


def per_onu_sources(
    total_rate_bps: float,
    n_onus: int,
    rng: np.random.Generator,
    burst_packets: float = 16.0,
) -> list:
    """Split an aggregate offered load evenly across ONUs."""
    rate = total_rate_bps / n_onus
    return [
        PoissonSource(rate_bps=rate, rng=rng, burst_packets=burst_packets)
        for _ in range(n_onus)
    ]


def background_rate_for_load(
    total_load: float,
    line_rate_bps: float,
    training_rate_bps: float = 0.0,
) -> float:
    """Offered background rate so that background + training == total load.

    The paper: "The background traffic follows Poisson distribution, which
    together with training traffic determines the total traffic load."
    """
    rate = total_load * line_rate_bps - training_rate_bps
    return max(rate, 0.0)
