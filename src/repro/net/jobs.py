"""Concurrent multi-tenant FL jobs sharing one PON + CPS substrate.

The paper's bandwidth-slicing claim is only ever exercised with a
single FL job owning the training slice.  Real edge deployments run
several federated jobs — different models, update sizes, priorities and
round cadences — whose training bursts contend for the *same* PON
cycles and the same CPS uplink ("Fair Allocation of Bandwidth At Edge
Servers For Concurrent Hierarchical Federated Learning",
arXiv 2409.04921).  This module is the job axis:

* :class:`JobSpec` — one tenant job: its client binding over the ONU
  population, per-job model size (downlink) which is also what its
  background-load share is priced at, scheduling weight, soft deadline
  and round cadence (``period``/``phase``) for the multi-round
  timeline.
* :func:`job_fair_split` — the per-cycle inter-job capacity split,
  pluggable by fairness policy: ``"maxmin"`` (the
  :func:`repro.net.multi_pon.cps_waterfill` machinery generalized to a
  job axis), ``"weighted"`` (water-level proportional to job weights)
  and ``"deadline"`` (earliest-slack-first greedy).  All three are
  exact waterfills expressed as sort + prefix-sum, batched over rows,
  and pass demands through untouched while total demand fits the cap —
  contention-free cycles are bitwise independent of the policy.
* :class:`JobRoundStats` — hierarchical per-job aggregation times:
  last upload per ONU (ONU tier), per PON/OLT (OLT tier) and the job's
  sync time at the CPS tier.
* :func:`simulate_jobs_round_reference` — the cycle-by-cycle dict
  oracle for one multi-job case, mirroring the batched engine's cycle
  sequence (push → CPS waterfill → background waterfill → per-job
  fairness split → per-job oldest-first grants) over owner-tagged
  :class:`repro.net.dba.OnuQueue` FIFOs.  The engine must match it at
  rtol 1e-6 across both DBA policies, all fairness policies and
  multi-PON topologies (``tests/test_jobs.py``).

The fairness split and the CPS coupling deliberately share *code* with
the engine (``job_fair_split``/``cps_waterfill`` are called with
identical shapes by both sides), so the oracle pins the cycle
*sequencing* while the allocation arithmetic is common by
construction.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.scheduler import schedule_slots, slots_to_arrays
from repro.core.slicing import ClientProfile, compute_slice
from repro.net.dba import OnuQueue
from repro.net.multi_pon import (
    MultiPonTopology,
    cps_waterfill,
    pon_bg_rates,
)
from repro.net.traffic import counter_streams_for_pons

__all__ = [
    "FAIRNESS_POLICIES",
    "JobSpec",
    "JobRoundStats",
    "job_fair_split",
    "validate_case_jobs",
    "compute_job_stats",
    "make_competing_jobs",
    "simulate_jobs_round_reference",
]

FAIRNESS_POLICIES = ("maxmin", "weighted", "deadline")

CAP_EPS = 1e-9                  # engine's capacity-exhausted threshold


@dataclass(frozen=True)
class JobSpec:
    """One tenant FL job contending for the shared substrate.

    ``clients`` are *global client ids* (placed on ONUs exactly like a
    workload's :class:`~repro.core.slicing.ClientProfile` ids).  Every
    client of a case's workload must belong to exactly one of the
    case's jobs (:func:`validate_case_jobs`).

    ``model_bits`` is the job's own global-model size — its downlink
    broadcast and the rate its training traffic is priced at when
    deriving background load.  Per-client *update* sizes stay on the
    workload's ``ClientProfile.m_ud_bits``.

    ``weight`` feeds the ``"weighted"`` fairness policy; ``deadline_s``
    is a *soft* per-job deadline consumed by the ``"deadline"`` policy
    as slack (it never cuts service — hard round deadlines remain a
    schedule-level feature of single-tenant sweeps).

    ``period``/``phase`` give the job its round cadence on a
    multi-round timeline: the job trains in round ``r`` iff
    ``r >= phase`` and ``(r - phase) % period == 0`` — offset cadences
    interleave jobs so contention varies round to round.
    """

    job_id: int
    clients: Tuple[int, ...]
    model_bits: float
    weight: float = 1.0
    deadline_s: Optional[float] = None
    period: int = 1
    phase: int = 0
    t_aggregate: float = 0.0

    def __post_init__(self):
        object.__setattr__(
            self, "clients", tuple(int(c) for c in self.clients)
        )
        if not self.clients:
            raise ValueError(f"job {self.job_id} has no clients")
        if float(self.model_bits) <= 0.0:
            raise ValueError(f"job {self.job_id}: model_bits must be > 0")
        if float(self.weight) <= 0.0:
            raise ValueError(f"job {self.job_id}: weight must be > 0")
        if int(self.period) < 1:
            raise ValueError(f"job {self.job_id}: period must be >= 1")
        if int(self.phase) < 0:
            raise ValueError(f"job {self.job_id}: phase must be >= 0")

    def active_in(self, round_index: int) -> bool:
        """Does this job train in timeline round ``round_index``?"""
        r = int(round_index) - int(self.phase)
        return r >= 0 and r % int(self.period) == 0


@dataclass(frozen=True)
class JobRoundStats:
    """Hierarchical aggregation times of one job in one round.

    ``onu_done``: global ONU id → completion time of the last upload
    the job's clients pushed through that ONU (the ONU-tier partial
    aggregate is ready then).  ``olt_done``: PON index → the last of
    its ONU-tier times (OLT-tier aggregate).  ``sync_time``: CPS-tier —
    the last client overall plus the job's ``t_aggregate``.
    """

    job_id: int
    sync_time: float
    onu_done: Dict[int, float] = field(default_factory=dict)
    olt_done: Dict[int, float] = field(default_factory=dict)
    n_clients: int = 0


def validate_case_jobs(jobs: Sequence[JobSpec], workload) -> None:
    """Jobs must partition the workload's client ids exactly."""
    ids = [job.job_id for job in jobs]
    if len(set(ids)) != len(ids):
        raise ValueError(f"duplicate job_id in jobs: {sorted(ids)}")
    owner: Dict[int, int] = {}
    for job in jobs:
        for cid in job.clients:
            if cid in owner:
                raise ValueError(
                    f"client {cid} belongs to jobs {owner[cid]} and "
                    f"{job.job_id}; jobs must partition the workload"
                )
            owner[cid] = job.job_id
    wl_ids = {c.client_id for c in workload.clients}
    missing = sorted(wl_ids - owner.keys())
    extra = sorted(owner.keys() - wl_ids)
    if missing or extra:
        raise ValueError(
            "jobs must partition workload.clients exactly; "
            f"unassigned clients {missing}, job clients not in the "
            f"workload {extra}"
        )


def job_fair_split(demand, cap, fairness: str = "maxmin",
                   weights=None, slack=None) -> np.ndarray:
    """Split per-row capacity across jobs by the fairness policy.

    ``demand``: ``(G, J)`` per-row per-job cycle demand (or a single
    ``(J,)`` vector); ``cap``: scalar or ``(G,)`` row capacity.
    Returns grants of ``demand``'s shape with ``out <= demand``
    elementwise and ``sum(out) <= cap`` per row whenever the cap binds.
    Rows whose total demand fits the cap pass through untouched under
    every policy — fairness only matters under contention.

    * ``"maxmin"``: :func:`repro.net.multi_pon.cps_waterfill` over the
      job axis (bitwise the same arithmetic as the CPS-over-PONs
      split).
    * ``"weighted"``: water level proportional to ``weights`` —
      ``out_j = min(d_j, w_j * mu)`` at the exact level; jobs with the
      smallest ``d/w`` saturate first and their weight leaves the pool
      (with unit weights this is bitwise ``"maxmin"``).
    * ``"deadline"``: earliest-slack-first greedy — jobs sorted by
      ``slack`` (stable; ties fall back to job order) take
      ``min(demand, room)`` of the remaining capacity in turn.
    """
    demand = np.asarray(demand, np.float64)
    if demand.ndim == 1:
        out = job_fair_split(
            demand[None, :], cap, fairness,
            None if weights is None else np.asarray(weights)[None, :],
            None if slack is None else np.asarray(slack)[None, :],
        )
        return out[0]
    G, J = demand.shape
    cap_b = np.broadcast_to(np.asarray(cap, np.float64), (G,))
    if fairness == "maxmin":
        return cps_waterfill(demand, cap_b)
    if fairness not in FAIRNESS_POLICIES:
        raise ValueError(
            f"unknown fairness policy {fairness!r}; "
            f"have {FAIRNESS_POLICIES}"
        )
    out = demand.copy()
    over = demand.sum(axis=1) > cap_b + CAP_EPS
    if not over.any():
        return out
    d = demand[over]
    c = cap_b[over]
    n = d.shape[0]
    rows = np.arange(n)[:, None]
    if fairness == "weighted":
        w = (np.ones_like(demand) if weights is None
             else np.broadcast_to(
                 np.asarray(weights, np.float64), demand.shape))
        if np.any(w <= 0.0):
            raise ValueError("job weights must be positive")
        wv = w[over]
        ratio = d / wv
        order = np.argsort(ratio, axis=1, kind="stable")
        d_s = d[rows, order]
        w_s = wv[rows, order]
        r_s = ratio[rows, order]
        prev = np.cumsum(d_s, axis=1) - d_s
        # after fully granting the k smallest-ratio jobs, the rest
        # split the residual pro rata: mu_k = (cap - granted) / w_rest
        w_rest = wv.sum(axis=1)[:, None] - (np.cumsum(w_s, axis=1) - w_s)
        mu_k = (c[:, None] - prev) / w_rest
        k = np.argmax(mu_k <= r_s, axis=1)
        mu = mu_k[np.arange(n), k]
        out[over] = np.minimum(d, wv * mu[:, None])
        return out
    # "deadline": earliest slack first, prefix-room greedy
    sl = (np.zeros_like(demand) if slack is None
          else np.broadcast_to(
              np.asarray(slack, np.float64), demand.shape))[over]
    order = np.argsort(sl, axis=1, kind="stable")
    d_s = d[rows, order]
    prefix = np.cumsum(d_s, axis=1)
    room = c[:, None] - (prefix - d_s)
    g_s = np.where(room > CAP_EPS, np.minimum(d_s, room), 0.0)
    g = np.empty_like(g_s)
    g[rows, order] = g_s
    out[over] = g
    return out


def compute_job_stats(jobs: Sequence[JobSpec], ul_done: Dict[int, float],
                      n_onus: int, n_pons: int) -> Dict[int, JobRoundStats]:
    """Per-job ONU → OLT → CPS aggregation times from upload times."""
    total = n_onus * n_pons
    stats: Dict[int, JobRoundStats] = {}
    for job in jobs:
        times = {
            cid: float(ul_done[cid]) for cid in job.clients
            if cid in ul_done and np.isfinite(ul_done[cid])
        }
        onu_done: Dict[int, float] = {}
        for cid, t in times.items():
            onu = int(cid) % total
            onu_done[onu] = max(onu_done.get(onu, -np.inf), t)
        olt_done: Dict[int, float] = {}
        for onu, t in onu_done.items():
            p = onu // n_onus
            olt_done[p] = max(olt_done.get(p, -np.inf), t)
        sync = (max(times.values()) + job.t_aggregate if times
                else float("nan"))
        stats[job.job_id] = JobRoundStats(
            job_id=job.job_id, sync_time=sync, onu_done=onu_done,
            olt_done=olt_done, n_clients=len(times),
        )
    return stats


def make_competing_jobs(primary_clients: Sequence[int],
                        primary_model_bits: float, n_jobs: int,
                        clients_each: int = 2,
                        model_scale: float = 0.5,
                        t_ud: float = 2.0,
                        weight: float = 1.0,
                        ) -> Tuple[Tuple[JobSpec, ...],
                                   Tuple[ClientProfile, ...]]:
    """Competitor jobs + their client profiles for co-sim/CLI use.

    Generates ``n_jobs`` tenant jobs with fresh client ids above the
    primary job's, each with ``clients_each`` clients, model size
    ``model_scale *`` the primary's (updates sized to the model) and a
    fixed compute time ``t_ud``.  Returns ``(jobs, profiles)`` —
    append the profiles to the workload's client list and the jobs
    (after the primary's own :class:`JobSpec`) to the case.
    """
    ids = [int(c) for c in primary_clients]
    if not ids:
        raise ValueError("primary_clients must be non-empty")
    nid = max(ids) + 1
    mb = float(primary_model_bits) * float(model_scale)
    jobs: List[JobSpec] = []
    profiles: List[ClientProfile] = []
    for j in range(int(n_jobs)):
        cids = tuple(range(nid, nid + int(clients_each)))
        nid += int(clients_each)
        jobs.append(JobSpec(job_id=j + 1, clients=cids, model_bits=mb,
                            weight=weight))
        profiles.extend(
            ClientProfile(client_id=cid, t_ud=t_ud, t_dl=0.0,
                          m_ud_bits=mb)
            for cid in cids
        )
    return tuple(jobs), tuple(profiles)


# ---------------------------------------------------------------------------
# cycle-level reference oracle
# ---------------------------------------------------------------------------


def _seq_waterfill(entries, cap: float) -> Dict[int, float]:
    """Sequential mirror of the engine's ``_waterfill``: oldest-first
    (ties by queue index) prefix-room grants, granting every queue in
    full — without sorting — while total demand sits a bit under cap.

    ``entries``: ``(hol_key, queue_index, backlog)`` triples.
    """
    total = sum(b for _, _, b in entries)
    if total <= cap - 1.0:
        return {i: b for _, i, b in entries}
    grants: Dict[int, float] = {}
    acc = 0.0
    for _, i, b in sorted(entries, key=lambda e: (e[0], e[1])):
        room = cap - acc
        grants[i] = min(b, room) if room > CAP_EPS else 0.0
        acc += b
    return grants


def simulate_jobs_round_reference(cfg, case, t_round_hint: float = 10.0,
                                  max_t: float = 600.0):
    """One multi-job round of ``case`` on the cycle-by-cycle dict
    simulator — the parity oracle for the engine's jobs path.

    Mirrors the batched engine's per-cycle sequence exactly: arrivals
    push (background first, then newly-ready FL clients), CPS waterfill
    over per-PON total demand (FCFS) or over ``(pon, job)`` grant
    shares (BS), background oldest-first waterfill, the inter-job
    :func:`job_fair_split`, then per-job oldest-first grants within the
    job's share.  Queues are owner-tagged :class:`OnuQueue` FIFOs per
    ``(pon, job, local onu)``; crediting uses the same
    ``repro.net.sim._credit`` the reference simulator uses.

    Restrictions (engine features outside the jobs matrix):
    ``no_dl_ids`` and injected arrival matrices are rejected.
    """
    from repro.net.sim import RoundResult, _credit

    jobs: Tuple[JobSpec, ...] = tuple(case.jobs)
    validate_case_jobs(jobs, case.workload)
    if case.no_dl_ids:
        raise ValueError("the jobs oracle does not model no_dl_ids")
    if case.dl_arrivals is not None or case.ul_arrivals is not None:
        raise ValueError(
            "the jobs oracle draws arrivals from counter streams; "
            "injected matrices are a single-tenant parity hook"
        )
    fairness = case.fairness
    if fairness not in FAIRNESS_POLICIES:
        raise ValueError(
            f"unknown fairness policy {fairness!r}; "
            f"have {FAIRNESS_POLICIES}"
        )
    topo = case.topology if case.topology is not None else MultiPonTopology()
    P = topo.n_pons
    n_local = cfg.n_onus
    total = P * n_local
    clients = list(case.workload.clients)
    J = len(jobs)
    jidx_of = {cid: j for j, job in enumerate(jobs) for cid in job.clients}
    mb_of = {cid: float(job.model_bits) for job in jobs
             for cid in job.clients}
    if case.policy not in ("fcfs", "bs"):
        raise ValueError(f"unknown policy {case.policy!r}")
    if case.policy == "bs":
        bad = [c.client_id for c in clients if c.client_id >= total]
        if bad:
            raise ValueError(
                f"bs policy requires client_id < n_onus * n_pons; got {bad}"
            )
    pon_of = {c.client_id: topo.pon_of(c.client_id, cfg) for c in clients}
    onu_of = {c.client_id: topo.local_onu(c.client_id, cfg)
              for c in clients}
    rates = topo.rates(cfg)
    cap_p = topo.capacity_bits(cfg)
    cps_cap = topo.cps_capacity_bits(cfg)
    per_onu = pon_bg_rates(clients, case.workload.model_bits, case.load,
                           cfg, topo, t_round_hint,
                           model_bits_by_client=mb_of)
    cyc = cfg.cycle_time_s
    prop = cfg.propagation_s
    weights = np.broadcast_to(
        np.array([float(job.weight) for job in jobs]), (P, J)
    )
    dl_j = np.broadcast_to(
        np.array([np.inf if job.deadline_s is None
                  else float(job.deadline_s) for job in jobs]),
        (P, J),
    )

    def fresh_queues():
        return [
            [[OnuQueue(i) for i in range(n_local)] for _ in range(J)]
            for _ in range(P)
        ]

    def push_pending(flq, pending, remaining, t):
        for cid, t_ready in list(pending.items()):
            if t_ready <= t + cyc:
                flq[pon_of[cid]][jidx_of[cid]][onu_of[cid]].push(
                    ("fl", cid), remaining[cid], max(t_ready, t)
                )
                del pending[cid]

    def fl_demand(flq) -> np.ndarray:
        demand = np.zeros((P, J))
        for p in range(P):
            for j in range(J):
                demand[p, j] = sum(q.backlog for q in flq[p][j])
        return demand

    def serve_jobs(flq, shares, remaining, done, t):
        for p in range(P):
            for j in range(J):
                gj = _seq_waterfill(
                    [(q.hol_time, i, q.backlog)
                     for i, q in enumerate(flq[p][j]) if q.backlog > 0.0],
                    float(shares[p, j]),
                )
                for i, g in gj.items():
                    if g > 0.0:
                        served = flq[p][j][i].serve(g)
                        _credit(served, remaining, done, t, cfg)

    def fcfs_phase(bits0, ready, phase_idx):
        bgq = [[OnuQueue(i) for i in range(n_local)] for _ in range(P)]
        flq = fresh_queues()
        streams = counter_streams_for_pons(
            case.seed, phase_idx, per_onu, cyc, n_local,
            cfg.bg_burst_packets, round_index=case.stream_round,
        )
        sources = [[streams[p].source(i) for i in range(n_local)]
                   for p in range(P)]
        remaining = dict(bits0)
        pending = dict(ready)
        done: Dict[int, float] = {}
        t = 0.0
        while remaining and t < max_t:
            for p in range(P):
                for q, src in zip(bgq[p], sources[p]):
                    q.push("bg", src.arrivals(cyc), t)
            push_pending(flq, pending, remaining, t)
            demand = fl_demand(flq)
            if cps_cap is None:
                eff = np.asarray(cap_p, np.float64).copy()
            else:
                want = np.minimum(
                    np.array([
                        sum(q.backlog for q in bgq[p]) + demand[p].sum()
                        for p in range(P)
                    ]),
                    cap_p,
                )
                eff = cps_waterfill(want, cps_cap)
            cap_fl = np.zeros(P)
            bg_grants = []
            for p in range(P):
                g = _seq_waterfill(
                    [(q.hol_time, i, q.backlog)
                     for i, q in enumerate(bgq[p]) if q.backlog > 0.0],
                    float(eff[p]),
                )
                bg_grants.append(g)
                cap_fl[p] = eff[p] - sum(g.values())
            shares = job_fair_split(demand, cap_fl, fairness,
                                    weights=weights, slack=dl_j - t)
            for p in range(P):
                for i, g in bg_grants[p].items():
                    if g > 0.0:
                        bgq[p][i].serve(g)
            serve_jobs(flq, shares, remaining, done, t)
            t += cyc
        for cid in list(remaining):
            done[cid] = t + prop
        return done

    def bs_phase(bits0, ready, dl_done):
        flq = fresh_queues()
        slots_p: List[list] = []
        for p in range(P):
            slot_list = []
            for j, job in enumerate(jobs):
                jset = set(job.clients)
                profs = [
                    ClientProfile(
                        client_id=c.client_id, t_ud=c.t_ud,
                        t_dl=dl_done[c.client_id],
                        m_ud_bits=c.m_ud_bits, distance_m=c.distance_m,
                    )
                    for c in clients
                    if pon_of[c.client_id] == p and c.client_id in jset
                ]
                if not profs:
                    continue
                spec = compute_slice(
                    profs, t_current=0.0, t_round=0.0,
                    capacity_bps=float(rates[p] * cfg.efficiency), h=1,
                )
                arr = slots_to_arrays(
                    schedule_slots(profs, spec, round_start=0.0)
                )
                for s in range(len(arr["client_id"])):
                    slot_list.append((
                        j, int(arr["client_id"][s]) % n_local,
                        float(arr["t_start"][s]), float(arr["t_end"][s]),
                        float(spec.bandwidth_bps),
                    ))
            slots_p.append(slot_list)
        remaining = dict(bits0)
        pending = dict(ready)
        done: Dict[int, float] = {}
        t = 0.0
        while remaining and t < max_t:
            push_pending(flq, pending, remaining, t)
            want_slots = []
            demand = np.zeros((P, J))
            for p in range(P):
                ws = []
                for (j, onu, ts, te, rate) in slots_p[p]:
                    te_g = te + cyc
                    if ts < t + cyc and te_g > t:
                        w = rate * max(
                            min(te_g, t + cyc) - max(ts, t), 0.0
                        )
                    elif te_g <= t:
                        # best-effort tail (matches the engine): an
                        # expired slot keeps requesting at the slice
                        # rate so backlog left behind by inter-job
                        # contention drains instead of starving
                        w = rate * cyc
                    else:
                        w = 0.0
                    w = min(w, flq[p][j][onu].backlog)
                    w = w if w > 0.0 else 0.0
                    ws.append(w)
                    demand[p, j] += w
                want_slots.append(ws)
            shares = job_fair_split(demand, cap_p, fairness,
                                    weights=weights, slack=dl_j - t)
            if cps_cap is not None:
                # the (case, pon, job) CPS waterfill: per-PON fairness
                # shares re-capped by the shared CPS uplink, job-minor
                shares = cps_waterfill(
                    shares.reshape(-1), cps_cap
                ).reshape(P, J)
            for p in range(P):
                acc = np.zeros(J)
                grants_onu: Dict[Tuple[int, int], float] = {}
                for (j, onu, ts, te, rate), w in zip(slots_p[p],
                                                     want_slots[p]):
                    g = min(w, max(float(shares[p, j]) - acc[j], 0.0))
                    acc[j] += w
                    if g > 0.0:
                        grants_onu[(j, onu)] = (
                            grants_onu.get((j, onu), 0.0) + g
                        )
                for (j, onu), g in grants_onu.items():
                    served = flq[p][j][onu].serve(g)
                    _credit(served, remaining, done, t, cfg)
            t += cyc
        for cid in list(remaining):
            done[cid] = t + prop
        return done

    if case.policy == "bs":
        dl_done = {
            c.client_id: (mb_of[c.client_id]
                          / (rates[pon_of[c.client_id]] * cfg.efficiency)
                          + prop)
            for c in clients
        }
    else:
        dl_done = fcfs_phase(
            {c.client_id: mb_of[c.client_id] for c in clients},
            {c.client_id: 0.0 for c in clients}, 0,
        )
    ready = {c.client_id: dl_done[c.client_id] + c.t_ud for c in clients}
    bits_ul = {c.client_id: c.m_ud_bits for c in clients}
    if case.policy == "bs":
        ul_done = bs_phase(bits_ul, dict(ready), dl_done)
    else:
        ul_done = fcfs_phase(bits_ul, dict(ready), 1)
    sync = max(ul_done.values()) + case.workload.t_aggregate
    return RoundResult(
        policy=case.policy,
        sync_time=sync,
        dl_done=dl_done,
        ready=ready,
        ul_done=ul_done,
        compute_bound=max(ready.values()),
        load=case.load,
        job_stats=compute_job_stats(jobs, ul_done, n_local, P),
    )
