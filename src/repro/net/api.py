"""Frozen sweep-spec facade over the round and timeline engines.

PR 9 redesigns the public entry points around one immutable bundle:
:class:`SweepSpec` carries the cases, the (optional) multi-round
schedule and every sweep-level knob that used to travel as positional
kwargs, validates the whole bundle once (``.validate()``), and
dispatches through :func:`simulate`. The legacy keyword forms of
``simulate_round_sweep``/``simulate_timeline_sweep`` still work — they
emit a ``DeprecationWarning`` and delegate to the same drivers, so the
two paths are result-identical (asserted in ``tests/test_api.py``).

Builders cover the common shapes::

    spec = SweepSpec.single_job(clients, model_bits=25e6,
                                load=0.6, policy="bs")
    spec = spec.with_schedule(TimelineSchedule(n_rounds=8))
    spec = spec.with_faults(FaultSchedule(dropout_rate=0.05))
    spec = spec.with_jobs(jobs, fairness="weighted")
    results = simulate(spec)
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

from repro.net.engine import SweepCase, _round_sweep, _sweep_topology
from repro.net.jobs import FAIRNESS_POLICIES, validate_case_jobs
from repro.net.sim import FLRoundWorkload, PONConfig
from repro.net.timeline import TimelineSchedule, _timeline_sweep

__all__ = ["SweepSpec", "simulate"]

_MODES = ("auto", "folded", "sequential")
_BACKENDS = (None, "numpy", "jit")
_POLICIES = ("fcfs", "bs")


@dataclass(frozen=True)
class SweepSpec:
    """One immutable sweep description: cases + schedule + knobs.

    ``pon`` is the :class:`repro.net.PONConfig` the sweep runs on
    (``None`` = the defaults, or whatever config is passed explicitly
    to :func:`simulate`). ``schedule`` turns the spec into a
    multi-round timeline; without it the spec is a single-round sweep
    and ``ul_deadline_s``/``ul_outage_s`` apply per round (they are
    illegal WITH a schedule — deadlines then live on the schedule).
    ``mode`` is the timeline fold/sequential selector and must stay
    ``"auto"`` for round sweeps.
    """

    cases: Tuple[SweepCase, ...] = field(default_factory=tuple)
    pon: Optional[PONConfig] = None
    schedule: Optional[TimelineSchedule] = None
    mode: str = "auto"
    t_round_hint: float = 10.0
    max_t: float = 600.0
    ul_deadline_s: Optional[object] = None
    ul_outage_s: Optional[object] = None
    backend: Optional[str] = None

    def __post_init__(self):
        object.__setattr__(self, "cases", tuple(self.cases))

    # -- validation --------------------------------------------------

    def validate(self) -> "SweepSpec":
        """Check the whole bundle; returns ``self`` for chaining."""
        if not self.cases:
            raise ValueError("SweepSpec needs at least one case")
        for b, case in enumerate(self.cases):
            if not isinstance(case, SweepCase):
                raise TypeError(
                    f"cases[{b}] must be a SweepCase; "
                    f"got {type(case).__name__}"
                )
            if case.policy not in _POLICIES:
                raise ValueError(
                    f"cases[{b}]: unknown policy {case.policy!r}; "
                    f"have {_POLICIES}"
                )
            if case.fairness not in FAIRNESS_POLICIES:
                raise ValueError(
                    f"cases[{b}]: unknown fairness {case.fairness!r}; "
                    f"have {FAIRNESS_POLICIES}"
                )
            if case.jobs is not None:
                try:
                    validate_case_jobs(case.jobs, case.workload)
                except ValueError as e:
                    raise ValueError(f"cases[{b}]: {e}") from None
        _sweep_topology(list(self.cases))
        if self.pon is not None and not isinstance(self.pon, PONConfig):
            raise TypeError("pon must be a repro.net.PONConfig or None")
        if self.mode not in _MODES:
            raise ValueError(
                f"unknown mode {self.mode!r}; have {_MODES}"
            )
        if self.backend not in _BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; have {_BACKENDS}"
            )
        if self.schedule is not None:
            if not isinstance(self.schedule, TimelineSchedule):
                raise TypeError(
                    "schedule must be a repro.net.TimelineSchedule"
                )
            if (self.ul_deadline_s is not None
                    or self.ul_outage_s is not None):
                raise ValueError(
                    "timeline specs take deadlines and faults from "
                    "the schedule; ul_deadline_s/ul_outage_s are "
                    "single-round sweep knobs"
                )
        elif self.mode != "auto":
            raise ValueError(
                "mode is a timeline knob; a round sweep (no schedule) "
                "has no folded/sequential split"
            )
        return self

    # -- builders ----------------------------------------------------

    @classmethod
    def single_job(cls, clients, model_bits: float, *, load: float,
                   policy: str = "bs", seed: int = 0,
                   t_aggregate: float = 0.0, topology=None,
                   pon: Optional[PONConfig] = None,
                   **kwargs) -> "SweepSpec":
        """A one-case, single-tenant spec from bare FL inputs."""
        wl = FLRoundWorkload(
            clients=list(clients), model_bits=float(model_bits),
            t_aggregate=float(t_aggregate),
        )
        case = SweepCase(workload=wl, load=float(load), policy=policy,
                         seed=int(seed), topology=topology)
        return cls(cases=(case,), pon=pon, **kwargs)

    def with_schedule(self, schedule: TimelineSchedule) -> "SweepSpec":
        """The same sweep as a multi-round timeline."""
        return replace(self, schedule=schedule)

    def with_faults(self, faults, retry=None) -> "SweepSpec":
        """Attach fault injection to the spec's schedule."""
        if self.schedule is None:
            raise ValueError(
                "with_faults needs a schedule; call "
                "with_schedule(TimelineSchedule(...)) first"
            )
        sched = replace(
            self.schedule, faults=faults,
            retry=retry if retry is not None else self.schedule.retry,
        )
        return replace(self, schedule=sched)

    def with_jobs(self, jobs, fairness: str = "maxmin") -> "SweepSpec":
        """Make every case multi-tenant with the same job tuple."""
        jobs = tuple(jobs)
        return replace(self, cases=tuple(
            replace(case, jobs=jobs, fairness=fairness)
            for case in self.cases
        ))


def simulate(spec: SweepSpec, cfg: Optional[PONConfig] = None,
             collector=None):
    """Run a validated :class:`SweepSpec`.

    Dispatches to the timeline driver when the spec carries a
    ``schedule`` (returns ``List[TimelineResult]``), else to the round
    engine (returns ``List[RoundResult]``). ``cfg`` overrides
    ``spec.pon``; with neither, the default :class:`PONConfig` runs.
    ``collector`` is a ``repro.obs.Collector`` (run-time state, so it
    rides outside the frozen spec).
    """
    if not isinstance(spec, SweepSpec):
        raise TypeError(
            f"simulate takes a SweepSpec; got {type(spec).__name__}"
        )
    spec.validate()
    pon = cfg if cfg is not None else (
        spec.pon if spec.pon is not None else PONConfig()
    )
    cases = list(spec.cases)
    if spec.schedule is not None:
        return _timeline_sweep(
            pon, cases, spec.schedule, mode=spec.mode,
            t_round_hint=spec.t_round_hint, max_t=spec.max_t,
            collector=collector, backend=spec.backend,
        )
    return _round_sweep(
        pon, cases, t_round_hint=spec.t_round_hint, max_t=spec.max_t,
        ul_deadline_s=spec.ul_deadline_s, ul_outage_s=spec.ul_outage_s,
        collector=collector, backend=spec.backend,
    )
