"""repro.obs — array-native metrics, span tracing, export sinks.

The observability subsystem for the co-sim stack: batched engine-side
accumulators (``Collector``, ``PhaseStats``, ``StreamingHistogram``),
a Chrome-trace span tracer (``SpanTracer``), and JSONL/CSV/JSON export
(``EventLog``, ``MetricsReport``).  Everything is opt-in: every entry
point in net/fl/dist/launch takes ``collector=None`` and the disabled
path is bitwise identical to a build without this package.
"""
from repro.obs.export import (  # noqa: F401
    EventLog,
    JsonlSink,
    MetricsReport,
    write_summary_csv,
    write_summary_json,
)
from repro.obs.metrics import (  # noqa: F401
    DEFAULT_DELAY_EDGES,
    DEFAULT_UTIL_EDGES,
    Collector,
    CounterArray,
    GaugeArray,
    PhaseStats,
    StreamingHistogram,
)
from repro.obs.trace import (  # noqa: F401
    NULL_TRACER,
    SpanTracer,
    load_trace,
    maybe_span,
    validate_trace,
)
