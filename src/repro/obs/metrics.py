"""Array-native metrics: counters, gauges, streaming histograms.

The engine computes per-cycle queue depths, grant totals and waterfill
residuals and (until now) threw them away.  This module provides
accumulators that live as batched numpy array state — shaped ``(B, …)``
so they fold under the engine's batch/round/PON row axes exactly like
``_BgQueues`` does — and are updated with a handful of vectorized
reductions per cycle (no per-row Python loops, no host round-trips
beyond the arrays the engine already holds).

Building blocks:

* ``CounterArray`` — monotone additive totals, ``(B, …)`` float64;
* ``GaugeArray`` — last/min/max/sum/count of an observed series
  (mean = sum/count), same shapes;
* ``StreamingHistogram`` — fixed-edge counts with underflow/overflow
  bins, exact ``n``/``sum``/``min``/``max`` sidecars, mergeable, with
  percentile estimation by linear interpolation inside bins (clamped
  to the exact observed min/max so tail percentiles of a single spike
  do not leak outside the data range).

``Collector`` is the config-and-state object the simulation stack
threads through (``simulate_round_sweep``/``simulate_timeline_sweep``/
``CoSimConfig``): it owns the histograms (FL upload delay, deadline
slack, per-cycle utilization), named counters/gauges, per-phase engine
accumulators (``PhaseStats``) and a span tracer.  The strict contract
everywhere it is accepted: ``collector=None`` (the default) leaves
every output bitwise identical — metrics observe, never perturb.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

__all__ = [
    "CounterArray",
    "GaugeArray",
    "StreamingHistogram",
    "PhaseStats",
    "Collector",
    "DEFAULT_DELAY_EDGES",
    "DEFAULT_UTIL_EDGES",
]

# upload delays: 0.1 s bins to 30 s (the engine's default max_t region
# of interest); utilization: 0..1 in 4% steps. Fixed edges keep the
# accumulators mergeable across phases/rounds/processes.
DEFAULT_DELAY_EDGES = np.round(np.linspace(0.0, 30.0, 301), 6)
DEFAULT_UTIL_EDGES = np.round(np.linspace(0.0, 1.0, 26), 6)


class CounterArray:
    """Monotone additive totals, optionally batched ``(B, …)``."""

    def __init__(self, shape=()):
        self.value = np.zeros(shape, np.float64)

    def add(self, x) -> None:
        np.add(self.value, x, out=self.value)

    @property
    def total(self) -> float:
        return float(np.sum(self.value))


class GaugeArray:
    """Summary of an observed series: last/min/max/sum/count."""

    def __init__(self, shape=()):
        self.last = np.zeros(shape, np.float64)
        self.min = np.full(shape, np.inf)
        self.max = np.full(shape, -np.inf)
        self.sum = np.zeros(shape, np.float64)
        self.count = np.zeros(shape, np.int64)

    def observe(self, x) -> None:
        x = np.asarray(x, np.float64)
        self.last = np.broadcast_to(x, self.last.shape).copy() \
            if x.shape != self.last.shape else x.copy()
        np.minimum(self.min, x, out=self.min)
        np.maximum(self.max, x, out=self.max)
        np.add(self.sum, x, out=self.sum)
        self.count += 1

    def observe_block(self, block: np.ndarray) -> None:
        """Fold ``(C, …)`` stacked observations (C per-cycle rows) in
        one shot — the chunked path ``PhaseStats`` flushes through."""
        block = np.asarray(block, np.float64)
        self.last = block[-1].copy()
        np.minimum(self.min, block.min(axis=0), out=self.min)
        np.maximum(self.max, block.max(axis=0), out=self.max)
        np.add(self.sum, block.sum(axis=0), out=self.sum)
        self.count += block.shape[0]

    @property
    def mean(self) -> np.ndarray:
        return self.sum / np.maximum(self.count, 1)

    def summary(self) -> dict:
        n = int(np.max(self.count)) if self.count.size else 0
        if n == 0:
            return {"count": 0}
        return {
            "count": n,
            "mean": float(np.mean(self.mean)),
            "min": float(np.min(self.min)),
            "max": float(np.max(self.max)),
            "last": float(np.mean(self.last)),
        }


class StreamingHistogram:
    """Fixed-edge streaming histogram with under/overflow bins.

    ``edges`` (strictly increasing, length ``E``) define ``E - 1``
    interior bins; ``counts`` has length ``E + 1`` where slot 0 holds
    values ``< edges[0]`` and slot ``E`` values ``> edges[-1]``
    (value ``v`` lands in ``searchsorted(edges, v, side="left")`` with
    exact-edge values going to the bin they close, matching
    ``np.histogram``'s half-open convention on the interior).  With a
    ``batch_shape`` the counts are ``(B, …, E + 1)`` and ``add`` takes
    matching leading row indices — the engine updates every sweep row
    in one call.
    """

    def __init__(self, edges: Sequence[float], batch_shape=()):
        edges = np.asarray(edges, np.float64)
        if edges.ndim != 1 or edges.size < 2:
            raise ValueError("edges must be a 1-D array of >= 2 values")
        if np.any(np.diff(edges) <= 0):
            raise ValueError("edges must be strictly increasing")
        self.edges = edges
        shape = tuple(batch_shape) + (edges.size + 1,)
        self.counts = np.zeros(shape, np.float64)
        lead = tuple(batch_shape)
        self.n = np.zeros(lead, np.float64)
        self.sum = np.zeros(lead, np.float64)
        self.vmin = np.full(lead, np.inf)
        self.vmax = np.full(lead, -np.inf)

    def _bin(self, values: np.ndarray) -> np.ndarray:
        # np.histogram's convention: [e_i, e_{i+1}) half-open, last bin
        # closed. side="right" maps e_i -> bin i+1; shift interior by 1
        # so slot 0 is the underflow and exact top-edge values stay in
        # the last interior bin.
        idx = np.searchsorted(self.edges, values, side="right")
        idx = np.where(values == self.edges[-1], self.edges.size - 1, idx)
        return idx

    def add(self, values, weights=None, rows=None) -> None:
        """Accumulate ``values`` (any shape).

        ``rows``: optional integer row indices (same shape as values)
        selecting the leading batch row each value belongs to; without
        it all values land in the (un-batched) histogram.
        """
        values = np.asarray(values, np.float64).ravel()
        if values.size == 0:
            return
        w = (np.ones_like(values) if weights is None
             else np.asarray(weights, np.float64).ravel())
        idx = self._bin(values)
        if rows is None:
            np.add.at(self.counts, idx, w)
            self.n += w.sum()
            self.sum += float((values * w).sum())
            self.vmin = np.minimum(self.vmin, values.min())
            self.vmax = np.maximum(self.vmax, values.max())
        else:
            rows = np.asarray(rows, np.int64).ravel()
            np.add.at(self.counts, (rows, idx), w)
            np.add.at(self.n, rows, w)
            np.add.at(self.sum, rows, values * w)
            np.minimum.at(self.vmin, rows, values)
            np.maximum.at(self.vmax, rows, values)

    def add_block_per_row(self, block: np.ndarray) -> None:
        """Accumulate a ``(C, B)`` block: one value per batch row per
        cycle, for all ``C`` cycles at once.

        Equivalent to ``C`` calls to ``add(block[c], rows=arange(B))``
        but with a single ``bincount`` instead of per-cycle scattered
        ``ufunc.at`` updates — the fast path ``PhaseStats`` flushes
        its per-cycle utilization samples through.
        """
        block = np.asarray(block, np.float64)
        if block.size == 0:
            return
        C, B = block.shape
        nbins = self.edges.size + 1
        idx = self._bin(block)
        flat = idx + np.arange(B) * nbins        # offset per batch row
        self.counts += np.bincount(
            flat.ravel(), minlength=B * nbins
        ).reshape(B, nbins)
        self.n += C
        self.sum += block.sum(axis=0)
        np.minimum(self.vmin, block.min(axis=0), out=self.vmin)
        np.maximum(self.vmax, block.max(axis=0), out=self.vmax)

    def merge(self, other: "StreamingHistogram") -> None:
        if not np.array_equal(self.edges, other.edges):
            raise ValueError("cannot merge histograms with differing edges")
        self.counts += other.counts
        self.n += other.n
        self.sum += other.sum
        self.vmin = np.minimum(self.vmin, other.vmin)
        self.vmax = np.maximum(self.vmax, other.vmax)

    def flat(self) -> "StreamingHistogram":
        """Batch axes collapsed into one histogram."""
        out = StreamingHistogram(self.edges)
        out.counts = self.counts.reshape(-1, self.counts.shape[-1]) \
            .sum(axis=0)
        out.n = np.asarray(float(np.sum(self.n)))
        out.sum = np.asarray(float(np.sum(self.sum)))
        out.vmin = np.asarray(float(np.min(self.vmin)))
        out.vmax = np.asarray(float(np.max(self.vmax)))
        return out

    def percentile(self, q) -> np.ndarray:
        """Percentile estimate(s) by linear interpolation inside bins.

        Under/overflow mass is pinned to the exact observed min/max
        (the only honest value available outside the edge range).
        Batched histograms return ``(…,) + q.shape`` arrays.
        """
        qs = np.atleast_1d(np.asarray(q, np.float64))
        counts = self.counts.reshape(-1, self.counts.shape[-1])
        n = np.asarray(self.n, np.float64).reshape(-1)
        vmin = np.asarray(self.vmin, np.float64).reshape(-1)
        vmax = np.asarray(self.vmax, np.float64).reshape(-1)
        E = self.edges.size
        # bin supports: underflow/overflow collapse onto observed extremes
        lo = np.concatenate(([0.0], self.edges))
        hi = np.concatenate((self.edges, [0.0]))
        out = np.full((counts.shape[0], qs.size), np.nan)
        for b in range(counts.shape[0]):
            if n[b] <= 0:
                continue
            c = counts[b]
            cum = np.cumsum(c)
            targets = qs / 100.0 * n[b]
            idx = np.searchsorted(cum, targets, side="left")
            idx = np.minimum(idx, E)
            prev = np.where(idx > 0, cum[idx - 1], 0.0)
            width = np.where(c[idx] > 0, (targets - prev) / c[idx], 0.0)
            b_lo = lo[idx].copy()
            b_hi = hi[idx].copy()
            # edge bins: the observed extremes bound the support
            b_lo[idx == 0] = vmin[b]
            b_hi[idx == 0] = min(self.edges[0], vmax[b])
            b_hi[idx == E] = vmax[b]
            b_lo[idx == E] = max(self.edges[-1], vmin[b])
            est = b_lo + width * (b_hi - b_lo)
            out[b] = np.clip(est, vmin[b], vmax[b])
        shape = np.shape(self.n) + qs.shape
        out = out.reshape(shape)
        return out if np.ndim(q) or np.shape(self.n) else float(out[0])

    def summary(self, percentiles=(50.0, 95.0, 99.0)) -> dict:
        h = self.flat() if np.shape(self.n) else self
        n = float(h.n)
        out = {"n": n, "edges": [float(h.edges[0]), float(h.edges[-1])],
               "bins": int(h.edges.size - 1)}
        if n > 0:
            out.update({
                "mean": float(h.sum) / n,
                "min": float(h.vmin),
                "max": float(h.vmax),
            })
            for q, v in zip(percentiles, np.atleast_1d(
                    h.percentile(list(percentiles)))):
                out[f"p{q:g}"] = float(v)
        return out


class PhaseStats:
    """Per-phase engine accumulators over the ``(B,)`` row axis.

    One instance per ``_run_phase`` call; every field folds under the
    engine's row layout (rows are sweep cells, or ``(case, pon)`` pairs
    under a topology, or ``(case, round)`` pairs in the folded
    timeline).  ``cycle(...)`` is called once per polling cycle with
    the arrays the engine already computed; to keep the enabled-
    collector overhead inside the CI budget it only *buffers* the
    references (the engine never mutates them in place — every capture
    is a fresh reduction or a never-written array) and the actual
    sums/min/max/histogram folds run once per ``_CHUNK`` cycles over a
    stacked ``(C, B)`` block.  ``summary()`` flushes the tail.
    """

    _CHUNK = 1024        # cycles buffered between vectorized folds

    def __init__(self, label: str, n_rows: int,
                 util_edges: np.ndarray = DEFAULT_UTIL_EDGES):
        self.label = label
        self.n_rows = n_rows
        self.cycles = np.zeros(n_rows, np.int64)
        self.cap_bits = CounterArray(n_rows)          # offered capacity
        self.bg_backlog = GaugeArray(n_rows)          # per-cycle bg depth
        self.fl_backlog = GaugeArray(n_rows)          # per-cycle FL depth
        self.bg_grant_bits = CounterArray(n_rows)
        self.fl_grant_bits = CounterArray(n_rows)
        self.residual_bits = CounterArray(n_rows)     # unused capacity
        self.util = StreamingHistogram(util_edges, (n_rows,))
        self.cps_want_bits = CounterArray(n_rows)     # CPS demand (row)
        self.cps_eff_bits = CounterArray(n_rows)      # CPS share granted
        self._buf: list = []
        self._zero = np.zeros(n_rows)

    def cycle(self, cap, bg_backlog=None, fl_backlog=None,
              bg_grants=None, fl_grants=None,
              cps_want=None, cps_eff=None) -> None:
        self._buf.append((cap, bg_backlog, fl_backlog, bg_grants,
                          fl_grants, cps_want, cps_eff))
        if len(self._buf) >= self._CHUNK:
            self._flush()

    def _flush(self) -> None:
        if not self._buf:
            return
        B = self.n_rows
        buf = self._buf
        # np.array over a list of same-shape 1-D arrays is a single
        # C-level pass — much cheaper than np.stack's per-item
        # expand_dims (the engine always passes (B,) rows; scalar caps
        # only show up through direct API use)
        caps = np.array([t[0] for t in buf], np.float64)
        if caps.ndim == 1:
            caps = np.repeat(caps[:, None], B, axis=1)
        C = caps.shape[0]
        self.cycles += C
        self.cap_bits.add(caps.sum(axis=0))

        def gather(i):
            vals = [t[i] for t in buf if t[i] is not None]
            return np.array(vals, np.float64) if vals else None

        bgd, fld = gather(1), gather(2)
        if bgd is not None:
            self.bg_backlog.observe_block(bgd)
        if fld is not None:
            self.fl_backlog.observe_block(fld)
        bg_g = np.array([t[3] if t[3] is not None else self._zero
                         for t in buf])
        fl_g = np.array([t[4] if t[4] is not None else self._zero
                         for t in buf])
        self.bg_grant_bits.add(bg_g.sum(axis=0))
        self.fl_grant_bits.add(fl_g.sum(axis=0))
        granted = bg_g + fl_g
        self.residual_bits.add(np.maximum(caps - granted, 0.0).sum(axis=0))
        util = np.divide(granted, caps, out=np.zeros_like(granted),
                         where=caps > 0)
        self.util.add_block_per_row(util)
        cw, ce = gather(5), gather(6)
        if cw is not None:
            self.cps_want_bits.add(cw.sum(axis=0))
        if ce is not None:
            self.cps_eff_bits.add(ce.sum(axis=0))
        self._buf.clear()

    def summary(self) -> dict:
        self._flush()
        cap = self.cap_bits.total
        grant = self.bg_grant_bits.total + self.fl_grant_bits.total
        cps_w = self.cps_want_bits.total
        out = {
            "label": self.label,
            "rows": self.n_rows,
            "cycles": int(self.cycles.max()) if self.n_rows else 0,
            "cap_bits": cap,
            "bg_grant_bits": self.bg_grant_bits.total,
            "fl_grant_bits": self.fl_grant_bits.total,
            "residual_bits": self.residual_bits.total,
            "grant_utilization": grant / cap if cap > 0 else 0.0,
            "bg_backlog": self.bg_backlog.summary(),
            "fl_backlog": self.fl_backlog.summary(),
            "util_hist": self.util.summary(),
        }
        if cps_w > 0:
            out["cps_want_bits"] = cps_w
            out["cps_eff_bits"] = self.cps_eff_bits.total
            out["cps_utilization"] = self.cps_eff_bits.total / cps_w
        return out


class Collector:
    """The observability hub threaded through the co-sim stack.

    Passing a ``Collector`` to ``simulate_round_sweep`` /
    ``simulate_timeline_sweep`` / ``FLNetworkCoSim.run`` /
    ``launch.train`` turns collection on; ``None`` (the default
    everywhere) is the strict no-op whose outputs are bitwise identical
    to a build without this module.

    Collected state:

    * ``phases`` — per-``_run_phase`` ``PhaseStats`` (cycle counts,
      backlog depths, grant utilization, waterfill residuals, CPS
      want/eff per row);
    * ``delay_hist[(policy, load)]`` — FL upload completion-time
      histograms (round-relative seconds);
    * ``slack_hist[(policy, load)]`` — deadline slack (deadline −
      completion) of arrived clients under deadline schedules;
    * ``staleness`` — counts per staleness value τ across rounds;
    * ``counters``/``gauges`` — named scalars (CPS bits, payload bits);
    * ``rounds``/``events`` — per-round and free-form event dicts
      (round wall time, arrived/dropped counts, payload bits);
    * ``tracer`` — a span tracer (``repro.obs.trace.SpanTracer``); the
      default is disabled (spans are no-ops) unless one is passed in.
    """

    def __init__(self,
                 delay_edges: Sequence[float] = DEFAULT_DELAY_EDGES,
                 util_edges: Sequence[float] = DEFAULT_UTIL_EDGES,
                 slack_edges: Optional[Sequence[float]] = None,
                 tracer=None,
                 keep_phases: bool = True):
        from repro.obs.trace import SpanTracer

        self.delay_edges = np.asarray(delay_edges, np.float64)
        self.util_edges = np.asarray(util_edges, np.float64)
        self.slack_edges = (self.delay_edges - self.delay_edges[-1] / 2
                            if slack_edges is None
                            else np.asarray(slack_edges, np.float64))
        self.tracer = tracer if tracer is not None else SpanTracer(
            enabled=False
        )
        self.keep_phases = keep_phases
        self.phases: List[PhaseStats] = []
        self.delay_hist: Dict[tuple, StreamingHistogram] = {}
        self.slack_hist: Dict[tuple, StreamingHistogram] = {}
        self.staleness: Dict[int, float] = {}
        self.counters: Dict[str, CounterArray] = {}
        self.gauges: Dict[str, GaugeArray] = {}
        self.rounds: List[dict] = []
        self.events: List[dict] = []

    # -- engine hooks -----------------------------------------------------

    def phase(self, label: str, n_rows: int) -> PhaseStats:
        st = PhaseStats(label, n_rows, self.util_edges)
        if self.keep_phases:
            self.phases.append(st)
        return st

    def record_upload_times(self, policy: str, load: float,
                            times) -> None:
        times = np.asarray(times, np.float64)
        times = times[np.isfinite(times)]
        if times.size == 0:
            return
        key = (policy, round(float(load), 6))
        hist = self.delay_hist.get(key)
        if hist is None:
            hist = self.delay_hist[key] = StreamingHistogram(
                self.delay_edges
            )
        hist.add(times)

    def record_slack(self, policy: str, load: float, slack) -> None:
        slack = np.asarray(slack, np.float64)
        slack = slack[np.isfinite(slack)]
        if slack.size == 0:
            return
        key = (policy, round(float(load), 6))
        hist = self.slack_hist.get(key)
        if hist is None:
            hist = self.slack_hist[key] = StreamingHistogram(
                self.slack_edges
            )
        hist.add(slack)

    def record_staleness(self, taus) -> None:
        for t in np.atleast_1d(np.asarray(taus, np.int64)).ravel():
            t = int(t)
            self.staleness[t] = self.staleness.get(t, 0.0) + 1.0

    # -- generic named metrics -------------------------------------------

    def counter(self, name: str, shape=()) -> CounterArray:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = CounterArray(shape)
        return c

    def gauge(self, name: str, shape=()) -> GaugeArray:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = GaugeArray(shape)
        return g

    # -- event streams ----------------------------------------------------

    def record_round(self, **fields) -> None:
        self.rounds.append(dict(fields))

    def event(self, kind: str, **fields) -> None:
        self.events.append({"kind": kind, **fields})

    # -- reporting ---------------------------------------------------------

    def report(self):
        """Fold everything into a serialisable ``MetricsReport``."""
        from repro.obs.export import MetricsReport

        return MetricsReport.from_collector(self)
