"""Export sinks: JSONL event logs, CSV/JSON summaries, MetricsReport.

Three consumers share these writers:

* ``launch.train`` / ``launch.serve`` — structured JSONL round/step
  events (``--log-jsonl``), with the legacy console lines kept as a
  *formatted view* of the same events (``EventLog``);
* benchmarks — ``MetricsReport`` summaries written as JSON + CSV
  artifacts next to the BENCH payloads;
* tests — round-trip the formats.

Every event is one JSON object per line with at least ``event`` and
``ts`` (unix seconds) keys; numeric values stay numbers so downstream
``jq``/pandas need no coercion.
"""
from __future__ import annotations

import csv
import json
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

__all__ = [
    "JsonlSink",
    "EventLog",
    "MetricsReport",
    "write_summary_json",
    "write_summary_csv",
]


def _jsonable(v):
    import numpy as np

    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return v


class JsonlSink:
    """Append-only JSON-lines event sink (one object per line)."""

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "a")
        self.n_events = 0

    def emit(self, event: dict) -> None:
        self._f.write(json.dumps(_jsonable(event), sort_keys=True))
        self._f.write("\n")
        self._f.flush()
        self.n_events += 1

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class EventLog:
    """Structured events with the console as a formatted view.

    ``emit("round", echo="round {round}: loss={loss:.4f}", round=3,
    loss=0.1)`` writes the full event to the JSONL sink (when one is
    attached) and prints the ``echo`` format string — so the CLI output
    stays exactly what it always was while every line gains a
    machine-readable twin.  ``echo=None`` logs silently.
    """

    def __init__(self, jsonl_path: Optional[str] = None,
                 console: bool = True, clock=time.time):
        self.sink = JsonlSink(jsonl_path) if jsonl_path else None
        self.console = console
        self._clock = clock

    def emit(self, event: str, echo: Optional[str] = None,
             **fields) -> None:
        if self.sink is not None:
            self.sink.emit({"event": event, "ts": self._clock(),
                            **fields})
        if self.console and echo is not None:
            print(echo.format(**fields), flush=True)

    def close(self) -> None:
        if self.sink is not None:
            self.sink.close()


def write_summary_json(path: str, payload: dict) -> None:
    with open(path, "w") as f:
        json.dump(_jsonable(payload), f, indent=2, sort_keys=True)
        f.write("\n")


def write_summary_csv(path: str, rows: List[dict]) -> None:
    """Rows of flat dicts -> CSV with the union of keys as header."""
    keys: List[str] = []
    for row in rows:
        for k in row:
            if k not in keys:
                keys.append(k)
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys)
        w.writeheader()
        for row in rows:
            w.writerow({k: _jsonable(row.get(k, "")) for k in keys})


@dataclass
class MetricsReport:
    """Serializable fold of a ``Collector``: what benchmarks emit and
    ``launch.train`` appends as its final JSONL event."""

    counters: Dict[str, float] = field(default_factory=dict)
    gauges: Dict[str, dict] = field(default_factory=dict)
    delay_percentiles: Dict[str, dict] = field(default_factory=dict)
    slack_percentiles: Dict[str, dict] = field(default_factory=dict)
    staleness: Dict[str, float] = field(default_factory=dict)
    phases: List[dict] = field(default_factory=list)
    rounds: List[dict] = field(default_factory=list)
    n_events: int = 0

    @classmethod
    def from_collector(cls, collector) -> "MetricsReport":
        delay = {
            f"{policy}@load{load:g}": hist.summary()
            for (policy, load), hist in sorted(collector.delay_hist.items())
        }
        slack = {
            f"{policy}@load{load:g}": hist.summary()
            for (policy, load), hist in sorted(collector.slack_hist.items())
        }
        return cls(
            counters={k: c.total for k, c in sorted(
                collector.counters.items())},
            gauges={k: g.summary() for k, g in sorted(
                collector.gauges.items())},
            delay_percentiles=delay,
            slack_percentiles=slack,
            staleness={str(k): v for k, v in sorted(
                collector.staleness.items())},
            phases=[p.summary() for p in collector.phases],
            rounds=list(collector.rounds),
            n_events=len(collector.events),
        )

    def to_dict(self) -> dict:
        return _jsonable({
            "counters": self.counters,
            "gauges": self.gauges,
            "delay_percentiles": self.delay_percentiles,
            "slack_percentiles": self.slack_percentiles,
            "staleness": self.staleness,
            "phases": self.phases,
            "rounds": self.rounds,
            "n_events": self.n_events,
        })

    def save_json(self, path: str) -> None:
        write_summary_json(path, self.to_dict())

    def phase_rows(self) -> List[dict]:
        """Flat per-phase rows for the CSV artifact."""
        rows = []
        for p in self.phases:
            rows.append({
                "phase": p.get("label", ""),
                "rows": p.get("rows", 0),
                "cycles": p.get("cycles", 0),
                "cap_bits": p.get("cap_bits", 0.0),
                "bg_grant_bits": p.get("bg_grant_bits", 0.0),
                "fl_grant_bits": p.get("fl_grant_bits", 0.0),
                "residual_bits": p.get("residual_bits", 0.0),
                "grant_utilization": p.get("grant_utilization", 0.0),
                "cps_utilization": p.get("cps_utilization", ""),
            })
        return rows

    def save_csv(self, path: str) -> None:
        write_summary_csv(path, self.phase_rows())
