"""Nested span tracer exporting Chrome trace-event JSON.

Host-side orchestration (engine phases, timeline rounds, aggregation,
checkpointing, co-sim coupling) is a tree of spans; this records them
as Chrome trace-event "X" (complete) events viewable in Perfetto /
``chrome://tracing``.  Disabled tracers are strict no-ops: ``span()``
yields immediately with no timestamping, so instrumented code paths
cost one attribute check when tracing is off.

Format (Chrome trace-event spec,
https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU):
``{"traceEvents": [{"name", "ph": "X", "ts", "dur", "pid", "tid",
"cat", "args"}, ...], "displayTimeUnit": "ms"}`` with timestamps in
microseconds.
"""
from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager
from typing import List

__all__ = ["SpanTracer", "NULL_TRACER", "load_trace", "validate_trace",
           "maybe_span"]

_REQUIRED_KEYS = ("name", "ph", "ts", "dur", "pid", "tid")


class SpanTracer:
    """Collects nested spans; ``enabled=False`` is a strict no-op."""

    def __init__(self, enabled: bool = True,
                 clock=time.perf_counter):
        self.enabled = enabled
        self._clock = clock
        self._t0 = clock()
        self.events: List[dict] = []
        self._depth = 0
        self._pid = os.getpid()
        self._tid = threading.get_ident() & 0xFFFF

    def _now_us(self) -> float:
        return (self._clock() - self._t0) * 1e6

    @contextmanager
    def span(self, name: str, cat: str = "repro", **args):
        if not self.enabled:
            yield self
            return
        t_start = self._now_us()
        self._depth += 1
        try:
            yield self
        finally:
            self._depth -= 1
            self.events.append({
                "name": name,
                "ph": "X",
                "ts": t_start,
                "dur": self._now_us() - t_start,
                "pid": self._pid,
                "tid": self._tid,
                "cat": cat,
                "args": {k: _jsonable(v) for k, v in args.items()},
            })

    def instant(self, name: str, cat: str = "repro", **args) -> None:
        if not self.enabled:
            return
        self.events.append({
            "name": name,
            "ph": "i",
            "ts": self._now_us(),
            "dur": 0.0,
            "pid": self._pid,
            "tid": self._tid,
            "cat": cat,
            "s": "t",
            "args": {k: _jsonable(v) for k, v in args.items()},
        })

    def to_chrome(self) -> dict:
        return {
            "traceEvents": sorted(self.events, key=lambda e: e["ts"]),
            "displayTimeUnit": "ms",
        }

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, indent=1)
            f.write("\n")


def _jsonable(v):
    try:
        json.dumps(v)
        return v
    except (TypeError, ValueError):
        return repr(v)


def load_trace(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def validate_trace(payload: dict) -> List[dict]:
    """Schema check; returns the events (raises on malformed input)."""
    events = payload.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError("trace payload missing 'traceEvents' list")
    for e in events:
        missing = [k for k in _REQUIRED_KEYS if k not in e]
        if missing:
            raise ValueError(f"trace event {e.get('name')!r} missing "
                             f"required keys {missing}")
        if e["ph"] not in ("X", "i", "B", "E", "M"):
            raise ValueError(f"unknown trace phase {e['ph']!r}")
        if e["ph"] == "X" and e["dur"] < 0:
            raise ValueError(f"negative span duration in {e['name']!r}")
    return events


NULL_TRACER = SpanTracer(enabled=False)


def maybe_span(collector, name: str, **args):
    """``collector.tracer.span(...)`` or a no-op context when
    ``collector`` is None — the one-liner instrumented call sites use
    so the disabled path stays a single identity check."""
    if collector is None:
        from contextlib import nullcontext

        return nullcontext()
    return collector.tracer.span(name, **args)
