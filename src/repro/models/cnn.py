"""The paper's own FL model: the LEAF FEMNIST CNN (two 5x5 conv layers).

Architecture (LEAF benchmark, arXiv:1812.01097): 28x28x1 input ->
conv5x5(32) -> maxpool2 -> conv5x5(64) -> maxpool2 -> fc(2048) -> fc(62).
~6.6 M params; at fp32 that is ~26.4 MB — the paper quotes 26.416 Mbit per
client update (their constant is reproduced verbatim in the benchmarks).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import softmax_cross_entropy

N_CLASSES = 62
IMG = 28


def init_params(key, n_classes: int = N_CLASSES, width: int = 1):
    """width scales the channel counts (width=1 is the paper's model)."""
    c1, c2, fc = 32 * width, 64 * width, 2048 * width
    k1, k2, k3, k4 = jax.random.split(key, 4)
    flat = 7 * 7 * c2
    return {
        "conv1": {
            "w": jax.random.normal(k1, (5, 5, 1, c1)) * (25 ** -0.5),
            "b": jnp.zeros((c1,)),
        },
        "conv2": {
            "w": jax.random.normal(k2, (5, 5, c1, c2)) * ((25 * c1) ** -0.5),
            "b": jnp.zeros((c2,)),
        },
        "fc1": {
            "w": jax.random.normal(k3, (flat, fc)) * (flat ** -0.5),
            "b": jnp.zeros((fc,)),
        },
        "fc2": {
            "w": jax.random.normal(k4, (fc, n_classes)) * (fc ** -0.5),
            "b": jnp.zeros((n_classes,)),
        },
    }


def _conv(x, p):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + p["b"]


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def forward(params, images):
    """images: (B, 28, 28, 1) float32 -> logits (B, n_classes)."""
    x = jax.nn.relu(_conv(images, params["conv1"]))
    x = _maxpool2(x)
    x = jax.nn.relu(_conv(x, params["conv2"]))
    x = _maxpool2(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return x @ params["fc2"]["w"] + params["fc2"]["b"]


def loss_fn(params, batch):
    logits = forward(params, batch["images"])
    return softmax_cross_entropy(logits, batch["labels"])


def accuracy(params, batch):
    logits = forward(params, batch["images"])
    return jnp.mean(
        (jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32)
    )


def param_bytes(params) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(params))


def param_bits(params) -> int:
    return 8 * param_bytes(params)
