"""Mamba-2 mixer built on SSD (state-space duality) — arXiv:2405.21060.

Block: in_proj -> [z | xBC | dt] -> causal conv on xBC -> SiLU ->
SSD recurrence over heads -> gated RMSNorm(y * silu(z)) -> out_proj.

The model path uses the *chunked* SSD algorithm in pure jnp (linear in S,
matmul-dominated — the TPU-native adaptation: intra-chunk quadratic term hits
the MXU, inter-chunk low-rank state pass is a cheap scan). The Pallas kernel
(repro.kernels.ssd) mirrors the same schedule with explicit VMEM tiling and
``ref.py`` holds the slow token-recurrence oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.models.rglru import causal_conv1d

NEG_INF = -1e30


def ssd_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.d_head
    d_xbc = d_inner + 2 * s.d_state
    return d_inner, n_heads, d_xbc


def ssd_block_init(key, cfg: ModelConfig):
    s = cfg.ssm
    D = cfg.d_model
    d_inner, n_heads, d_xbc = ssd_dims(cfg)
    dt = cfg.param_dtype
    ks = jax.random.split(key, 4)
    d_proj = d_inner + d_xbc + n_heads  # z | xBC | dt
    return {
        "in_proj": dense_init(ks[0], D, d_proj, dt),
        "conv_w": (
            jax.random.normal(ks[1], (s.d_conv, d_xbc)) * (s.d_conv ** -0.5)
        ).astype(dt),
        "a_log": jnp.zeros((n_heads,), dt),         # A = -exp(a_log) = -1
        "dt_bias": jnp.zeros((n_heads,), dt),
        "d_skip": jnp.ones((n_heads,), dt),
        "gate_norm_scale": jnp.ones((d_inner,), dt),
        "out_proj": dense_init(ks[2], d_inner, D, dt),
    }


def _split_proj(params, x, cfg: ModelConfig):
    d_inner, n_heads, d_xbc = ssd_dims(cfg)
    proj = x @ params["in_proj"].astype(x.dtype)
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner : d_inner + d_xbc]
    dt_raw = proj[..., d_inner + d_xbc :]
    return z, xbc, dt_raw


def _conv_split(params, xbc, cfg: ModelConfig, conv_state=None):
    s = cfg.ssm
    d_inner, _, _ = ssd_dims(cfg)
    xbc, new_conv = causal_conv1d(xbc, params["conv_w"], conv_state)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :d_inner]
    B_mat = xbc[..., d_inner : d_inner + s.d_state]
    C_mat = xbc[..., d_inner + s.d_state :]
    return xs, B_mat, C_mat, new_conv


def ssd_chunked(xh, B_mat, C_mat, dt, a, chunk, h0=None):
    """Chunked SSD scan.

    xh:    (B, S, H, P)   per-head inputs
    B_mat: (B, S, N)      input projection (single group, shared across heads)
    C_mat: (B, S, N)      output projection
    dt:    (B, S, H)      positive step sizes (post-softplus) fp32
    a:     (H,)           negative decay rates (A = -exp(a_log)) fp32
    h0:    (B, H, P, N)   initial state or None
    Returns (y: (B,S,H,P), h_final: (B,H,P,N)) in fp32.
    """
    Bsz, S, H, P = xh.shape
    N = B_mat.shape[-1]
    Q = min(chunk, S)
    S_orig = S
    if S % Q:
        # pad tail with dt=0 steps: decay exp(0)=1 keeps state, zero input
        pad = Q - S % Q
        pad_cfg = [(0, 0), (0, pad)] + [(0, 0)] * (xh.ndim - 2)
        xh = jnp.pad(xh, pad_cfg)
        B_mat = jnp.pad(B_mat, [(0, 0), (0, pad), (0, 0)])
        C_mat = jnp.pad(C_mat, [(0, 0), (0, pad), (0, 0)])
        dt = jnp.pad(dt, [(0, 0), (0, pad), (0, 0)])
        S = S + pad
    nc = S // Q

    xh = xh.astype(jnp.float32).reshape(Bsz, nc, Q, H, P)
    Bm = B_mat.astype(jnp.float32).reshape(Bsz, nc, Q, N)
    Cm = C_mat.astype(jnp.float32).reshape(Bsz, nc, Q, N)
    dt = dt.reshape(Bsz, nc, Q, H)

    dA = dt * a[None, None, None, :]                     # (B,nc,Q,H) negative
    cum = jnp.cumsum(dA, axis=2)                         # inclusive cumsum
    seg_total = cum[:, :, -1:, :]                        # (B,nc,1,H)

    # --- intra-chunk (quadratic in Q, matmul-dominated) ---
    # L[t, s] = exp(cum_t - cum_s) for s <= t else 0
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), jnp.float32))
    L = jnp.exp(rel) * tri[None, None, :, :, None]
    cb = jnp.einsum("bcqn,bcsn->bcqs", Cm, Bm)           # (B,nc,Q,Q)
    scores = cb[..., None] * L                           # (B,nc,Q,Q,H)
    xdt = xh * dt[..., None]                             # (B,nc,Q,H,P)
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", scores, xdt)

    # --- per-chunk end state: sum_s exp(seg_total - cum_s) dt_s B_s x_s ---
    decay_to_end = jnp.exp(seg_total - cum)              # (B,nc,Q,H)
    states = jnp.einsum(
        "bcqh,bcqn,bcqhp->bchpn", decay_to_end, Bm, xdt
    )                                                    # (B,nc,H,P,N)

    # --- inter-chunk recurrence over nc (cheap scan) ---
    seg_decay = jnp.exp(seg_total[:, :, 0, :])           # (B,nc,H)

    def step(h, inp):
        sd, st = inp                                     # (B,H), (B,H,P,N)
        h_new = h * sd[..., None, None] + st
        return h_new, h                                  # emit state *before*

    h_init = (
        jnp.zeros((Bsz, H, P, N), jnp.float32) if h0 is None
        else h0.astype(jnp.float32)
    )
    h_last, h_prev = jax.lax.scan(
        step,
        h_init,
        (jnp.moveaxis(seg_decay, 1, 0), jnp.moveaxis(states, 1, 0)),
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)                  # (B,nc,H,P,N)

    # --- inter-chunk contribution: C_t exp(cum_t) h_prev ---
    y_inter = jnp.einsum(
        "bcqn,bchpn,bcqh->bcqhp", Cm, h_prev, jnp.exp(cum)
    )
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)[:, :S_orig]
    return y, h_last


def _gated_norm(y, z, scale, eps: float = 1e-6):
    g = y * jax.nn.silu(z.astype(y.dtype))
    ms = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    return g * jax.lax.rsqrt(ms + eps) * scale.astype(y.dtype)


def _ssd_core(params, x, cfg, conv_state=None, h0=None):
    s = cfg.ssm
    d_inner, n_heads, _ = ssd_dims(cfg)
    Bsz, S, _ = x.shape
    z, xbc, dt_raw = _split_proj(params, x, cfg)
    xs, B_mat, C_mat, new_conv = _conv_split(params, xbc, cfg, conv_state)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    xh = xs.reshape(Bsz, S, n_heads, s.d_head)
    y, h_last = ssd_chunked(xh, B_mat, C_mat, dt, a, s.chunk, h0)
    y = y + xh.astype(jnp.float32) * params["d_skip"].astype(jnp.float32)[
        None, None, :, None
    ]
    y = y.reshape(Bsz, S, d_inner).astype(x.dtype)
    y = _gated_norm(y, z, params["gate_norm_scale"])
    return y @ params["out_proj"].astype(x.dtype), new_conv, h_last


def ssd_full(params, x, cfg: ModelConfig, spec=None, positions=None):
    y, _, _ = _ssd_core(params, x, cfg)
    return y


def init_ssd_cache(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    d_inner, n_heads, d_xbc = ssd_dims(cfg)
    return {
        "h": jnp.zeros((batch, n_heads, s.d_head, s.d_state), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, d_xbc), cfg.dtype),
    }


def ssd_prefill(params, x, cfg, spec, positions, cache):
    y, new_conv, h_last = _ssd_core(params, x, cfg, cache["conv"], cache["h"])
    return y, {"h": h_last, "conv": new_conv}


def ssd_decode(params, x, cfg, spec, pos, cache):
    """Single-token state update. x: (B,1,D)."""
    s = cfg.ssm
    d_inner, n_heads, _ = ssd_dims(cfg)
    Bsz = x.shape[0]
    z, xbc, dt_raw = _split_proj(params, x, cfg)
    xs, B_mat, C_mat, new_conv = _conv_split(params, xbc, cfg, cache["conv"])
    dt = jax.nn.softplus(
        dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )                                                    # (B,H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    xh = xs[:, 0].reshape(Bsz, n_heads, s.d_head).astype(jnp.float32)
    dA = jnp.exp(dt * a[None, :])                        # (B,H)
    inc = jnp.einsum(
        "bh,bn,bhp->bhpn", dt, B_mat[:, 0].astype(jnp.float32), xh
    )
    h = cache["h"] * dA[..., None, None] + inc
    y = jnp.einsum("bn,bhpn->bhp", C_mat[:, 0].astype(jnp.float32), h)
    y = y + xh * params["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(Bsz, 1, d_inner).astype(x.dtype)
    y = _gated_norm(y, z, params["gate_norm_scale"])
    return y @ params["out_proj"].astype(x.dtype), {"h": h, "conv": new_conv}
