"""Decoder-only language model assembled from the config-driven block zoo.

Layer stacking: the config's repeating *pattern unit* (e.g. gemma3's
5×local + 1×global, recurrentgemma's rec-rec-attn) is initialised as a
stacked pytree with a leading ``n_units`` axis and applied with
``jax.lax.scan`` — HLO size is O(1) in depth, which is what a production
deployment (and a 1-core compile budget) needs. Remainder layers
(n_layers % unit_len) get their own unrolled params.

Three entry points per the assigned shapes:
  ``forward_train`` (+ ``loss_fn``)  — train_4k
  ``prefill``                        — prefill_32k (fills KV caches)
  ``decode_step``                    — decode_32k / long_500k (1 token)
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, RGLRU, SSD, LayerSpec, ModelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssd as ssd_mod
from repro.models.layers import (
    apply_norm,
    embed_init,
    mlp_apply,
    mlp_init,
    norm_init,
    sinusoidal_embed,
    softmax_cross_entropy,
)


def _add_abs_pos(x, cfg, positions):
    if cfg.abs_sinusoidal:
        x = x + sinusoidal_embed(positions, cfg.d_model).astype(x.dtype)
    return x

# ---------------------------------------------------------------------------
# block = mixer (+ FFN/MoE) with pre-norms
# ---------------------------------------------------------------------------


def _block_init(key, cfg: ModelConfig, spec: LayerSpec):
    k_mix, k_ffn, k_moe = jax.random.split(key, 3)
    p = {"mix_norm": norm_init(cfg)}
    if spec.kind == ATTN:
        p["mixer"] = attn_mod.attn_init(k_mix, cfg)
    elif spec.kind == RGLRU:
        p["mixer"] = rglru_mod.rglru_block_init(k_mix, cfg)
    elif spec.kind == SSD:
        p["mixer"] = ssd_mod.ssd_block_init(k_mix, cfg)
    if spec.kind != SSD:  # mamba2 blocks carry no FFN (d_ff == 0)
        if cfg.moe is not None and spec.kind == ATTN:
            p["ffn_norm"] = norm_init(cfg)
            p["moe"] = moe_mod.moe_init(k_moe, cfg)
            if cfg.moe.dense_residual:
                p["mlp"] = mlp_init(k_ffn, cfg)          # arctic dense branch
        elif cfg.d_ff > 0:
            p["ffn_norm"] = norm_init(cfg)
            p["mlp"] = mlp_init(k_ffn, cfg)
    return p


def _block_apply(params, x, cfg, spec, positions, mode, cache, pos):
    """Returns (y, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(params["mix_norm"], x, cfg)
    new_cache = cache
    if spec.kind == ATTN:
        if mode == "train":
            mix = attn_mod.attn_full(params["mixer"], h, cfg, spec, positions)
        elif mode == "prefill":
            mix, new_cache = attn_mod.attn_prefill(
                params["mixer"], h, cfg, spec, positions, cache
            )
        else:
            mix, new_cache = attn_mod.attn_decode(
                params["mixer"], h, cfg, spec, pos, cache
            )
    elif spec.kind == RGLRU:
        if mode == "train":
            mix = rglru_mod.rglru_full(params["mixer"], h, cfg, spec, positions)
        elif mode == "prefill":
            mix, new_cache = rglru_mod.rglru_prefill(
                params["mixer"], h, cfg, spec, positions, cache
            )
        else:
            mix, new_cache = rglru_mod.rglru_decode(
                params["mixer"], h, cfg, spec, pos, cache
            )
    else:  # SSD
        if mode == "train":
            mix = ssd_mod.ssd_full(params["mixer"], h, cfg, spec, positions)
        elif mode == "prefill":
            mix, new_cache = ssd_mod.ssd_prefill(
                params["mixer"], h, cfg, spec, positions, cache
            )
        else:
            mix, new_cache = ssd_mod.ssd_decode(
                params["mixer"], h, cfg, spec, pos, cache
            )
    x = x + mix

    if "moe" in params:
        h2 = apply_norm(params["ffn_norm"], x, cfg)
        moe_out, moe_aux = moe_mod.moe_apply(params["moe"], h2, cfg)
        aux = aux + moe_aux
        ffn_out = moe_out
        if "mlp" in params:                              # arctic dense residual
            ffn_out = ffn_out + mlp_apply(params["mlp"], h2, cfg)
        x = x + ffn_out
    elif "mlp" in params:
        h2 = apply_norm(params["ffn_norm"], x, cfg)
        x = x + mlp_apply(params["mlp"], h2, cfg)
    return x, new_cache, aux


def _unit_init(key, cfg: ModelConfig, pattern):
    keys = jax.random.split(key, max(len(pattern), 1))
    return {
        f"b{i}": _block_init(keys[i], cfg, spec)
        for i, spec in enumerate(pattern)
    }


def _unit_apply(params, x, cfg, pattern, positions, mode, cache, pos):
    new_cache = {}
    aux = jnp.zeros((), jnp.float32)
    for i, spec in enumerate(pattern):
        c = cache.get(f"b{i}") if cache else None
        x, nc, a = _block_apply(
            params[f"b{i}"], x, cfg, spec, positions, mode, c, pos
        )
        if nc is not None:
            new_cache[f"b{i}"] = nc
        aux = aux + a
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig):
    k_emb, k_units, k_rem, k_head = jax.random.split(key, 4)
    unit_keys = jax.random.split(k_units, cfg.n_units)
    params = {
        "embed": embed_init(k_emb, cfg.vocab_size, cfg.d_model, cfg.param_dtype),
        "units": jax.vmap(lambda k: _unit_init(k, cfg, cfg.pattern))(unit_keys),
        "final_norm": norm_init(cfg),
    }
    if cfg.n_remainder:
        params["rem"] = _unit_init(k_rem, cfg, cfg.remainder_pattern)
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(k_head, (cfg.d_model, cfg.vocab_size)) * 0.02
        ).astype(cfg.param_dtype)
    return params


def _embed(params, cfg, tokens, extra_embeds):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.dtype)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(cfg.dtype), x], axis=1)
    return x


def _logits(params, cfg, x):
    x = apply_norm(params["final_norm"], x, cfg)
    head = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ).astype(cfg.dtype)
    logits = x @ head
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits


def _remat_wrap(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return fn


def _run_stack(params, cfg, x, positions, mode, cache, pos):
    """Scan the stacked units, then the remainder unit."""
    aux0 = jnp.zeros((), jnp.float32)

    def unit_fn(carry, xs):
        xc, aux = carry
        unit_params, unit_cache = xs
        y, new_cache, a = _unit_apply(
            unit_params, xc, cfg, cfg.pattern, positions, mode, unit_cache, pos
        )
        return (y, aux + a), new_cache

    unit_fn = _remat_wrap(unit_fn, cfg)
    stacked_cache = cache["units"] if cache else None
    if stacked_cache is None:

        def unit_fn_nocache(carry, unit_params):  # train path, no cache
            xc, aux = carry
            y, _, a = _unit_apply(
                unit_params, xc, cfg, cfg.pattern, positions, mode, None, pos
            )
            return (y, aux + a), None

        unit_fn_nocache = _remat_wrap(unit_fn_nocache, cfg)
        (x, aux), _ = jax.lax.scan(
            unit_fn_nocache, (x, aux0), params["units"]
        )
        new_unit_caches = None
    else:
        (x, aux), new_unit_caches = jax.lax.scan(
            unit_fn, (x, aux0), (params["units"], stacked_cache)
        )

    new_rem_cache = None
    if cfg.n_remainder:
        rem_cache = cache["rem"] if cache else None
        x, new_rem_cache, a = _unit_apply(
            params["rem"], x, cfg, cfg.remainder_pattern, positions, mode,
            rem_cache, pos,
        )
        aux = aux + a
    new_cache = None
    if cache is not None:
        new_cache = {"units": new_unit_caches, "rem": new_rem_cache,
                     "pos": cache["pos"] + (1 if mode == "decode" else 0)}
        if mode == "prefill":
            new_cache["pos"] = jnp.asarray(positions.shape[-1], jnp.int32)
        if new_rem_cache is None:
            new_cache.pop("rem")
    return x, new_cache, aux


def forward_train(params, cfg: ModelConfig, tokens, extra_embeds=None):
    """tokens: (B, S_text) int32; extra_embeds: (B, n_frontend, D) or None."""
    x = _embed(params, cfg, tokens, extra_embeds)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = _add_abs_pos(x, cfg, positions)
    x, _, aux = _run_stack(params, cfg, x, positions, "train", None, None)
    return _logits(params, cfg, x), aux


def loss_fn(params, cfg: ModelConfig, batch):
    """batch: tokens (B,S), labels (B,S), optional weights, extra_embeds."""
    logits, aux = forward_train(
        params, cfg, batch["tokens"], batch.get("extra_embeds")
    )
    n_front = cfg.n_frontend_tokens if batch.get("extra_embeds") is not None else 0
    logits = logits[:, n_front:]
    loss = softmax_cross_entropy(logits, batch["labels"], batch.get("weights"))
    return loss + aux


# ---------------------------------------------------------------------------
# caches / serving
# ---------------------------------------------------------------------------


def _block_cache(cfg, spec: LayerSpec, batch, max_len):
    if spec.kind == ATTN:
        return attn_mod.init_layer_cache(cfg, spec, batch, max_len)
    if spec.kind == RGLRU:
        return rglru_mod.init_rglru_cache(cfg, batch)
    return ssd_mod.init_ssd_cache(cfg, batch)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    unit_cache = {
        f"b{i}": _block_cache(cfg, spec, batch, max_len)
        for i, spec in enumerate(cfg.pattern)
    }
    cache = {
        "units": jax.tree.map(
            lambda l: jnp.zeros((cfg.n_units,) + l.shape, l.dtype), unit_cache
        ),
        "pos": jnp.zeros((), jnp.int32),
    }
    if cfg.n_remainder:
        cache["rem"] = {
            f"b{i}": _block_cache(cfg, spec, batch, max_len)
            for i, spec in enumerate(cfg.remainder_pattern)
        }
    return cache


def prefill(params, cfg: ModelConfig, tokens, cache, extra_embeds=None):
    """Forward over the prompt, filling caches. Returns (logits, cache)."""
    x = _embed(params, cfg, tokens, extra_embeds)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = _add_abs_pos(x, cfg, positions)
    x, new_cache, _ = _run_stack(params, cfg, x, positions, "prefill", cache, None)
    return _logits(params, cfg, x[:, -1:]), new_cache


def decode_step(params, cfg: ModelConfig, token, cache):
    """token: (B, 1) int32. Returns (logits (B,1,V), new_cache)."""
    pos = cache["pos"]
    x = _embed(params, cfg, token, None)
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    x = _add_abs_pos(x, cfg, positions)
    x, new_cache, _ = _run_stack(params, cfg, x, positions, "decode", cache, pos)
    return _logits(params, cfg, x), new_cache
