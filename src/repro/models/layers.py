"""Shared neural-net building blocks (pure functional JAX).

Parameters are plain nested dicts of jnp arrays; every block exposes
``init(key, cfg, ...) -> params`` and ``apply(params, x, ...) -> y``.
Compute runs in ``cfg.dtype`` with fp32 reductions where it matters.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = (d_in ** -0.5) if scale is None else scale
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# normalisation
# ---------------------------------------------------------------------------


def norm_init(cfg: ModelConfig, d: int | None = None):
    d = d or cfg.d_model
    if cfg.norm == "layernorm_nonparam":          # olmo: no scale / bias
        return {}
    if cfg.norm == "layernorm":
        return {
            "scale": jnp.ones((d,), cfg.param_dtype),
            "bias": jnp.zeros((d,), cfg.param_dtype),
        }
    return {"scale": jnp.ones((d,), cfg.param_dtype)}  # rmsnorm


def apply_norm(params, x, cfg: ModelConfig, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm.startswith("layernorm"):
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + eps)
        if params:
            y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(
                jnp.float32
            )
        return y.astype(x.dtype)
    # rmsnorm
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps)
    if params:
        y = y * params["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm_init(d_head: int, dtype):
    """qk-norm (qwen3): RMSNorm over the head dimension."""
    return {"scale": jnp.ones((d_head,), dtype)}


def apply_head_norm(params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps) * params["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------


def rope_frequencies(d_head: int, theta: float) -> jnp.ndarray:
    exponents = jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head
    return 1.0 / (theta ** exponents)  # (d_head//2,)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (..., seq, n_heads, d_head); positions: (..., seq) int32."""
    d_head = x.shape[-1]
    freqs = rope_frequencies(d_head, theta)                  # (d/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., s, d/2)
    cos = jnp.cos(angles)[..., :, None, :]                   # (..., s, 1, d/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embed(positions: jnp.ndarray, d_model: int) -> jnp.ndarray:
    """Classic transformer sinusoidal embedding. positions: (..., S) int."""
    half = d_model // 2
    freqs = jnp.exp(
        -jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half
    )
    angles = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------


def mlp_init(key, cfg: ModelConfig, d_ff: int | None = None):
    d_ff = cfg.d_ff if d_ff is None else d_ff
    dt = cfg.param_dtype
    D = cfg.d_model
    if cfg.mlp_act == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "w_gate": dense_init(k1, D, d_ff, dt),
            "w_up": dense_init(k2, D, d_ff, dt),
            "w_down": dense_init(k3, d_ff, D, dt),
        }
    k1, k2 = jax.random.split(key)
    return {
        "w_in": dense_init(k1, D, d_ff, dt),
        "w_down": dense_init(k2, d_ff, D, dt),
    }


def mlp_apply(params, x, cfg: ModelConfig):
    dt = x.dtype
    if cfg.mlp_act == "swiglu":
        gate = x @ params["w_gate"].astype(dt)
        up = x @ params["w_up"].astype(dt)
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(x @ params["w_in"].astype(dt))
    return h @ params["w_down"].astype(dt)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def softmax_cross_entropy(logits, labels, weights=None, z_loss: float = 0.0):
    """logits: (..., V) fp-any; labels int32 (...); weights optional (...)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    label_logit = jnp.take_along_axis(
        logits, labels[..., None].astype(jnp.int32), axis=-1
    )[..., 0]
    loss = lse - label_logit
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    if weights is None:
        return jnp.mean(loss)
    total = jnp.maximum(jnp.sum(weights), 1.0)
    return jnp.sum(loss * weights) / total
