"""Mixture-of-Experts layer: top-k routing, capacity-based GShard dispatch.

Expert weights are stacked along a leading expert axis so expert parallelism
is a plain sharding decision (``repro.dist.sharding``). Dispatch/combine use
one-hot matmuls (MXU-friendly, shardable); tokens over capacity are dropped
(capacity factor 1.25 by default) which keeps the step shape-static.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init


def moe_init(key, cfg: ModelConfig):
    e = cfg.moe
    D, E, F = cfg.d_model, e.n_experts, e.d_ff_expert
    dt = cfg.param_dtype
    k1, k2, k3, k4 = jax.random.split(key, 4)

    def stack_init(k, d_in, d_out):
        keys = jax.random.split(k, E)
        return jnp.stack([dense_init(kk, d_in, d_out, dt) for kk in keys])

    return {
        "router": dense_init(k1, D, E, dt, scale=0.02),
        "w_gate": stack_init(k2, D, F),
        "w_up": stack_init(k3, D, F),
        "w_down": stack_init(k4, F, D),
    }


def _dispatch_combine(gates_idx, gates_val, n_tokens, n_experts, capacity):
    """Build (N, E, C) dispatch one-hot and combine weights.

    gates_idx: (N, k) int32 expert ids; gates_val: (N, k) fp32 weights.
    """
    k = gates_idx.shape[1]
    onehot = jax.nn.one_hot(gates_idx, n_experts, dtype=jnp.float32)  # (N,k,E)
    # priority: slot 0 of every token first, then slot 1, ... (GShard order)
    flat = jnp.transpose(onehot, (1, 0, 2)).reshape(k * n_tokens, n_experts)
    pos_in_expert = jnp.cumsum(flat, axis=0) - flat                   # (kN, E)
    pos = jnp.sum(pos_in_expert * flat, axis=-1)                      # (kN,)
    keep = (pos < capacity).astype(jnp.float32)
    pos_oh = jax.nn.one_hot(pos, capacity, dtype=jnp.float32)         # (kN, C)
    disp_flat = flat[:, :, None] * pos_oh[:, None, :] * keep[:, None, None]
    disp = disp_flat.reshape(k, n_tokens, n_experts, capacity).transpose(
        1, 0, 2, 3
    )                                                                 # (N,k,E,C)
    dispatch = jnp.sum(disp, axis=1)                                  # (N,E,C)
    combine = jnp.sum(disp * gates_val[:, :, None, None], axis=1)     # (N,E,C)
    return dispatch, combine


def _n_groups(n_tokens: int, group_tokens: int) -> int:
    """Largest power-of-two group count with groups >= ~group_tokens."""
    g = 1
    while (
        n_tokens % (g * 2) == 0 and n_tokens // (g * 2) >= group_tokens
    ):
        g *= 2
    return g


def _moe_group(params, xt, cfg: ModelConfig, capacity: int):
    """Dispatch+compute one token group. xt: (n, D) -> (y, aux)."""
    e = cfg.moe
    n, D = xt.shape
    dt = xt.dtype
    logits = xt.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                           # (n,E)
    gate_val, gate_idx = jax.lax.top_k(probs, e.top_k)
    gate_val = gate_val / jnp.maximum(
        jnp.sum(gate_val, axis=-1, keepdims=True), 1e-9
    )
    dispatch, combine = _dispatch_combine(
        gate_idx, gate_val, n, e.n_experts, capacity
    )
    expert_in = jnp.einsum("nec,nd->ecd", dispatch.astype(dt), xt)    # (E,C,D)
    h = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", expert_in, params["w_gate"].astype(dt))
    ) * jnp.einsum("ecd,edf->ecf", expert_in, params["w_up"].astype(dt))
    expert_out = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(dt))
    y = jnp.einsum("nec,ecd->nd", combine.astype(dt), expert_out)

    frac_tokens = jnp.mean(
        jax.nn.one_hot(gate_idx[:, 0], e.n_experts, dtype=jnp.float32), axis=0
    )
    aux = e.n_experts * jnp.sum(frac_tokens * jnp.mean(probs, axis=0))
    return y, aux * e.load_balance_weight


def moe_apply(params, x, cfg: ModelConfig, capacity_factor: float | None = None):
    """x: (B, S, D) -> (y, aux_loss).

    Tokens are processed in GShard-style groups (``moe.group_tokens``): the
    (g, E, C) dispatch/combine tensors are bounded per group and the group
    loop is a scan, so dispatch memory no longer scales with the full
    sequence — the fix that takes mixtral's prefill from TB-scale dispatch
    buffers to tens of MB (EXPERIMENTS.md §Perf).
    """
    e = cfg.moe
    if capacity_factor is None:
        capacity_factor = e.capacity_factor
    B, S, D = x.shape
    N = B * S
    xt = x.reshape(N, D)

    g = _n_groups(N, e.group_tokens)
    n = N // g
    capacity = int(max(e.top_k, capacity_factor * n * e.top_k / e.n_experts))
    capacity = min(capacity, n)

    if g == 1:
        y, aux = _moe_group(params, xt, cfg, capacity)
        return y.reshape(B, S, D), aux

    xg = xt.reshape(g, n, D)
    ys, auxs = jax.lax.map(
        lambda xi: _moe_group(params, xi, cfg, capacity), xg
    )
    return ys.reshape(B, S, D), jnp.mean(auxs)
