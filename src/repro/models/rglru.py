"""Griffin / RecurrentGemma recurrent block (RG-LRU) — arXiv:2402.19427.

Temporal-mixing block: two branches from the (pre-normed) input,
  branch1 = GeLU(x @ W_b1)                      (gate branch)
  branch2 = RG-LRU(causal_conv1d(x @ W_b2))     (recurrent branch)
  out     = (branch1 * branch2) @ W_out

RG-LRU recurrence (element-wise, width R):
  r_t = sigmoid(u_t @ W_a + b_a)            recurrence gate
  i_t = sigmoid(u_t @ W_i + b_i)            input gate
  log_a_t = -c * softplus(Lambda) * r_t
  h_t = exp(log_a_t) * h_{t-1} + sqrt(1 - exp(2*log_a_t)) * (i_t * u_t)

Training uses ``jax.lax.associative_scan`` (parallel prefix — the TPU-native
formulation); the Pallas kernel (repro.kernels.rglru) implements the blocked
sequential scan for long sequences.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init


def causal_conv1d(x, w, conv_state=None):
    """Depthwise causal conv. x: (B,S,R), w: (d_conv,R).

    conv_state: (B, d_conv-1, R) previous tokens (decode) or None (train).
    Returns (y, new_state) where new_state holds the trailing d_conv-1 tokens.
    """
    d_conv = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], d_conv - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)          # (B, S+d_conv-1, R)
    S = x.shape[1]
    y = jnp.zeros_like(x)
    for i in range(d_conv):                          # d_conv is tiny (4)
        y = y + xp[:, i : i + S] * w[i].astype(x.dtype)
    new_state = xp[:, -(d_conv - 1) :]
    return y, new_state


def rglru_scan(u, r, i, lam, c_const, h0=None):
    """Associative-scan RG-LRU. u,r,i: (B,S,R) ; lam: (R,) ; h0: (B,R)|None."""
    log_a = -c_const * jax.nn.softplus(lam.astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * u)
    if h0 is not None:
        gated = gated.at[:, 0].add(a[:, 0] * h0)

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, a_r * b_l + b_r

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h  # (B,S,R) fp32


def rglru_block_init(key, cfg: ModelConfig):
    D = cfg.d_model
    R = cfg.recurrent.rnn_width
    dc = cfg.recurrent.d_conv
    dt = cfg.param_dtype
    ks = jax.random.split(key, 6)
    return {
        "w_branch1": dense_init(ks[0], D, R, dt),
        "w_branch2": dense_init(ks[1], D, R, dt),
        "conv_w": (jax.random.normal(ks[2], (dc, R)) * (dc ** -0.5)).astype(dt),
        "w_a": dense_init(ks[3], R, R, dt),
        "b_a": jnp.zeros((R,), dt),
        "w_i": dense_init(ks[4], R, R, dt),
        "b_i": jnp.zeros((R,), dt),
        "lam": jnp.full((R,), 2.0, dt),  # softplus(2) ~ 2.1 -> moderate decay
        "w_out": dense_init(ks[5], R, D, dt),
    }


def _branches(params, x, cfg, conv_state=None):
    dt = x.dtype
    b1 = jax.nn.gelu(x @ params["w_branch1"].astype(dt))
    u = x @ params["w_branch2"].astype(dt)
    u, new_conv = causal_conv1d(u, params["conv_w"], conv_state)
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ params["w_a"].astype(jnp.float32) + params["b_a"])
    i = jax.nn.sigmoid(uf @ params["w_i"].astype(jnp.float32) + params["b_i"])
    return b1, uf, r, i, new_conv


def rglru_full(params, x, cfg: ModelConfig, spec=None, positions=None):
    b1, u, r, i, _ = _branches(params, x, cfg)
    h = rglru_scan(u, r, i, params["lam"], cfg.recurrent.c_const)
    y = (b1 * h.astype(x.dtype)) @ params["w_out"].astype(x.dtype)
    return y


def init_rglru_cache(cfg: ModelConfig, batch: int):
    R = cfg.recurrent.rnn_width
    return {
        "h": jnp.zeros((batch, R), jnp.float32),
        "conv": jnp.zeros((batch, cfg.recurrent.d_conv - 1, R), cfg.dtype),
    }


def rglru_prefill(params, x, cfg, spec, positions, cache):
    b1, u, r, i, new_conv = _branches(params, x, cfg, cache["conv"])
    h = rglru_scan(u, r, i, params["lam"], cfg.recurrent.c_const, cache["h"])
    y = (b1 * h.astype(x.dtype)) @ params["w_out"].astype(x.dtype)
    return y, {"h": h[:, -1], "conv": new_conv}


def rglru_decode(params, x, cfg, spec, pos, cache):
    """x: (B,1,D)."""
    b1, u, r, i, new_conv = _branches(params, x, cfg, cache["conv"])
    log_a = (
        -cfg.recurrent.c_const
        * jax.nn.softplus(params["lam"].astype(jnp.float32))
        * r[:, 0]
    )
    a = jnp.exp(log_a)
    h = a * cache["h"] + jnp.sqrt(
        jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)
    ) * (i[:, 0] * u[:, 0])
    y = (b1 * h[:, None].astype(x.dtype)) @ params["w_out"].astype(x.dtype)
    return y, {"h": h, "conv": new_conv}
