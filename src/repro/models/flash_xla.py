"""Flash attention in pure XLA: q-tiled outer scan + kv-block inner scan.

The reference attention materialises fp32 (S x T) score tensors; a naive
kv-block scan still streams the full-length online-softmax carry (m, l, acc
over all S) through HBM every step — S^2-scale traffic either way (measured
in EXPERIMENTS.md §Perf iteration 2). This version tiles queries first:

  outer scan over q tiles (bq rows)         -> emits out/lse per tile
    inner scan over kv blocks (bk columns)  -> carry is only (bq x Dh)

so every loop-resident tensor is tile-sized; k/v live in one loop-invariant
buffer read blockwise. The backward recomputes per (q-tile, kv-block) pair:
dq is emitted per q tile, dk/dv accumulate into an aliased (T x KDh) carry
via in-place dynamic-update-slice.

Causal/window masks apply per tile pair; fully-masked pairs still execute
(static trip counts), costing ~2x ideal FLOPs on the causal triangle — the
roofline report calls this out. Pure jnp: works under jit / GSPMD / the
scan-over-layers stack, and is the beyond-paper §Perf optimisation. The
Pallas kernel (repro.kernels.attention) is its TPU-native twin.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512


def _pad_to(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


def _mask(q_pos, k_pos, causal, window, true_t):
    m = k_pos[None, :] < true_t
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= (q_pos[:, None] - k_pos[None, :]) < window
    return m                                            # (bq, bk)


def _fwd_impl(q, k, v, causal, window, bq, bk):
    """q: (B,S,H,Dh); k,v: (B,T,K,Dh) -> out (B,S,H,Dh), lse (B,K,G,S)."""
    B, S, H, Dh = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    bq = min(bq, S)
    bk = min(bk, T)
    scale = Dh ** -0.5

    qf = _pad_to(
        q.astype(jnp.float32).reshape(B, S, K, G, Dh), 1, bq
    )                                                   # (B,Sp,K,G,Dh)
    kf = _pad_to(k.astype(jnp.float32), 1, bk)
    vf = _pad_to(v.astype(jnp.float32), 1, bk)
    Sp, Tp = qf.shape[1], kf.shape[1]
    nq, nb = Sp // bq, Tp // bk

    q_tiles = jnp.moveaxis(
        qf.reshape(B, nq, bq, K, G, Dh), 1, 0
    )                                                   # (nq,B,bq,K,G,Dh)
    kb = jnp.moveaxis(kf.reshape(B, nb, bk, K, Dh), 1, 0)
    vb = jnp.moveaxis(vf.reshape(B, nb, bk, K, Dh), 1, 0)

    def q_step(_, tile_inp):
        q_tile, qi = tile_inp                           # (B,bq,K,G,Dh)
        q_pos = qi * bq + jnp.arange(bq)

        def kv_step(carry, kv_inp):
            m, l, acc = carry                           # (B,K,G,bq[,Dh])
            k_blk, v_blk, bi = kv_inp
            k_pos = bi * bk + jnp.arange(bk)
            s = jnp.einsum(
                "bqkgd,btkd->bkgqt", q_tile, k_blk,
                preferred_element_type=jnp.float32,
            ) * scale                                   # (B,K,G,bq,bk)
            s = jnp.where(
                _mask(q_pos, k_pos, causal, window, T)[None, None, None],
                s, NEG_INF,
            )
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p, v_blk,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, bq), jnp.float32)
        acc0 = jnp.zeros((B, K, G, bq, Dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, acc0), (kb, vb, jnp.arange(nb))
        )
        safe_l = jnp.where(l == 0.0, 1.0, l)
        out_tile = acc / safe_l[..., None]              # (B,K,G,bq,Dh)
        lse_tile = m + jnp.log(safe_l)                  # (B,K,G,bq)
        return None, (out_tile, lse_tile)

    _, (out_tiles, lse_tiles) = jax.lax.scan(
        q_step, None, (q_tiles, jnp.arange(nq))
    )
    # (nq,B,K,G,bq,Dh) -> (B, Sp, H, Dh)
    out = jnp.moveaxis(out_tiles, 0, 3)                 # (B,K,G,nq,bq,Dh)
    out = out.reshape(B, K, G, Sp, Dh)
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sp, H, Dh)[:, :S]
    lse = jnp.moveaxis(lse_tiles, 0, 3).reshape(B, K, G, Sp)[..., :S]
    return out.astype(q.dtype), lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention_xla(
    q, k, v, causal: bool = True, window: Optional[int] = None,
    block_q: int = DEFAULT_BLOCK_Q, block_k: int = DEFAULT_BLOCK_K,
):
    """q: (B,S,H,Dh); k,v: (B,T,K,Dh) -> (B,S,H,Dh). GQA via H = K*G."""
    out, _ = _fwd_impl(q, k, v, causal, window, block_q, block_k)
    return out


def _vjp_fwd(q, k, v, causal, window, block_q, block_k):
    out, lse = _fwd_impl(q, k, v, causal, window, block_q, block_k)
    return out, (q, k, v, out, lse)


def _vjp_bwd(causal, window, block_q, block_k, res, g):
    q, k, v, out, lse = res
    B, S, H, Dh = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    bq = min(block_q, S)
    bk = min(block_k, T)
    scale = Dh ** -0.5

    qf = _pad_to(q.astype(jnp.float32).reshape(B, S, K, G, Dh), 1, bq)
    g5 = _pad_to(g.astype(jnp.float32).reshape(B, S, K, G, Dh), 1, bq)
    o5 = _pad_to(out.astype(jnp.float32).reshape(B, S, K, G, Dh), 1, bq)
    lse_p = _pad_to(lse, 3, bq)                         # (B,K,G,Sp)
    kf = _pad_to(k.astype(jnp.float32), 1, bk)
    vf = _pad_to(v.astype(jnp.float32), 1, bk)
    Sp, Tp = qf.shape[1], kf.shape[1]
    nq, nb = Sp // bq, Tp // bk

    q_tiles = jnp.moveaxis(qf.reshape(B, nq, bq, K, G, Dh), 1, 0)
    g_tiles = jnp.moveaxis(g5.reshape(B, nq, bq, K, G, Dh), 1, 0)
    o_tiles = jnp.moveaxis(o5.reshape(B, nq, bq, K, G, Dh), 1, 0)
    lse_tiles = jnp.moveaxis(
        lse_p.reshape(B, K, G, nq, bq), 3, 0
    )                                                   # (nq,B,K,G,bq)
    kb = jnp.moveaxis(kf.reshape(B, nb, bk, K, Dh), 1, 0)
    vb = jnp.moveaxis(vf.reshape(B, nb, bk, K, Dh), 1, 0)

    def q_step(carry, tile_inp):
        dk_acc, dv_acc = carry                          # (B,Tp,K,Dh) f32
        q_tile, g_tile, o_tile, lse_tile, qi = tile_inp
        q_pos = qi * bq + jnp.arange(bq)
        gt = jnp.moveaxis(g_tile, 1, 3)                 # (B,K,G,bq,Dh)
        ot = jnp.moveaxis(o_tile, 1, 3)
        delta = jnp.sum(gt * ot, axis=-1)               # (B,K,G,bq)

        def kv_step(dq_tile, kv_inp):
            k_blk, v_blk, bi = kv_inp
            k_pos = bi * bk + jnp.arange(bk)
            s = jnp.einsum(
                "bqkgd,btkd->bkgqt", q_tile, k_blk,
                preferred_element_type=jnp.float32,
            ) * scale
            s = jnp.where(
                _mask(q_pos, k_pos, causal, window, T)[None, None, None],
                s, NEG_INF,
            )
            p = jnp.exp(s - lse_tile[..., None])        # (B,K,G,bq,bk)
            dv_blk = jnp.einsum(
                "bkgqt,bkgqd->btkd", p, gt,
                preferred_element_type=jnp.float32,
            )
            dp = jnp.einsum(
                "bkgqd,btkd->bkgqt", gt, v_blk,
                preferred_element_type=jnp.float32,
            )
            ds = p * (dp - delta[..., None]) * scale
            dq_tile = dq_tile + jnp.einsum(
                "bkgqt,btkd->bqkgd", ds, k_blk,
                preferred_element_type=jnp.float32,
            )
            dk_blk = jnp.einsum(
                "bkgqt,bqkgd->btkd", ds, q_tile,
                preferred_element_type=jnp.float32,
            )
            return dq_tile, (dk_blk, dv_blk)

        dq0 = jnp.zeros((B, bq, K, G, Dh), jnp.float32)
        dq_tile, (dk_blks, dv_blks) = jax.lax.scan(
            kv_step, dq0, (kb, vb, jnp.arange(nb))
        )
        # fold per-block dk/dv into the aliased full-T accumulators
        dk_new = jnp.moveaxis(dk_blks, 0, 1).reshape(B, Tp, K, Dh)
        dv_new = jnp.moveaxis(dv_blks, 0, 1).reshape(B, Tp, K, Dh)
        return (dk_acc + dk_new, dv_acc + dv_new), dq_tile

    dk0 = jnp.zeros((B, Tp, K, Dh), jnp.float32)
    dv0 = jnp.zeros((B, Tp, K, Dh), jnp.float32)
    (dk_p, dv_p), dq_tiles = jax.lax.scan(
        q_step, (dk0, dv0),
        (q_tiles, g_tiles, o_tiles, lse_tiles, jnp.arange(nq)),
    )
    dq = jnp.moveaxis(dq_tiles, 0, 1).reshape(B, Sp, H, Dh)[:, :S]
    return (
        dq.astype(q.dtype),
        dk_p[:, :T].astype(k.dtype),
        dv_p[:, :T].astype(v.dtype),
    )


flash_attention_xla.defvjp(_vjp_fwd, _vjp_bwd)
