"""Model zoo: config-driven decoder LMs + the paper's FEMNIST CNN."""
from repro.models import attention, cnn, layers, lm, moe, rglru, ssd  # noqa: F401
