"""Grouped-query attention with RoPE, sliding windows, qk-norm and KV caches.

Reference (pure-XLA) implementation used for training, dry-run lowering and as
the oracle for the Pallas flash-attention kernel (``repro.kernels.attention``).
Cache layout: post-RoPE keys, ring buffer for windowed layers.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models.layers import (
    apply_head_norm,
    apply_rope,
    dense_init,
    rms_head_norm_init,
)

NEG_INF = -1e30


def attn_init(key, cfg: ModelConfig):
    D, H, K, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    dt = cfg.param_dtype
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, D, H * Dh, dt),
        "wk": dense_init(k2, D, K * Dh, dt),
        "wv": dense_init(k3, D, K * Dh, dt),
        "wo": dense_init(k4, H * Dh, D, dt, scale=(H * Dh) ** -0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = rms_head_norm_init(Dh, dt)
        p["k_norm"] = rms_head_norm_init(Dh, dt)
    return p


def init_layer_cache(cfg: ModelConfig, spec: LayerSpec, batch: int, max_len: int):
    """Cache pytree for one attention layer. Ring buffer if windowed.

    Layout note: keys/values are stored with the kv-head and head dims FUSED
    (B, cap, K*Dh) so the cache carries exactly the same sharding as the
    K/V projection output (the fused column-parallel dim). With a separate
    (K, Dh) layout GSPMD cannot map a 16-way "model" axis onto K=8 heads and
    falls back to all-gathering the whole cache every decode step — the
    dominant collective in the baseline decode roofline (EXPERIMENTS §Perf).
    """
    cap = max_len if spec.window is None else min(spec.window, max_len)
    K, Dh = cfg.n_kv_heads, cfg.d_head
    if cfg.kv_cache_dtype == "int8":
        # per-(batch, slot) scales; int8 payload halves/quarters the HBM
        # footprint AND the per-step read traffic (dequant fuses into the
        # attention matmul read) — the fix for arctic-480b decode_32k.
        return {
            "k": jnp.zeros((batch, cap, K * Dh), jnp.int8),
            "v": jnp.zeros((batch, cap, K * Dh), jnp.int8),
            "k_scale": jnp.ones((batch, cap), jnp.float32),
            "v_scale": jnp.ones((batch, cap), jnp.float32),
        }
    dt = jnp.dtype(cfg.dtype)
    return {
        "k": jnp.zeros((batch, cap, K * Dh), dt),
        "v": jnp.zeros((batch, cap, K * Dh), dt),
    }


def _quant_rows(x):
    """x: (B, S, KD) -> (int8, scale (B,S)) symmetric per row."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127
    )
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def _dequant_rows(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def _mask_full(seq_q: int, seq_k: int, window: Optional[int], offset: int = 0):
    """Causal (+window) mask for full-sequence attention.

    offset: absolute position of query 0 minus absolute position of key 0.
    """
    qi = jnp.arange(seq_q)[:, None] + offset
    kj = jnp.arange(seq_k)[None, :]
    mask = kj <= qi
    if window is not None:
        mask &= (qi - kj) < window
    return mask  # (seq_q, seq_k) bool


def _sdpa(q, k, v, mask):
    """q: (B,S,H,Dh) k,v: (B,T,K,Dh) mask: broadcastable to (B,K,G,S,T)."""
    B, S, H, Dh = q.shape
    Kh = k.shape[2]
    G = H // Kh
    scale = Dh ** -0.5
    qg = q.reshape(B, S, Kh, G, Dh)
    scores = jnp.einsum(
        "bskgd,btkd->bkgst", qg, k, preferred_element_type=jnp.float32
    )
    scores = scores * scale
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(B, S, H * Dh)


def _project_qkv(params, x, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    H, K, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    dt = x.dtype
    q = (x @ params["wq"].astype(dt)).reshape(B, S, H, Dh)
    k = (x @ params["wk"].astype(dt)).reshape(B, S, K, Dh)
    v = (x @ params["wv"].astype(dt)).reshape(B, S, K, Dh)
    if cfg.qk_norm:
        q = apply_head_norm(params["q_norm"], q)
        k = apply_head_norm(params["k_norm"], k)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _attend_full(q, k, v, cfg: ModelConfig, spec: LayerSpec, seq: int):
    """Dispatch full-sequence attention by cfg.attn_impl."""
    if cfg.attn_impl == "pallas":
        from repro.kernels.attention import ops as flash_ops

        out = flash_ops.flash_attention(q, k, v, causal=True,
                                        window=spec.window)
        return out.reshape(out.shape[0], seq, cfg.n_heads * cfg.d_head)
    if cfg.attn_impl == "chunked":
        from repro.models.flash_xla import flash_attention_xla

        out = flash_attention_xla(q, k, v, True, spec.window)
        return out.reshape(out.shape[0], seq, cfg.n_heads * cfg.d_head)
    mask = _mask_full(seq, seq, spec.window)
    return _sdpa(q, k, v, mask)


def attn_full(params, x, cfg: ModelConfig, spec: LayerSpec, positions):
    """Full-sequence attention (training / prefill compute)."""
    q, k, v = _project_qkv(params, x, cfg, positions)
    out = _attend_full(q, k, v, cfg, spec, x.shape[1])
    return out @ params["wo"].astype(x.dtype)


def attn_prefill(params, x, cfg, spec, positions, cache):
    """Full attention + fill the layer cache (ring layout for windows)."""
    q, k, v = _project_qkv(params, x, cfg, positions)
    out = _attend_full(q, k, v, cfg, spec, x.shape[1])
    cap = cache["k"].shape[1]
    B, S = x.shape[:2]
    KD = cache["k"].shape[2]
    kf = k.reshape(B, S, KD)
    vf = v.reshape(B, S, KD)
    quant = "k_scale" in cache
    ks = vs = None
    if quant:
        kf, ks = _quant_rows(kf)
        vf, vs = _quant_rows(vf)
    if S >= cap:
        # keep the last `cap` tokens, rolled so slot = position % cap
        shift = S % cap
        new_k = jnp.roll(kf[:, S - cap :], shift=shift, axis=1)
        new_v = jnp.roll(vf[:, S - cap :], shift=shift, axis=1)
        if quant:
            ks = jnp.roll(ks[:, S - cap :], shift=shift, axis=1)
            vs = jnp.roll(vs[:, S - cap :], shift=shift, axis=1)
    else:
        new_k = jax.lax.dynamic_update_slice(cache["k"], kf, (0, 0, 0))
        new_v = jax.lax.dynamic_update_slice(cache["v"], vf, (0, 0, 0))
        if quant:
            ks = jax.lax.dynamic_update_slice(cache["k_scale"], ks, (0, 0))
            vs = jax.lax.dynamic_update_slice(cache["v_scale"], vs, (0, 0))
    new_cache = {"k": new_k, "v": new_v}
    if quant:
        new_cache["k_scale"] = ks
        new_cache["v_scale"] = vs
    return out @ params["wo"].astype(x.dtype), new_cache


def attn_decode(params, x, cfg: ModelConfig, spec: LayerSpec, pos, cache):
    """One-token decode against the cache.

    x: (B, 1, D); pos: scalar int32 — absolute position of the new token
    (== number of tokens already in the cache).
    """
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    q, k, v = _project_qkv(params, x, cfg, positions)
    cap = cache["k"].shape[1]
    KD = cache["k"].shape[2]
    slot = pos % cap if spec.window is not None else pos
    quant = "k_scale" in cache
    kf, vf = k.reshape(B, 1, KD), v.reshape(B, 1, KD)
    new_scales = {}
    if quant:
        kf, ks_row = _quant_rows(kf)
        vf, vs_row = _quant_rows(vf)
        new_scales["k_scale"] = jax.lax.dynamic_update_slice(
            cache["k_scale"], ks_row, (0, slot)
        )
        new_scales["v_scale"] = jax.lax.dynamic_update_slice(
            cache["v_scale"], vs_row, (0, slot)
        )
    k_cache = jax.lax.dynamic_update_slice(cache["k"], kf, (0, slot, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], vf, (0, slot, 0))

    j = jnp.arange(cap)
    if spec.window is None:
        valid = j <= pos
    else:
        # ring: slots hold tokens (pos-cap, pos]; all valid once pos+1 >= cap
        valid = j <= pos  # only limiting before wrap-around
        valid = jnp.where(pos + 1 >= cap, jnp.ones_like(valid), valid)
    mask = valid[None, None, None, None, :]  # (1,1,1,1,T) -> bcast (B,K,G,1,T)
    K, Dh = cfg.n_kv_heads, cfg.d_head
    if quant:
        k_read = _dequant_rows(k_cache, new_scales["k_scale"], x.dtype)
        v_read = _dequant_rows(v_cache, new_scales["v_scale"], x.dtype)
    else:
        k_read, v_read = k_cache, v_cache
    out = _sdpa(
        q,
        k_read.reshape(B, cap, K, Dh),
        v_read.reshape(B, cap, K, Dh),
        mask,
    )
    new_cache = {"k": k_cache, "v": v_cache, **new_scales}
    return out @ params["wo"].astype(x.dtype), new_cache
