"""Command-line driver: ``python -m repro.analysis``.

Exit codes follow ``benchmarks/compare.py``: 0 = clean (modulo
baseline), 1 = non-baselined findings (or failed self-test), 2 = wiring
error (nothing scanned, unreadable baseline) — a misconfigured pass
must never read as a passing one.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

from repro.analysis import ANALYSIS_VERSION
from repro.analysis.baseline import apply_baseline, load_baseline
from repro.analysis.core import all_checkers, load_modules, run_checkers
from repro.analysis.registry import registry_payload

DEFAULT_BASELINE = "analysis-baseline.json"


def _default_paths() -> List[str]:
    for candidate in ("src/repro", "repro"):
        if os.path.isdir(candidate):
            return [candidate]
    return []


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description=(
            "invariant-aware static analysis (RPA0xx rules, see "
            "DESIGN.md §13)"
        ),
    )
    ap.add_argument(
        "paths", nargs="*",
        help="files/directories to scan (default: src/repro)",
    )
    ap.add_argument(
        "--format", choices=("text", "json"), default="text",
        help="report format on stdout",
    )
    ap.add_argument(
        "--baseline", metavar="FILE", default=None,
        help=(
            f"baseline suppression file (default: {DEFAULT_BASELINE} "
            f"when present)"
        ),
    )
    ap.add_argument(
        "--output", metavar="PATH", default=None,
        help="additionally write the JSON report to PATH (CI artifact)",
    )
    ap.add_argument(
        "--select", metavar="CODES", default=None,
        help="comma-separated RPA codes to run (default: all)",
    )
    ap.add_argument(
        "--dump-registry", action="store_true",
        help="print the generated stream-key constant registry and exit",
    )
    ap.add_argument(
        "--self-test", action="store_true",
        help=(
            "verify every rule fires on its synthetic violating fixture "
            "and passes its fixed twin (mirrors compare.py --self-test)"
        ),
    )
    args = ap.parse_args(argv)

    if args.self_test:
        from repro.analysis.selftest import run_self_test

        return run_self_test()

    paths = args.paths or _default_paths()
    if not paths:
        print(
            "error: no paths given and no src/repro directory here",
            file=sys.stderr,
        )
        return 2
    try:
        modules = load_modules(paths)
    except (FileNotFoundError, SyntaxError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    if not modules:
        print(f"error: no python files under {paths}", file=sys.stderr)
        return 2

    if args.dump_registry:
        print(json.dumps(registry_payload(modules), indent=2))
        return 0

    select = args.select.split(",") if args.select else None
    try:
        checkers = all_checkers(select=select)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    baseline_path = args.baseline
    if baseline_path is None and os.path.isfile(DEFAULT_BASELINE):
        baseline_path = DEFAULT_BASELINE
    entries = []
    if baseline_path is not None:
        try:
            entries = load_baseline(baseline_path)
        except (OSError, ValueError, json.JSONDecodeError) as e:
            print(f"error: baseline {baseline_path}: {e}", file=sys.stderr)
            return 2

    findings = run_checkers(modules, checkers)
    new, suppressed, stale = apply_baseline(findings, entries)

    payload = {
        "analysis_version": ANALYSIS_VERSION,
        "paths": list(paths),
        "rules": [
            {"code": c.code, "name": c.name, "description": c.description}
            for c in checkers
        ],
        "summary": {
            "files": len(modules),
            "findings": len(new),
            "baselined": len(suppressed),
            "stale_baseline_entries": len(stale),
        },
        "findings": [
            {
                "code": f.code, "path": f.path, "line": f.line,
                "col": f.col, "symbol": f.symbol, "message": f.message,
            }
            for f in new
        ],
        "baselined": [
            {
                "code": f.code, "path": f.path, "line": f.line,
                "symbol": f.symbol,
            }
            for f in suppressed
        ],
        "stale_baseline_entries": [
            {"code": e.code, "path": e.path, "symbol": e.symbol}
            for e in stale
        ],
    }

    if args.output:
        with open(args.output, "w", encoding="utf-8") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")

    if args.format == "json":
        print(json.dumps(payload, indent=2))
    else:
        for f in new:
            print(f"{f.location()}: {f.code} [{f.symbol}] {f.message}")
        for e in stale:
            print(
                f"warning: stale baseline entry {e.code} {e.path} "
                f"[{e.symbol}] matches nothing — remove it",
                file=sys.stderr,
            )
        print(
            f"{len(modules)} files: {len(new)} finding(s), "
            f"{len(suppressed)} baselined, {len(stale)} stale baseline "
            f"entr{'y' if len(stale) == 1 else 'ies'}",
            file=sys.stderr,
        )
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
