"""Checker framework: findings, module loading, registry, AST helpers.

A *checker* owns one ``RPA0xx`` code and is either per-module
(``check_module`` runs once per scanned file) or project-level
(``check_project`` runs once over the whole scan set — used by the
stream-key registry and the kernel-triple layout rules, which reason
about several files at once).

Everything here is stdlib-only by design: the CI analysis job must run
without jax/numpy installed.
"""

from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple


@dataclass(frozen=True, order=True)
class Finding:
    """One rule violation, addressable for baseline suppression.

    ``symbol`` is the enclosing function/class qualname (``"<module>"``
    at top level) — baselines match on ``(code, path-suffix, symbol)``
    so entries survive unrelated line drift.
    """

    path: str
    line: int
    col: int
    code: str
    symbol: str
    message: str

    def location(self) -> str:
        return f"{self.path}:{self.line}:{self.col}"


@dataclass
class ModuleInfo:
    """A parsed source file plus the path metadata checkers scope on."""

    path: str                      # path as scanned (posix separators)
    tree: ast.Module
    source: str
    lines: List[str] = field(default_factory=list)

    def __post_init__(self) -> None:
        if not self.lines:
            self.lines = self.source.splitlines()

    @property
    def pkg_parts(self) -> Tuple[str, ...]:
        """Path parts from the last ``repro`` component on (falls back
        to the full path) — the unit scope predicates match against, so
        fixture trees shaped ``tmp/repro/net/x.py`` scope like the real
        package."""
        parts = tuple(self.path.split("/"))
        for i in range(len(parts) - 1, -1, -1):
            if parts[i] == "repro":
                return parts[i:]
        return parts

    def in_package(self, *prefixes: str) -> bool:
        """True when the module lives under any ``repro/<prefix>`` tree."""
        parts = self.pkg_parts
        if not parts or parts[0] != "repro":
            return False
        return any(
            parts[1:1 + len(p.split("/"))] == tuple(p.split("/"))
            for p in prefixes
        )

    def noqa_codes(self, line: int) -> Tuple[str, ...]:
        """RPA codes named in a ``# noqa:`` comment on ``line`` (1-based)."""
        if not 1 <= line <= len(self.lines):
            return ()
        text = self.lines[line - 1]
        marker = text.find("# noqa")
        if marker < 0:
            return ()
        return tuple(
            tok for tok in text[marker:].replace(",", " ").split()
            if tok.startswith("RPA")
        )


class Checker:
    """Base class; subclasses register themselves via ``__init_subclass__``."""

    code: str = ""
    name: str = ""
    description: str = ""

    _registry: Dict[str, "type[Checker]"] = {}

    def __init_subclass__(cls, **kwargs) -> None:
        super().__init_subclass__(**kwargs)
        if cls.code:
            Checker._registry[cls.code] = cls

    def check_module(self, mod: ModuleInfo) -> Iterator[Finding]:
        return iter(())

    def check_project(
        self, modules: Sequence[ModuleInfo]
    ) -> Iterator[Finding]:
        return iter(())

    def finding(
        self, mod_or_path, node: Optional[ast.AST], message: str,
        symbol: str = "<module>",
    ) -> Finding:
        path = (
            mod_or_path.path
            if isinstance(mod_or_path, ModuleInfo) else str(mod_or_path)
        )
        line = getattr(node, "lineno", 0) if node is not None else 0
        col = getattr(node, "col_offset", 0) if node is not None else 0
        return Finding(
            path=path, line=line, col=col, code=self.code,
            symbol=symbol, message=message,
        )


def all_checkers(select: Optional[Iterable[str]] = None) -> List[Checker]:
    """Instantiate every registered checker (importing the rule modules
    registers them), optionally filtered to the ``select`` codes."""
    from repro.analysis import checkers as _  # noqa: F401  (registration)

    codes = sorted(Checker._registry)
    if select is not None:
        want = set(select)
        unknown = want - set(codes)
        if unknown:
            raise ValueError(f"unknown rule codes: {sorted(unknown)}")
        codes = [c for c in codes if c in want]
    return [Checker._registry[c]() for c in codes]


def load_modules(paths: Sequence[str]) -> List[ModuleInfo]:
    """Parse every ``.py`` file under ``paths`` (files or directories).

    Walk order is sorted so findings, reports and registry dumps are
    byte-stable across runs and machines.
    """
    files: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
        elif os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(
                    d for d in dirnames
                    if d not in ("__pycache__", ".git", ".ruff_cache")
                )
                files.extend(
                    os.path.join(dirpath, f)
                    for f in sorted(filenames) if f.endswith(".py")
                )
        else:
            raise FileNotFoundError(p)
    modules = []
    for f in sorted(dict.fromkeys(files)):
        with open(f, encoding="utf-8") as fh:
            source = fh.read()
        tree = ast.parse(source, filename=f)
        modules.append(ModuleInfo(path=f.replace(os.sep, "/"), tree=tree,
                                  source=source))
    return modules


def run_checkers(
    modules: Sequence[ModuleInfo],
    checkers: Optional[Sequence[Checker]] = None,
) -> List[Finding]:
    """Run every checker over the scan set; honors inline ``# noqa: RPAxxx``."""
    if checkers is None:
        checkers = all_checkers()
    findings: List[Finding] = []
    by_path = {m.path: m for m in modules}
    for checker in checkers:
        raw: List[Finding] = []
        for mod in modules:
            raw.extend(checker.check_module(mod))
        raw.extend(checker.check_project(modules))
        for f in raw:
            mod = by_path.get(f.path)
            if mod is not None and f.code in mod.noqa_codes(f.line):
                continue
            findings.append(f)
    return sorted(dict.fromkeys(findings))


# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------


def walk_functions(
    tree: ast.Module,
) -> Iterator[Tuple[str, ast.AST]]:
    """Yield ``(qualname, def-node)`` for every function/method, including
    nested ones (qualnames use ``.`` separators, methods include the
    class name)."""

    def _walk(node: ast.AST, prefix: str) -> Iterator[Tuple[str, ast.AST]]:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}{child.name}"
                yield qual, child
                yield from _walk(child, f"{qual}.")
            elif isinstance(child, ast.ClassDef):
                yield from _walk(child, f"{prefix}{child.name}.")
            else:
                yield from _walk(child, prefix)

    yield from _walk(tree, "")


def enclosing_symbols(tree: ast.Module) -> Dict[ast.AST, str]:
    """Map every AST node to its enclosing function qualname (or
    ``"<module>"``) — the symbol findings and baselines key on."""
    out: Dict[ast.AST, str] = {}

    def _mark(node: ast.AST, symbol: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if isinstance(child, ast.ClassDef):
                _mark(child, symbol)
                continue
            out[child] = symbol
            _mark(child, symbol)

    _mark(tree, "<module>")
    for qual, fn in walk_functions(tree):
        out[fn] = out.get(fn, "<module>")
        for child in ast.iter_child_nodes(fn):
            if not isinstance(
                child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
            ):
                out[child] = qual
                _mark(child, qual)
    return out


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def import_aliases(tree: ast.Module) -> Dict[str, str]:
    """Local name → imported dotted path, for plain and from-imports."""
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0]
                )
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


def resolve_call_target(
    node: ast.Call, aliases: Dict[str, str]
) -> Optional[str]:
    """Fully-qualified dotted target of a call, through import aliases
    (``rnd.random()`` with ``import random as rnd`` → ``random.random``)."""
    dn = dotted_name(node.func)
    if dn is None:
        return None
    head, _, rest = dn.partition(".")
    head = aliases.get(head, head)
    return f"{head}.{rest}" if rest else head
