"""Stream-key derivation-constant registry (RPA006 backing store).

Every stream class in the codebase derives its threefry keys by
Weyl-shifting with module-level constants:

* ``kernels/traffic/ref.py``   — ``KEY_WEYL_*`` (per-draw derived keys);
* ``kernels/traffic/ops.py``   — ``_PON_WEYL_*`` / ``_JOB_WEYL_*``
  (``make_stream_key``'s pon/job axes);
* ``faults/streams.py``        — ``_CLASS_WEYL_*`` / ``_CASE_WEYL``
  (fault-class streams).

The no-aliasing contract (DESIGN §6/§7/§10) requires all of them to be
pairwise distinct — a new stream class reusing a constant would let two
logically independent streams collide for some ``(seed, index)``
combination.  This module extracts the constants from source by AST
(no imports — the registry works without numpy/jax and cannot observe a
stale installed copy) so the analysis pass, ``--dump-registry`` and the
tests all see the same generated view.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import List, Sequence

from repro.analysis.core import ModuleInfo

#: module-path suffixes that may define stream-key constants
ANCHOR_SUFFIXES = (
    "repro/kernels/traffic/ref.py",
    "repro/kernels/traffic/ops.py",
    "repro/faults/streams.py",
)

#: a shrinking anchor set is a wiring error, not a pass (compare.py's
#: zero-match philosophy): today the three anchors define 9 constants
MIN_CONSTANTS = 8

_MASK32 = 0xFFFFFFFF


@dataclass(frozen=True, order=True)
class StreamConstant:
    path: str
    name: str
    value: int
    line: int

    @property
    def is_weyl(self) -> bool:
        """Weyl increments must be odd (an even shift is non-injective
        mod 2^32); non-Weyl derivation constants (``_C240``) are exempt."""
        return "WEYL" in self.name


def _is_constant_name(name: str) -> bool:
    return "WEYL" in name or name in ("_C240", "_CASE_WEYL")


def extract_constants(modules: Sequence[ModuleInfo]) -> List[StreamConstant]:
    """All stream-key constants defined by anchor modules in the scan set."""
    out: List[StreamConstant] = []
    for mod in modules:
        if not mod.path.endswith(ANCHOR_SUFFIXES):
            continue
        for node in mod.tree.body:
            if not isinstance(node, ast.Assign):
                continue
            for target in node.targets:
                if not (
                    isinstance(target, ast.Name)
                    and _is_constant_name(target.id)
                ):
                    continue
                if isinstance(node.value, ast.Constant) and isinstance(
                    node.value.value, int
                ):
                    out.append(
                        StreamConstant(
                            path=mod.path, name=target.id,
                            value=node.value.value, line=node.lineno,
                        )
                    )
    return sorted(out)


def validate_constants(
    constants: Sequence[StreamConstant],
) -> List[str]:
    """Disjointness / range / parity violations, as human-readable strings
    (RPA006 wraps them into findings)."""
    problems: List[str] = []
    by_value: dict = {}
    for c in constants:
        if not 0 < c.value <= _MASK32:
            problems.append(
                f"{c.name} ({c.path}:{c.line}) = {c.value:#x} is outside "
                f"(0, 2^32] — not a valid uint32 derivation constant"
            )
        if c.is_weyl and c.value % 2 == 0:
            problems.append(
                f"{c.name} ({c.path}:{c.line}) = {c.value:#x} is even — a "
                f"Weyl increment must be odd to stay injective mod 2^32"
            )
        by_value.setdefault(c.value, []).append(c)
    for value, cs in sorted(by_value.items()):
        if len(cs) > 1:
            names = ", ".join(f"{c.name} ({c.path}:{c.line})" for c in cs)
            problems.append(
                f"duplicate derivation constant {value:#x}: {names} — "
                f"streams derived through these constants can alias "
                f"(DESIGN §6/§10 disjointness contract)"
            )
    return problems


def registry_payload(modules: Sequence[ModuleInfo]) -> dict:
    """JSON-friendly generated registry (``--dump-registry``)."""
    constants = extract_constants(modules)
    return {
        "constants": [
            {
                "name": c.name,
                "value": f"{c.value:#010x}",
                "path": c.path,
                "line": c.line,
                "weyl": c.is_weyl,
            }
            for c in constants
        ],
        "problems": validate_constants(constants),
    }
