"""RPA008: kernel-triple conformance.

Every accelerator op lives in ``repro/kernels/<name>/`` as a triple
(DESIGN §6/§11 layout, mirrored by all six existing kernels):

* ``kernel.py`` — the Pallas device kernel (public entry carries an
  accelerator suffix: ``_fwd``/``_tpu``/``_pallas``);
* ``ref.py``    — the pure-jnp oracle (``*_ref``), importable without
  the kernel: parity tests must be able to trust it as an independent
  witness, so ``ref.py`` must not import ``kernel``/``ops``;
* ``ops.py``    — the public dispatch (may import both).

Layering: ``kernel.py`` must not import ``ops.py`` (the dispatch sits
on top).  Signature conformance: for every public ops function ``X``
with an oracle ``X_ref``, the parameter names the two share must appear
in the same relative order (a transposed or renamed argument between
dispatch and oracle is how a parity test silently starts comparing the
wrong thing); the first positional parameter must match exactly.  The
same check runs against ``X_<accel-suffix>`` kernels.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from repro.analysis.core import Checker, Finding, ModuleInfo

_TRIPLE = ("kernel.py", "ref.py", "ops.py")
_ACCEL_SUFFIXES = ("_fwd", "_tpu", "_pallas", "_kernel", "_xla")


def _kernel_packages(
    modules: Sequence[ModuleInfo],
) -> Dict[str, Dict[str, ModuleInfo]]:
    """``{package-dir: {filename: module}}`` for kernels/<name>/ dirs."""
    out: Dict[str, Dict[str, ModuleInfo]] = {}
    for mod in modules:
        parts = mod.pkg_parts
        if (
            len(parts) == 4
            and parts[0] == "repro"
            and parts[1] == "kernels"
            and parts[3].endswith(".py")
        ):
            pkg_dir = mod.path.rsplit("/", 1)[0]
            out.setdefault(pkg_dir, {})[parts[3]] = mod
    return out


def _public_fns(mod: ModuleInfo) -> Dict[str, ast.FunctionDef]:
    return {
        node.name: node
        for node in mod.tree.body
        if isinstance(node, ast.FunctionDef)
        and not node.name.startswith("_")
    }


def _positional_names(fn: ast.FunctionDef) -> List[str]:
    args = fn.args
    return [a.arg for a in list(args.posonlyargs) + list(args.args)]


def _all_param_names(fn: ast.FunctionDef) -> List[str]:
    args = fn.args
    return [
        a.arg
        for a in list(args.posonlyargs) + list(args.args)
        + list(args.kwonlyargs)
    ]


def _imports_sibling(mod: ModuleInfo, sibling: str) -> Optional[ast.AST]:
    """Import node when ``mod`` imports the named sibling module of the
    same kernel package (absolute or relative form)."""
    pkg = ".".join(mod.pkg_parts[:-1])  # e.g. repro.kernels.traffic
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == f"{pkg}.{sibling}":
                    return node
        elif isinstance(node, ast.ImportFrom):
            module = node.module or ""
            if node.level:  # relative: from . import kernel / from .kernel
                if module == sibling or (
                    module == "" and any(
                        a.name == sibling for a in node.names
                    )
                ):
                    return node
            elif module == f"{pkg}.{sibling}":
                return node
            elif module == pkg and any(
                a.name == sibling for a in node.names
            ):
                return node
    return None


def _order_conflict(
    ops_params: List[str], other_params: List[str]
) -> Optional[Tuple[str, str]]:
    """First pair of shared parameter names whose relative order differs."""
    shared = [p for p in ops_params if p in other_params]
    pos = {p: other_params.index(p) for p in shared}
    for i in range(1, len(shared)):
        if pos[shared[i]] < pos[shared[i - 1]]:
            return shared[i - 1], shared[i]
    return None


class KernelTripleChecker(Checker):
    code = "RPA008"
    name = "kernel-triple"
    description = (
        "every kernels/<name>/ package must ship the "
        "kernel.py/ref.py/ops.py triple with layered imports and "
        "order-consistent public signatures"
    )

    def check_project(
        self, modules: Sequence[ModuleInfo]
    ) -> Iterator[Finding]:
        for pkg_dir, files in sorted(_kernel_packages(modules).items()):
            if "__init__.py" not in files:
                continue
            init = files["__init__.py"]
            for required in _TRIPLE:
                if required not in files:
                    yield self.finding(
                        init, init.tree,
                        f"kernel package {pkg_dir} is missing "
                        f"{required} — every kernel ships the "
                        f"kernel/ref/ops triple (DESIGN §6 layout)",
                    )
            if not all(f in files for f in _TRIPLE):
                continue
            yield from self._check_triple(pkg_dir, files)

    def _check_triple(
        self, pkg_dir: str, files: Dict[str, ModuleInfo]
    ) -> Iterator[Finding]:
        ref, kernel, ops = files["ref.py"], files["kernel.py"], files["ops.py"]

        for sibling in ("kernel", "ops"):
            node = _imports_sibling(ref, sibling)
            if node is not None:
                yield self.finding(
                    ref, node,
                    f"ref.py imports {sibling}.py — the oracle must stay "
                    f"an independent witness (parity tests lose their "
                    f"meaning if the reference shares kernel code)",
                )
        node = _imports_sibling(kernel, "ops")
        if node is not None:
            yield self.finding(
                kernel, node,
                "kernel.py imports ops.py — the dispatch layer sits on "
                "top of the kernel, not under it",
            )

        ref_fns = _public_fns(ref)
        kernel_fns = _public_fns(kernel)
        ops_fns = _public_fns(ops)
        if not any(n.endswith("_ref") for n in ref_fns):
            yield self.finding(
                ref, ref.tree,
                f"ref.py in {pkg_dir} defines no public *_ref oracle",
            )
        if not any(
            n.endswith(_ACCEL_SUFFIXES) for n in kernel_fns
        ):
            yield self.finding(
                kernel, kernel.tree,
                f"kernel.py in {pkg_dir} defines no public accelerator "
                f"entry (*_fwd/*_tpu/*_pallas)",
            )
        if not ops_fns:
            yield self.finding(
                ops, ops.tree,
                f"ops.py in {pkg_dir} defines no public dispatch function",
            )

        for name, ops_fn in sorted(ops_fns.items()):
            counterparts = [(f"{name}_ref", ref, ref_fns.get(f"{name}_ref"))]
            counterparts += [
                (f"{name}{suf}", kernel, kernel_fns.get(f"{name}{suf}"))
                for suf in _ACCEL_SUFFIXES
            ]
            for other_name, other_mod, other_fn in counterparts:
                if other_fn is None:
                    continue
                # kw-only parameters are order-free by construction, so
                # conformance is judged on positional parameters only
                ops_pos = _positional_names(ops_fn)
                other_pos = _positional_names(other_fn)
                if (
                    ops_pos
                    and other_pos
                    and ops_pos[0] != other_pos[0]
                ):
                    yield self.finding(
                        other_mod, other_fn,
                        f"{other_name} leads with parameter "
                        f"`{other_pos[0]}` but dispatch {name} leads "
                        f"with `{ops_pos[0]}` — triple signatures must "
                        f"agree on the primary operand",
                        other_name,
                    )
                conflict = _order_conflict(ops_pos, other_pos)
                if conflict is not None:
                    a, b = conflict
                    yield self.finding(
                        other_mod, other_fn,
                        f"{other_name} orders shared parameters "
                        f"`{b}` before `{a}` but dispatch {name} passes "
                        f"`{a}` before `{b}` — transposed triple "
                        f"signatures silently break parity",
                        other_name,
                    )
