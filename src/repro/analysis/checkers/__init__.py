"""Concrete RPA rule modules; importing this package registers them all."""

from repro.analysis.checkers import (  # noqa: F401
    collector,
    determinism,
    kernel_triple,
    stream_keys,
    tracer,
    x64,
)
