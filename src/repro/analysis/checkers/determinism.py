"""RPA001–RPA003: engine-path determinism.

The PON/FL co-simulation engine (``repro.net``, ``repro.kernels``,
``repro.faults``) is bitwise-reproducible because every random draw is
a counter-based threefry stream keyed on ``(seed, phase, round, ...)``
(DESIGN §6/§10) and nothing reads ambient host state.  These rules keep
it that way:

* **RPA001** — host RNG: stdlib ``random.*``, any ``np.random.*`` call
  outside an explicitly *seeded* ``default_rng``/``Generator``
  construction, and ``np.random.seed`` (global-state mutation).
* **RPA002** — wall-clock reads (``time.time``, ``datetime.now``, …):
  simulated time is the only clock the engine may consult.
* **RPA003** — unordered iteration feeding numeric state: iterating a
  ``set``/``frozenset`` (hash order), unsorted ``os.listdir``/``glob``
  results, or ``vars()``-style namespace dicts.  Plain dict iteration
  is *not* flagged — insertion order is deterministic in py3.7+ and the
  engine relies on it.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.core import (
    Checker,
    Finding,
    ModuleInfo,
    dotted_name,
    enclosing_symbols,
    import_aliases,
    resolve_call_target,
)

ENGINE_SCOPE = ("net", "kernels", "faults")

_SEEDED_CTORS = {"default_rng", "Generator", "SeedSequence", "PCG64", "Philox"}

_CLOCK_CALLS = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns", "time.clock_gettime",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
}

_LISTING_CALLS = {"os.listdir", "os.scandir", "glob.glob", "glob.iglob"}


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        fn = dotted_name(node.func)
        return fn in ("set", "frozenset")
    return False


class HostRngChecker(Checker):
    code = "RPA001"
    name = "determinism-host-rng"
    description = (
        "engine paths must draw randomness from counter-based streams, "
        "never host RNG (stdlib random, unseeded np.random)"
    )

    def check_module(self, mod: ModuleInfo) -> Iterator[Finding]:
        if not mod.in_package(*ENGINE_SCOPE):
            return
        aliases = import_aliases(mod.tree)
        symbols = enclosing_symbols(mod.tree)
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            target = resolve_call_target(node, aliases)
            if target is None:
                continue
            symbol = symbols.get(node, "<module>")
            if target.startswith("random."):
                yield self.finding(
                    mod, node,
                    f"stdlib host RNG call `{target}` — engine randomness "
                    f"must come from keyed threefry streams "
                    f"(kernels.traffic / faults.streams)",
                    symbol,
                )
            elif target.startswith(("numpy.random.", "np.random.")):
                leaf = target.rsplit(".", 1)[1]
                if leaf == "seed":
                    yield self.finding(
                        mod, node,
                        "`np.random.seed` mutates global RNG state — "
                        "engine paths must not touch the legacy global "
                        "generator",
                        symbol,
                    )
                elif leaf not in _SEEDED_CTORS:
                    yield self.finding(
                        mod, node,
                        f"legacy global-state RNG call `np.random.{leaf}` "
                        f"— use a seeded np.random.default_rng or a "
                        f"counter-based stream",
                        symbol,
                    )
                elif not node.args and not node.keywords:
                    yield self.finding(
                        mod, node,
                        f"`np.random.{leaf}()` without a seed draws OS "
                        f"entropy — pass an explicit seed",
                        symbol,
                    )


class WallClockChecker(Checker):
    code = "RPA002"
    name = "determinism-wall-clock"
    description = (
        "engine paths must not read the wall clock; simulated time is "
        "the only clock"
    )

    def check_module(self, mod: ModuleInfo) -> Iterator[Finding]:
        if not mod.in_package(*ENGINE_SCOPE):
            return
        aliases = import_aliases(mod.tree)
        symbols = enclosing_symbols(mod.tree)
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            target = resolve_call_target(node, aliases)
            if target in _CLOCK_CALLS:
                yield self.finding(
                    mod, node,
                    f"wall-clock read `{target}()` inside an engine path — "
                    f"simulation results must not depend on host time",
                    symbols.get(node, "<module>"),
                )


class UnorderedIterChecker(Checker):
    code = "RPA003"
    name = "determinism-unordered-iteration"
    description = (
        "engine paths must not iterate hash-ordered sets or unsorted "
        "directory listings into numeric state"
    )

    def check_module(self, mod: ModuleInfo) -> Iterator[Finding]:
        if not mod.in_package(*ENGINE_SCOPE):
            return
        aliases = import_aliases(mod.tree)
        symbols = enclosing_symbols(mod.tree)
        sorted_args = set()
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                fn = dotted_name(node.func)
                if fn in ("sorted", "min", "max", "len", "any", "all"):
                    for a in node.args:
                        sorted_args.add(id(a))
        for node in ast.walk(mod.tree):
            iters = []
            if isinstance(node, (ast.For, ast.AsyncFor)):
                iters.append(node.iter)
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                iters.extend(g.iter for g in node.generators)
            elif isinstance(node, ast.Call):
                fn = dotted_name(node.func)
                if fn in ("sum", "list", "tuple", "enumerate"):
                    iters.extend(node.args[:1])
            for it in iters:
                if id(it) in sorted_args:
                    continue
                if _is_set_expr(it):
                    yield self.finding(
                        mod, it,
                        "iteration over a set is hash-ordered — sort it "
                        "(or keep a list/array) before it feeds engine "
                        "state",
                        symbols.get(it, symbols.get(node, "<module>")),
                    )
                elif isinstance(it, ast.Call):
                    target = resolve_call_target(it, aliases)
                    if target in _LISTING_CALLS:
                        yield self.finding(
                            mod, it,
                            f"`{target}` order is filesystem-dependent — "
                            f"wrap in sorted()",
                            symbols.get(it, symbols.get(node, "<module>")),
                        )
                    elif (
                        isinstance(it.func, ast.Attribute)
                        and it.func.attr in ("keys", "values", "items")
                        and isinstance(it.func.value, ast.Call)
                        and dotted_name(it.func.value.func)
                        in ("vars", "globals", "locals")
                    ):
                        yield self.finding(
                            mod, it,
                            "iterating a namespace dict "
                            "(vars/globals/locals) feeds reflection order "
                            "into engine state",
                            symbols.get(it, symbols.get(node, "<module>")),
                        )
