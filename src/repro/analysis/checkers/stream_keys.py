"""RPA006: stream-key disjointness (see ``repro.analysis.registry``).

The checker extracts every Weyl/derivation constant from the anchor
modules into the generated registry and verifies pairwise disjointness,
oddness and range.  An empty extraction while anchor modules are in the
scan set is itself a finding (a rename that silently empties the
registry must not read as "no collisions").
"""

from __future__ import annotations

from typing import Iterator, Sequence

from repro.analysis import registry
from repro.analysis.core import Checker, Finding, ModuleInfo


class StreamKeyChecker(Checker):
    code = "RPA006"
    name = "stream-key-disjointness"
    description = (
        "stream-key Weyl/derivation constants must be pairwise distinct "
        "odd uint32s so no stream class can alias another"
    )

    def check_project(
        self, modules: Sequence[ModuleInfo]
    ) -> Iterator[Finding]:
        anchors = [
            m for m in modules
            if m.path.endswith(registry.ANCHOR_SUFFIXES)
        ]
        if not anchors:
            return
        constants = registry.extract_constants(modules)
        if len(constants) < registry.MIN_CONSTANTS:
            names = sorted({c.name for c in constants})
            yield self.finding(
                anchors[0], anchors[0].tree,
                f"stream-key registry extraction found only "
                f"{len(constants)} constants ({names}) across "
                f"{len(anchors)} anchor modules — expected at least "
                f"{registry.MIN_CONSTANTS}; a rename/move must update "
                f"repro.analysis.registry, not silently shrink the "
                f"registry",
            )
        for problem in registry.validate_constants(constants):
            # anchor the finding at the first named constant's location
            target = next(
                (
                    c for c in constants
                    if c.name in problem and f"{c.path}:{c.line}" in problem
                ),
                constants[0] if constants else None,
            )
            yield Finding(
                path=target.path if target else anchors[0].path,
                line=target.line if target else 0,
                col=0,
                code=self.code,
                symbol=target.name if target else "<module>",
                message=problem,
            )
