"""RPA004: x64 hygiene.

The jit PON backend does float64 queue arithmetic under a *scoped*
``jax.experimental.enable_x64()`` context (DESIGN §11); the ambient
``jax_enable_x64`` flag is never flipped, because an ambient flip
changes dtypes (and therefore bits) for every other jitted program in
the process — including the traffic sampler's pinned uint32/float32
streams.  This rule flags every ambient flip:

* ``jax.config.update("jax_enable_x64", ...)`` (any alias of
  ``jax.config`` / ``from jax import config``);
* attribute assignment ``jax.config.jax_enable_x64 = ...``;
* ``os.environ["JAX_ENABLE_X64"] = ...`` / ``putenv``.

Reads of the flag and the scoped ``enable_x64()`` context manager are
allowed.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.core import (
    Checker,
    Finding,
    ModuleInfo,
    dotted_name,
    enclosing_symbols,
)


def _const_str(node: ast.AST) -> str:
    return node.value if isinstance(node, ast.Constant) and isinstance(
        node.value, str
    ) else ""


class X64HygieneChecker(Checker):
    code = "RPA004"
    name = "x64-hygiene"
    description = (
        "the ambient jax_enable_x64 flag must never be flipped — use the "
        "scoped jax.experimental.enable_x64() context"
    )

    def check_module(self, mod: ModuleInfo) -> Iterator[Finding]:
        symbols = enclosing_symbols(mod.tree)
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                fn = dotted_name(node.func) or ""
                if fn.endswith("config.update") or fn == "config.update":
                    if node.args and "x64" in _const_str(node.args[0]):
                        yield self.finding(
                            mod, node,
                            "ambient `config.update(\"jax_enable_x64\", …)` "
                            "— flip x64 only through the scoped "
                            "jax.experimental.enable_x64() context "
                            "(DESIGN §11 precision policy)",
                            symbols.get(node, "<module>"),
                        )
                elif fn in ("os.putenv",):
                    if node.args and "X64" in _const_str(node.args[0]):
                        yield self.finding(
                            mod, node,
                            "setting JAX_ENABLE_X64 via the environment "
                            "flips x64 process-wide — use the scoped "
                            "enable_x64() context",
                            symbols.get(node, "<module>"),
                        )
            elif isinstance(node, ast.Assign):
                for target in node.targets:
                    dn = dotted_name(target) or ""
                    if dn.endswith("jax_enable_x64"):
                        yield self.finding(
                            mod, target,
                            "direct assignment to the ambient "
                            "jax_enable_x64 flag — use the scoped "
                            "enable_x64() context",
                            symbols.get(node, "<module>"),
                        )
                    elif (
                        isinstance(target, ast.Subscript)
                        and (dotted_name(target.value) or "").endswith(
                            "environ"
                        )
                        and "X64" in _const_str(target.slice)
                    ):
                        yield self.finding(
                            mod, target,
                            "setting JAX_ENABLE_X64 via os.environ flips "
                            "x64 process-wide — use the scoped "
                            "enable_x64() context",
                            symbols.get(node, "<module>"),
                        )
