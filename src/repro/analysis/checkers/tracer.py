"""RPA005: tracer purity in device-traced kernel code.

The jit PON backend (DESIGN §11) compiles whole phases into one
``lax.while_loop`` program, and every ``kernels/<name>/`` triple ships a
traced oracle (``*_ref``) plus a Pallas kernel.  A host sync inside a
traced function — ``.item()``, ``float()``/``int()`` on a traced value,
``np.asarray`` on a tracer, Python ``if`` on a traced predicate — either
crashes under jit or, worse, silently freezes a traced value at trace
time (a wrong-answer bug, not an error).

Traced roots are discovered structurally, per module in
``repro/kernels/``:

* functions wrapped by ``jax.jit`` / ``functools.partial(jax.jit, …)``
  (decorator or call form) and ``jax.vmap``/``jax.grad``;
* callees handed to ``lax.while_loop``/``cond``/``scan``/``fori_loop``/
  ``switch``/``map`` and ``pl.pallas_call``;
* public ``*_ref`` oracles (traced-by-contract: they run under the
  engine's jit program).

plus everything they call (direct same-module calls, nested defs
included).  Inside those bodies the rule flags host syncs.  Python
branches are only flagged when the tested name is *array-like* (used in
``jnp.``/``lax.`` arithmetic inside the same function) and the test is
not a static accessor (``is None``, ``.shape``/``.ndim``/``.dtype``,
``len()``, ``isinstance``).
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Set

from repro.analysis.core import (
    Checker,
    Finding,
    ModuleInfo,
    dotted_name,
    enclosing_symbols,
    walk_functions,
)

_LAX_HOFS = {
    "while_loop", "cond", "scan", "fori_loop", "switch", "map",
    "associated_scan", "associative_scan",
}
_JIT_WRAPPERS = {"jit", "vmap", "grad", "value_and_grad", "pmap", "checkify"}
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "name"}
_HOST_SYNC_METHODS = {"item", "tolist", "to_py"}
_NP_HOST_CALLS = {"asarray", "array", "ascontiguousarray", "copyto", "save"}


def _callable_names(node: ast.AST) -> List[str]:
    """Plain function names referenced by an expression (Name or
    functools.partial(Name, …))."""
    if isinstance(node, ast.Name):
        return [node.id]
    if isinstance(node, ast.Call):
        fn = dotted_name(node.func) or ""
        if fn.endswith("partial"):
            out: List[str] = []
            for a in node.args:
                out.extend(_callable_names(a))
            return out
    return []


class _FnInfo:
    def __init__(self, qual: str, node: ast.AST) -> None:
        self.qual = qual
        self.node = node
        self.calls: Set[str] = set()       # unqualified callee names
        for n in ast.walk(node):
            if isinstance(n, ast.Call) and isinstance(n.func, ast.Name):
                self.calls.add(n.func.id)


class TracerPurityChecker(Checker):
    code = "RPA005"
    name = "tracer-purity"
    description = (
        "functions traced under jit/pallas must not host-sync "
        "(.item(), float()/int(), np.asarray, Python branches on tracers)"
    )

    def check_module(self, mod: ModuleInfo) -> Iterator[Finding]:
        if not mod.in_package("kernels"):
            return
        symbols = enclosing_symbols(mod.tree)
        fns: Dict[str, _FnInfo] = {}
        by_name: Dict[str, List[str]] = {}
        for qual, node in walk_functions(mod.tree):
            fns[qual] = _FnInfo(qual, node)
            by_name.setdefault(qual.rsplit(".", 1)[-1], []).append(qual)

        roots = self._find_roots(mod, fns)
        reachable = self._reach(roots, fns, by_name)
        for qual in sorted(reachable):
            yield from self._check_body(mod, fns[qual], symbols)

    # -- root discovery ----------------------------------------------------

    def _find_roots(
        self, mod: ModuleInfo, fns: Dict[str, _FnInfo]
    ) -> Set[str]:
        roots: Set[str] = set()
        simple = {q.rsplit(".", 1)[-1]: q for q in fns}

        def add_names(expr: ast.AST) -> None:
            for name in _callable_names(expr):
                if name in simple:
                    roots.add(simple[name])

        for qual, info in fns.items():
            node = info.node
            name = qual.rsplit(".", 1)[-1]
            if name.endswith("_ref") and not name.startswith("_"):
                roots.add(qual)
            for dec in getattr(node, "decorator_list", []):
                targets = [dotted_name(dec) or ""]
                if isinstance(dec, ast.Call):
                    targets = [dotted_name(dec.func) or ""]
                    for a in dec.args:
                        targets.append(dotted_name(a) or "")
                for t in targets:
                    leaf = t.rsplit(".", 1)[-1]
                    if leaf in _JIT_WRAPPERS:
                        roots.add(qual)
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = dotted_name(node.func) or ""
            leaf = fn.rsplit(".", 1)[-1]
            if leaf in _JIT_WRAPPERS:
                for a in node.args:
                    add_names(a)
            elif leaf in _LAX_HOFS:
                for a in node.args:
                    add_names(a)
            elif leaf == "pallas_call":
                for a in list(node.args) + [
                    kw.value for kw in node.keywords
                ]:
                    add_names(a)
        return roots

    def _reach(
        self,
        roots: Set[str],
        fns: Dict[str, _FnInfo],
        by_name: Dict[str, List[str]],
    ) -> Set[str]:
        seen: Set[str] = set()
        stack = sorted(roots)
        while stack:
            qual = stack.pop()
            if qual in seen or qual not in fns:
                continue
            seen.add(qual)
            # nested defs trace with their parent
            prefix = qual + "."
            for other in fns:
                if other.startswith(prefix) and "." not in other[len(prefix):]:
                    stack.append(other)
            for callee in fns[qual].calls:
                for target in by_name.get(callee, []):
                    stack.append(target)
        return seen

    # -- body rules --------------------------------------------------------

    def _check_body(
        self, mod: ModuleInfo, info: _FnInfo, symbols
    ) -> Iterator[Finding]:
        node = info.node
        params = set()
        for a in (
            list(node.args.args)
            + list(node.args.posonlyargs)
            + list(node.args.kwonlyargs)
        ):
            if a.arg in ("self", "cls"):
                continue
            # `n_draws: int`-style annotations declare a static config
            # argument — never a tracer candidate
            ann = a.annotation
            if isinstance(ann, ast.Name) and ann.id in (
                "int", "float", "bool", "str", "bytes"
            ):
                continue
            params.add(a.arg)
        arraylike = self._arraylike_names(node, params)

        own_nested = set()
        for n in ast.walk(node):
            if n is not node and isinstance(
                n, (ast.FunctionDef, ast.AsyncFunctionDef)
            ):
                own_nested.add(n)

        def in_nested(n: ast.AST) -> bool:
            return any(
                n in ast.walk(nested) and n is not nested
                for nested in own_nested
            )

        for n in ast.walk(node):
            if n is node or in_nested(n):
                continue  # nested defs are checked as their own unit
            if isinstance(n, ast.Call):
                fn = dotted_name(n.func) or ""
                leaf = fn.rsplit(".", 1)[-1]
                if (
                    isinstance(n.func, ast.Attribute)
                    and n.func.attr in _HOST_SYNC_METHODS
                ):
                    yield self.finding(
                        mod, n,
                        f"`.{n.func.attr}()` forces a host sync — illegal "
                        f"inside a traced function",
                        symbols.get(n, info.qual),
                    )
                elif fn.startswith(("np.", "numpy.")) and (
                    leaf in _NP_HOST_CALLS
                ):
                    yield self.finding(
                        mod, n,
                        f"`{fn}` materialises on host — a traced value "
                        f"must stay jnp (use jnp.{leaf})",
                        symbols.get(n, info.qual),
                    )
                elif fn in ("float", "int", "bool") and n.args:
                    a = n.args[0]
                    if not isinstance(a, ast.Constant) and self._mentions(
                        a, arraylike
                    ):
                        yield self.finding(
                            mod, n,
                            f"builtin `{fn}()` on a traced value forces a "
                            f"concrete host scalar at trace time",
                            symbols.get(n, info.qual),
                        )
            elif isinstance(n, (ast.If, ast.While)):
                test = n.test
                if self._is_dynamic_test(test, arraylike):
                    kind = "if" if isinstance(n, ast.If) else "while"
                    yield self.finding(
                        mod, test,
                        f"Python `{kind}` on a traced value — tracing "
                        f"freezes one branch; use lax.cond/jnp.where",
                        symbols.get(n, info.qual),
                    )

    def _arraylike_names(self, node: ast.AST, params: Set[str]) -> Set[str]:
        """Params (and names derived from jnp/lax results) that plausibly
        hold traced arrays: used inside jnp./lax. calls or in arithmetic
        with them."""
        arraylike: Set[str] = set()
        for n in ast.walk(node):
            if isinstance(n, ast.Call):
                fn = dotted_name(n.func) or ""
                if fn.startswith(("jnp.", "lax.", "jax.numpy.", "jax.lax.")):
                    for a in list(n.args) + [kw.value for kw in n.keywords]:
                        for name_node in self._walk_same_scope(a):
                            if (
                                isinstance(name_node, ast.Name)
                                and name_node.id in params
                            ):
                                arraylike.add(name_node.id)
        return arraylike

    def _walk_same_scope(self, node: ast.AST):
        """ast.walk that does not descend into nested defs/lambdas —
        their bodies reference closure names from a different scope."""
        yield node
        stack = [node]
        while stack:
            cur = stack.pop()
            for child in ast.iter_child_nodes(cur):
                if isinstance(
                    child,
                    (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda),
                ):
                    continue
                yield child
                stack.append(child)

    def _mentions(self, expr: ast.AST, names: Set[str]) -> bool:
        return any(
            isinstance(n, ast.Name) and n.id in names
            for n in ast.walk(expr)
        )

    def _is_dynamic_test(self, test: ast.AST, arraylike: Set[str]) -> bool:
        if not self._mentions(test, arraylike):
            return False
        # static accessors make the test trace-safe
        for n in ast.walk(test):
            if isinstance(n, ast.Name) and n.id in arraylike:
                if not self._static_use(n, test):
                    return True
        return False

    def _static_use(self, name_node: ast.Name, test: ast.AST) -> bool:
        """True when this reference only feeds static accessors
        (.shape/.ndim/.dtype, len(), isinstance, `is None`)."""
        parents: Dict[ast.AST, ast.AST] = {}
        for p in ast.walk(test):
            for c in ast.iter_child_nodes(p):
                parents[c] = p
        n: ast.AST = name_node
        parent = parents.get(n)
        while parent is not None:
            if isinstance(parent, ast.Attribute) and (
                parent.attr in _STATIC_ATTRS
            ):
                return True
            if isinstance(parent, ast.Call):
                fn = dotted_name(parent.func) or ""
                if fn in ("len", "isinstance", "type", "getattr", "hasattr"):
                    return True
                return False
            if isinstance(parent, ast.Compare) and any(
                isinstance(op, (ast.Is, ast.IsNot))
                for op in parent.ops
            ):
                return True
            n, parent = parent, parents.get(parent)
        return False
