"""RPA007: collector purity — the bitwise-uninstrumented contract.

DESIGN §9: every entry point takes ``collector=None`` and a disabled
collector must be *bitwise* free — not one extra numpy op, not one
state divergence.  Two source-level rules make that auditable:

* every use of a ``collector`` parameter (attribute access, method
  call) must sit under a ``collector is not None`` guard — an early
  ``if collector is None: return`` counts, as do aliases bound from
  guarded collector calls (``obs = collector.phase(...)`` →
  ``if obs is not None:`` blocks are guarded too).  Passing the bare
  ``collector`` name through to another function is always fine (the
  callee re-guards).
* inside those guarded blocks, no *engine state* may be written: any
  assignment to a name that is also bound outside guarded blocks, any
  subscript/attribute store on a non-collector object, any augmented
  assignment and any mutating method call (``.append``/``.update``/…)
  on an outside object is flagged — instrumentation must be read-only
  with respect to the simulation.  Obs-local names (bound only under
  guards) are fine.

``self._collector`` attributes follow the same rules as a ``collector``
parameter.
"""

from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Set, Tuple

from repro.analysis.core import (
    Checker,
    Finding,
    ModuleInfo,
    dotted_name,
    walk_functions,
)

_MUTATORS = {
    "append", "extend", "insert", "remove", "pop", "clear", "update",
    "setdefault", "popitem", "add", "discard", "sort", "reverse", "fill",
}


def _has_collector_param(fn: ast.AST) -> bool:
    args = fn.args
    return any(
        a.arg == "collector"
        for a in list(args.args) + list(args.posonlyargs)
        + list(args.kwonlyargs)
    )


def _collector_param_optional(fn: ast.AST) -> bool:
    """True when the ``collector`` parameter defaults to ``None``."""
    args = fn.args
    positional = list(args.posonlyargs) + list(args.args)
    defaults = list(args.defaults)
    # defaults align with the tail of the positional list
    offset = len(positional) - len(defaults)
    for i, a in enumerate(positional):
        if a.arg == "collector":
            if i >= offset:
                d = defaults[i - offset]
                return isinstance(d, ast.Constant) and d.value is None
            return False
    for a, d in zip(args.kwonlyargs, args.kw_defaults):
        if a.arg == "collector":
            return isinstance(d, ast.Constant) and d.value is None
    return False


def _has_none_test(fn: ast.AST, roots: Set[str], excluded: Set[int]) -> bool:
    """True when the body tests any collector root against ``None``."""
    for n in ast.walk(fn):
        if id(n) in excluded:
            continue
        if isinstance(n, ast.Compare) and _none_test(n, roots) is not None:
            return True
    return False


def _collector_roots(fn: ast.AST, excluded: Set[int]) -> Set[str]:
    """Dotted expressions denoting the collector inside this unit."""
    roots: Set[str] = set()
    if _has_collector_param(fn):
        roots.add("collector")
    for n in ast.walk(fn):
        if id(n) in excluded:
            continue
        if isinstance(n, ast.Attribute):
            dn = dotted_name(n)
            if dn in ("self._collector", "self.collector"):
                roots.add(dn)
    return roots


def _none_test(test: ast.AST, roots: Set[str]) -> Optional[Tuple[str, bool]]:
    """(root, is_not_none) when ``test`` is ``<root> is [not] None``."""
    if (
        isinstance(test, ast.Compare)
        and len(test.ops) == 1
        and isinstance(test.ops[0], (ast.Is, ast.IsNot))
        and isinstance(test.comparators[0], ast.Constant)
        and test.comparators[0].value is None
    ):
        dn = dotted_name(test.left)
        if dn in roots:
            return dn, isinstance(test.ops[0], ast.IsNot)
    return None


def _body_guarded(test: ast.AST, roots: Set[str]) -> bool:
    """True when the if-body only runs with the collector present."""
    nt = _none_test(test, roots)
    if nt is not None:
        return nt[1]
    if isinstance(test, ast.BoolOp) and isinstance(test.op, ast.And):
        return any(
            (_none_test(v, roots) or (None, False))[1]
            for v in test.values
        )
    return False


def _implies_present_after(test: ast.AST, roots: Set[str]) -> bool:
    """True when a terminating if-body proves the collector is present
    afterwards (test is ``x is None`` or an or-chain containing it)."""
    nt = _none_test(test, roots)
    if nt is not None:
        return not nt[1]
    if isinstance(test, ast.BoolOp) and isinstance(test.op, ast.Or):
        return any(
            _none_test(v, roots) is not None
            and not _none_test(v, roots)[1]
            for v in test.values
        )
    return False


def _terminates(stmts: List[ast.stmt]) -> bool:
    return bool(stmts) and isinstance(
        stmts[-1], (ast.Return, ast.Raise, ast.Continue, ast.Break)
    )


class CollectorPurityChecker(Checker):
    code = "RPA007"
    name = "collector-purity"
    description = (
        "obs work must be guarded under `collector is not None` and "
        "guarded blocks must not write engine state "
        "(collector=None is bitwise-uninstrumented)"
    )

    def check_module(self, mod: ModuleInfo) -> Iterator[Finding]:
        units = []
        for qual, fn in walk_functions(mod.tree):
            units.append((qual, fn))
        with_param = {id(fn) for _, fn in units if _has_collector_param(fn)}
        for qual, fn in units:
            # nested units with their own collector param are analyzed
            # standalone; exclude their subtrees from the enclosing unit
            excluded: Set[int] = set()
            for n in ast.walk(fn):
                if n is not fn and id(n) in with_param:
                    for sub in ast.walk(n):
                        excluded.add(id(sub))
            roots = _collector_roots(fn, excluded)
            if not roots:
                continue
            # The contract covers *optional* collectors only: a required
            # collector argument (no None default, never None-tested —
            # e.g. an obs-layer helper that always receives one) is not
            # subject to the guarded-use rule.
            if not (
                (_has_collector_param(fn) and _collector_param_optional(fn))
                or _has_none_test(fn, roots, excluded)
            ):
                continue
            yield from self._check_unit(mod, qual, fn, roots, excluded)

    # ------------------------------------------------------------------

    def _check_unit(
        self,
        mod: ModuleInfo,
        qual: str,
        fn: ast.AST,
        roots: Set[str],
        excluded: Set[int],
    ) -> Iterator[Finding]:
        aliases = set(roots)
        self._collect_aliases(fn, aliases, excluded)

        guarded: Set[int] = set()
        self._mark(fn.body, aliases, False, guarded, excluded)
        self._mark_expr_guards(fn, aliases, guarded, excluded)

        outside = self._outside_bindings(fn, guarded, aliases, excluded)

        for n in ast.walk(fn):
            if id(n) in excluded or n is fn:
                continue
            if id(n) in guarded:
                yield from self._guarded_rules(
                    mod, qual, n, aliases, outside
                )
            else:
                yield from self._unguarded_rules(mod, qual, n, aliases)

    def _collect_aliases(
        self, fn: ast.AST, aliases: Set[str], excluded: Set[int]
    ) -> None:
        changed = True
        while changed:
            changed = False
            for n in ast.walk(fn):
                if id(n) in excluded:
                    continue
                if isinstance(n, ast.Assign) and self._alias_expr(
                    n.value, aliases
                ):
                    for t in n.targets:
                        if isinstance(t, ast.Name) and t.id not in aliases:
                            aliases.add(t.id)
                            changed = True

    def _alias_expr(self, expr: Optional[ast.AST], aliases: Set[str]) -> bool:
        """True when ``expr`` *produces* a collector-derived object: a
        bare copy of an alias, or a call dispatched *on* an alias
        (``collector.phase(...)``).  Merely passing the collector as an
        argument (``simulate(..., collector=collector)``) does not make
        the result obs-owned — the callee re-guards."""
        if expr is None:
            return False
        if isinstance(expr, ast.Name):
            return expr.id in aliases
        if isinstance(expr, ast.Attribute):
            return dotted_name(expr) in aliases
        if isinstance(expr, ast.Call):
            fn = expr.func
            while isinstance(fn, ast.Attribute):
                if dotted_name(fn) in aliases:
                    return True
                fn = fn.value
            return isinstance(fn, ast.Name) and fn.id in aliases
        if isinstance(expr, ast.IfExp):
            return self._alias_expr(expr.body, aliases) or self._alias_expr(
                expr.orelse, aliases
            )
        return False

    def _rooted(self, expr: Optional[ast.AST], aliases: Set[str]) -> bool:
        if expr is None:
            return False
        for n in ast.walk(expr):
            if isinstance(n, ast.Name) and n.id in aliases:
                return True
            if isinstance(n, ast.Attribute) and dotted_name(n) in aliases:
                return True
        return False

    # -- guard propagation -------------------------------------------------

    def _mark(
        self,
        stmts: List[ast.stmt],
        aliases: Set[str],
        guarded: bool,
        out: Set[int],
        excluded: Set[int],
    ) -> None:
        present = guarded
        for stmt in stmts:
            if id(stmt) in excluded:
                continue
            if present:
                for sub in ast.walk(stmt):
                    if id(sub) not in excluded:
                        out.add(id(sub))
                continue
            if isinstance(stmt, ast.If):
                self._mark(
                    stmt.body, aliases,
                    _body_guarded(stmt.test, aliases), out, excluded,
                )
                nt = _none_test(stmt.test, aliases)
                else_guarded = nt is not None and not nt[1]
                self._mark(stmt.orelse, aliases, else_guarded, out, excluded)
                if (
                    _implies_present_after(stmt.test, aliases)
                    and _terminates(stmt.body)
                ):
                    present = True
                continue
            for attr in ("body", "orelse", "finalbody"):
                sub = getattr(stmt, attr, None)
                if isinstance(sub, list) and sub and isinstance(
                    sub[0], ast.stmt
                ):
                    self._mark(sub, aliases, False, out, excluded)
            for handler in getattr(stmt, "handlers", []) or []:
                self._mark(handler.body, aliases, False, out, excluded)

    def _mark_expr_guards(
        self,
        fn: ast.AST,
        aliases: Set[str],
        guarded: Set[int],
        excluded: Set[int],
    ) -> None:
        """Expression-level guards: ``x.y if x is not None else z`` and
        short-circuit chains ``x is not None and x.y`` /
        ``x is None or x.y``."""
        for n in ast.walk(fn):
            if id(n) in excluded:
                continue
            if isinstance(n, ast.IfExp):
                if _body_guarded(n.test, aliases):
                    guarded.update(id(s) for s in ast.walk(n.body))
                nt = _none_test(n.test, aliases)
                if nt is not None and not nt[1]:
                    guarded.update(id(s) for s in ast.walk(n.orelse))
            elif isinstance(n, ast.BoolOp):
                seen_guard = False
                for v in n.values:
                    if seen_guard:
                        guarded.update(id(s) for s in ast.walk(v))
                        continue
                    nt = _none_test(v, aliases)
                    if nt is not None and (
                        nt[1] if isinstance(n.op, ast.And) else not nt[1]
                    ):
                        seen_guard = True

    # -- bindings ----------------------------------------------------------

    def _outside_bindings(
        self,
        fn: ast.AST,
        guarded: Set[int],
        aliases: Set[str],
        excluded: Set[int],
    ) -> Set[str]:
        bound: Set[str] = set()
        args = fn.args
        for a in (
            list(args.args) + list(args.posonlyargs) + list(args.kwonlyargs)
        ):
            bound.add(a.arg)
        if args.vararg:
            bound.add(args.vararg.arg)
        if args.kwarg:
            bound.add(args.kwarg.arg)
        for n in ast.walk(fn):
            if id(n) in guarded or id(n) in excluded:
                continue
            targets: List[ast.AST] = []
            if isinstance(n, ast.Assign):
                targets = list(n.targets)
            elif isinstance(n, (ast.AugAssign, ast.AnnAssign)):
                targets = [n.target]
            elif isinstance(n, (ast.For, ast.AsyncFor)):
                targets = [n.target]
            elif isinstance(n, ast.withitem) and n.optional_vars:
                targets = [n.optional_vars]
            for t in targets:
                for sub in ast.walk(t):
                    if isinstance(sub, ast.Name):
                        bound.add(sub.id)
        return bound - aliases

    # -- rule bodies -------------------------------------------------------

    def _unguarded_rules(
        self, mod: ModuleInfo, qual: str, n: ast.AST, aliases: Set[str]
    ) -> Iterator[Finding]:
        if isinstance(n, ast.Attribute):
            base = n.value
            base_dn = (
                base.id if isinstance(base, ast.Name) else dotted_name(base)
            )
            full = dotted_name(n)
            if base_dn in aliases and full not in aliases:
                yield self.finding(
                    mod, n,
                    f"unguarded collector use "
                    f"`{full or f'{base_dn}.{n.attr}'}` — wrap in "
                    f"`if {base_dn} is not None:` (collector=None must be "
                    f"bitwise-uninstrumented, DESIGN §9)",
                    qual,
                )

    def _guarded_rules(
        self,
        mod: ModuleInfo,
        qual: str,
        n: ast.AST,
        aliases: Set[str],
        outside: Set[str],
    ) -> Iterator[Finding]:
        if isinstance(n, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            targets = (
                list(n.targets) if isinstance(n, ast.Assign) else [n.target]
            )
            rhs_obs = self._rooted(getattr(n, "value", None), aliases)
            for t in targets:
                if isinstance(t, ast.Name):
                    if t.id in outside and (
                        isinstance(n, ast.AugAssign) or not rhs_obs
                    ):
                        yield self.finding(
                            mod, n,
                            f"assignment to `{t.id}` (also bound outside "
                            f"the guard) inside a collector-guarded block "
                            f"— engine state must be identical with "
                            f"collector=None",
                            qual,
                        )
                elif isinstance(t, (ast.Subscript, ast.Attribute)):
                    root = t.value
                    root_dn = (
                        root.id if isinstance(root, ast.Name)
                        else dotted_name(root)
                    )
                    if root_dn not in aliases and not rhs_obs:
                        yield self.finding(
                            mod, n,
                            "store through a non-collector object inside "
                            "a collector-guarded block — engine state "
                            "must be identical with collector=None",
                            qual,
                        )
        elif isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute):
            if n.func.attr in _MUTATORS and isinstance(
                n.func.value, ast.Name
            ):
                base_dn = n.func.value.id
                if base_dn in outside and base_dn not in aliases:
                    yield self.finding(
                        mod, n,
                        f"mutating call `{base_dn}.{n.func.attr}()` on an "
                        f"engine-state object inside a collector-guarded "
                        f"block",
                        qual,
                    )
