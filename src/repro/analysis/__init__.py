"""Invariant-aware static analysis for the repro codebase.

The simulation's reproducibility story rests on source-level contracts
(DESIGN.md §5–§12): counter-based streams only, no ambient x64 flips,
tracer-pure device code, disjoint stream-key derivation constants,
bitwise-uninstrumented ``collector=None`` paths and the
``kernel.py``/``ref.py``/``ops.py`` triple per Pallas kernel.  Runtime
tests catch violations *after* they ship; this package enforces them at
the AST level, pre-merge::

    python -m repro.analysis [--format text|json] [--baseline FILE] [paths...]

Rule codes are ``RPA0xx`` (see DESIGN.md §13 for the code ↔ contract
map).  Pre-existing, justified debt lives in ``analysis-baseline.json``;
everything else fails CI.  The package is intentionally stdlib-only so
the CI job needs no jax/numpy install.
"""

from repro.analysis.core import (  # noqa: F401
    Checker,
    Finding,
    ModuleInfo,
    all_checkers,
    load_modules,
    run_checkers,
)

#: Stamped into BENCH payload ``meta`` blocks and JSON reports; bump on
#: any rule-behaviour change so artifacts record which pass produced them.
ANALYSIS_VERSION = "1.0.0"

__all__ = [
    "ANALYSIS_VERSION",
    "Checker",
    "Finding",
    "ModuleInfo",
    "all_checkers",
    "load_modules",
    "run_checkers",
]
