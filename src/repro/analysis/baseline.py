"""Baseline suppression: checked-in, justified pre-existing findings.

``analysis-baseline.json`` holds entries of the form::

    {"code": "RPA005", "path": "src/repro/kernels/x/ref.py",
     "symbol": "foo_ref", "note": "host-exact table build, not traced"}

Matching is on ``(code, path-suffix, symbol)`` — never line numbers, so
entries survive unrelated edits.  ``note`` is mandatory: an exemption
without a recorded justification is itself a finding.  Stale entries
(matching nothing) are reported so the file cannot silently rot.
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from typing import List, Sequence, Tuple

from repro.analysis.core import Finding


@dataclass(frozen=True)
class BaselineEntry:
    code: str
    path: str
    symbol: str
    note: str

    def matches(self, finding: Finding) -> bool:
        if self.code != finding.code:
            return False
        if not (
            finding.path.endswith(self.path) or self.path.endswith(finding.path)
        ):
            return False
        return self.symbol in ("*", finding.symbol)


def load_baseline(path: str) -> List[BaselineEntry]:
    with open(path, encoding="utf-8") as fh:
        payload = json.load(fh)
    entries = []
    for raw in payload.get("entries", []):
        missing = {"code", "path", "symbol", "note"} - set(raw)
        if missing:
            raise ValueError(
                f"baseline entry {raw!r} is missing {sorted(missing)} — "
                f"every exemption needs a code, location and justification"
            )
        if not str(raw["note"]).strip():
            raise ValueError(
                f"baseline entry {raw!r} has an empty note — record why "
                f"the finding is exempt"
            )
        entries.append(
            BaselineEntry(
                code=raw["code"], path=raw["path"],
                symbol=raw["symbol"], note=raw["note"],
            )
        )
    return entries


def apply_baseline(
    findings: Sequence[Finding], entries: Sequence[BaselineEntry]
) -> Tuple[List[Finding], List[Finding], List[BaselineEntry]]:
    """Split findings into (new, suppressed); also return stale entries."""
    new: List[Finding] = []
    suppressed: List[Finding] = []
    used = [False] * len(entries)
    for f in findings:
        hit = None
        for i, e in enumerate(entries):
            if e.matches(f):
                hit = i
                break
        if hit is None:
            new.append(f)
        else:
            used[hit] = True
            suppressed.append(f)
    stale = [e for i, e in enumerate(entries) if not used[i]]
    return new, suppressed, stale
