"""``--self-test``: the analysis pass checks itself before checking code.

Mirrors ``benchmarks/compare.py --self-test`` (the synthetic-regression
probe for the benchmark gate): for every rule, a minimal *violating*
snippet must fire and its *fixed twin* must stay silent, and a
synthetically corrupted stream-key constant must trip RPA006.  A
checker whose positive fixture stops firing has silently lost its
teeth — that must fail CI exactly like a real regression would.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Tuple

from repro.analysis import registry
from repro.analysis.core import ModuleInfo, all_checkers, run_checkers

# (code, violating-source, clean-twin-source, synthetic path)
FIXTURES: List[Tuple[str, str, str, str]] = [
    (
        "RPA001",
        "import numpy as np\n"
        "def jitter(n):\n"
        "    return np.random.poisson(3.0, n)\n",
        "import numpy as np\n"
        "def jitter(n, seed):\n"
        "    return np.random.default_rng(seed).poisson(3.0, n)\n",
        "repro/net/_fixture_rng.py",
    ),
    (
        "RPA002",
        "import time\n"
        "def stamp(rows):\n"
        "    return [(time.time(), r) for r in rows]\n",
        "def stamp(rows, now_s):\n"
        "    return [(now_s, r) for r in rows]\n",
        "repro/net/_fixture_clock.py",
    ),
    (
        "RPA003",
        "def total(ids):\n"
        "    out = 0.0\n"
        "    for i in set(ids):\n"
        "        out += 1.0 / (1 + i)\n"
        "    return out\n",
        "def total(ids):\n"
        "    out = 0.0\n"
        "    for i in sorted(set(ids)):\n"
        "        out += 1.0 / (1 + i)\n"
        "    return out\n",
        "repro/net/_fixture_set.py",
    ),
    (
        "RPA004",
        "import jax\n"
        "jax.config.update(\"jax_enable_x64\", True)\n",
        "from jax.experimental import enable_x64\n"
        "def run(fn):\n"
        "    with enable_x64():\n"
        "        return fn()\n",
        "repro/net/_fixture_x64.py",
    ),
    (
        "RPA005",
        "import jax.numpy as jnp\n"
        "def scale_ref(x, lim):\n"
        "    if x > lim:\n"
        "        return float(x)\n"
        "    return jnp.minimum(x, lim)\n",
        "import jax.numpy as jnp\n"
        "def scale_ref(x, lim):\n"
        "    return jnp.where(x > lim, x, jnp.minimum(x, lim))\n",
        "repro/kernels/_fixture_tracer.py",
    ),
    (
        "RPA007",
        "def simulate(state, collector):\n"
        "    if collector is not None:\n"
        "        collector.event(\"round\")\n"
        "        state = state + 1\n"
        "    return state\n",
        "def simulate(state, collector):\n"
        "    if collector is not None:\n"
        "        collector.event(\"round\", state=state)\n"
        "    return state + 1\n",
        "repro/net/_fixture_collector.py",
    ),
]


def _mod(path: str, source: str) -> ModuleInfo:
    return ModuleInfo(path=path, tree=ast.parse(source), source=source)


def run_self_test(verbose: bool = True) -> int:
    """0 on success; prints one line per probe like compare.py's."""
    failures = 0

    def report(ok: bool, label: str) -> None:
        nonlocal failures
        if not ok:
            failures += 1
        if verbose or not ok:
            print(f"self-test {'ok  ' if ok else 'FAIL'}: {label}")

    for code, bad_src, good_src, path in FIXTURES:
        checkers = all_checkers(select=[code])
        bad = run_checkers([_mod(path, bad_src)], checkers)
        good = run_checkers([_mod(path, good_src)], checkers)
        report(
            any(f.code == code for f in bad),
            f"{code} fires on its violating fixture",
        )
        report(
            not good,
            f"{code} stays silent on the fixed twin"
            + (f" (got: {good[0].message})" if good else ""),
        )

    # RPA006: corrupt one Weyl constant of a synthetic two-module anchor
    # set so the duplicate-detection path is exercised end to end.
    ref_src = (
        "KEY_WEYL_0 = 0x9E3779B9\n"
        "KEY_WEYL_1 = 0x85EBCA6B\n"
        "_C240 = 0x1BD11BDA\n"
    )
    fault_ok = (
        "_CLASS_WEYL_0 = 0x9E3779B1\n"
        "_CLASS_WEYL_1 = 0x85EBCA77\n"
        "_CASE_WEYL = 0x6C8E9CF5\n"
        "_PON_WEYL_0 = 0xCC9E2D51\n"
        "_PON_WEYL_1 = 0x1B873593\n"
        "_JOB_WEYL_0 = 0xC2B2AE35\n"
        "_JOB_WEYL_1 = 0x27D4EB2F\n"
    )
    # corruption: the fault-class constant collides with KEY_WEYL_0
    fault_bad = fault_ok.replace("0x9E3779B1", "0x9E3779B9")
    checkers = all_checkers(select=["RPA006"])
    clean = run_checkers(
        [
            _mod("repro/kernels/traffic/ref.py", ref_src),
            _mod("repro/faults/streams.py", fault_ok),
        ],
        checkers,
    )
    corrupt = run_checkers(
        [
            _mod("repro/kernels/traffic/ref.py", ref_src),
            _mod("repro/faults/streams.py", fault_bad),
        ],
        checkers,
    )
    report(not clean, "RPA006 passes a disjoint synthetic registry")
    report(
        any("duplicate" in f.message for f in corrupt),
        "RPA006 flags a corrupted (colliding) stream-key constant",
    )
    even = run_checkers(
        [
            _mod("repro/kernels/traffic/ref.py", ref_src),
            _mod(
                "repro/faults/streams.py",
                fault_ok.replace("0x6C8E9CF5", "0x6C8E9CF4"),
            ),
        ],
        checkers,
    )
    report(
        any("even" in f.message for f in even),
        "RPA006 flags an even Weyl increment",
    )

    # RPA008: a kernel package missing its oracle must be flagged
    triple: Dict[str, str] = {
        "repro/kernels/fake/__init__.py": "",
        "repro/kernels/fake/kernel.py": (
            "def op_fwd(x, block):\n    return x\n"
        ),
        "repro/kernels/fake/ops.py": "def op(x, block):\n    return x\n",
    }
    checkers = all_checkers(select=["RPA008"])
    missing = run_checkers(
        [_mod(p, s) for p, s in triple.items()], checkers
    )
    full = run_checkers(
        [_mod(p, s) for p, s in triple.items()]
        + [
            _mod(
                "repro/kernels/fake/ref.py",
                "def op_ref(x, block):\n    return x\n",
            )
        ],
        checkers,
    )
    report(
        any("missing" in f.message for f in missing),
        "RPA008 flags a kernel package without ref.py",
    )
    report(not full, "RPA008 passes a complete conforming triple")

    # registry sanity: the validator itself must reject a duplicate
    consts = [
        registry.StreamConstant("a.py", "A_WEYL", 0x9E3779B9, 1),
        registry.StreamConstant("b.py", "B_WEYL", 0x9E3779B9, 1),
    ]
    report(
        bool(registry.validate_constants(consts)),
        "registry validator rejects duplicated constants",
    )

    if failures:
        print(f"self-test: {failures} probe(s) FAILED")
        return 1
    print("self-test: all probes passed")
    return 0
