"""Checkpoint substrate: atomic msgpack checkpoints + lifecycle manager."""
from repro.checkpoint.checkpoint import (  # noqa: F401
    AsyncWriter,
    CheckpointCorruption,
    load,
    save,
)
from repro.checkpoint.manager import CheckpointManager  # noqa: F401
