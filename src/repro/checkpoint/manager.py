"""Checkpoint lifecycle: rotation, latest-valid discovery, resume."""
from __future__ import annotations

import glob
import os
import re
from typing import Any, Dict, List, Optional, Tuple

from repro.checkpoint.checkpoint import (
    AsyncWriter,
    CheckpointCorruption,
    load,
    save,
)

_STEP_RE = re.compile(r"step_(\d+)\.ckpt$")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, use_async: bool = True):
        self.directory = directory
        self.keep = keep
        self.writer = AsyncWriter() if use_async else None
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step}.ckpt")

    def all_steps(self) -> List[int]:
        steps = []
        for p in glob.glob(os.path.join(self.directory, "step_*.ckpt")):
            m = _STEP_RE.search(p)
            if m:
                steps.append(int(m.group(1)))
        return sorted(steps)

    def save(self, step: int, tree: Any, metadata: Optional[Dict] = None):
        meta = dict(metadata or {})
        meta["step"] = step
        path = self._path(step)
        if self.writer:
            self.writer.save(path, tree, meta)
        else:
            save(path, tree, meta)
        self._rotate()

    def wait(self):
        if self.writer:
            self.writer.wait()

    def _rotate(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            try:
                os.remove(self._path(s))
            except OSError:
                pass

    def restore_latest(
        self, like: Any = None, shardings: Any = None
    ) -> Optional[Tuple[Any, Dict]]:
        """Restore the newest checkpoint that passes validation; corrupt ones
        are skipped (fault tolerance for crashes mid-write or disk faults)."""
        self.wait()
        for step in reversed(self.all_steps()):
            try:
                return load(self._path(step), like=like, shardings=shardings)
            except (CheckpointCorruption, OSError, ValueError):
                continue
        return None
