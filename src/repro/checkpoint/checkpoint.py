"""Fault-tolerant checkpointing: atomic, checksummed, background-capable.

Format: one msgpack file holding a manifest (tree structure, shapes, dtypes,
crc32 per leaf, user metadata) + raw little-endian buffers. Writes go to a
temp file in the same directory and are atomically renamed, so a crash
mid-write never corrupts the latest checkpoint. Restore verifies checksums
and can re-shard onto a *different* mesh than the one that saved (elastic
restart across topology changes).
"""
from __future__ import annotations

import os
import threading
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_FORMAT_VERSION = 2


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
             for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save(path: str, tree: Any, metadata: Optional[Dict] = None) -> None:
    """Atomically write ``tree`` (pytree of arrays) to ``path``."""
    paths, leaves, _ = _flatten_with_paths(tree)
    record = {
        "version": _FORMAT_VERSION,
        "metadata": metadata or {},
        "leaves": [],
    }
    buffers = []
    for p, leaf in zip(paths, leaves):
        arr = np.asarray(jax.device_get(leaf))
        buf = arr.tobytes()
        record["leaves"].append(
            {
                "path": p,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),   # name survives bf16 (ml_dtypes)
                "crc32": zlib.crc32(buf),
                "nbytes": len(buf),
            }
        )
        buffers.append(buf)
    payload = msgpack.packb(record, use_bin_type=True)
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(len(payload).to_bytes(8, "little"))
        f.write(payload)
        for buf in buffers:
            f.write(buf)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)   # atomic on POSIX


class CheckpointCorruption(RuntimeError):
    pass


def load(path: str, like: Any = None,
         shardings: Any = None) -> Tuple[Any, Dict]:
    """Load a checkpoint. If ``like`` is given, restore into its tree
    structure (paths must match); ``shardings`` (same structure) re-shards
    leaves on restore — enabling elastic restarts onto a different mesh.
    Returns (tree, metadata)."""
    with open(path, "rb") as f:
        header_len = int.from_bytes(f.read(8), "little")
        record = msgpack.unpackb(f.read(header_len), raw=False)
        arrays = {}
        for entry in record["leaves"]:
            buf = f.read(entry["nbytes"])
            if zlib.crc32(buf) != entry["crc32"]:
                raise CheckpointCorruption(
                    f"crc mismatch for leaf {entry['path']!r} in {path}"
                )
            arrays[entry["path"]] = np.frombuffer(
                buf, dtype=jnp.dtype(entry["dtype"])
            ).reshape(entry["shape"])

    if like is None:
        # return a flat dict when no structure is provided
        return arrays, record["metadata"]

    paths, leaves, treedef = _flatten_with_paths(like)
    missing = [p for p in paths if p not in arrays]
    if missing:
        raise CheckpointCorruption(f"missing leaves in {path}: {missing[:5]}")
    shard_leaves = (
        jax.tree.leaves(shardings, is_leaf=lambda s: s is None or hasattr(s, "spec"))
        if shardings is not None
        else [None] * len(paths)
    )
    out = []
    for p, ref, shard in zip(paths, leaves, shard_leaves):
        arr = arrays[p].astype(ref.dtype) if hasattr(ref, "dtype") else arrays[p]
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out), record["metadata"]


class AsyncWriter:
    """Single-slot background writer: training never blocks on I/O.

    A new save while the previous one is in flight waits for it (bounded
    memory) — the standard single-buffer async checkpoint pattern.
    """

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, path: str, tree: Any, metadata: Optional[Dict] = None):
        self.wait()
        # device_get NOW so training can mutate params right after return
        host_tree = jax.tree.map(lambda l: np.asarray(jax.device_get(l)), tree)

        def _run():
            try:
                save(path, host_tree, metadata)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
