"""Static analysis of optimised (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, which makes
it useless for scan-over-layers models. This module re-derives the roofline
inputs directly from the HLO text, with loop-trip multipliers:

* ``flops``       — 2 x prod(result) x prod(contracting dims) per dot op
                    (matmuls dominate every model here; elementwise flops are
                    reported separately by XLA's own counter);
* ``hbm_bytes``   — operand + result bytes of every top-level op in traffic
                    computations (entry, while bodies/conds, branches):
                    fusion boundaries are exactly XLA's HBM-traffic model;
* ``collectives`` — result bytes per collective kind.

Trip counts come from each while op's ``known_trip_count`` backend config
(exact for lax.scan); the per-depth fallback list covers the rare unpinned
loop. Async -start/-done pairs are counted once. Fusion-internal traffic is
invisible by construction (that is XLA's own HBM model); dynamic-(update-)
slice is counted at slice granularity (aliased in place).
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
)

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$"
)
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERAND = re.compile(r"%([\w.\-]+)")
_SKIP_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "bitcast-convert", "iota", "after-all", "partition-id", "replica-id",
    # control ops: their bodies' ops are accounted directly
    "while", "conditional", "call",
}


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _first_shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE.search(type_str)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",") if d] if dims else []


class HloModule:
    def __init__(self, text: str):
        # computation -> list of (name, result_type, op, rest_of_line)
        self.comps: Dict[str, List[Tuple[str, str, str, str]]] = {}
        self.symbols: Dict[str, str] = {}        # instr name -> result type
        self.while_callees: Dict[str, set] = {}  # loop-entered computations
        self.trip_counts: Dict[str, int] = {}    # body/cond comp -> known trip
        self.fusion_callees: set = set()
        self.branch_callees: set = set()
        current = None
        for raw in text.splitlines():
            line = raw.strip()
            hm = _COMP_HEADER.match(line)
            if hm and "=" not in line.split("(")[0]:
                current = hm.group(1)
                self.comps.setdefault(current, [])
                continue
            if current is None or not line or line == "}":
                continue
            im = _INSTR.match(line)
            if not im:
                continue
            name, rtype, op, rest = im.groups()
            self.comps[current].append((name, rtype, op, rest))
            self.symbols[name] = rtype
            if op == "while":
                tc = re.search(r'"known_trip_count":\{"n":"(\d+)"', rest)
                trip = int(tc.group(1)) if tc else None
                for key in ("body", "condition"):
                    m = re.search(rf"{key}=%?([\w.\-]+)", rest)
                    if m:
                        self.while_callees.setdefault(current, set()).add(
                            m.group(1)
                        )
                        if trip is not None:
                            self.trip_counts[m.group(1)] = trip
            for m in re.finditer(r"calls=%?([\w.\-]+)", rest):
                self.fusion_callees.add(m.group(1))
            for m in re.finditer(r"branch_computations=\{([^}]*)\}", rest):
                for name2 in _OPERAND.findall(m.group(1)):
                    self.branch_callees.add(name2)
                for name2 in re.findall(r"([\w.\-]+)", m.group(1)):
                    self.branch_callees.add(name2)

    def multipliers(self, trips) -> Dict[str, int]:
        """computation -> execution multiplier.

        Trip counts come from the HLO's own ``known_trip_count`` backend
        config when present (exact); ``trips`` (per nesting depth, deeper
        loops reuse the last entry) is the fallback.
        """
        if isinstance(trips, int):
            trips = [trips]
        trips = list(trips) or [1]
        # entry is conventionally the LAST computation in HLO text
        entry = list(self.comps.keys())[-1]
        mult = {entry: 1}
        depth = {entry: 0}
        frontier = [entry]
        while frontier:
            comp = frontier.pop()
            m = mult[comp]
            d = depth[comp]
            fallback = trips[min(d, len(trips) - 1)]
            for callee in self.while_callees.get(comp, ()):  # loop body/cond
                trip = self.trip_counts.get(callee, fallback)
                nm = m * trip
                if mult.get(callee, 0) < nm:
                    mult[callee] = nm
                    depth[callee] = d + 1
                    frontier.append(callee)
            # walk branches at same multiplicity and depth
            for _, _, op, rest in self.comps.get(comp, ()):
                if op == "conditional":
                    for cal in re.findall(r"([\w.\-]+)", rest):
                        if cal in self.comps and cal not in mult:
                            mult[cal] = m
                            depth[cal] = d
                            frontier.append(cal)
        return mult


def analyze(text: str, loop_trips=(1,)) -> Dict:
    mod = HloModule(text)
    mult = mod.multipliers(loop_trips)

    flops = 0.0
    dot_count = 0
    for comp, instrs in mod.comps.items():
        # dots inside fusion computations execute as part of the fusion's
        # computation: give them the multiplier of any caller context.
        m = mult.get(comp)
        if m is None:
            # fusion-internal computation: inherit loop membership by name
            # lookup through the call graph — approximate with trip if ANY
            # loop body calls it.
            m = None
        for name, rtype, op, rest in instrs:
            if op != "dot":
                continue
            dot_count += 1
            result_dims = _first_shape_dims(rtype) or []
            operands = _OPERAND.findall(rest.split(")", 1)[0])
            lhs_type = mod.symbols.get(operands[0], "") if operands else ""
            lhs_dims = _first_shape_dims(lhs_type) or []
            cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
            contract = 1
            if cm and lhs_dims:
                for idx in cm.group(1).split(","):
                    if idx and int(idx) < len(lhs_dims):
                        contract *= lhs_dims[int(idx)]
            n_out = 1
            for d in result_dims:
                n_out *= d
            eff_m = m if m is not None else _fusion_multiplier(
                mod, comp, mult
            )
            flops += 2.0 * n_out * contract * eff_m

    hbm_bytes = 0.0
    traffic_comps = {c: m for c, m in mult.items()}
    for comp, m in traffic_comps.items():
        for name, rtype, op, rest in mod.comps.get(comp, ()):
            if op in _SKIP_OPS:
                continue
            operands = _OPERAND.findall(rest.split(")", 1)[0])
            if op == "dynamic-update-slice":
                # aliased in-place: traffic = the updated slice (read+write)
                upd = operands[1] if len(operands) > 1 else None
                nbytes = 2 * _type_bytes(mod.symbols.get(upd, ""))
            elif op == "dynamic-slice":
                nbytes = 2 * _type_bytes(rtype)
            else:
                nbytes = _type_bytes(rtype)
                for o in operands:
                    nbytes += _type_bytes(mod.symbols.get(o, ""))
                if op == "fusion":
                    # a fusion whose root is dynamic-update-slice aliases the
                    # big buffer in place: count the updated slice, not the
                    # full buffer on both sides.
                    cm2 = re.search(r"calls=%?([\w.\-]+)", rest)
                    fused = mod.comps.get(cm2.group(1), []) if cm2 else []
                    dus = [i for i in fused if i[2] == "dynamic-update-slice"]
                    if dus:
                        rb = _type_bytes(rtype)
                        for o in operands:
                            if _type_bytes(mod.symbols.get(o, "")) == rb:
                                nbytes -= 2 * rb
                                break
                        for d in dus:
                            u_ops = _OPERAND.findall(d[3].split(")", 1)[0])
                            upd = u_ops[1] if len(u_ops) > 1 else None
                            nbytes += 2 * _type_bytes(
                                mod.symbols.get(upd, "")
                            )
                        nbytes = max(nbytes, 0)
            hbm_bytes += nbytes * m

    per_kind: Dict[str, float] = {}
    count = 0
    for comp, instrs in mod.comps.items():
        m = mult.get(comp)
        if m is None:
            m = _fusion_multiplier(mod, comp, mult)
        for name, rtype, op, rest in instrs:
            base = op.replace("-start", "")
            if base in COLLECTIVE_KINDS and not op.endswith("-done"):
                per_kind[base] = per_kind.get(base, 0.0) + _type_bytes(rtype) * m
                count += 1
    return {
        "flops": flops,
        "dot_count": dot_count,
        "hbm_bytes": hbm_bytes,
        "collectives": {
            "per_kind": per_kind,
            "total_bytes": sum(per_kind.values()),
            "static_op_count": count,
        },
    }


def _fusion_multiplier(mod: HloModule, comp: str, mult: Dict[str, int]) -> int:
    """Multiplier for a fusion-internal computation: that of its caller."""
    for caller, instrs in mod.comps.items():
        cm = mult.get(caller)
        if cm is None:
            continue
        for _, _, _, rest in instrs:
            if re.search(rf"calls=%?{re.escape(comp)}\b", rest):
                return cm
    # not found at top level: assume loop membership is unknown -> 1
    return 1
