"""ShapeDtypeStruct input specs for every (arch x input-shape x step).

No device allocation anywhere: parameter/optimizer/cache shapes come from
``jax.eval_shape`` over the real init functions, then get NamedShardings from
``repro.dist.sharding``. This is what the dry-run lowers.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.dist import sharding as shd
from repro.dist.stepfns import TrainState, init_fed_state, init_train_state
from repro.models import lm
from repro.optim.optimizers import OptimizerConfig


def _sds(shape, dtype, mesh, spec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=NamedSharding(mesh, spec)
    )


def _with_shardings(shape_tree, sharding_tree):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shape_tree,
        sharding_tree,
    )


# ---------------------------------------------------------------------------
# state specs
# ---------------------------------------------------------------------------


def state_shapes(cfg: ModelConfig, opt_cfg: OptimizerConfig,
                 n_pods: int = 0) -> TrainState:
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    if n_pods:
        return jax.eval_shape(
            partial(init_fed_state, cfg=cfg, opt_cfg=opt_cfg, n_pods=n_pods),
            key,
        )
    return jax.eval_shape(
        partial(init_train_state, cfg=cfg, opt_cfg=opt_cfg), key
    )


def state_spec_tree(state_shape: TrainState, cfg: ModelConfig, mesh,
                    fed: bool = False) -> TrainState:
    """PartitionSpec tree matching a TrainState shape-tree."""
    strip = 1 if fed else 0

    def despecced(leaf_shape):
        return jax.ShapeDtypeStruct(
            leaf_shape.shape[strip:], leaf_shape.dtype
        )

    def podded(spec: P) -> P:
        return P(*(("pod",) + tuple(spec))) if fed else spec

    params_inner = jax.tree.map(despecced, state_shape.params)
    p_specs = shd.param_specs(params_inner, cfg, mesh)
    p_specs = jax.tree.map(podded, p_specs, is_leaf=lambda x: isinstance(x, P))

    def moment_specs(tree):
        inner = jax.tree.map(despecced, tree)
        specs = shd.opt_moment_specs(inner, cfg, mesh)
        return jax.tree.map(podded, specs, is_leaf=lambda x: isinstance(x, P))

    opt_specs = type(state_shape.opt)(
        step=P("pod") if fed else P(),
        mu=moment_specs(state_shape.opt.mu),
        nu=moment_specs(state_shape.opt.nu),
    )
    return TrainState(params=p_specs, opt=opt_specs)


def state_specs(cfg: ModelConfig, opt_cfg: OptimizerConfig, mesh,
                fed: bool = False, n_pods: int = 0):
    """Returns (state ShapeDtypeStruct tree w/ shardings, sharding tree)."""
    shapes = state_shapes(cfg, opt_cfg, n_pods if fed else 0)
    specs = state_spec_tree(shapes, cfg, mesh, fed=fed)
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )
    return _with_shardings(shapes, shardings), shardings


# ---------------------------------------------------------------------------
# batch / serving input specs
# ---------------------------------------------------------------------------


def train_batch_specs(cfg: ModelConfig, shape: InputShape, mesh,
                      fed: bool = False, n_pods: int = 0) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    n_front = cfg.n_frontend_tokens
    s_text = S - n_front
    bspec = shd.batch_spec(mesh, B)
    batch = {
        "tokens": _sds((B, s_text), jnp.int32, mesh, bspec),
        "labels": _sds((B, s_text), jnp.int32, mesh, bspec),
    }
    if cfg.frontend:
        fspec = P(*(tuple(bspec) + (None, None))) if tuple(bspec) else P()
        batch["extra_embeds"] = _sds(
            (B, n_front, cfg.d_model), jnp.dtype(cfg.dtype), mesh, fspec
        )
    if fed:
        def podify(sds):
            per_pod = sds.shape[0] // n_pods
            data_ok = (
                "data" in mesh.axis_names
                and per_pod % mesh.shape["data"] == 0
            )
            spec = P("pod", "data" if data_ok else None,
                     *((None,) * (len(sds.shape) - 1)))
            return _sds((n_pods, per_pod) + sds.shape[1:], sds.dtype, mesh,
                        spec)

        batch = {k: podify(v) for k, v in batch.items()}
    return batch


def cache_specs(cfg: ModelConfig, batch: int, max_len: int, mesh):
    shapes = jax.eval_shape(
        partial(lm.init_cache, cfg, batch, max_len)
    )
    spec_tree = shd.cache_specs(shapes, cfg, mesh, batch)
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
    return _with_shardings(shapes, shardings), shardings


def decode_input_specs(cfg: ModelConfig, shape: InputShape, mesh):
    """(params..., token, cache) for decode_step; token at position seq_len-1."""
    B = shape.global_batch
    cache, cache_shardings = cache_specs(cfg, B, shape.seq_len, mesh)
    token = _sds((B, 1), jnp.int32, mesh, shd.batch_spec(mesh, B))
    return token, cache, cache_shardings


def prefill_input_specs(cfg: ModelConfig, shape: InputShape, mesh):
    B, S = shape.global_batch, shape.seq_len
    n_front = cfg.n_frontend_tokens
    bspec = shd.batch_spec(mesh, B)
    tokens = _sds((B, S - n_front), jnp.int32, mesh, bspec)
    cache, cache_shardings = cache_specs(cfg, B, S, mesh)
    extra = None
    if cfg.frontend:
        fspec = P(*(tuple(bspec) + (None, None))) if tuple(bspec) else P()
        extra = _sds((B, n_front, cfg.d_model), jnp.dtype(cfg.dtype), mesh,
                     fspec)
    return tokens, cache, cache_shardings, extra
