"""End-to-end federated LM training driver (CPU-scale; TPU-shaped).

Runs the full production stack on whatever devices exist: config-driven
model, sharded train step, federated pod-axis rounds (FedAvg with optional
int8 round compression), BS-timed rounds via the PON co-simulation,
checkpoint/restart. This is the driver the examples call; on a real fleet
only the mesh constructor changes.

Usage:
  python -m repro.launch.train --arch olmo-1b --smoke --steps 50 \
      --rounds 5 --ckpt-dir /tmp/ckpt

Observability: ``--log-jsonl PATH`` writes every console line as a
structured JSON event (the console stays a formatted view of the same
events) plus per-round records and a final metrics summary;
``--trace PATH`` writes a Chrome-trace JSON of the run's spans
(open in Perfetto / chrome://tracing).
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core.slicing import ClientProfile
from repro.data import TokenBatcher, lm_tokens
from repro.dist import stepfns
from repro.faults import FaultSchedule
from repro.launch.mesh import make_host_mesh
from repro.net.api import SweepSpec, simulate
from repro.net.engine import SweepCase
from repro.net.jobs import JobSpec, make_competing_jobs
from repro.net.multi_pon import MultiPonTopology
from repro.net.sim import FLRoundWorkload, PONConfig
from repro.net.timeline import TimelineSchedule
from repro.optim.optimizers import OptimizerConfig
from repro.optim.schedules import warmup_cosine


def train(
    arch: str = "olmo-1b",
    smoke: bool = True,
    steps_per_round: int = 20,
    rounds: int = 3,
    n_pods: int = 2,
    global_batch: int = 8,
    seq_len: int = 64,
    lr: float = 3e-3,
    ckpt_dir: Optional[str] = None,
    policy: str = "bs",
    load: float = 0.8,
    compress: str = "int8",
    log_every: int = 10,
    config_overrides: Optional[dict] = None,
    n_pons: int = 1,
    cps_gbps: Optional[float] = None,
    deadline_s: Optional[float] = None,
    deadline_policy: str = "defer",
    async_buffer: Optional[int] = None,
    log_jsonl: Optional[str] = None,
    trace_path: Optional[str] = None,
    collector=None,
    resume: bool = True,
    dropout_rate: float = 0.0,
    outage_rate: float = 0.0,
    loss_rate: float = 0.0,
    fault_seed: int = 0,
    quorum: Optional[float] = None,
    jobs: int = 0,
    fairness: str = "maxmin",
):
    from repro.obs import Collector, EventLog, SpanTracer
    from repro.obs.trace import maybe_span

    if jobs > 0 and (deadline_s is not None or async_buffer is not None
                     or quorum is not None or dropout_rate > 0.0
                     or outage_rate > 0.0 or loss_rate > 0.0):
        raise ValueError(
            "--jobs contention runs plain rounds: deadlines, async "
            "buffering, fault injection and quorum are single-tenant "
            "features (per-job deadlines go through JobSpec.deadline_s)"
        )

    cfg = get_config(arch, smoke=smoke).replace(grad_accum=1)
    if config_overrides:
        cfg = cfg.replace(**config_overrides)
    opt_cfg = OptimizerConfig(name="adamw", lr=lr)
    schedule = warmup_cosine(lr, 20, steps_per_round * rounds)

    log = EventLog(jsonl_path=log_jsonl)
    if collector is None and (log_jsonl or trace_path):
        collector = Collector(
            tracer=SpanTracer(enabled=trace_path is not None)
        )

    n_dev = jax.device_count()
    pods = n_pods if n_dev % n_pods == 0 and n_dev >= n_pods else 1
    mesh = make_host_mesh(model_parallel=1, pods=pods) if pods > 1 else (
        make_host_mesh(model_parallel=1)
    )
    log.emit("mesh", echo="mesh: {shape} devices={devices}",
             shape=dict(mesh.shape), devices=n_dev, arch=arch,
             pods=pods, policy=policy, load=load)

    # federated data: one disjoint shard per pod
    tokens = lm_tokens(400_000, cfg.vocab_size, seed=0)
    batchers = [
        TokenBatcher(tokens, global_batch // max(pods, 1), seq_len,
                     seed=i, pod_index=i, n_pods=max(pods, 1))
        for i in range(max(pods, 1))
    ]
    iters = [iter(b) for b in batchers]

    with mesh:
        fed = pods > 1
        if fed:
            state = stepfns.init_fed_state(
                jax.random.PRNGKey(0), cfg, opt_cfg, pods
            )
            step = jax.jit(stepfns.make_fed_train_step(cfg, opt_cfg, schedule))
            round_step = jax.jit(
                stepfns.make_fed_round_step(cfg, compress=compress)
            )
        else:
            state = stepfns.init_train_state(
                jax.random.PRNGKey(0), cfg, opt_cfg
            )
            step = jax.jit(stepfns.make_train_step(cfg, opt_cfg, schedule))
            round_step = None

        # deadline/async rounds: not every pod's update reaches every
        # aggregation — the buffered staleness-weighted round step is
        # driven from the simulated arrivals instead of the plain
        # FedAvg. Built BEFORE restore so the checkpoint template
        # matches what gets saved (train + async state as one tree).
        coupled = fed and (deadline_s is not None or async_buffer is not None)
        if coupled:
            astate = stepfns.init_async_state(state)
            around = jax.jit(
                stepfns.make_async_round_step(
                    cfg, compress=compress, quorum_frac=quorum,
                    quorum_expected=pods if quorum is not None else None,
                )
            )

        mgr = CheckpointManager(ckpt_dir, keep=2) if ckpt_dir else None
        start_round = 0
        if mgr is not None and resume:
            template = {"train": state, "async": astate} if coupled else state
            restored = mgr.restore_latest(like=template)
            if restored is not None:
                tree, meta = restored
                if coupled:
                    state, astate = tree["train"], tree["async"]
                else:
                    state = tree
                start_round = int(meta.get("round", 0))
                log.emit("resume", echo="resumed from round {round}",
                         round=start_round)
                # fast-forward the deterministic data streams to where
                # the checkpointed run stopped — a resumed run must
                # consume the same batch sequence as an uninterrupted
                # one (TokenBatcher is a pure function of its seed)
                for _ in range(start_round * steps_per_round):
                    for g in iters:
                        next(g)

        # PON timing for the round (the paper's co-simulation); the slice
        # is sized for the measured payloads, not the paper's CNN
        # constant: compressed per-pod uplink, fp32 broadcast downlink
        up_bits = float(stepfns.fed_update_bits(cfg, compress))
        down_bits = float(stepfns.fed_update_bits(cfg, "none"))
        log.emit("payload", compress=compress, upload_bits=up_bits,
                 model_bits=down_bits)
        rng = np.random.default_rng(0)
        profiles = [
            ClientProfile(client_id=i, t_ud=float(t), t_dl=0.0,
                          m_ud_bits=up_bits)
            for i, t in enumerate(rng.uniform(1.0, 5.0, max(pods, 2)))
        ]
        # several OLT/wavelength segments sharing a CPS uplink: the PON
        # config describes ONE segment. Client i sits on global ONU
        # i % (n_pons * n_onus) with PON = onu // n_onus, so spreading
        # the pods over the stack needs n_onus = ceil(pods / n_pons)
        # exactly (any larger floor would cluster them on PON 0).
        # competitor jobs (--jobs) add 2 clients each above the pods,
        # so the ONU stack must cover the whole tenant population
        n_clients = max(pods, 2) + 2 * max(jobs, 0)
        if n_pons > 1:
            pon = PONConfig(n_onus=max(1, -(-n_clients // n_pons)))
        else:
            pon = PONConfig(n_onus=max(8, n_clients))
        topology = None
        if n_pons > 1 or cps_gbps is not None:
            topology = MultiPonTopology(
                n_pons=n_pons,
                cps_rate_bps=None if cps_gbps is None else cps_gbps * 1e9,
            )
        # one stacked multi-round timeline provides every round's sync
        # time (per-round arrival streams, not one number reused R times);
        # deadlines/async cut rounds short and hand arrivals + staleness
        # to the aggregation step below. ALWAYS the full schedule, even
        # on resume: round r's counter streams are keyed by r, so a
        # resumed run replays the identical network realization and
        # lands on the same final params as an uninterrupted one.
        faults = None
        if dropout_rate > 0.0 or outage_rate > 0.0 or loss_rate > 0.0:
            faults = FaultSchedule(
                seed=fault_seed, dropout_rate=dropout_rate,
                outage_rate=outage_rate, loss_rate=loss_rate,
            )
        job_specs = None
        if jobs > 0:
            # the pods' FL task becomes tenant job 0; --jobs competitor
            # jobs (half-size models, 2 clients each) contend with it
            # under --fairness inside the same PON/CPS cycle
            comp, extra = make_competing_jobs(
                [p.client_id for p in profiles], down_bits, jobs
            )
            job_specs = (JobSpec(
                job_id=0,
                clients=tuple(p.client_id for p in profiles),
                model_bits=down_bits,
            ),) + comp
            profiles = profiles + list(extra)
            log.emit("jobs", echo="tenant jobs: {n} competitors "
                     "(fairness={fairness})", n=jobs, fairness=fairness)
        wl = FLRoundWorkload(clients=profiles, model_bits=down_bits)
        n_net_rounds = max(rounds, 1)
        net_spec = SweepSpec(
            cases=(SweepCase(workload=wl, load=load, policy=policy,
                             seed=0, topology=topology, jobs=job_specs,
                             fairness=fairness),),
            pon=pon,
            schedule=TimelineSchedule(n_rounds=n_net_rounds,
                                      deadline_s=deadline_s,
                                      deadline_policy=deadline_policy,
                                      buffer_k=async_buffer,
                                      faults=faults,
                                      quorum_frac=quorum),
        )
        with maybe_span(collector, "net:timeline", rounds=n_net_rounds):
            timeline = simulate(net_spec, collector=collector)[0]
        if job_specs is not None:
            # the pods' wall clock follows THEIR job's sync time; the
            # competitors only show up as contention
            sync_times = np.array([
                rnd.job_sync.get(0, rnd.sync_time)
                for rnd in timeline.rounds
            ])
        else:
            sync_times = timeline.sync_times

        wall_simulated = 0.0
        # pods whose failed upload is retrying (they re-enter the
        # timeline as carriers and must NOT re-snapshot their payload);
        # replayed over the pre-resume rounds so a resumed run holds
        # the same fault bookkeeping as an uninterrupted one
        in_retry: set = set()
        for rn in timeline.rounds[:start_round]:
            in_retry |= set(rn.failed) | set(rn.lost)
            in_retry -= set(rn.arrived) | set(rn.gave_up)
        history = []
        for rnd in range(start_round, rounds):
            t0 = time.time()
            losses = []
            for it in range(steps_per_round):
                if fed:
                    parts = [next(g) for g in iters]
                    batch = {
                        k: jnp.stack([jnp.asarray(p[k]) for p in parts])
                        for k in parts[0]
                    }
                else:
                    batch = {
                        k: jnp.asarray(v) for k, v in next(iters[0]).items()
                    }
                state, metrics = step(state, batch)
                loss = float(jnp.mean(metrics["loss"]))
                losses.append(loss)
                if it % log_every == 0:
                    log.emit(
                        "step",
                        echo="round {round} step {step}: loss={loss:.4f}",
                        round=rnd, step=it, loss=loss,
                    )
            if fed:
                weights = jnp.ones((pods,), jnp.float32)
                if coupled:
                    idx = min(rnd, len(timeline.rounds) - 1)
                    rn = timeline.rounds[idx]
                    prev_def = (timeline.rounds[idx - 1].deferred
                                if idx > 0 else {})
                    # a retry join is in ul_bits but not a fresh entry:
                    # it re-sends its snapshotted payload unchanged
                    fresh = set(rn.ul_bits) - set(prev_def) - in_retry
                    contrib = {cid: 1.0 for cid in rn.arrived}
                    contrib.update({cid: f for cid, f in rn.partial.items()
                                    if f > 0.0})
                    arrived = np.zeros(pods, bool)
                    stale = np.zeros(pods, np.int32)
                    fracs = np.ones(pods, np.float32)
                    snap = np.zeros(pods, bool)
                    rejoin = np.zeros(pods, bool)
                    for cid in range(pods):
                        snap[cid] = cid in fresh
                        if cid in contrib:
                            arrived[cid] = True
                            fracs[cid] = contrib[cid]
                            stale[cid] = rn.staleness.get(cid, 0)
                        # every cut pod re-enters fresh — including a
                        # partial pod whose served fraction was 0 (its
                        # update is discarded exactly like a drop) and
                        # a pod that gave up on its retries
                        if (cid in contrib or cid in rn.dropped
                                or cid in rn.partial
                                or cid in rn.gave_up):
                            rejoin[cid] = True
                    state, astate = around(
                        state, astate, weights, jnp.asarray(arrived),
                        jnp.asarray(stale), jnp.asarray(fracs),
                        jnp.asarray(snap), jnp.asarray(rejoin),
                    )
                    in_retry |= set(rn.failed) | set(rn.lost)
                    in_retry -= set(rn.arrived) | set(rn.gave_up)
                else:
                    state = round_step(state, weights)
            sync = float(sync_times[min(rnd, len(sync_times) - 1)])
            wall_simulated += sync
            entry = {"round": rnd, "loss": float(np.mean(losses)),
                     "sync_s": sync, "wall_s": time.time() - t0}
            history.append(entry)
            log.emit("round", **entry)
            if mgr is not None:
                tree = {"train": state, "async": astate} if coupled else state
                mgr.save(rnd + 1, tree, metadata={"round": rnd + 1})
        if mgr is not None:
            mgr.wait()
        if history:
            log.emit(
                "done",
                echo="done: {rounds} rounds, final loss {loss:.4f}, "
                     "simulated FL wall-clock {wall_s:.1f}s "
                     "({policy} @ load {load})",
                rounds=rounds, loss=history[-1]["loss"],
                wall_s=wall_simulated, policy=policy, load=load,
            )
        else:
            log.emit(
                "done",
                echo="nothing to do: resumed at round {round}/{rounds}",
                round=start_round, rounds=rounds, loss=None,
                wall_s=0.0, policy=policy, load=load,
            )
        if collector is not None:
            log.emit("metrics", summary=collector.report().to_dict())
            if trace_path:
                collector.tracer.save(trace_path)
        log.close()
        return state, history


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--policy", choices=("bs", "fcfs"), default="bs")
    ap.add_argument("--load", type=float, default=0.8)
    ap.add_argument("--pons", type=int, default=1,
                    help="wavelength/OLT segments sharing the CPS uplink")
    ap.add_argument("--cps-gbps", type=float, default=None,
                    help="CPS uplink rate in Gb/s (default uncontended)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="round upload deadline in seconds (stragglers "
                         "handled per --deadline-policy)")
    ap.add_argument("--deadline-policy", default="defer",
                    choices=("defer", "drop", "partial"),
                    help="what happens to a straggler's unserved bits "
                         "at the deadline")
    ap.add_argument("--async-buffer", type=int, default=None,
                    help="async (FedBuff) mode: aggregate as soon as K "
                         "uploads complete; stragglers defer with "
                         "staleness")
    ap.add_argument("--log-jsonl", default=None,
                    help="write structured JSONL events to this path "
                         "(console lines become a formatted view of "
                         "the same events)")
    ap.add_argument("--trace", default=None,
                    help="write a Chrome-trace JSON of the run's spans "
                         "to this path (view in Perfetto)")
    ap.add_argument("--resume", dest="resume", action="store_true",
                    default=True,
                    help="resume from the latest checkpoint in "
                         "--ckpt-dir (the default); a resumed run "
                         "reproduces an uninterrupted run exactly")
    ap.add_argument("--no-resume", dest="resume", action="store_false",
                    help="ignore existing checkpoints and start fresh")
    ap.add_argument("--dropout-rate", type=float, default=0.0,
                    help="per-round client dropout probability "
                         "(deterministic counter-based fault stream)")
    ap.add_argument("--outage-rate", type=float, default=0.0,
                    help="per-round probability of an upstream "
                         "link-outage window per PON")
    ap.add_argument("--loss-rate", type=float, default=0.0,
                    help="per-round probability a completed upload's "
                         "payload arrives corrupted")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed of the fault-injection streams")
    ap.add_argument("--quorum", type=float, default=None,
                    help="quorum aggregation: a round commits only "
                         "when at least this fraction of pending "
                         "uploads arrived (needs --deadline)")
    ap.add_argument("--jobs", type=int, default=0,
                    help="competitor FL jobs contending with the pods' "
                         "task inside the same PON/CPS cycle (each "
                         "brings 2 clients and a half-size model)")
    ap.add_argument("--fairness", default="maxmin",
                    choices=("maxmin", "weighted", "deadline"),
                    help="how each cycle's capacity is split across "
                         "tenant jobs")
    args = ap.parse_args(argv)
    train(
        arch=args.arch, smoke=args.smoke, steps_per_round=args.steps,
        rounds=args.rounds, n_pods=args.pods, global_batch=args.batch,
        seq_len=args.seq, lr=args.lr, ckpt_dir=args.ckpt_dir,
        policy=args.policy, load=args.load,
        n_pons=args.pons, cps_gbps=args.cps_gbps,
        deadline_s=args.deadline, deadline_policy=args.deadline_policy,
        async_buffer=args.async_buffer,
        log_jsonl=args.log_jsonl, trace_path=args.trace,
        resume=args.resume,
        dropout_rate=args.dropout_rate, outage_rate=args.outage_rate,
        loss_rate=args.loss_rate, fault_seed=args.fault_seed,
        quorum=args.quorum,
        jobs=args.jobs, fairness=args.fairness,
    )


if __name__ == "__main__":
    main()
