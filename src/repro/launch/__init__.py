"""Launchers: meshes, dry-run, training and serving drivers.

NOTE: importing this package must not initialise jax devices;
``dryrun.py`` sets XLA_FLAGS itself and must be run as __main__.
"""
