"""Production meshes.

Function-scoped (importing this module never touches jax device state):
single-pod 16x16 = 256 chips ("data", "model"), multi-pod 2x16x16 = 512
chips ("pod", "data", "model"). The "pod" axis is the federated axis — one
pod per EC-node site in the paper's mapping (DESIGN.md §3).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1, pods: int = 1):
    """Small mesh over however many devices exist (tests / examples)."""
    n = jax.device_count()
    data = n // (model_parallel * pods)
    if pods > 1:
        return jax.make_mesh(
            (pods, data, model_parallel), ("pod", "data", "model")
        )
    return jax.make_mesh((data, model_parallel), ("data", "model"))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
