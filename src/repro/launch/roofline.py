"""Roofline analysis from dry-run records (TPU v5e targets).

Terms per (arch x shape x mesh) cell, all in seconds:

  compute    = HLO_dot_FLOPs_per_device / peak_FLOPs        (197 TFLOP/s bf16)
  memory     = HLO_HBM_bytes_per_device / HBM_bw            (819 GB/s)
  collective = collective_bytes_per_device / link_bw        (~50 GB/s/link)

plus MODEL_FLOPS = 6·N·D (active-N for MoE) and the usefulness ratio
MODEL_FLOPS / (HLO_FLOPs x chips). The dominant term is the bottleneck the
§Perf loop iterates on. Per-device figures come straight from the post-SPMD
HLO (see hlo_analysis.py), so "roofline fraction" = compute / max(all terms).
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,        # one token per sequence
    "long_500k": 1,
}


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_global: float
    useful_ratio: float
    roofline_fraction: float
    mem_bytes_per_dev: Optional[int]
    record: Dict

    @property
    def bound(self) -> str:
        return self.dominant


def analyze_record(rec: Dict) -> Optional[RooflineRow]:
    if not rec.get("ok"):
        return None
    chips = rec.get("chips", 256)
    flops_dev = rec.get("hlo_flops", 0.0)
    hbm_dev = rec.get("hlo_hbm_bytes", 0.0)
    coll_dev = rec.get("collectives", {}).get("total_bytes", 0.0)

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = hbm_dev / HBM_BW
    collective_s = coll_dev / ICI_BW

    terms = {
        "compute": compute_s, "memory": memory_s, "collective": collective_s
    }
    dominant = max(terms, key=terms.get)

    tokens = TOKENS.get(rec["shape"], 1)
    n_active = rec.get("params_active", rec.get("params_total", 0))
    mult = 6 if rec.get("kind") == "train" else 2
    model_flops = mult * n_active * tokens
    hlo_global = flops_dev * chips
    useful = model_flops / hlo_global if hlo_global else 0.0
    frac = compute_s / max(max(terms.values()), 1e-30)

    ma = rec.get("memory_analysis") or {}
    mem_dev = None
    if ma:
        out_extra = max(
            0,
            ma.get("output_size_in_bytes", 0)
            - ma.get("alias_size_in_bytes", 0),   # donated buffers alias
        )
        mem_dev = (
            ma.get("argument_size_in_bytes", 0)
            + out_extra
            + ma.get("temp_size_in_bytes", 0)
        )
    return RooflineRow(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=model_flops,
        hlo_flops_global=hlo_global, useful_ratio=useful,
        roofline_fraction=frac, mem_bytes_per_dev=mem_dev, record=rec,
    )


def load_rows(paths: List[str]) -> List[RooflineRow]:
    rows = []
    for path in paths:
        with open(path) as f:
            for line in f:
                if not line.strip():
                    continue
                row = analyze_record(json.loads(line))
                if row is not None:
                    rows.append(row)
    return rows


def format_table(rows: List[RooflineRow]) -> str:
    hdr = (
        f"{'arch':18s} {'shape':12s} {'mesh':8s} "
        f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
        f"{'dominant':>10s} {'useful':>7s} {'roofline':>9s} {'mem/dev':>9s}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        mem = f"{r.mem_bytes_per_dev/2**30:.1f}G" if r.mem_bytes_per_dev else "-"
        lines.append(
            f"{r.arch:18s} {r.shape:12s} {r.mesh:8s} "
            f"{r.compute_s:10.4f} {r.memory_s:10.4f} {r.collective_s:10.4f} "
            f"{r.dominant:>10s} {r.useful_ratio:7.2f} "
            f"{r.roofline_fraction:9.3f} {mem:>9s}"
        )
    return "\n".join(lines)


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("inputs", nargs="+", help="dryrun JSONL files")
    ap.add_argument("--csv", default=None)
    args = ap.parse_args(argv)
    rows = load_rows(args.inputs)
    rows.sort(key=lambda r: (r.mesh, r.arch, r.shape))
    print(format_table(rows))
    if args.csv:
        with open(args.csv, "w") as f:
            f.write(
                "arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
                "useful_ratio,roofline_fraction,mem_bytes_per_dev\n"
            )
            for r in rows:
                f.write(
                    f"{r.arch},{r.shape},{r.mesh},{r.compute_s:.6g},"
                    f"{r.memory_s:.6g},{r.collective_s:.6g},{r.dominant},"
                    f"{r.useful_ratio:.4g},{r.roofline_fraction:.4g},"
                    f"{r.mem_bytes_per_dev or ''}\n"
                )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
