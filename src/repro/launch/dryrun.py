import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
initialisation, and the production meshes need 512 placeholder host devices.

For each cell this script:
  1. builds ShapeDtypeStruct specs (params via eval_shape — no allocation),
  2. ``jax.jit(step, in_shardings, out_shardings).lower(...).compile()``,
  3. records ``memory_analysis()``, ``cost_analysis()`` and collective bytes
     parsed from the optimised (post-SPMD) HLO,
  4. appends a JSON record consumed by the roofline report.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun.json
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --fed \
      --mesh multi      # federated pod-axis steps (paper's technique)
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import applicable_shapes, get_config, list_architectures
from repro.configs.base import SHAPES_BY_NAME, InputShape, param_count
from repro.dist import stepfns
from repro.launch import specs as specs_mod
from repro.launch.hlo_analysis import analyze as analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.optim.optimizers import OptimizerConfig

# ---------------------------------------------------------------------------
# per-cell dry-run
# ---------------------------------------------------------------------------


def _mem_analysis_dict(compiled) -> Optional[Dict]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None
    if ma is None:
        return None
    keys = (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
        "alias_size_in_bytes", "host_argument_size_in_bytes",
        "host_output_size_in_bytes", "host_temp_size_in_bytes",
    )
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out or {"repr": str(ma)}


def run_cell(
    arch: str,
    shape: InputShape,
    multi_pod: bool,
    fed: bool = False,
    fed_round: bool = False,
    keep_hlo: bool = False,
    config_overrides: Optional[Dict] = None,
) -> Dict:
    """Lower+compile one cell; returns the JSON record."""
    cfg = get_config(arch)
    if config_overrides:
        cfg = cfg.replace(**config_overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_pods = mesh.shape.get("pod", 1)
    opt_cfg = OptimizerConfig(name="adamw", state_dtype=cfg.opt_state_dtype)
    rec: Dict = {
        "arch": arch,
        "shape": shape.name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": mesh.size,
        "kind": shape.kind,
        "fed": fed,
        "fed_round": fed_round,
        "ok": False,
    }
    t0 = time.time()

    with mesh:
        if fed_round:
            step = stepfns.make_fed_round_step(cfg)
            state, state_shardings = specs_mod.state_specs(
                cfg, opt_cfg, mesh, fed=True, n_pods=n_pods
            )
            weights = jax.ShapeDtypeStruct((n_pods,), jnp.float32)
            lowered = jax.jit(
                step,
                in_shardings=(state_shardings, None),
                out_shardings=state_shardings,
                donate_argnums=0,
            ).lower(state, weights)
        elif shape.kind == "train":
            state, state_shardings = specs_mod.state_specs(
                cfg, opt_cfg, mesh, fed=fed, n_pods=n_pods
            )
            # pin the grad-accum carry to the params' layout so the
            # scan -> ZeRO-update boundary needs no involuntary reshard
            if fed:
                from jax.sharding import NamedSharding, PartitionSpec

                inner = specs_mod.state_spec_tree(
                    specs_mod.state_shapes(cfg, opt_cfg, 0), cfg, mesh,
                    fed=False,
                )
                grad_sh = jax.tree.map(
                    lambda p: NamedSharding(mesh, p), inner.params,
                    is_leaf=lambda x: isinstance(x, PartitionSpec),
                )
                step = stepfns.make_fed_train_step(
                    cfg, opt_cfg, grad_shardings=grad_sh,
                    spmd_axis_name="pod",
                )
            else:
                step = stepfns.make_train_step(
                    cfg, opt_cfg,
                    grad_shardings=state_shardings.params,
                )
            batch = specs_mod.train_batch_specs(
                cfg, shape, mesh, fed=fed, n_pods=n_pods
            )
            lowered = jax.jit(
                step,
                in_shardings=(state_shardings,
                              jax.tree.map(lambda s: s.sharding, batch)),
                out_shardings=(state_shardings, None),
                donate_argnums=0,
            ).lower(state, batch)
        elif shape.kind == "prefill":
            step = stepfns.make_prefill_step(cfg)
            pstate, p_shardings = specs_mod.state_specs(cfg, opt_cfg, mesh)
            params, param_shardings = pstate.params, p_shardings.params
            tokens, cache, cache_shardings, extra = (
                specs_mod.prefill_input_specs(cfg, shape, mesh)
            )
            args = (params, tokens, cache) + ((extra,) if extra is not None else ())
            in_sh = (param_shardings, tokens.sharding, cache_shardings) + (
                (extra.sharding,) if extra is not None else ()
            )
            lowered = jax.jit(
                step,
                in_shardings=in_sh,
                out_shardings=(None, cache_shardings),
                donate_argnums=2,          # cache buffers alias in place
            ).lower(*args)
        else:  # decode
            step = stepfns.make_decode_step(cfg)
            pstate, p_shardings = specs_mod.state_specs(cfg, opt_cfg, mesh)
            params, param_shardings = pstate.params, p_shardings.params
            token, cache, cache_shardings = specs_mod.decode_input_specs(
                cfg, shape, mesh
            )
            lowered = jax.jit(
                step,
                in_shardings=(param_shardings, token.sharding, cache_shardings),
                out_shardings=(None, cache_shardings),
                donate_argnums=2,          # cache buffers alias in place
            ).lower(params, token, cache)

        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):  # older jaxlib: one dict per device
            cost = cost[0] if cost else {}
        rec["cost_analysis"] = {
            k: float(v)
            for k, v in cost.items()
            if isinstance(v, (int, float)) and (
                k in ("flops", "transcendentals", "bytes accessed")
                or k.startswith("bytes accessed")
            )
        }
        rec["memory_analysis"] = _mem_analysis_dict(compiled)

        hlo = compiled.as_text()
        rec["hlo_bytes"] = len(hlo)
        # while-loop trips by nesting depth: [grad-accum,] unit-scan, inner
        trips = [max(cfg.n_units, 1)]
        if cfg.ssm is not None and shape.kind in ("train", "prefill"):
            seq = shape.seq_len - cfg.n_frontend_tokens
            trips.append(max(seq // cfg.ssm.chunk, 1))   # SSD chunk scan
        if shape.kind == "train" and cfg.grad_accum > 1 and not fed_round:
            trips = [cfg.grad_accum] + trips
        if fed_round:
            trips = [1]
        analysis = analyze_hlo(hlo, loop_trips=trips)
        rec["hlo_flops"] = analysis["flops"]
        rec["hlo_hbm_bytes"] = analysis["hbm_bytes"]
        rec["hlo_dot_count"] = analysis["dot_count"]
        rec["collectives"] = analysis["collectives"]
        rec["loop_trips"] = trips
        if keep_hlo:
            rec["hlo"] = hlo

        pc = param_count(cfg)
        rec["params_total"] = pc["total"]
        rec["params_active"] = pc["active"]
        rec["ok"] = True
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x applicable shape) cell")
    ap.add_argument("--fed", action="store_true",
                    help="lower the federated pod-axis steps instead")
    ap.add_argument("--fed-round", action="store_true",
                    help="lower the cross-pod FedAvg round step")
    ap.add_argument("--out", default=None, help="append JSON records here")
    ap.add_argument("--override", default=None,
                    help="JSON dict of ModelConfig overrides (perf exps)")
    args = ap.parse_args(argv)

    cells = []
    archs = list_architectures() if (args.all or not args.arch) else [args.arch]
    for arch in archs:
        cfg = get_config(arch)
        shapes = (
            applicable_shapes(cfg) if (args.all or not args.shape)
            else [SHAPES_BY_NAME[args.shape]]
        )
        for shape in shapes:
            meshes = {
                "single": [False], "multi": [True], "both": [False, True]
            }[args.mesh]
            for multi in meshes:
                cells.append((arch, shape, multi))

    overrides = json.loads(args.override) if args.override else None
    records = []
    failures = 0
    for arch, shape, multi in cells:
        label = f"{arch} x {shape.name} x {'2x16x16' if multi else '16x16'}"
        try:
            rec = run_cell(arch, shape, multi, fed=args.fed,
                           fed_round=args.fed_round,
                           config_overrides=overrides)
            flops = rec["cost_analysis"].get("flops", 0)
            coll = rec["collectives"]["total_bytes"]
            print(
                f"[ok] {label}: lower {rec['lower_s']}s compile "
                f"{rec['compile_s']}s flops {flops:.3e} coll {coll:.3e}B",
                flush=True,
            )
        except Exception as e:
            failures += 1
            rec = {
                "arch": arch, "shape": shape.name,
                "mesh": "2x16x16" if multi else "16x16",
                "ok": False, "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:],
            }
            print(f"[FAIL] {label}: {type(e).__name__}: {e}", flush=True)
        records.append(rec)
        if args.out:
            with open(args.out, "a") as f:
                for r in records[-1:]:
                    f.write(json.dumps(r) + "\n")

    print(f"\n{len(records) - failures}/{len(records)} cells OK")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
