"""Batched serving driver: prefill + decode with sharded KV caches.

Serves a (smoke-scale) model over batched requests: prefill fills the ring/
full caches, then tokens decode step-by-step. The same step functions lower
on the production meshes in the dry-run; here they run on the host devices.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.dist import stepfns
from repro.models import lm


def serve(
    arch: str = "olmo-1b",
    smoke: bool = True,
    batch: int = 4,
    prompt_len: int = 32,
    max_new_tokens: int = 16,
    temperature: float = 0.0,
    seed: int = 0,
    log_jsonl=None,
):
    from repro.obs import EventLog

    log = EventLog(jsonl_path=log_jsonl)
    cfg = get_config(arch, smoke=smoke)
    key = jax.random.PRNGKey(seed)
    params = lm.init_params(key, cfg)
    prefill_step = jax.jit(stepfns.make_prefill_step(cfg))
    decode_step = jax.jit(stepfns.make_decode_step(cfg))

    prompts = jax.random.randint(
        jax.random.PRNGKey(seed + 1), (batch, prompt_len), 0, cfg.vocab_size
    )
    extra = None
    if cfg.frontend:
        extra = jax.random.normal(
            key, (batch, cfg.n_frontend_tokens, cfg.d_model), dtype=cfg.dtype
        )
    cache = lm.init_cache(cfg, batch, prompt_len + max_new_tokens + 8)

    t0 = time.time()
    logits, cache = prefill_step(params, prompts, cache, extra)
    prefill_s = time.time() - t0

    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    generated = [tok]
    t1 = time.time()
    for i in range(max_new_tokens - 1):
        logits, cache = decode_step(params, tok, cache)
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, -1] / temperature
            )[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        generated.append(tok)
    decode_s = time.time() - t1
    out = jnp.concatenate(generated, axis=1)
    tps = batch * max_new_tokens / max(decode_s, 1e-9)
    log.emit(
        "serve",
        echo="{arch}: prefill({batch}x{prompt_len})={prefill_ms:.1f}ms "
             "decode {new_tokens} steps={decode_ms:.1f}ms "
             "({tps:.1f} tok/s batched)",
        arch=arch, batch=batch, prompt_len=prompt_len,
        prefill_ms=prefill_s * 1e3, new_tokens=max_new_tokens,
        decode_ms=decode_s * 1e3, tps=tps,
    )
    log.close()
    return np.asarray(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--log-jsonl", default=None,
                    help="write structured JSONL events to this path")
    args = ap.parse_args(argv)
    serve(
        arch=args.arch, batch=args.batch, prompt_len=args.prompt_len,
        max_new_tokens=args.max_new_tokens, temperature=args.temperature,
        log_jsonl=args.log_jsonl,
    )


if __name__ == "__main__":
    main()
