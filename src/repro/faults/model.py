"""Fault model + retry policy for the PON/FL co-simulation.

``FaultSchedule`` describes three fault classes, all drawn from the
counter-based streams in ``repro.faults.streams``:

* **client dropout** (``dropout_rate``): a pending client dies partway
  through its upload. The cut point is a second uniform — the client
  transmits ``frac`` of its pending bits, then disappears; whatever it
  served is wasted wire time, and the round treats the client as
  failed regardless of deadline policy.
* **ONU/link outage** (``outage_rate``): a whole PON's upstream goes
  dark for a window ``[start, start + duration)`` of the round's
  upload phase (phase-relative seconds, like ``ul_deadline_s``).
  Outages mask capacity — grants are zero during the window — but
  cancel nothing by themselves; they interact with deadlines through
  the normal defer/drop/partial policies, which is why outage-only
  schedules stay fold-legal.
* **payload loss** (``loss_rate``): a completed upload arrives
  corrupted and is discarded. The draw is made for every pending
  client of the round (not only the ones that happened to arrive), so
  the decision is independent of simulation outcomes — quorum
  deadline-extension reruns and the reference oracle see identical
  loss sets.

Dropout and loss cancel an update in flight; the failed client
re-sends under ``RetryPolicy`` (exponential backoff in rounds, a
bounded number of attempts, then it gives up and re-enters fresh via
membership). ``trivial`` schedules (all rates zero) are bitwise
identical to ``faults=None`` — the standing faults-off invariant.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Sequence

import numpy as np

from repro.faults.streams import (
    FAULT_DROPOUT,
    FAULT_LOSS,
    FAULT_OUTAGE,
    fault_uniforms,
)

__all__ = ["FaultSchedule", "RetryPolicy"]


@dataclass(frozen=True)
class FaultSchedule:
    """Deterministic fault process shared by every case of a sweep.

    ``seed`` keys the fault streams; each sweep case additionally mixes
    its own ``SweepCase.seed`` into the key, so cases draw independent
    faults while both simulation backends (and any rerun of the same
    round) agree exactly.
    """

    seed: int = 0
    dropout_rate: float = 0.0
    loss_rate: float = 0.0
    outage_rate: float = 0.0
    outage_duration_s: float = 0.5
    outage_start_max_s: float = 2.0

    def __post_init__(self):
        for name in ("dropout_rate", "loss_rate", "outage_rate"):
            v = float(getattr(self, name))
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1]; got {v}")
            object.__setattr__(self, name, v)
        if self.outage_duration_s <= 0.0:
            raise ValueError("outage_duration_s must be positive")
        if self.outage_start_max_s < 0.0:
            raise ValueError("outage_start_max_s must be >= 0")

    @property
    def trivial(self) -> bool:
        """All rates zero: must be bitwise identical to ``None``."""
        return (self.dropout_rate == 0.0 and self.loss_rate == 0.0
                and self.outage_rate == 0.0)

    @property
    def couples_rounds(self) -> bool:
        """Dropout/loss book retries across round boundaries (no
        folding); outage-only schedules stay fold-legal."""
        return self.dropout_rate > 0.0 or self.loss_rate > 0.0

    def dropouts(self, round_index: int, client_ids: Sequence[int],
                 case_seed: int = 0) -> Dict[int, float]:
        """``{client_id: served fraction before death}`` for the round's
        dropout victims among ``client_ids``."""
        if self.dropout_rate == 0.0 or not len(client_ids):
            return {}
        ids = np.asarray(list(client_ids), np.int64)
        u_occ, u_frac = fault_uniforms(
            self.seed, FAULT_DROPOUT, round_index, ids, case_seed
        )
        hit = u_occ < self.dropout_rate
        return {int(i): float(f)
                for i, f in zip(ids[hit], u_frac[hit])}

    def losses(self, round_index: int, client_ids: Sequence[int],
               case_seed: int = 0) -> frozenset:
        """Clients whose *completed* upload would arrive corrupted."""
        if self.loss_rate == 0.0 or not len(client_ids):
            return frozenset()
        ids = np.asarray(list(client_ids), np.int64)
        u_occ, _ = fault_uniforms(
            self.seed, FAULT_LOSS, round_index, ids, case_seed
        )
        return frozenset(int(i) for i in ids[u_occ < self.loss_rate])

    def outage_windows(self, round_index: int, n_pons: int,
                       case_seed: int = 0) -> np.ndarray:
        """``(n_pons, 2)`` upstream outage ``[start, end)`` windows in
        phase-relative seconds; ``[inf, inf]`` rows mean no outage."""
        out = np.full((n_pons, 2), np.inf)
        if self.outage_rate == 0.0 or n_pons < 1:
            return out
        pons = np.arange(n_pons, dtype=np.int64)
        u_occ, u_start = fault_uniforms(
            self.seed, FAULT_OUTAGE, round_index, pons, case_seed
        )
        hit = u_occ < self.outage_rate
        start = u_start * self.outage_start_max_s
        out[hit, 0] = start[hit]
        out[hit, 1] = start[hit] + self.outage_duration_s
        return out


@dataclass(frozen=True)
class RetryPolicy:
    """Exponential-backoff retransmission of a failed upload.

    A failure at round ``r`` on attempt ``a`` (1-based) schedules the
    retransmission for round ``r + delay_rounds(a)``; past
    ``max_retries`` attempts the client gives the update up and
    re-enters fresh through membership.
    """

    base_delay_rounds: int = 1
    backoff: float = 2.0
    max_retries: int = 3

    def __post_init__(self):
        if self.base_delay_rounds < 1:
            raise ValueError("base_delay_rounds must be >= 1")
        if self.backoff < 1.0:
            raise ValueError("backoff must be >= 1.0")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")

    def delay_rounds(self, attempt: int) -> int:
        """Backoff in rounds before attempt ``attempt`` (1-based)."""
        return int(math.ceil(
            self.base_delay_rounds * self.backoff ** (attempt - 1)
        ))
