"""Deterministic, counter-based fault injection for the co-simulation.

See ``repro.faults.model`` (the fault/retry model) and
``repro.faults.streams`` (the threefry-keyed decision streams).
"""
from repro.faults.model import FaultSchedule, RetryPolicy
from repro.faults.streams import (
    FAULT_DROPOUT,
    FAULT_LOSS,
    FAULT_OUTAGE,
    fault_fingerprint,
    fault_key,
    fault_uniforms,
)

__all__ = [
    "FaultSchedule",
    "RetryPolicy",
    "FAULT_DROPOUT",
    "FAULT_LOSS",
    "FAULT_OUTAGE",
    "fault_fingerprint",
    "fault_key",
    "fault_uniforms",
]
