"""Counter-based fault streams: threefry uniforms keyed per fault event.

The fault subsystem follows the same determinism contract as the
background-traffic sampler (``repro.kernels.traffic``): every fault
decision is a pure function of ``(seed, fault_class, round, entity)``
(entity = client id for dropout/loss draws, PON index for outage
windows), evaluated through the same vectorised Threefry-2x32 core.
Streams are therefore

* **O(1)-seekable** — round ``r``'s draws are addressed directly, no
  sequential RNG state, so a resumed or re-run round sees identical
  faults;
* **chunk-invariant** — drawing one entity or a batch of entities
  yields the same values per entity (pinned by
  ``tests/test_faults.py``);
* **fold-invariant** — the folded timeline (round axis in the batch
  axis) and the sequential/reference loops consult the identical
  stream.

Key derivation mirrors ``make_stream_key``: the seed fills one key
word, the fault class Weyl-shifts both words, and the per-case seed
mixes in through a third Weyl constant — all constants distinct from
the traffic sampler's, so a fault stream can never alias an arrival
stream.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.kernels.traffic.ops import threefry2x32_np

_MASK32 = 0xFFFFFFFF

# fault classes (the stream key's class word)
FAULT_DROPOUT = 0                 # client dies mid-upload
FAULT_OUTAGE = 1                  # ONU/link outage window (per PON)
FAULT_LOSS = 2                    # update payload lost/corrupted

# Weyl constants: xxhash PRIME32_1/2 + splitmix increment — deliberately
# distinct from *every* traffic-sampler constant (KEY_WEYL_* in
# traffic/ref.py and _PON_WEYL_*/_JOB_WEYL_* in traffic/ops.py); the
# RPA006 stream-key checker enforces pairwise disjointness
_CLASS_WEYL_0 = 0x9E3779B1
_CLASS_WEYL_1 = 0x85EBCA77
_CASE_WEYL = 0x6C8E9CF5

_INV_2_32 = float(2.0 ** -32)


def fault_key(seed: int, fault_class: int, case_seed: int = 0,
              ) -> Tuple[int, int]:
    """uint32 key words for one ``(seed, fault_class, case)`` stream."""
    eff = (int(seed) + int(case_seed) * _CASE_WEYL) & _MASK32
    k0 = (eff + int(fault_class) * _CLASS_WEYL_0) & _MASK32
    k1 = ((int(fault_class) + 1) * _CLASS_WEYL_1) & _MASK32
    return k0, k1


def fault_uniforms(seed: int, fault_class: int, round_index: int,
                   entity, case_seed: int = 0,
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Two independent uniforms in (0, 1) per ``(round, entity)`` event.

    ``entity`` is an int or int array (client ids, or PON indices);
    the return matches its shape. The open-interval mapping
    ``(x + 0.5) * 2^-32`` guarantees ``rate=0.0`` never fires and
    ``rate=1.0`` always fires regardless of the raw 32-bit word.
    """
    ent = np.atleast_1d(np.asarray(entity, np.int64))
    k0, k1 = fault_key(seed, fault_class, case_seed)
    c0 = np.full(ent.shape, int(round_index) & _MASK32, np.uint32)
    c1 = (ent & _MASK32).astype(np.uint32)
    x0, x1 = threefry2x32_np(np.uint32(k0), np.uint32(k1), c0, c1)
    u0 = (x0.astype(np.float64) + 0.5) * _INV_2_32
    u1 = (x1.astype(np.float64) + 0.5) * _INV_2_32
    if np.ndim(entity) == 0:
        return float(u0[0]), float(u1[0])
    return u0, u1


def fault_fingerprint(seed: int, fault_class: int, round_index: int,
                      n_entities: int, case_seed: int = 0) -> int:
    """XOR-reduced raw stream words over entities ``0..n-1`` — a cheap
    pinned regression value for the stream's exact bits."""
    ent = np.arange(n_entities, dtype=np.int64)
    k0, k1 = fault_key(seed, fault_class, case_seed)
    c0 = np.full(ent.shape, int(round_index) & _MASK32, np.uint32)
    c1 = (ent & _MASK32).astype(np.uint32)
    x0, x1 = threefry2x32_np(np.uint32(k0), np.uint32(k1), c0, c1)
    words = (x0.astype(np.uint64) << np.uint64(32)) | x1.astype(np.uint64)
    return int(np.bitwise_xor.reduce(words))
