"""Deadline-driven client selection — the paper's reference [4] baseline
(Nishio & Yonetani, "Client selection for FL with heterogeneous resources in
mobile edge", IEEE ICC 2019).

Filters stragglers: only clients whose estimated round completion fits the
deadline participate. The paper's critique — "the stragglers' contribution to
the training process is ignored, and thereby the learning accuracy may be
degraded" — is exactly what the FL co-simulation quantifies (fewer clients →
lower saturated accuracy, Fig 2a).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

from repro.core.slicing import ClientProfile


def estimated_completion(
    c: ClientProfile, uplink_bps: float
) -> float:
    """Optimistic per-client round estimate: Δ_i + dedicated-line upload."""
    return c.delta + c.m_ud_bits / uplink_bps + c.propagation_s


def select_by_deadline(
    clients: Sequence[ClientProfile],
    deadline_s: float,
    uplink_bps: float,
) -> Tuple[List[ClientProfile], List[ClientProfile]]:
    """Returns (selected, filtered_stragglers)."""
    selected, dropped = [], []
    for c in clients:
        (selected if estimated_completion(c, uplink_bps) <= deadline_s
         else dropped).append(c)
    return selected, dropped


def greedy_max_clients(
    clients: Sequence[ClientProfile],
    deadline_s: float,
    uplink_bps: float,
) -> List[ClientProfile]:
    """Nishio's greedy: pack as many clients as possible into the deadline
    when uploads are serialised on the shared uplink (FCFS order by Δ)."""
    order = sorted(clients, key=lambda c: c.delta)
    chosen: List[ClientProfile] = []
    cursor = 0.0
    for c in order:
        start = max(cursor, c.delta)
        end = start + c.m_ud_bits / uplink_bps + c.propagation_s
        if end <= deadline_s:
            chosen.append(c)
            cursor = start + c.m_ud_bits / uplink_bps
    return chosen
