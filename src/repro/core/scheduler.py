"""Slot scheduling inside the BS slice + mapping onto PON polling cycles.

Once the slice ``S{t_s, t_e, B}`` exists, the OLT schedules a *fixed time
slot* for each ONU (paper §2). Clients are served in ascending readiness
order (earliest Δ_i first — they can start uploading while stragglers still
compute), each slot long enough to drain ``M_i^UD`` at the slice bandwidth.

Because PON upstream bandwidth is granted per polling cycle, the continuous
slot plan is then quantised into per-cycle grants (``map_to_polling_cycles``)
— the exact mechanism of Fig. 1's bottom timeline.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence

import numpy as np

from repro.core.slicing import ClientProfile, SliceSpec


@dataclass(frozen=True)
class SlotAssignment:
    client_id: int
    t_start: float          # absolute time the slot opens
    t_end: float            # absolute time the slot closes
    bits: float

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start


@dataclass(frozen=True)
class CycleGrant:
    cycle_index: int
    t_cycle_start: float
    client_id: int
    bits: float


def schedule_slots(
    clients: Sequence[ClientProfile],
    spec: SliceSpec,
    round_start: float,
) -> List[SlotAssignment]:
    """Earliest-ready-first fixed slots inside the slice.

    A client's upload can start no earlier than max(slice start, its own
    readiness ``round_start + Δ_i``); slots are packed back-to-back at the
    slice bandwidth ``B``.
    """
    order = sorted(clients, key=lambda c: c.delta)
    slots: List[SlotAssignment] = []
    cursor = spec.t_start
    for c in order:
        ready = round_start + c.delta
        start = max(cursor, ready)
        dur = c.m_ud_bits / spec.bandwidth_bps
        slots.append(
            SlotAssignment(
                client_id=c.client_id,
                t_start=start,
                t_end=start + dur,
                bits=c.m_ud_bits,
            )
        )
        cursor = start + dur
    return slots


def schedule_makespan(slots: Sequence[SlotAssignment]) -> float:
    return max(s.t_end for s in slots) if slots else 0.0


def slots_to_arrays(slots: Sequence[SlotAssignment]) -> Dict[str, np.ndarray]:
    """Slot schedule as parallel arrays, t_start-sorted (stable, matching
    ``SlicedDBA``'s slot ordering) — the form the vectorized engine
    consumes."""
    order = sorted(range(len(slots)), key=lambda i: slots[i].t_start)
    return {
        "t_start": np.array([slots[i].t_start for i in order], np.float64),
        "t_end": np.array([slots[i].t_end for i in order], np.float64),
        "client_id": np.array([slots[i].client_id for i in order], np.int64),
        "bits": np.array([slots[i].bits for i in order], np.float64),
    }


def map_to_polling_cycles(
    slots: Sequence[SlotAssignment],
    spec: SliceSpec,
    cycle_time_s: float = 1e-3,
) -> List[CycleGrant]:
    """Quantise the continuous slot plan into per-polling-cycle grants.

    Each cycle of length ``cycle_time_s`` carries ``B * cycle_time_s`` bits of
    the slice; a slot spanning [a, b) receives grants in every cycle it
    overlaps, proportional to the overlap.
    """
    grants: List[CycleGrant] = []
    if not slots:
        return grants
    t0 = min(s.t_start for s in slots)
    import math

    for s in slots:
        first = int(math.floor((s.t_start - t0) / cycle_time_s))
        last = int(math.ceil((s.t_end - t0) / cycle_time_s))
        for idx in range(first, last):
            c_start = t0 + idx * cycle_time_s
            c_end = c_start + cycle_time_s
            overlap = min(s.t_end, c_end) - max(s.t_start, c_start)
            if overlap <= 0:
                continue
            grants.append(
                CycleGrant(
                    cycle_index=idx,
                    t_cycle_start=c_start,
                    client_id=s.client_id,
                    bits=overlap * spec.bandwidth_bps,
                )
            )
    return grants


def validate_schedule(
    clients: Sequence[ClientProfile],
    slots: Sequence[SlotAssignment],
    spec: SliceSpec,
    round_start: float,
    tol: float = 1e-6,
) -> None:
    """Invariants (used by tests and asserted in the simulator):

    - one slot per client, carrying exactly its update bits;
    - no slot starts before the client is ready or before the slice opens;
    - slots do not overlap (single upstream wavelength);
    - every slot drains at the slice bandwidth.
    """
    by_id = {c.client_id: c for c in clients}
    assert len(slots) == len(clients), "one slot per client"
    prev_end = -float("inf")
    for s in sorted(slots, key=lambda s: s.t_start):
        c = by_id[s.client_id]
        assert s.bits == c.m_ud_bits
        assert s.t_start >= round_start + c.delta - tol, "slot before readiness"
        assert s.t_start >= spec.t_start - tol, "slot before slice opens"
        assert s.t_start >= prev_end - tol, "overlapping slots"
        expected = s.bits / spec.bandwidth_bps
        assert abs(s.duration - expected) < tol * max(1.0, expected)
        prev_end = s.t_end
