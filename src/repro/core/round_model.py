"""Synchronisation-round time model (paper §2, Fig. 1).

One synchronous FL round per client i:

    T_i = T_i^DL (global model download)
        + T_i^UD (local training)
        + T_i^UL (local model upload)
        + T_a    (aggregation at the CPS; paper assumes ≈ 0)

The round's synchronisation time is ``max_i T_i^DL+T_i^UD + upload drain``,
where the upload drain depends on the DBA policy — this module computes the
*analytic* BS value; the FCFS benchmark value comes from the event simulator
(``repro.net``), which also cross-validates the BS analytic model.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

from repro.core.scheduler import schedule_makespan, schedule_slots
from repro.core.slicing import ClientProfile, SliceSpec, compute_slice


@dataclass(frozen=True)
class RoundTiming:
    sync_time: float            # wall-clock for the full round
    compute_bound: float        # max_i (T_i^DL + T_i^UD): the floor
    comm_overhead: float        # sync_time - compute_bound
    per_client_upload_end: dict


def bs_round_time(
    clients: Sequence[ClientProfile],
    capacity_bps: float,
    t_aggregate: float = 0.0,
    spec: SliceSpec | None = None,
) -> RoundTiming:
    """Analytic round time under bandwidth slicing (round starts at t=0)."""
    if spec is None:
        spec = compute_slice(clients, t_current=0.0, t_round=0.0,
                             capacity_bps=capacity_bps, h=1)
    # slice times here are relative to the round start (t_current=0, h*0=0)
    slots = schedule_slots(clients, spec, round_start=0.0)
    makespan = schedule_makespan(slots)
    compute_bound = max(c.delta for c in clients)
    prop = max(c.propagation_s for c in clients)
    sync = makespan + prop + t_aggregate
    return RoundTiming(
        sync_time=sync,
        compute_bound=compute_bound,
        comm_overhead=sync - compute_bound,
        per_client_upload_end={s.client_id: s.t_end for s in slots},
    )


def download_time(model_bits: float, downlink_bps: float,
                  distance_m: float = 20_000.0) -> float:
    """T_i^DL for the broadcast of the global model on reserved downlink."""
    from repro.core.slicing import LIGHT_SPEED_FIBER

    return model_bits / downlink_bps + distance_m / LIGHT_SPEED_FIBER


def heterogeneous_compute_times(
    n_clients: int,
    rng,
    t_min_s: float = 1.0,
    t_max_s: float = 5.0,
) -> list:
    """Paper Fig 2(b): T_i^UD uniform in [1, 5] s across the EC nodes."""
    return list(rng.uniform(t_min_s, t_max_s, size=n_clients))
