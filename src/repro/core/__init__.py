"""The paper's primary contribution: bandwidth slicing for FL in edge computing."""
from repro.core.deadline import (  # noqa: F401
    greedy_max_clients,
    select_by_deadline,
)
from repro.core.membership import MembershipEvent, SliceManager  # noqa: F401
from repro.core.round_model import (  # noqa: F401
    RoundTiming,
    bs_round_time,
    download_time,
    heterogeneous_compute_times,
)
from repro.core.scheduler import (  # noqa: F401
    CycleGrant,
    SlotAssignment,
    map_to_polling_cycles,
    schedule_makespan,
    schedule_slots,
    validate_schedule,
)
from repro.core.slicing import (  # noqa: F401
    ClientProfile,
    SliceSpec,
    compute_slice,
    min_round_time,
    nabla,
    validate_round_deadline,
)
