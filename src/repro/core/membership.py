"""Elastic client membership — the BS re-trigger semantics (paper §2).

"The proposed BS algorithm is triggered only when new clients join or leave
the FL task." This module tracks Φ across rounds, detects membership deltas,
and re-runs the BS algorithm exactly when they occur. It is also the
fault-tolerance hook: a client that fails mid-round is a `leave` event; a
recovered client is a `join`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.core.slicing import ClientProfile, SliceSpec, compute_slice


@dataclass
class MembershipEvent:
    time: float
    kind: str                   # "join" | "leave"
    client: ClientProfile


@dataclass
class SliceManager:
    """Owns the current slice; recomputes only on membership change."""

    capacity_bps: float
    t_round: float
    clients: Dict[int, ClientProfile] = field(default_factory=dict)
    current_slice: Optional[SliceSpec] = None
    recompute_count: int = 0
    event_log: List[MembershipEvent] = field(default_factory=list)

    def bootstrap(self, clients: Sequence[ClientProfile], t_now: float = 0.0):
        self.clients = {c.client_id: c for c in clients}
        self._retrigger(t_now)

    def join(self, client: ClientProfile, t_now: float):
        self.event_log.append(MembershipEvent(t_now, "join", client))
        self.clients[client.client_id] = client
        self._retrigger(t_now)

    def leave(self, client_id: int, t_now: float):
        client = self.clients.pop(client_id, None)
        if client is None:
            return  # unknown client: no-op, no re-trigger
        self.event_log.append(MembershipEvent(t_now, "leave", client))
        if self.clients:
            self._retrigger(t_now)
        else:
            self.current_slice = None

    def on_round(self, t_now: float) -> Optional[SliceSpec]:
        """Called every round; returns the slice WITHOUT recomputation.

        (The paper's key property: rounds reuse the slice; only membership
        changes pay the recomputation.)
        """
        return self.current_slice

    def _retrigger(self, t_now: float):
        if not self.clients:
            self.current_slice = None
            return
        self.current_slice = compute_slice(
            list(self.clients.values()),
            t_current=t_now,
            t_round=self.t_round,
            capacity_bps=self.capacity_bps,
            h=1,
        )
        self.recompute_count += 1

    @property
    def profile_set(self) -> List[ClientProfile]:
        return list(self.clients.values())
