"""The paper's core contribution: the Bandwidth Slicing (BS) algorithm.

Implements Algorithm 1 of the paper. Given the set Φ of involved clients —
their local-training times ``T_i^UD``, global-model download times ``T_i^DL``
and update sizes ``M_i^UD`` — compute the slice ``S{t_s, t_e, B}`` that
reserves uplink bandwidth for the FL task so that early-finishing clients
upload inside the slack window of the stragglers:

    Δ_i    = T_i^UD + T_i^DL
    T^max  = max(Δ) + ∇          (∇ = serialization+propagation of the last
    T^min  = min(Δ)               arriving update, estimated from distance)
    τ      = T^max − T^min
    B      = min(Σ_i M_i^UD / τ, C)        [paper line 8 prints Max — typo,
                                            the text mandates B ≤ C]
    t_s    = t_current + T^min + h·T^round
    t_e    = t_current + T^max + h·T^round

The slice is (re-)computed only on membership change (client join/leave) —
see ``repro.core.membership``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

LIGHT_SPEED_FIBER = 2.0e8  # m/s


@dataclass(frozen=True)
class ClientProfile:
    """One involved client (ONU/EC node) in the FL task (an entry of Φ)."""

    client_id: int
    t_ud: float            # local training (computation) time, seconds
    t_dl: float            # global model download time, seconds
    m_ud_bits: float       # model update size, bits
    distance_m: float = 20_000.0   # ONU<->OLT distance (paper: 20 km)

    @property
    def delta(self) -> float:
        return self.t_ud + self.t_dl

    @property
    def propagation_s(self) -> float:
        return self.distance_m / LIGHT_SPEED_FIBER


@dataclass(frozen=True)
class SliceSpec:
    """Output of the BS algorithm: S{t_s, t_e, B} (+ bookkeeping)."""

    t_start: float
    t_end: float
    bandwidth_bps: float
    t_max: float             # T^max relative to round start
    t_min: float             # T^min relative to round start
    tau: float               # slack window length
    feasible: bool           # demanded bandwidth fits the uplink capacity
    demanded_bps: float      # Σ M_i / τ before capping at C
    round_index: int = 1     # h

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start


def nabla(clients: Sequence[ClientProfile], capacity_bps: float) -> float:
    """∇: time to transmit the latest-arriving update.

    Estimated from the straggler's update size at full line rate plus the
    one-way propagation for its distance (paper: "can be estimated based on
    the distance between the ONUs and the OLT").
    """
    if not clients:
        return 0.0
    straggler = max(clients, key=lambda c: c.delta)
    return straggler.m_ud_bits / capacity_bps + straggler.propagation_s


def deadline_bandwidth(
    clients: Sequence[ClientProfile], t_max: float
) -> float:
    """Smallest B such that earliest-ready-first slots all finish by t_max.

    The paper's ``B = Σ M_i / τ`` is a *lower* bound: when client readiness
    is spread out, the slice idles before early deadlines and the last slots
    overrun ``t_max``. The classic feasibility bound fixes this:

        B >= max_k ( Σ_{i : Δ_i >= Δ_(k)} M_i ) / (t_max − Δ_(k))

    (every suffix of later-ready clients must drain in its remaining
    window). We use this sizing by default and record the paper's value in
    ``SliceSpec.demanded_bps`` — a documented beyond-paper correction.
    """
    order = sorted(clients, key=lambda c: c.delta)
    suffix = 0.0
    best = 0.0
    for c in reversed(order):
        suffix += c.m_ud_bits
        remaining = t_max - c.delta
        if remaining <= 0:
            return float("inf")
        best = max(best, suffix / remaining)
    return best


def compute_slice(
    clients: Sequence[ClientProfile],
    t_current: float,
    t_round: float,
    capacity_bps: float,
    h: int = 1,
    sizing: str = "deadline",     # "deadline" (corrected) | "paper" (line 8)
) -> SliceSpec:
    """Algorithm 1 (BS). ``h`` is the number of rounds until the slice is
    first used (1 <= h < H): the slice created now serves round ``h`` ahead.
    """
    if not clients:
        raise ValueError("BS algorithm needs a non-empty client set Φ")
    if h < 1:
        raise ValueError(f"h must be >= 1 (got {h})")

    deltas = sorted((c.delta for c in clients), reverse=True)  # line 4 (sort)
    grad = nabla(clients, capacity_bps)
    t_max = deltas[0] + grad                                   # line 5
    t_min = deltas[-1]                                         # line 6
    tau = max(t_max - t_min, 1e-9)                             # line 7

    total_bits = sum(c.m_ud_bits for c in clients)
    demanded = total_bits / tau                                # line 8
    if sizing == "deadline":
        demanded = max(demanded, deadline_bandwidth(clients, t_max))
    feasible = demanded <= capacity_bps
    bandwidth = min(demanded, capacity_bps)

    # If infeasible at C, the window must widen: uploads still fit within the
    # round as long as total_bits/C <= t_round - t_min (checked by caller via
    # `validate_round_deadline`); the slice then runs at full capacity.
    if not feasible:
        if sizing == "deadline":
            order = sorted(clients, key=lambda c: c.delta)
            suffix = 0.0
            t_needed = t_min
            for c in reversed(order):
                suffix += c.m_ud_bits
                t_needed = max(t_needed, c.delta + suffix / capacity_bps)
            t_max = t_needed
            tau = max(t_max - t_min, 1e-9)
        else:
            tau = total_bits / capacity_bps
            t_max = t_min + tau

    t_s = t_current + t_min + h * t_round                      # line 10
    t_e = t_current + t_max + h * t_round                      # line 9
    return SliceSpec(
        t_start=t_s,
        t_end=t_e,
        bandwidth_bps=bandwidth,
        t_max=t_max,
        t_min=t_min,
        tau=tau,
        feasible=feasible,
        demanded_bps=demanded,
        round_index=h,
    )


def validate_round_deadline(
    clients: Sequence[ClientProfile],
    spec: SliceSpec,
    t_round: float,
    t_aggregate: float = 0.0,
) -> bool:
    """T^round must cover T_i^DL + T_i^UD + T_i^UL + T_a for every client.

    With the slice in place each client's upload finishes by ``t_max`` (its
    slot ends inside the slice), so the condition reduces to
    ``t_max + T_a <= t_round``.
    """
    return spec.t_max + t_aggregate <= t_round


def min_round_time(
    clients: Sequence[ClientProfile],
    capacity_bps: float,
    t_aggregate: float = 0.0,
) -> float:
    """Smallest feasible T^round for this client set (used to set deadlines)."""
    spec = compute_slice(clients, 0.0, 0.0, capacity_bps, h=1)
    return spec.t_max + t_aggregate
