"""Multi-round timeline engine throughput vs the per-round loop.

The paper's Fig. 3 quantities are multi-round: R synchronisation rounds
with elastic client membership. This benchmark drives the (policy ×
load) grid of the Fig. 3 operating point over R rounds two ways —

* ``timeline``: ONE stacked simulation (round axis folded into the
  engine batch, ``repro.net.timeline``);
* ``per-round``: the PR 2 loop — one engine call per round, queue state
  rebuilt every round (what ``FLNetworkCoSim`` did before the timeline
  backend; elastic membership defeats its fixed-client-set cache);

plus timeline rounds/sec at growing ONU counts, and a module-aggregated
profile of the folded run showing where time goes (the counter-based
sampler must not dominate — it replaced numpy draws that were ~1/4 of
engine time).

``python benchmarks/timeline.py --full --json BENCH_timeline.json``
measures the full R=24 sweep and writes the checked-in JSON; the
harness ``run()`` (slow tier — CI runs this module once, via its
dedicated ``BENCH_timeline.json`` step) times a reduced configuration.
"""
from __future__ import annotations

import argparse
import cProfile
import json
import pstats
import time

import numpy as np

from repro.core.slicing import ClientProfile
from repro.net import (
    FLRoundWorkload,
    PONConfig,
    SweepCase,
    SweepSpec,
    TimelineSchedule,
    simulate,
    simulate_timeline_per_round,
)

TIER = "slow"                     # CI's dedicated step runs it instead

M_BITS = 26.416e6
N_ONUS = 128
PARTICIPATION = 0.8
# FL-transfer-dominated operating point: background traffic present but
# light, so the round is governed by the model uploads themselves (the
# regime the paper's slicing argument targets, and where the folded jit
# engine's scalar-S fast path pays off most).
FL_LOAD = 0.05


def _clients(n, seed=42):
    rng = np.random.default_rng(seed)
    t_uds = rng.uniform(1.0, 5.0, n)
    return [
        ClientProfile(client_id=i, t_ud=float(t_uds[i]), t_dl=0.0,
                      m_ud_bits=M_BITS)
        for i in range(n)
    ]


def fig3_cases(n_onus=N_ONUS, loads=(0.3, 0.8), seed=0):
    wl = FLRoundWorkload(clients=_clients(n_onus), model_bits=M_BITS)
    return [
        SweepCase(workload=wl, load=load, policy=policy, seed=seed)
        for policy in ("fcfs", "bs") for load in loads
    ]


def elastic_schedule(n_rounds, n_clients=N_ONUS, seed=7):
    memb = (np.random.default_rng(seed).random((n_rounds, n_clients))
            < PARTICIPATION)
    memb[0] = True
    return TimelineSchedule(n_rounds=n_rounds, membership=memb)


def _best_of(f, repeats):
    best, out = float("inf"), None
    for _ in range(max(repeats, 1)):
        t0 = time.time()
        out = f()
        best = min(best, time.time() - t0)
    return best, out


def profile_shares(cfg, cases, schedule):
    """Module-aggregated tottime of one folded run: the sampler
    (kernels/traffic) vs the engine cycle loop (net/engine+timeline)."""
    prof = cProfile.Profile()
    prof.enable()
    simulate(SweepSpec(cases=tuple(cases), pon=cfg,
                       schedule=schedule, mode="folded"))
    prof.disable()
    stats = pstats.Stats(prof)
    shares = {"kernels/traffic": 0.0, "net/engine": 0.0, "other": 0.0}
    top_name, top_t = "", 0.0
    total = 0.0
    for (fname, _, func), (_, _, tottime, _, _) in stats.stats.items():
        total += tottime
        if "kernels/traffic" in fname:
            shares["kernels/traffic"] += tottime
        elif "net/engine" in fname or "net/timeline" in fname:
            shares["net/engine"] += tottime
        else:
            shares["other"] += tottime
        if tottime > top_t:
            top_t, top_name = tottime, f"{fname.split('/')[-1]}:{func}"
    return {
        "total_s": total,
        "shares": {k: v / max(total, 1e-9) for k, v in shares.items()},
        "top_function": top_name,
        "sampler_is_top_module": (
            shares["kernels/traffic"] >= shares["net/engine"]
        ),
    }


def throughput(n_onus_grid=(128, 512, 2048), n_rounds=4, load=0.8,
               backend=None):
    """Timeline rounds/sec at growing ONU counts (line rate scaled so
    the offered load stays feasible, as in benchmarks/net_engine.py).

    ``backend="jit"`` times the device cycle engine; one untimed
    warm-up run per shape pays the one-compile-per-schedule-shape cost
    up front (the documented usage model), so the rows measure steady
    throughput for both backends alike.
    """
    out = []
    for n in n_onus_grid:
        cfg = PONConfig(n_onus=n, line_rate_bps=10e9 * n / 128)
        wl = FLRoundWorkload(clients=_clients(n), model_bits=M_BITS)
        sched = elastic_schedule(n_rounds, n)
        case = [SweepCase(workload=wl, load=load, policy="fcfs",
                          seed=0)]
        spec = SweepSpec(cases=tuple(case), pon=cfg, schedule=sched,
                         backend=backend)
        if backend is not None:
            simulate(spec)
        t0 = time.time()
        res = simulate(spec)[0]
        wall = time.time() - t0
        out.append({
            "n_onus": n,
            "wall_s": wall,
            "rounds_per_sec": n_rounds / wall,
            "mean_sync_s": float(res.sync_times.mean()),
        })
    return out


def _attach_speedup(jit_rows, numpy_rows):
    """Stamp per-row jit-vs-numpy speedup (matched n_onus)."""
    base = {r["n_onus"]: r["wall_s"] for r in numpy_rows}
    for r in jit_rows:
        if r["n_onus"] in base:
            r["speedup_vs_numpy"] = base[r["n_onus"]] / r["wall_s"]
    return jit_rows


def stacked_run(n_pons=100, onus_per_pon=1024, n_rounds=2,
                load=FL_LOAD):
    """The 100k-ONU x 100-PON stacked device run: every round of every
    PON of the whole deployment folded into ONE jit device program.
    Far beyond interactive numpy reach, so the row records completion
    + throughput of the jit backend only."""
    from repro.net import MultiPonTopology

    n_total = n_pons * onus_per_pon
    cfg = PONConfig(n_onus=onus_per_pon,
                    line_rate_bps=10e9 * onus_per_pon / 128)
    wl = FLRoundWorkload(clients=_clients(onus_per_pon),
                         model_bits=M_BITS)
    topo = MultiPonTopology(n_pons=n_pons)
    sched = elastic_schedule(n_rounds, onus_per_pon)
    cases = [SweepCase(workload=wl, load=load, policy="fcfs", seed=0,
                       topology=topo)]
    t0 = time.time()
    res = simulate(SweepSpec(cases=tuple(cases), pon=cfg,
                             schedule=sched, backend="jit"))[0]
    wall = time.time() - t0
    return {
        "n_onus_total": n_total,
        "n_pons": n_pons,
        "onus_per_pon": onus_per_pon,
        "n_rounds": n_rounds,
        "load": load,
        "completed": len(res.rounds) == n_rounds,
        "wall_s": wall,
        "rounds_per_sec": n_rounds / wall,
        "mean_sync_s": float(res.sync_times.mean()),
    }


def measure(full: bool = False) -> dict:
    """The BENCH_timeline.json payload."""
    n_rounds = 24 if full else 6
    grid = (128, 512, 2048) if full else (128, 512)
    fl_grid = (512, 2048)
    cfg = PONConfig(n_onus=N_ONUS)
    cases = fig3_cases()
    sched = elastic_schedule(n_rounds)
    # warm allocators, jit caches and sampler LUTs
    simulate(SweepSpec(cases=tuple(cases[:1]), pon=cfg,
                       schedule=elastic_schedule(1)))

    fold_wall, fold = _best_of(
        lambda: simulate(SweepSpec(cases=tuple(cases), pon=cfg,
                                   schedule=sched, mode="folded")),
        repeats=3 if full else 2,
    )
    per_round_wall, per_round = _best_of(
        lambda: simulate_timeline_per_round(cfg, cases, sched),
        repeats=2 if full else 1,
    )
    assert all(
        np.allclose(a.sync_times, b.sync_times, rtol=1e-9)
        for a, b in zip(fold, per_round)
    ), "folded and per-round timelines diverged"
    tp = throughput(grid)
    fl_np = throughput(fl_grid, load=FL_LOAD)
    payload = {
        "benchmark": "fig3_multiround_timeline_vs_per_round",
        "n_onus": N_ONUS,
        "n_rounds": n_rounds,
        "participation": PARTICIPATION,
        "sweep_cells": len(cases),
        "timeline_wall_s": fold_wall,
        "per_round_wall_s": per_round_wall,
        "speedup": per_round_wall / fold_wall,
        "rounds_per_sec_sweep": n_rounds * len(cases) / fold_wall,
        "sync_times_s": {
            f"{c.policy}_load{c.load}": [round(float(s), 4)
                                         for s in r.sync_times]
            for c, r in zip(cases, fold)
        },
        "profile": profile_shares(cfg, cases, sched),
        "throughput": tp,
        # backend-keyed rows: the jit regression gate.  Two operating
        # points per backend — the fig3 load (0.8, both engines
        # sampler-bound on CPU, jit must hold parity) and the
        # FL-dominated light load where the device engine's folded
        # scalar-S fast path delivers its >=5x at 2048+ ONUs.
        "fl_load": FL_LOAD,
        "throughput_jit": _attach_speedup(
            throughput(grid, backend="jit"), tp),
        "throughput_fl": fl_np,
        "throughput_fl_jit": _attach_speedup(
            throughput(fl_grid, load=FL_LOAD, backend="jit"), fl_np),
    }
    if full:
        # the 100k-ONU x 100-PON regime: one folded jit device program
        payload["stacked"] = stacked_run()
    return payload


def run() -> list:
    m = measure(full=False)
    rows = [
        {
            "name": "timeline_fig3_multiround_sweep",
            "us_per_call": m["timeline_wall_s"] * 1e6,
            "derived": (
                f"rounds={m['n_rounds']} "
                f"speedup_vs_per_round={m['speedup']:.1f}x "
                f"sampler_share="
                f"{m['profile']['shares']['kernels/traffic']:.2f}"
            ),
        }
    ]
    for key, suffix in (("throughput", ""), ("throughput_jit", "_jit"),
                        ("throughput_fl", "_fl"),
                        ("throughput_fl_jit", "_fl_jit")):
        for tp in m[key]:
            extra = (f" speedup_vs_numpy={tp['speedup_vs_numpy']:.2f}x"
                     if "speedup_vs_numpy" in tp else "")
            rows.append({
                "name": f"timeline_rounds_n{tp['n_onus']}{suffix}",
                "us_per_call": tp["wall_s"] * 1e6,
                "derived": (
                    f"rounds_per_sec={tp['rounds_per_sec']:.2f} "
                    f"mean_sync_s={tp['mean_sync_s']:.2f}" + extra
                ),
            })
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="measure the full R=24 sweep (minutes)")
    ap.add_argument("--json", metavar="PATH",
                    help="write the measurement payload as JSON")
    args = ap.parse_args()
    m = measure(full=args.full)
    print(json.dumps(m, indent=2))
    if args.json:
        from benchmarks._env import stamp

        with open(args.json, "w") as f:
            json.dump(stamp(m), f, indent=2)
            f.write("\n")


if __name__ == "__main__":
    main()
