"""Fig 2(a): learning accuracy vs rounds, per client-involvement fraction.

Real federated training (LEAF-style CNN on synthetic writer-skewed FEMNIST)
— reduced scale for the CPU container: 16 EC clients, fractions
{0.25, 0.5, 1.0}. The paper's qualitative claims: accuracy saturates with
rounds; larger involvement reaches higher saturated accuracy.
"""
from __future__ import annotations

import time

import jax

from repro.data import build_federated_cnn_clients
from repro.fl import CPSServer, SelectionConfig
from repro.fl.client import LocalTrainConfig
from repro.models import cnn

N_CLIENTS = 16
N_ROUNDS = 10
FRACTIONS = (0.25, 0.5, 1.0)


def run() -> list:
    rows = []
    clients, test = build_federated_cnn_clients(
        n_clients=N_CLIENTS,
        samples_per_client=64,
        loss_fn=cnn.loss_fn,
        train_cfg=LocalTrainConfig(lr=0.04, batch_size=16, local_epochs=2),
        seed=0,
    )
    test_batch = {"images": test["images"][:512], "labels": test["labels"][:512]}
    for frac in FRACTIONS:
        params = cnn.init_params(jax.random.PRNGKey(0))
        server = CPSServer(
            global_params=params,
            clients=clients,
            selection=SelectionConfig(strategy="fraction", fraction=frac),
            seed=1,
        )
        t0 = time.time()
        accs = []
        for _ in range(N_ROUNDS):
            log = server.run_round(
                eval_fn=lambda p: cnn.accuracy(p, test_batch)
            )
            accs.append(log.eval_metric)
        wall = time.time() - t0
        rows.append(
            {
                "name": f"fig2a_frac{int(frac*100)}",
                "us_per_call": wall / N_ROUNDS * 1e6,
                "derived": (
                    f"acc_first={accs[0]:.3f} acc_final={accs[-1]:.3f} "
                    f"curve={'/'.join(f'{a:.2f}' for a in accs)}"
                ),
            }
        )
    return rows
