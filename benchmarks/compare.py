"""CI benchmark-regression gate.

Three BENCH_*.json baselines are committed (net engine, timeline,
multi-PON) but were, until now, write-only: nothing compared a fresh
CI measurement against them.  This script extracts *throughput-shaped*
metrics (``rounds_per_sec``, ``speedup*`` — higher is better) from any
of the repo's benchmark artifacts:

* harness artifacts (``benchmarks/run.py --json``): ``rows`` whose
  ``derived`` string carries ``key=value`` tokens;
* measurement payloads (``benchmarks/net_engine.py --json`` etc.):
  known per-benchmark shapes, emitted under the same key names the
  harness rows use, so current-vs-baseline keys line up whenever the
  measured configuration matches (config-dependent one-off numbers —
  e.g. the timeline sweep speedup, whose round count differs between
  the fast tier and ``--full`` — embed the config in the key and
  simply never match).

The gate fails (exit 1) when any matching key regresses by more than
``--threshold`` (default 25%).  Zero matching keys is a wiring error
(exit 2), not a pass — and the same check runs *per baseline file*:
a committed BENCH_*.json whose keys all miss the current metrics would
otherwise silently drop out of the intersection compare() walks, so
adding a new baseline without wiring its producer into CI can never
weaken the gate unnoticed.  Each uncovered file is reported with its
unmatched keys (exit 2).

``--update-baselines`` records the current metrics into
``benchmarks/baseline_overrides.json`` — entries there take precedence
over the committed payloads (the escape hatch for accepted machine or
algorithm changes; commit the file).  ``--self-test`` checks the gate
itself: a synthetic 25%+ regression of the baselines must fail and an
unchanged copy must pass.

Usage (CI)::

    python benchmarks/compare.py \
        --current BENCH_ci.json BENCH_timeline_ci.json \
        --baseline BENCH_net_engine.json BENCH_timeline.json \
                   BENCH_multi_pon.json
    python benchmarks/compare.py --self-test --baseline BENCH_*.json
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys
from typing import Dict, List

_TOKEN = re.compile(r"(rounds_per_sec|speedup\w*)=([0-9.eE+-]+)x?")

OVERRIDES_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                              "baseline_overrides.json")


def _rows_metrics(payload: dict) -> Dict[str, float]:
    out: Dict[str, float] = {}
    for row in payload.get("rows", []):
        derived = str(row.get("derived", ""))
        for key, val in _TOKEN.findall(derived):
            try:
                out[f"{row['name']}.{key}"] = float(val)
            except ValueError:
                continue
    return out


def _payload_metrics(payload: dict) -> Dict[str, float]:
    bench = payload.get("benchmark")
    out: Dict[str, float] = {}
    if bench == "fig2b_sweep_reference_vs_vectorized":
        for key, suffix in (("engine_throughput", ""),
                            ("engine_throughput_jit", "_jit")):
            for tp in payload.get(key, []):
                out[f"net_engine_round_n{tp['n_onus']}{suffix}"
                    f".rounds_per_sec"] = tp["rounds_per_sec"]
    elif bench == "fig3_multiround_timeline_vs_per_round":
        # the sweep speedup depends on the measured round count: key it
        # by config so fast-tier (R=6) and --full (R=24) never collide
        out[f"timeline_fig3_sweep_r{payload['n_rounds']}.speedup"] = (
            payload["speedup"]
        )
        for key, suffix in (("throughput", ""), ("throughput_jit", "_jit"),
                            ("throughput_fl", "_fl"),
                            ("throughput_fl_jit", "_fl_jit")):
            for tp in payload.get(key, []):
                out[f"timeline_rounds_n{tp['n_onus']}{suffix}"
                    f".rounds_per_sec"] = tp["rounds_per_sec"]
        stacked = payload.get("stacked")
        if stacked and stacked.get("completed"):
            out[f"timeline_stacked_n{stacked['n_onus_total']}"
                f"_p{stacked['n_pons']}.rounds_per_sec"] = (
                stacked["rounds_per_sec"]
            )
    elif bench == "async_timeline_policies":
        # the net part runs R=6 in both default and --full modes, so
        # baseline and CI keys match; embedding R in the key makes any
        # future round-count change un-match instead of mis-compare
        r = payload["n_rounds"]
        out[f"async_net_r{r}.rounds_per_sec"] = (
            payload["async_rounds_per_sec"]
        )
        out[f"defer_net_r{r}.rounds_per_sec"] = (
            payload["defer_rounds_per_sec"]
        )
    elif bench == "multi_pon_stacked_vs_per_pon_loop":
        for cell in payload.get("cells", []):
            name = f"multi_pon_round_n{cell['n_onus']}_p{cell['n_pons']}"
            out[f"{name}.rounds_per_sec"] = cell["rounds_per_sec"]
            if "speedup_vs_ref_loop" in cell:
                out[f"{name}.speedup_vs_ref_loop"] = (
                    cell["speedup_vs_ref_loop"]
                )
    elif bench == "multi_job_fairness_grid":
        # J and the fairness policy are embedded in the key so a grid
        # change un-matches instead of mis-comparing
        for cell in payload.get("cells", []):
            name = (f"jobs_grid_n{cell['n_onus']}_j{cell['n_jobs']}"
                    f"_{cell['fairness']}")
            out[f"{name}.rounds_per_sec"] = cell["rounds_per_sec"]
    elif bench == "fault_injection_grid":
        # same names as benchmarks/faults.py's harness rows; the rate
        # grid is embedded in the key so a grid change un-matches
        # instead of mis-comparing
        for cell in payload.get("cells", []):
            name = (f"fault_grid_{cell['mode']}"
                    f"_d{int(cell['dropout_rate'] * 100):02d}"
                    f"_o{int(cell['outage_rate'] * 100):02d}")
            out[f"{name}.rounds_per_sec"] = cell["rounds_per_sec"]
    return out


def extract_metrics(payload: dict) -> Dict[str, float]:
    """Throughput-shaped metrics (higher = better) from any artifact.

    The ``meta`` environment-provenance block (``benchmarks/_env.py``)
    is ignored: machine/stack info never participates in comparisons.
    """
    payload = {k: v for k, v in payload.items() if k != "meta"}
    if "rows" in payload:
        return _rows_metrics(payload)
    return _payload_metrics(payload)


def load_metrics_per_file(paths: List[str]) -> Dict[str, Dict[str, float]]:
    """Per-path metric dicts (the flat merge loses which file
    contributed what — coverage checking needs the attribution)."""
    out: Dict[str, Dict[str, float]] = {}
    for path in paths:
        with open(path) as f:
            payload = json.load(f)
        got = extract_metrics(payload)
        if not got:
            print(f"warning: no throughput metrics in {path}",
                  file=sys.stderr)
        out[path] = got
    return out


def load_metrics(paths: List[str]) -> Dict[str, float]:
    out: Dict[str, float] = {}
    for got in load_metrics_per_file(paths).values():
        out.update(got)
    return out


def check_baseline_coverage(per_file: Dict[str, Dict[str, float]],
                            current: Dict[str, float]) -> List[str]:
    """Error strings for baseline files with zero keys in ``current``.

    ``compare`` only walks the key intersection, so a baseline file
    none of whose keys match contributes nothing — it is dead weight
    that *looks* gated.  That happens exactly when a new BENCH_*.json
    is committed without teaching CI to produce the matching fresh
    measurement; flag it per file (with the orphaned keys) instead of
    letting the global gate quietly shrink.
    """
    errors = []
    for path, keys in per_file.items():
        if keys and not set(keys) & set(current):
            errors.append(
                f"{path}: none of its {len(keys)} baseline keys match "
                f"the current metrics; unmatched keys: {sorted(keys)}"
            )
    return errors


def apply_overrides(baseline: Dict[str, float],
                    path: str = OVERRIDES_PATH) -> Dict[str, float]:
    if os.path.exists(path):
        with open(path) as f:
            overrides = json.load(f)
        baseline = dict(baseline)
        baseline.update({k: float(v) for k, v in overrides.items()})
    return baseline


def compare(current: Dict[str, float], baseline: Dict[str, float],
            threshold: float) -> List[str]:
    """Regression messages for matching keys (empty = gate passes)."""
    regressions = []
    matched = sorted(set(current) & set(baseline))
    if not matched:
        raise SystemExit(
            "benchmark gate mis-wired: no matching keys between current "
            f"metrics ({sorted(current)}) and baselines "
            f"({sorted(baseline)})"
        )
    for key in matched:
        cur, base = current[key], baseline[key]
        if base <= 0:
            continue
        drop = 1.0 - cur / base
        status = "REGRESSION" if drop > threshold else "ok"
        print(f"{status:>10}  {key}: baseline={base:.4g} "
              f"current={cur:.4g} ({-drop:+.1%})")
        if drop > threshold:
            regressions.append(
                f"{key}: {base:.4g} -> {cur:.4g} "
                f"({drop:.1%} > {threshold:.0%} threshold)"
            )
    return regressions


def self_test(baseline: Dict[str, float], threshold: float) -> int:
    """The gate must fail a synthetic 25%+ regression and pass an
    unchanged measurement."""
    degraded = {k: v * (1.0 - threshold - 0.05) for k, v in
                baseline.items()}
    print(f"--- self-test: synthetic {threshold + 0.05:.0%} regression "
          "(must fail) ---")
    if not compare(degraded, baseline, threshold):
        print("self-test FAILED: synthetic regression passed the gate",
              file=sys.stderr)
        return 1
    print("--- self-test: unchanged metrics (must pass) ---")
    if compare(dict(baseline), baseline, threshold):
        print("self-test FAILED: unchanged metrics flagged",
              file=sys.stderr)
        return 1
    print("--- self-test: uncovered baseline file (must be flagged) ---")
    phantom = {"BENCH_phantom.json": {"phantom.rounds_per_sec": 1.0}}
    if not check_baseline_coverage(phantom, dict(baseline)):
        print("self-test FAILED: fully-unmatched baseline file passed "
              "the coverage check", file=sys.stderr)
        return 1
    if check_baseline_coverage({"covered.json": dict(baseline)},
                               baseline):
        print("self-test FAILED: covered baseline file flagged",
              file=sys.stderr)
        return 1
    print("self-test OK: gate rejects regressions, passes parity and "
          "flags uncovered baseline files")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current", nargs="+", default=[],
                    metavar="JSON", help="freshly measured artifacts")
    ap.add_argument("--baseline", nargs="+", required=True,
                    metavar="JSON", help="committed baseline payloads")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max tolerated fractional drop (default 0.25)")
    ap.add_argument("--update-baselines", action="store_true",
                    help="record current metrics as overrides instead "
                         "of failing")
    ap.add_argument("--self-test", action="store_true",
                    help="verify the gate trips on a synthetic "
                         "regression")
    args = ap.parse_args(argv)

    per_file = load_metrics_per_file(args.baseline)
    merged: Dict[str, float] = {}
    for got in per_file.values():
        merged.update(got)
    baseline = apply_overrides(merged)
    if args.self_test:
        return self_test(baseline, args.threshold)
    if not args.current:
        ap.error("--current is required unless --self-test")
    current = load_metrics(args.current)
    if args.update_baselines:
        overrides = {}
        if os.path.exists(OVERRIDES_PATH):
            with open(OVERRIDES_PATH) as f:
                overrides = json.load(f)
        overrides.update(
            {k: current[k] for k in set(current) & set(baseline)}
        )
        with open(OVERRIDES_PATH, "w") as f:
            json.dump(overrides, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {len(overrides)} baseline overrides to "
              f"{OVERRIDES_PATH}")
        return 0
    uncovered = check_baseline_coverage(per_file, current)
    if uncovered:
        print("benchmark gate mis-wired: baseline file(s) contribute "
              "zero matching keys:", file=sys.stderr)
        for err in uncovered:
            print(f"  {err}", file=sys.stderr)
        return 2
    regressions = compare(current, baseline, args.threshold)
    if regressions:
        print("\nbenchmark regressions past the gate threshold:",
              file=sys.stderr)
        for r in regressions:
            print(f"  {r}", file=sys.stderr)
        return 1
    print("benchmark gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
