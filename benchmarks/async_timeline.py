"""Async/stale FL rounds: policy comparison + engine throughput.

Two questions, matching ROADMAP's two closed open items:

* **Engine throughput** (default + ``--full``): at the paper's 0.8-load
  operating point (the Fig. 2b cell whose sync time is pinned at
  5.0581 s), how fast does the timeline engine advance async
  (FedBuff, two engine passes per round) rounds vs the sequential
  deferral loop — simulator rounds/sec for both, plus the *simulated*
  per-round sync times (async rounds fire at the ``buffer_k``-th
  arrival, so their simulated span is a fraction of a full sync
  round).
* **Time-to-target accuracy** (``--full`` only — real CNN training):
  the Fig. 2a-style accuracy-vs-wall-clock comparison across
  sync / defer / drop / partial / async at 0.8 load, via the coupled
  co-simulation (``FLNetworkCoSim.run(mode=..., deadline_s=...,
  deadline_policy=...)``). The committed ``BENCH_async.json`` records
  async reaching the target accuracy in less simulated wall-clock than
  the synchronous baseline.

``python benchmarks/async_timeline.py --full --json BENCH_async.json``
writes the checked-in baseline; the default configuration (CI's
``BENCH_async_ci.json`` step) measures the engine-throughput part only
under the identical network configuration, so the regression-gate keys
line up.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# make `python benchmarks/async_timeline.py` work from anywhere: the
# repo root (the ``benchmarks`` package's parent) must be importable
sys.path.insert(
    0, os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
)

from repro.net import (  # noqa: E402
    FLRoundWorkload,
    PONConfig,
    SweepCase,
    SweepSpec,
    TimelineSchedule,
    simulate,
)

TIER = "slow"                     # CI's dedicated step runs it instead

M_BITS = 26.416e6
N_ONUS = 128
N_CLIENTS = 12
LOAD = 0.8
DEADLINE_S = 4.0
BUFFER_K = 6


def op_point_case(policy: str = "fcfs", seed: int = 1) -> SweepCase:
    """The Fig. 2b 0.8-load operating point (sync pinned 5.0581 s) —
    the same client construction as benchmarks/timeline.py, truncated
    to the op point's 12 involved clients."""
    from benchmarks.timeline import _clients

    wl = FLRoundWorkload(clients=_clients(N_ONUS)[:N_CLIENTS],
                         model_bits=M_BITS)
    return SweepCase(workload=wl, load=LOAD, policy=policy, seed=seed)


def net_part(n_rounds: int) -> dict:
    """Async vs sequential-deferral engine throughput at the op point."""
    cfg = PONConfig(n_onus=N_ONUS)
    case = op_point_case()
    # warm allocators / sampler LUTs
    simulate(SweepSpec(cases=(case,), pon=cfg,
                       schedule=TimelineSchedule(n_rounds=1)))

    out = {"n_rounds": n_rounds, "load": LOAD, "n_onus": N_ONUS,
           "deadline_s": DEADLINE_S, "buffer_k": BUFFER_K}
    t0 = time.time()
    sync = simulate(SweepSpec(
        cases=(case,), pon=cfg,
        schedule=TimelineSchedule(n_rounds=n_rounds),
    ))[0]
    out["sync_wall_s"] = time.time() - t0
    t0 = time.time()
    defer = simulate(SweepSpec(
        cases=(case,), pon=cfg,
        schedule=TimelineSchedule(n_rounds=n_rounds,
                                  deadline_s=DEADLINE_S),
    ))[0]
    defer_wall = time.time() - t0
    t0 = time.time()
    asyn = simulate(SweepSpec(
        cases=(case,), pon=cfg,
        schedule=TimelineSchedule(n_rounds=n_rounds,
                                  buffer_k=BUFFER_K),
    ))[0]
    async_wall = time.time() - t0
    out.update({
        "defer_wall_s": defer_wall,
        "defer_rounds_per_sec": n_rounds / defer_wall,
        "async_wall_s": async_wall,
        "async_rounds_per_sec": n_rounds / async_wall,
        "sim_sync_mean_s": float(sync.sync_times.mean()),
        "sim_defer_mean_s": float(defer.sync_times.mean()),
        "sim_async_mean_s": float(asyn.sync_times.mean()),
        # simulated wall-clock advantage of firing at the k-th arrival
        "sim_async_speedup_vs_sync": float(
            sync.sync_times.mean() / asyn.sync_times.mean()
        ),
        "async_deferrals": int(
            sum(len(r.deferred) for r in asyn.rounds)
        ),
    })
    return out


def accuracy_part(n_rounds: int, target: float = 0.8) -> dict:
    """Time-to-target accuracy across sync/defer/drop/partial/async
    (real CNN co-simulation at 0.8 load)."""
    import jax

    from repro.data import build_federated_cnn_clients
    from repro.fl import CPSServer, SelectionConfig
    from repro.fl.client import LocalTrainConfig
    from repro.fl.simulation import CoSimConfig, FLNetworkCoSim
    from repro.models import cnn

    clients, test = build_federated_cnn_clients(
        n_clients=8, samples_per_client=64, loss_fn=cnn.loss_fn,
        train_cfg=LocalTrainConfig(lr=0.04, batch_size=16,
                                   local_epochs=2),
        seed=0,
    )
    test_batch = {"images": test["images"][:512],
                  "labels": test["labels"][:512]}

    def eval_fn(p):
        return cnn.accuracy(p, test_batch)

    def cosim():
        server = CPSServer(
            global_params=cnn.init_params(jax.random.PRNGKey(0)),
            clients=clients,
            selection=SelectionConfig(strategy="all"),
            seed=1,
        )
        # uploads sized so the 3.5s deadline genuinely cuts a slot
        # mid-transfer (partial fractions in (0, 1), not just 0)
        cfg = CoSimConfig(
            policy="bs", total_load=LOAD, model_bits=2e6,
            upload_bits=3e8, timing_seeds=1,
            pon=PONConfig(n_onus=8, line_rate_bps=1e9),
        )
        return FLNetworkCoSim(server, cfg)

    modes = {
        "sync": {},
        "defer": {"deadline_s": 3.5, "deadline_policy": "defer"},
        "drop": {"deadline_s": 3.5, "deadline_policy": "drop"},
        "partial": {"deadline_s": 3.5, "deadline_policy": "partial"},
        "async": {"mode": "async", "async_buffer": 4},
    }
    cells = {}
    for name, kw in modes.items():
        res = cosim().run(n_rounds, eval_fn=eval_fn, **kw)
        tt = res.time_to_metric(target)
        cells[name] = {
            "total_sim_s": res.total_time_s,
            "time_to_target_s": tt,
            "acc_curve": [round(float(r["eval_metric"]), 3)
                          for r in res.rounds],
            "sync_times_s": [round(float(r["sync_time_s"]), 3)
                             for r in res.rounds],
        }
    return {"target_accuracy": target, "n_rounds": n_rounds,
            "cells": cells}


def measure(full: bool = False) -> dict:
    # the net part runs the SAME configuration with and without --full,
    # so the committed baseline's throughput keys match CI's fresh
    # measurement; --full adds the (minutes-long) accuracy comparison
    payload = {
        "benchmark": "async_timeline_policies",
        **net_part(n_rounds=6),
    }
    if full:
        payload["accuracy"] = accuracy_part(n_rounds=10)
    return payload


def run() -> list:
    m = measure(full=False)
    return [
        {
            "name": "async_timeline_net",
            "us_per_call": m["async_wall_s"] * 1e6,
            "derived": (
                f"async_rounds_per_sec={m['async_rounds_per_sec']:.2f} "
                f"defer_rounds_per_sec={m['defer_rounds_per_sec']:.2f} "
                f"sim_async_speedup={m['sim_async_speedup_vs_sync']:.2f}x"
            ),
        }
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="also run the CNN accuracy comparison (minutes)")
    ap.add_argument("--json", metavar="PATH",
                    help="write the measurement payload as JSON")
    args = ap.parse_args()
    m = measure(full=args.full)
    print(json.dumps(m, indent=2))
    if args.json:
        from benchmarks._env import stamp

        with open(args.json, "w") as f:
            json.dump(stamp(m), f, indent=2)
            f.write("\n")


if __name__ == "__main__":
    main()
