"""Fault-injection chaos benchmark: engine throughput under faults.

How much does deterministic fault injection (``repro.faults``) cost the
timeline engine, and what does it do to the paper's training-time
story? The fast tier measures simulator **rounds/sec** over a
dropout-rate × outage-rate grid in three aggregation modes at the
Fig. 2b 0.8-load operating point:

* ``sync``  — deferral deadline (the PR 5 sequential carry driver,
  now also booking retry-with-backoff entries);
* ``async`` — FedBuff ``buffer_k`` rounds (faulted uploads never count
  toward the buffer);
* ``quorum`` — quorum aggregation (deadline doubles until ``>= q``
  un-faulted arrivals, then degrades).

``--full`` adds a time-to-target-accuracy comparison (real CNN
co-simulation, clean vs faulty vs faulty+quorum) — the chaos
counterpart of ``benchmarks/async_timeline.py``'s accuracy part.

``--gate-overhead`` re-runs the grid's heaviest cell with an enabled
``repro.obs`` collector and exits 1 when instrumenting the fault sweep
costs more than ``--threshold`` (10%) extra wall-clock — the nightly
chaos step's guard that fault/retry/quorum event recording stays cheap.

``python benchmarks/faults.py --json BENCH_faults.json`` writes the
committed baseline; ``benchmarks/compare.py`` gates the per-cell
``rounds_per_sec`` keys against it in CI.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(
    0, os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
)

from benchmarks.async_timeline import (  # noqa: E402
    BUFFER_K,
    DEADLINE_S,
    LOAD,
    N_ONUS,
    op_point_case,
)
from repro.faults import FaultSchedule  # noqa: E402
from repro.net import (  # noqa: E402
    PONConfig,
    SweepSpec,
    TimelineSchedule,
    simulate,
)

TIER = "fast"

THRESHOLD = 0.10                   # obs-overhead gate (chaos nightly)
N_ROUNDS = 6
DROPOUT_RATES = (0.0, 0.2)
OUTAGE_RATES = (0.0, 0.5)


def _schedule(mode: str, n_rounds: int,
              faults: FaultSchedule) -> TimelineSchedule:
    f = None if faults.trivial else faults
    if mode == "sync":
        return TimelineSchedule(n_rounds=n_rounds, deadline_s=DEADLINE_S,
                                faults=f)
    if mode == "async":
        return TimelineSchedule(n_rounds=n_rounds, buffer_k=BUFFER_K,
                                faults=f)
    if mode == "quorum":
        return TimelineSchedule(n_rounds=n_rounds, deadline_s=DEADLINE_S,
                                deadline_policy="drop", faults=f,
                                quorum_frac=0.75)
    raise ValueError(mode)


def _grid_faults(dropout: float, outage: float) -> FaultSchedule:
    return FaultSchedule(seed=3, dropout_rate=dropout, loss_rate=0.0,
                         outage_rate=outage, outage_duration_s=0.5,
                         outage_start_max_s=2.0)


def _best_of(f, repeats):
    best, out = float("inf"), None
    for _ in range(max(repeats, 1)):
        t0 = time.time()
        out = f()
        best = min(best, time.time() - t0)
    return best, out


def grid_part(n_rounds: int, repeats: int = 2) -> dict:
    """Rounds/sec over the dropout × outage grid, per aggregation mode."""
    cfg = PONConfig(n_onus=N_ONUS)
    case = op_point_case()
    # warm allocators / sampler LUTs
    simulate(SweepSpec(cases=(case,), pon=cfg,
                       schedule=TimelineSchedule(n_rounds=1)))

    cells = []
    for dropout in DROPOUT_RATES:
        for outage in OUTAGE_RATES:
            faults = _grid_faults(dropout, outage)
            for mode in ("sync", "async", "quorum"):
                sched = _schedule(mode, n_rounds, faults)
                wall, res = _best_of(
                    lambda s=sched: simulate(SweepSpec(
                        cases=(case,), pon=cfg, schedule=s,
                    )),
                    repeats,
                )
                tl = res[0]
                cells.append({
                    "mode": mode,
                    "dropout_rate": dropout,
                    "outage_rate": outage,
                    "wall_s": wall,
                    "rounds_per_sec": n_rounds / wall,
                    "sim_total_s": float(tl.sync_times.sum()),
                    "n_failed": int(sum(len(r.failed) for r in tl.rounds)),
                    "n_retries": int(
                        sum(len(r.retry_at) for r in tl.rounds)
                    ),
                    "n_extends": int(
                        sum(r.deadline_extensions for r in tl.rounds)
                    ),
                })
    return {"n_rounds": n_rounds, "load": LOAD, "n_onus": N_ONUS,
            "deadline_s": DEADLINE_S, "buffer_k": BUFFER_K,
            "cells": cells}


def accuracy_part(n_rounds: int, target: float = 0.8) -> dict:
    """Time-to-target accuracy, clean vs faulty vs faulty+quorum (real
    CNN coupled co-simulation at 0.8 load; ``--full`` only)."""
    import jax

    from repro.data import build_federated_cnn_clients
    from repro.fl import CPSServer, SelectionConfig
    from repro.fl.client import LocalTrainConfig
    from repro.fl.simulation import CoSimConfig, FLNetworkCoSim
    from repro.models import cnn

    clients, test = build_federated_cnn_clients(
        n_clients=8, samples_per_client=64, loss_fn=cnn.loss_fn,
        train_cfg=LocalTrainConfig(lr=0.04, batch_size=16,
                                   local_epochs=2),
        seed=0,
    )
    test_batch = {"images": test["images"][:512],
                  "labels": test["labels"][:512]}

    def eval_fn(p):
        return cnn.accuracy(p, test_batch)

    faults = FaultSchedule(seed=3, dropout_rate=0.2, loss_rate=0.1,
                           outage_rate=0.5, outage_duration_s=0.5,
                           outage_start_max_s=2.0)

    def cosim(**cfg_kw):
        server = CPSServer(
            global_params=cnn.init_params(jax.random.PRNGKey(0)),
            clients=clients,
            selection=SelectionConfig(strategy="all"),
            seed=1,
        )
        cfg = CoSimConfig(
            policy="bs", total_load=LOAD, model_bits=2e6,
            upload_bits=3e8, timing_seeds=1,
            pon=PONConfig(n_onus=8, line_rate_bps=1e9),
            **cfg_kw,
        )
        return FLNetworkCoSim(server, cfg)

    modes = {
        "clean": ({}, {"deadline_s": 3.5, "deadline_policy": "drop"}),
        "faulty": ({"faults": faults},
                   {"deadline_s": 3.5, "deadline_policy": "drop"}),
        "faulty_quorum": ({"faults": faults, "quorum_frac": 0.5},
                          {"deadline_s": 3.5, "deadline_policy": "drop"}),
    }
    cells = {}
    for name, (cfg_kw, run_kw) in modes.items():
        res = cosim(**cfg_kw).run(n_rounds, eval_fn=eval_fn, **run_kw)
        cells[name] = {
            "total_sim_s": res.total_time_s,
            "time_to_target_s": res.time_to_metric(target),
            "acc_curve": [round(float(r["eval_metric"]), 3)
                          for r in res.rounds],
            "n_failed": int(sum(r.get("n_failed", 0)
                                for r in res.rounds)),
            "n_lost": int(sum(r.get("n_lost", 0) for r in res.rounds)),
        }
    return {"target_accuracy": target, "n_rounds": n_rounds,
            "cells": cells}


def overhead_part(n_rounds: int, repeats: int = 3) -> dict:
    """Enabled-collector overhead on the grid's heaviest cell (dropout
    + outage + quorum: every fault/retry/quorum event path fires)."""
    from repro.obs import Collector

    cfg = PONConfig(n_onus=N_ONUS)
    case = op_point_case()
    sched = _schedule("quorum", n_rounds,
                      _grid_faults(DROPOUT_RATES[-1], OUTAGE_RATES[-1]))
    warm = SweepSpec(cases=(case,), pon=cfg,
                     schedule=TimelineSchedule(n_rounds=1))
    simulate(warm, collector=Collector())

    spec = SweepSpec(cases=(case,), pon=cfg, schedule=sched)
    off_wall, off = _best_of(lambda: simulate(spec), repeats)
    on_wall, on = _best_of(
        lambda: simulate(spec, collector=Collector()), repeats
    )
    assert all(
        np.array_equal(a.sync_times, b.sync_times)
        for a, b in zip(off, on)
    ), "collector changed fault-sweep outputs"
    return {
        "off_wall_s": off_wall,
        "on_wall_s": on_wall,
        "overhead_frac": on_wall / off_wall - 1.0,
        "threshold": THRESHOLD,
    }


def measure(full: bool = False) -> dict:
    # the grid runs the SAME configuration with and without --full so
    # the committed baseline's throughput keys match CI's fresh
    # measurement; --full adds the (minutes-long) accuracy comparison
    payload = {
        "benchmark": "fault_injection_grid",
        **grid_part(n_rounds=N_ROUNDS),
    }
    if full:
        payload["accuracy"] = accuracy_part(n_rounds=10)
    return payload


def run() -> list:
    m = measure(full=False)
    rows = []
    for cell in m["cells"]:
        name = (f"fault_grid_{cell['mode']}"
                f"_d{int(cell['dropout_rate'] * 100):02d}"
                f"_o{int(cell['outage_rate'] * 100):02d}")
        rows.append({
            "name": name,
            "us_per_call": cell["wall_s"] * 1e6,
            "derived": (
                f"rounds_per_sec={cell['rounds_per_sec']:.2f} "
                f"failed={cell['n_failed']} "
                f"retries={cell['n_retries']} "
                f"extends={cell['n_extends']}"
            ),
        })
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true",
                    help="also run the CNN accuracy comparison (minutes)")
    ap.add_argument("--gate-overhead", action="store_true",
                    help="measure collector overhead on the faulty "
                         "quorum sweep and exit 1 past the threshold")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--threshold", type=float, default=THRESHOLD)
    ap.add_argument("--json", metavar="PATH",
                    help="write the measurement payload as JSON")
    args = ap.parse_args(argv)

    m = measure(full=args.full)
    if args.gate_overhead:
        m["obs_overhead"] = overhead_part(N_ROUNDS, repeats=args.repeats)
    print(json.dumps(m, indent=2))
    if args.json:
        from benchmarks._env import stamp

        with open(args.json, "w") as f:
            json.dump(stamp(m), f, indent=2)
            f.write("\n")
    if args.gate_overhead:
        frac = m["obs_overhead"]["overhead_frac"]
        if frac > args.threshold:
            print(
                f"fault-sweep obs overhead gate FAILED: {frac:.1%} > "
                f"{args.threshold:.0%}", file=sys.stderr,
            )
            return 1
        print(f"fault-sweep obs overhead gate passed: {frac:.1%} <= "
              f"{args.threshold:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
