"""Engine throughput + reference-vs-vectorized wall-clock for the Fig 2b sweep.

Records the perf trajectory of the PON co-simulation:

* ``rounds/sec`` of the vectorized engine at n_onus in {128, 512, 2048}
  (line rate scaled with the ONU count so the offered load stays
  feasible and rounds keep the paper's ~5 s shape);
* before/after wall-clock of the full 16-cell Fig 2b sweep — the
  reference cycle-by-cycle simulator vs one stacked engine simulation.

``python benchmarks/net_engine.py --full --json BENCH_net_engine.json``
measures the reference on the *full* sweep (minutes) and writes the
checked-in JSON; the harness ``run()`` times the reference on a single
representative cell so the fast benchmark tier stays fast.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.slicing import ClientProfile
from repro.net import (
    FLRoundWorkload,
    PONConfig,
    SweepCase,
    SweepSpec,
    simulate,
    simulate_round,
)

TIER = "fast"

M_BITS = 26.416e6
N_ONUS = 128


def _clients(n, n_onus, seed=42):
    rng = np.random.default_rng(seed)
    t_uds = rng.uniform(1.0, 5.0, n_onus)
    return [
        ClientProfile(client_id=i, t_ud=float(t_uds[i]), t_dl=0.0,
                      m_ud_bits=M_BITS)
        for i in range(n)
    ]


def _fig2b_cases(seed=1):
    try:
        from benchmarks.fig2b_sync_time import sweep_cases
    except ModuleNotFoundError:  # invoked as a script, not via the harness
        import os
        import sys

        sys.path.insert(
            0, os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        )
        from benchmarks.fig2b_sync_time import sweep_cases

    return sweep_cases(seed=seed)


def time_engine_sweep(cfg=None, cases=None, repeats: int = 3):
    """Best-of-N wall-clock (suppresses machine noise; results from the
    last run — the sweep is deterministic per seed)."""
    cfg = cfg or PONConfig(n_onus=N_ONUS)
    cases = cases or _fig2b_cases()
    best = float("inf")
    for _ in range(max(repeats, 1)):
        t0 = time.time()
        results = simulate(SweepSpec(cases=tuple(cases), pon=cfg))
        best = min(best, time.time() - t0)
    return best, results


def time_reference_sweep(cfg=None, cases=None):
    cfg = cfg or PONConfig(n_onus=N_ONUS)
    cases = cases or _fig2b_cases()
    t0 = time.time()
    results = [
        simulate_round(cfg, c.workload, c.load, c.policy, seed=c.seed,
                       backend="reference")
        for c in cases
    ]
    return time.time() - t0, results


def engine_throughput(n_onus_grid=(128, 512, 2048), policy="fcfs",
                      load=0.8, backend=None):
    """Rounds/sec of a single engine round at growing ONU counts.

    ``backend="jit"`` times the device cycle engine after one untimed
    warm-up run per shape (compile once per shape is the documented
    usage model), so numpy and jit rows measure steady throughput on
    equal terms.
    """
    out = []
    for n in n_onus_grid:
        cfg = PONConfig(n_onus=n, line_rate_bps=10e9 * n / 128)
        wl = FLRoundWorkload(clients=_clients(n, n), model_bits=M_BITS)
        spec = SweepSpec(
            cases=(SweepCase(workload=wl, load=load, policy=policy,
                             seed=0),),
            pon=cfg, backend=backend,
        )
        if backend is not None:
            simulate(spec)
        t0 = time.time()
        r = simulate(spec)[0]
        wall = time.time() - t0
        out.append({
            "n_onus": n,
            "wall_s": wall,
            "rounds_per_sec": 1.0 / wall,
            "sync_s": r.sync_time,
        })
    return out


def _attach_speedup(jit_rows, numpy_rows):
    """Stamp per-row jit-vs-numpy speedup (matched n_onus)."""
    base = {r["n_onus"]: r["wall_s"] for r in numpy_rows}
    for r in jit_rows:
        if r["n_onus"] in base:
            r["speedup_vs_numpy"] = base[r["n_onus"]] / r["wall_s"]
    return jit_rows


def measure(full: bool = False) -> dict:
    """The BENCH_net_engine.json payload."""
    cfg = PONConfig(n_onus=N_ONUS)
    cases = _fig2b_cases()
    # warm up allocators/caches so neither side pays one-time costs
    simulate(SweepSpec(cases=tuple(cases[:1]), pon=cfg))
    eng_wall, eng_results = time_engine_sweep(cfg, cases)
    if full:
        ref_wall, ref_results = time_reference_sweep(cfg, cases)
        ref_cells = len(cases)
        eng_speedup_base = eng_wall / len(cases)
    else:
        # one representative cell (the slowest: fcfs, load 0.8, full
        # involvement) keeps the fast tier fast; the speedup compares
        # BOTH backends on that same cell (like for like) — the
        # checked-in JSON is produced with --full over all 16 cells
        cell = [c for c in cases
                if c.policy == "fcfs" and c.load == 0.8
                and len(c.workload.clients) == N_ONUS]
        ref_wall, ref_results = time_reference_sweep(cfg, cell)
        ref_cells = len(cell)
        eng_cell_wall, _ = time_engine_sweep(cfg, cell, repeats=2)
        eng_speedup_base = eng_cell_wall / len(cell)
    return {
        "benchmark": "fig2b_sweep_reference_vs_vectorized",
        "n_onus": N_ONUS,
        "sweep_cells": len(cases),
        "reference_cells_timed": ref_cells,
        "reference_wall_s": ref_wall,
        "reference_wall_per_cell_s": ref_wall / ref_cells,
        "vectorized_wall_s": eng_wall,
        "vectorized_wall_per_cell_s": eng_wall / len(cases),
        "speedup_per_cell": (
            (ref_wall / ref_cells) / eng_speedup_base
        ),
        "speedup_full_sweep": (
            (ref_wall / ref_cells * len(cases))
            / (eng_speedup_base * len(cases))
        ),
        "sync_times_s": {
            f"{c.policy}_load{c.load}_n{len(c.workload.clients)}":
            r.sync_time
            for c, r in zip(cases, eng_results)
        },
        "engine_throughput": (tp := engine_throughput()),
        # backend-keyed rows: the jit device engine at the same
        # operating point, with per-row speedup vs the numpy rows above
        # (~parity at load 0.8 on CPU — both engines sampler-bound; the
        # FL-dominated wins live in benchmarks/timeline.py)
        "engine_throughput_jit": _attach_speedup(
            engine_throughput(backend="jit"), tp),
    }


def run() -> list:
    m = measure(full=False)
    rows = [
        {
            "name": "net_engine_fig2b_sweep_vectorized",
            "us_per_call": m["vectorized_wall_per_cell_s"] * 1e6,
            "derived": (
                f"sweep_s={m['vectorized_wall_s']:.2f} "
                f"speedup_vs_ref={m['speedup_per_cell']:.1f}x"
            ),
        }
    ]
    for key, suffix in (("engine_throughput", ""),
                        ("engine_throughput_jit", "_jit")):
        for tp in m[key]:
            extra = (f" speedup_vs_numpy={tp['speedup_vs_numpy']:.2f}x"
                     if "speedup_vs_numpy" in tp else "")
            rows.append(
                {
                    "name": f"net_engine_round_n{tp['n_onus']}{suffix}",
                    "us_per_call": tp["wall_s"] * 1e6,
                    "derived": (
                        f"rounds_per_sec={tp['rounds_per_sec']:.2f} "
                        f"sync_s={tp['sync_s']:.2f}" + extra
                    ),
                }
            )
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="time the reference on the full 16-cell sweep")
    ap.add_argument("--json", metavar="PATH",
                    help="write the measurement payload as JSON")
    args = ap.parse_args()
    m = measure(full=args.full)
    print(json.dumps(m, indent=2))
    if args.json:
        from benchmarks._env import stamp

        with open(args.json, "w") as f:
            json.dump(stamp(m), f, indent=2)
            f.write("\n")


if __name__ == "__main__":
    main()
