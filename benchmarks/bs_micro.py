"""Microbenchmarks: the BS algorithm + slot scheduler themselves.

The OLT recomputes the slice on membership change; Algorithm 1 must be
cheap at the 128-ONU scale (and far beyond, for the 1000-node story).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.scheduler import (
    map_to_polling_cycles,
    schedule_slots,
    slots_to_arrays,
)
from repro.core.slicing import ClientProfile, compute_slice

TIER = "fast"

M = 26.416e6


def _clients(n, seed=0):
    rng = np.random.default_rng(seed)
    return [
        ClientProfile(client_id=i, t_ud=float(t), t_dl=0.01, m_ud_bits=M)
        for i, t in enumerate(rng.uniform(1.0, 5.0, n))
    ]


def run() -> list:
    rows = []
    for n in (128, 1024, 4096):
        clients = _clients(n)
        reps = 20 if n <= 1024 else 5
        t0 = time.time()
        for _ in range(reps):
            spec = compute_slice(clients, 0.0, 10.0, 10e9, h=1)
            slots = schedule_slots(clients, spec, 0.0)
        wall = (time.time() - t0) / reps
        rows.append(
            {
                "name": f"bs_algorithm_n{n}",
                "us_per_call": wall * 1e6,
                "derived": f"B_mbps={spec.bandwidth_bps/1e6:.1f} "
                           f"tau_s={spec.tau:.3f} slots={len(slots)}",
            }
        )
    clients = _clients(128)
    spec = compute_slice(clients, 0.0, 10.0, 10e9, h=1)
    slots = schedule_slots(clients, spec, 0.0)
    t0 = time.time()
    grants = map_to_polling_cycles(slots, spec, cycle_time_s=1e-3)
    rows.append(
        {
            "name": "bs_polling_cycle_mapping_n128",
            "us_per_call": (time.time() - t0) * 1e6,
            "derived": f"grants={len(grants)}",
        }
    )
    t0 = time.time()
    arrays = slots_to_arrays(slots)
    rows.append(
        {
            "name": "bs_slots_to_arrays_n128",
            "us_per_call": (time.time() - t0) * 1e6,
            "derived": f"slots={len(arrays['client_id'])}",
        }
    )
    # one full BS round on the vectorized engine (slice + slots + queues)
    from repro.net import FLRoundWorkload, PONConfig, SweepCase, \
        SweepSpec, simulate

    wl = FLRoundWorkload(
        clients=[ClientProfile(client_id=c.client_id, t_ud=c.t_ud,
                               t_dl=0.0, m_ud_bits=c.m_ud_bits)
                 for c in clients],
        model_bits=M,
    )
    t0 = time.time()
    r = simulate(SweepSpec(
        cases=(SweepCase(workload=wl, load=0.8, policy="bs", seed=0),),
        pon=PONConfig(n_onus=128),
    ))[0]
    rows.append(
        {
            "name": "bs_engine_round_n128",
            "us_per_call": (time.time() - t0) * 1e6,
            "derived": f"sync_s={r.sync_time:.3f}",
        }
    )
    return rows
