"""Observability overhead guard: metrics-on vs metrics-off timeline.

The ``repro.obs`` contract is twofold: ``collector=None`` is *bitwise
identical* to an uninstrumented build (tested in ``tests/test_obs.py``)
and an *enabled* collector must stay cheap — the per-cycle accumulators
are vectorized reductions over arrays the engine already computed, so
turning metrics on may not cost more than ``THRESHOLD`` (10%) extra
wall-clock on the folded Fig. 3 timeline sweep.

``python benchmarks/obs_overhead.py --gate`` exits 1 past the
threshold (the CI step); ``--json/--summary/--trace`` write the
measurement payload, the enabled run's ``MetricsReport`` (JSON + CSV
next to it) and its Chrome trace — the artifacts CI uploads.  The
harness ``run()`` (fast tier) reports the overhead as an informational
row; the hard gate lives in the dedicated CI step, where best-of-N
timing is allowed more repeats.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(
    0, os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
)

from benchmarks.timeline import elastic_schedule, fig3_cases  # noqa: E402
from repro.net import PONConfig, SweepSpec, simulate  # noqa: E402

TIER = "fast"

THRESHOLD = 0.10                   # max tolerated enabled/disabled - 1
N_ROUNDS = 6


def _best_of(f, repeats):
    best, out = float("inf"), None
    for _ in range(max(repeats, 1)):
        t0 = time.time()
        out = f()
        best = min(best, time.time() - t0)
    return best, out


def measure(repeats: int = 3, n_rounds: int = N_ROUNDS) -> dict:
    from repro.obs import Collector, SpanTracer

    cfg = PONConfig(n_onus=128)
    cases = fig3_cases()
    sched = elastic_schedule(n_rounds)
    # warm allocators, sampler LUTs and the obs module itself
    simulate(SweepSpec(cases=tuple(cases[:1]), pon=cfg,
                       schedule=elastic_schedule(1)),
             collector=Collector())

    spec = SweepSpec(cases=tuple(cases), pon=cfg, schedule=sched,
                     mode="folded")
    off_wall, off = _best_of(lambda: simulate(spec), repeats)
    collectors = []

    def run_on():
        col = Collector(tracer=SpanTracer())
        collectors.append(col)
        return simulate(spec, collector=col)

    on_wall, on = _best_of(run_on, repeats)
    assert all(
        np.array_equal(a.sync_times, b.sync_times)
        for a, b in zip(off, on)
    ), "collector changed simulation outputs"
    overhead = on_wall / off_wall - 1.0
    return {
        "benchmark": "obs_collector_overhead",
        "n_rounds": n_rounds,
        "sweep_cells": len(cases),
        "repeats": repeats,
        "off_wall_s": off_wall,
        "on_wall_s": on_wall,
        "overhead_frac": overhead,
        "threshold": THRESHOLD,
        "_collector": collectors[-1],   # popped before serialisation
    }


def run() -> list:
    m = measure(repeats=2)
    m.pop("_collector")
    return [{
        "name": "obs_collector_overhead",
        "us_per_call": m["on_wall_s"] * 1e6,
        "derived": (
            f"off_s={m['off_wall_s']:.3f} on_s={m['on_wall_s']:.3f} "
            f"overhead={m['overhead_frac'] * 100:.1f}%"
        ),
    }]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--gate", action="store_true",
                    help="exit 1 when overhead exceeds the threshold")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--threshold", type=float, default=THRESHOLD)
    ap.add_argument("--json", metavar="PATH",
                    help="write the measurement payload as JSON")
    ap.add_argument("--summary", metavar="PATH",
                    help="write the enabled run's MetricsReport JSON "
                         "(+ .csv next to it)")
    ap.add_argument("--trace", metavar="PATH",
                    help="write the enabled run's Chrome trace JSON")
    args = ap.parse_args(argv)

    m = measure(repeats=args.repeats)
    col = m.pop("_collector")
    print(json.dumps(m, indent=2))
    if args.json:
        from benchmarks._env import stamp

        with open(args.json, "w") as f:
            json.dump(stamp(m), f, indent=2)
            f.write("\n")
    if args.summary:
        report = col.report()
        report.save_json(args.summary)
        report.save_csv(args.summary.rsplit(".", 1)[0] + ".csv")
    if args.trace:
        col.tracer.save(args.trace)
    if args.gate and m["overhead_frac"] > args.threshold:
        print(
            f"obs overhead gate FAILED: {m['overhead_frac']:.1%} > "
            f"{args.threshold:.0%} (off={m['off_wall_s']:.3f}s "
            f"on={m['on_wall_s']:.3f}s)",
            file=sys.stderr,
        )
        return 1
    if args.gate:
        print(f"obs overhead gate passed: {m['overhead_frac']:.1%} <= "
              f"{args.threshold:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
