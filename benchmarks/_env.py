"""Environment provenance stamped into benchmark artifacts.

Every BENCH_*.json payload carries a ``meta`` block (jax version,
backend, devices, host platform) so a number can be traced to the
machine and stack that produced it.  The regression gate
(``benchmarks/compare.py``) extracts only throughput metrics and
ignores the block entirely — metadata never participates in
comparisons.
"""
from __future__ import annotations

import platform


def env_metadata() -> dict:
    """jax/backend/device + host info, best-effort (never raises)."""
    meta = {
        "python": platform.python_version(),
        "platform": platform.platform(),
        "machine": platform.machine(),
    }
    try:
        import jax

        meta["jax"] = jax.__version__
        meta["backend"] = jax.default_backend()
        devices = jax.devices()
        meta["device_count"] = len(devices)
        meta["device_kind"] = devices[0].device_kind if devices else None
    except Exception as e:  # pragma: no cover - jax-less environments
        meta["jax_error"] = f"{type(e).__name__}: {e}"
    try:
        import numpy as np

        meta["numpy"] = np.__version__
    except Exception:  # pragma: no cover
        pass
    return meta


def stamp(payload: dict) -> dict:
    """Attach ``meta`` to a benchmark payload (in place, returned)."""
    payload.setdefault("meta", env_metadata())
    return payload
