"""The headline claim: training-time saving of BS vs FCFS at load 0.8.

Two estimates:
  * event-sim: rounds x simulated sync time from the cycle-level PON
    simulator (conservative for FCFS — see EXPERIMENTS.md discussion);
  * serialized-residual analytic model: both FL transfer phases drain at the
    residual rate (eff - load)·C — this is the model that matches the
    paper's own Fig 2(b) numbers (~6 s @ 0.3, ~8.4 s @ 0.8) and reproduces
    its 36% saving.

Same number of rounds for both policies (identical learning dynamics —
FedAvg does not depend on the transport), so the saving is purely
per-round sync time.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.slicing import ClientProfile
from repro.net import (
    FLRoundWorkload,
    PONConfig,
    SweepCase,
    SweepSpec,
    TimelineSchedule,
    simulate,
)

TIER = "fast"

M_BITS = 26.416e6
N_ONUS = 128
LOAD = 0.8
SEEDS = 2
N_ROUNDS = 8                      # multi-round (Fig. 3) estimate


def _mk_clients(seed=42):
    rng = np.random.default_rng(seed)
    t_uds = rng.uniform(1.0, 5.0, N_ONUS)
    return [
        ClientProfile(client_id=i, t_ud=float(t_uds[i]), t_dl=0.0,
                      m_ud_bits=M_BITS)
        for i in range(N_ONUS)
    ]


def analytic_serialized(clients, load, cfg: PONConfig):
    """DL_all + max T_UD + UL_all at the residual rate (1-load)·C.

    This is the model that reproduces the paper's own Fig 2(b) magnitudes
    (~6 s @ load 0.3, ~8.4 s @ 0.8) and its 36%-class saving.
    """
    residual = max((1.0 - load), 0.02) * cfg.line_rate_bps
    total_bits = sum(c.m_ud_bits for c in clients)
    phase = total_bits / residual
    return phase + max(c.t_ud for c in clients) + phase


def analytic_bs(clients, cfg: PONConfig):
    from repro.core.round_model import bs_round_time

    return bs_round_time(
        clients, cfg.line_rate_bps * cfg.efficiency
    ).sync_time


def run() -> list:
    cfg = PONConfig(n_onus=N_ONUS)
    clients = _mk_clients()
    wl = FLRoundWorkload(clients=clients, model_bits=M_BITS)
    t0 = time.time()

    # both policies x all seeds as one stacked engine simulation
    cases = [
        SweepCase(workload=wl, load=LOAD, policy=policy, seed=s)
        for policy in ("fcfs", "bs") for s in range(SEEDS)
    ]
    results = simulate(SweepSpec(cases=tuple(cases), pon=cfg))
    sim_fcfs = np.mean([r.sync_time for r in results[:SEEDS]])
    sim_bs = np.mean([r.sync_time for r in results[SEEDS:]])
    an_fcfs = analytic_serialized(clients, LOAD, cfg)
    an_bs = analytic_bs(clients, cfg)
    wall = time.time() - t0

    # Fig. 3 view: R rounds as one stacked timeline per (policy, seed);
    # the saving compounds over the whole training wall-clock
    t1 = time.time()
    sched = TimelineSchedule(n_rounds=N_ROUNDS)
    tl = simulate(SweepSpec(cases=tuple(cases),
                            pon=PONConfig(n_onus=N_ONUS),
                            schedule=sched))
    total_fcfs = np.mean([r.total_time_s for r in tl[:SEEDS]])
    total_bs = np.mean([r.total_time_s for r in tl[SEEDS:]])
    save_multi = 100.0 * (1 - total_bs / total_fcfs)
    wall_tl = time.time() - t1

    save_sim = 100.0 * (1 - sim_bs / sim_fcfs)
    save_an = 100.0 * (1 - an_bs / an_fcfs)
    return [
        {
            "name": f"time_saving_timeline_{N_ROUNDS}rounds_load0.8",
            "us_per_call": wall_tl * 1e6 / (2 * SEEDS),
            "derived": (
                f"fcfs_total_s={total_fcfs:.2f} "
                f"bs_total_s={total_bs:.2f} "
                f"saving_pct={save_multi:.1f}"
            ),
        },
        {
            "name": "time_saving_eventsim_load0.8",
            "us_per_call": wall * 1e6 / 4,
            "derived": (
                f"fcfs_s={sim_fcfs:.3f} bs_s={sim_bs:.3f} "
                f"saving_pct={save_sim:.1f}"
            ),
        },
        {
            "name": "time_saving_analytic_load0.8",
            "us_per_call": 0.0,
            "derived": (
                f"fcfs_s={an_fcfs:.3f} bs_s={an_bs:.3f} "
                f"saving_pct={save_an:.1f} (paper: 36)"
            ),
        },
    ]
