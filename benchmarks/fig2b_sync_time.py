"""Fig 2(b): involvement fraction vs synchronisation time, FCFS vs BS.

The paper's exact network setting: 128 ONUs/EC nodes, 10 Gbps, 20 km,
26.416 Mbit updates, T_i^UD ~ U[1, 5] s; loads 0.3 and 0.8 for the FCFS
benchmark, BS for the proposal. Claims reproduced: FCFS sync grows with
load; BS is pinned at the compute bound, independent of load.

The whole (policy x load x fraction) grid runs as ONE stacked simulation
on the vectorized engine (``repro.net.engine``).

The sweep runs under a ``repro.obs.Collector``, so beyond the per-cell
sync times it reports the FL upload-delay *distribution* per
(policy, load) — p50/p95/p99 from the engine's streaming histograms —
the tail-latency view the paper's mean-only Fig. 2b hides.  (The
percentile tokens never match the regression gate's throughput regex;
they are informational rows.)
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.slicing import ClientProfile
from repro.net import (
    FLRoundWorkload,
    PONConfig,
    SweepCase,
    SweepSpec,
    simulate,
)

TIER = "fast"

M_BITS = 26.416e6
N_ONUS = 128
FRACTIONS = (0.1, 0.4, 0.7, 1.0)
GRID = (("fcfs", 0.3), ("fcfs", 0.8), ("bs", 0.3), ("bs", 0.8))


def _clients(n, seed=42):
    rng = np.random.default_rng(seed)
    t_uds = rng.uniform(1.0, 5.0, N_ONUS)
    return [
        ClientProfile(client_id=i, t_ud=float(t_uds[i]), t_dl=0.0,
                      m_ud_bits=M_BITS)
        for i in range(n)
    ]


def sweep_cases(seed: int = 1) -> list:
    cases = []
    for policy, load in GRID:
        for frac in FRACTIONS:
            n = max(1, int(frac * N_ONUS))
            wl = FLRoundWorkload(clients=_clients(n), model_bits=M_BITS)
            cases.append(
                SweepCase(workload=wl, load=load, policy=policy, seed=seed)
            )
    return cases


def run() -> list:
    from repro.obs import Collector

    cfg = PONConfig(n_onus=N_ONUS)
    cases = sweep_cases()
    collector = Collector(keep_phases=False)
    t0 = time.time()
    results = simulate(SweepSpec(cases=tuple(cases), pon=cfg),
                       collector=collector)
    wall = time.time() - t0
    rows = []
    tags = [(policy, load, frac) for policy, load in GRID
            for frac in FRACTIONS]          # same order as sweep_cases()
    for (policy, load, frac), r in zip(tags, results):
        rows.append(
            {
                "name": (
                    f"fig2b_{policy}_load{load}_inv{int(frac * 100)}"
                ),
                "us_per_call": wall * 1e6 / len(cases),
                "derived": (
                    f"sync_s={r.sync_time:.3f} "
                    f"compute_bound_s={r.compute_bound:.3f} "
                    f"comm_s={r.comm_overhead:.3f}"
                ),
            }
        )
    # upload-delay distribution per (policy, load), pooled over the
    # involvement fractions — the engine's streaming histograms
    for (policy, load), hist in sorted(collector.delay_hist.items()):
        s = hist.summary()
        rows.append(
            {
                "name": f"fig2b_ul_delay_{policy}_load{load:g}",
                "us_per_call": wall * 1e6 / len(cases),
                "derived": (
                    f"n={int(s['n'])} "
                    f"ul_p50_s={s['p50']:.3f} "
                    f"ul_p95_s={s['p95']:.3f} "
                    f"ul_p99_s={s['p99']:.3f} "
                    f"ul_mean_s={s['mean']:.3f}"
                ),
            }
        )
    return rows
