"""Fig 2(b): involvement fraction vs synchronisation time, FCFS vs BS.

The paper's exact network setting: 128 ONUs/EC nodes, 10 Gbps, 20 km,
26.416 Mbit updates, T_i^UD ~ U[1, 5] s; loads 0.3 and 0.8 for the FCFS
benchmark, BS for the proposal. Claims reproduced: FCFS sync grows with
load; BS is pinned at the compute bound, independent of load.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.slicing import ClientProfile
from repro.net import FLRoundWorkload, PONConfig, simulate_round

M_BITS = 26.416e6
N_ONUS = 128
FRACTIONS = (0.1, 0.4, 0.7, 1.0)


def _clients(n, seed=42):
    rng = np.random.default_rng(seed)
    t_uds = rng.uniform(1.0, 5.0, N_ONUS)
    return [
        ClientProfile(client_id=i, t_ud=float(t_uds[i]), t_dl=0.0,
                      m_ud_bits=M_BITS)
        for i in range(n)
    ]


def run() -> list:
    cfg = PONConfig(n_onus=N_ONUS)
    rows = []
    for policy, load in (("fcfs", 0.3), ("fcfs", 0.8), ("bs", 0.3),
                         ("bs", 0.8)):
        for frac in FRACTIONS:
            n = max(1, int(frac * N_ONUS))
            wl = FLRoundWorkload(clients=_clients(n), model_bits=M_BITS)
            t0 = time.time()
            r = simulate_round(cfg, wl, load, policy, seed=1)
            wall = time.time() - t0
            rows.append(
                {
                    "name": f"fig2b_{policy}_load{load}_inv{int(frac*100)}",
                    "us_per_call": wall * 1e6,
                    "derived": (
                        f"sync_s={r.sync_time:.3f} "
                        f"compute_bound_s={r.compute_bound:.3f} "
                        f"comm_s={r.comm_overhead:.3f}"
                    ),
                }
            )
    return rows
