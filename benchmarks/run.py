"""Benchmark harness: one module per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV (one row per measurement).

``--tier fast`` runs only the cheap tier (module attribute
``TIER == "fast"``; training/roofline modules are the slow tier);
``--json out.json`` additionally writes the rows (plus environment
metadata) as JSON — the artifact CI uploads; ``--profile`` stamps a
per-stage (per-module) wall-time breakdown into the payload ``meta``
block, which ``compare.py`` ignores (provenance, never a gated metric).
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time
import traceback

# make `python benchmarks/run.py` work from anywhere: the repo root (the
# ``benchmarks`` package's parent) must be importable
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def collect_modules(tier: str):
    from benchmarks import (
        async_timeline,
        bs_micro,
        faults,
        fig2a_accuracy,
        fig2b_sync_time,
        jobs,
        multi_pon,
        net_engine,
        obs_overhead,
        roofline_report,
        timeline,
        training_time_saving,
    )

    # sorted by name so the row order (and CI log diff) is deterministic
    # regardless of how modules get added to this list
    modules = sorted(
        [
            ("bs_micro", bs_micro),
            ("fig2b_sync_time", fig2b_sync_time),
            ("training_time_saving", training_time_saving),
            ("net_engine", net_engine),
            ("multi_pon", multi_pon),
            ("jobs", jobs),
            ("timeline", timeline),
            ("async_timeline", async_timeline),
            ("faults", faults),
            ("obs_overhead", obs_overhead),
            ("fig2a_accuracy", fig2a_accuracy),
            ("roofline_report", roofline_report),
        ]
    )
    if tier == "all":
        return modules
    return [
        (name, mod) for name, mod in modules
        if getattr(mod, "TIER", "slow") == tier
    ]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tier", choices=("fast", "slow", "all"),
                    default="all", help="which benchmark tier to run")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write rows + metadata as JSON")
    ap.add_argument("--profile", action="store_true",
                    help="stamp per-stage wall-time breakdown into the "
                         "JSON meta block (compare.py-ignored)")
    args = ap.parse_args(argv)

    modules = collect_modules(args.tier)
    print("name,us_per_call,derived")
    rows = []
    profile: dict = {}
    failures = 0
    for name, mod in modules:
        t0 = time.time()
        try:
            for row in mod.run():
                derived = str(row["derived"]).replace(",", ";")
                print(f"{row['name']},{row['us_per_call']:.1f},{derived}",
                      flush=True)
                rows.append({**row, "module": name})
        except Exception as e:  # pragma: no cover
            failures += 1
            traceback.print_exc()
            print(f"{name},0,ERROR: {type(e).__name__}: {e}", flush=True)
            rows.append({"name": name, "us_per_call": 0.0, "module": name,
                         "derived": f"ERROR: {type(e).__name__}: {e}"})
        finally:
            wall = time.time() - t0
            profile[name] = wall
            rows.append({
                "name": f"{name}__module_wall",
                "us_per_call": wall * 1e6,
                "derived": "module wall-clock",
                "module": name,
            })
    if args.profile:
        total = sum(profile.values()) or 1.0
        print("--- profile (wall per stage) ---", file=sys.stderr)
        for name, wall in sorted(profile.items(), key=lambda kv: -kv[1]):
            print(f"{name:<24s} {wall:8.2f}s  {wall / total:6.1%}",
                  file=sys.stderr)
    if args.json:
        from benchmarks._env import env_metadata

        meta = env_metadata()
        try:
            from repro.analysis import ANALYSIS_VERSION
        except ImportError:  # src/ not on the path — provenance only
            ANALYSIS_VERSION = None
        meta["analysis"] = {"version": ANALYSIS_VERSION}
        if args.profile:
            # provenance only: compare.py drops the whole meta block, so
            # the breakdown can never become a gated (noisy) metric
            meta["profile"] = {
                "total_wall_s": sum(profile.values()),
                "stage_wall_s": {k: round(v, 4)
                                 for k, v in profile.items()},
            }
        payload = {
            "tier": args.tier,
            "python": platform.python_version(),
            "platform": platform.platform(),
            "meta": meta,
            "rows": rows,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"wrote {args.json} ({len(rows)} rows)", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
