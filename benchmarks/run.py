"""Benchmark harness: one module per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV (one row per measurement).
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        bs_micro,
        fig2a_accuracy,
        fig2b_sync_time,
        roofline_report,
        training_time_saving,
    )

    modules = [
        ("bs_micro", bs_micro),
        ("fig2b_sync_time", fig2b_sync_time),
        ("training_time_saving", training_time_saving),
        ("fig2a_accuracy", fig2a_accuracy),
        ("roofline_report", roofline_report),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        try:
            for row in mod.run():
                derived = str(row["derived"]).replace(",", ";")
                print(f"{row['name']},{row['us_per_call']:.1f},{derived}",
                      flush=True)
        except Exception as e:  # pragma: no cover
            failures += 1
            traceback.print_exc()
            print(f"{name},0,ERROR: {type(e).__name__}: {e}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
