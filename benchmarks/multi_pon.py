"""Multi-PON stacked engine vs the per-PON Python loops.

The 1000+-ONU story: ``n_pons`` wavelength/OLT segments (each sized
like the paper's PON) simulated as ONE stacked engine call with
``(case, pon)`` rows, against two per-PON Python loops:

* ``ref_loop`` — the cycle-by-cycle per-PON reference loop + CPS
  post-pass (``simulate_multi_pon_round``, the parity oracle): the
  semantically identical baseline, consuming the same pon-keyed
  counter streams, so its results must match the stacked engine at
  rtol 1e-6 (asserted).  This is the honest "what stacking replaces"
  number — a dict simulator looping PONs inside a Python cycle loop.
* ``loop`` — a Python loop of one *vectorized* single-PON engine call
  per segment (each segment remapped to a standalone PON; streams
  keyed ``pon=0`` per call, so agreement is statistical, asserted
  loosely).  This isolates the pure batching dividend of folding the
  PON axis, with the engine's array kernels on both sides.

Cells: ``n_onus`` (total) x ``n_pons``; each PON carries
``n_onus / n_pons`` ONUs at a line rate scaled so the offered load
stays feasible and rounds keep the paper's ~5 s shape (as in
``benchmarks/net_engine.py``).

``python benchmarks/multi_pon.py --full --json BENCH_multi_pon.json``
measures the full {1024, 2048, 4096} x {8, 16, 32} grid (reference
loop at the 4096-ONU acceptance cell; minutes) and writes the
checked-in JSON; the harness ``run()`` (fast tier) times the small
(256, 4) cell that the CI benchmark-regression gate compares against
the committed baseline.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.slicing import ClientProfile
from repro.net import (
    FLRoundWorkload,
    MultiPonTopology,
    PONConfig,
    SweepCase,
    SweepSpec,
    simulate,
    simulate_multi_pon_round,
)

TIER = "fast"

M_BITS = 26.416e6
LOAD = 0.8
POLICY = "fcfs"

FAST_CELL = (256, 4)
FULL_GRID = [(n, p) for n in (1024, 2048, 4096) for p in (8, 16, 32)]


def _t_uds(n_total, seed=42):
    return np.random.default_rng(seed).uniform(1.0, 5.0, n_total)


def _pon_cfg(n_total, n_pons):
    n_local = n_total // n_pons
    return PONConfig(n_onus=n_local, line_rate_bps=10e9 * n_local / 128)


def _stacked_case(n_total, n_pons, seed=0):
    t_uds = _t_uds(n_total)
    clients = [
        ClientProfile(client_id=i, t_ud=float(t_uds[i]), t_dl=0.0,
                      m_ud_bits=M_BITS)
        for i in range(n_total)
    ]
    wl = FLRoundWorkload(clients=clients, model_bits=M_BITS)
    return SweepCase(workload=wl, load=LOAD, policy=POLICY, seed=seed,
                     topology=MultiPonTopology(n_pons=n_pons))


def run_stacked(n_total, n_pons, seed=0):
    cfg = _pon_cfg(n_total, n_pons)
    case = _stacked_case(n_total, n_pons, seed)
    t0 = time.time()
    res = simulate(SweepSpec(cases=(case,), pon=cfg))[0]
    return time.time() - t0, res


def run_per_pon_loop(n_total, n_pons, seed=0):
    """The pre-stacking alternative: one single-PON engine call per
    wavelength segment, segment clients remapped to a standalone PON."""
    cfg = _pon_cfg(n_total, n_pons)
    n_local = cfg.n_onus
    t_uds = _t_uds(n_total)
    t0 = time.time()
    sync = 0.0
    for p in range(n_pons):
        ids = range(p * n_local, (p + 1) * n_local)
        clients = [
            ClientProfile(client_id=i - p * n_local,
                          t_ud=float(t_uds[i]), t_dl=0.0,
                          m_ud_bits=M_BITS)
            for i in ids
        ]
        wl = FLRoundWorkload(clients=clients, model_bits=M_BITS)
        r = simulate(SweepSpec(
            cases=(SweepCase(workload=wl, load=LOAD, policy=POLICY,
                             seed=seed),),
            pon=cfg,
        ))[0]
        sync = max(sync, r.sync_time)
    return time.time() - t0, sync


def run_reference_loop(n_total, n_pons, seed=0):
    """The parity oracle: per-PON dict-simulator loop + CPS post-pass,
    on the identical pon-keyed counter streams."""
    cfg = _pon_cfg(n_total, n_pons)
    case = _stacked_case(n_total, n_pons, seed)
    t0 = time.time()
    res = simulate_multi_pon_round(
        cfg, case.topology, case.workload, case.load, case.policy,
        seed=seed,
    )
    return time.time() - t0, res


def measure_cell(n_total, n_pons, with_loop: bool,
                 with_ref_loop: bool = False) -> dict:
    wall, res = run_stacked(n_total, n_pons)
    cell = {
        "n_onus": n_total,
        "n_pons": n_pons,
        "onus_per_pon": n_total // n_pons,
        "stacked_wall_s": wall,
        "rounds_per_sec": 1.0 / wall,
        "sync_s": res.sync_time,
    }
    if with_loop:
        loop_wall, loop_sync = run_per_pon_loop(n_total, n_pons)
        cell["loop_wall_s"] = loop_wall
        cell["speedup_vs_loop"] = loop_wall / wall
        # different (pon-keyed vs pon-0) streams: statistical agreement
        assert abs(loop_sync - res.sync_time) / res.sync_time < 0.10, (
            f"stacked sync {res.sync_time} vs loop sync {loop_sync}"
        )
    if with_ref_loop:
        ref_wall, ref = run_reference_loop(n_total, n_pons)
        cell["ref_loop_wall_s"] = ref_wall
        cell["speedup_vs_ref_loop"] = ref_wall / wall
        # identical streams: the oracle must agree to the float
        assert abs(ref.sync_time - res.sync_time) <= (
            1e-6 * res.sync_time
        ), f"stacked sync {res.sync_time} vs oracle {ref.sync_time}"
    return cell


def cps_contention_demo(n_total=256, n_pons=4, provisioning=0.9) -> dict:
    """Sync-time shift when the shared CPS uplink actually binds: the
    same workload under an uncontended vs a 90%-provisioned CPS (still
    above the ~80% sustained offered load, so the queues stay stable
    and the CPS binds only on the bursts and the FL upload wave)."""
    cfg = _pon_cfg(n_total, n_pons)
    case = _stacked_case(n_total, n_pons)
    free = simulate(SweepSpec(cases=(case,), pon=cfg))[0]
    tight_rate = provisioning * n_pons * cfg.line_rate_bps
    tight_topo = MultiPonTopology(n_pons=n_pons, cps_rate_bps=tight_rate)
    tight = simulate(SweepSpec(
        cases=(SweepCase(workload=case.workload, load=LOAD,
                         policy=POLICY, seed=case.seed,
                         topology=tight_topo),),
        pon=cfg,
    ))[0]
    return {
        "n_onus": n_total,
        "n_pons": n_pons,
        "cps_provisioning": provisioning,
        "sync_uncontended_s": free.sync_time,
        "sync_contended_s": tight.sync_time,
        "sync_stretch": tight.sync_time / free.sync_time,
    }


def measure(full: bool = False) -> dict:
    # warm allocators, jit caches and sampler LUTs
    simulate(SweepSpec(cases=(_stacked_case(64, 2),),
                       pon=_pon_cfg(64, 2)))
    cells = [measure_cell(*FAST_CELL, with_loop=True,
                          with_ref_loop=True)]
    if full:
        for n, p in FULL_GRID:
            # both loop baselines at 4096 — the acceptance cells; the
            # reference loop (minutes) only at the headline 32-PON cell
            cells.append(measure_cell(
                n, p, with_loop=(n == 4096),
                with_ref_loop=(n == 4096 and p == 32),
            ))
    return {
        "benchmark": "multi_pon_stacked_vs_per_pon_loop",
        "load": LOAD,
        "policy": POLICY,
        "m_ud_bits": M_BITS,
        "cells": cells,
        "cps_demo": cps_contention_demo(),
    }


def run() -> list:
    m = measure(full=False)
    rows = []
    for cell in m["cells"]:
        # the (256, 4) cell's engine-loop speedup (~1.2x) is too close
        # to 1 to gate at a 25% threshold without flakes, so only
        # rounds/sec and the (machine-ratio, noise-robust) reference-
        # loop speedup become gated tokens
        derived = (
            f"rounds_per_sec={cell['rounds_per_sec']:.3f} "
            f"sync_s={cell['sync_s']:.2f} "
            f"loop_x{cell.get('speedup_vs_loop', 0.0):.2f}"
        )
        if "speedup_vs_ref_loop" in cell:
            derived += (
                f" speedup_vs_ref_loop="
                f"{cell['speedup_vs_ref_loop']:.1f}x"
            )
        rows.append({
            "name": (f"multi_pon_round_n{cell['n_onus']}"
                     f"_p{cell['n_pons']}"),
            "us_per_call": cell["stacked_wall_s"] * 1e6,
            "derived": derived,
        })
    demo = m["cps_demo"]
    rows.append({
        "name": "multi_pon_cps_contention",
        "us_per_call": 0.0,
        "derived": f"sync_stretch={demo['sync_stretch']:.3f}",
    })
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="measure the full 1024-4096 x 8-32 grid")
    ap.add_argument("--json", metavar="PATH",
                    help="write the measurement payload as JSON")
    args = ap.parse_args()
    m = measure(full=args.full)
    print(json.dumps(m, indent=2))
    if args.json:
        from benchmarks._env import stamp

        with open(args.json, "w") as f:
            json.dump(stamp(m), f, indent=2)
            f.write("\n")


if __name__ == "__main__":
    main()
