"""Roofline summary rows from recorded dry-run JSONL (if present)."""
from __future__ import annotations

import glob
import os

from repro.launch.roofline import load_rows

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def run() -> list:
    paths = sorted(glob.glob(os.path.join(RESULTS, "dryrun_*_final.jsonl")))
    if not paths:
        paths = sorted(glob.glob(os.path.join(RESULTS, "dryrun_*.jsonl")))
    if not paths:
        return [
            {
                "name": "roofline_report",
                "us_per_call": 0.0,
                "derived": "no dryrun records; run repro.launch.dryrun first",
            }
        ]
    rows = load_rows(paths)
    out = []
    for r in sorted(rows, key=lambda r: (r.mesh, r.arch, r.shape)):
        out.append(
            {
                "name": f"roofline_{r.arch}_{r.shape}_{r.mesh}",
                "us_per_call": r.compute_s * 1e6,
                "derived": (
                    f"compute_s={r.compute_s:.4f} memory_s={r.memory_s:.4f} "
                    f"coll_s={r.collective_s:.4f} dominant={r.dominant} "
                    f"useful={r.useful_ratio:.2f} "
                    f"roofline_frac={r.roofline_fraction:.3f}"
                ),
            }
        )
    return out
