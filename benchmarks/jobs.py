"""Multi-tenant contention grid: J concurrent jobs on one 2048-ONU PON.

One stacked BS round per cell over jobs ∈ {1, 2, 4, 8} × fairness ∈
{maxmin, weighted}: the primary FL job plus J-1 half-sized tenants
contend for the same cycles, and the row records engine throughput
(the multi-job path is numpy — the jit ponsim backend covers
single-tenant sweeps only) plus each job's p95 upload-completion time
through a ``repro.obs`` collector, the hierarchical-slicing
degradation signal CI tracks.

``python benchmarks/jobs.py --json BENCH_jobs.json`` writes the
payload ``benchmarks/compare.py`` gates on
(``jobs_grid_n{N}_j{J}_{fairness}.rounds_per_sec``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(
    0, os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
)

from repro.core.slicing import ClientProfile  # noqa: E402
from repro.net import (  # noqa: E402
    FLRoundWorkload,
    JobSpec,
    PONConfig,
    SweepCase,
    SweepSpec,
    simulate,
)

TIER = "fast"

M_BITS = 26.416e6
N_ONUS = 2048
LOAD = 0.8
CLIENTS_PER_JOB = 8
JOB_GRID = (1, 2, 4, 8)
FAIRNESS_GRID = ("maxmin", "weighted")


def _case(n_jobs: int, fairness: str) -> SweepCase:
    """The primary job + (n_jobs-1) half-sized, double-weight tenants."""
    rng = np.random.default_rng(42)
    ids = list(range(n_jobs * CLIENTS_PER_JOB))
    jobs = []
    clients = []
    for j in range(n_jobs):
        cids = ids[j * CLIENTS_PER_JOB:(j + 1) * CLIENTS_PER_JOB]
        mb = M_BITS if j == 0 else 0.5 * M_BITS
        jobs.append(JobSpec(job_id=j, clients=tuple(cids),
                            model_bits=mb,
                            weight=1.0 if j == 0 else 2.0))
        clients.extend(
            ClientProfile(client_id=i, t_ud=float(rng.uniform(1.0, 5.0)),
                          t_dl=0.0, m_ud_bits=mb)
            for i in cids
        )
    wl = FLRoundWorkload(clients=clients, model_bits=M_BITS)
    return SweepCase(workload=wl, load=LOAD, policy="bs", seed=0,
                     jobs=tuple(jobs), fairness=fairness)


def _best_of(f, repeats):
    best, out = float("inf"), None
    for _ in range(max(repeats, 1)):
        t0 = time.time()
        out = f()
        best = min(best, time.time() - t0)
    return best, out


def _per_job_p95(case: SweepCase, res) -> dict:
    """p95 upload-completion per job via the obs histogram machinery."""
    from repro.obs import Collector

    col = Collector()
    for job in case.jobs:
        col.record_upload_times(
            f"job{job.job_id}", case.load,
            [res.ul_done[cid] for cid in job.clients],
        )
    return {
        int(key[0][3:]): float(hist.percentile(95.0))
        for key, hist in col.delay_hist.items()
    }


def measure(repeats: int = 2, n_onus: int = N_ONUS) -> dict:
    cfg = PONConfig(n_onus=n_onus)
    # warm allocators and the sampler LUTs
    simulate(SweepSpec(cases=(_case(2, "maxmin"),), pon=cfg))
    cells = []
    for fairness in FAIRNESS_GRID:
        for n_jobs in JOB_GRID:
            case = _case(n_jobs, fairness)
            spec = SweepSpec(cases=(case,), pon=cfg)
            wall, res = _best_of(lambda s=spec: simulate(s)[0], repeats)
            cells.append({
                "n_onus": n_onus,
                "n_jobs": n_jobs,
                "fairness": fairness,
                "wall_s": wall,
                "rounds_per_sec": 1.0 / wall,
                "sync_s": float(res.sync_time),
                "primary_sync_s": float(res.job_stats[0].sync_time),
                "per_job_p95_s": _per_job_p95(case, res),
            })
    return {
        "benchmark": "multi_job_fairness_grid",
        "n_onus": n_onus,
        "load": LOAD,
        "policy": "bs",
        "clients_per_job": CLIENTS_PER_JOB,
        "cells": cells,
    }


def run() -> list:
    rows = []
    for cell in measure(repeats=1)["cells"]:
        p95 = cell["per_job_p95_s"]
        rows.append({
            "name": (f"jobs_n{cell['n_onus']}_j{cell['n_jobs']}"
                     f"_{cell['fairness']}"),
            "us_per_call": cell["wall_s"] * 1e6,
            "derived": (
                f"rounds_per_sec={cell['rounds_per_sec']:.2f} "
                f"sync_s={cell['sync_s']:.3f} "
                f"p95_job0={p95[0]:.3f}s"
            ),
        })
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", metavar="PATH",
                    help="write the measurement payload as JSON")
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--n-onus", type=int, default=N_ONUS)
    args = ap.parse_args(argv)

    m = measure(repeats=args.repeats, n_onus=args.n_onus)
    print(json.dumps(m, indent=2))
    if args.json:
        from benchmarks._env import stamp

        with open(args.json, "w") as f:
            json.dump(stamp(m), f, indent=2)
            f.write("\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
