"""Substrate tests: optimizers, checkpointing (fault tolerance), data."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointCorruption,
    CheckpointManager,
    load,
    save,
)
from repro.data import TokenBatcher, femnist_like, lm_tokens, partition_tokens
from repro.optim import (
    OptimizerConfig,
    apply_updates,
    constant,
    init_opt_state,
    inverse_sqrt,
    warmup_cosine,
)


def quad_params():
    return {"w": jnp.array([3.0, -2.0]), "b": {"x": jnp.array([[1.5]])}}


class TestOptimizers:
    @pytest.mark.parametrize("name", ["sgd", "momentum", "adamw"])
    def test_descends_quadratic(self, name):
        cfg = OptimizerConfig(name=name, lr=0.1, weight_decay=0.0,
                              grad_clip=0.0)
        params = quad_params()
        state = init_opt_state(params, cfg)

        def loss(p):
            return sum(jnp.sum(jnp.square(l)) for l in jax.tree.leaves(p))

        l0 = float(loss(params))
        for _ in range(50):
            grads = jax.grad(loss)(params)
            params, state, _ = apply_updates(params, grads, state, cfg)
        assert float(loss(params)) < 0.2 * l0

    def test_grad_clip_bounds_update(self):
        cfg = OptimizerConfig(name="sgd", lr=1.0, grad_clip=1.0)
        params = {"w": jnp.zeros((3,))}
        state = init_opt_state(params, cfg)
        grads = {"w": jnp.array([100.0, 0.0, 0.0])}
        new_params, _, gnorm = apply_updates(params, grads, state, cfg)
        assert float(gnorm) == pytest.approx(100.0)
        assert float(jnp.abs(new_params["w"]).max()) <= 1.0 + 1e-6

    def test_schedules(self):
        s = warmup_cosine(1.0, 10, 100)
        assert float(s(jnp.asarray(0))) == pytest.approx(0.0)
        assert float(s(jnp.asarray(10))) == pytest.approx(1.0, rel=1e-3)
        assert float(s(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-2)
        s2 = inverse_sqrt(1.0, 100)
        assert float(s2(jnp.asarray(400))) == pytest.approx(0.5, rel=1e-3)
        assert float(constant(0.3)(jnp.asarray(5))) == pytest.approx(0.3)


class TestCheckpoint:
    def test_save_load_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(10, dtype=jnp.float32),
                "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
        path = str(tmp_path / "t.ckpt")
        save(path, tree, metadata={"step": 7})
        restored, meta = load(path, like=tree)
        assert meta["step"] == 7
        np.testing.assert_allclose(np.asarray(restored["a"]),
                                   np.asarray(tree["a"]))
        assert restored["b"]["c"].dtype == jnp.bfloat16

    def test_corruption_detected(self, tmp_path):
        tree = {"a": jnp.arange(100, dtype=jnp.float32)}
        path = str(tmp_path / "t.ckpt")
        save(path, tree)
        raw = bytearray(open(path, "rb").read())
        raw[-10] ^= 0xFF                      # flip a payload bit
        open(path, "wb").write(raw)
        with pytest.raises(CheckpointCorruption):
            load(path, like=tree)

    def test_manager_resumes_latest_valid(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=5, use_async=False)
        tree = {"a": jnp.zeros((4,))}
        for step in (1, 2, 3):
            mgr.save(step, jax.tree.map(lambda l: l + step, tree),
                     metadata={})
        # corrupt the newest checkpoint: restore must fall back to step 2
        p3 = os.path.join(str(tmp_path), "step_3.ckpt")
        raw = bytearray(open(p3, "rb").read())
        raw[-1] ^= 0xFF
        open(p3, "wb").write(raw)
        restored, meta = mgr.restore_latest(like=tree)
        assert meta["step"] == 2
        assert float(restored["a"][0]) == 2.0

    def test_rotation(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, use_async=False)
        for step in range(5):
            mgr.save(step, {"a": jnp.zeros(1)})
        assert mgr.all_steps() == [3, 4]

    def test_async_writer(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, use_async=True)
        mgr.save(1, {"a": jnp.arange(5, dtype=jnp.float32)})
        mgr.wait()
        restored, _ = mgr.restore_latest(like={"a": jnp.zeros(5)})
        np.testing.assert_allclose(np.asarray(restored["a"]),
                                   np.arange(5, dtype=np.float32))


class TestData:
    def test_femnist_like_partitions(self):
        writers, test = femnist_like(n_writers=8, samples_per_writer=32,
                                     seed=0)
        assert len(writers) == 8
        for w in writers:
            assert w["images"].shape == (32, 28, 28, 1)
            assert w["labels"].min() >= 0 and w["labels"].max() < 62
        # non-IID: writers have different label distributions
        h0 = np.bincount(writers[0]["labels"], minlength=62)
        h1 = np.bincount(writers[1]["labels"], minlength=62)
        assert not np.array_equal(h0, h1)

    def test_lm_tokens_and_batcher(self):
        toks = lm_tokens(10_000, vocab_size=97, seed=0)
        assert toks.min() >= 0 and toks.max() < 97
        b = TokenBatcher(toks, global_batch=4, seq_len=16, seed=0)
        batch = next(iter(b))
        assert batch["tokens"].shape == (4, 16)
        np.testing.assert_array_equal(
            batch["tokens"][:, 1:], batch["labels"][:, :-1]
        )

    def test_partition_tokens_disjoint(self):
        toks = np.arange(10_000, dtype=np.int32)
        shards = partition_tokens(toks, n_clients=4, seq_len=9)
        seen = set()
        for s in shards:
            flat = set(s.reshape(-1).tolist())
            assert not (seen & flat)
            seen |= flat
