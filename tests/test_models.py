"""Model zoo tests: per-arch smoke (reduced config), decode parity, CNN."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import applicable_shapes, get_config, list_architectures
from repro.configs.base import param_count
from repro.models import cnn, lm

ARCHS = list_architectures()


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch, key):
    """Reduced same-family config: one forward + one SGD step on CPU."""
    cfg = get_config(arch, smoke=True)
    params = lm.init_params(key, cfg)
    B, S = 2, 16
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.frontend:
        batch["extra_embeds"] = jax.random.normal(
            key, (B, cfg.n_frontend_tokens, cfg.d_model)
        )
    logits, aux = lm.forward_train(params, cfg, tokens,
                                   batch.get("extra_embeds"))
    exp_seq = S + (cfg.n_frontend_tokens if cfg.frontend else 0)
    assert logits.shape == (B, exp_seq, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())

    loss, grads = jax.value_and_grad(lambda p: lm.loss_fn(p, cfg, batch))(
        params
    )
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0.0

    new_params = jax.tree.map(lambda p, g: p - 0.01 * g, params, grads)
    loss2 = lm.loss_fn(new_params, cfg, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch, key):
    """prefill(S-1) + decode(1) == forward(S)[-1] — validates every cache."""
    cfg = get_config(arch, smoke=True)
    params = lm.init_params(key, cfg)
    B, S = 2, 17          # odd length stresses ring/window/chunk paths
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    extra = None
    if cfg.frontend:
        extra = jax.random.normal(key, (B, cfg.n_frontend_tokens, cfg.d_model))
    logits_full, _ = lm.forward_train(params, cfg, tokens, extra)
    cache = lm.init_cache(cfg, B, 32)
    _, cache = lm.prefill(params, cfg, tokens[:, :-1], cache, extra)
    logits_d, _ = lm.decode_step(params, cfg, tokens[:, -1:], cache)
    np.testing.assert_allclose(
        np.asarray(logits_full[:, -1]), np.asarray(logits_d[:, 0]),
        atol=2e-3, rtol=1e-2,
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_multi_token_decode_consistency(arch, key):
    """Three sequential decode steps match the full forward logits."""
    cfg = get_config(arch, smoke=True)
    params = lm.init_params(key, cfg)
    B, S, n_dec = 1, 12, 3
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                cfg.vocab_size)
    extra = None
    if cfg.frontend:
        extra = jax.random.normal(key, (B, cfg.n_frontend_tokens, cfg.d_model))
    logits_full, _ = lm.forward_train(params, cfg, tokens, extra)
    n_front = cfg.n_frontend_tokens if cfg.frontend else 0
    cache = lm.init_cache(cfg, B, 32)
    _, cache = lm.prefill(params, cfg, tokens[:, : S - n_dec], cache, extra)
    for i in range(n_dec):
        pos = S - n_dec + i
        logits_d, cache = lm.decode_step(params, cfg, tokens[:, pos:pos + 1],
                                         cache)
        np.testing.assert_allclose(
            np.asarray(logits_full[:, n_front + pos]),
            np.asarray(logits_d[:, 0]),
            atol=2e-3, rtol=1e-2,
        )


def test_param_count_close_to_nominal():
    """Analytic param counts should be in the right ballpark per arch."""
    nominal = {
        "llama3_8b": 8.0e9, "qwen3_14b": 14.8e9, "olmo_1b": 1.2e9,
        "mamba2_780m": 0.78e9, "gemma3_12b": 12e9, "mixtral_8x22b": 141e9,
        "arctic_480b": 482e9, "musicgen_large": 2.4e9,
        "recurrentgemma_2b": 2.7e9, "pixtral_12b": 12.4e9,
    }
    for arch, approx in nominal.items():
        total = param_count(get_config(arch))["total"]
        assert total == pytest.approx(approx, rel=0.35), arch


def test_long_context_applicability():
    """long_500k runs only for sub-quadratic archs (DESIGN.md skip list)."""
    runs = {
        a for a in ARCHS
        if any(s.name == "long_500k"
               for s in applicable_shapes(get_config(a)))
    }
    assert runs == {
        "mamba2_780m", "recurrentgemma_2b", "gemma3_12b", "mixtral_8x22b"
    }


class TestCNN:
    def test_forward_shape_and_loss(self):
        key = jax.random.PRNGKey(0)
        params = cnn.init_params(key)
        imgs = jax.random.normal(key, (4, 28, 28, 1))
        logits = cnn.forward(params, imgs)
        assert logits.shape == (4, 62)
        labels = jnp.array([0, 1, 2, 3])
        loss = cnn.loss_fn(params, {"images": imgs, "labels": labels})
        assert np.isfinite(float(loss))

    def test_param_size_matches_paper_scale(self):
        """LEAF CNN ~6.6 M params: 26.4 MB fp32 (the paper's 26.416 constant)."""
        params = cnn.init_params(jax.random.PRNGKey(0))
        mb = cnn.param_bytes(params) / 1e6
        assert 24.0 < mb < 29.0


@pytest.mark.parametrize("arch", ["arctic_480b", "gemma3_12b", "llama3_8b"])
def test_int8_kv_cache_decode_parity(arch, key):
    """Quantised KV cache: decode within ~1% of the exact logits."""
    cfg = get_config(arch, smoke=True).replace(kv_cache_dtype="int8")
    params = lm.init_params(key, cfg)
    B, S = 2, 17
    tokens = jax.random.randint(jax.random.PRNGKey(9), (B, S), 0,
                                cfg.vocab_size)
    logits_full, _ = lm.forward_train(params, cfg, tokens)
    cache = lm.init_cache(cfg, B, 32)
    _, cache = lm.prefill(params, cfg, tokens[:, :-1], cache)
    logits_d, _ = lm.decode_step(params, cfg, tokens[:, -1:], cache)
    ref = np.asarray(logits_full[:, -1])
    got = np.asarray(logits_d[:, 0])
    rel = np.max(np.abs(ref - got)) / (np.max(np.abs(ref)) + 1e-9)
    assert rel < 0.05, rel
