"""Pallas kernel tests: shape/dtype sweeps, assert_allclose vs ref oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention.ops import flash_attention
from repro.kernels.attention.ref import attention_ref
from repro.kernels.quant import ops as quant_ops
from repro.kernels.quant import ref as quant_ref
from repro.kernels.rglru.ops import rglru_scan
from repro.kernels.rglru.ref import rglru_scan_ref
from repro.kernels.ssd.ops import ssd_scan
from repro.kernels.ssd.ref import ssd_scan_ref

KEY = jax.random.PRNGKey(7)


def tol_for(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(
        atol=2e-5, rtol=2e-5
    )


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "B,S,H,K,D,causal,window",
        [
            (2, 256, 4, 2, 64, True, None),      # GQA causal
            (1, 128, 8, 8, 32, True, None),      # MHA
            (1, 333, 4, 1, 64, True, None),      # MQA, ragged seq
            (2, 256, 4, 2, 64, True, 64),        # sliding window
            (1, 192, 2, 2, 128, False, None),    # bidirectional
            (1, 96, 4, 4, 64, True, 8),          # tiny window < block
        ],
    )
    def test_matches_reference(self, B, S, H, K, D, causal, window, dtype):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, S, H, D), dtype)
        k = jax.random.normal(ks[1], (B, S, K, D), dtype)
        v = jax.random.normal(ks[2], (B, S, K, D), dtype)
        out = flash_attention(q, k, v, causal, window)
        ref = attention_ref(q, k, v, causal, window)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            **tol_for(dtype),
        )

    def test_backward_matches_reference_grad(self):
        ks = jax.random.split(KEY, 3)
        B, S, H, K, D = 1, 64, 2, 1, 32
        q = jax.random.normal(ks[0], (B, S, H, D))
        k = jax.random.normal(ks[1], (B, S, K, D))
        v = jax.random.normal(ks[2], (B, S, K, D))

        g1 = jax.grad(lambda q_: jnp.sum(flash_attention(q_, k, v, True, None)))(q)
        g2 = jax.grad(lambda q_: jnp.sum(attention_ref(q_, k, v, True, None)))(q)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   atol=1e-4, rtol=1e-4)


class TestRGLRU:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,S,R", [(2, 200, 96), (1, 64, 256), (3, 17, 33)])
    def test_matches_reference(self, B, S, R, dtype):
        ks = jax.random.split(KEY, 3)
        a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, R))).astype(dtype)
        b = (jax.random.normal(ks[1], (B, S, R)) * 0.1).astype(dtype)
        h0 = jax.random.normal(ks[2], (B, R))
        out = rglru_scan(a, b, h0)
        ref = rglru_scan_ref(a, b, h0)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), **tol_for(dtype)
        )

    def test_zero_initial_state(self):
        a = jnp.full((1, 8, 16), 0.5)
        b = jnp.ones((1, 8, 16))
        out = rglru_scan(a, b, jnp.zeros((1, 16)))
        ref = rglru_scan_ref(a, b, None)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-6)


class TestSSD:
    @pytest.mark.parametrize(
        "B,S,H,P,N,chunk",
        [(2, 120, 3, 16, 32, 128), (1, 256, 2, 64, 64, 64), (1, 33, 1, 8, 16, 8)],
    )
    def test_matches_reference(self, B, S, H, P, N, chunk):
        ks = jax.random.split(KEY, 4)
        xh = jax.random.normal(ks[0], (B, S, H, P))
        bm = jax.random.normal(ks[1], (B, S, N)) * 0.3
        cm = jax.random.normal(ks[2], (B, S, N)) * 0.3
        dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
        a = -jnp.exp(jax.random.normal(KEY, (H,)) * 0.2)
        from repro.kernels.ssd.kernel import ssd_scan_fwd

        out = ssd_scan_fwd(xh, bm, cm, dt, a, chunk=chunk, interpret=True)
        ref = ssd_scan_ref(xh, bm, cm, dt, a)
        scale = float(jnp.max(jnp.abs(ref))) + 1e-9
        assert float(jnp.max(jnp.abs(out - ref))) / scale < 1e-4

    def test_matches_model_chunked_path(self):
        """Kernel == the jnp chunked algorithm used by the model."""
        from repro.models.ssd import ssd_chunked

        ks = jax.random.split(KEY, 4)
        B, S, H, P, N = 1, 64, 2, 16, 32
        xh = jax.random.normal(ks[0], (B, S, H, P))
        bm = jax.random.normal(ks[1], (B, S, N)) * 0.3
        cm = jax.random.normal(ks[2], (B, S, N)) * 0.3
        dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
        a = -jnp.exp(jax.random.normal(KEY, (H,)) * 0.2)
        out = ssd_scan(xh, bm, cm, dt, a)
        y_model, _ = ssd_chunked(xh, bm, cm, dt, a, chunk=16)
        np.testing.assert_allclose(np.asarray(out), np.asarray(y_model),
                                   atol=1e-4, rtol=1e-4)


class TestQuant:
    @pytest.mark.parametrize("shape", [(100,), (1000, 37), (5, 5, 5)])
    @pytest.mark.parametrize("block", [64, 256, 4096])
    def test_matches_reference(self, shape, block):
        x = jax.random.normal(KEY, shape)
        q, s = quant_ops.quantize_int8(x, block=block)
        qr, sr = quant_ref.quantize_int8_ref(x, block=block)
        assert bool(jnp.all(q == qr))
        np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)

    def test_roundtrip_error_bounded_by_scale(self):
        x = jax.random.normal(KEY, (512, 16)) * 3.0
        rt = quant_ops.roundtrip(x, block=512)
        # per-block bound: |err| <= scale/2
        blocks = np.asarray(x).reshape(-1, 512)
        scales = np.abs(blocks).max(axis=1) / 127.0
        err = np.abs(np.asarray(rt) - np.asarray(x)).reshape(-1, 512)
        assert (err <= scales[:, None] * 0.5 + 1e-6).all()

    def test_zeros_are_exact(self):
        x = jnp.zeros((256,))
        rt = quant_ops.roundtrip(x, block=128)
        assert float(jnp.max(jnp.abs(rt))) == 0.0
