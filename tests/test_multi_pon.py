"""Multi-PON stacked engine vs the per-PON reference oracle.

The wavelength-stacked engine (``(case, pon)`` rows + per-cycle CPS
waterfill, ``repro.net.engine``) must reproduce the cycle-by-cycle
per-PON dict simulator with the CPS post-pass
(``repro.net.multi_pon.simulate_multi_pon_round``) at rtol 1e-6 —
both DBA policies, shared-ONU clients, elastic membership and deadline
deferral — because both consume the identical counter streams keyed
``(seed, phase, round, pon)``.  The waterfill itself is
property-tested (conservation, bounds, per-PON monotonicity), and the
``n_pons=1`` path is pinned bitwise against the PR 3 stream and engine
values.
"""
import numpy as np
import pytest

from repro.core.slicing import ClientProfile
from repro.kernels.traffic import ops
from repro.net import (
    FLRoundWorkload,
    MultiPonTopology,
    PONConfig,
    SweepCase,
    TimelineSchedule,
    cps_waterfill,
    simulate_multi_pon_round,
    simulate_round_sweep,
    simulate_timeline_reference,
    simulate_timeline_sweep,
)

CFG = PONConfig(n_onus=4, line_rate_bps=1e9)


def _clients(ids, seed=0, m_lo=1e5, m_hi=1e6):
    rng = np.random.default_rng(seed)
    return [
        ClientProfile(client_id=int(i),
                      t_ud=float(rng.uniform(0.05, 0.5)), t_dl=0.0,
                      m_ud_bits=float(rng.uniform(m_lo, m_hi)))
        for i in ids
    ]


def _assert_round_parity(ref, eng, rtol=1e-6):
    for name in ("dl_done", "ready", "ul_done"):
        a, b = getattr(ref, name), getattr(eng, name)
        assert set(a) == set(b)
        for cid in a:
            if np.isnan(a[cid]):
                assert np.isnan(b[cid])
                continue
            assert b[cid] == pytest.approx(a[cid], rel=rtol, abs=1e-12), (
                f"{name}[{cid}]: oracle={a[cid]} engine={b[cid]}"
            )
    assert eng.sync_time == pytest.approx(ref.sync_time, rel=rtol)
    assert eng.compute_bound == pytest.approx(ref.compute_bound, rel=rtol)


class TestCpsWaterfill:
    def test_unconstrained_is_identity(self):
        want = np.array([[2.0, 3.0, 1.0]])
        assert np.array_equal(cps_waterfill(want, 10.0), want)

    def test_conservation_bounds_and_level(self):
        rng = np.random.default_rng(0)
        for _ in range(50):
            P = int(rng.integers(2, 9))
            want = rng.uniform(0.0, 10.0, (4, P))
            cap = float(rng.uniform(1.0, 0.9 * want.sum(axis=1).max()))
            eff = cps_waterfill(want, cap)
            assert (eff >= 0.0).all()
            assert (eff <= want + 1e-12).all()
            # served never exceeds the CPS capacity per cycle
            assert (eff.sum(axis=1) <= cap * (1 + 1e-12) + 1e-9).all()
            for g in range(want.shape[0]):
                if want[g].sum() <= cap:
                    assert np.array_equal(eff[g], want[g])
                else:
                    # binding rows sit at one water level: every PON cut
                    # below its demand gets the same share mu
                    assert eff[g].sum() == pytest.approx(cap, rel=1e-12)
                    cut = eff[g] < want[g] - 1e-9
                    assert cut.any()
                    assert np.ptp(eff[g][cut]) <= 1e-9 * max(cap, 1.0)

    def test_monotone_in_capacity(self):
        rng = np.random.default_rng(1)
        want = rng.uniform(0.0, 5.0, (3, 6))
        prev = np.zeros_like(want)
        for cap in np.linspace(0.5, want.sum(axis=1).max() + 1, 40):
            eff = cps_waterfill(want, float(cap))
            assert (eff >= prev - 1e-9).all(), "per-PON grant decreased"
            prev = eff

    def test_batched_matches_per_row(self):
        rng = np.random.default_rng(2)
        want = rng.uniform(0.0, 4.0, (8, 5))
        caps = rng.uniform(2.0, 12.0, 8)
        batched = np.stack([
            cps_waterfill(want[g], float(caps[g])) for g in range(8)
        ])
        got = np.stack([
            cps_waterfill(want[g:g + 1], float(caps[g]))[0]
            for g in range(8)
        ])
        assert np.array_equal(batched, got)


class TestEngineMatchesOracle:
    """Seeded randomized parity trials (dict-sim oracle, so kept small)."""

    @pytest.mark.parametrize("trial", range(8))
    def test_parity_random_workloads(self, trial):
        rng = np.random.default_rng(500 + trial)
        policy = ["fcfs", "bs"][trial % 2]
        P = int(rng.integers(2, 4))
        n_local = int(rng.integers(2, 5))
        cfg = PONConfig(n_onus=n_local, line_rate_bps=1e9)
        total = P * n_local
        # every other trial contends on the CPS (stable offered load,
        # bursty demand exceeding the CPS in plenty of cycles)
        cps = None if trial % 4 < 2 else 0.55e9 * P
        topo = MultiPonTopology(n_pons=P, cps_rate_bps=cps)
        n = int(rng.integers(2, 7))
        if policy == "bs":
            ids = rng.choice(total, size=min(n, total),
                             replace=False).tolist()
        else:
            # ids beyond total exercise shared-ONU (multi-client) queues
            ids = list(dict.fromkeys(
                rng.integers(0, 3 * total, size=n).tolist()
            ))
        wl = FLRoundWorkload(clients=_clients(ids, seed=trial),
                             model_bits=1.2e6)
        load = float(rng.uniform(0.1, 0.4))
        eng = simulate_round_sweep(
            cfg,
            [SweepCase(workload=wl, load=load, policy=policy,
                       seed=trial, topology=topo)],
        )[0]
        ref = simulate_multi_pon_round(
            cfg, topo, wl, load, policy, seed=trial
        )
        _assert_round_parity(ref, eng)

    def test_batched_cases_match_solo(self):
        """Batch composition must not change a multi-PON case."""
        topo = MultiPonTopology(n_pons=2, cps_rate_bps=1.1e9)
        wl = FLRoundWorkload(clients=_clients([0, 1, 5, 6, 7], seed=5),
                             model_bits=1e6)
        cases = [
            SweepCase(workload=wl, load=load, policy=policy, seed=s,
                      topology=topo)
            for policy in ("fcfs", "bs") for load in (0.2, 0.35)
            for s in (0, 1)
        ]
        batched = simulate_round_sweep(CFG, cases)
        for case, got in zip(cases, batched):
            solo = simulate_round_sweep(CFG, [case])[0]
            assert got.sync_time == solo.sync_time
            assert got.ul_done == solo.ul_done

    def test_per_pon_rate_overrides(self):
        """A slower wavelength stretches its own clients' times only."""
        topo_eq = MultiPonTopology(n_pons=2)
        topo_slow = MultiPonTopology(n_pons=2,
                                     pon_rates_bps=(1e9, 0.25e9))
        wl = FLRoundWorkload(
            clients=_clients([0, 5], seed=2, m_lo=2e7, m_hi=2e7),
            model_bits=2e6,
        )
        eng = {
            name: simulate_round_sweep(
                CFG, [SweepCase(workload=wl, load=0.2, policy="fcfs",
                                seed=0, topology=t)],
            )[0]
            for name, t in (("eq", topo_eq), ("slow", topo_slow))
        }
        ref = simulate_multi_pon_round(CFG, topo_slow, wl, 0.2, "fcfs",
                                       seed=0)
        _assert_round_parity(ref, eng["slow"])
        # client 5 sits on PON 1 (the throttled wavelength): its upload
        # service time stretches ~4x while client 0's stays put
        slow5 = eng["slow"].ul_done[5] - eng["slow"].ready[5]
        eq5 = eng["eq"].ul_done[5] - eng["eq"].ready[5]
        assert slow5 > 2.0 * eq5
        assert eng["slow"].ul_done[0] - eng["slow"].ready[0] == (
            pytest.approx(eng["eq"].ul_done[0] - eng["eq"].ready[0],
                          rel=0.25)
        )

    def test_tighter_cps_never_speeds_up(self):
        wl = FLRoundWorkload(clients=_clients([0, 1, 5, 6], seed=3),
                             model_bits=1.5e6)
        syncs = []
        for cps in (None, 2.0e9, 1.5e9, 1.05e9):
            topo = MultiPonTopology(n_pons=2, cps_rate_bps=cps)
            syncs.append(simulate_round_sweep(
                CFG, [SweepCase(workload=wl, load=0.35, policy="fcfs",
                                seed=1, topology=topo)],
            )[0].sync_time)
        assert all(b >= a - 1e-9 for a, b in zip(syncs, syncs[1:])), syncs


class TestTimelineMultiPon:
    TOPO = MultiPonTopology(n_pons=2, cps_rate_bps=1.1e9)

    def _wl(self, policy, seed=0):
        ids = range(6) if policy == "bs" else [0, 1, 5, 9, 13]
        return FLRoundWorkload(clients=_clients(ids, seed),
                               model_bits=1e6)

    def _assert_equal(self, a, b, rtol=1e-6):
        for ra, rb in zip(a, b):
            assert np.allclose(ra.sync_times, rb.sync_times, rtol=rtol)
            for x, y in zip(ra.rounds, rb.rounds):
                assert set(x.ul_bits) == set(y.ul_bits)
                for cid, bits in x.ul_bits.items():
                    assert bits == pytest.approx(y.ul_bits[cid],
                                                 rel=rtol, abs=2.0)
                assert set(x.deferred) == set(y.deferred)
                assert x.arrived == y.arrived

    @pytest.mark.parametrize("policy", ["fcfs", "bs"])
    def test_elastic_membership_parity(self, policy):
        rng = np.random.default_rng(11)
        memb = rng.random((3, 5 if policy == "fcfs" else 6)) < 0.7
        memb[0] = True
        sched = TimelineSchedule(n_rounds=3, membership=memb)
        cases = [SweepCase(workload=self._wl(policy), load=0.3,
                           policy=policy, seed=7, topology=self.TOPO)]
        self._assert_equal(
            simulate_timeline_sweep(CFG, cases, sched, mode="folded"),
            simulate_timeline_reference(CFG, cases, sched),
        )

    @pytest.mark.parametrize("policy", ["fcfs", "bs"])
    def test_deadline_deferral_parity(self, policy):
        sched = TimelineSchedule(n_rounds=3, deadline_s=0.25)
        cases = [SweepCase(workload=self._wl(policy), load=0.3,
                           policy=policy, seed=9, topology=self.TOPO)]
        eng = simulate_timeline_sweep(CFG, cases, sched)
        ref = simulate_timeline_reference(CFG, cases, sched)
        assert sum(len(r.deferred) for r in eng[0].rounds) > 0
        self._assert_equal(eng, ref)


class TestStreamPinning:
    """The (seed, phase, round, pon) key leaves pon=0 streams bitwise
    where PR 3 pinned them."""

    def test_pon0_key_is_pr3_key(self):
        for seed, phase, rnd in [(0, 0, 0), (3, 1, 2), (77, 0, 9)]:
            legacy = np.array(
                [seed & 0xFFFFFFFF, (phase + 2 * rnd) & 0xFFFFFFFF],
                np.uint32,
            )
            assert np.array_equal(
                ops.make_stream_key(seed, phase, rnd), legacy
            )
            assert np.array_equal(
                ops.make_stream_key(seed, phase, rnd, pon=0), legacy
            )

    def test_pon_keys_distinct(self):
        keys = {tuple(ops.make_stream_key(3, 1, 2, pon=p).tolist())
                for p in range(64)}
        assert len(keys) == 64

    def test_pon_axis_fingerprint_pinned(self):
        """Pins the pon>0 stream definition itself (key mixing plus the
        sampler). Update deliberately if the stream format changes."""
        key = ops.make_stream_key(seed=3, phase=1, round_index=2, pon=1)
        assert key.tolist() == [3432918356, 461845912]
        got = ops.sample_arrival_bits(key, 128, 256, 8, 0.5, 1 / 16.0,
                                      12_000.0, backend="numpy")
        assert got.sum() == 193_656_000.0
        assert got[0, :7, 0].tolist() == [
            72000.0, 0.0, 24000.0, 0.0, 0.0, 0.0, 0.0
        ]

    def test_single_pon_engine_bitwise_unchanged(self):
        """n_pons=1 must reproduce the PR 3 engine exactly: the pinned
        Fig. 2b operating-point sync (BENCH_net_engine.json) with and
        without a trivial topology attached."""
        rng = np.random.default_rng(42)
        t_uds = rng.uniform(1.0, 5.0, 128)
        clients = [
            ClientProfile(client_id=i, t_ud=float(t_uds[i]), t_dl=0.0,
                          m_ud_bits=26.416e6)
            for i in range(12)
        ]
        wl = FLRoundWorkload(clients=clients, model_bits=26.416e6)
        cfg = PONConfig(n_onus=128)
        for topo in (None, MultiPonTopology()):
            r = simulate_round_sweep(
                cfg,
                [SweepCase(workload=wl, load=0.8, policy="fcfs", seed=1,
                           topology=topo)],
            )[0]
            assert r.sync_time == pytest.approx(5.058100000000024,
                                                abs=1e-9)


class TestTopologyValidation:
    def test_mixed_topologies_rejected(self):
        wl = FLRoundWorkload(clients=_clients([0, 1]), model_bits=1e6)
        cases = [
            SweepCase(workload=wl, load=0.3, policy="fcfs", seed=0,
                      topology=MultiPonTopology(n_pons=2)),
            SweepCase(workload=wl, load=0.3, policy="fcfs", seed=0,
                      topology=MultiPonTopology(n_pons=3)),
        ]
        with pytest.raises(ValueError, match="share one"):
            simulate_round_sweep(CFG, cases)

    def test_bs_ids_must_fit_the_stack(self):
        wl = FLRoundWorkload(clients=_clients([9]), model_bits=1e6)
        with pytest.raises(ValueError, match="client_id < n_onus"):
            simulate_round_sweep(
                CFG,
                [SweepCase(workload=wl, load=0.3, policy="bs", seed=0,
                           topology=MultiPonTopology(n_pons=2))],
            )

    def test_pon_rates_length_checked(self):
        with pytest.raises(ValueError, match="pon_rates_bps"):
            MultiPonTopology(n_pons=2, pon_rates_bps=(1e9,))

    def test_cps_rate_positive(self):
        with pytest.raises(ValueError, match="cps_rate_bps"):
            MultiPonTopology(n_pons=2, cps_rate_bps=0.0)
