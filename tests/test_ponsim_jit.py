"""``backend="jit"`` device cycle engine vs the numpy engine.

The jit backend (``repro.kernels.ponsim``) must reproduce the numpy
engine at rtol 1e-6 across {fcfs, bs} x {defer, drop, partial, async}
x multi-PON x faults on/off — the numpy engine itself is pinned to the
cycle-level reference oracles by the existing suites, so engine parity
chains the device program all the way down.  On top of parity:

* the fused in-scan sampler must be *bit-identical* to the host
  ``kernels.traffic`` streams (pinned fingerprint);
* one device program compiles per (mode, shape, flag) spec — re-running
  the same schedule shape must not retrace;
* importing ``repro.net`` / running a jit round must never flip the
  global ``jax_enable_x64`` flag (the backend scopes x64 locally);
* the Pallas waterfill kernel (interpret mode on CPU) must agree with
  the engine's sequential-grant semantics.
"""
import hashlib

import jax
import numpy as np
import pytest

from repro.core.slicing import ClientProfile
from repro.faults import FaultSchedule
from repro.kernels import ponsim
from repro.kernels.ponsim import ops as ponsim_ops
from repro.kernels.ponsim.kernel import waterfill_grants_pallas
from repro.kernels.traffic.ops import (
    _poisson_thresholds,
    _tail_bound,
    make_stream_key,
    sample_arrival_bits,
)
from repro.kernels.traffic.ref import WINDOW
from repro.net import (
    FLRoundWorkload,
    PONConfig,
    PrecomputedSource,
    SweepCase,
    simulate_round,
    simulate_round_sweep,
)
from repro.net.engine import PACKET_BITS, _waterfill
from repro.net.multi_pon import MultiPonTopology
from repro.net.timeline import TimelineSchedule, simulate_timeline_sweep
from repro.net.traffic import burst_lambda

CFG = PONConfig(n_onus=4, line_rate_bps=1e9)
FAULTS = FaultSchedule(seed=3, dropout_rate=0.25, loss_rate=0.15,
                       outage_rate=0.5, outage_duration_s=0.1,
                       outage_start_max_s=0.5)


def _workload(ids, seed=1):
    rng = np.random.default_rng(seed)
    clients = [
        ClientProfile(client_id=int(i), t_ud=float(rng.uniform(0.05, 0.5)),
                      t_dl=0.0, m_ud_bits=float(rng.uniform(1e5, 2e6)))
        for i in ids
    ]
    return FLRoundWorkload(clients=clients, model_bits=1.5e6)


WL = _workload([0, 1, 2, 3])
WL_MULTI = _workload([0, 1, 2, 3, 5, 9])   # multi-client-per-ONU (fcfs)


def _dicts_close(a, b):
    assert set(a) == set(b)
    for k in a:
        assert np.isclose(b[k], a[k], rtol=1e-6, equal_nan=True), (
            f"[{k}]: numpy={a[k]} jit={b[k]}"
        )


def _assert_round_parity(a, b):
    assert np.isclose(b.sync_time, a.sync_time, rtol=1e-6, equal_nan=True)
    for name in ("dl_done", "ready", "ul_done"):
        _dicts_close(getattr(a, name), getattr(b, name))
    _dicts_close(a.ul_remaining or {}, b.ul_remaining or {})


def _assert_timeline_parity(a, b):
    for ra, rb in zip(a.rounds, b.rounds):
        for attr in ("sync_time", "t_start", "t_end"):
            assert np.isclose(getattr(rb, attr), getattr(ra, attr),
                              rtol=1e-6, equal_nan=True), attr
        assert set(ra.arrived) == set(rb.arrived)
        assert set(ra.lost) == set(rb.lost)
        assert set(ra.gave_up) == set(rb.gave_up)
        assert ra.quorum_met == rb.quorum_met
        assert ra.deadline_extensions == rb.deadline_extensions
        for attr in ("ul_bits", "deferred", "staleness", "dropped",
                     "partial", "failed", "retry_at"):
            _dicts_close(getattr(ra, attr), getattr(rb, attr))


class TestEngineParity:
    """simulate_round_sweep(backend="jit") vs the default numpy engine."""

    @pytest.mark.parametrize("policy,load", [
        ("fcfs", 0.2), ("fcfs", 0.6), ("fcfs", 0.9),
        ("bs", 0.2), ("bs", 0.9),
    ])
    def test_single_round(self, policy, load):
        wl = WL_MULTI if policy == "fcfs" else WL
        cases = [SweepCase(workload=wl, load=load, policy=policy, seed=7)]
        a = simulate_round_sweep(CFG, cases)
        b = simulate_round_sweep(CFG, cases, backend="jit")
        _assert_round_parity(a[0], b[0])

    @pytest.mark.parametrize("policy", ["fcfs", "bs"])
    def test_deadline_and_outage(self, policy):
        wl = WL_MULTI if policy == "fcfs" else WL
        cases = [SweepCase(workload=wl, load=0.8, policy=policy, seed=3)]
        kw = dict(ul_deadline_s=[1.5], ul_outage_s=[(0.2, 0.6)])
        a = simulate_round_sweep(CFG, cases, **kw)
        b = simulate_round_sweep(CFG, cases, backend="jit", **kw)
        _assert_round_parity(a[0], b[0])

    @pytest.mark.parametrize("policy", ["fcfs", "bs"])
    def test_multi_pon_cps(self, policy):
        topo = MultiPonTopology(n_pons=3, cps_rate_bps=1.5e9)
        ids = [0, 3, 5, 8, 11] if policy == "fcfs" else [0, 2, 5, 7, 10]
        wl = _workload(ids, seed=2)
        outage = np.array([[0.1, 0.4], [0.0, 0.0], [0.2, 0.5]])
        cases = [SweepCase(workload=wl, load=0.3, policy=policy, seed=5,
                           topology=topo)]
        for kw in ({}, {"ul_deadline_s": [1.2], "ul_outage_s": [outage]}):
            a = simulate_round_sweep(CFG, cases, **kw)
            b = simulate_round_sweep(CFG, cases, backend="jit", **kw)
            _assert_round_parity(a[0], b[0])

    def test_mixed_batch(self):
        cases = [SweepCase(workload=WL_MULTI, load=l, policy="fcfs", seed=s)
                 for l in (0.3, 0.7) for s in (1, 2)]
        cases.append(SweepCase(workload=WL, load=0.5, policy="bs", seed=4))
        a = simulate_round_sweep(CFG, cases)
        b = simulate_round_sweep(CFG, cases, backend="jit")
        for ra, rb in zip(a, b):
            _assert_round_parity(ra, rb)

    def test_simulate_round_backend(self):
        a = simulate_round(CFG, WL, 0.5, "fcfs", seed=9)
        b = simulate_round(CFG, WL, 0.5, "fcfs", seed=9, backend="jit")
        _assert_round_parity(a, b)

    def test_jit_rejects_injected_arrivals(self):
        dl = np.zeros((64, CFG.n_onus))
        cases = [SweepCase(workload=WL, load=0.3, policy="fcfs", seed=0,
                           dl_arrivals=dl, ul_arrivals=dl)]
        with pytest.raises(ValueError, match="jit"):
            simulate_round_sweep(CFG, cases, backend="jit")
        with pytest.raises(ValueError, match="jit"):
            simulate_round(
                CFG, WL, 0.3, "fcfs", seed=0, backend="jit",
                _ul_sources=[PrecomputedSource(np.zeros(64))
                             for _ in range(CFG.n_onus)],
            )


class TestTimelineParity:
    """simulate_timeline_sweep(backend="jit") across every mode."""

    @pytest.mark.parametrize("policy", ["fcfs", "bs"])
    @pytest.mark.parametrize("schedule", [
        TimelineSchedule(n_rounds=4),                                # folded
        TimelineSchedule(n_rounds=4, deadline_s=0.35),               # defer
        TimelineSchedule(n_rounds=4, deadline_s=0.35,
                         deadline_policy="drop"),
        TimelineSchedule(n_rounds=4, deadline_s=0.35,
                         deadline_policy="partial"),
        TimelineSchedule(n_rounds=3, buffer_k=2),                    # async
        TimelineSchedule(n_rounds=3, deadline_s=0.25,
                         quorum_frac=0.9),                           # quorum
        TimelineSchedule(n_rounds=4, deadline_s=0.5, faults=FAULTS),
    ], ids=["folded", "defer", "drop", "partial", "async", "quorum",
            "faults"])
    def test_modes(self, policy, schedule):
        cases = [SweepCase(workload=WL, load=0.4, policy=policy, seed=11)]
        a = simulate_timeline_sweep(CFG, cases, schedule)
        b = simulate_timeline_sweep(CFG, cases, schedule, backend="jit")
        _assert_timeline_parity(a[0], b[0])

    def test_multi_pon_timeline(self):
        topo = MultiPonTopology(n_pons=2, cps_rate_bps=1.2e9)
        wl = _workload([0, 2, 5, 7], seed=4)
        cases = [SweepCase(workload=wl, load=0.3, policy="fcfs", seed=6,
                           topology=topo)]
        schedule = TimelineSchedule(n_rounds=3, deadline_s=0.6,
                                    deadline_policy="drop")
        a = simulate_timeline_sweep(CFG, cases, schedule)
        b = simulate_timeline_sweep(CFG, cases, schedule, backend="jit")
        _assert_timeline_parity(a[0], b[0])


class TestFusedSampler:
    """The in-scan sampler is bit-identical to the host traffic streams."""

    def _stream_params(self):
        keys = np.stack([make_stream_key(7, 1, r, p)
                         for r in (0, 1) for p in (0, 2)])
        lam = burst_lambda(0.3 * 1e9 / 16, 1e-3, PACKET_BITS, 16.0)
        return keys, np.full((keys.shape[0],), lam, np.float32)

    def test_bit_identical_to_host(self):
        keys, lams = self._stream_params()
        n_onus = 16
        host = sample_arrival_bits(keys, 0, 4 * WINDOW, n_onus, lams,
                                   1.0 / 16.0, PACKET_BITS,
                                   backend="numpy")
        n_draws = _tail_bound(float(lams.max()) * WINDOW)
        thr = _poisson_thresholds(
            np.asarray(lams, np.float64) * WINDOW, n_draws)
        dev = np.concatenate([
            np.asarray(
                ponsim.sample_window_ref(
                    keys, thr, w, n_onus=n_onus, n_draws=n_draws,
                    inv_burst=np.float32(1.0 / 16.0),
                    packet_bits=np.float32(PACKET_BITS)),
                np.float64)
            for w in range(4)
        ], axis=1)
        assert np.array_equal(dev, host)

    def test_pinned_fingerprint(self):
        # Bitwise regression of the exact stream the fused sampler (and
        # every host backend) must produce.  If this moves, every
        # multi-round result in the repo moves with it.
        keys, lams = self._stream_params()
        host = sample_arrival_bits(keys, 0, 4 * WINDOW, 16, lams,
                                   1.0 / 16.0, PACKET_BITS,
                                   backend="numpy")
        digest = hashlib.sha256(
            np.ascontiguousarray(host).tobytes()).hexdigest()
        assert digest == ("7df0b5fe7c7a5a214089bec8540252e0"
                          "8add05f7bce9f2c0ba49c770a693f3fe")
        assert host.sum() == 327768000.0


class TestCompileCaching:
    """One trace per (mode, shape, flags) spec; replays hit the cache."""

    def test_no_retrace_on_same_shape(self):
        ponsim_ops.clear_cache()
        cases = [SweepCase(workload=WL, load=0.5, policy="fcfs", seed=21)]
        simulate_round_sweep(CFG, cases, backend="jit")
        first = ponsim_ops.compile_count()
        assert first > 0
        # same spec (same shapes, same load hence same n_draws), new
        # seed: the stream keys are dynamic inputs — zero new traces
        cases2 = [SweepCase(workload=WL, load=0.5, policy="fcfs", seed=22)]
        simulate_round_sweep(CFG, cases2, backend="jit")
        assert ponsim_ops.compile_count() == first
        # new batch shape: retraces
        simulate_round_sweep(CFG, cases + cases2, backend="jit")
        assert ponsim_ops.compile_count() > first


class TestPrecisionPolicy:
    """The jit backend scopes x64 locally; the global flag never flips."""

    def test_global_x64_untouched(self):
        import repro.net  # noqa: F401

        assert jax.config.jax_enable_x64 is False
        cases = [SweepCase(workload=WL, load=0.5, policy="bs", seed=13)]
        res = simulate_round_sweep(CFG, cases, backend="jit")
        assert np.isfinite(res[0].sync_time)
        assert jax.config.jax_enable_x64 is False


class TestPallasWaterfillKernel:
    """Interpret-mode Pallas grant kernel vs the engine's numpy grants."""

    def test_matches_engine_waterfill(self):
        rng = np.random.default_rng(5)
        R, N = 4, 128
        backlog = np.where(rng.random((R, N)) < 0.6,
                           rng.uniform(0.0, 3e4, (R, N)), 0.0)
        key = np.where(backlog > 0,
                       rng.integers(0, 500, (R, N)).astype(np.float64),
                       np.inf)
        cap = np.array([1e4, 2e5, backlog[2].sum() + 10.0, 5.0])
        want = _waterfill(backlog, lambda: key, cap)
        g32 = np.asarray(waterfill_grants_pallas(
            backlog.astype(np.float32), key.astype(np.float32),
            cap.astype(np.float32), interpret=True), np.float64)
        # f32 kernel: full-drain lanes are exact, partial lanes are
        # f32-rounded — the engine restores f64 on full lanes, so check
        # the same contract here.
        full = want == backlog
        assert np.array_equal(g32 == backlog.astype(np.float32), full)
        assert np.allclose(g32, want, rtol=1e-4, atol=1.0)

    def test_full_rows_bitwise(self):
        rng = np.random.default_rng(6)
        R, N = 2, 128
        backlog = rng.uniform(0.0, 1e3, (R, N))
        key = rng.integers(0, 99, (R, N)).astype(np.float64)
        cap = backlog.sum(axis=1) + 100.0
        g32 = np.asarray(waterfill_grants_pallas(
            backlog.astype(np.float32), key.astype(np.float32),
            cap.astype(np.float32), interpret=True))
        assert np.array_equal(g32, backlog.astype(np.float32))
