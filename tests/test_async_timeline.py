"""Async/stale FL rounds: deadline policies + FedBuff timeline.

Three things are pinned here:

* ``deadline_policy="defer"`` is the PR 3/4 deferral behaviour,
  bit-for-bit — including the Fig. 2b operating-point sync pin;
* drop / partial / async agree with the cycle-level reference oracle
  at rtol 1e-6 over both DBA policies and multi-PON topologies;
* the satellite bugfixes: ``TimelineSchedule`` defensively copies its
  caller's arrays, ``_round_view`` refuses to drop pending clients,
  and the co-sim timing cache keys on the payload sizes.
"""
import numpy as np
import pytest

from repro.core.slicing import ClientProfile
from repro.net import (
    FLRoundWorkload,
    MultiPonTopology,
    PONConfig,
    SweepCase,
    TimelineSchedule,
    simulate_timeline_per_round,
    simulate_timeline_reference,
    simulate_timeline_sweep,
)
from repro.net.timeline import _round_view

CFG = PONConfig(n_onus=8, line_rate_bps=1e9)


def _clients(ids, seed=0, m_lo=1e5, m_hi=2e6):
    rng = np.random.default_rng(seed)
    return [
        ClientProfile(client_id=int(i),
                      t_ud=float(rng.uniform(0.05, 0.6)), t_dl=0.0,
                      m_ud_bits=float(rng.uniform(m_lo, m_hi)))
        for i in ids
    ]


def _wl(policy, seed=0):
    ids = range(6) if policy == "bs" else [0, 1, 5, 9, 17, 19]
    return FLRoundWorkload(clients=_clients(ids, seed), model_bits=1.5e6)


def _assert_equal(a, b, rtol=1e-6):
    for ra, rb in zip(a, b):
        assert np.allclose(ra.sync_times, rb.sync_times, rtol=rtol), (
            f"sync {ra.sync_times} vs {rb.sync_times}"
        )
        for x, y in zip(ra.rounds, rb.rounds):
            assert x.arrived == y.arrived
            assert x.staleness == y.staleness
            for name in ("ul_bits", "deferred", "dropped", "partial"):
                xd, yd = getattr(x, name), getattr(y, name)
                assert set(xd) == set(yd), (x.round_index, name)
                for cid, v in xd.items():
                    assert v == pytest.approx(yd[cid], rel=rtol, abs=2.0)


class TestPolicyParityVsOracle:
    @pytest.mark.parametrize("policy", ["fcfs", "bs"])
    @pytest.mark.parametrize("dpolicy", ["drop", "partial"])
    def test_deadline_policies(self, policy, dpolicy):
        sched = TimelineSchedule(n_rounds=4, deadline_s=0.35,
                                 deadline_policy=dpolicy)
        cases = [SweepCase(workload=_wl(policy), load=0.6,
                           policy=policy, seed=5)]
        eng = simulate_timeline_sweep(CFG, cases, sched)
        ref = simulate_timeline_reference(CFG, cases, sched)
        cut = "dropped" if dpolicy == "drop" else "partial"
        assert sum(len(getattr(r, cut)) for r in eng[0].rounds) > 0, (
            "deadline chosen to force cutoffs"
        )
        _assert_equal(eng, ref)

    @pytest.mark.parametrize("policy", ["fcfs", "bs"])
    @pytest.mark.parametrize("buffer_k", [1, 3])
    def test_async_buffered(self, policy, buffer_k):
        sched = TimelineSchedule(n_rounds=4, buffer_k=buffer_k)
        cases = [SweepCase(workload=_wl(policy), load=0.6,
                           policy=policy, seed=5)]
        eng = simulate_timeline_sweep(CFG, cases, sched)
        ref = simulate_timeline_reference(CFG, cases, sched)
        _assert_equal(eng, ref)
        assert sum(len(r.deferred) for r in eng[0].rounds) > 0, (
            "buffer_k chosen to leave stragglers in flight"
        )

    @pytest.mark.parametrize("policy", ["fcfs", "bs"])
    def test_multi_pon_policies(self, policy):
        topo = MultiPonTopology(n_pons=2, cps_rate_bps=1.8e9)
        cases = [SweepCase(workload=_wl(policy), load=0.4,
                           policy=policy, seed=5, topology=topo)]
        for sched in (
            TimelineSchedule(n_rounds=3, buffer_k=3),
            TimelineSchedule(n_rounds=3, deadline_s=0.35,
                             deadline_policy="partial"),
            TimelineSchedule(n_rounds=3, deadline_s=0.35,
                             deadline_policy="drop"),
        ):
            _assert_equal(
                simulate_timeline_sweep(CFG, cases, sched),
                simulate_timeline_reference(CFG, cases, sched),
            )

    def test_folded_matches_sequential_for_drop_partial(self):
        for dpolicy in ("drop", "partial"):
            sched = TimelineSchedule(n_rounds=3, deadline_s=0.35,
                                     deadline_policy=dpolicy)
            cases = [SweepCase(workload=_wl("fcfs"), load=0.6,
                               policy="fcfs", seed=7)]
            _assert_equal(
                simulate_timeline_sweep(CFG, cases, sched,
                                        mode="folded"),
                simulate_timeline_sweep(CFG, cases, sched,
                                        mode="sequential"),
                rtol=1e-12,
            )


class TestDeferUnchanged:
    """``deadline_policy="defer"`` must be the PR 3/4 deferral,
    bit-for-bit."""

    def test_default_policy_is_defer(self):
        assert TimelineSchedule(n_rounds=1).deadline_policy == "defer"

    def test_explicit_defer_identical_to_default(self):
        cases = [SweepCase(workload=_wl("fcfs"), load=0.6,
                           policy="fcfs", seed=5)]
        a = simulate_timeline_sweep(
            CFG, cases, TimelineSchedule(n_rounds=3, deadline_s=0.35),
        )
        b = simulate_timeline_sweep(
            CFG, cases,
            TimelineSchedule(n_rounds=3, deadline_s=0.35,
                             deadline_policy="defer"),
        )
        for x, y in zip(a[0].rounds, b[0].rounds):
            assert x.sync_time == y.sync_time
            assert x.ul_bits == y.ul_bits
            assert x.deferred == y.deferred

    def test_operating_point_sync_pinned(self):
        """The Fig. 2b 0.8-load cell through the defer-policy timeline
        (deadline wide enough that nothing defers) reproduces the
        pinned sync bit-for-bit."""
        rng = np.random.default_rng(42)
        t_uds = rng.uniform(1.0, 5.0, 128)
        clients = [
            ClientProfile(client_id=i, t_ud=float(t_uds[i]), t_dl=0.0,
                          m_ud_bits=26.416e6)
            for i in range(12)
        ]
        wl = FLRoundWorkload(clients=clients, model_bits=26.416e6)
        cfg = PONConfig(n_onus=128)
        case = SweepCase(workload=wl, load=0.8, policy="fcfs", seed=1)
        for sched in (
            TimelineSchedule(n_rounds=1),
            TimelineSchedule(n_rounds=1, deadline_s=30.0,
                             deadline_policy="defer"),
            TimelineSchedule(n_rounds=1, deadline_s=30.0,
                             deadline_policy="drop"),
        ):
            res = simulate_timeline_sweep(cfg, [case], sched)[0]
            assert res.rounds[0].sync_time == pytest.approx(
                5.058100000000024, abs=1e-9
            )


class TestPolicySemantics:
    def _run(self, dpolicy, rounds=4, deadline=0.35):
        sched = TimelineSchedule(n_rounds=rounds, deadline_s=deadline,
                                 deadline_policy=dpolicy)
        wl = _wl("fcfs")
        res = simulate_timeline_sweep(
            CFG, [SweepCase(workload=wl, load=0.6, policy="fcfs",
                            seed=7)], sched,
        )[0]
        return wl, res

    def test_drop_discards_and_reenters_fresh(self):
        wl, res = self._run("drop")
        m_ud = {c.client_id: c.m_ud_bits for c in wl.clients}
        saw_drop = False
        for r in res.rounds:
            assert r.deferred == {}
            for cid, bits in r.dropped.items():
                saw_drop = True
                assert bits > 0.0
            # every participant starts from its full update each round
            for cid, served in r.ul_bits.items():
                assert served <= m_ud[cid] + 2.0
        assert saw_drop

    def test_partial_fraction_is_served_over_total(self):
        wl, res = self._run("partial")
        m_ud = {c.client_id: c.m_ud_bits for c in wl.clients}
        saw_partial = False
        for r in res.rounds:
            assert r.deferred == {} and r.dropped == {}
            for cid, frac in r.partial.items():
                saw_partial = True
                assert 0.0 <= frac < 1.0
                assert frac == pytest.approx(
                    r.ul_bits[cid] / m_ud[cid], rel=1e-9
                )
        assert saw_partial

    def test_async_fires_at_kth_arrival(self):
        k = 2
        sched = TimelineSchedule(n_rounds=4, buffer_k=k)
        res = simulate_timeline_sweep(
            CFG, [SweepCase(workload=_wl("fcfs"), load=0.6,
                            policy="fcfs", seed=7)], sched,
        )[0]
        for r in res.rounds:
            pending = len(r.ul_bits)
            assert len(r.arrived) >= min(k, pending)
            # the aggregation fires at the k-th completion: its time
            # bounds the round (modulo the aggregation term and the
            # final cycle completing)
            if r.deferred:
                times = sorted(r.result.ul_done[c] for c in r.arrived)
                assert r.sync_time == pytest.approx(times[k - 1])

    def test_async_staleness_counts_rounds_in_flight(self):
        sched = TimelineSchedule(n_rounds=5, buffer_k=1)
        res = simulate_timeline_sweep(
            CFG, [SweepCase(workload=_wl("fcfs"), load=0.6,
                            policy="fcfs", seed=7)], sched,
        )[0]
        # with k=1 the slowest clients stay in flight across several
        # aggregations and must arrive with staleness > 0
        stale = [t for r in res.rounds for t in r.staleness.values()]
        assert max(stale) > 0
        for r in res.rounds:
            for cid in r.arrived:
                assert r.staleness[cid] >= 0

    def test_async_conserves_upload_bits(self):
        wl = _wl("fcfs")
        sched = TimelineSchedule(n_rounds=5, buffer_k=2)
        res = simulate_timeline_sweep(
            CFG, [SweepCase(workload=wl, load=0.6, policy="fcfs",
                            seed=7)], sched,
        )[0]
        m_ud = {c.client_id: c.m_ud_bits for c in wl.clients}
        served = {cid: 0.0 for cid in m_ud}
        done = {cid: 0 for cid in m_ud}
        for r in res.rounds:
            for cid, bits in r.ul_bits.items():
                served[cid] += bits
            for cid in r.arrived:
                done[cid] += 1
        for cid in m_ud:
            leftover = served[cid] - done[cid] * m_ud[cid]
            assert -2.0 <= leftover <= m_ud[cid]


class TestScheduleValidation:
    def test_unknown_policy_rejected(self):
        with pytest.raises(ValueError, match="deadline_policy"):
            TimelineSchedule(n_rounds=1, deadline_s=1.0,
                             deadline_policy="teleport")

    def test_policy_requires_deadline(self):
        with pytest.raises(ValueError, match="needs"):
            TimelineSchedule(n_rounds=1, deadline_policy="drop")

    def test_async_excludes_deadline(self):
        with pytest.raises(ValueError, match="buffer_k"):
            TimelineSchedule(n_rounds=1, deadline_s=1.0, buffer_k=2)

    def test_async_rejects_folded(self):
        with pytest.raises(ValueError, match="folded"):
            simulate_timeline_sweep(
                CFG,
                [SweepCase(workload=_wl("fcfs"), load=0.5,
                           policy="fcfs", seed=0)],
                TimelineSchedule(n_rounds=2, buffer_k=2),
                mode="folded",
            )

    def test_defer_deadline_rejects_folded(self):
        with pytest.raises(ValueError, match="folded"):
            simulate_timeline_sweep(
                CFG,
                [SweepCase(workload=_wl("fcfs"), load=0.5,
                           policy="fcfs", seed=0)],
                TimelineSchedule(n_rounds=2, deadline_s=0.5),
                mode="folded",
            )

    def test_per_round_handles_async(self):
        sched = TimelineSchedule(n_rounds=2, buffer_k=2)
        cases = [SweepCase(workload=_wl("fcfs"), load=0.5,
                           policy="fcfs", seed=0)]
        _assert_equal(
            simulate_timeline_per_round(CFG, cases, sched),
            simulate_timeline_sweep(CFG, cases, sched),
            rtol=1e-12,
        )


class TestScheduleDefensiveCopies:
    """Satellite bugfix: mutating the caller's arrays after
    construction must not desync folded vs reference results (both
    must see the construction-time values)."""

    def test_membership_and_m_ud_copied(self):
        memb = np.ones((3, 6), bool)
        m_ud = np.full(3, 5e5)
        dl = np.array([0.35, 0.35, 0.35])
        sched = TimelineSchedule(n_rounds=3, membership=memb,
                                 m_ud_bits=m_ud, deadline_s=dl)
        cases = [SweepCase(workload=_wl("fcfs"), load=0.5,
                           policy="fcfs", seed=3)]
        before = simulate_timeline_sweep(CFG, cases, sched)
        # caller mutates everything after construction
        memb[:] = False
        m_ud[:] = 1.0
        dl[:] = 1e-4
        after = simulate_timeline_sweep(CFG, cases, sched)
        ref = simulate_timeline_reference(CFG, cases, sched)
        _assert_equal(before, after, rtol=1e-12)
        _assert_equal(after, ref)
        assert sched.deadline(0) == 0.35
        assert sched.round_m_ud(0, 0, 0.0) == 5e5

    def test_lookups_use_normalised_arrays(self):
        sched = TimelineSchedule(n_rounds=2, deadline_s=0.7,
                                 m_ud_bits=[1e5, 2e5])
        assert sched.deadline(1) == 0.7
        assert sched.round_m_ud(1, 3, 0.0) == 2e5
        assert isinstance(sched.deadline_s, np.ndarray)
        assert isinstance(sched.m_ud_bits, np.ndarray)


class TestRoundViewInvariant:
    """Satellite bugfix: a missing round result with pending clients
    must raise instead of silently dropping their bits."""

    def test_none_result_with_pending_raises(self):
        with pytest.raises(RuntimeError, match="pending"):
            _round_view(2, 0.0, None, {7: 1e6}, 0.0)

    def test_none_result_without_pending_is_empty_round(self):
        rnd, carry = _round_view(2, 1.0, None, {}, 0.25)
        assert carry == {}
        assert rnd.sync_time == 0.25
        assert rnd.ul_bits == {} and rnd.arrived == []


class TestCoSimCoupled:
    def _cosim(self):
        pytest.importorskip("jax")
        import jax

        from repro.data import build_federated_cnn_clients
        from repro.fl import CPSServer, SelectionConfig
        from repro.fl.client import LocalTrainConfig
        from repro.fl.simulation import CoSimConfig, FLNetworkCoSim
        from repro.models import cnn

        clients, _ = build_federated_cnn_clients(
            n_clients=4, samples_per_client=16, loss_fn=cnn.loss_fn,
            train_cfg=LocalTrainConfig(lr=0.05, batch_size=8,
                                       local_epochs=1),
            seed=0,
        )
        server = CPSServer(
            global_params=cnn.init_params(jax.random.PRNGKey(0)),
            clients=clients,
            selection=SelectionConfig(strategy="all"),
            seed=0,
        )
        cfg = CoSimConfig(
            policy="bs", total_load=0.5, model_bits=2e6,
            upload_bits=2e6, timing_seeds=1,
            pon=PONConfig(n_onus=8, line_rate_bps=1e9),
        )
        return FLNetworkCoSim(server, cfg)

    def test_async_mode_runs_and_sums(self):
        sim = self._cosim()
        res = sim.run(n_rounds=3, mode="async", async_buffer=2)
        assert len(res.rounds) == 3
        assert all(r["n_arrived"] >= 1 for r in res.rounds)
        assert res.total_time_s == pytest.approx(
            sum(r["sync_time_s"] for r in res.rounds)
        )

    @pytest.mark.parametrize("dpolicy", ["defer", "drop", "partial"])
    def test_deadline_policies_run(self, dpolicy):
        sim = self._cosim()
        res = sim.run(n_rounds=2, deadline_s=2.0,
                      deadline_policy=dpolicy)
        assert len(res.rounds) == 2
        assert all(r["sync_time_s"] > 0 for r in res.rounds)

    def test_coupled_requires_single_timing_seed(self):
        """Arrival sets are events, not averageable times — multi-seed
        configs must be rejected, not silently collapsed to seed 0."""
        sim = self._cosim()
        sim.cfg.timing_seeds = 3
        with pytest.raises(ValueError, match="timing_seeds"):
            sim.run(n_rounds=1, mode="async", async_buffer=1)

    def test_failure_prob_drops_updates_in_coupled_path(self):
        """``failure_prob`` must roll in the coupled path exactly as in
        run_round: with certain failure no update ever applies."""
        import jax

        sim = self._cosim()
        sim.server.failure_prob = 1.0
        before = jax.tree.leaves(sim.server.global_params)[0].copy()
        res = sim.run(n_rounds=2, mode="async", async_buffer=2)
        after = jax.tree.leaves(sim.server.global_params)[0]
        assert all(r["n_arrived"] == 0 for r in res.rounds)
        np.testing.assert_array_equal(np.asarray(before),
                                      np.asarray(after))

    def test_async_rejects_compression_measured_bits(self):
        sim = self._cosim()
        with pytest.raises(ValueError, match="decoupled"):
            sim.run(n_rounds=1, mode="async", async_buffer=1,
                    update_bits_from_compression=True)

    def test_unknown_mode_raises(self):
        sim = self._cosim()
        with pytest.raises(ValueError, match="unknown mode"):
            sim.run(n_rounds=1, mode="eventually")


class TestCoSimTimingCacheKey:
    """Satellite bugfix: ``_round_sync_time`` must key on the payload
    sizes — mutating ``cfg`` between ``run()`` calls on a reused co-sim
    must re-simulate, not serve stale timings."""

    def test_model_bits_change_invalidates_cache(self):
        pytest.importorskip("jax")
        sim = TestCoSimCoupled._cosim(self)
        res1 = sim.run(n_rounds=1, backend="per_round")
        t1 = res1.rounds[0]["sync_time_s"]
        # mutate ONLY model_bits: the upload profiles (and with them
        # the old, buggy cache key) stay identical, but the download
        # broadcast grows ~0.9s — a stale cache would return t1
        sim.cfg.model_bits = sim.cfg.model_bits * 400
        res2 = sim.run(n_rounds=1, backend="per_round")
        t2 = res2.rounds[0]["sync_time_s"]
        assert t2 > t1 + 0.5, (
            "bigger model broadcast must yield a longer simulated "
            "sync (stale cache served)"
        )
