"""Vectorized PON engine vs the cycle-by-cycle reference simulator.

The engine must reproduce the reference's per-client done-times exactly
(rtol 1e-6) when both consume the same background arrival process; the
property test drives both backends with identical injected arrival
matrices over random workloads, loads and policies.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # seeded trials below still cover parity
    HAVE_HYPOTHESIS = False

from repro.core.slicing import ClientProfile  # noqa: E402
from repro.net import (  # noqa: E402
    FLRoundWorkload,
    PONConfig,
    PrecomputedSource,
    SweepCase,
    simulate_round,
    simulate_round_sweep,
)

PACKET = 12_000.0            # 1500 B frames, as the traffic model


def _arrival_matrix(rng, n_cycles, n_onus, load, line_rate, cycle_s,
                    burst=8.0):
    per_onu = load * line_rate / n_onus
    lam = per_onu / (PACKET * burst) * cycle_s
    counts = rng.poisson(lam, (n_cycles, n_onus))
    packets = counts.astype(np.float64)
    nz = counts > 0
    if nz.any():
        packets[nz] += rng.negative_binomial(counts[nz], 1.0 / burst)
    return packets * PACKET


def _run_both(cfg, wl, policy, load, seed):
    T = 25_000
    rng = np.random.default_rng(seed + 10_000)
    dl = _arrival_matrix(rng, T, cfg.n_onus, load, cfg.line_rate_bps,
                         cfg.cycle_time_s)
    ul = _arrival_matrix(rng, T, cfg.n_onus, load, cfg.line_rate_bps,
                         cfg.cycle_time_s)
    ref = simulate_round(
        cfg, wl, load, policy, seed=seed, backend="reference",
        _dl_sources=[PrecomputedSource(dl[:, i]) for i in range(cfg.n_onus)],
        _ul_sources=[PrecomputedSource(ul[:, i]) for i in range(cfg.n_onus)],
    )
    eng = simulate_round_sweep(
        cfg,
        [SweepCase(workload=wl, load=load, policy=policy, seed=seed,
                   dl_arrivals=dl, ul_arrivals=ul)],
    )[0]
    return ref, eng


def _assert_parity(ref, eng):
    for name in ("dl_done", "ready", "ul_done"):
        a, b = getattr(ref, name), getattr(eng, name)
        assert set(a) == set(b)
        for cid in a:
            assert b[cid] == pytest.approx(a[cid], rel=1e-6), (
                f"{name}[{cid}]: reference={a[cid]} vectorized={b[cid]}"
            )
    assert eng.sync_time == pytest.approx(ref.sync_time, rel=1e-6)
    assert eng.compute_bound == pytest.approx(ref.compute_bound, rel=1e-6)


class TestEngineMatchesReferenceSeeded:
    """Deterministic randomized parity trials (run with or without
    hypothesis installed)."""

    @pytest.mark.parametrize("trial", range(8))
    def test_parity_random_workloads(self, trial):
        rng = np.random.default_rng(1000 + trial)
        policy = ["fcfs", "bs"][trial % 2]
        n_onus = int(rng.integers(2, 6))
        n = int(rng.integers(1, 9))
        if policy == "bs":
            ids = rng.choice(n_onus, size=min(n, n_onus),
                             replace=False).tolist()
        else:
            # ids beyond n_onus exercise multi-client-per-ONU queues
            ids = list(dict.fromkeys(
                rng.integers(0, 3 * n_onus, size=n).tolist()
            ))
        clients = [
            ClientProfile(client_id=int(i),
                          t_ud=float(rng.uniform(0.05, 1.5)),
                          t_dl=0.0,
                          m_ud_bits=float(rng.uniform(1e4, 3e6)))
            for i in ids
        ]
        cfg = PONConfig(n_onus=n_onus, line_rate_bps=1e9)
        wl = FLRoundWorkload(clients=clients, model_bits=1.5e6)
        load = float(rng.uniform(0.05, 0.85))
        ref, eng = _run_both(cfg, wl, policy, load, seed=trial)
        _assert_parity(ref, eng)


if HAVE_HYPOTHESIS:
    workloads = st.lists(
        st.tuples(
            st.floats(0.05, 1.5),        # t_ud
            st.floats(1e4, 3e6),         # m_ud bits
        ),
        min_size=1,
        max_size=8,
    )

    class TestEngineMatchesReferenceHypothesis:
        @settings(max_examples=12, deadline=None)
        @given(workloads, st.floats(0.05, 0.85), st.integers(0, 10_000),
               st.integers(2, 5))
        def test_fcfs_parity_random_workloads(self, profs, load, seed,
                                              n_onus):
            # ids beyond n_onus exercise multi-client-per-ONU queues
            clients = [
                ClientProfile(client_id=3 * i + 1, t_ud=t, t_dl=0.0,
                              m_ud_bits=m)
                for i, (t, m) in enumerate(profs)
            ]
            cfg = PONConfig(n_onus=n_onus, line_rate_bps=1e9)
            wl = FLRoundWorkload(clients=clients, model_bits=1.5e6)
            ref, eng = _run_both(cfg, wl, "fcfs", load, seed)
            _assert_parity(ref, eng)

        @settings(max_examples=12, deadline=None)
        @given(workloads, st.floats(0.05, 0.85), st.integers(0, 10_000))
        def test_bs_parity_random_workloads(self, profs, load, seed):
            n_onus = max(len(profs), 2)
            clients = [
                ClientProfile(client_id=i, t_ud=t, t_dl=0.0, m_ud_bits=m)
                for i, (t, m) in enumerate(profs)
            ]
            cfg = PONConfig(n_onus=n_onus, line_rate_bps=1e9)
            wl = FLRoundWorkload(clients=clients, model_bits=1.5e6)
            ref, eng = _run_both(cfg, wl, "bs", load, seed)
            _assert_parity(ref, eng)


class TestSeedRegression:
    """The reference backend's sync_time at the paper's operating point
    (128 ONUs, 10G, 26.416 Mbit updates, load 0.8, seed 1) must stay
    exactly what the seed repo produced."""

    @staticmethod
    def _workload(n=12):
        rng = np.random.default_rng(42)
        t_uds = rng.uniform(1.0, 5.0, 128)
        clients = [
            ClientProfile(client_id=i, t_ud=float(t_uds[i]), t_dl=0.0,
                          m_ud_bits=26.416e6)
            for i in range(n)
        ]
        return FLRoundWorkload(clients=clients, model_bits=26.416e6)

    def test_reference_fcfs_sync_unchanged_from_seed(self):
        r = simulate_round(PONConfig(n_onus=128), self._workload(), 0.8,
                           "fcfs", seed=1, backend="reference")
        assert r.sync_time == pytest.approx(5.029100000000014, abs=1e-9)

    def test_reference_bs_sync_unchanged_from_seed(self):
        r = simulate_round(PONConfig(n_onus=128), self._workload(), 0.8,
                           "bs", seed=1, backend="reference")
        assert r.sync_time == pytest.approx(4.909099999999974, abs=1e-9)

    def test_vectorized_close_to_reference_at_operating_point(self):
        # different RNG stream, same queueing model: close, not equal
        r = simulate_round(PONConfig(n_onus=128), self._workload(), 0.8,
                           "fcfs", seed=1, backend="vectorized")
        assert r.sync_time == pytest.approx(5.0291, rel=0.05)


class TestSweepAPI:
    def _cases(self):
        rng = np.random.default_rng(3)
        clients = [
            ClientProfile(client_id=i, t_ud=float(t), t_dl=0.0,
                          m_ud_bits=2e6)
            for i, t in enumerate(rng.uniform(0.2, 1.0, 6))
        ]
        wl = FLRoundWorkload(clients=clients, model_bits=2e6)
        return [
            SweepCase(workload=wl, load=load, policy=policy, seed=s)
            for policy in ("fcfs", "bs")
            for load in (0.3, 0.8)
            for s in (0, 1)
        ]

    def test_batched_equals_per_case(self):
        """Batch composition must not change any case's result."""
        cfg = PONConfig(n_onus=8, line_rate_bps=1e9)
        cases = self._cases()
        batched = simulate_round_sweep(cfg, cases)
        for case, got in zip(cases, batched):
            solo = simulate_round_sweep(cfg, [case])[0]
            assert got.sync_time == solo.sync_time
            assert got.ul_done == solo.ul_done

    def test_sweep_preserves_headline_ordering(self):
        cfg = PONConfig(n_onus=8, line_rate_bps=1e9)
        res = {(c.policy, c.load, c.seed): r
               for c, r in zip(self._cases(),
                               simulate_round_sweep(cfg, self._cases()))}
        # BS is load-independent; FCFS grows with load
        assert res[("bs", 0.8, 0)].sync_time == pytest.approx(
            res[("bs", 0.3, 0)].sync_time, rel=0.05
        )
        assert (res[("fcfs", 0.8, 0)].sync_time
                >= res[("fcfs", 0.3, 0)].sync_time - 1e-6)

    def test_bs_requires_client_ids_within_onus(self):
        clients = [ClientProfile(client_id=9, t_ud=0.5, t_dl=0.0,
                                 m_ud_bits=1e6)]
        wl = FLRoundWorkload(clients=clients, model_bits=1e6)
        with pytest.raises(ValueError, match="client_id < n_onus"):
            simulate_round_sweep(
                PONConfig(n_onus=4),
                [SweepCase(workload=wl, load=0.5, policy="bs", seed=0)],
            )

    def test_duplicate_client_ids_rejected(self):
        clients = [
            ClientProfile(client_id=1, t_ud=0.5, t_dl=0.0, m_ud_bits=1e6),
            ClientProfile(client_id=1, t_ud=0.7, t_dl=0.0, m_ud_bits=1e6),
        ]
        wl = FLRoundWorkload(clients=clients, model_bits=1e6)
        with pytest.raises(ValueError, match="duplicate client_id"):
            simulate_round_sweep(
                PONConfig(n_onus=4),
                [SweepCase(workload=wl, load=0.5, policy="fcfs", seed=0)],
            )


class TestBgQueueSnapHead:
    """A partial grant whose sub-1-bit residue snaps a segment away
    must leave the head pointer on the next *arrival* cycle (the
    reference's restore loop), not on a possibly-empty ptr+1 cycle."""

    def test_snap_advances_to_next_real_segment(self):
        from repro.net.dba import OnuQueue
        from repro.net.engine import _BgQueues

        bg = _BgQueues(1, 1)
        ref = OnuQueue(0)
        arrivals = [1000.0, 0.0, 0.0, 500.0]
        for k, bits in enumerate(arrivals):
            bg.push(k, np.array([[bits]]))
            if bits:
                ref.push("bg", bits, float(k))
        bg.serve(np.array([[999.5]]), k=3)
        ref.serve(999.5, kind="bg")
        assert bg.backlog[0, 0] == pytest.approx(ref.backlog)
        # FCFS age key == the surviving segment's arrival cycle
        assert int(bg.hol_key()[0, 0]) == 3
        assert ref.hol_time == pytest.approx(3.0)


class TestServeRebuild:
    """The single-pass OnuQueue.serve keeps its exact semantics."""

    def test_many_segments_fifo_and_compaction(self):
        from repro.net.dba import OnuQueue

        q = OnuQueue(0)
        for i in range(50):
            q.push("bg", 100.0, t=float(i))
        served = q.serve(3 * 100.0 + 99.5)     # leaves 0.5 bit in seg 3
        assert served["bg"] == pytest.approx(399.5)
        # the 0.5-bit remnant is compacted away; 46 segments remain
        assert len(q.segments) == 46
        assert q.hol_time == pytest.approx(4.0)
        assert q.backlog == pytest.approx(46 * 100.0)

    def test_kind_filter_preserves_other_kind(self):
        from repro.net.dba import OnuQueue

        q = OnuQueue(0)
        q.push("bg", 50.0, t=0.0)
        q.push("fl", 80.0, t=1.0)
        q.push("bg", 50.0, t=2.0)
        served = q.serve(100.0, kind="bg")
        assert served == {"bg": pytest.approx(100.0)}
        assert q.backlog_of("fl") == pytest.approx(80.0)
        assert q.hol_time == pytest.approx(1.0)
