"""End-to-end behaviour tests: the paper's system claims, in miniature.

A reduced FL task (16 clients, LEAF-style synthetic FEMNIST, real JAX
training) co-simulated with the PON: accuracy must improve over rounds,
more clients must reach higher accuracy (Fig 2a), and BS must beat FCFS on
wall-clock time-to-accuracy at high load (the 36%-saving claim, reduced).
"""
import jax
import numpy as np
import pytest

from repro.data import build_federated_cnn_clients
from repro.fl import (
    CompressorConfig,
    CoSimConfig,
    CPSServer,
    FLNetworkCoSim,
    SelectionConfig,
)
from repro.fl.client import LocalTrainConfig
from repro.models import cnn
from repro.net.sim import PONConfig


def _build(n_clients=8, fraction=1.0, policy="bs", load=0.8, seed=0,
           failure_prob=0.0, scheme="none", n_classes=62):
    clients, test = build_federated_cnn_clients(
        n_clients=n_clients,
        samples_per_client=48,
        loss_fn=cnn.loss_fn,
        train_cfg=LocalTrainConfig(lr=0.05, batch_size=16, local_epochs=1),
        seed=seed,
    )
    params = cnn.init_params(jax.random.PRNGKey(seed))
    server = CPSServer(
        global_params=params,
        clients=clients,
        selection=SelectionConfig(strategy="fraction", fraction=fraction),
        compression=CompressorConfig(scheme=scheme),
        failure_prob=failure_prob,
        seed=seed,
    )
    cfg = CoSimConfig(
        policy=policy,
        total_load=load,
        pon=PONConfig(n_onus=max(n_clients, 8)),
        timing_seeds=1,
    )
    test_batch = {"images": test["images"][:256], "labels": test["labels"][:256]}
    def eval_fn(p):
        return cnn.accuracy(p, test_batch)
    return FLNetworkCoSim(server, cfg), eval_fn


@pytest.mark.slow
class TestEndToEnd:
    def test_accuracy_improves_over_rounds(self):
        sim, eval_fn = _build(n_clients=8)
        res = sim.run(n_rounds=6, eval_fn=eval_fn)
        accs = [r["eval_metric"] for r in res.rounds]
        assert accs[-1] > accs[0] + 0.05
        assert accs[-1] > 0.10          # far above 1/62 chance

    def test_more_clients_higher_accuracy(self):
        """Fig 2a: involvement fraction drives saturated accuracy."""
        sim_small, ev = _build(n_clients=8, fraction=0.25, seed=1)
        sim_full, ev2 = _build(n_clients=8, fraction=1.0, seed=1)
        acc_small = sim_small.run(n_rounds=5, eval_fn=ev).rounds[-1][
            "eval_metric"]
        acc_full = sim_full.run(n_rounds=5, eval_fn=ev2).rounds[-1][
            "eval_metric"]
        assert acc_full >= acc_small - 0.02

    def test_bs_faster_than_fcfs_to_same_accuracy(self):
        """The headline claim: identical learning curve, less wall-clock."""
        sim_bs, ev = _build(policy="bs", load=0.8, seed=2)
        sim_fcfs, ev2 = _build(policy="fcfs", load=0.8, seed=2)
        res_bs = sim_bs.run(n_rounds=3, eval_fn=ev)
        res_fcfs = sim_fcfs.run(n_rounds=3, eval_fn=ev2)
        # same seeds -> identical training; BS strictly faster per round
        assert res_bs.sync_time_s < res_fcfs.sync_time_s
        assert res_bs.total_time_s < res_fcfs.total_time_s

    def test_survives_client_failures(self):
        sim, ev = _build(failure_prob=0.3, seed=3)
        res = sim.run(n_rounds=4, eval_fn=ev)
        assert len(res.rounds) == 4
        assert all(np.isfinite(r["mean_loss"]) or r["n_arrived"] == 0
                   for r in res.rounds)

    def test_compression_reduces_slice_demand(self):
        """int8 updates shrink M_i^UD and hence the BS slice bandwidth."""
        from repro.core.slicing import ClientProfile, compute_slice

        full = [ClientProfile(i, 1.0 + i, 0.01, 26.416e6) for i in range(4)]
        comp = [ClientProfile(i, 1.0 + i, 0.01, 26.416e6 / 4) for i in range(4)]
        # the M_i^UD lever acts on the paper's demand formula (line 8)
        s_full = compute_slice(full, 0.0, 10.0, 10e9, sizing="paper")
        s_comp = compute_slice(comp, 0.0, 10.0, 10e9, sizing="paper")
        assert s_comp.bandwidth_bps < s_full.bandwidth_bps / 3.5
        # and the corrected sizing still demands no more for smaller updates
        d_full = compute_slice(full, 0.0, 10.0, 10e9)
        d_comp = compute_slice(comp, 0.0, 10.0, 10e9)
        assert d_comp.bandwidth_bps <= d_full.bandwidth_bps + 1e-6
