"""Tests for repro.analysis — the invariant-aware static analysis pass.

Layout mirrors the rule list in DESIGN.md §13: for every RPA0xx code a
violating fixture must fire and its fixed twin must stay silent; the
stream-key disjointness rule is additionally exercised end to end by
corrupting one Weyl constant in a synthetic repro-shaped tree; and the
real package must come out clean modulo the checked-in baseline.
"""

from __future__ import annotations

import ast
import json
import os

import pytest

from repro.analysis import ANALYSIS_VERSION
from repro.analysis.baseline import apply_baseline, load_baseline
from repro.analysis.cli import main
from repro.analysis.core import ModuleInfo, all_checkers, run_checkers
from repro.analysis.selftest import run_self_test

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _findings(code, path, source):
    mod = ModuleInfo(path=path, tree=ast.parse(source), source=source)
    return run_checkers([mod], all_checkers(select=[code]))


def _assert_fires(code, path, source):
    found = _findings(code, path, source)
    assert any(f.code == code for f in found), f"{code} did not fire"
    return found


def _assert_silent(code, path, source):
    found = _findings(code, path, source)
    assert not found, f"{code} fired unexpectedly: {found[0].message}"


# ---------------------------------------------------------------------------
# RPA001 — host RNG in engine paths


def test_rpa001_fires_on_unseeded_numpy_rng():
    _assert_fires(
        "RPA001",
        "repro/net/x.py",
        "import numpy as np\n"
        "def jitter(n):\n"
        "    return np.random.poisson(3.0, n)\n",
    )


def test_rpa001_fires_on_stdlib_random():
    _assert_fires(
        "RPA001",
        "repro/kernels/x.py",
        "import random\n"
        "def pick(xs):\n"
        "    return random.choice(xs)\n",
    )


def test_rpa001_silent_on_seeded_generator():
    _assert_silent(
        "RPA001",
        "repro/net/x.py",
        "import numpy as np\n"
        "def jitter(n, seed):\n"
        "    return np.random.default_rng(seed).poisson(3.0, n)\n",
    )


def test_rpa001_scoped_to_engine_packages():
    # the same host RNG outside net/kernels/faults is out of scope
    _assert_silent(
        "RPA001",
        "repro/obs/x.py",
        "import random\n"
        "def pick(xs):\n"
        "    return random.choice(xs)\n",
    )


# ---------------------------------------------------------------------------
# RPA002 — wall-clock reads


def test_rpa002_fires_on_time_time():
    _assert_fires(
        "RPA002",
        "repro/net/x.py",
        "import time\n"
        "def stamp(rows):\n"
        "    return [(time.time(), r) for r in rows]\n",
    )


def test_rpa002_silent_when_time_is_a_parameter():
    _assert_silent(
        "RPA002",
        "repro/net/x.py",
        "def stamp(rows, now_s):\n"
        "    return [(now_s, r) for r in rows]\n",
    )


def test_rpa002_respects_noqa():
    _assert_silent(
        "RPA002",
        "repro/net/x.py",
        "import time\n"
        "def stamp():\n"
        "    return time.time()  # noqa: RPA002\n",
    )


# ---------------------------------------------------------------------------
# RPA003 — unordered iteration


def test_rpa003_fires_on_set_iteration():
    _assert_fires(
        "RPA003",
        "repro/net/x.py",
        "def total(ids):\n"
        "    out = 0.0\n"
        "    for i in set(ids):\n"
        "        out += 1.0 / (1 + i)\n"
        "    return out\n",
    )


def test_rpa003_fires_on_unsorted_listdir():
    _assert_fires(
        "RPA003",
        "repro/faults/x.py",
        "import os\n"
        "def cases(d):\n"
        "    return [f for f in os.listdir(d)]\n",
    )


def test_rpa003_silent_when_sorted():
    _assert_silent(
        "RPA003",
        "repro/net/x.py",
        "def total(ids):\n"
        "    out = 0.0\n"
        "    for i in sorted(set(ids)):\n"
        "        out += 1.0 / (1 + i)\n"
        "    return out\n",
    )


def test_rpa003_silent_on_order_free_reductions():
    _assert_silent(
        "RPA003",
        "repro/net/x.py",
        "def n_unique(ids):\n"
        "    return len(set(ids))\n",
    )


# ---------------------------------------------------------------------------
# RPA004 — ambient x64 flips


def test_rpa004_fires_on_ambient_config_update():
    _assert_fires(
        "RPA004",
        "repro/util.py",
        "import jax\n"
        "jax.config.update(\"jax_enable_x64\", True)\n",
    )


def test_rpa004_fires_on_env_var_store():
    _assert_fires(
        "RPA004",
        "repro/util.py",
        "import os\n"
        "os.environ[\"JAX_ENABLE_X64\"] = \"1\"\n",
    )


def test_rpa004_silent_on_scoped_context():
    _assert_silent(
        "RPA004",
        "repro/util.py",
        "from jax.experimental import enable_x64\n"
        "def run(fn):\n"
        "    with enable_x64():\n"
        "        return fn()\n",
    )


# ---------------------------------------------------------------------------
# RPA005 — tracer purity


def test_rpa005_fires_on_branch_and_float_in_traced_ref():
    found = _assert_fires(
        "RPA005",
        "repro/kernels/x/ref.py",
        "import jax.numpy as jnp\n"
        "def scale_ref(x, lim):\n"
        "    if x > lim:\n"
        "        return float(x)\n"
        "    return jnp.minimum(x, lim)\n",
    )
    assert len(found) >= 2  # both the branch and the float() sync


def test_rpa005_fires_on_item_in_jit_callee():
    _assert_fires(
        "RPA005",
        "repro/kernels/x/ops.py",
        "import jax\n"
        "import jax.numpy as jnp\n"
        "def _step(c):\n"
        "    return jnp.float32(c.item())\n"
        "run = jax.jit(_step)\n",
    )


def test_rpa005_silent_on_lax_cond():
    _assert_silent(
        "RPA005",
        "repro/kernels/x/ref.py",
        "import jax.numpy as jnp\n"
        "def scale_ref(x, lim):\n"
        "    return jnp.where(x > lim, x, jnp.minimum(x, lim))\n",
    )


def test_rpa005_annotated_static_param_is_not_a_tracer():
    # regression: `n: int` kw-only config params may drive Python
    # control flow even when the name also appears (via a closure)
    # inside lax/jnp call arguments — ponsim's sample_window_ref shape
    _assert_silent(
        "RPA005",
        "repro/kernels/x/ref.py",
        "import jax.numpy as jnp\n"
        "from jax import lax\n"
        "def win_ref(x, *, n_draws: int):\n"
        "    j_half = max(1, n_draws // 2)\n"
        "    if j_half < n_draws:\n"
        "        x = x * 2\n"
        "    return lax.cond(\n"
        "        jnp.sum(x) > 0,\n"
        "        lambda p: p * n_draws,\n"
        "        lambda p: p,\n"
        "        x,\n"
        "    )\n",
    )


def test_rpa005_static_shape_branch_is_fine():
    _assert_silent(
        "RPA005",
        "repro/kernels/x/ref.py",
        "import jax.numpy as jnp\n"
        "def pad_ref(x):\n"
        "    if x.ndim == 1:\n"
        "        x = x[None, :]\n"
        "    return jnp.cumsum(x, axis=-1)\n",
    )


# ---------------------------------------------------------------------------
# RPA007 — collector purity


def test_rpa007_fires_on_unguarded_collector_use():
    _assert_fires(
        "RPA007",
        "repro/net/x.py",
        "def simulate(state, collector=None):\n"
        "    collector.event(\"round\")\n"
        "    return state + 1\n",
    )


def test_rpa007_fires_on_engine_write_in_guard():
    _assert_fires(
        "RPA007",
        "repro/net/x.py",
        "def simulate(state, collector=None):\n"
        "    if collector is not None:\n"
        "        collector.event(\"round\")\n"
        "        state = state + 1\n"
        "    return state\n",
    )


def test_rpa007_silent_on_guarded_readonly_obs():
    _assert_silent(
        "RPA007",
        "repro/net/x.py",
        "def simulate(state, collector=None):\n"
        "    if collector is not None:\n"
        "        collector.event(\"round\", state=state)\n"
        "    return state + 1\n",
    )


def test_rpa007_silent_on_early_none_return():
    _assert_silent(
        "RPA007",
        "repro/net/x.py",
        "def record(collector, rows):\n"
        "    if collector is None or not rows:\n"
        "        return\n"
        "    collector.event(\"rows\", n=len(rows))\n",
    )


def test_rpa007_required_collector_is_out_of_scope():
    # regression: a helper whose collector argument is mandatory (no
    # None default, never None-tested) is not an optional-obs entry
    # point — obs/export.py's MetricsReport.from_collector shape
    _assert_silent(
        "RPA007",
        "repro/obs/x.py",
        "def export(collector):\n"
        "    rows = collector.rows()\n"
        "    return {\"n\": len(rows), \"meta\": collector.meta}\n",
    )


def test_rpa007_passing_collector_through_is_not_an_alias():
    # regression: `timeline = simulate(..., collector=collector)` must
    # not mark `timeline` as a collector alias (launch/train.py shape)
    _assert_silent(
        "RPA007",
        "repro/net/x.py",
        "def run(cfg, collector=None):\n"
        "    timeline = simulate(cfg, collector=collector)\n"
        "    total = timeline.sum()\n"
        "    return total\n",
    )


# ---------------------------------------------------------------------------
# RPA006 — stream-key disjointness (synthetic repro-shaped tree)

_REF_SRC = (
    "KEY_WEYL_0 = 0x9E3779B9\n"
    "KEY_WEYL_1 = 0x85EBCA6B\n"
    "_C240 = 0x1BD11BDA\n"
)
_OPS_SRC = (
    "_PON_WEYL_0 = 0xCC9E2D51\n"
    "_PON_WEYL_1 = 0x1B873593\n"
    "_JOB_WEYL_0 = 0xC2B2AE35\n"
    "_JOB_WEYL_1 = 0x27D4EB2F\n"
)
_STREAMS_SRC = (
    "_CLASS_WEYL_0 = 0x9E3779B1\n"
    "_CLASS_WEYL_1 = 0x85EBCA77\n"
    "_CASE_WEYL = 0x6C8E9CF5\n"
)


def _write_tree(tmp_path, streams_src):
    pkg = tmp_path / "repro"
    (pkg / "kernels" / "traffic").mkdir(parents=True)
    (pkg / "faults").mkdir()
    (pkg / "kernels" / "traffic" / "ref.py").write_text(_REF_SRC)
    (pkg / "kernels" / "traffic" / "ops.py").write_text(_OPS_SRC)
    (pkg / "faults" / "streams.py").write_text(streams_src)
    return str(pkg)


def test_rpa006_clean_registry_passes(tmp_path):
    root = _write_tree(tmp_path, _STREAMS_SRC)
    assert main(["--select", "RPA006", root]) == 0


def test_rpa006_corrupted_weyl_constant_fails(tmp_path, capsys):
    # corrupt one fault-class constant into the traffic sampler's
    # KEY_WEYL_0 — exactly the latent collision this PR fixed for real
    bad = _STREAMS_SRC.replace("0x9E3779B1", "0x9E3779B9")
    root = _write_tree(tmp_path, bad)
    assert main(["--select", "RPA006", root]) == 1
    out = capsys.readouterr().out
    assert "RPA006" in out and "duplicate" in out


def test_rpa006_even_weyl_increment_fails(tmp_path, capsys):
    bad = _STREAMS_SRC.replace("0x6C8E9CF5", "0x6C8E9CF4")
    root = _write_tree(tmp_path, bad)
    assert main(["--select", "RPA006", root]) == 1
    assert "even" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# RPA008 — kernel-triple conformance

_TRIPLE = {
    "repro/kernels/fake/__init__.py": "",
    "repro/kernels/fake/kernel.py": (
        "def op_fwd(x, block):\n    return x\n"
    ),
    "repro/kernels/fake/ref.py": "def op_ref(x, block):\n    return x\n",
    "repro/kernels/fake/ops.py": "def op(x, block):\n    return x\n",
}


def _triple_findings(overrides):
    files = dict(_TRIPLE)
    files.update(overrides)
    mods = [
        ModuleInfo(path=p, tree=ast.parse(s), source=s)
        for p, s in sorted(files.items())
        if s is not None
    ]
    return run_checkers(mods, all_checkers(select=["RPA008"]))


def test_rpa008_complete_triple_passes():
    assert not _triple_findings({})


def test_rpa008_missing_ref_fires():
    found = _triple_findings({"repro/kernels/fake/ref.py": None})
    assert any("missing" in f.message for f in found)


def test_rpa008_ref_importing_kernel_fires():
    found = _triple_findings(
        {
            "repro/kernels/fake/ref.py": (
                "from repro.kernels.fake import kernel\n"
                "def op_ref(x, block):\n    return x\n"
            )
        }
    )
    assert any("independent witness" in f.message for f in found)


def test_rpa008_transposed_positional_params_fire():
    found = _triple_findings(
        {
            "repro/kernels/fake/ref.py": (
                "def op_ref(block, x):\n    return x\n"
            )
        }
    )
    assert found


def test_rpa008_kwonly_params_are_order_free():
    # regression: traffic's sample_arrival_bits_ref takes its config as
    # keyword-only args — their order vs the dispatch is irrelevant
    assert not _triple_findings(
        {
            "repro/kernels/fake/ops.py": (
                "def op(x, *, block, width):\n    return x\n"
            ),
            "repro/kernels/fake/ref.py": (
                "def op_ref(x, *, width, block):\n    return x\n"
            ),
        }
    )


# ---------------------------------------------------------------------------
# baseline mechanics


def test_baseline_suppresses_and_reports_stale(tmp_path):
    src = (
        "import time\n"
        "def stamp():\n"
        "    return time.time()\n"
    )
    mod = ModuleInfo(
        path="repro/net/x.py", tree=ast.parse(src), source=src
    )
    findings = run_checkers([mod], all_checkers(select=["RPA002"]))
    assert findings
    bl = tmp_path / "bl.json"
    bl.write_text(
        json.dumps(
            {
                "entries": [
                    {
                        "code": "RPA002",
                        "path": "repro/net/x.py",
                        "symbol": "*",
                        "note": "test exemption",
                    },
                    {
                        "code": "RPA001",
                        "path": "repro/net/gone.py",
                        "symbol": "*",
                        "note": "stale on purpose",
                    },
                ]
            }
        )
    )
    new, suppressed, stale = apply_baseline(findings, load_baseline(str(bl)))
    assert not new and suppressed
    assert [e.path for e in stale] == ["repro/net/gone.py"]


def test_baseline_requires_justification(tmp_path):
    bl = tmp_path / "bl.json"
    bl.write_text(
        json.dumps(
            {
                "entries": [
                    {
                        "code": "RPA002",
                        "path": "x.py",
                        "symbol": "*",
                        "note": "   ",
                    }
                ]
            }
        )
    )
    with pytest.raises(ValueError, match="empty note"):
        load_baseline(str(bl))


# ---------------------------------------------------------------------------
# CLI behavior


def test_cli_json_format_and_artifact(tmp_path, capsys):
    pkg = tmp_path / "repro" / "net"
    pkg.mkdir(parents=True)
    (pkg / "x.py").write_text(
        "import time\n"
        "def stamp():\n"
        "    return time.time()\n"
    )
    out_path = tmp_path / "report.json"
    rc = main(
        [
            "--format", "json",
            "--output", str(out_path),
            str(tmp_path / "repro"),
        ]
    )
    assert rc == 1
    payload = json.loads(capsys.readouterr().out)
    assert payload["analysis_version"] == ANALYSIS_VERSION
    assert payload["summary"]["findings"] >= 1
    assert any(f["code"] == "RPA002" for f in payload["findings"])
    on_disk = json.loads(out_path.read_text())
    assert on_disk["summary"] == payload["summary"]


def test_cli_wiring_errors_exit_2(tmp_path):
    assert main([str(tmp_path / "does-not-exist")]) == 2
    empty = tmp_path / "empty"
    empty.mkdir()
    assert main([str(empty)]) == 2


def test_cli_unknown_select_exits_2():
    assert main(["--select", "RPA999", "src/repro"]) == 2


def test_self_test_passes():
    assert run_self_test(verbose=False) == 0


# ---------------------------------------------------------------------------
# the real package is clean modulo the checked-in baseline


def test_self_run_on_repro_is_clean():
    rc = main(
        [
            "--baseline", os.path.join(REPO_ROOT, "analysis-baseline.json"),
            os.path.join(REPO_ROOT, "src", "repro"),
        ]
    )
    assert rc == 0
