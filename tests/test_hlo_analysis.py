"""Unit tests for the HLO static analyser (roofline inputs)."""
import textwrap

from repro.launch.hlo_analysis import HloModule, _type_bytes, analyze


SAMPLE = textwrap.dedent("""
    HloModule jit_step, num_partitions=4

    %body.1 (p.1: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %p.1 = (s32[], f32[8,16]) parameter(0)
      %g.1 = f32[8,16]{1,0} get-tuple-element(%p.1), index=1
      %w.1 = f32[16,16]{1,0} constant({...})
      %dot.1 = f32[8,16]{1,0} dot(%g.1, %w.1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar.1 = f32[8,16]{1,0} all-reduce(%dot.1), replica_groups={}
      %i.1 = s32[] get-tuple-element(%p.1), index=0
      ROOT %t.1 = (s32[], f32[8,16]) tuple(%i.1, %ar.1)
    }

    %cond.1 (p.2: (s32[], f32[8,16])) -> pred[] {
      %p.2 = (s32[], f32[8,16]) parameter(0)
      %i.2 = s32[] get-tuple-element(%p.2), index=0
      %c.2 = s32[] constant(10)
      ROOT %lt = pred[] compare(%i.2, %c.2), direction=LT
    }

    ENTRY %main.1 (arg0: f32[8,16]) -> f32[8,16] {
      %arg0 = f32[8,16]{1,0} parameter(0)
      %i0 = s32[] constant(0)
      %t0 = (s32[], f32[8,16]) tuple(%i0, %arg0)
      %w = (s32[], f32[8,16]) while(%t0), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
      %out = f32[8,16]{1,0} get-tuple-element(%w), index=1
      %ag = f32[32,16]{1,0} all-gather(%out), dimensions={0}
      ROOT %slice = f32[8,16]{1,0} slice(%ag), slice={[0:8], [0:16]}
    }
""")


def test_type_bytes():
    assert _type_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
    assert _type_bytes("bf16[4]") == 8
    assert _type_bytes("(s32[], f32[2,2])") == 4 + 16
    assert _type_bytes("pred[]") == 1


def test_known_trip_count_used():
    mod = HloModule(SAMPLE)
    mult = mod.multipliers([999])        # fallback must NOT be used
    assert mult["body.1"] == 10
    assert mult["main.1"] == 1


def test_dot_flops_with_loop_expansion():
    a = analyze(SAMPLE, loop_trips=[1])
    # dot: 2 * (8*16) * 16 = 4096 flops per trip, 10 trips
    assert a["flops"] == 2 * 8 * 16 * 16 * 10
    assert a["dot_count"] == 1


def test_collective_bytes_per_kind():
    a = analyze(SAMPLE)
    per = a["collectives"]["per_kind"]
    # all-reduce inside the loop: 8*16*4 bytes x 10 trips
    assert per["all-reduce"] == 8 * 16 * 4 * 10
    # all-gather at entry: result 32*16*4, once
    assert per["all-gather"] == 32 * 16 * 4


def test_hbm_bytes_counts_fusion_boundaries():
    a = analyze(SAMPLE)
    assert a["hbm_bytes"] > 0
