"""Observability (``repro.obs``): the no-perturbation contract + tools.

Four guarantees are pinned here:

* ``collector=None`` (the default everywhere) is *bitwise identical*
  to an uninstrumented build — including the Fig. 2b operating-point
  sync pin, every timeline deadline mode, and the multi-PON oracle;
* the streaming histogram (both the scattered ``add`` and the chunked
  ``add_block_per_row`` fast path) matches ``np.histogram`` counts
  exactly and ``np.percentile`` estimates within the bin width;
* Chrome traces round-trip through save/load/validate;
* ``launch.train --log-jsonl`` writes parseable structured events
  whose console lines are a formatted view of the same records.
"""
import json

import numpy as np
import pytest

from repro.core.slicing import ClientProfile
from repro.net import (
    FLRoundWorkload,
    MultiPonTopology,
    PONConfig,
    SweepCase,
    TimelineSchedule,
    simulate_round_sweep,
    simulate_timeline_sweep,
)
from repro.net.multi_pon import simulate_multi_pon_round
from repro.obs import (
    Collector,
    EventLog,
    GaugeArray,
    SpanTracer,
    StreamingHistogram,
)
from repro.obs.trace import load_trace, validate_trace


def _op_point_case(policy="fcfs"):
    """The pinned Fig. 2b operating point (BENCH_net_engine.json)."""
    rng = np.random.default_rng(42)
    t_uds = rng.uniform(1.0, 5.0, 128)
    clients = [
        ClientProfile(client_id=i, t_ud=float(t_uds[i]), t_dl=0.0,
                      m_ud_bits=26.416e6)
        for i in range(12)
    ]
    wl = FLRoundWorkload(clients=clients, model_bits=26.416e6)
    return SweepCase(workload=wl, load=0.8, policy=policy, seed=1)


def _nan_safe(items):
    """NaN compares unequal to itself; map it to None for tuple
    equality (identity here means bit-identical or both-NaN)."""
    return tuple(
        (k, None if isinstance(v, float) and np.isnan(v) else v)
        for k, v in items
    )


def _fingerprint(res):
    """Everything a timeline result exposes, as comparable tuples."""
    out = []
    for rnd in res.rounds:
        out.append((
            rnd.result.sync_time,
            _nan_safe(sorted(rnd.result.ul_done.items())),
            tuple(sorted(rnd.ul_bits.items())),
            tuple(sorted(rnd.arrived)),
            tuple(sorted(rnd.deferred.items())),
            tuple(sorted(rnd.dropped)),
            tuple(sorted(rnd.partial.items())),
            tuple(sorted(rnd.staleness.items())),
        ))
    return out


class TestDisabledCollectorIdentity:
    def test_round_sweep_bitwise_and_pinned(self):
        cfg = PONConfig(n_onus=128)
        cases = [_op_point_case("fcfs"), _op_point_case("bs")]
        base = simulate_round_sweep(cfg, cases)
        col = Collector(tracer=SpanTracer())
        inst = simulate_round_sweep(cfg, cases, collector=col)
        for a, b in zip(base, inst):
            assert a.sync_time == b.sync_time
            assert a.dl_done == b.dl_done
            assert a.ul_done == b.ul_done
        # the PR 3/4 operating-point pin still holds on both paths
        assert base[0].sync_time == pytest.approx(5.058100000000024,
                                                  abs=1e-9)
        # and the enabled run actually collected
        assert len(col.phases) == 3
        assert ("fcfs", 0.8) in col.delay_hist

    @pytest.mark.parametrize("schedule", [
        TimelineSchedule(n_rounds=3),
        TimelineSchedule(n_rounds=3, deadline_s=4.0,
                         deadline_policy="drop"),
        TimelineSchedule(n_rounds=3, deadline_s=4.0,
                         deadline_policy="partial"),
        TimelineSchedule(n_rounds=3, deadline_s=4.0,
                         deadline_policy="defer"),
        TimelineSchedule(n_rounds=3, buffer_k=6),
    ], ids=["nodl", "drop", "partial", "defer", "async"])
    def test_timeline_bitwise(self, schedule):
        cfg = PONConfig(n_onus=128)
        cases = [_op_point_case("fcfs"), _op_point_case("bs")]
        base = simulate_timeline_sweep(cfg, cases, schedule)
        on = simulate_timeline_sweep(cfg, cases, schedule,
                                     collector=Collector())
        for a, b in zip(base, on):
            assert np.array_equal(a.sync_times, b.sync_times)
            assert _fingerprint(a) == _fingerprint(b)

    def test_multi_pon_oracle_bitwise(self):
        # feasible CPS share (an overloaded shared uplink starves FL
        # behind prioritized background and runs the oracle to max_t)
        cfg = PONConfig(n_onus=4, line_rate_bps=1e9)
        topo = MultiPonTopology(n_pons=2, cps_rate_bps=15e9)
        rng = np.random.default_rng(5)
        clients = [
            ClientProfile(client_id=i, t_ud=float(rng.uniform(0.05, 0.3)),
                          t_dl=0.0, m_ud_bits=2e6)
            for i in range(6)
        ]
        wl = FLRoundWorkload(clients=clients, model_bits=2e6)
        base = simulate_multi_pon_round(cfg, topo, wl, 0.5, "fcfs",
                                        seed=3, max_t=5.0)
        col = Collector()
        inst = simulate_multi_pon_round(cfg, topo, wl, 0.5, "fcfs",
                                        seed=3, max_t=5.0, collector=col)
        assert base.sync_time == inst.sync_time
        assert base.ul_done == inst.ul_done
        assert col.counters["multi_pon.cps_want_bits"].total > 0.0


class TestStreamingHistogram:
    def test_counts_match_numpy(self):
        rng = np.random.default_rng(0)
        vals = rng.uniform(-1.0, 31.0, 5000)  # spills both edge bins
        edges = np.linspace(0.0, 30.0, 61)
        h = StreamingHistogram(edges)
        h.add(vals)
        ref, _ = np.histogram(vals, bins=edges)
        np.testing.assert_array_equal(h.counts[1:-1], ref)
        assert h.counts[0] == np.sum(vals < edges[0])
        assert h.counts[-1] == np.sum(vals > edges[-1])
        assert float(h.n) == vals.size
        assert float(h.sum) == pytest.approx(vals.sum(), rel=1e-12)

    def test_exact_edges_follow_numpy_convention(self):
        edges = np.array([0.0, 1.0, 2.0, 3.0])
        h = StreamingHistogram(edges)
        vals = np.array([0.0, 1.0, 2.0, 3.0])  # top edge -> last bin
        h.add(vals)
        ref, _ = np.histogram(vals, bins=edges)
        np.testing.assert_array_equal(h.counts[1:-1], ref)

    def test_percentiles_close_to_numpy(self):
        rng = np.random.default_rng(1)
        vals = rng.gamma(2.0, 2.0, 20000)
        edges = np.linspace(0.0, 30.0, 301)
        h = StreamingHistogram(edges)
        h.add(vals)
        width = float(edges[1] - edges[0])
        for q in (50.0, 95.0, 99.0):
            est = h.percentile(q)
            ref = np.percentile(vals, q)
            assert est == pytest.approx(ref, abs=width)
        s = h.summary()
        assert s["mean"] == pytest.approx(vals.mean(), rel=1e-6)
        assert s["min"] == pytest.approx(vals.min(), rel=1e-6)
        assert s["max"] == pytest.approx(vals.max(), rel=1e-6)

    def test_block_per_row_equals_scattered_add(self):
        rng = np.random.default_rng(2)
        C, B = 500, 7
        block = rng.uniform(0.0, 1.2, (C, B))  # overflow bin exercised
        edges = np.linspace(0.0, 1.0, 26)
        fast = StreamingHistogram(edges, (B,))
        fast.add_block_per_row(block)
        slow = StreamingHistogram(edges, (B,))
        rows = np.arange(B)
        for c in range(C):
            slow.add(block[c], rows=rows)
        np.testing.assert_array_equal(fast.counts, slow.counts)
        np.testing.assert_array_equal(fast.n, slow.n)
        np.testing.assert_allclose(fast.sum, slow.sum, rtol=1e-12)
        np.testing.assert_array_equal(fast.vmin, slow.vmin)
        np.testing.assert_array_equal(fast.vmax, slow.vmax)

    def test_merge_and_flat(self):
        edges = np.linspace(0.0, 1.0, 11)
        a = StreamingHistogram(edges)
        b = StreamingHistogram(edges)
        a.add([0.1, 0.2])
        b.add([0.8, 0.9])
        a.merge(b)
        assert float(a.n) == 4
        assert a.summary()["max"] == pytest.approx(0.9)

    def test_gauge_block_equals_sequential(self):
        rng = np.random.default_rng(3)
        block = rng.normal(size=(40, 5))
        g1, g2 = GaugeArray(5), GaugeArray(5)
        g1.observe_block(block)
        for row in block:
            g2.observe(row)
        for attr in ("last", "min", "max", "count"):
            np.testing.assert_array_equal(getattr(g1, attr),
                                          getattr(g2, attr))
        np.testing.assert_allclose(g1.sum, g2.sum, rtol=1e-12)


class TestTraceRoundTrip:
    def test_save_load_validate(self, tmp_path):
        tr = SpanTracer()
        with tr.span("outer", rows=4):
            with tr.span("inner"):
                pass
            tr.instant("marker", note="hi")
        path = str(tmp_path / "trace.json")
        tr.save(path)
        payload = load_trace(path)
        events = validate_trace(payload)
        names = {e["name"] for e in events}
        assert names == {"outer", "inner", "marker"}
        outer = next(e for e in events if e["name"] == "outer")
        assert outer["args"] == {"rows": 4}
        assert payload["displayTimeUnit"] == "ms"

    def test_validate_rejects_malformed(self):
        with pytest.raises(ValueError, match="traceEvents"):
            validate_trace({})
        with pytest.raises(ValueError, match="missing"):
            validate_trace({"traceEvents": [{"name": "x", "ph": "X"}]})

    def test_disabled_tracer_collects_nothing(self):
        tr = SpanTracer(enabled=False)
        with tr.span("ignored"):
            tr.instant("also ignored")
        assert tr.events == []


class TestExport:
    def test_report_round_trip(self, tmp_path):
        col = Collector()
        col.record_upload_times("fcfs", 0.8, [1.0, 2.0, 3.0])
        col.record_staleness([0, 0, 2])
        col.counter("bits").add(42.0)
        col.record_round(round=0, sync_time=1.5)
        report = col.report()
        path = str(tmp_path / "summary.json")
        report.save_json(path)
        with open(path) as f:
            loaded = json.load(f)
        assert loaded["counters"]["bits"] == 42.0
        assert loaded["staleness"] == {"0": 2.0, "2": 1.0}
        assert loaded["delay_percentiles"]["fcfs@load0.8"]["n"] == 3.0
        assert loaded["rounds"] == [{"round": 0, "sync_time": 1.5}]
        # CSV artifact: header + one row per phase (none here)
        report.save_csv(str(tmp_path / "summary.csv"))

    def test_event_log_jsonl_and_echo(self, tmp_path, capsys):
        path = str(tmp_path / "events.jsonl")
        log = EventLog(jsonl_path=path)
        log.emit("step", echo="round {round} step {step}: loss={loss:.4f}",
                 round=1, step=0, loss=0.25)
        log.emit("round", round=1, loss=0.25)       # silent
        log.close()
        assert capsys.readouterr().out == "round 1 step 0: loss=0.2500\n"
        events = [json.loads(line) for line in open(path)]
        assert [e["event"] for e in events] == ["step", "round"]
        assert events[0]["loss"] == 0.25
        assert all("ts" in e for e in events)


@pytest.mark.slow
class TestTrainJsonlSmoke:
    def test_train_writes_structured_events(self, tmp_path):
        from repro.launch.train import train

        jsonl = str(tmp_path / "train.jsonl")
        trace = str(tmp_path / "train_trace.json")
        train(
            arch="olmo-1b", smoke=True, steps_per_round=1, rounds=1,
            n_pods=1, global_batch=2, seq_len=16,
            log_jsonl=jsonl, trace_path=trace,
        )
        events = [json.loads(line) for line in open(jsonl)]
        kinds = [e["event"] for e in events]
        for expected in ("mesh", "payload", "step", "round", "done",
                         "metrics"):
            assert expected in kinds, (expected, kinds)
        summary = events[kinds.index("metrics")]["summary"]
        assert "phases" in summary and "delay_percentiles" in summary
        validate_trace(load_trace(trace))
